# Test tiers.  `make smoke` is the tier-1 inner loop (<60 s): core
# semantics, kernel parity smoke, golden regressions, roofline.  `make test`
# is the full suite (~10 min; the slow tier spawns multi-device
# subprocesses and training loops).  `make lint` runs ruff when installed
# plus the stdlib fallback linter (tools/lint.py) always, so the gate works
# in the minimal container too.  `make bench` runs the fused-macro
# benchmark — including the activity-gating density sweep — writes the
# machine-readable perf-trajectory records CI uploads per PR, and
# validates their schema.  `make bench-check` additionally gates clean-path
# regressions against the committed BENCH_fused_macro.json (>20 %
# normalized median fails; see tools/check_bench.py).

PYTHONPATH_SRC := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH))
PYTEST := $(PYTHONPATH_SRC) python -m pytest
LINT_PATHS := src tests benchmarks examples tools

.PHONY: smoke train-smoke serve-smoke chaos-smoke obs-smoke test lint \
	bench bench-check tune tune-smoke

# `smoke`, `train-smoke`, and `serve-smoke` partition the fast tier
# (silicon-training tests are owned by `train-smoke`, serving-engine and
# preemption tests by `serve-smoke`), so CI can run all three without
# executing anything twice; together they are the whole tier-1 set.
smoke:
	$(PYTEST) -q -m "fast and not slow" \
		--ignore=tests/test_silicon_train.py \
		--ignore=tests/test_serve_engine.py \
		--ignore=tests/test_serve_preempt.py

# Tier-1 silicon-training gate: the 20-step loss-decrease smoke plus the
# fast-marked gradient-parity subset of tests/test_silicon_train.py.
train-smoke:
	$(PYTEST) -q -m "fast and not slow" tests/test_silicon_train.py

# Tier-1 serving gate: continuous-batching engine parity (bitwise vs the
# one-shot forward, clean and noisy), scheduler/bucketing bugfix pins,
# the BatchedEngine rng/round accounting tests, and the preemptive-
# scheduling suite (checkpoint/restore parity, shedding, validation).
serve-smoke:
	$(PYTEST) -q -m "fast and not slow" tests/test_serve_engine.py \
		tests/test_serve_preempt.py

# Chaos gate: adversarial serving traces (oversized bursts, malformed
# tensors, randomized mid-round preemptions, hog+shorts fairness,
# deadline storms) with hard invariant assertions; nonzero on violation.
chaos-smoke:
	$(PYTHONPATH_SRC) python tools/chaos_serve.py --smoke

# Observability gate: a traced 6-request engine run must export a
# Perfetto-loadable timeline (slot residency + scheduler phases +
# checkpoint transfers) whose metric counters equal the engine ledgers;
# the exported file is then re-validated by the standalone checker.
obs-smoke:
	$(PYTHONPATH_SRC) python tools/obs_report.py --smoke \
		--trace-out /tmp/obs_smoke_trace.json \
		--metrics-out /tmp/obs_smoke_metrics.json
	python tools/obs_report.py --check /tmp/obs_smoke_trace.json

test:
	$(PYTEST) -x -q

lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check $(LINT_PATHS); \
	else \
		echo "ruff not installed; stdlib fallback only (CI runs ruff)"; \
	fi
	python tools/lint.py $(LINT_PATHS)

bench:
	$(PYTHONPATH_SRC) python benchmarks/bench_fused_macro.py \
		--out BENCH_fused_macro.json
	python tools/check_bench.py BENCH_fused_macro.json

bench-check:
	@cp BENCH_fused_macro.json /tmp/bench_baseline.json
	$(MAKE) bench
	python tools/check_bench.py BENCH_fused_macro.json \
		--baseline /tmp/bench_baseline.json

# Regenerate the persistent tile-plan cache (PLAN_CACHE_fused_macro.json):
# autotune the canonical launch shapes on this machine and persist the
# winners plan_tiles will consume.  OBJECTIVE: ms | pj_per_sop | blend.
OBJECTIVE := ms
tune:
	$(PYTHONPATH_SRC) python tools/tune_plans.py --objective $(OBJECTIVE)

# CI smoke for the tune subsystem: one tiny cell, 2 timed iters, written
# to a throwaway path, asserting the cache round-trips into plan_tiles.
tune-smoke:
	$(PYTHONPATH_SRC) python tools/tune_plans.py --smoke \
		--out /tmp/plan_cache_smoke.json
