# Test tiers.  `make smoke` is the tier-1 inner loop (<60 s): core
# semantics, kernel parity smoke, golden regressions, roofline.  `make test`
# is the full suite (~10 min; the slow tier spawns multi-device
# subprocesses and training loops).

PYTEST := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest

.PHONY: smoke test

smoke:
	$(PYTEST) -q -m "fast and not slow"

test:
	$(PYTEST) -x -q
