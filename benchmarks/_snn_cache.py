"""Shared SNN training cache for the accuracy benchmarks (Figs. 5b/6c/8,
Table I): each (dataset, mode, train_nlq) model is trained once and memoized
to disk so the benchmark suite doesn't retrain per figure."""

from __future__ import annotations

import os
import pickle

import jax

from repro.data import events as ev_lib
from repro.models import snn

CACHE_DIR = os.path.join(os.path.dirname(__file__), ".cache")

TRAIN_STEPS = int(os.environ.get("REPRO_SNN_TRAIN_STEPS", "350"))
EVAL_BATCHES = int(os.environ.get("REPRO_SNN_EVAL_BATCHES", "6"))

_KWN_K = {"nmnist": 3, "dvs_gesture": 12, "quiroga": 6}
_ACT = {"nmnist": "quadratic", "dvs_gesture": "relu", "quiroga": "sigmoid4"}


def snn_config(dataset: str, mode: str, train_nlq: bool = True) -> snn.SNNConfig:
    d = ev_lib.DATASETS[dataset]
    return snn.SNNConfig(
        n_in=d.n_in, n_steps=d.n_steps, n_classes=d.n_classes,
        mode=mode, k=_KWN_K[dataset], activation=_ACT[dataset],
        train_nlq=train_nlq)


def trained_model(dataset: str, mode: str, train_nlq: bool = True,
                  seed: int = 0):
    os.makedirs(CACHE_DIR, exist_ok=True)
    tag = f"{dataset}_{mode}_nlq{int(train_nlq)}_s{seed}_t{TRAIN_STEPS}"
    path = os.path.join(CACHE_DIR, tag + ".pkl")
    cfg = snn_config(dataset, mode, train_nlq)
    ds = ev_lib.EventDataset(ev_lib.DATASETS[dataset])
    if os.path.exists(path):
        with open(path, "rb") as f:
            p = pickle.load(f)
        return p, cfg, ds
    # per-cell training budget: the quadratic NLD cell degrades past ~350
    # steps (ramp-knee gradient spikes), the relu NLD (dvs) keeps improving.
    steps = TRAIN_STEPS
    if mode == "nld" and dataset == "dvs_gesture":
        steps = TRAIN_STEPS * 2
    p, losses = snn.train(cfg, ds, n_steps=steps, batch=64, seed=seed, lr=0.1)
    with open(path, "wb") as f:
        pickle.dump(jax.tree.map(lambda x: __import__("numpy").asarray(x), p), f)
    return p, cfg, ds


def eval_model(p, cfg, ds, seed: int = 1, **kw):
    return snn.evaluate(p, cfg, ds, jax.random.PRNGKey(seed),
                        n_batches=EVAL_BATCHES, **kw)
