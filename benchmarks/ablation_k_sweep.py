"""Beyond-paper K-sweep: the KWN winner count K trades accuracy against
ADC/LIF latency and energy (the paper reports K=3 and K=12 points; we sweep
the whole curve on the synthetic stand-ins using the cached trained models).

For each K: silicon accuracy, measured early-stop ADC steps, LIF updates,
and the calibrated pJ/SOP — the full efficiency/accuracy frontier of Eq. (1).
"""

from __future__ import annotations

from benchmarks import _snn_cache as C
from repro.core import energy

KS = (1, 3, 6, 12, 24, 48)


def run() -> dict:
    out = {}
    for ds_name in ("nmnist", "dvs_gesture"):
        p, cfg, ds = C.trained_model(ds_name, "kwn", train_nlq=True)
        rate = energy.SPIKE_RATES[ds_name]
        curve = []
        for k in KS:
            acc, tele = C.eval_model(p, cfg, ds, k=k)
            curve.append({
                "k": k,
                "acc": round(acc, 4),
                "mean_adc_steps": round(tele["adc_steps"], 2),
                "adc_saving_measured": round(1 - tele["adc_steps"] / 31, 3),
                "lif_updates": tele["lif_updates"],
                "lif_speedup": round(128 / k, 1),
                "pj_per_sop_model": round(energy.kwn_pj_per_sop(k, rate), 3),
            })
        out[ds_name] = curve
        # knee: smallest K within 1% of the best accuracy in the sweep
        best = max(c["acc"] for c in curve)
        knee = next(c for c in curve if c["acc"] >= best - 0.01)
        out[f"{ds_name}_knee"] = {"k": knee["k"], "acc": knee["acc"],
                                  "pj_per_sop": knee["pj_per_sop_model"]}
    out["note"] = ("paper operating points: K=3 (N-MNIST), K=12 (DVS "
                   "Gesture); the sweep shows where those sit on the "
                   "accuracy/energy frontier")
    return out
