"""Beyond-paper ablation: the macro's mechanisms applied to LM training.

Three questions the paper's ideas raise at LM scale, answered on a reduced
smolLM/MoE config (CPU-runnable):
  1. KWN-FFN — Eq. (1) winner sparsity on FFN hidden units: how much loss do
     we give up at k = 12.5% / 25% of units vs dense?
  2. CIM mode — ternary twin-cell weights + NLQ activations on every
     projection (C1+C2): trainable? loss gap vs fp?
  3. SNL router rescue — the sensitive-neuron probabilistic rescue (C5)
     applied to MoE routing: does load balance (aux loss) improve?
"""

from __future__ import annotations

import dataclasses

import jax

from repro.configs import ARCHS
from repro.configs.base import reduced
from repro.data.synthetic_lm import DataConfig, SyntheticLM
from repro.models import lm
from repro.nn import module, moe
from repro.train import optim, train_loop

STEPS = 40


def _train(cfg, seed=0, steps=STEPS):
    ocfg = optim.AdamWConfig(lr=5e-3, warmup_steps=4, total_steps=steps)
    params = module.materialize(lm.param_specs(cfg), jax.random.PRNGKey(seed))
    opt = optim.adamw_init(params, ocfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 64, 8, seed=seed))
    step = jax.jit(train_loop.build_train_step(cfg, None, n_micro=2,
                                               opt_cfg=ocfg))
    losses = []
    for i in range(steps):
        params, opt, m = step(params, opt, data.batch_at(i, n_micro=2))
        losses.append(float(m["loss"]))
    return losses


def run() -> dict:
    base = reduced(ARCHS["smollm-135m"])
    out = {}

    dense = _train(base)
    out["ffn_dense_final_loss"] = round(dense[-1], 4)
    for k, tag in ((16, "kwn_ffn_k16(12.5%)"), (32, "kwn_ffn_k32(25%)")):
        l = _train(dataclasses.replace(base, kwn_ffn_k=k))
        out[f"{tag}_final_loss"] = round(l[-1], 4)
        out[f"{tag}_gap_vs_dense"] = round(l[-1] - dense[-1], 4)

    cim = _train(dataclasses.replace(base, cim_linear=True))
    out["cim_mode_final_loss"] = round(cim[-1], 4)
    out["cim_mode_gap_vs_dense"] = round(cim[-1] - dense[-1], 4)
    out["cim_mode_trains"] = bool(cim[-1] < cim[0])

    # SNL-style router rescue on a small MoE layer (direct measurement)
    key = jax.random.PRNGKey(0)
    d, e, kk, t = 32, 8, 2, 512
    p = {
        "router": jax.random.normal(key, (d, e)) * 0.5,
        "w_in": jax.random.normal(jax.random.fold_in(key, 1), (e, d, 64)),
        "w_gate": jax.random.normal(jax.random.fold_in(key, 2), (e, d, 64)),
        "w_out": jax.random.normal(jax.random.fold_in(key, 3), (e, 64, d)),
    }
    x = jax.random.normal(jax.random.fold_in(key, 4), (1, t, d))
    _, aux0 = moe.moe_ref(p, x, k=kk)
    _, aux1 = moe.moe_ref(p, x, k=kk, snl_rescue=0.05,
                          rng=jax.random.PRNGKey(7))
    out["router_aux_balance_no_snl"] = round(float(aux0), 4)
    out["router_aux_balance_snl"] = round(float(aux1), 4)
    out["snl_improves_balance"] = bool(aux1 < aux0)
    return out
