"""Fused macro-step kernel vs the composed kernel chain.

Wall-clock: one fused Pallas kernel (MAC -> IMA -> KWN -> LIF, VMEM-resident)
against the four-kernel composed path (``ternary_mac`` -> ``nlq_convert`` ->
``kwn_topk`` -> ``lif_step``) that round-trips every intermediate through HBM.
Default geometry is the paper's physical macro: 256 rows x 128 columns.

Also emits the measured KWN early-stop step statistics (histogram + mean) the
energy model consumes — the fused kernel reports them per row, so the energy
figures below come from *measured* ramp activity, not the analytic fit.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy, ima as ima_lib
from repro.kernels import ops

M, N_IN, N_OUT = 128, 256, 128   # batch x the physical macro geometry
K_WIN = 12
CODE_BITS = 5
DRIVE_GAIN = 0.25


SPIKE_RATE = 0.05   # event-stream duty cycle: MACs land inside the ramp range


def _operands(key):
    ks = jax.random.split(key, 7)
    tern = lambda k, s: jax.random.randint(k, s, -1, 2).astype(jnp.int8)
    sparse = (jax.random.uniform(ks[6], (M, N_IN)) < SPIKE_RATE)
    x = (tern(ks[0], (M, N_IN)) * sparse).astype(jnp.int8)
    msb, lsb = tern(ks[1], (N_IN, N_OUT)), tern(ks[2], (N_IN, N_OUT))
    cb = ima_lib.nlq_codebook(CODE_BITS, -24, 24)
    scale = jax.random.uniform(ks[3], (N_OUT,), minval=0.05, maxval=0.3)
    v = jax.random.normal(ks[4], (M, N_OUT)) * 0.5
    noise = 0.05 * jnp.sign(jax.random.normal(ks[5], (M, N_OUT)))
    return x, msb, lsb, cb, scale, v, noise


def _composed_step(x, msb, lsb, cb, scale, v, noise):
    """The pre-fusion pipeline: four kernels, three HBM round trips."""
    mac = ops.ternary_mac(x, msb, lsb)
    _, mac_q = ops.nlq_convert(mac, cb.boundaries, cb.levels)
    mask, steps = ops.kwn_topk(mac, cb.boundaries, K_WIN)
    drive = mac_q * scale * mask * DRIVE_GAIN
    v_out, spikes = ops.lif_step(v, drive, mask, noise)
    return v_out, spikes, mask, steps


def _fused_step(x, msb, lsb, cb, scale, v, noise):
    _, v_out, spikes, mask, steps = ops.fused_macro_step(
        x, msb, lsb, cb.boundaries, cb.levels, scale, v, noise,
        mode="kwn", k=K_WIN, drive_gain=DRIVE_GAIN)
    return v_out, spikes, mask, steps


def _time(fn, args, iters: int = 20) -> float:
    out = fn(*args)                       # compile + warm up
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> dict:
    x, msb, lsb, cb, scale, v, noise = _operands(jax.random.PRNGKey(0))
    args = (x, msb, lsb, cb, scale, v, noise)

    fused = _fused_step(*args)
    composed = _composed_step(*args)
    parity = {
        "v_mem_equal": bool(jnp.array_equal(fused[0], composed[0])),
        "mask_equal": bool(jnp.array_equal(fused[2], composed[2])),
        "steps_equal": bool(jnp.array_equal(fused[3], composed[3])),
    }

    us_fused = _time(_fused_step, args)
    us_composed = _time(_composed_step, args)

    # Early-stop statistics the energy model consumes (measured, per row).
    steps = np.asarray(fused[3]).reshape(-1)
    full = 2 ** CODE_BITS - 1
    hist = np.bincount(steps, minlength=full + 1)
    mean_steps = float(steps.mean())
    saving = 1.0 - mean_steps / full
    e_model = energy.kwn_step_energy(K_WIN, energy.SPIKE_RATES["dvs_gesture"])

    return {
        "geometry": f"{N_IN}x{N_OUT}", "batch": M, "k": K_WIN,
        "us_fused": round(us_fused, 1),
        "us_composed": round(us_composed, 1),
        "speedup": round(us_composed / us_fused, 2),
        "parity": parity,
        "early_stop": {
            "mean_adc_steps": round(mean_steps, 2),
            "full_ramp_steps": full,
            "measured_saving": round(saving, 3),
            "model_saving_k12": round(energy.early_stop_saving(K_WIN), 3),
            "step_histogram": hist.tolist(),
        },
        "energy_model_pj_per_step": round(e_model.total, 1),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
