"""Fused macro kernel vs the composed kernel chain, across three axes.

1. **step**: one fused Pallas kernel (MAC -> IMA -> KWN -> LIF,
   VMEM-resident) against the four-kernel composed path (``ternary_mac`` ->
   ``nlq_convert`` -> ``kwn_topk`` -> ``lif_step``) that round-trips every
   intermediate through HBM.  Geometry: the paper's physical macro,
   256 rows x 128 columns.
2. **large_layer**: the same comparison on a 512x256 layer (2x2 virtual
   macro grid) — the fused path now tiles rows/columns *inside* the kernel
   (digital partial-sum accumulation) instead of falling back to the
   composed chain.
3. **sequence**: a T-step event stream through (a) one time-major fused
   launch (T folded into the kernel grid, LIF membrane carried in VMEM),
   (b) a jitted scan of per-step fused launches (the PR 1 cadence), and
   (c) eager per-step launches (the streaming cadence where every time
   step pays Python dispatch + kernel setup — what an event-driven server
   pays when it cannot batch the sequence).
4. **noisy**: the same time-major launch under the in-kernel Fig. 7 IMA
   error model (counter-PRNG draws generated inside the kernel) vs the
   clean launch — the cost of noise-faithful serving — with a bitwise
   parity check against the counter-based ``ref.py`` noisy oracle and the
   KWN early-stop histogram under noise next to the clean one.
5. **density sweep**: activity-gated vs dense execution at 1 %, 5 %, 10 %,
   25 %, 50 % and fully dense event rates, on both the single-step and the
   time-major sequence shapes.  The *dense* side is the pre-sparsity
   pipeline exactly (``gate=False``, raw-MAC telemetry on); the *gated*
   side is the serving default (occupancy-gated MAC, bounded KWN sweep,
   telemetry off).  Sequence events follow a bursty DVS-like model (a
   density-d stream is silent steps + active steps at ~20 % in-burst
   rate — the temporal structure real event cameras produce and the
   activity planner exploits); single-step events are uniform.  Gated
   outputs are parity-checked against the ``ref.py`` oracles at every
   density, and each entry reports the measured skipped-block ratio.

6. **multilayer**: a 2-layer KWN stack (256x256 -> 256x128) through (a)
   one stacked fused launch (per-layer membranes carried in VMEM, the
   inter-layer spike tensor never written to HBM, layer 1 activity-gated
   in-kernel by layer 0's winner sets) vs (b) the layer-by-layer HBM
   round trip: two sequential single-layer ``fused_macro_seq`` launches
   with the spike stack materialized between them.  Both bitwise-checked
   against the composed per-layer oracle chain.

7. **train step**: one SGD-momentum step through (a) the software BPTT
   path (``forward_train``: dense-f32 scan + STE fake-quant — the
   pre-silicon-training baseline), (b) the silicon path (forward = the
   fused kernel, backward = the time-reversed surrogate BPTT Pallas
   kernel via ``jax.custom_vjp``), and (c) the silicon path under the
   in-kernel Fig. 7 noise model (noise-aware QAT) — the training-side
   cost of gradients that see the serving kernel.

Also emits the measured KWN early-stop step statistics (histogram + mean) the
energy model consumes — the fused kernel reports them per row, so the energy
figures below come from *measured* ramp activity, not the analytic fit.

Run as a script to print the full report; ``--out PATH`` additionally
writes the machine-readable trajectory records (fixed schema: op, shape,
mode, median_ms, speedup, density) that ``make bench`` / CI track per PR as
``BENCH_fused_macro.json`` (``tools/check_bench.py`` validates the schema
and gates clean-path regressions).
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy, ima as ima_lib, macro as macro_lib
from repro.kernels import ops, ref
from repro.tune import measure

M, N_IN, N_OUT = 128, 256, 128   # batch x the physical macro geometry
K_WIN = 12
CODE_BITS = 5
DRIVE_GAIN = 0.25


SPIKE_RATE = 0.05   # event-stream duty cycle: MACs land inside the ramp range

T_SEQ = 32                       # sequence sweep length
LARGE_N_IN, LARGE_N_OUT = 512, 256   # 2x2 virtual macro grid

DENSITIES = (0.01, 0.05, 0.10, 0.25, 0.50, 1.0)
IN_BURST_DENSITY = measure.IN_BURST_DENSITY   # shared with the autotuner


def _operands(key, m=M, n_in=N_IN, n_out=N_OUT, t=None):
    ks = jax.random.split(key, 7)
    tern = lambda k, s: jax.random.randint(k, s, -1, 2).astype(jnp.int8)
    xshape = (m, n_in) if t is None else (t, m, n_in)
    sparse = (jax.random.uniform(ks[6], xshape) < SPIKE_RATE)
    x = (tern(ks[0], xshape) * sparse).astype(jnp.int8)
    msb, lsb = tern(ks[1], (n_in, n_out)), tern(ks[2], (n_in, n_out))
    cb = ima_lib.nlq_codebook(CODE_BITS, -24, 24)
    scale = jax.random.uniform(ks[3], (n_out,), minval=0.05, maxval=0.3)
    v = jax.random.normal(ks[4], (m, n_out)) * 0.5
    nshape = (m, n_out) if t is None else (t, m, n_out)
    noise = 0.05 * jnp.sign(jax.random.normal(ks[5], nshape))
    return x, msb, lsb, cb, scale, v, noise


def _composed_step(x, msb, lsb, cb, scale, v, noise):
    """The pre-fusion pipeline: four kernels, three HBM round trips."""
    mac = ops.ternary_mac(x, msb, lsb)
    _, mac_q = ops.nlq_convert(mac, cb.boundaries, cb.levels)
    mask, steps = ops.kwn_topk(mac, cb.boundaries, K_WIN)
    drive = mac_q * scale * mask * DRIVE_GAIN
    v_out, spikes = ops.lif_step(v, drive, mask, noise)
    return v_out, spikes, mask, steps


def _fused_step(x, msb, lsb, cb, scale, v, noise):
    _, v_out, spikes, mask, steps = ops.fused_macro_step(
        x, msb, lsb, cb.boundaries, cb.levels, scale, v, noise,
        mode="kwn", k=K_WIN, drive_gain=DRIVE_GAIN)
    return v_out, spikes, mask, steps


# The timing loop is the shared instrument in ``repro.tune.measure`` —
# bench medians and autotuner medians come from the same stopwatch, so a
# "tuned beats heuristic" verdict can never be clock-skew.
_time = measure.median_us


def _seq_variants(t=T_SEQ, m=M, n_in=N_IN, n_out=N_OUT):
    """Time-major vs per-step cadences for a whole event sequence."""
    x, msb, lsb, cb, scale, v, noise = _operands(
        jax.random.PRNGKey(1), m=m, n_in=n_in, n_out=n_out, t=t)
    kw = dict(mode="kwn", k=K_WIN, drive_gain=DRIVE_GAIN)

    @jax.jit
    def seq(x, v):
        return ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                   scale, v, noise, **kw)

    @jax.jit
    def step_scan(x, v):
        def body(vc, inp):
            xt, nt = inp
            _, v_out, spk, _, steps = ops.fused_macro_step(
                xt, msb, lsb, cb.boundaries, cb.levels, scale, vc, nt, **kw)
            return v_out, (spk, steps)
        return jax.lax.scan(body, v, (x, noise))

    def step_eager(x, v):
        outs = []
        for tt in range(t):
            _, v, spk, _, steps = ops.fused_macro_step(
                x[tt], msb, lsb, cb.boundaries, cb.levels, scale, v,
                noise[tt], **kw)
            outs.append((spk, steps))
        return v, outs

    args = (x, v)
    ms_seq = _time(seq, args, iters=5) / 1e3
    ms_scan = _time(step_scan, args, iters=5) / 1e3
    ms_eager = _time(step_eager, args, iters=3) / 1e3

    # parity: the three cadences must agree bitwise on the final membrane
    v_seq = seq(x, v)[1]
    v_scan = step_scan(x, v)[0]
    v_eager = step_eager(x, v)[0]
    return {
        "t": t, "batch": m, "geometry": f"{n_in}x{n_out}",
        "ms_time_major": round(ms_seq, 1),
        "ms_per_step_scan": round(ms_scan, 1),
        "ms_per_step_eager": round(ms_eager, 1),
        "steps_per_s_time_major": round(t / (ms_seq / 1e3), 1),
        "steps_per_s_per_step_scan": round(t / (ms_scan / 1e3), 1),
        "speedup_vs_scan": round(ms_scan / ms_seq, 2),
        "speedup_vs_eager_launches": round(ms_eager / ms_seq, 2),
        "parity": {
            "scan_equal": bool(jnp.array_equal(v_seq, v_scan)),
            "eager_equal": bool(jnp.array_equal(v_seq, v_eager)),
        },
    }


def _step_histogram(steps) -> list[int]:
    full = 2 ** CODE_BITS - 1
    return np.bincount(np.asarray(steps).reshape(-1),
                       minlength=full + 1).tolist()


def _noisy_variants(t=T_SEQ, m=M, n_in=N_IN, n_out=N_OUT):
    """Noisy vs clean time-major launches: what noise-faithful serving costs.

    The noisy launch generates every Fig. 7 conversion-error draw (and the
    SNL sign noise) inside the kernel, so it streams exactly the same
    operands as the clean launch — the delta is pure in-VMEM counter-PRNG
    arithmetic.  Parity is checked bitwise against the counter-based noisy
    oracle, and the KWN early-stop histograms are reported side by side
    (noise spreads the code distribution, which shifts where the ramp's
    K-th crossing lands).
    """
    x, msb, lsb, cb, scale, v, _ = _operands(
        jax.random.PRNGKey(3), m=m, n_in=n_in, n_out=n_out, t=t)
    noise_p = ima_lib.kernel_noise_params(ima_lib.IMANoiseModel(), cb)
    kw = dict(mode="kwn", k=K_WIN, drive_gain=DRIVE_GAIN)

    @jax.jit
    def clean(x, v):
        return ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                   scale, v, None, **kw)

    @jax.jit
    def noisy(x, v):
        return ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                   scale, v, None, ima_noise=noise_p,
                                   snl_amp=0.05, seed=7, **kw)

    args = (x, v)
    ms_clean = _time(clean, args, iters=5) / 1e3
    ms_noisy = _time(noisy, args, iters=5) / 1e3

    out_noisy = noisy(x, v)
    want = jax.jit(functools.partial(
        ref.fused_macro_seq_ref, ima_noise=noise_p, snl_amp=0.05, seed=7,
        **kw))(x, msb, lsb, cb.boundaries, cb.levels, scale, v, None)
    want = (want[0], want[1], want[2], want[3], want[4][..., 0])
    parity = bool(all(jnp.array_equal(a, b)
                      for a, b in zip(out_noisy, want)))

    out_clean = clean(x, v)
    clean_steps, noisy_steps = out_clean[4], out_noisy[4]
    return {
        "t": t, "batch": m, "geometry": f"{n_in}x{n_out}",
        "ms_clean": round(ms_clean, 1),
        "ms_noisy": round(ms_noisy, 1),
        "noise_overhead": round(ms_noisy / ms_clean, 2),
        "parity_vs_noisy_oracle": parity,
        "early_stop": {
            "clean_mean_steps": round(float(np.asarray(clean_steps).mean()),
                                      2),
            "noisy_mean_steps": round(float(np.asarray(noisy_steps).mean()),
                                      2),
            "clean_step_histogram": _step_histogram(clean_steps),
            "noisy_step_histogram": _step_histogram(noisy_steps),
        },
    }


# Bursty DVS-like stream generator — also the shared instrument (the
# autotuner must see the same temporal structure the sweep below sees).
_event_stream = measure.event_stream


def _density_sweep(t=T_SEQ, m=M, n_in=N_IN, n_out=N_OUT):
    """Activity-gated vs dense fused execution across event densities.

    The dense side is the pre-sparsity hot path verbatim (``gate=False``,
    raw-MAC telemetry on); the gated side is the serving default
    (``gate=True``, telemetry off).  Gated (v_mem, spikes, mask,
    adc_steps) are checked equal to the jitted ``ref.py`` seq oracle at
    every density — gating is a pure execution optimization, so any
    mismatch is a bug, not a tolerance.
    """
    keys = jax.random.split(jax.random.PRNGKey(5), 6)
    tern = lambda k, s: jax.random.randint(k, s, -1, 2).astype(jnp.int8)
    msb, lsb = tern(keys[0], (n_in, n_out)), tern(keys[1], (n_in, n_out))
    cb = ima_lib.nlq_codebook(CODE_BITS, -24, 24)
    scale = jax.random.uniform(keys[2], (n_out,), minval=0.05, maxval=0.3)
    v = jax.random.normal(keys[3], (m, n_out)) * 0.5
    kw = dict(mode="kwn", k=K_WIN, drive_gain=DRIVE_GAIN)

    # v rides as an argument everywhere (never a jit-closure constant):
    # XLA constant-folds closed-over f32 operands with different
    # contraction than runtime ops, which breaks bitwise oracle parity.
    @functools.partial(jax.jit, static_argnames=("gate",))
    def run_seq(x, v, gate):
        return ops.fused_macro_seq(
            x, msb, lsb, cb.boundaries, cb.levels, scale, v, None,
            gate=gate, mac_telemetry=not gate, **kw)[1:]

    @functools.partial(jax.jit, static_argnames=("gate",))
    def run_step(x, v, gate):
        return ops.fused_macro_step(
            x, msb, lsb, cb.boundaries, cb.levels, scale, v, None,
            gate=gate, mac_telemetry=not gate, **kw)[1:]

    oracle_seq = jax.jit(functools.partial(ref.fused_macro_seq_ref, **kw))
    oracle_step = jax.jit(functools.partial(ref.fused_macro_step_ref, **kw))

    def entry(x, runner, oracle, iters):
        from repro.kernels import fused_macro as fused_kernel
        ms_dense = _time(lambda x: runner(x, v, gate=False), (x,),
                         iters=iters) / 1e3
        ms_gated = _time(lambda x: runner(x, v, gate=True), (x,),
                         iters=iters) / 1e3
        got = runner(x, v, gate=True)
        want = oracle(x, msb, lsb, cb.boundaries, cb.levels, scale, v, None)
        want = (want[1], want[2], want[3], want[4][..., 0])
        parity = bool(all(jnp.array_equal(a, b)
                          for a, b in zip(got, want)))
        xs = x if x.ndim == 3 else x[None]
        plan = fused_kernel.plan_tiles(m, n_in, n_out, n_out, xs.shape[0])
        occ = ops.fused_activity_map(
            jnp.pad(xs, ((0, 0), (0, plan.m_pad - m),
                         (0, plan.k_pad - n_in))), plan)
        return {
            "measured_density": round(float((x != 0).mean()), 4),
            "skipped_block_ratio": round(1.0 - float(occ.mean()), 4),
            "ms_dense": round(ms_dense, 2),
            "ms_gated": round(ms_gated, 2),
            "speedup": round(ms_dense / ms_gated, 2),
            "parity_vs_oracle": parity,
        }

    seq_entries, step_entries = [], []
    for i, d in enumerate(DENSITIES):
        kd = jax.random.fold_in(keys[4], i)
        x_seq = _event_stream(kd, d, (t, m, n_in))
        seq_entries.append({"density": d,
                            **entry(x_seq, run_seq, oracle_seq, iters=9)})
        x_step = _event_stream(jax.random.fold_in(keys[5], i), d, (m, n_in))
        step_entries.append({"density": d,
                             **entry(x_step, run_step, oracle_step,
                                     iters=15)})
    return {
        "geometry": f"{n_in}x{n_out}", "batch": m, "t": t,
        "in_burst_density": IN_BURST_DENSITY,
        "seq": seq_entries,
        "step": step_entries,
    }


TRAIN_M, TRAIN_N_IN, TRAIN_N_OUT, TRAIN_T = 64, 256, 128, 16


def _train_variants(m=TRAIN_M, n_in=TRAIN_N_IN, n_out=TRAIN_N_OUT,
                    t=TRAIN_T):
    """Train-step throughput: fused-VJP silicon training vs software BPTT.

    One full SGD-momentum step each (loss + grad + update, jitted):
    the software path back-propagates through the dense-f32 scan; the
    silicon paths run the fused kernel forward and the surrogate backward
    kernel (clean, and under the in-kernel Fig. 7 noise model — the
    noise-aware QAT configuration).  ``train_step`` donates its parameter
    buffers, so the timed closures copy them first — identical overhead on
    every variant, negligible next to the step itself.
    """
    from repro.core import ima as ima_mod
    from repro.models import snn

    cfg = snn.SNNConfig(n_in=n_in, n_hidden=n_out, n_classes=10,
                        n_steps=t, mode="kwn", k=K_WIN)
    key = jax.random.PRNGKey(11)
    k1, k2, k3 = jax.random.split(key, 3)
    ev = _event_stream(k1, 0.05, (t, m, n_in)).astype(jnp.float32)
    ev = jnp.moveaxis(ev, 0, 1)                       # (B, T, N_in)
    lab = jax.random.randint(k2, (m,), 0, 10)
    p0 = snn.init_params(cfg, k3)
    m0 = jax.tree.map(jnp.zeros_like, p0)
    lr = jnp.float32(0.05)
    seed = jnp.float32(9.0)
    noise = ima_mod.IMANoiseModel()

    def run(**kw):
        def step():
            pp = jax.tree.map(jnp.copy, p0)
            mm = jax.tree.map(jnp.copy, m0)
            return snn.train_step(pp, mm, ev, lab, cfg, lr, **kw)
        return step

    bptt = run()
    silicon = run(seed=seed, silicon=True)
    silicon_noisy = run(seed=seed, silicon=True, noise=noise)
    ms_bptt = _time(bptt, (), iters=5) / 1e3
    ms_sil = _time(silicon, (), iters=5) / 1e3
    ms_noisy = _time(silicon_noisy, (), iters=5) / 1e3
    loss0 = float(bptt()[2])
    loss_sil = float(silicon()[2])
    return {
        "batch": m, "geometry": f"{n_in}x{n_out}", "t": t,
        "ms_bptt": round(ms_bptt, 1),
        "ms_silicon_vjp": round(ms_sil, 1),
        "ms_silicon_vjp_noisy": round(ms_noisy, 1),
        "silicon_vs_bptt": round(ms_bptt / ms_sil, 2),
        "noise_overhead": round(ms_noisy / ms_sil, 2),
        "loss_bptt": round(loss0, 3),
        "loss_silicon": round(loss_sil, 3),
    }


ML_WIDTHS = (256, 128)   # 2-layer stack: 256x256 -> 256x128
ML_T = 16


def _multilayer_variants(t=ML_T, m=M, n_in=N_IN, widths=ML_WIDTHS):
    """Stacked 2-layer fused launch vs the layer-by-layer HBM round trip.

    Three cadences for the same 2-layer KWN network over a T-step stream:

    * **fused stack** — one Pallas launch for all layers and steps; the
      inter-layer spike tensor lives in registers, layer 1's activity is
      layer 0's winner set evaluated in-kernel;
    * **composed round trip** — the pre-fusion pipeline per layer per step
      (``ternary_mac`` -> ``nlq_convert`` -> ``kwn_topk`` -> ``lif_step``
      under one jitted scan): every stage intermediate AND every
      inter-layer spike tensor round-trips through HBM — the baseline the
      ISSUE's >=1.2x floor gates on, and the direct depth generalization
      of this bench's canonical ``composed_step`` row;
    * **per-layer fused launches** — two sequential single-layer
      ``fused_macro_seq`` launches with the spike stack materialized and
      re-activity-planned between them (the best the single-layer kernel
      can do for depth; reported as supplementary detail — on the
      interpret-mode CPU its compute is identical to the stack's, so the
      gap there is launch/interchange overhead only).

    All three are checked bitwise against the composed per-layer oracle
    chain (``ref.fused_macro_multi_seq_ref``).
    """
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 4)
    tern = lambda k, s: jax.random.randint(k, s, -1, 2).astype(jnp.int8)
    x = _event_stream(ks[0], 0.05, (t, m, n_in))
    cb = ima_lib.nlq_codebook(CODE_BITS, -24, 24)
    planes, f_in = [], n_in
    for li, w in enumerate(widths):
        planes.append((tern(jax.random.fold_in(ks[1], li), (f_in, w)),
                       tern(jax.random.fold_in(ks[2], li), (f_in, w)),
                       cb.boundaries, cb.levels,
                       jax.random.uniform(jax.random.fold_in(ks[3], li),
                                          (w,), minval=0.05, maxval=0.3)))
        f_in = w
    vs = [jnp.zeros((m, w)) for w in widths]
    noises = [jnp.zeros((t, m, w)) for w in widths]
    win = (K_WIN,) * len(widths)

    def fused(x, v1, v2, n1, n2):
        # ops.fused_macro_multi_seq is jitted internally; x/v/noise ride
        # as arguments (never closure constants — see the sweep note)
        out = ops.fused_macro_multi_seq(x, planes, [v1, v2], [n1, n2],
                                        ks=win, drive_gain=DRIVE_GAIN)
        return out.v_outs, out.spikes

    @jax.jit
    def composed(x, v1, v2, n1, n2):
        def body(carry, inp):
            vs_c = carry
            cur, new_vs = inp[0], []
            for (msb, lsb, bounds, levels, scale), v, nz in zip(
                    planes, vs_c, inp[1:]):
                mac = ops.ternary_mac(cur, msb, lsb)
                _, mac_q = ops.nlq_convert(mac, bounds, levels)
                mask, steps = ops.kwn_topk(mac, bounds, K_WIN)
                drive = mac_q * scale * mask * DRIVE_GAIN
                v, cur = ops.lif_step(v, drive, mask, nz)
                new_vs.append(v)
            return tuple(new_vs), cur
        (v1o, v2o), spk = jax.lax.scan(body, (v1, v2),
                                       (x, noises[0], noises[1]))
        return (v1o, v2o), spk

    def per_layer(x, v1, v2, n1, n2):
        p1, p2 = planes
        _, v1o, spk1, _, _ = ops.fused_macro_seq(
            x, p1[0], p1[1], p1[2], p1[3], p1[4], v1, n1, mode="kwn",
            k=K_WIN, drive_gain=DRIVE_GAIN, mac_telemetry=False)
        _, v2o, spk2, _, _ = ops.fused_macro_seq(
            spk1.astype(jnp.int8), p2[0], p2[1], p2[2], p2[3], p2[4], v2,
            n2, mode="kwn", k=K_WIN, drive_gain=DRIVE_GAIN,
            mac_telemetry=False)
        return (v1o, v2o), spk2

    args = (x, vs[0], vs[1], noises[0], noises[1])
    ms_fused = _time(fused, args, iters=5) / 1e3
    ms_composed = _time(composed, args, iters=5) / 1e3
    ms_layer = _time(per_layer, args, iters=5) / 1e3

    vf, spk_f = fused(*args)
    vc, spk_c = composed(*args)
    vl, spk_l = per_layer(*args)
    want_v, want_spk, *_ = ref.fused_macro_multi_seq_ref(
        x, planes, vs, noises, ks=win, drive_gain=DRIVE_GAIN)

    def _eq(vres, spk):
        return bool(jnp.array_equal(spk, want_spk)
                    and all(jnp.array_equal(a, b)
                            for a, b in zip(vres, want_v)))

    parity = {
        "fused_vs_oracle": _eq(vf, spk_f),
        "composed_vs_oracle": _eq(vc, spk_c),
        "per_layer_vs_oracle": _eq(vl, spk_l),
    }
    return {
        "t": t, "batch": m,
        "geometry": f"{n_in}x{'x'.join(str(w) for w in widths)}",
        "layers": len(widths),
        "ms_fused_stack": round(ms_fused, 1),
        "ms_layer_roundtrip": round(ms_composed, 1),
        "ms_per_layer_launches": round(ms_layer, 1),
        "speedup_vs_roundtrip": round(ms_composed / ms_fused, 2),
        "speedup_vs_per_layer_launches": round(ms_layer / ms_fused, 2),
        "parity": parity,
    }


SERVE_SLOTS, SERVE_ROUND = 8, 8
# 10 distinct stream lengths, two arrivals each: realistic event traffic
# does not quantize to a handful of durations, so the drain engine's
# length buckets stay thin (10 launches, most under-filled) while the
# continuous engine packs every round from the same pool of slots.
SERVE_LENGTHS = (8, 10, 12, 14, 16, 18, 20, 24, 28, 32) * 2
SERVE_DENSITIES = (0.02, 0.05, 0.2)


def _serve_trace(key, n_in):
    """Mixed-length, mixed-density arrival trace for the serving bench."""
    reqs = []
    for i, t in enumerate(SERVE_LENGTHS):
        d = SERVE_DENSITIES[i % len(SERVE_DENSITIES)]
        ev = _event_stream(jax.random.fold_in(key, i), d, (t, 1, n_in))
        reqs.append((i, np.asarray(ev[:, 0, :], np.float32), d))
    return reqs


def _serve_variants():
    """Serving load test: continuous batching vs drain-the-queue.

    One fixed request trace (mixed stream lengths 8..32, mixed densities)
    is served three ways — the continuous engine (persistent slots,
    round-granularity admission/eviction), the legacy drain engine
    (whole-sequence batches bucketed by length), and the continuous
    engine under the in-kernel Fig. 7 noise model.  Each variant follows
    the cold/profile/warm trial discipline: the cold trial pays the jit
    compiles (the legacy path compiles one entry per distinct T in the
    trace — exactly the cost continuous batching deletes), a profile
    trial collects the SLO/energy columns from ``energy_report``, and the
    reported number is the median of repeated warm full-trace runs.

    The drain path's cost scales with the *sum of per-bucket max
    lengths* (every batch runs its longest member's step count, padded
    slots and all); the continuous path's cost scales with total
    request-steps over slot utilization — that gap is the throughput
    column CI tracks.
    """
    from repro.models import snn as snn_lib
    from repro.serve.engine import EventRequest, SNNEventEngine
    cfg = snn_lib.SNNConfig(n_in=N_IN, n_hidden=N_OUT, n_classes=10,
                            k=K_WIN, n_steps=T_SEQ)
    p = snn_lib.init_params(cfg, jax.random.PRNGKey(0))
    trace = _serve_trace(jax.random.PRNGKey(1), N_IN)
    total_steps = sum(SERVE_LENGTHS)

    def serve(continuous, noise=None):
        eng = SNNEventEngine(cfg, p, batch_slots=SERVE_SLOTS, seed=0,
                             continuous=continuous, round_steps=SERVE_ROUND,
                             noise=noise)
        for uid, ev, d in trace:
            eng.submit(EventRequest(uid=uid, events=ev, density=d))
        t0 = time.perf_counter()
        done = eng.run()
        dt = (time.perf_counter() - t0) * 1e3
        assert len(done) == len(trace), (len(done), len(trace))
        return dt, eng

    cold_cont, _ = serve(True)               # compiles the stream round
    cold_drain, _ = serve(False)             # compiles one entry per T
    _, prof = serve(True)                    # profile trial: SLO columns
    rep = prof.energy_report("dvs_gesture")
    ms_cont = float(np.median([serve(True)[0] for _ in range(5)]))
    ms_drain = float(np.median([serve(False)[0] for _ in range(5)]))
    noise = ima_lib.IMANoiseModel()
    serve(True, noise)                       # noisy cold trial
    ms_noisy = float(np.median([serve(True, noise)[0] for _ in range(3)]))
    mean_density = float(np.mean([d for _, _, d in trace]))
    return {
        "slots": SERVE_SLOTS, "round_steps": SERVE_ROUND,
        "n_requests": len(trace),
        "t_min": min(SERVE_LENGTHS), "t_max": max(SERVE_LENGTHS),
        "total_request_steps": total_steps,
        "mean_density": round(mean_density, 4),
        "cold_ms_continuous": round(cold_cont, 1),
        "cold_ms_drain": round(cold_drain, 1),
        "ms_continuous": round(ms_cont, 1),
        "ms_drain": round(ms_drain, 1),
        "ms_continuous_noisy": round(ms_noisy, 1),
        "throughput_vs_drain": round(ms_drain / ms_cont, 2),
        "noise_overhead": round(ms_noisy / ms_cont, 2),
        "req_steps_per_s": round(total_steps / (ms_cont * 1e-3), 1),
        "latency_ms_p50": round(rep["latency_ms_p50"], 2),
        "latency_ms_p95": round(rep["latency_ms_p95"], 2),
        "pj_per_sop_measured": round(rep["pj_per_sop"], 3),
        # observability block (informative, schema-checked but never
        # perf-gated — interpret-mode round times are too noisy to gate):
        # kernel-round wall-time quantiles and the measured activity-plan
        # skip rate, from the profile trial's engine
        "obs": {
            "round_ms_p50": round(rep["round_ms_p50"], 3),
            "round_ms_p95": round(rep["round_ms_p95"], 3),
            "skipped_block_ratio": round(
                rep.get("mean_skipped_block_ratio", 0.0), 4),
        },
    }


PREEMPT_SLOTS, PREEMPT_ROUND = 2, 8
PREEMPT_HOG_T, PREEMPT_SHORT_T = 96, 8
PREEMPT_N_HOGS, PREEMPT_N_SHORTS = 2, 10


def _preempt_variants():
    """Fairness under hogs: shorts' p95 latency, preemptive vs FIFO.

    The adversarial trace: ``PREEMPT_N_HOGS`` long streams grab every slot
    first, then ``PREEMPT_N_SHORTS`` short priority-1 requests arrive.
    Without preemption the shorts queue behind the hogs' full runtime;
    with it the scheduler checkpoints a hog (``snn.SlotCheckpoint``),
    serves the shorts, and resumes the hog from its step offset — results
    stay bitwise-identical either way (pinned by tests + chaos harness),
    so the only thing that moves is the latency distribution.  The
    fairness SLO CI enforces (``check_bench.py``): shorts' p95 with
    preemption must not be worse than without it on this trace.  Median
    of 3 full-trace trials per variant, after a warmup trial that pays
    every jit compile both variants share.
    """
    from repro.models import snn as snn_lib
    from repro.serve.engine import EventRequest, SNNEventEngine
    cfg = snn_lib.SNNConfig(n_in=N_IN, n_hidden=N_OUT, n_classes=10,
                            k=K_WIN, n_steps=T_SEQ)
    p = snn_lib.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(4)
    hogs = [np.asarray(_event_stream(jax.random.fold_in(key, i), 0.05,
                                     (PREEMPT_HOG_T, 1, N_IN))[:, 0, :],
                       np.float32) for i in range(PREEMPT_N_HOGS)]
    shorts = [np.asarray(_event_stream(jax.random.fold_in(key, 100 + i),
                                       0.05,
                                       (PREEMPT_SHORT_T, 1, N_IN))[:, 0, :],
                         np.float32) for i in range(PREEMPT_N_SHORTS)]

    def trial(preemptive):
        eng = SNNEventEngine(cfg, p, batch_slots=PREEMPT_SLOTS, seed=0,
                             round_steps=PREEMPT_ROUND,
                             preemptive=preemptive, preempt_quantum=1,
                             backoff_rounds=1)
        for i, ev in enumerate(hogs):
            eng.submit(EventRequest(uid=i, priority=0, events=ev))
        eng.run(max_rounds=1)            # hogs take residence first
        short_reqs = [EventRequest(uid=100 + i, priority=1, events=ev)
                      for i, ev in enumerate(shorts)]
        for r in short_reqs:
            eng.submit(r)
        eng.run()
        lat = sorted(r.latency_ms for r in short_reqs)
        p95 = lat[min(len(lat) - 1, int(len(lat) * 0.95))]
        return p95, eng.preemption_count

    trial(True)                          # warmup: compiles shared entries
    trial(False)
    on = [trial(True) for _ in range(3)]
    off = [trial(False) for _ in range(3)]
    p95_on = float(np.median([t[0] for t in on]))
    p95_off = float(np.median([t[0] for t in off]))
    return {
        "slots": PREEMPT_SLOTS, "round_steps": PREEMPT_ROUND,
        "hogs": PREEMPT_N_HOGS, "hog_t": PREEMPT_HOG_T,
        "shorts": PREEMPT_N_SHORTS, "short_t": PREEMPT_SHORT_T,
        "shorts_p95_ms_fifo": round(p95_off, 2),
        "shorts_p95_ms_preemptive": round(p95_on, 2),
        "fairness_gain": round(p95_off / p95_on, 2),
        "preemptions_per_trace": int(np.median([t[1] for t in on])),
    }


# Tuned-vs-heuristic cells: the two sequence geometries the bench tracks,
# at the standard event rate.  (m, n_in, n_out, t, density.)
TUNE_CELLS = ((M, N_IN, N_OUT, T_SEQ, 0.05),
              (M, LARGE_N_IN, LARGE_N_OUT, T_SEQ, 0.05))


def _tuned_variants(cells=TUNE_CELLS):
    """Cache-tuned tile plan vs the PR 4 heuristic, per tracked cell.

    For each cell the serving-path plan resolution runs for real: the
    persistent cache is consulted exactly as ``plan_tiles`` consults it
    (density=None — the serving key), and both the cached plan and the
    heuristic plan are measured in this run with the shared stopwatch.
    The *tuned* row is the better of the two — which is not a thumb on the
    scale but the subsystem's actual invariant: the tuner always measures
    the heuristic as a candidate, so consuming the cache can never be
    slower than ignoring it (a stale winner loses this run's rematch and
    the row degrades to speedup 1.0 with ``tuned_source: heuristic``).
    With no cache file both plans coincide and the row reports exactly
    1.0.  The cached plan's outputs are checked bitwise against the
    heuristic plan's (tile plans are execution geometry, never semantics).
    """
    from repro.kernels import fused_macro as fused_kernel
    from repro.tune import cache as plan_cache
    entries = []
    for ci, (m, n_in, n_out, t, d) in enumerate(cells):
        ks = jax.random.split(jax.random.PRNGKey(31 + ci), 5)
        tern = lambda k, s: jax.random.randint(k, s, -1, 2).astype(jnp.int8)
        x = _event_stream(ks[0], d, (t, m, n_in))
        msb, lsb = tern(ks[1], (n_in, n_out)), tern(ks[2], (n_in, n_out))
        cb = ima_lib.nlq_codebook(CODE_BITS, -24, 24)
        scale = jax.random.uniform(ks[3], (n_out,), minval=0.05, maxval=0.3)
        v = jax.random.normal(ks[4], (m, n_out)) * 0.5

        heur = fused_kernel.plan_tiles(m, n_in, n_out, n_out, t,
                                       use_cache=False)
        heur_blocks = (heur.bm, heur.bk, heur.bn)
        hit = plan_cache.lookup(m, n_in, n_out, n_out, t, mode="kwn")
        cached_blocks = tuple(hit) if hit is not None else heur_blocks

        def runner(blocks):
            return jax.jit(functools.partial(
                ops.fused_macro_seq, mode="kwn", k=K_WIN,
                drive_gain=DRIVE_GAIN, gate=True, mac_telemetry=False,
                bm=blocks[0], bk=blocks[1], bn=blocks[2]))

        args = (x, msb, lsb, cb.boundaries, cb.levels, scale, v)
        ms_heur = _time(runner(heur_blocks), args, iters=7) / 1e3
        if cached_blocks == heur_blocks:
            ms_cached, plan_parity = ms_heur, True
        else:
            run_c = runner(cached_blocks)
            ms_cached = _time(run_c, args, iters=7) / 1e3
            out_h = runner(heur_blocks)(*args)
            out_c = run_c(*args)
            plan_parity = bool(all(
                jnp.array_equal(a, b) for a, b in zip(out_h[1:], out_c[1:])))
        if ms_cached <= ms_heur and cached_blocks != heur_blocks:
            tuned_blocks, ms_tuned, source = cached_blocks, ms_cached, "cache"
        else:
            tuned_blocks, ms_tuned, source = heur_blocks, ms_heur, "heuristic"
        entries.append({
            "batch": m, "geometry": f"{n_in}x{n_out}", "t": t, "density": d,
            "heuristic_plan": list(heur_blocks),
            "cached_plan": list(cached_blocks) if hit is not None else None,
            "tuned_plan": list(tuned_blocks),
            "tuned_source": source,
            "ms_heuristic": round(ms_heur, 2),
            "ms_cached": round(ms_cached, 2),
            "ms_tuned": round(ms_tuned, 2),
            "speedup_vs_heuristic": round(ms_heur / ms_tuned, 4),
            "plan_parity_bitwise": plan_parity,
        })
    return entries


def _step_comparison(m, n_in, n_out, key):
    """Fused-vs-composed single step at a given layer geometry."""
    x, msb, lsb, cb, scale, v, noise = _operands(key, m=m, n_in=n_in,
                                                 n_out=n_out)
    args = (x, msb, lsb, cb, scale, v, noise)
    fused = _fused_step(*args)
    composed = _composed_step(*args)
    parity = {
        "v_mem_equal": bool(jnp.array_equal(fused[0], composed[0])),
        "mask_equal": bool(jnp.array_equal(fused[2], composed[2])),
        "steps_equal": bool(jnp.array_equal(fused[3], composed[3])),
    }
    us_fused = _time(_fused_step, args)
    us_composed = _time(_composed_step, args)
    return fused, parity, us_fused, us_composed


def run() -> dict:
    fused, parity, us_fused, us_composed = _step_comparison(
        M, N_IN, N_OUT, jax.random.PRNGKey(0))

    # Large layer: 2x2 virtual macro grid, fused stays in-kernel (tiled).
    _, big_parity, us_big_fused, us_big_composed = _step_comparison(
        M, LARGE_N_IN, LARGE_N_OUT, jax.random.PRNGKey(2))
    cb = ima_lib.nlq_codebook(CODE_BITS, -24, 24)
    big_fw = macro_lib.FusedMacroWeights(
        msb=jnp.zeros((LARGE_N_IN, LARGE_N_OUT), jnp.int8),
        lsb=jnp.zeros((LARGE_N_IN, LARGE_N_OUT), jnp.int8),
        scale=jnp.ones((LARGE_N_OUT,)), boundaries=cb.boundaries,
        levels=cb.levels, w_dend=None, mode="kwn")
    big_plan, big_geo = macro_lib.plan_fused_tiles(M, big_fw, LARGE_N_OUT)

    seq_stats = _seq_variants()
    noisy_stats = _noisy_variants()
    density_stats = _density_sweep()
    train_stats = _train_variants()
    multilayer_stats = _multilayer_variants()
    serve_stats = _serve_variants()
    preempt_stats = _preempt_variants()
    tuned_stats = _tuned_variants()

    # Early-stop statistics the energy model consumes (measured, per row).
    steps = np.asarray(fused[3]).reshape(-1)
    full = 2 ** CODE_BITS - 1
    hist = np.bincount(steps, minlength=full + 1)
    mean_steps = float(steps.mean())
    saving = 1.0 - mean_steps / full
    e_model = energy.kwn_step_energy(K_WIN, energy.SPIKE_RATES["dvs_gesture"])

    return {
        "geometry": f"{N_IN}x{N_OUT}", "batch": M, "k": K_WIN,
        "us_fused": round(us_fused, 1),
        "us_composed": round(us_composed, 1),
        "speedup": round(us_composed / us_fused, 2),
        "parity": parity,
        "large_layer": {
            "geometry": f"{LARGE_N_IN}x{LARGE_N_OUT}", "batch": M,
            "virtual_macros": big_geo.n_macros,
            "tile_grid": list(big_plan.grid),
            "vmem_resident_kb": round(big_plan.vmem_resident_bytes / 1024, 1),
            "us_fused_tiled": round(us_big_fused, 1),
            "us_composed": round(us_big_composed, 1),
            "speedup": round(us_big_composed / us_big_fused, 2),
            "parity": big_parity,
        },
        "sequence": seq_stats,
        "noisy": noisy_stats,
        "density_sweep": density_stats,
        "train": train_stats,
        "multilayer": multilayer_stats,
        "serve": serve_stats,
        "preempt": preempt_stats,
        "tuned": tuned_stats,
        "early_stop": {
            "mean_adc_steps": round(mean_steps, 2),
            "full_ramp_steps": full,
            "measured_saving": round(saving, 3),
            "model_saving_k12": round(energy.early_stop_saving(K_WIN), 3),
            "step_histogram": hist.tolist(),
        },
        "energy_model_pj_per_step": round(e_model.total, 1),
    }


def records(report: dict) -> list[dict]:
    """Flatten the report into fixed-schema perf-trajectory records.

    Schema (every record, exactly these keys):
      op        — what ran (fused_step / composed_step / ... / fused_seq_gated)
      shape     — "BxIxN[xT]" geometry string
      mode      — "kwn" or "kwn+noise"
      median_ms — median wall time, milliseconds
      speedup   — vs the record's natural baseline (1.0 for baselines)
      density   — configured |event| rate of the operand stream

    CI uploads this as ``BENCH_fused_macro.json`` per PR, so the perf
    trajectory of the fused path is a diffable artifact, not a claim;
    ``tools/check_bench.py`` validates the schema and fails clean-path
    regressions against the committed copy.
    """
    g, b = report["geometry"], report["batch"]
    big, seq, noisy = (report["large_layer"], report["sequence"],
                       report["noisy"])
    sweep = report["density_sweep"]
    train = report["train"]
    shape = f"{b}x{g}"
    big_shape = f"{big['batch']}x{big['geometry']}"
    seq_shape = f"{seq['batch']}x{seq['geometry']}x{seq['t']}"
    noisy_shape = f"{noisy['batch']}x{noisy['geometry']}x{noisy['t']}"
    sweep_step_shape = f"{sweep['batch']}x{sweep['geometry']}"
    sweep_seq_shape = f"{sweep['batch']}x{sweep['geometry']}x{sweep['t']}"
    us = 1e-3
    out = [
        {"op": "composed_step", "shape": shape, "mode": "kwn",
         "median_ms": round(report["us_composed"] * us, 3), "speedup": 1.0,
         "density": SPIKE_RATE},
        {"op": "fused_step", "shape": shape, "mode": "kwn",
         "median_ms": round(report["us_fused"] * us, 3),
         "speedup": report["speedup"], "density": SPIKE_RATE},
        {"op": "composed_step", "shape": big_shape, "mode": "kwn",
         "median_ms": round(big["us_composed"] * us, 3), "speedup": 1.0,
         "density": SPIKE_RATE},
        {"op": "fused_step_tiled", "shape": big_shape, "mode": "kwn",
         "median_ms": round(big["us_fused_tiled"] * us, 3),
         "speedup": big["speedup"], "density": SPIKE_RATE},
        {"op": "fused_seq_per_step_scan", "shape": seq_shape, "mode": "kwn",
         "median_ms": seq["ms_per_step_scan"], "speedup": 1.0,
         "density": SPIKE_RATE},
        {"op": "fused_seq_time_major", "shape": seq_shape, "mode": "kwn",
         "median_ms": seq["ms_time_major"],
         "speedup": seq["speedup_vs_scan"], "density": SPIKE_RATE},
        {"op": "fused_seq_time_major", "shape": noisy_shape, "mode": "kwn",
         "median_ms": noisy["ms_clean"], "speedup": 1.0,
         "density": SPIKE_RATE},
        {"op": "fused_seq_noisy", "shape": noisy_shape, "mode": "kwn+noise",
         "median_ms": noisy["ms_noisy"],
         "speedup": round(1.0 / noisy["noise_overhead"], 2),
         "density": SPIKE_RATE},
    ]
    ml = report["multilayer"]
    ml_shape = f"{ml['batch']}x{ml['geometry']}x{ml['t']}"
    out += [
        {"op": "fused_seq_2layer_roundtrip", "shape": ml_shape,
         "mode": "kwn", "median_ms": ml["ms_layer_roundtrip"],
         "speedup": 1.0, "density": 0.05},
        {"op": "fused_seq_2layer", "shape": ml_shape, "mode": "kwn",
         "median_ms": ml["ms_fused_stack"],
         "speedup": ml["speedup_vs_roundtrip"], "density": 0.05},
    ]
    train_shape = f"{train['batch']}x{train['geometry']}x{train['t']}"
    out += [
        {"op": "train_step_bptt", "shape": train_shape, "mode": "kwn",
         "median_ms": train["ms_bptt"], "speedup": 1.0, "density": 0.05},
        {"op": "train_step_silicon_vjp", "shape": train_shape,
         "mode": "kwn", "median_ms": train["ms_silicon_vjp"],
         "speedup": train["silicon_vs_bptt"], "density": 0.05},
        {"op": "train_step_silicon_vjp", "shape": train_shape,
         "mode": "kwn+noise", "median_ms": train["ms_silicon_vjp_noisy"],
         "speedup": round(1.0 / train["noise_overhead"], 2),
         "density": 0.05},
    ]
    srv = report["serve"]
    srv_shape = (f"{srv['slots']}x{g}"
                 f"xT{srv['t_min']}-{srv['t_max']}")
    out += [
        {"op": "serve_stream_drain", "shape": srv_shape, "mode": "kwn",
         "median_ms": srv["ms_drain"], "speedup": 1.0,
         "density": srv["mean_density"]},
        # the continuous row carries the optional "obs" block —
        # round-time quantiles + measured skip rate from the profile
        # trial (check_bench validates its schema but never gates on it)
        {"op": "serve_stream_continuous", "shape": srv_shape, "mode": "kwn",
         "median_ms": srv["ms_continuous"],
         "speedup": srv["throughput_vs_drain"],
         "density": srv["mean_density"], "obs": srv["obs"]},
        {"op": "serve_stream_noisy", "shape": srv_shape, "mode": "kwn+noise",
         "median_ms": srv["ms_continuous_noisy"],
         "speedup": round(1.0 / srv["noise_overhead"], 2),
         "density": srv["mean_density"]},
    ]
    pre = report["preempt"]
    pre_shape = (f"{pre['slots']}x{g}xH{pre['hogs']}T{pre['hog_t']}"
                 f"S{pre['shorts']}T{pre['short_t']}")
    out += [
        # median_ms here is the shorts' p95 latency on the hog trace —
        # the fairness SLO, not a throughput number.  check_bench floors
        # serve_preempt_on's speedup (p95_fifo / p95_preemptive) at 1.0.
        {"op": "serve_preempt_off", "shape": pre_shape, "mode": "kwn",
         "median_ms": pre["shorts_p95_ms_fifo"], "speedup": 1.0,
         "density": SPIKE_RATE},
        {"op": "serve_preempt_on", "shape": pre_shape, "mode": "kwn",
         "median_ms": pre["shorts_p95_ms_preemptive"],
         "speedup": pre["fairness_gain"], "density": SPIKE_RATE},
    ]
    for kind, kshape in (("seq", sweep_seq_shape), ("step",
                                                    sweep_step_shape)):
        for e in sweep[kind]:
            out.append({"op": f"fused_{kind}_dense", "shape": kshape,
                        "mode": "kwn", "median_ms": e["ms_dense"],
                        "speedup": 1.0, "density": e["density"]})
            out.append({"op": f"fused_{kind}_gated", "shape": kshape,
                        "mode": "kwn", "median_ms": e["ms_gated"],
                        "speedup": e["speedup"], "density": e["density"]})
    for e in report["tuned"]:
        tshape = f"{e['batch']}x{e['geometry']}x{e['t']}"
        out.append({"op": "fused_seq_heuristic_plan", "shape": tshape,
                    "mode": "kwn", "median_ms": e["ms_heuristic"],
                    "speedup": 1.0, "density": e["density"]})
        out.append({"op": "tuned_vs_heuristic", "shape": tshape,
                    "mode": "kwn", "median_ms": e["ms_tuned"],
                    "speedup": e["speedup_vs_heuristic"],
                    "density": e["density"]})
    return out


def main(argv=None):
    import argparse
    import json
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="write fixed-schema trajectory records to this "
                         "JSON file (e.g. BENCH_fused_macro.json)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export a Perfetto trace of the whole bench run "
                         "(every measurement + serving round becomes a "
                         "span; slightly perturbs the timings, so CI "
                         "baselines are recorded without it)")
    args = ap.parse_args(argv)
    if args.trace_out:
        from repro.obs import trace as obs_trace
        obs_trace.set_tracer(obs_trace.Tracer(enabled=True,
                                              capacity=1 << 18))
    report = run()
    print(json.dumps(report, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"bench": "fused_macro", "records": records(report)},
                      f, indent=1)
        print(f"\nwrote {args.out}")
    if args.trace_out:
        from repro.obs import trace as obs_trace
        n = obs_trace.get_tracer().export(args.trace_out)
        print(f"wrote {n} spans to {args.trace_out}")


if __name__ == "__main__":
    main()
