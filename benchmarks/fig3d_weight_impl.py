"""Fig. 3d: multi-bit weight implementation cost — twin 9T multi-VDD vs PWM
vs multi-cell (MCL).  Paper claims 4x latency (vs PWM) and 7.8x bit-cell
count (vs MCL) advantages at 5-bit."""

from repro.core import ternary


def run() -> dict:
    table = {}
    for bits in (2, 3, 4, 5, 6):
        row = {}
        for scheme in ("twin", "pwm", "mcl"):
            lat, cells = ternary.weight_implementation_cost(bits, scheme)
            row[scheme] = {"latency": lat, "cells": cells}
        table[f"{bits}b"] = row
    lat_adv = table["5b"]["pwm"]["latency"] / table["5b"]["twin"]["latency"]
    cell_adv = table["5b"]["mcl"]["cells"] / table["5b"]["twin"]["cells"]
    return {
        "table": table,
        "latency_advantage_5b_vs_pwm": lat_adv,     # paper: 4x
        "cell_advantage_5b_vs_mcl": round(cell_adv, 2),  # paper: 7.8x
        "matches_paper": bool(abs(lat_adv - 4.0) < 0.01
                              and abs(cell_adv - 7.75) < 0.1),
    }
