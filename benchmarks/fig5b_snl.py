"""Fig. 5b: SNL + PRBS noise accuracy improvement in KWN mode.
Paper: +0.5-0.6 % on both datasets."""

from benchmarks import _snn_cache as C


def run() -> dict:
    out = {}
    for ds_name in ("nmnist", "dvs_gesture"):
        p, cfg, ds = C.trained_model(ds_name, "kwn", train_nlq=True)
        acc_snl, _ = C.eval_model(p, cfg, ds, use_snl=True)
        acc_no, _ = C.eval_model(p, cfg, ds, use_snl=False)
        out[ds_name] = {
            "kwn_with_snl": round(acc_snl, 4),
            "kwn_without_snl": round(acc_no, 4),
            "snl_gain_pct": round((acc_snl - acc_no) * 100, 2),
        }
    out["paper_claim_pct"] = "0.5-0.6"
    return out
