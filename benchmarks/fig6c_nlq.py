"""Fig. 6c: NLQ-in-training accuracy improvement (KWN mode).
Paper: +0.5-0.7 % on two datasets when the nonlinear quantization is used
during training (vs training oblivious to the 5-bit ramp)."""

from benchmarks import _snn_cache as C


def run() -> dict:
    out = {}
    for ds_name in ("nmnist", "dvs_gesture"):
        p_nlq, cfg_nlq, ds = C.trained_model(ds_name, "kwn", train_nlq=True)
        p_raw, cfg_raw, _ = C.trained_model(ds_name, "kwn", train_nlq=False)
        acc_nlq, _ = C.eval_model(p_nlq, cfg_nlq, ds)
        acc_raw, _ = C.eval_model(p_raw, cfg_raw, ds)
        out[ds_name] = {
            "kwn_nlq_trained": round(acc_nlq, 4),
            "kwn_nlq_oblivious": round(acc_raw, 4),
            "nlq_gain_pct": round((acc_nlq - acc_raw) * 100, 2),
        }
    out["paper_claim_pct"] = "0.5-0.7"
    return out
