"""Fig. 7: measured NL-IMA statistics.
(a) NLQ transfer error: paper mu=0.41 LSB, sigma=1.34 LSB.
(b) NL-activation (y=0.5x^2) INL: paper 0.91 LSB."""

import jax

from repro.core import ima


def run() -> dict:
    key = jax.random.PRNGKey(0)
    nlq = ima.nlq_codebook(5, -64, 64)
    transfer = ima.measure_transfer_error(nlq, key)
    act = ima.activation_codebook(5, ima.quadratic, -8, 8)
    inl_model = ima.measure_inl(act, ima.quadratic, key=key,
                                noise=ima.IMANoiseModel())
    inl_ideal = ima.measure_inl(act, ima.quadratic)
    return {
        "nlq_mean_lsb": round(transfer["mean_lsb"], 3),      # paper 0.41
        "nlq_sigma_lsb": round(transfer["std_lsb"], 3),      # paper 1.34
        "nl_activation_inl_lsb": round(inl_model, 3),        # paper 0.91
        "nl_activation_inl_ideal_emulation_lsb": round(inl_ideal, 3),
        "paper": {"mu": 0.41, "sigma": 1.34, "inl": 0.91},
    }
