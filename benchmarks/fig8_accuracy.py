"""Fig. 8 + Table I accuracy rows: NLD vs KWN modes, 3-bit weights, 5-bit
NL-IMA, with the silicon noise model.

Paper (real datasets): N-MNIST NLD 97.2 / KWN 96.2; DVS Gesture NLD 95.5 /
KWN 93.8; Quiroga NLD 96.1.  Synthetic stand-ins: the *ordering* (NLD > KWN)
and mechanism deltas are the reproducible claims (DESIGN.md data caveat)."""


from benchmarks import _snn_cache as C
from repro.core import ima


def run() -> dict:
    noise = ima.IMANoiseModel()
    out = {}
    for ds_name in ("nmnist", "dvs_gesture", "quiroga"):
        row = {}
        p, cfg, ds = C.trained_model(ds_name, "nld")
        acc, _ = C.eval_model(p, cfg, ds, noise=noise)
        row["nld"] = round(acc, 4)
        p, cfg, ds = C.trained_model(ds_name, "kwn")
        acc, tele = C.eval_model(p, cfg, ds, noise=noise)
        row["kwn"] = round(acc, 4)
        row["kwn_k"] = cfg.k
        row["mean_adc_steps_per_conv"] = round(tele["adc_steps"], 2)
        out[ds_name] = row
    out["ordering_nld_ge_kwn"] = all(
        out[d]["nld"] >= out[d]["kwn"] - 0.02
        for d in ("nmnist", "dvs_gesture"))
    out["paper"] = {"nmnist": {"nld": 0.972, "kwn": 0.962},
                    "dvs_gesture": {"nld": 0.955, "kwn": 0.938},
                    "quiroga": {"nld": 0.961}}
    return out
