"""Fig. 9: (a) energy breakdown per mode, (b) EE across supply voltages.
Calibrated model: core/energy.py."""

from repro.core import energy


def run() -> dict:
    kwn_bd = energy.kwn_step_energy(12, energy.SPIKE_RATES["dvs_gesture"])
    nld_bd = energy.nld_step_energy(energy.SPIKE_RATES["dvs_gesture"], "relu")
    return {
        "breakdown_kwn_dvs": kwn_bd.as_dict(),
        "breakdown_nld_dvs": nld_bd.as_dict(),
        "kwn_control_power_frac": round(kwn_bd.as_dict()["frac"]["control"], 3),
        "paper_control_frac": 0.168,
        "ee_vs_vdd": energy.ee_vs_vdd(),
        "table1": energy.table1_energy_entries(),
        "improvement_vs_sota_1p3": round(energy.improvement_vs_sota(), 3),
    }
