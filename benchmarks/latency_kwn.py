"""KWN latency claims: ADC early-stop (-30 % @ K=12 on DVS Gesture) and
serial-LIF update reduction (10x @ K=12 of 128).

Reports both the calibrated model AND the ramp-scan measurement on the
synthetic event streams (adc_steps from the kwn kernel semantics)."""


from benchmarks import _snn_cache as C
from repro.core import energy


def run() -> dict:
    out = {"model": {
        "adc_saving_k3": round(energy.early_stop_saving(3), 3),
        "adc_saving_k12": round(energy.early_stop_saving(12), 3),  # paper 0.30
        "lif_speedup_k12": round(energy.lif_latency_speedup(12), 2),  # ~10x
        "lif_speedup_k3": round(energy.lif_latency_speedup(3), 2),
    }}
    # measured on synthetic streams through the trained model
    for ds_name, k in (("nmnist", 3), ("dvs_gesture", 12)):
        p, cfg, ds = C.trained_model(ds_name, "kwn")
        _, tele = C.eval_model(p, cfg, ds)
        full = 2 ** cfg.code_bits - 1
        out[ds_name] = {
            "k": cfg.k,
            "measured_mean_adc_steps": round(tele["adc_steps"], 2),
            "full_ramp_steps": full,
            "measured_adc_saving": round(1 - tele["adc_steps"] / full, 3),
            "measured_lif_updates_per_step": tele["lif_updates"],
            "lif_updates_dense": 128,
            "measured_lif_speedup": round(128 / tele["lif_updates"], 1),
        }
    out["paper"] = {"adc_saving": 0.30, "lif_speedup": "10x"}
    return out
