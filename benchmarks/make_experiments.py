"""Builds EXPERIMENTS.md from the current artifacts:
dryrun_results/*.json, perf_results/*.json, benchmarks/.cache/results/*.json.

    PYTHONPATH=src python -m benchmarks.make_experiments
"""

from __future__ import annotations

import json
import os

from benchmarks import roofline_report

HERE = os.path.dirname(__file__)
ROOT = os.path.join(HERE, "..")
RESULTS = os.path.join(HERE, ".cache", "results")


def _load(name):
    path = os.path.join(RESULTS, f"{name}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None


def _perf(cell):
    path = os.path.join(ROOT, "perf_results", f"{cell}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None


def _fix_sentence(r):
    dom = r["dominant"]
    if dom == "collective":
        return ("shrink activation-collective volume (comm quantization, "
                "pipeline over the pod axis, fewer remat passes)")
    if dom == "memory":
        if "decode" in r["shape"] or "500k" in r["shape"]:
            return ("cut cache/param bytes (NLQ KV quantization, ternary "
                    "twin-cell weights, larger decode batch)")
        return "raise arithmetic intensity (bigger microbatch, fused ops)"
    return ("cut wasted flops (remat policy, causal-optimal attention "
            "kernel, capacity factor)")


def build() -> str:
    md = []
    md.append("# EXPERIMENTS — NeuDW-CIM framework\n")
    md.append(
        "Runtime: CPU-only container; TPU v5e is the *target* "
        "(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI per chip). "
        "Dry-runs lower+compile on 512 simulated host devices; kernels "
        "validate in Pallas interpret mode; roofline terms are analytical "
        "(validated vs XLA cost_analysis on unrolled configs — "
        "tests/test_system.py::TestRooflineModelValidation) with "
        "HLO-parsed collective cross-checks stored per cell.\n")

    # ------------------------------------------------------------- claims
    md.append("## §Paper-claims validation (faithful reproduction)\n")
    md.append("| Paper claim | Paper value | Reproduced | Source |")
    md.append("|---|---|---|---|")
    f9 = _load("fig9_energy") or {}
    t1 = f9.get("table1", {})
    rows = [
        ("KWN EE, N-MNIST (K=3, 0.7V)", "0.8 pJ/SOP",
         f"{t1.get('kwn_nmnist_pj_per_sop', 0):.2f} pJ/SOP", "fig9_energy"),
        ("KWN EE, DVS Gesture (K=12)", "1.5 pJ/SOP",
         f"{t1.get('kwn_dvs_pj_per_sop', 0):.2f} pJ/SOP", "fig9_energy"),
        ("NLD EE (3 datasets)", "1.8 / 2.3 / 2.1 pJ/SOP",
         f"{t1.get('nld_nmnist_pj_per_sop', 0):.2f} / "
         f"{t1.get('nld_dvs_pj_per_sop', 0):.2f} / "
         f"{t1.get('nld_quiroga_pj_per_sop', 0):.2f}", "fig9_energy"),
        ("EE improvement vs SOTA [9]", "1.6x",
         f"{f9.get('improvement_vs_sota_1p3', 0):.2f}x", "fig9_energy"),
        ("KWN control logic power share", "16.8 %",
         f"{100 * f9.get('kwn_control_power_frac', 0):.1f} %", "fig9_energy"),
    ]
    f3 = _load("fig3d_weight_impl") or {}
    rows += [
        ("5-bit weight: latency vs PWM", "4x",
         f"{f3.get('latency_advantage_5b_vs_pwm', 0):.1f}x", "fig3d"),
        ("5-bit weight: cells vs MCL", "7.8x",
         f"{f3.get('cell_advantage_5b_vs_mcl', 0):.2f}x", "fig3d"),
    ]
    f7 = _load("fig7_ima") or {}
    rows += [
        ("NLQ transfer error mu", "0.41 LSB",
         f"{f7.get('nlq_mean_lsb', 0):.2f} LSB", "fig7"),
        ("NLQ transfer error sigma", "1.34 LSB",
         f"{f7.get('nlq_sigma_lsb', 0):.2f} LSB", "fig7"),
        ("NL-activation INL (y=0.5x^2)", "0.91 LSB",
         f"{f7.get('nl_activation_inl_lsb', 0):.2f} LSB", "fig7"),
    ]
    lat = _load("latency_kwn") or {}
    mdl = lat.get("model", {})
    rows += [
        ("ADC early-stop saving (K=12)", "30 %",
         f"{100 * mdl.get('adc_saving_k12', 0):.0f} % (model); "
         f"{100 * lat.get('dvs_gesture', {}).get('measured_adc_saving', 0):.0f} % "
         "(measured, synthetic)", "latency_kwn"),
        ("LIF latency reduction (K=12/128)", "10x",
         f"{mdl.get('lif_speedup_k12', 0):.1f}x (exact); measured "
         f"{lat.get('dvs_gesture', {}).get('measured_lif_speedup', 0)}x",
         "latency_kwn"),
    ]
    f8 = _load("fig8_accuracy") or {}
    if f8:
        nm, dv = f8.get("nmnist", {}), f8.get("dvs_gesture", {})
        qg = f8.get("quiroga", {})
        def _ord(row):
            return ">" if row.get("nld", 0) >= row.get("kwn", 0) else "<"
        rows += [
            ("Accuracy ordering NLD > KWN (N-MNIST)", "97.2 > 96.2 %",
             f"{100 * nm.get('nld', 0):.1f} {_ord(nm)} "
             f"{100 * nm.get('kwn', 0):.1f} % (synthetic; ordering NOT "
             "reproduced here — see note)", "fig8"),
            ("Accuracy ordering NLD > KWN (DVS Ges.)", "95.5 > 93.8 %",
             f"{100 * dv.get('nld', 0):.1f} {_ord(dv)} "
             f"{100 * dv.get('kwn', 0):.1f} % (synthetic; ordering "
             "reproduced)", "fig8"),
            ("Accuracy ordering NLD > KWN (Quiroga)", "96.1 % (NLD)",
             f"{100 * qg.get('nld', 0):.1f} {_ord(qg)} "
             f"{100 * qg.get('kwn', 0):.1f} % (synthetic; ordering "
             "reproduced)", "fig8"),
        ]
    f5 = _load("fig5b_snl") or {}
    if f5:
        rows.append(("SNL+noise accuracy gain", "+0.5-0.6 %",
                     f"+{f5.get('nmnist', {}).get('snl_gain_pct', 0):.2f} % "
                     f"(nmnist) / +{f5.get('dvs_gesture', {}).get('snl_gain_pct', 0):.2f} % "
                     "(dvs)", "fig5b"))
    f6 = _load("fig6c_nlq") or {}
    if f6:
        rows.append(("NLQ-in-training gain", "+0.5-0.7 %",
                     f"+{f6.get('nmnist', {}).get('nlq_gain_pct', 0):.2f} % "
                     f"(nmnist) / +{f6.get('dvs_gesture', {}).get('nlq_gain_pct', 0):.2f} % "
                     "(dvs)", "fig6c"))
    for r in rows:
        md.append("| " + " | ".join(str(x) for x in r) + " |")
    md.append(
        "\n*Accuracy rows use synthetic event-stream stand-ins (offline "
        "container; see DESIGN.md data caveat): the mechanism deltas and "
        "orderings are the reproducible claims, not absolute accuracies. "
        "NLD > KWN reproduces on 2/3 datasets; on the N-MNIST stand-in the "
        "dense-trained KWN path wins because the synthetic task is nearly "
        "linearly separable — the dendritic nonlinearity has nothing to add "
        "there, unlike on real N-MNIST. The measured ADC early-stop saving "
        "(~80 %) exceeds the paper's 30 % because synthetic MAC codes are "
        "mid-scale concentrated; the calibrated energy model keeps the "
        "silicon's measured distribution.*\n")

    # ------------------------------------------------------------- dry-run
    cells = roofline_report.load_cells()
    rows_r = [roofline_report.row(c) for c in cells]
    n = len(rows_r)
    fits = sum(1 for r in rows_r if r["fits_v5e_16g"])
    md.append("## §Dry-run\n")
    md.append(
        f"- **{n}/62 cells lowered AND compiled** on the production meshes "
        "(16x16 = 256 chips single-pod; 2x16x16 = 512 chips multi-pod) — "
        "every runnable (architecture x input-shape) pair; skipped cells "
        "(encoder-only decode, quadratic-attention long_500k) are listed "
        "with reasons in `repro/configs/__init__.py::SHAPE_SKIPS`.")
    md.append(
        f"- {fits}/{n} cells fit 16 GiB/chip as configured. The over-budget "
        "cells are the 340B/480B/1T trainers at 256 chips — true to life: "
        "1T-param training needs >= 2-4 pods; the multi-pod mesh halves "
        "per-device bytes (see table) and the trend reaches 16 GiB at 4 "
        "pods with the same sharding rules.")
    md.append(
        "- Parallelism exercised: DP (pod x data), TP (model axis; Megatron "
        "sequence-parallel activations for the 5 big archs), 2D EP "
        "(experts over DP rows x TP inside experts — kimi/arctic), FSDP "
        "(dense giants), split-KV decode (cache sequence-sharded over "
        "model; GSPMD emits the partial-softmax all-reduces), GPipe-style "
        "PP available over the pod axis (dist/pipeline.py).")
    md.append(
        "- Collective schedules, per-device memory and HLO text summaries "
        "are archived per cell in `dryrun_results/*.json` "
        "(`collectives_hlo` keys = wire bytes by op kind parsed from the "
        "compiled module with while-loop trip attribution).\n")

    # ------------------------------------------------------------ roofline
    md.append("## §Roofline (per arch x shape x mesh)\n")
    md.append(
        "Terms in seconds per step at v5e peaks; dominant term bold; "
        "`useful/impl` = MODEL_FLOPS(6*N*D | 2*N*D) / implemented FLOPs "
        "(remat + causal waste + MoE capacity visible); roofline frac = "
        "achievable fraction of peak useful FLOPs at the dominant bound.\n")
    md.append(roofline_report.table_md(rows_r))
    md.append("\n**What would move each dominant term down** (per family):\n")
    seen = set()
    for r in rows_r:
        key = (r["arch"], r["dominant"])
        if key in seen or r["mesh"] != "16x16":
            continue
        seen.add(key)
        md.append(f"- `{r['arch']}` x `{r['shape']}` [{r['dominant']}]: "
                  f"{_fix_sentence(r)}.")

    # ---------------------------------------------------------------- perf
    md.append("\n## §Perf — hillclimbing log (3 cells)\n")
    md.append(
        "Cells chosen per the assignment: most paper-representative "
        "(kimi-k2: the MoE router IS the paper's KWN circuit), "
        "compute-bound giant (nemotron-340b), and the worst *fixable* "
        "roofline fraction (qwen2.5-32b decode, memory-bound serving). "
        "Each iteration: hypothesis -> code change -> re-lower+compile on "
        "the production mesh -> analytical+measured deltas -> verdict. "
        "The paper-faithful baseline is row 1 of each ladder; "
        "beyond-paper optimizations follow it.\n")
    for cell in ("kimi", "nemotron", "qwen"):
        data = _perf(cell)
        if not data:
            continue
        arch, shape = data[0]["arch"], data[0]["shape"]
        md.append(f"### {arch} x {shape}\n")
        md.append("| iteration | hypothesis | compute s | memory s | coll s "
                  "| dominant | roofline frac | GiB/dev | verdict |")
        md.append("|---|---|---|---|---|---|---|---|---|")
        for e in data:
            a = e["analytical"]
            mem = e.get("compiled", {}).get("mem_gib")
            md.append(
                f"| {e['name']} | {e['hypothesis'][:90]}... "
                f"| {a['compute_s']:.3f} | {a['memory_s']:.3f} "
                f"| {a['collective_s']:.3f} | {a['dominant']} "
                f"| {a['roofline_frac']:.3f} "
                f"| {mem:.1f} |" if mem is not None else
                f"| {e['name']} | {e['hypothesis'][:90]}... "
                f"| {a['compute_s']:.3f} | {a['memory_s']:.3f} "
                f"| {a['collective_s']:.3f} | {a['dominant']} "
                f"| {a['roofline_frac']:.3f} | - |")
            md[-1] += f" {e.get('verdict', 'baseline')} |"
        base = data[0]["analytical"]
        accepted = next((e for e in data if e.get("accepted_final")),
                        data[0])
        final = accepted["analytical"]
        dom = base["dominant"]
        md.append(
            f"\n**{arch}** accepted state = `{accepted['name']}`: roofline "
            f"fraction {base['roofline_frac']:.3f} (paper-faithful) -> "
            f"{final['roofline_frac']:.3f} (optimized, "
            f"{final['roofline_frac'] / max(base['roofline_frac'], 1e-9):.2f}x); "
            f"dominant-term {dom} {base[dom + '_s']:.3f}s -> "
            f"{final[dom + '_s']:.3f}s. Refuted iterations are retained "
            "above (rolled back in code).\n")

    md.append("### Perf-knob provenance (paper tie-ins) and lessons\n")
    md.append(
        "- `kv_quant` int8/int4 — the IMA's low-bit code + LUT scale "
        "(paper C2/C6) applied to the KV cache;\n"
        "- `moe_wire_dtype` int8 — NLQ-style companded payloads on the "
        "dispatch wire (visible as s8 all-to-alls in the compiled HLO);\n"
        "- `moe_capacity_factor` — the KWN early-stop philosophy (process "
        "only winners) applied to expert capacity;\n"
        "- `remat_policy`/`remat_mode` — beyond-paper XLA-level knobs.\n\n"
        "Lessons from refuted iterations (kept in the ladders above):\n"
        "- `attn_only_remat`: wire dropped exactly as hypothesized but "
        "memory went 42 -> 351 GiB — without block-level remat the layer "
        "scan pins EVERY MoE internal for the backward;\n"
        "- `save_moe_recv` (pin only the post-a2a tokens): still 205 GiB — "
        "the pinned tensor is post-TP-gather, 16x larger than estimated; "
        "napkin math missed the gather fan-in;\n"
        "- `dots_remat` resolved it: saving matmul *outputs* keeps the "
        "F-sliced (small) expert tensors, not the gathered inputs — same "
        "wire win at 52 GiB, ACCEPTED. The sequence is a textbook "
        "hypothesis->measure->revise chain;\n"
        "- `dots_remat_mb16` (nemotron): more microbatches double the FSDP "
        "regathers — wire regression, refuted.\n\n"
        "The accepted knobs ship as `repro.configs.base.optimized(cfg)`; "
        "registry defaults stay paper-faithful so §Roofline remains the "
        "reproduction baseline.\n")
    md.append(
        "### Additional beyond-paper perf work\n\n"
        "- **Flash-attention Pallas kernel** "
        "(`kernels/flash_attention.py`): online-softmax forward with causal "
        "block skipping — validated vs the naive oracle (max err ~6e-7) and "
        "skips 49.2 % of block pairs at 32k/512-blocks, i.e. removes the 2x "
        "causal flops waste the `useful/impl` column shows for attention-"
        "heavy prefill cells (applies on real TPU; serving prefill is "
        "forward-only so no backward kernel is needed).\n"
        "- **K-sweep frontier** (`benchmarks/ablation_k_sweep.py`): the "
        "KWN winner count traces a clean accuracy/energy frontier on the "
        "synthetic stand-ins (K=1: 76 % @ 0.78 pJ/SOP -> K=12: 99.6 % @ "
        "0.90 pJ/SOP on nmnist) — the paper's K=3/K=12 operating points "
        "sit at the knees.\n"
        "- **KWN-FFN at LM scale** (`benchmarks/ablation_kwn_lm.py`): "
        "Eq. (1) winner sparsity on FFN hidden units is loss-neutral at "
        "12.5 % density on the smoke LM (gap -0.002), and CIM-mode "
        "(ternary weights + NLQ activations on every projection) trains "
        "stably — the macro's execution model transfers to transformers.\n")
    return "\n".join(md) + "\n"


def main():
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write(build())
    print("wrote", os.path.abspath(out))


if __name__ == "__main__":
    main()
