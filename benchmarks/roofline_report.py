"""Roofline report: aggregates dryrun_results/*.json into the per-(arch x
shape x mesh) table for EXPERIMENTS.md §Roofline — three terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, per-device memory."""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results")


def load_cells() -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def recompute_roofline(c: dict) -> dict:
    """Recompute the analytical terms from the current flops model (the
    compiled JSON keeps memory_analysis + HLO cross-checks; the analytical
    model is versioned with the code so reports always use the latest)."""
    from repro.configs import get_config
    from repro.roofline import flops_model
    cfg = get_config(c["arch"])
    mesh = flops_model.mesh_for(c["mesh"] != "16x16")
    return flops_model.analyze(
        cfg, c["shape"], mesh, n_micro=c.get("n_micro", 1),
        grad_bytes=2 if c.get("grad_dtype") == "bfloat16" else 4,
        moment_bytes=2 if c.get("moment_dtype") == "bfloat16" else 4)


def row(c: dict) -> dict:
    try:
        r = recompute_roofline(c)
    except Exception:
        r = c.get("roofline", {})
    return {
        "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
        "compute_s": r.get("compute_s", 0.0),
        "memory_s": r.get("memory_s", 0.0),
        "collective_s": r.get("collective_s", 0.0),
        "dominant": r.get("dominant", "?"),
        "model_over_impl_flops": r.get("model_over_hlo", 0.0),
        "roofline_frac": r.get("roofline_frac", 0.0),
        "mem_gib_per_dev": c.get("bytes_per_device", 0) / 2 ** 30,
        "fits_v5e_16g": c.get("bytes_per_device", 0) / 2 ** 30 <= 16.0,
        "compile_s": c.get("compile_s", 0.0),
    }


def table_md(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | coll s | dominant "
           "| useful/impl | roofline frac | GiB/dev | fits 16G |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['model_over_impl_flops']:.2f} | {r['roofline_frac']:.2f} "
            f"| {r['mem_gib_per_dev']:.2f} | {'Y' if r['fits_v5e_16g'] else 'N'} |")
    return hdr + "\n".join(lines)


def run() -> dict:
    cells = load_cells()
    rows = [row(c) for c in cells]
    n_ok = len(rows)
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    worst = sorted((r for r in rows if r["mesh"] == "16x16"),
                   key=lambda r: r["roofline_frac"])[:3]
    most_coll = sorted((r for r in rows if r["mesh"] == "16x16"),
                       key=lambda r: -(r["collective_s"]
                                       / max(r["compute_s"] + r["memory_s"],
                                             1e-12)))[:3]
    return {
        "n_cells_compiled": n_ok,
        "dominant_histogram": doms,
        "worst_roofline_frac": [
            {k: r[k] for k in ("arch", "shape", "roofline_frac")}
            for r in worst],
        "most_collective_bound": [
            {k: r[k] for k in ("arch", "shape", "collective_s")}
            for r in most_coll],
        "rows": rows,
    }


def write_markdown(path: str):
    cells = load_cells()
    rows = [row(c) for c in cells]
    with open(path, "w") as f:
        f.write(table_md(rows))
    return path
