"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig8_accuracy]

Prints ``name,us_per_call,derived`` CSV (derived = compact JSON of the
reproduced numbers) and a human-readable block per benchmark.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks import (ablation_k_sweep, ablation_kwn_lm,
                        bench_fused_macro, fig3d_weight_impl, fig5b_snl,
                        fig6c_nlq, fig7_ima, fig8_accuracy, fig9_energy,
                        latency_kwn, roofline_report, table1_comparison)

BENCHES = {
    "fig3d_weight_impl": fig3d_weight_impl,
    "fig7_ima": fig7_ima,
    "fig9_energy": fig9_energy,
    "latency_kwn": latency_kwn,
    "bench_fused_macro": bench_fused_macro,
    "fig5b_snl": fig5b_snl,
    "fig6c_nlq": fig6c_nlq,
    "fig8_accuracy": fig8_accuracy,
    "table1_comparison": table1_comparison,
    "ablation_kwn_lm": ablation_kwn_lm,
    "ablation_k_sweep": ablation_k_sweep,
    "roofline_report": roofline_report,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    names = [args.only] if args.only else list(BENCHES)
    results_dir = os.path.join(os.path.dirname(__file__), ".cache", "results")
    os.makedirs(results_dir, exist_ok=True)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod = BENCHES[name]
        t0 = time.time()
        try:
            derived = mod.run()
            us = (time.time() - t0) * 1e6
            with open(os.path.join(results_dir, f"{name}.json"), "w") as f:
                json.dump(derived, f, indent=1, default=str)
            compact = json.dumps(derived, separators=(",", ":"),
                                 default=str)
            if len(compact) > 6000:
                compact = json.dumps(
                    {k: v for k, v in derived.items() if k != "rows"},
                    separators=(",", ":"), default=str)
            print(f"{name},{us:.0f},{compact}")
            print(f"--- {name} ---", file=sys.stderr)
            print(json.dumps(derived, indent=1, default=str)[:4000],
                  file=sys.stderr)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name},FAILED,{e!r}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
