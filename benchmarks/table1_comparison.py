"""Table I: this work's column vs the published SOTA rows — energy
efficiency (calibrated model), accuracy (synthetic stand-ins), macro
parameters, plus the 1.6x EE improvement claim."""

from benchmarks import fig8_accuracy
from repro.core import energy

SOTA = {
    "ESSERC25_[2]": {"tech_nm": 65, "ee_pj_sop": None, "dvs_acc": 0.9354},
    "ISSCC23_[1]": {"tech_nm": 28, "ee_pj_sop": 1.5, "nmnist_acc": 0.96,
                    "dvs_acc": 0.92},
    "ISSCC24_[4]": {"tech_nm": 22, "ee_pj_sop": 3.78, "nmnist_acc": 0.97,
                    "dvs_acc": 0.94},
    "VLSI25_[9]": {"tech_nm": 130, "ee_pj_sop": 1.3, "nmnist_acc": 0.971,
                   "dvs_acc": 0.9012},
}


def run() -> dict:
    ee = energy.table1_energy_entries()
    acc = fig8_accuracy.run()
    this_work = {
        "tech_nm": 65,
        "macro": "256x128",
        "weight_bits": "2-3 (twin-cell multi-VDD)",
        "vmem_bits": 12,
        "input": "binary/ternary",
        "lif": "digital (KWN sparse update)",
        "ee_kwn_nmnist_pj_sop": round(ee["kwn_nmnist_pj_per_sop"], 3),
        "ee_kwn_dvs_pj_sop": round(ee["kwn_dvs_pj_per_sop"], 3),
        "ee_nld_pj_sop": {k: round(v, 3) for k, v in ee.items()
                          if k.startswith("nld")},
        "acc_synthetic": {d: acc[d] for d in ("nmnist", "dvs_gesture",
                                              "quiroga")},
        "power_mw_modeled": {
            "kwn_dvs@468kHz": round(energy.modeled_power_mw(
                "kwn", "dvs_gesture", 468e3), 3),
            "nld_dvs@160kHz": round(energy.modeled_power_mw(
                "nld", "dvs_gesture", 160e3), 3),
        },
    }
    return {
        "this_work": this_work,
        "sota": SOTA,
        "ee_improvement_vs_vlsi25": round(energy.improvement_vs_sota(1.3), 3),
        "paper_claim": "1.6x over 1.3 pJ/SOP [9]",
    }
