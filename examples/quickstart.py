"""Quickstart: run the NeuDW-CIM macro in both modes on one event batch.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper end-to-end at macro scale: ternary events -> twin-cell MAC ->
(KWN: NLQ ramp + top-K early stop + sparse LIF w/ SNL) vs (NLD: dendritic
branch MACs through the NL-activation ramp + dense LIF), then prints the
latency/energy numbers the silicon measures.
"""

import jax
import jax.numpy as jnp

from repro.core import dendrite, energy, lif, macro, ternary

key = jax.random.PRNGKey(0)

# --- a batch of ternary event vectors (ON/OFF/idle), 256 inputs -------------
events = jnp.sign(jax.random.normal(key, (8, 256)))
events = events * (jax.random.uniform(jax.random.fold_in(key, 1),
                                      (8, 256)) < 0.06)
print(f"input spike rate: {float(jnp.mean(jnp.abs(events))):.3f}")

# --- twin 9T weights: 3-bit from two ternary planes --------------------------
w_float = jax.random.normal(jax.random.fold_in(key, 2), (256, 128))
w_int, scale = ternary.quantize_weights_3bit(w_float)
msb, lsb = ternary.weight_decompose(w_int)
print(f"weights: int grid [-3,3], msb/lsb ternary planes, "
      f"compose check: {bool(jnp.all(ternary.weight_compose(msb, lsb) == w_int))}")

# --- KWN mode: NLQ conversion + top-12 winners with early stop ---------------
cfg = macro.CIMMacroConfig(code_bits=5, mac_range=24.0)
drive, mask, res = macro.kwn_forward(events, w_int, k=12, cfg=cfg)
print(f"\nKWN mode: {int(mask[0].sum())} winners/128 columns, "
      f"ADC stopped after {int(res.adc_steps[0])}/31 ramp steps "
      f"({1 - float(res.adc_steps[0]) / 31:.0%} latency saved)")

state = lif.lif_init((8, 128))
state, spikes = lif.lif_step(state, drive * 0.02, lif.LIFParams(),
                             update_mask=mask)
print(f"LIF: {int(spikes.sum())} spikes, only {int(mask[0].sum())} of 128 "
      f"V_mem updates ({128 / int(mask[0].sum()):.1f}x serial-latency saving)")

# --- NLD mode: nonlinear dendrites through the reconfigurable IMA ------------
dp = dendrite.dendrite_init(jax.random.fold_in(key, 3), 256, 128, n_branches=2)
nld_drive = macro.nld_forward(events, dp, macro.CIMMacroConfig(
    code_bits=5, mac_range=4.0), activation="quadratic")
print(f"\nNLD mode: dendritic drive range [{float(nld_drive.min()):.2f}, "
      f"{float(nld_drive.max()):.2f}] via quadratic NL-IMA (f(x)=0.5x^2)")

# --- the numbers the paper measures ------------------------------------------
print("\nenergy model (calibrated to silicon):")
for k, v in energy.table1_energy_entries().items():
    print(f"  {k:28s} {v:.2f} pJ/SOP")
print(f"  1.6x-vs-SOTA check: {energy.improvement_vs_sota():.2f}x")
