"""End-to-end serving driver (the paper's kind: inference efficiency):
batched requests through a smolLM-architecture model, comparing standard
execution vs NeuDW-CIM mode (ternary twin-cell weights + NLQ activations on
every projection).

    PYTHONPATH=src python examples/serve_lm_cim.py
"""

from repro.launch import serve


def main():
    print("== standard execution ==")
    serve.main(["--arch", "smollm-135m", "--smoke", "--requests", "6",
                "--slots", "3", "--max-new", "8"])
    print("\n== NeuDW-CIM mode (ternary weights + NLQ activations) ==")
    serve.main(["--arch", "smollm-135m", "--smoke", "--requests", "6",
                "--slots", "3", "--max-new", "8", "--cim"])


if __name__ == "__main__":
    main()
