"""Small-LM training end-to-end: loss decreases, checkpoints are written,
and a restart resumes exactly (the production train driver on a reduced
smolLM config).

    PYTHONPATH=src python examples/train_lm_small.py
"""

import shutil
import tempfile

from repro.launch import train


def main():
    ckpt = tempfile.mkdtemp(prefix="repro_example_ckpt_")
    try:
        history = train.main([
            "--arch", "smollm-135m", "--smoke", "--steps", "12",
            "--global-batch", "8", "--seq-len", "64", "--n-micro", "2",
            "--ckpt-dir", ckpt, "--ckpt-every", "6", "--lr", "5e-3"])
        first, last = history[0]["loss"], history[-1]["loss"]
        print(f"\nloss {first:.3f} -> {last:.3f} "
              f"({'DECREASED' if last < first else 'did not decrease'})")
        print("resuming from checkpoint for 4 more steps...")
        train.main([
            "--arch", "smollm-135m", "--smoke", "--steps", "16",
            "--global-batch", "8", "--seq-len", "64", "--n-micro", "2",
            "--ckpt-dir", ckpt, "--ckpt-every", "8", "--lr", "5e-3"])
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
