"""Train the paper's SNN on the synthetic N-MNIST stand-in and evaluate both
silicon modes (the paper's Fig. 8 experiment, reduced).

Both the noise-free and the *noisy* silicon evaluations run on the fused
macro kernel (MAC -> IMA -> KWN/NLD -> LIF in one Pallas launch per event
sequence): the Fig. 7 IMA error model's per-step Gaussian draws are
generated inside the kernel by the counter PRNG, so noisy accuracy costs
the same single launch as clean.  The serving demo drains the same batched
engine twice — clean and noisy — to show noise-faithful serving.

The KWN cell additionally demonstrates **silicon-in-the-loop fine-tuning**
(the reduced Fig. 8 robustness experiment): after the software pre-train,
``--silicon-steps`` noise-aware QAT steps run *through* the fused kernel
(forward = the serving kernel under the Fig. 7 error model with a fresh
counter seed per step; backward = the surrogate BPTT Pallas kernel), and
the clean/noisy fused accuracies are printed before and after — the point
being that training against the silicon's own noise closes the
clean->noisy gap the software-trained model pays at serving time.

Passing ``--stack W1,W2[,...]`` appends a stacked-KWN cell: the same
software train, then clean + noisy evaluation through the *multi-layer*
fused kernel — all L macro layers chained in one Pallas launch per
sequence, the inter-layer spike tensor never leaving the chip — and the
same serving-engine drain.  (Silicon fine-tuning stays single-layer: the
stacked backward is a roadmap follow-up.)

    PYTHONPATH=src python examples/train_snn_events.py [--steps 150]
        [--silicon-steps 60] [--stack 96,64]
"""

import argparse

import jax

from repro.core import ima
from repro.data import events as ev_lib
from repro.models import snn
from repro.serve.engine import EventRequest, SNNEventEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--silicon-steps", type=int, default=60,
                    help="noise-aware QAT fine-tune steps through the fused "
                         "kernel (KWN mode; 0 disables the stage)")
    ap.add_argument("--silicon-lr", type=float, default=0.02)
    ap.add_argument("--dataset", default="nmnist",
                    choices=list(ev_lib.DATASETS))
    ap.add_argument("--serve-requests", type=int, default=96,
                    help="event streams pushed through the serving engine")
    ap.add_argument("--stack", default="",
                    help="comma-separated hidden widths for an extra "
                         "stacked-KWN cell (e.g. 96,64); every layer runs "
                         "in one multi-layer fused launch")
    args = ap.parse_args()

    ds = ev_lib.EventDataset(ev_lib.DATASETS[args.dataset])
    dcfg = ev_lib.DATASETS[args.dataset]
    noise_model = ima.IMANoiseModel()

    for mode in ("kwn", "nld"):
        cfg = snn.SNNConfig(n_in=dcfg.n_in, n_steps=dcfg.n_steps,
                            n_classes=dcfg.n_classes, mode=mode,
                            k=12 if args.dataset == "dvs_gesture" else 3)
        p, losses = snn.train(cfg, ds, n_steps=args.steps, batch=64)
        acc_n, _ = snn.evaluate(p, cfg, ds, jax.random.PRNGKey(1),
                                n_batches=4, noise=noise_model,
                                fused=True)
        acc_f, tele_f = snn.evaluate(p, cfg, ds, jax.random.PRNGKey(1),
                                     n_batches=4, fused=True)
        print(f"{args.dataset} {mode.upper():3s}: loss "
              f"{losses[0]:.2f}->{losses[-1]:.2f}  "
              f"fused acc {acc_f:.3f}  noisy fused acc {acc_n:.3f}  "
              f"mean ADC steps {tele_f['adc_steps']:.1f}/31  "
              f"LIF updates/step {tele_f['lif_updates']:.0f}/128")

        if mode == "kwn" and args.silicon_steps:
            # Silicon-in-the-loop fine-tune: train against the fused kernel
            # under the Fig. 7 error model (fresh counter seed per step).
            p_ft, ft_losses = snn.train(
                cfg, ds, n_steps=args.silicon_steps, batch=64,
                lr=args.silicon_lr, seed=5, silicon=True,
                noise=noise_model, params=p)
            ft_clean, _ = snn.evaluate(p_ft, cfg, ds, jax.random.PRNGKey(1),
                                       n_batches=4, fused=True)
            ft_noisy, _ = snn.evaluate(p_ft, cfg, ds, jax.random.PRNGKey(1),
                                       n_batches=4, noise=noise_model,
                                       fused=True)
            print(f"  silicon fine-tune ({args.silicon_steps} steps, "
                  f"noise-aware QAT): loss "
                  f"{ft_losses[0]:.3f}->{ft_losses[-1]:.3f}  "
                  f"clean {acc_f:.3f}->{ft_clean:.3f}  "
                  f"noisy {acc_n:.3f}->{ft_noisy:.3f}  "
                  f"(gap {acc_f - acc_n:+.3f} -> "
                  f"{ft_clean - ft_noisy:+.3f})")
            p = p_ft   # serve the silicon-tuned model below

        if mode == "kwn" and args.serve_requests:
            key = jax.random.PRNGKey(7)
            ev, lab = ds.sample(key, args.serve_requests)
            for tag, noise in (("clean", None), ("noisy", noise_model)):
                engine = SNNEventEngine(cfg, p, batch_slots=32, noise=noise)
                for i in range(args.serve_requests):
                    engine.submit(EventRequest(uid=i, events=ev[i],
                                               label=int(lab[i])))
                done = engine.run()
                hits = sum(r.pred == r.label for r in done)
                rep = engine.energy_report(args.dataset)
                print(f"  serve[{tag}]: {len(done)} requests  "
                      f"acc {hits/len(done):.3f}  measured ADC saving "
                      f"{rep['measured_adc_saving']:.2f}  "
                      f"{rep['pj_per_sop']:.2f} pJ/SOP")

    if args.stack:
        widths = tuple(int(w) for w in args.stack.split(","))
        k_top = 12 if args.dataset == "dvs_gesture" else 3
        cfg = snn.SNNConfig(n_in=dcfg.n_in, n_steps=dcfg.n_steps,
                            n_classes=dcfg.n_classes, mode="kwn",
                            hidden_layers=widths,
                            k_layers=(k_top,) * len(widths))
        p, losses = snn.train(cfg, ds, n_steps=args.steps, batch=64)
        acc_f, tele_f = snn.evaluate(p, cfg, ds, jax.random.PRNGKey(1),
                                     n_batches=4, fused=True)
        acc_n, _ = snn.evaluate(p, cfg, ds, jax.random.PRNGKey(1),
                                n_batches=4, noise=noise_model, fused=True)
        layers = "x".join(str(w) for w in widths)
        print(f"{args.dataset} KWN stack {layers} (one fused launch, "
              f"{len(widths)} layers on-chip): loss "
              f"{losses[0]:.2f}->{losses[-1]:.2f}  "
              f"fused acc {acc_f:.3f}  noisy fused acc {acc_n:.3f}  "
              f"skipped blocks {tele_f['skipped_block_ratio']:.2f}")
        if args.serve_requests:
            ev, lab = ds.sample(jax.random.PRNGKey(7), args.serve_requests)
            engine = SNNEventEngine(cfg, p, batch_slots=32)
            for i in range(args.serve_requests):
                engine.submit(EventRequest(uid=i, events=ev[i],
                                           label=int(lab[i])))
            done = engine.run()
            hits = sum(r.pred == r.label for r in done)
            rep = engine.energy_report(args.dataset)
            print(f"  serve[stack]: {len(done)} requests  "
                  f"acc {hits/len(done):.3f}  "
                  f"{rep['pj_per_sop']:.2f} pJ/SOP")


if __name__ == "__main__":
    main()
