"""Train the paper's SNN on the synthetic N-MNIST stand-in and evaluate both
silicon modes (the paper's Fig. 8 experiment, reduced).

The noise-free silicon evaluation and the batched event-stream serving demo
run on the *fused* macro-step kernel (MAC -> IMA -> KWN/NLD -> LIF in one
Pallas kernel per time step); the noisy evaluation exercises the composed
path with the Fig. 7 IMA error model.

    PYTHONPATH=src python examples/train_snn_events.py [--steps 150]
"""

import argparse

import jax

from repro.core import ima
from repro.data import events as ev_lib
from repro.models import snn
from repro.serve.engine import EventRequest, SNNEventEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--dataset", default="nmnist",
                    choices=list(ev_lib.DATASETS))
    ap.add_argument("--serve-requests", type=int, default=96,
                    help="event streams pushed through the serving engine")
    args = ap.parse_args()

    ds = ev_lib.EventDataset(ev_lib.DATASETS[args.dataset])
    dcfg = ev_lib.DATASETS[args.dataset]

    for mode in ("kwn", "nld"):
        cfg = snn.SNNConfig(n_in=dcfg.n_in, n_steps=dcfg.n_steps,
                            n_classes=dcfg.n_classes, mode=mode,
                            k=12 if args.dataset == "dvs_gesture" else 3)
        p, losses = snn.train(cfg, ds, n_steps=args.steps, batch=64)
        acc, tele = snn.evaluate(p, cfg, ds, jax.random.PRNGKey(1),
                                 n_batches=4, noise=ima.IMANoiseModel())
        acc_f, tele_f = snn.evaluate(p, cfg, ds, jax.random.PRNGKey(1),
                                     n_batches=4, fused=True)
        print(f"{args.dataset} {mode.upper():3s}: loss "
              f"{losses[0]:.2f}->{losses[-1]:.2f}  silicon acc {acc:.3f}  "
              f"fused acc {acc_f:.3f}  "
              f"mean ADC steps {tele_f['adc_steps']:.1f}/31  "
              f"LIF updates/step {tele_f['lif_updates']:.0f}/128")

        if mode == "kwn" and args.serve_requests:
            engine = SNNEventEngine(cfg, p, batch_slots=32)
            key = jax.random.PRNGKey(7)
            ev, lab = ds.sample(key, args.serve_requests)
            for i in range(args.serve_requests):
                engine.submit(EventRequest(uid=i, events=ev[i],
                                           label=int(lab[i])))
            done = engine.run()
            hits = sum(r.pred == r.label for r in done)
            rep = engine.energy_report(args.dataset)
            print(f"  serve: {len(done)} requests  acc {hits/len(done):.3f}  "
                  f"measured ADC saving {rep['measured_adc_saving']:.2f}  "
                  f"{rep['pj_per_sop']:.2f} pJ/SOP")


if __name__ == "__main__":
    main()
