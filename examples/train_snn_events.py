"""Train the paper's SNN on the synthetic N-MNIST stand-in and evaluate both
silicon modes (the paper's Fig. 8 experiment, reduced).

    PYTHONPATH=src python examples/train_snn_events.py [--steps 150]
"""

import argparse

import jax

from repro.core import ima
from repro.data import events as ev_lib
from repro.models import snn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--dataset", default="nmnist",
                    choices=list(ev_lib.DATASETS))
    args = ap.parse_args()

    ds = ev_lib.EventDataset(ev_lib.DATASETS[args.dataset])
    dcfg = ev_lib.DATASETS[args.dataset]

    for mode in ("kwn", "nld"):
        cfg = snn.SNNConfig(n_in=dcfg.n_in, n_steps=dcfg.n_steps,
                            n_classes=dcfg.n_classes, mode=mode,
                            k=12 if args.dataset == "dvs_gesture" else 3)
        p, losses = snn.train(cfg, ds, n_steps=args.steps, batch=64)
        acc, tele = snn.evaluate(p, cfg, ds, jax.random.PRNGKey(1),
                                 n_batches=4, noise=ima.IMANoiseModel())
        print(f"{args.dataset} {mode.upper():3s}: loss "
              f"{losses[0]:.2f}->{losses[-1]:.2f}  silicon acc {acc:.3f}  "
              f"mean ADC steps {tele['adc_steps']:.1f}/31  "
              f"LIF updates/step {tele['lif_updates']:.0f}/128")


if __name__ == "__main__":
    main()
