"""Train the paper's SNN on the synthetic N-MNIST stand-in and evaluate both
silicon modes (the paper's Fig. 8 experiment, reduced).

Both the noise-free and the *noisy* silicon evaluations run on the fused
macro kernel (MAC -> IMA -> KWN/NLD -> LIF in one Pallas launch per event
sequence): the Fig. 7 IMA error model's per-step Gaussian draws are
generated inside the kernel by the counter PRNG, so noisy accuracy costs
the same single launch as clean.  The serving demo drains the same batched
engine twice — clean and noisy — to show noise-faithful serving.

    PYTHONPATH=src python examples/train_snn_events.py [--steps 150]
"""

import argparse

import jax

from repro.core import ima
from repro.data import events as ev_lib
from repro.models import snn
from repro.serve.engine import EventRequest, SNNEventEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--dataset", default="nmnist",
                    choices=list(ev_lib.DATASETS))
    ap.add_argument("--serve-requests", type=int, default=96,
                    help="event streams pushed through the serving engine")
    args = ap.parse_args()

    ds = ev_lib.EventDataset(ev_lib.DATASETS[args.dataset])
    dcfg = ev_lib.DATASETS[args.dataset]

    for mode in ("kwn", "nld"):
        cfg = snn.SNNConfig(n_in=dcfg.n_in, n_steps=dcfg.n_steps,
                            n_classes=dcfg.n_classes, mode=mode,
                            k=12 if args.dataset == "dvs_gesture" else 3)
        p, losses = snn.train(cfg, ds, n_steps=args.steps, batch=64)
        acc_n, _ = snn.evaluate(p, cfg, ds, jax.random.PRNGKey(1),
                                n_batches=4, noise=ima.IMANoiseModel(),
                                fused=True)
        acc_f, tele_f = snn.evaluate(p, cfg, ds, jax.random.PRNGKey(1),
                                     n_batches=4, fused=True)
        print(f"{args.dataset} {mode.upper():3s}: loss "
              f"{losses[0]:.2f}->{losses[-1]:.2f}  "
              f"fused acc {acc_f:.3f}  noisy fused acc {acc_n:.3f}  "
              f"mean ADC steps {tele_f['adc_steps']:.1f}/31  "
              f"LIF updates/step {tele_f['lif_updates']:.0f}/128")

        if mode == "kwn" and args.serve_requests:
            key = jax.random.PRNGKey(7)
            ev, lab = ds.sample(key, args.serve_requests)
            for tag, noise in (("clean", None), ("noisy",
                                                ima.IMANoiseModel())):
                engine = SNNEventEngine(cfg, p, batch_slots=32, noise=noise)
                for i in range(args.serve_requests):
                    engine.submit(EventRequest(uid=i, events=ev[i],
                                               label=int(lab[i])))
                done = engine.run()
                hits = sum(r.pred == r.label for r in done)
                rep = engine.energy_report(args.dataset)
                print(f"  serve[{tag}]: {len(done)} requests  "
                      f"acc {hits/len(done):.3f}  measured ADC saving "
                      f"{rep['measured_adc_saving']:.2f}  "
                      f"{rep['pj_per_sop']:.2f} pJ/SOP")


if __name__ == "__main__":
    main()
