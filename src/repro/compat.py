"""Version shims for jax API drift.

The repo targets current jax but must run on the pinned container jax as
well; three APIs moved underneath us:

* ``jax.shard_map`` (new) vs ``jax.experimental.shard_map.shard_map`` (old),
  with the replication-check kwarg renamed ``check_rep`` -> ``check_vma``;
* ``jax.sharding.AxisType`` (new explicit-sharding mesh axis types) does not
  exist on older jax — ``make_mesh`` here passes ``axis_types`` only when the
  running jax knows about it;
* ``Compiled.cost_analysis()`` returns a bare dict on older jax and a
  one-element list of dicts on newer jax.

Import from here, never feature-detect at call sites.
"""

from __future__ import annotations

import jax


def shard_map(fn, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on old."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if hasattr(jax.sharding, "AxisType"):
        axis_types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
    return jax.make_mesh(axis_shapes, axis_names)


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` to a single per-module dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, dict):
        return ca
    return ca[0] if ca else {}
