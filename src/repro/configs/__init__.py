"""Architecture registry: --arch <id> -> LMConfig.

Each assigned architecture has its own module with the exact published config;
``get_config(id)`` resolves by the public id (dashes/dots as assigned).
"""

from repro.configs import (arctic_480b, gemma2_2b, hubert_xlarge,
                           internvl2_26b, kimi_k2_1t_a32b, nemotron_4_340b,
                           qwen2_5_32b, recurrentgemma_9b, smollm_135m,
                           xlstm_350m)
from repro.configs.base import reduced

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (kimi_k2_1t_a32b, arctic_480b, nemotron_4_340b, gemma2_2b,
              qwen2_5_32b, smollm_135m, hubert_xlarge, xlstm_350m,
              recurrentgemma_9b, internvl2_26b)
}


def get_config(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


# (arch, shape) cells that are skipped, with reasons (DESIGN.md SS4).
SHAPE_SKIPS = {
    ("hubert-xlarge", "decode_32k"): "encoder-only: no decode step",
    ("hubert-xlarge", "long_500k"): "encoder-only: no decode step",
    ("kimi-k2-1t-a32b", "long_500k"): "full attention: 500k is quadratic",
    ("arctic-480b", "long_500k"): "full attention: 500k is quadratic",
    ("nemotron-4-340b", "long_500k"): "full attention: 500k is quadratic",
    ("qwen2.5-32b", "long_500k"): "full attention: 500k is quadratic",
    ("smollm-135m", "long_500k"): "full attention: 500k is quadratic",
    ("internvl2-26b", "long_500k"): "full attention: 500k is quadratic",
    ("gemma2-2b", "long_500k"):
        "alternating local/GLOBAL: global layers are full attention",
}


def cells(shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k")):
    """All runnable (arch, shape) dry-run cells."""
    out = []
    for a in ARCHS:
        for s in shapes:
            if (a, s) not in SHAPE_SKIPS:
                out.append((a, s))
    return out
