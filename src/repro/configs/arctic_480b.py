"""arctic-480b — Snowflake Arctic: 128-expert top-2 MoE with a parallel
dense residual FFN [hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864, MoE 128e top-2, vocab 32000."""

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv=8,
    d_ff=4864,
    vocab_size=32000,
    activation="silu",
    moe=True,
    n_experts=128,
    moe_top_k=2,
    moe_dense_residual=True,
    sharding_overrides={
        "seq": "model",                    # Megatron sequence parallelism
        "experts": ("pod", "data"),        # 2D EP: experts over DP rows
        "expert_ffn": "model",             # TP inside each expert
        "embed": ("pod", "data"),          # FSDP for dense (attn/embed) weights
    },
)
