"""Config helpers: reduced-config factory for CPU smoke tests + the
optimized perf profile (the knobs ACCEPTED by the §Perf hillclimbs)."""

from __future__ import annotations

import dataclasses

from repro.models.lm import LMConfig


def optimized(cfg: LMConfig, *, serving: bool = False) -> LMConfig:
    """Apply the §Perf-accepted knobs (EXPERIMENTS.md):

    - MoE: int8 dispatch wire + capacity 1.0 (kimi ladder, confirmed);
    - training: dots remat policy (kimi + nemotron ladders, confirmed;
      costs ~15-40 % more activation memory — size the mesh accordingly);
    - serving: int8 KV cache (qwen ladder, confirmed; int4 available via
      kv_quant="int4" with an accuracy-risk note).

    Registry defaults stay paper-faithful so the §Roofline baseline table
    remains the reproduction; this profile is the beyond-paper state.
    """
    kw: dict = {"remat_policy": "dots"}
    if cfg.moe:
        kw.update(moe_wire_dtype="int8", moe_capacity_factor=1.0)
    if serving:
        kw.update(kv_quant="int8")
    return dataclasses.replace(cfg, **kw)


def reduced(cfg: LMConfig, *, n_layers: int | None = None, d_model: int = 64,
            vocab: int = 128) -> LMConfig:
    """Shrink an architecture to smoke-test size, preserving its *family
    structure* (pattern, GQA ratio, MoE routing, frontends, softcaps)."""
    heads = max(2, min(cfg.n_heads, 4))
    # preserve the GQA ratio where possible
    ratio = max(1, cfg.n_heads // cfg.n_kv)
    n_kv = max(1, heads // ratio)
    nl = n_layers or max(len(cfg.pattern),
                         2 * len(cfg.pattern) + len(cfg.tail_pattern))
    return dataclasses.replace(
        cfg,
        n_layers=nl,
        d_model=d_model,
        n_heads=heads,
        n_kv=n_kv,
        head_dim=d_model // heads if cfg.head_dim else 0,
        d_ff=d_model * 2 if cfg.d_ff else 0,
        d_rnn=d_model if cfg.d_rnn else 0,
        vocab_size=vocab,
        n_experts=8 if cfg.moe else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe else 0,
        window=32 if cfg.window else None,
        frontend_dim=16 if cfg.frontend_dim else 0,
        n_patches=4 if cfg.n_patches else 0,
        attn_chunk=64,
        dtype="float32",
        remat=False,
        vocab_pad_to=16,
    )
