"""gemma2-2b — local/global alternating attention, logit softcaps,
pre+post norms, tied embeddings [arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4, head_dim 256) d_ff=9216 vocab=256000.
long_500k is SKIPPED: the global layers are full attention (DESIGN.md)."""

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    activation="gelu",
    pattern=("attn_local", "attn"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    tie_embeddings=True,
    scale_embed=True,
)
