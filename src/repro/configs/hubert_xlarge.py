"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447].

48L d_model=1280 16H (MHA kv=16) d_ff=5120, 504 k-means target classes.
Modality frontend is a STUB per the assignment: the conv waveform stem is
replaced by precomputed 512-d frame embeddings + a learned projector.
Encoder-only: no decode shapes (DESIGN.md shape-skip table)."""

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv=16,
    d_ff=5120,
    vocab_size=504,
    activation="gelu",
    gated_ffn=False,
    encoder_only=True,
    frontend="audio_frames",
    frontend_dim=512,
)
