"""internvl2-26b — InternViT + InternLM2 VLM [arXiv:2404.16821].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 (padded to 92672 for
16-way sharding, Megatron-style).  The InternViT-6B frontend is a STUB per
the assignment: input_specs() provides precomputed 3200-d patch embeddings;
a learned projector maps them into the LM."""

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=16384,
    vocab_size=92553,
    activation="silu",
    frontend="vision_patches",
    frontend_dim=3200,
    n_patches=256,
    sharding_overrides={
        "seq": "model",                    # Megatron sequence parallelism
        "embed": ("pod", "data"),          # FSDP: weights sharded over DP too
    },
)
