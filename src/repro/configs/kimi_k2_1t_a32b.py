"""kimi-k2-1t-a32b — trillion-param MoE (Kimi K2) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) per-expert d_ff=2048, MoE 384 experts top-8
(+1 shared expert), vocab 163840.  The top-8 router is the paper's KWN circuit
at datacenter scale (DESIGN.md SS4)."""

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv=8,
    d_ff=2048,
    vocab_size=163840,
    activation="silu",
    moe=True,
    n_experts=384,
    moe_top_k=8,
    n_shared_experts=1,
    rope_theta=50000.0,
    sharding_overrides={
        "seq": "model",                    # Megatron sequence parallelism
        "experts": ("pod", "data"),        # 2D EP: experts over DP rows
        "expert_ffn": "model",             # TP inside each expert
        "embed": ("pod", "data"),          # FSDP for dense (attn/embed) weights
    },
)
