"""nemotron-4-340b — dense GQA with squared-ReLU FFN [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.  The squared-ReLU
activation is the quadratic nonlinearity the paper's NL-IMA implements
natively (DESIGN.md SS4: f(x)=0.5x^2, Fig. 7b)."""

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv=8,
    d_ff=73728,
    vocab_size=256000,
    activation="squared_relu",
    gated_ffn=False,
    sharding_overrides={
        "seq": "model",                    # Megatron sequence parallelism
        "embed": ("pod", "data"),          # FSDP: weights sharded over DP too
    },
)
