"""qwen2.5-32b — dense GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B family].

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064."""

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    d_ff=27648,
    vocab_size=152064,
    activation="silu",
    qkv_bias=True,
    rope_theta=1000000.0,
    sharding_overrides={
        "seq": "model",                    # Megatron sequence parallelism
        "embed": ("pod", "data"),          # FSDP: weights sharded over DP too
    },
)
