"""recurrentgemma-9b — Griffin: RG-LRU + local attention, 2:1
[arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1, head_dim 256) d_ff=12288 vocab=256000,
window 2048.  Sub-quadratic (LRU recurrence + bounded window) -> runs
long_500k.  Pattern (rglru, rglru, attn_local) x12 + 2 tail rglru blocks."""

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    activation="gelu",
    pattern=("rglru", "rglru", "attn_local"),
    window=2048,
    d_rnn=4096,
    scale_embed=True,
    tie_embeddings=True,
    supports_long_context=True,
)
