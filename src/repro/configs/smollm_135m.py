"""smollm-135m — llama-architecture small model
[hf:HuggingFaceTB/SmolLM-135M].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.  Tiny: data-parallel
dominant sharding (heads unsharded; see sharding_overrides)."""

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv=3,
    d_ff=1536,
    vocab_size=49152,
    activation="silu",
    tie_embeddings=True,
    sharding_overrides={"heads": None, "kv_heads": None},
)
