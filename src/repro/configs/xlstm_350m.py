"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517].

24 blocks d_model=1024 4H, no FFN (d_ff=0; xLSTM blocks carry their own
projections), vocab 50304, pattern mLSTM:sLSTM = 3:1.  Fully recurrent ->
runs long_500k.  mLSTM trains chunkwise (nn/recurrent.py)."""

from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv=4,
    d_ff=0,
    vocab_size=50304,
    pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    supports_long_context=True,
)
