"""NeuDW-CIM core: the paper's contribution as composable JAX modules.

ternary   — twin 9T bit-cell ternary quantization + multi-VDD composition (C1)
ima       — reconfigurable nonlinear in-memory ADC: NLQ / NL activation (C2)
kwn       — top-K winner selection with ramp early stop (C3)
dendrite  — nonlinear dendrites, Eq. (2) (C4)
lif       — digital LIF + SNL + PRBS noise, Eq. (1) (C5)
prbs      — LFSR noise generator
ctrprng   — counter-based Threefry PRNG shared by the fused kernel + oracles
macro     — 256x128 macro simulator + virtual macro-grid tiling
energy    — calibrated energy/latency model (Fig. 9, Table I)
"""

from repro.core import (  # noqa: F401
    ctrprng, dendrite, energy, ima, kwn, lif, macro, prbs, ternary)
