"""Counter-based PRNG shared by the fused Pallas kernel and its oracles.

The Fig. 7 IMA error model needs a fresh Gaussian per (time step, row,
column) *inside* the fused kernel.  A stateful generator (the TPU hardware
PRNG behind ``pltpu.prng_random_bits``, or the PRBS LFSR) cannot serve here:
its stream depends on how the launch iterates the grid, so changing the tile
plan — or comparing against a pure-JAX reference — changes the draws.  A
*counter-based* generator makes the draw a pure function of
``(seed, step, row, column)``: the same element gets the same noise for any
(bm, bk, bn) tiling, any batch padding, and in the jnp oracle, which is what
lets noisy fused output stay **bitwise-equal** to ``kernels/ref.py`` and
lets a re-run with the same seed reproduce spikes exactly.

The block cipher is Threefry-2x32 with the standard 20-round schedule (the
same construction ``jax.random`` uses; implemented here by hand so the
identical uint32 ops run both inside the Pallas kernel body and in the
oracle).  Gaussians come from one cipher call per element via Box–Muller on
the two output words; SNL sign noise uses the low bit of the first word.
Distinct consumers are domain-separated through the key's second word
(``tag ^ step``), so the IMA and SNL streams never collide.

Everything here is plain ``jnp`` uint32/f32 arithmetic — no host callbacks,
no Pallas-specific primitives — so the same function object is traceable
inside a kernel body (interpret or compiled) and in ordinary jitted code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Key-lane tags: domain separation between the noise consumers.
TAG_IMA = 0x494D4101   # IMA conversion error (Fig. 7a/b)
TAG_SNL = 0x534E4C01   # SNL probabilistic-firing sign noise (Eq. 1 n(t))

_PARITY = 0x1BD11BDA   # Threefry key-schedule parity constant
_ROT_A = (13, 15, 26, 6)
_ROT_B = (17, 29, 16, 24)


def _rotl(x: jax.Array, r: int) -> jax.Array:
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def _mix(x0: jax.Array, x1: jax.Array, rots) -> tuple[jax.Array, jax.Array]:
    for r in rots:
        x0 = x0 + x1
        x1 = _rotl(x1, r) ^ x0
    return x0, x1


def threefry2x32(k0, k1, c0, c1) -> tuple[jax.Array, jax.Array]:
    """Threefry-2x32-20: key (k0, k1), counter (c0, c1) -> two uint32 words.

    All inputs broadcast; arithmetic is mod-2^32 (uint32 wraparound).
    """
    k0 = jnp.asarray(k0).astype(jnp.uint32)
    k1 = jnp.asarray(k1).astype(jnp.uint32)
    ks2 = k0 ^ k1 ^ jnp.uint32(_PARITY)
    x0 = jnp.asarray(c0).astype(jnp.uint32) + k0
    x1 = jnp.asarray(c1).astype(jnp.uint32) + k1
    ks = (k0, k1, ks2)
    for i in range(5):
        x0, x1 = _mix(x0, x1, _ROT_A if i % 2 == 0 else _ROT_B)
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


def _unit_open(bits: jax.Array) -> jax.Array:
    """uint32 -> f32 uniform on the open interval (0, 1).

    Uses the top 24 bits (exact in f32) shifted off zero by half an ulp so
    ``log`` in Box–Muller never sees 0.
    """
    hi24 = (bits >> jnp.uint32(8)).astype(jnp.float32)
    return (hi24 + jnp.float32(0.5)) * jnp.float32(2.0 ** -24)


def counter_normal(seed, step, rows: jax.Array, cols: jax.Array,
                   tag: int) -> jax.Array:
    """One standard-normal draw per (row, col) element.

    seed:  uint32/int32 scalar (traced or Python int).
    step:  time-step index (traced or Python int) — folded into the key.
    rows/cols:  broadcastable int32 arrays of *global* element coordinates
                (absolute row index, logical column index), so padding and
                tiling cannot shift the stream.
    """
    k0 = jnp.asarray(seed).astype(jnp.uint32)
    k1 = jnp.uint32(tag) ^ jnp.asarray(step).astype(jnp.uint32)
    b0, b1 = threefry2x32(k0, k1, rows, cols)
    r = jnp.sqrt(jnp.float32(-2.0) * jnp.log(_unit_open(b0)))
    theta = jnp.float32(2.0 * 3.141592653589793) * _unit_open(b1)
    return r * jnp.cos(theta)


def counter_sign(seed, step, rows: jax.Array, cols: jax.Array,
                 tag: int) -> jax.Array:
    """±1.0 f32 per element — the PRBS-equivalent two-level noise source."""
    k0 = jnp.asarray(seed).astype(jnp.uint32)
    k1 = jnp.uint32(tag) ^ jnp.asarray(step).astype(jnp.uint32)
    b0, _ = threefry2x32(k0, k1, rows, cols)
    return (b0 & jnp.uint32(1)).astype(jnp.float32) * 2.0 - 1.0


def noisy_ima_codes(ideal_codes: jax.Array, x: jax.Array,
                    rows: jax.Array, cols: jax.Array, seed, step,
                    params, n_codes: int) -> jax.Array:
    """Fig. 7 error injection in code space, shared by kernel and oracle.

    Mirrors ``ima.ima_convert_noisy`` operation-for-operation: a slow
    sinusoidal INL profile over the normalized input range, a constant
    comparator offset, and Gaussian thermal noise — all in code LSBs — then
    round and clip to the ripple-counter range.  ``params`` is any object
    with ``offset_lsb / sigma_lsb / inl_lsb / in_lo / in_hi`` floats
    (``ima.IMAKernelNoise``).
    """
    u = (x - jnp.float32(params.in_lo)) / jnp.float32(
        params.in_hi - params.in_lo + 1e-9)
    inl = jnp.float32(params.inl_lsb) * jnp.sin(
        jnp.float32(2.0 * 3.141592653589793) * u)
    g = counter_normal(seed, step, rows, cols, TAG_IMA)
    eps = jnp.float32(params.offset_lsb) + jnp.float32(params.sigma_lsb) * g
    code = jnp.round(ideal_codes.astype(jnp.float32) + inl + eps)
    return jnp.clip(code.astype(jnp.int32), 0, n_codes - 1)
