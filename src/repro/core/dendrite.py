"""Nonlinear dendrites — NLD mode (paper C4, Eq. 2, Fig. 1c).

Each output neuron p owns J dendritic branches; branch j computes a sparse
synaptic MAC passed through the NL-IMA activation f(), then the soma combines
branches with dendritic weights W^d:

    V_mem^p(t+1) = sum_j W^d_{j,p} f( sum_i W^s_{i,j,p} S_i ) + beta V_mem^p(t)

"Owing to the inherent sparsity of the connections, this enhancement is
achieved without increasing the total parameter overhead": each branch sees
only a subset of inputs.  We realize that with a fixed (hash-based) binary
connectivity mask so total synapse count matches a dense single-stage layer.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ima as ima_lib


class DendriteParams(NamedTuple):
    w_syn: jax.Array    # (J, I, N) synaptic weights (masked sparse)
    w_dend: jax.Array   # (J, N) dendritic combine weights
    mask: jax.Array     # (J, I, N) fixed 0/1 connectivity


def dendrite_init(key: jax.Array, n_in: int, n_out: int, n_branches: int,
                  fanin_frac: float | None = None,
                  gain: float = 8.0) -> DendriteParams:
    """Sparse branch connectivity keeping total synapses == n_in * n_out.

    Default fan-in fraction 1/J so J branches together cost the same as one
    dense layer (paper: no parameter overhead).  ``gain`` scales w_syn so
    branch MACs land in the NL-IMA's useful range for *sparse event* inputs
    (a few % spike rate): without it the quadratic dendrite squashes
    near-zero MACs to nothing and the soma never fires.
    """
    if fanin_frac is None:
        fanin_frac = 1.0 / n_branches
    k1, k2, k3 = jax.random.split(key, 3)
    mask = (jax.random.uniform(k1, (n_branches, n_in, n_out)) < fanin_frac)
    mask = mask.astype(jnp.float32)
    fan_in = max(1.0, n_in * fanin_frac)
    w_syn = gain * jax.random.normal(k2, (n_branches, n_in, n_out)) \
        / jnp.sqrt(fan_in)
    w_dend = jax.random.normal(k3, (n_branches, n_out)) / jnp.sqrt(float(n_branches))
    return DendriteParams(w_syn * mask, w_dend, mask)


def dendrite_mac(params: DendriteParams, spikes: jax.Array,
                 f: Callable[[jax.Array], jax.Array] | None = None,
                 nl_cb: ima_lib.RampCodebook | None = None,
                 quantize: bool = False) -> jax.Array:
    """Eq. (2) drive term: sum_j W^d_j f(branch_mac_j).

    spikes: (..., I) ternary inputs.
    f:      ideal activation (training path);
    nl_cb:  NL-IMA codebook — when given with ``quantize=True`` the branch MACs
            go through the quantized ramp (silicon inference path).
    """
    w = params.w_syn * params.mask
    # branch MACs: (..., J, N)
    mac = jnp.einsum("...i,jin->...jn", spikes, w)
    if quantize and nl_cb is not None:
        if f is not None:
            # STE around the true activation: forward = quantized NL-IMA,
            # backward = f'(mac) (much better-conditioned than a straight
            # pass-through for training the dendrites).
            act_f = f(mac)
            act = act_f + jax.lax.stop_gradient(
                ima_lib.ima_quantize(mac, nl_cb) - act_f)
        else:
            act = ima_lib.ima_quantize_ste(mac, nl_cb)
    elif f is not None:
        act = f(mac)
    else:
        act = mac
    return jnp.einsum("...jn,jn->...n", act, params.w_dend)
