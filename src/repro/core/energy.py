"""Calibrated analytical energy/latency model (paper Fig. 9, Table I).

The analog physics (bit-line discharge, multi-VDD rails, ramp ADC cycles,
serial digital LIF) exists on TPU only as a *model*.  Component energies are
calibrated at VDD_ref = 0.7 V so the model reproduces the paper's measured
numbers:

  KWN  K=3  (N-MNIST)      0.8 pJ/SOP      KWN  K=12 (DVS Gesture)  1.5 pJ/SOP
  NLD  N-MNIST 1.8 / DVS Gesture 2.3 / Quiroga 2.1 pJ/SOP
  KWN control logic = 16.8 % of total power
  ADC early-stop saving ~30 % (K=12, DVS);  LIF 10x (K=12 of 128)
  1.6x EE improvement over the 1.3 pJ/SOP SOTA [9]

Dynamic energy scales ~VDD^2 (Fig. 9b).  All energies in pJ, VDD in volts.
"""

from __future__ import annotations

from typing import NamedTuple

MACRO_ROWS, MACRO_COLS = 256, 128
VDD_REF = 0.7
N_RAMP_STEPS = 31          # 5-bit IMA: 2^5 - 1 ramp steps
CTRL_FRAC_KWN = 0.168      # KWN early-stop control logic share of total power

# --- calibrated component energies at VDD_REF (pJ) -------------------------
E_MAC_PER_SOP = 0.5                    # analog twin-cell MAC per active SOP
E_ADC_PER_STEP_COL = 0.08              # linear/NLQ ramp: per step, per column
E_LIF_PER_UPDATE = 1.0                 # digital 12-bit LIF pipeline per neuron
E_ADC_NL_PER_STEP_COL = {              # NL-activation ramps (pulse-width mod.)
    "quadratic": 0.139,                # N-MNIST NLD activation
    "relu": 0.0549,                    # DVS Gesture NLD activation
    "sigmoid4": 0.100,                 # Quiroga NLD activation
}
N_DENDRITE_BRANCHES = 2                # J conversions per output in NLD mode

# --- calibrated dataset statistics (input spike rate on the macro) ---------
SPIKE_RATES = {
    "nmnist": 0.0289,
    "dvs_gesture": 0.0096,
    "quiroga": 0.0176,
}
NLD_ACTIVATION = {
    "nmnist": "quadratic",
    "dvs_gesture": "relu",
    "quiroga": "sigmoid4",
}
KWN_K = {"nmnist": 3, "dvs_gesture": 12}


def vdd_scale(vdd: float) -> float:
    return (vdd / VDD_REF) ** 2


def early_stop_saving(k: int) -> float:
    """Fraction of ramp steps saved by Stop_ADC after the K-th crossing.

    Linear fit through the two calibration points implied by the measured
    energies (K=3 -> 51.6 %, K=12 -> 30 % = the paper's DVS Gesture claim).
    """
    return max(0.0, 0.588 - 0.024 * k)


def adc_steps_early_stop(k: int) -> float:
    return N_RAMP_STEPS * (1.0 - early_stop_saving(k))


class EnergyBreakdown(NamedTuple):
    mac: float
    adc: float
    lif: float
    control: float

    @property
    def total(self) -> float:
        return self.mac + self.adc + self.lif + self.control

    def as_dict(self) -> dict:
        t = self.total
        return {
            "mac_pj": self.mac, "adc_pj": self.adc, "lif_pj": self.lif,
            "control_pj": self.control, "total_pj": t,
            "frac": {"mac": self.mac / t, "adc": self.adc / t,
                     "lif": self.lif / t, "control": self.control / t},
        }


def sops_per_step(spike_rate: float) -> float:
    """Active synaptic operations per macro time step."""
    return spike_rate * MACRO_ROWS * MACRO_COLS


def kwn_step_energy(k: int, spike_rate: float, vdd: float = VDD_REF,
                    adc_steps: float | None = None) -> EnergyBreakdown:
    """Energy of one macro time step in KWN mode (all 128 columns).

    ``adc_steps`` overrides the analytic early-stop fit with a *measured*
    mean ramp step count (e.g. the fused kernel's per-row telemetry).
    """
    s = vdd_scale(vdd)
    if adc_steps is None:
        adc_steps = adc_steps_early_stop(k)
    e_mac = sops_per_step(spike_rate) * E_MAC_PER_SOP * s
    e_adc = MACRO_COLS * adc_steps * E_ADC_PER_STEP_COL * s
    e_lif = k * E_LIF_PER_UPDATE * s
    parts = e_mac + e_adc + e_lif
    e_ctrl = parts * CTRL_FRAC_KWN / (1.0 - CTRL_FRAC_KWN)
    return EnergyBreakdown(e_mac, e_adc, e_lif, e_ctrl)


def nld_step_energy(spike_rate: float, activation: str,
                    vdd: float = VDD_REF) -> EnergyBreakdown:
    """Energy of one macro time step in NLD mode (full conversion, dense LIF)."""
    s = vdd_scale(vdd)
    e_mac = sops_per_step(spike_rate) * E_MAC_PER_SOP * s
    e_adc = (N_DENDRITE_BRANCHES * MACRO_COLS * N_RAMP_STEPS
             * E_ADC_NL_PER_STEP_COL[activation] * s)
    e_lif = MACRO_COLS * E_LIF_PER_UPDATE * s
    return EnergyBreakdown(e_mac, e_adc, e_lif, 0.0)


def kwn_pj_per_sop(k: int, spike_rate: float, vdd: float = VDD_REF) -> float:
    return kwn_step_energy(k, spike_rate, vdd).total / sops_per_step(spike_rate)


def nld_pj_per_sop(spike_rate: float, activation: str,
                   vdd: float = VDD_REF) -> float:
    return (nld_step_energy(spike_rate, activation, vdd).total
            / sops_per_step(spike_rate))


# ---------------------------------------------------------------------------
# Paper-table reproductions
# ---------------------------------------------------------------------------

def table1_energy_entries(vdd: float = VDD_REF) -> dict:
    """The Table I EE cells this model must reproduce."""
    return {
        "kwn_nmnist_pj_per_sop": kwn_pj_per_sop(3, SPIKE_RATES["nmnist"], vdd),
        "kwn_dvs_pj_per_sop": kwn_pj_per_sop(12, SPIKE_RATES["dvs_gesture"], vdd),
        "nld_nmnist_pj_per_sop": nld_pj_per_sop(
            SPIKE_RATES["nmnist"], NLD_ACTIVATION["nmnist"], vdd),
        "nld_dvs_pj_per_sop": nld_pj_per_sop(
            SPIKE_RATES["dvs_gesture"], NLD_ACTIVATION["dvs_gesture"], vdd),
        "nld_quiroga_pj_per_sop": nld_pj_per_sop(
            SPIKE_RATES["quiroga"], NLD_ACTIVATION["quiroga"], vdd),
    }


def improvement_vs_sota(sota_pj_per_sop: float = 1.3) -> float:
    """1.6x claim vs NeuC-CIM [9] (1.3 pJ/SOP)."""
    best = kwn_pj_per_sop(3, SPIKE_RATES["nmnist"], VDD_REF)
    return sota_pj_per_sop / best


def ee_vs_vdd(vdds=(0.7, 0.8, 0.9, 1.0)) -> dict:
    """Fig. 9b: EE across supply voltages for the two headline points."""
    return {
        f"{v:.1f}V": {
            "kwn_k3_nmnist": kwn_pj_per_sop(3, SPIKE_RATES["nmnist"], v),
            "kwn_k12_dvs": kwn_pj_per_sop(12, SPIKE_RATES["dvs_gesture"], v),
        }
        for v in vdds
    }


def lif_latency_speedup(k: int = 12, n: int = MACRO_COLS) -> float:
    return n / float(k)


def modeled_power_mw(mode: str, dataset: str, step_rate_hz: float,
                     vdd: float = VDD_REF) -> float:
    """Average power at a macro step rate (duty-cycled, paper: 0.22/0.17 mW)."""
    if mode == "kwn":
        e = kwn_step_energy(KWN_K[dataset], SPIKE_RATES[dataset], vdd).total
    else:
        e = nld_step_energy(SPIKE_RATES[dataset],
                            NLD_ACTIVATION[dataset], vdd).total
    return e * 1e-12 * step_rate_hz * 1e3
