"""Reconfigurable nonlinear In-Memory ADC (paper C2, Figs. 6/7).

The silicon IMA is a 46x128 SRAM array that builds a differential *ramp* on the
read bit-lines: rows are turned on sequentially until the ramp crosses the MAC
result stored on the RBL; the crossing step index (ripple-counter value) is the
digital code.  Two reconfigurations:

  * **NLQ** (KWN mode): variable pulse width per row makes the ramp nonlinear,
    so a 5-bit code spans an 8-bit input range with fine resolution where MAC
    values are dense.  Codes are mapped back to 8-bit values with a LUT.
  * **NL activation** (NLD mode): the ramp directly realizes y = f(x) (e.g.
    y = 0.5 x^2, Fig. 7b) by modulating the pulse width of each quantization
    step -> the counter output *is* f(x) quantized.

TPU adaptation: a ramp comparison against monotone level boundaries is exactly
``searchsorted`` against a codebook.  We implement the codebooks, the
quantize/dequantize pair, the INL/noise model matching the measured silicon
(mu = 0.41 LSB, sigma = 1.34 LSB for NLQ; INL 0.91 LSB for NL activation), and
differentiable (STE) variants for QAT.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ctrprng


class RampCodebook(NamedTuple):
    """Monotone ramp description.

    levels:     (2^code_bits,) reconstruction values (LUT in KWN mode).
    boundaries: (2^code_bits - 1,) decision thresholds between codes.
    in_range:   (lo, hi) full-scale analog input range.
    """

    levels: jax.Array
    boundaries: jax.Array
    in_lo: float
    in_hi: float

    @property
    def n_codes(self) -> int:
        return int(self.levels.shape[0])


def linear_codebook(code_bits: int, in_lo: float, in_hi: float) -> RampCodebook:
    """Uniform ramp: the IMA's default linear-ADC configuration."""
    n = 2 ** code_bits
    levels = jnp.linspace(in_lo, in_hi, n)
    boundaries = 0.5 * (levels[1:] + levels[:-1])
    return RampCodebook(levels, boundaries, float(in_lo), float(in_hi))


def nlq_codebook(code_bits: int, in_lo: float, in_hi: float,
                 gamma: float = 2.0) -> RampCodebook:
    """Nonlinear quantization codebook (Fig. 6b).

    MAC distributions are zero-peaked, so NLQ spends codes densely near zero
    and sparsely at the tails — a mu-law-like companding ramp realized on
    silicon by shrinking the pulse width of early rows.  ``gamma`` controls
    companding strength; gamma=2 gives the 5-bit-covers-8-bit-range behaviour
    the paper uses (each NLQ code maps back to an 8-bit LUT value).
    """
    n = 2 ** code_bits
    # Symmetric companding on [-1, 1] then affine to [in_lo, in_hi].
    u = jnp.linspace(-1.0, 1.0, n)
    comp = jnp.sign(u) * (jnp.abs(u) ** gamma)
    mid, half = (in_hi + in_lo) / 2.0, (in_hi - in_lo) / 2.0
    levels = mid + half * comp
    boundaries = 0.5 * (levels[1:] + levels[:-1])
    return RampCodebook(levels, boundaries, float(in_lo), float(in_hi))


def activation_codebook(code_bits: int, f: Callable[[jax.Array], jax.Array],
                        in_lo: float, in_hi: float) -> RampCodebook:
    """NL-activation ramp: counter output approximates f(x) (Fig. 6a, NLD).

    The ramp still *decides* on uniform input steps (row index <-> input
    level), but the per-step pulse-width modulation makes the accumulated
    counter value equal f(level) — i.e. reconstruction levels are f(x_i).
    """
    n = 2 ** code_bits
    xs = jnp.linspace(in_lo, in_hi, n)
    boundaries = 0.5 * (xs[1:] + xs[:-1])
    return RampCodebook(f(xs), boundaries, float(in_lo), float(in_hi))


# ---------------------------------------------------------------------------
# Convert / reconstruct
# ---------------------------------------------------------------------------

def ima_convert(x: jax.Array, cb: RampCodebook) -> jax.Array:
    """Ramp conversion: analog value -> integer code (ripple-counter value)."""
    return jnp.searchsorted(cb.boundaries, x).astype(jnp.int32)


def ima_reconstruct(code: jax.Array, cb: RampCodebook) -> jax.Array:
    """LUT map-back (8-bit value in KWN mode; f(x) sample in NLD mode)."""
    return jnp.take(cb.levels, jnp.clip(code, 0, cb.n_codes - 1))


def ima_quantize(x: jax.Array, cb: RampCodebook) -> jax.Array:
    """convert + reconstruct in one go (the value the digital LIF receives)."""
    return ima_reconstruct(ima_convert(x, cb), cb)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _ima_ste(x: jax.Array, levels: jax.Array, boundaries: jax.Array) -> jax.Array:
    code = jnp.searchsorted(boundaries, x)
    return jnp.take(levels, code)


def _ima_ste_fwd(x, levels, boundaries):
    return _ima_ste(x, levels, boundaries), (x, levels)


def _ima_ste_bwd(res, g):
    x, levels = res
    lo, hi = levels[0], levels[-1]
    # Straight-through inside range (the ramp saturates outside full scale).
    mask = ((x >= jnp.minimum(lo, hi) - 0.5) & (x <= jnp.maximum(lo, hi) + 0.5))
    return g * mask.astype(g.dtype), jnp.zeros_like(levels), None


_ima_ste.defvjp(_ima_ste_fwd, _ima_ste_bwd)


def ima_quantize_ste(x: jax.Array, cb: RampCodebook) -> jax.Array:
    """Differentiable fake-quant through the IMA (used for NLQ-aware training,
    the paper's Fig. 6c 'NLQ used in training' experiment)."""
    return _ima_ste(x, cb.levels, cb.boundaries)


# ---------------------------------------------------------------------------
# Silicon error model (Fig. 7)
# ---------------------------------------------------------------------------

class IMANoiseModel(NamedTuple):
    """Injected non-idealities, in LSB units of the codebook.

    The *injection* constants below are calibrated so the *measured* statistics
    (via ``measure_transfer_error`` / ``measure_inl``, which include rounding
    interactions exactly like the silicon measurement does) reproduce the
    paper's Fig. 7: mean error 0.41 LSB, sigma 1.34 LSB, activation INL 0.91 LSB.
    """

    offset_lsb: float = 0.45   # -> measured mu  ~ 0.41 LSB (Fig. 7a)
    sigma_lsb: float = 1.35    # -> measured sig ~ 1.34 LSB (Fig. 7a)
    inl_lsb: float = 0.56      # -> measured INL ~ 0.91 LSB (Fig. 7b)


def lsb_size(cb: RampCodebook) -> jax.Array:
    return (cb.in_hi - cb.in_lo) / (cb.n_codes - 1)


def ima_convert_noisy(x: jax.Array, cb: RampCodebook, key: jax.Array,
                      noise: IMANoiseModel = IMANoiseModel()) -> jax.Array:
    """Conversion including comparator offset + thermal noise.

    The paper measures the error *in code LSBs* (Fig. 7a: mu=0.41, sigma=1.34)
    — i.e. the ripple-counter value deviates by whole steps — so we model it in
    code space: a deterministic INL profile (slow sinusoid over the ramp, peak
    ``inl_lsb``, the pulse-width systematic) plus offset and Gaussian noise.
    """
    ideal = ima_convert(x, cb).astype(jnp.float32)
    u = (x - cb.in_lo) / (cb.in_hi - cb.in_lo + 1e-9)
    inl = noise.inl_lsb * jnp.sin(2.0 * jnp.pi * u)
    eps = noise.offset_lsb + noise.sigma_lsb * jax.random.normal(key, x.shape)
    code = jnp.round(ideal + inl + eps).astype(jnp.int32)
    return jnp.clip(code, 0, cb.n_codes - 1)


class IMAKernelNoise(NamedTuple):
    """Kernel-consumable form of ``IMANoiseModel``: all-static floats.

    The fused Pallas kernel takes this struct as a *static* argument (it is
    hashable), so the injection constants and the codebook's full-scale range
    compile into the kernel body; only the seed/step counter words are traced.
    Build it with ``kernel_noise_params`` so the range always matches the
    codebook the ramp actually sweeps.
    """

    offset_lsb: float
    sigma_lsb: float
    inl_lsb: float
    in_lo: float
    in_hi: float


def kernel_noise_params(noise: IMANoiseModel,
                        cb: RampCodebook) -> IMAKernelNoise:
    """Bind an ``IMANoiseModel`` to a codebook's input range for the kernel."""
    return IMAKernelNoise(
        offset_lsb=float(noise.offset_lsb), sigma_lsb=float(noise.sigma_lsb),
        inl_lsb=float(noise.inl_lsb), in_lo=float(cb.in_lo),
        in_hi=float(cb.in_hi))


def ima_convert_noisy_ctr(x: jax.Array, cb: RampCodebook,
                          params: IMAKernelNoise, seed, step=0) -> jax.Array:
    """Counter-based noisy conversion: the in-kernel Fig. 7 error model.

    Same statistics as ``ima_convert_noisy`` but every draw is a pure
    function of ``(seed, step, row, column)`` — the exact stream the fused
    kernel generates, so host-side evaluation of this function *is* the
    noisy-kernel oracle.  ``x`` is at most 2-D ``(rows, cols)``; a 1-D input
    is treated as a single row.
    """
    x2 = x[None] if x.ndim == 1 else x
    assert x2.ndim == 2, x.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, x2.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, x2.shape, 1)
    ideal = ima_convert(x2, cb)
    code = ctrprng.noisy_ima_codes(ideal, x2, rows, cols, seed, step, params,
                                   cb.n_codes)
    return code[0] if x.ndim == 1 else code


def measure_transfer_error_ctr(cb: RampCodebook,
                               noise: IMANoiseModel = IMANoiseModel(),
                               seed: int = 0, n_points: int = 4096,
                               n_steps: int = 8) -> dict:
    """Fig. 7a measurement against the *counter* noise stream.

    Sweeps the input range at ``n_points`` resolution across ``n_steps``
    independent time steps and reports the code-error moments in LSB — the
    golden test pins these to the paper's mu ~ 0.41 / sigma ~ 1.34.
    """
    params = kernel_noise_params(noise, cb)
    xs = jnp.broadcast_to(jnp.linspace(cb.in_lo, cb.in_hi, n_points),
                          (n_steps, n_points))
    ideal = ima_convert(xs, cb)

    def one_step(step):
        return ima_convert_noisy_ctr(xs[step], cb, params, seed, step)

    noisy = jax.vmap(one_step)(jnp.arange(n_steps))
    err = (noisy - ideal).astype(jnp.float32)
    return {"mean_lsb": float(jnp.mean(err)), "std_lsb": float(jnp.std(err))}


def measure_transfer_error(cb: RampCodebook, key: jax.Array,
                           noise: IMANoiseModel = IMANoiseModel(),
                           n_points: int = 4096) -> dict:
    """Monte-Carlo the silicon measurement of Fig. 7a: sweep the input range,
    convert with noise, compare against the ideal code; report mu/sigma in LSB.
    """
    xs = jnp.linspace(cb.in_lo, cb.in_hi, n_points)
    ideal = ima_convert(xs, cb)
    noisy = ima_convert_noisy(xs, cb, key, noise)
    err = (noisy - ideal).astype(jnp.float32)
    return {"mean_lsb": float(jnp.mean(err)), "std_lsb": float(jnp.std(err))}


def measure_inl(cb: RampCodebook, f: Callable[[jax.Array], jax.Array],
                n_points: int = 4096, key: jax.Array | None = None,
                noise: "IMANoiseModel | None" = None) -> float:
    """Average INL of the NL-activation ramp vs the ideal curve (Fig. 7b),
    in LSB of the *output* range.

    With ``noise`` given, includes the silicon's systematic pulse-width error
    (this is what the paper's 0.91 LSB measurement contains); without, it is
    the ideal-emulation INL (quantization only).
    """
    xs = jnp.linspace(cb.in_lo, cb.in_hi, n_points)
    if noise is not None and key is not None:
        codes = ima_convert_noisy(xs, cb, key,
                                  IMANoiseModel(0.0, noise.sigma_lsb * 0.0,
                                                noise.inl_lsb))
        y_hat = ima_reconstruct(codes, cb)
    else:
        y_hat = ima_quantize(xs, cb)
    y = f(xs)
    out_lsb = (jnp.max(cb.levels) - jnp.min(cb.levels)) / (cb.n_codes - 1)
    inl = jnp.abs(y_hat - y) / jnp.maximum(out_lsb, 1e-9)
    return float(jnp.mean(inl))


# Convenience activations the NLD experiments use --------------------------------

def quadratic(x: jax.Array) -> jax.Array:
    """y = 0.5 x^2 — the measured Fig. 7b activation."""
    return 0.5 * x * x


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)


def sigmoid4(x: jax.Array) -> jax.Array:
    """Saturating dendritic nonlinearity."""
    return 4.0 * jax.nn.sigmoid(x)


DENDRITE_ACTIVATIONS: dict[str, Callable[[jax.Array], jax.Array]] = {
    "quadratic": quadratic,
    "relu": relu,
    "sigmoid4": sigmoid4,
}
