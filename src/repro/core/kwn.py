"""Top-K Winner (KWN) selection with ramp early stop (paper C3, Fig. 4, Eq. 1).

Silicon behaviour: after the MAC settles on the RBLs, the IMA sweeps a
*descending* ramp; the largest MAC values cross first.  A priority encoder
records (column index j, counter value Z_j) at each crossing; after the K-th
crossing the controller asserts Stop_ADC — the remaining 128-K columns are
never converted.  Only the K winners' V_mem are updated by the digital LIF.

TPU adaptation: we provide
  * ``kwn_select`` — exact top-K (jax.lax.top_k fast path) returning the same
    (indices, codes, mask) the silicon registers would hold;
  * ``kwn_ramp_scan`` — the literal descending threshold scan, used by the
    latency model (its step count *is* the ADC cycle count with early stop)
    and by the Pallas kernel's reference semantics;
  * latency accounting that reproduces the −30 % ADC and 10× LIF claims.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.ima import RampCodebook, ima_convert


class KWNResult(NamedTuple):
    indices: jax.Array   # (..., K) winner column indices (PENC outputs)
    codes: jax.Array     # (..., K) quantized MAC codes Z_j for the winners
    mask: jax.Array      # (..., N) 1.0 where the column won, else 0.0
    adc_steps: jax.Array # (...,) ramp steps until the K-th crossing (early stop)


def kwn_select(mac: jax.Array, k: int, cb: RampCodebook) -> KWNResult:
    """Exact top-K with ramp-consistent codes.

    Ties are broken by column index (lower index wins), matching the priority
    encoder.  ``adc_steps`` is derived from the K-th largest code: a descending
    ramp starting at the top code reaches it after (n_codes - 1 - code_k) steps.
    """
    n = mac.shape[-1]
    codes_all = ima_convert(mac, cb)
    # The ramp ranks columns by *quantized code* (crossing step), ties broken
    # by the priority encoder in index order — rank on exactly that.
    tie = jnp.arange(n, dtype=jnp.float32) * (0.5 / n)
    vals, idx = jax.lax.top_k(codes_all.astype(jnp.float32) - tie, k)
    codes = jnp.take_along_axis(codes_all, idx, axis=-1)
    mask = _scatter_mask(idx, n, mac.dtype)
    kth_code = codes[..., -1]
    adc_steps = (cb.n_codes - 1 - kth_code).astype(jnp.int32)
    return KWNResult(idx, codes, mask, adc_steps)


def _scatter_mask(idx: jax.Array, n: int, dtype) -> jax.Array:
    """One-hot union over the last axis for batched idx (..., K) -> (..., N)."""
    onehot = jax.nn.one_hot(idx, n, dtype=dtype)  # (..., K, N)
    return jnp.clip(jnp.sum(onehot, axis=-2), 0.0, 1.0)


def kwn_ramp_scan(mac: jax.Array, k: int, cb: RampCodebook) -> KWNResult:
    """Literal descending-ramp emulation (the hardware algorithm).

    Scans codes from high to low; a column 'crosses' at the step where the ramp
    level drops below its MAC value.  Stops (functionally: masks) after K
    crossings.  Equivalent to ``kwn_select`` up to tie handling; kept as the
    semantics oracle + latency source.
    """
    n_codes = cb.n_codes
    codes_all = ima_convert(mac, cb)                       # (..., N)

    def step(carry, level):
        n_found, mask = carry
        crossing = (codes_all >= level) & (mask == 0.0)
        # Priority encoding: admit crossings only while count < k, in index order.
        order = jnp.cumsum(crossing.astype(jnp.int32), axis=-1)
        admit = crossing & ((n_found[..., None] + order) <= k)
        mask = mask + admit.astype(mask.dtype)
        n_found = n_found + jnp.sum(admit.astype(jnp.int32), axis=-1)
        return (n_found, mask), n_found

    levels = jnp.arange(n_codes - 1, -1, -1)
    batch_shape = mac.shape[:-1]
    init = (jnp.zeros(batch_shape, jnp.int32), jnp.zeros_like(mac))
    (n_found, mask), counts = jax.lax.scan(step, init, levels)

    # Steps until K found (early stop): first scan index with count >= k.
    reached = counts >= jnp.minimum(k, mac.shape[-1])      # (steps, ...)
    adc_steps = jnp.argmax(reached, axis=0).astype(jnp.int32)
    adc_steps = jnp.where(jnp.any(reached, axis=0), adc_steps, n_codes - 1)

    # Extract winner indices/codes in ramp order (descending code, then index).
    score = jnp.where(mask > 0, codes_all, -1)
    tie = jnp.arange(mac.shape[-1], dtype=jnp.float32) * 1e-6
    _, idx = jax.lax.top_k(score.astype(jnp.float32) - tie, k)
    codes = jnp.take_along_axis(codes_all, idx, axis=-1)
    return KWNResult(idx, codes, mask, adc_steps)


# ---------------------------------------------------------------------------
# Latency accounting (paper: ADC −30 %, LIF 10×)
# ---------------------------------------------------------------------------

def adc_latency_cycles(adc_steps: jax.Array, n_codes: int) -> dict:
    """Early-stop ADC latency vs full ramp.

    A full linear conversion sweeps all n_codes-1 steps; with early stop the
    ramp halts at the K-th crossing.  Returns mean cycles and the saving
    fraction (the paper measures ~30 % on DVS Gesture)."""
    full = float(n_codes - 1)
    mean_steps = float(jnp.mean(adc_steps.astype(jnp.float32)))
    return {
        "full_cycles": full,
        "early_stop_cycles": mean_steps,
        "saving_frac": 1.0 - mean_steps / full,
    }


def lif_latency_updates(k: int, n_neurons: int = 128) -> dict:
    """Serial digital LIF: n updates full vs K with KWN (10x at K=12, N=128)."""
    return {
        "full_updates": float(n_neurons),
        "kwn_updates": float(k),
        "speedup": n_neurons / float(k),
    }
