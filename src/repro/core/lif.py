"""Digital LIF neuron with SNL + PRBS noise (paper C5, Eq. 1, Fig. 5).

Hardware: a 3-stage pipeline (leak -> update -> compare) serially updates the
V_mem register file (12-bit).  In KWN mode only the K winner columns receive a
nonzero Z_j, so only K of 128 updates run (10x latency saving).  A Sensitive
Neuron List (SNL) tracks neurons with V_th2 < V_mem < V_th1; PRBS noise n(t)
lets them fire probabilistically, recovering spikes that top-K truncation would
mistime (+0.5-0.6 % accuracy, Fig. 5b).

Implemented as a pure functional state update usable inside lax.scan over time
steps, with a surrogate-gradient spike for training.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import prbs


class LIFParams(NamedTuple):
    beta: float = 0.9          # leak factor
    v_th1: float = 1.0         # firing threshold
    v_th2: float = 0.6         # SNL lower threshold (V_th2 < V_mem < V_th1)
    v_reset: float = 0.0
    noise_amp: float = 0.05    # PRBS injection amplitude (V_mem LSBs)
    vmem_bits: int = 12        # register width; V_mem is clipped to this range
    surrogate_beta: float = 4.0


class LIFState(NamedTuple):
    v_mem: jax.Array           # (..., N)
    prbs_state: jax.Array      # LFSR state (uint32 scalar)


def lif_init(shape, seed: int = 1) -> LIFState:
    return LIFState(jnp.zeros(shape, jnp.float32), prbs.lfsr_init(seed))


@jax.custom_vjp
def spike_fn(v: jax.Array, v_th: jax.Array) -> jax.Array:
    return (v >= v_th).astype(jnp.float32)


def _spike_fwd(v, v_th):
    return spike_fn(v, v_th), (v, v_th)


def _spike_bwd(res, g):
    v, v_th = res
    # Fast-sigmoid surrogate (SuperSpike).
    beta = 4.0
    x = beta * (v - v_th)
    sg = 1.0 / (1.0 + jnp.abs(x)) ** 2 * beta
    return g * sg, jnp.zeros_like(v_th)


spike_fn.defvjp(_spike_fwd, _spike_bwd)


def vmem_limit(bits: int) -> float:
    """Signed V_mem register full scale in threshold-normalized units
    (``bits`` wide with 8 fractional bits).  The fused kernels clip to this
    same value — single source so the bitwise-parity contract can't drift."""
    return float(2 ** (bits - 1)) / 256.0


def _vmem_clip(v: jax.Array, bits: int) -> jax.Array:
    """12-bit signed register saturation (in threshold-normalized units)."""
    lim = vmem_limit(bits)
    return jnp.clip(v, -lim, lim)


def lif_step(state: LIFState, drive: jax.Array, p: LIFParams,
             update_mask: jax.Array | None = None,
             use_snl: bool = True) -> tuple[LIFState, jax.Array]:
    """One time step of Eq. (1).

    drive:        (..., N) quantized MAC input (Z_j mapped back through LUT);
                  zero for non-winners in KWN mode.
    update_mask:  (..., N) 1 for winners.  None -> dense update (NLD mode).
    use_snl:      enable the sensitive-neuron probabilistic firing path.

    Returns (new_state, spikes).
    """
    v = state.v_mem
    if update_mask is None:
        v_new = p.beta * v + drive
        noise_state = state.prbs_state
        noise = jnp.zeros_like(v)
    else:
        # Winners: leak + integrate.  Non-winners: hold (Eq. 1 bottom branch).
        v_upd = p.beta * v + drive
        v_new = jnp.where(update_mask > 0, v_upd, v)
        if use_snl:
            noise_state, noise = prbs.prbs_noise(state.prbs_state, v.shape, p.noise_amp)
        else:
            noise_state, noise = state.prbs_state, jnp.zeros_like(v)

    if update_mask is not None and use_snl:
        # SNL: neurons with v_th2 < V < v_th1 get the PRBS kick (even if they
        # were not winners this step — that is the point of the list).
        snl = (v_new > p.v_th2) & (v_new < p.v_th1)
        v_new = jnp.where(snl, v_new + noise, v_new)

    v_new = _vmem_clip(v_new, p.vmem_bits)
    s = spike_fn(v_new, jnp.asarray(p.v_th1, v_new.dtype))
    v_out = jnp.where(s > 0, p.v_reset, v_new)
    if update_mask is None:
        noise_state = state.prbs_state
    return LIFState(v_out, noise_state), s


def lif_run(state: LIFState, drives: jax.Array, p: LIFParams,
            update_masks: jax.Array | None = None,
            use_snl: bool = True) -> tuple[LIFState, jax.Array]:
    """Scan over T time steps. drives: (T, ..., N)."""
    def step(st, xs):
        if update_masks is None:
            d, m = xs, None
        else:
            d, m = xs
        return lif_step(st, d, p, m, use_snl)

    xs = drives if update_masks is None else (drives, update_masks)
    return jax.lax.scan(step, state, xs)
