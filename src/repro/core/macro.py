"""CIM macro simulator: 256x128 MAC array + 46x128 NL-IMA (paper Fig. 2).

Combines the pieces: ternary inputs hit twin-cell MSB/LSB weight planes, the
analog MAC (with optional variation model) lands on the RBLs, the IMA converts
(linear, NLQ, or NL-activation ramp), and the mode logic (KWN / NLD) produces
the LIF drive.

Layers larger than the physical 256(rows) x 128(cols) array are tiled onto a
*virtual macro grid*: row tiles accumulate in the digital domain (partial-sum
adds after conversion are what the silicon would do across macro instances),
column tiles are independent.  ``MacroGeometry`` tracks how many physical
macro invocations a layer costs — the energy model consumes that.

Two execution paths:

  * **composed** (``cim_mac`` / ``kwn_forward`` / ``nld_forward``): each
    pipeline stage is a separate jnp/kernel call with HBM-visible
    intermediates — use it when you need those intermediates (codebook
    studies, training STE paths);
  * **fused** (``pack_kwn_weights``/``pack_nld_weights`` + ``fused_step`` /
    ``fused_seq``): the whole MAC -> IMA -> mode-head -> LIF step runs
    inside one Pallas kernel (``repro.kernels.fused_macro``), the way the
    silicon never leaves the macro.  Layers wider than one 256x128 macro
    are tiled onto the virtual macro grid *inside* the kernel (column tiles
    + K tiles with digital partial-sum accumulation), and ``fused_seq``
    folds the whole event sequence into one launch with the LIF membrane
    carried in VMEM across time steps.  This is the inference hot path; it
    is bitwise-equal to the composed reference at f32 accumulation, and it
    carries the Fig. 7 IMA error model *inside* the kernel
    (``fused_kernel_noise`` + the counter PRNG in ``core.ctrprng``), so
    noisy silicon evaluation no longer leaves the fused path.
    ``plan_fused_tiles`` exposes the tile planner (padding, grid, VMEM
    footprint, macro-invocation count for the energy model).

    The fused path is also *activity-gated* by default: ``plan_activity``
    computes the per-(step, row-tile, K-tile) occupancy map of an event
    sequence (the host-side pass the KWN controller's row-activity logic
    performs in silicon), and the kernel skips the plane decode + MXU
    contraction for all-zero blocks and bounds the KWN ramp sweep to the
    occupied code range — bit-identical outputs, event-proportional work.
    Raw-MAC telemetry is opt-in on this path (``mac_telemetry``): serving
    never pays the (T, ..., NC) HBM stack.

Stacked-layer API (multi-layer fused networks)
----------------------------------------------
Deep KWN networks chain macro layers *on chip*: ``pack_kwn_stack`` packs a
list of per-layer integer weights into one ``FusedMacroWeights`` list, and
``fused_multi_seq`` runs the whole stack — every layer, every time step —
in a single Pallas launch (``kernels.fused_macro.fused_macro_multi_seq``).
Per-layer weight planes are layer-stationary (const-indexed, staged once
per launch), per-layer LIF membranes are carried in VMEM across the T
axis, and the inter-layer ternary spike tensor is a register value handed
from layer l's KWN head straight into layer l+1's MAC — it never touches
HBM.  Only the *final* layer's spike/mask stacks are materialized.

Because each KWN layer emits exactly K winners of N columns, layer l's
winner set IS layer l+1's activity plan: the stacked kernel computes the
multi-layer occupancy map *in kernel* (``jnp.any`` over each register-
resident K tile of the previous layer's spikes) instead of host-side —
only layer 0, whose events are host-visible, uses the scalar-prefetched
host map.  All-zero tiles skip the plane decode + MXU contraction exactly
like the single-layer gating (bitwise-neutral), and the per-layer
occupied-block counters leave the kernel as telemetry
(``MultiSeqOut.occupancy`` / ``total_blocks`` -> the serving
skipped-block ratio), so depth costs no HBM spike traffic even for the
energy accounting: hidden-layer SOP counts come from the per-step
row-wise ``spike_counts`` reduction, not from spike tensors.

``plan_fused_stack`` exposes the per-layer tile plans (layer 0 follows
the single-layer planner; deeper layers tile in kernel with ragged tails
— no column padding exists past layer 0).  The oracle is the composed
per-layer chain ``kernels.ref.fused_macro_multi_seq_ref`` — layer-major
and step-major schedules compute the same dataflow DAG, so parity is
bitwise, clean and noisy (per-layer counter seeds keep the noise streams
collision-free).  The stacked path is KWN-only; NLD stacks and the
multi-layer surrogate backward are roadmap follow-ups.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ima as ima_lib
from repro.core import kwn as kwn_lib
from repro.core import ternary as ternary_lib

MACRO_ROWS = 256   # MAC array word-lines (inputs)
MACRO_COLS = 128   # columns (neurons)
IMA_ROWS = 46      # ramp array rows


class MacroGeometry(NamedTuple):
    n_in: int
    n_out: int
    row_tiles: int
    col_tiles: int

    @property
    def n_macros(self) -> int:
        return self.row_tiles * self.col_tiles


def geometry(n_in: int, n_out: int) -> MacroGeometry:
    return MacroGeometry(
        n_in, n_out,
        row_tiles=math.ceil(n_in / MACRO_ROWS),
        col_tiles=math.ceil(n_out / MACRO_COLS),
    )


class CIMMacroConfig(NamedTuple):
    code_bits: int = 5                 # IMA resolution (5-bit over 8-bit range w/ NLQ)
    mac_range: float = 64.0            # full-scale analog MAC range (in weight LSBs)
    nlq_gamma: float = 2.0
    ratio_sigma: float = 0.0           # MC current-ratio spread (0 = ideal)
    ima_noise: ima_lib.IMANoiseModel | None = None  # None = ideal conversion


def _codebooks(cfg: CIMMacroConfig):
    lin = ima_lib.linear_codebook(cfg.code_bits, -cfg.mac_range, cfg.mac_range)
    nlq = ima_lib.nlq_codebook(cfg.code_bits, -cfg.mac_range, cfg.mac_range,
                               cfg.nlq_gamma)
    return lin, nlq


def cim_mac(spikes: jax.Array, w_int: jax.Array, cfg: CIMMacroConfig,
            key: jax.Array | None = None) -> jax.Array:
    """Analog ternary MAC: spikes (..., I) x int weights (I, N) in [-3, 3].

    The MSB/LSB twin-cell split and (optional) current-ratio variation are
    applied per column, exactly as the multi-VDD banks realize the weight.
    """
    msb, lsb = ternary_lib.weight_decompose(w_int)
    if cfg.ratio_sigma > 0.0 and key is not None:
        w_eff = ternary_lib.effective_weights(msb, lsb, key, cfg.ratio_sigma)
    else:
        w_eff = ternary_lib.weight_compose(msb, lsb)
    s = ternary_lib.ternary_input_encode(spikes)
    return jnp.einsum("...i,in->...n", s, w_eff)


def kwn_forward(spikes: jax.Array, w_int: jax.Array, k: int,
                cfg: CIMMacroConfig, key: jax.Array | None = None):
    """KWN mode: MAC -> NLQ ramp (descending) -> top-K early stop.

    Returns (drive, mask, result): drive is the LUT-mapped Z_j for winners and
    exactly 0 for the rest (what the LIF receives), result carries indices /
    codes / adc_steps for the latency model.
    """
    _, nlq = _codebooks(cfg)
    mac = cim_mac(spikes, w_int, cfg, key)
    if cfg.ima_noise is not None and key is not None:
        k_noise = jax.random.fold_in(key, 1)
        codes = ima_lib.ima_convert_noisy(mac, nlq, k_noise, cfg.ima_noise)
        mac_eff = ima_lib.ima_reconstruct(codes, nlq)
    else:
        mac_eff = mac
    res = kwn_lib.kwn_select(mac_eff, k, nlq)
    drive = ima_lib.ima_reconstruct(
        ima_lib.ima_convert(mac_eff, nlq), nlq) * res.mask
    return drive, res.mask, res


def nld_forward(spikes: jax.Array, dendrite_params, cfg: CIMMacroConfig,
                activation: str = "quadratic", quantize: bool = True):
    """NLD mode: branch MACs through the NL-activation ramp, soma combine."""
    f = ima_lib.DENDRITE_ACTIVATIONS[activation]
    cb = ima_lib.activation_codebook(cfg.code_bits, f, -cfg.mac_range,
                                     cfg.mac_range)
    from repro.core import dendrite as dendrite_lib
    return dendrite_lib.dendrite_mac(
        dendrite_params, spikes, f=f, nl_cb=cb, quantize=quantize)


# ---------------------------------------------------------------------------
# Fused macro-step path (single Pallas kernel per time step)
# ---------------------------------------------------------------------------

class FusedMacroWeights(NamedTuple):
    """Device-ready operands for the fused macro-step kernel.

    msb/lsb:     (I, NC) int8 twin-cell planes (NC = N for KWN, J*N branch-
                 major for NLD).
    scale:       (NC,) per-column weight quantization scale.
    boundaries:  (n_codes-1,) ramp thresholds.
    levels:      (n_codes,) LUT / activation samples.
    w_dend:      (J, N) soma combine weights, or None in KWN mode.
    mode:        "kwn" | "nld".
    """

    msb: jax.Array
    lsb: jax.Array
    scale: jax.Array
    boundaries: jax.Array
    levels: jax.Array
    w_dend: jax.Array | None
    mode: str


def pack_kwn_weights(w_int: jax.Array, scale: jax.Array,
                     cfg: CIMMacroConfig) -> FusedMacroWeights:
    """KWN-mode packing: int weights in [-3, 3] + per-column scale.

    The NLQ ramp operates in integer MAC units (``cfg.mac_range``); the
    per-column float scale is applied to the winner drive after the LUT
    map-back, exactly like ``kwn_forward`` + the SNN silicon path.
    """
    msb, lsb = ternary_lib.weight_decompose(w_int)
    _, nlq = _codebooks(cfg)
    return FusedMacroWeights(
        msb=ternary_lib.pack_ternary(msb), lsb=ternary_lib.pack_ternary(lsb),
        scale=scale.reshape(-1).astype(jnp.float32),
        boundaries=nlq.boundaries, levels=nlq.levels, w_dend=None, mode="kwn")


def pack_nld_weights(dendrite_params, cfg: CIMMacroConfig,
                     activation: str = "quadratic") -> FusedMacroWeights:
    """NLD-mode packing: branch weights onto the twin-cell grid.

    The fused NLD path stores the branch synapses the way the silicon does —
    as 3-bit twin-cell ternary pairs with a per-(branch, column) scale — so
    branch MACs accumulate in integer units and are rescaled to float units
    just before the NL-activation ramp.  (The composed ``nld_forward`` keeps
    float weights; the fused path is the more silicon-faithful of the two.)
    Column packing is branch-major: column j*N + p is branch j of neuron p.
    """
    w_syn = dendrite_params.w_syn * dendrite_params.mask   # (J, I, N)
    n_branches, n_in, n_out = w_syn.shape
    scale = jnp.maximum(jnp.max(jnp.abs(w_syn), axis=1) / 3.0, 1e-8)  # (J, N)
    w_int = jnp.round(jnp.clip(w_syn / scale[:, None, :], -3, 3))
    msb, lsb = ternary_lib.weight_decompose(w_int)
    # (J, I, NC) -> (I, J*N) branch-major flat columns
    flat = lambda t: jnp.transpose(t, (1, 0, 2)).reshape(n_in,
                                                         n_branches * n_out)
    f = ima_lib.DENDRITE_ACTIVATIONS[activation]
    cb = ima_lib.activation_codebook(cfg.code_bits, f, -cfg.mac_range,
                                     cfg.mac_range)
    return FusedMacroWeights(
        msb=ternary_lib.pack_ternary(flat(msb)),
        lsb=ternary_lib.pack_ternary(flat(lsb)),
        scale=scale.reshape(-1).astype(jnp.float32),
        boundaries=cb.boundaries, levels=cb.levels,
        w_dend=dendrite_params.w_dend, mode="nld")


def plan_fused_tiles(batch: int, fw: FusedMacroWeights, n_out: int,
                     n_steps: int = 1):
    """Tile plan + macro accounting for one fused launch.

    Parameters
    ----------
    batch : flattened batch rows the launch will carry (the leading dims
        of the event tensor collapsed to one axis).
    fw : the packed ``FusedMacroWeights`` — supplies the layer geometry
        (n_in x nc weight planes) and the mode (kwn/nld).
    n_out : per-neuron output width (== nc in KWN mode; nc / n_branches
        in NLD mode).
    n_steps : time steps folded into the kernel grid (1 = single step).

    Returns (plan, geometry): the kernel-facing ``TilePlan`` (block sizes,
    padded shapes, grid, resident VMEM bytes) and the ``MacroGeometry`` the
    energy model consumes (physical macro invocations for the layer).

    Delegates to ``kernels.fused_macro.plan_tiles`` with no overrides, so
    tuned plans from the persistent cache (``docs/TILE_PLANS.md``) apply
    transparently; with no cache entry this is the PR 4 heuristic.  Every
    caller that pairs a plan with a separately built activity map must
    plan through here (or through ``plan_activity``, which does) so both
    sides resolve the same cache entry.
    """
    from repro.kernels import fused_macro as fused_kernel
    n_in, nc = fw.msb.shape
    n_branches = nc // n_out if fw.mode == "nld" else 1
    plan = fused_kernel.plan_tiles(batch, n_in, nc, n_out, n_steps,
                                   mode=fw.mode, n_branches=n_branches)
    return plan, geometry(n_in, nc)


def plan_activity(spikes: jax.Array, fw: FusedMacroWeights,
                  n_out: int) -> jax.Array:
    """Occupancy map for a time-major event sequence: the activity plan.

    Parameters
    ----------
    spikes : (T, ..., I) event tensor in {-1, 0, +1}.
    fw : the packed ``FusedMacroWeights`` for the layer the events drive.
    n_out : per-neuron output width (as in ``plan_fused_tiles``).

    Returns the (T, row-tiles, K-tiles) int32 map (1 = the block holds at
    least one event) matching the tile plan ``plan_fused_tiles`` would
    pick for this launch — the same map ``fused_seq`` computes internally
    when none is passed.  Built once per sequence; ``1 - map.mean()`` is
    the skipped-block ratio the serving telemetry reports next to the KWN
    early-stop statistics.

    The map's row-tile/K-tile granularity IS the plan's (bm, bk): both
    sides plan through ``plan_tiles`` with identical arguments (and no
    density refinement), so a tuned cache entry (``docs/TILE_PLANS.md``)
    moves the map and the kernel grid together.  Handing this map to a
    launch planned with *different* block overrides is a shape error by
    construction — pass no overrides, or none of the map.
    """
    from repro.kernels import ops as kernel_ops
    s = ternary_lib.ternary_input_encode(spikes)
    t = s.shape[0]
    xm = s.reshape(t, -1, s.shape[-1])
    plan, _ = plan_fused_tiles(xm.shape[1], fw, n_out, n_steps=t)
    xm = jnp.pad(xm, ((0, 0), (0, plan.m_pad - xm.shape[1]),
                      (0, plan.k_pad - xm.shape[-1])))
    return kernel_ops.fused_activity_map(xm, plan)


def fused_kernel_noise(fw: FusedMacroWeights,
                       cfg: CIMMacroConfig) -> "ima_lib.IMAKernelNoise | None":
    """The kernel-consumable Fig. 7 noise struct for a packed weight set.

    Binds ``cfg.ima_noise`` to the full-scale range of the ramp the packed
    weights actually sweep (integer MAC units in KWN mode, float units in
    NLD mode — both are ``±cfg.mac_range`` by construction of the packers).
    Returns None when the config is ideal, so callers can pass the result
    straight to ``fused_step``/``fused_seq``.
    """
    if cfg.ima_noise is None:
        return None
    cb = ima_lib.RampCodebook(fw.levels, fw.boundaries,
                              -cfg.mac_range, cfg.mac_range)
    return ima_lib.kernel_noise_params(cfg.ima_noise, cb)


def fused_step(spikes: jax.Array, fw: FusedMacroWeights, v: jax.Array,
               noise: jax.Array | None = None, *, k: int = 12,
               drive_gain: float = 1.0, beta: float = 0.9,
               v_th1: float = 1.0, v_th2: float = 0.6,
               v_reset: float = 0.0, v_lim: float = 8.0,
               use_snl: bool = True, ima_noise=None, snl_amp: float = 0.0,
               gate: bool = True, mac_telemetry: bool = True,
               seed=0, step_offset=0):
    """One fused macro time step: spikes (..., I), v/noise (..., N).

    ``ima_noise`` (``ima.IMAKernelNoise``, see ``fused_kernel_noise``)
    enables the in-kernel Fig. 7 conversion-error model; with
    ``noise=None`` the SNL stream is generated in-kernel too (counter PRNG
    at ``snl_amp``), keyed on ``(seed, step_offset)``.  ``gate`` /
    ``mac_telemetry`` select activity-gated execution (default, output-
    invariant) and the raw-MAC HBM stack (mac is None when off).
    Returns (v_out, spikes_out, mask, adc_steps, mac) — the LIF state update,
    the KWN winner mask (ones in NLD mode), the per-row early-stop ADC step
    count, and the raw integer-unit MAC for telemetry.
    """
    from repro.kernels import ops as kernel_ops
    s = ternary_lib.ternary_input_encode(spikes)
    mac, v_out, spk, mask, steps = kernel_ops.fused_macro_step(
        s, fw.msb, fw.lsb, fw.boundaries, fw.levels, fw.scale, v, noise,
        fw.w_dend, mode=fw.mode, k=k, drive_gain=drive_gain, beta=beta,
        v_th1=v_th1, v_th2=v_th2, v_reset=v_reset, v_lim=v_lim,
        use_snl=use_snl, ima_noise=ima_noise, snl_amp=snl_amp, gate=gate,
        mac_telemetry=mac_telemetry, seed=seed, step_offset=step_offset)
    return v_out, spk, mask, steps, mac


def stream_row_ctl(seeds: jax.Array, step_offsets: jax.Array,
                   row_ids: jax.Array | None = None) -> jax.Array:
    """Per-slot noise-stream control lane for resumable serving.

    Builds the ``(S, 3) = [seed, step_offset, row_id]`` int32 tensor the
    fused kernel's ``row_ctl`` path consumes: slot ``s`` replays the
    counter-PRNG stream of an independent batch-1 run keyed on its own
    request ``seeds[s]``, positioned at absolute stream step
    ``step_offsets[s]``.  ``row_ids`` defaults to all-zero — every slot
    claims batch row 0 of its virtual batch-1 run, which is precisely what
    makes slot state *relocatable*: a checkpointed slot can be restored
    into ANY free slot (``snn.silicon_stream_restore``) and the replayed
    stream is unchanged, because nothing in the noise keying ever sees the
    physical slot index.
    """
    seeds = jnp.asarray(seeds, jnp.int32)
    rows = (jnp.zeros_like(seeds) if row_ids is None
            else jnp.asarray(row_ids, jnp.int32))
    return jnp.stack(
        [seeds, jnp.asarray(step_offsets, jnp.int32), rows], axis=-1)


def fused_seq(spikes: jax.Array, fw: FusedMacroWeights, v: jax.Array,
              noise: jax.Array | None = None, *, k: int = 12,
              drive_gain: float = 1.0, beta: float = 0.9,
              v_th1: float = 1.0, v_th2: float = 0.6,
              v_reset: float = 0.0, v_lim: float = 8.0,
              use_snl: bool = True, ima_noise=None, snl_amp: float = 0.0,
              gate: bool = True, activity: jax.Array | None = None,
              mac_telemetry: bool = True, seed=0, step_offset=0,
              row_ctl: jax.Array | None = None):
    """A whole fused event sequence: spikes (T, ..., I), v (..., N),
    noise (T, ..., N) — or None for the in-kernel counter noise streams
    (see ``fused_step``; this is the noisy-silicon serving path, with no
    pre-drawn noise tensor and no composed-path fallback).

    ``row_ctl`` ((..., 3) int32, batch lead dims) carries per-row
    ``[seed, step_offset, row_id]`` noise-stream control for the
    continuous-batching engine — each slot replays the counter stream of
    an independent batch-1 run (see ``kernels.ops.fused_macro_seq``).

    One kernel launch covers all T time steps (time-major grid axis, LIF
    membrane carried in VMEM) and any virtual-macro tiling the layer needs.
    ``gate`` selects activity-gated execution (default; pass the
    ``plan_activity`` map as ``activity`` to build the plan once per
    sequence and reuse it for telemetry); ``mac_telemetry=False`` skips
    the raw-MAC HBM stack (mac is None).
    Returns (v_out (..., N), spikes_out (T, ..., N), mask (T, ..., N),
    adc_steps (T, ...), mac (T, ..., NC) or None).
    """
    from repro.kernels import ops as kernel_ops
    s = ternary_lib.ternary_input_encode(spikes)
    mac, v_out, spk, mask, steps = kernel_ops.fused_macro_seq(
        s, fw.msb, fw.lsb, fw.boundaries, fw.levels, fw.scale, v, noise,
        fw.w_dend, mode=fw.mode, k=k, drive_gain=drive_gain, beta=beta,
        v_th1=v_th1, v_th2=v_th2, v_reset=v_reset, v_lim=v_lim,
        use_snl=use_snl, ima_noise=ima_noise, snl_amp=snl_amp, gate=gate,
        activity=activity, mac_telemetry=mac_telemetry, seed=seed,
        step_offset=step_offset, row_ctl=row_ctl)
    return v_out, spk, mask, steps, mac


def pack_kwn_stack(w_ints, scales, cfg: CIMMacroConfig):
    """Pack a KWN layer stack: per-layer int weights -> fused operands.

    ``w_ints``/``scales`` are parallel per-layer lists ((I_l, N_l) integer
    weights in [-3, 3] with their per-column scales; I_l must equal
    N_{l-1} for l > 0 — the layers chain).  All layers share the macro
    config (one NLQ ramp codebook); only widths differ.  Returns the
    ``FusedMacroWeights`` list ``fused_multi_seq`` consumes.
    """
    stack = [pack_kwn_weights(w, s, cfg) for w, s in zip(w_ints, scales)]
    for prev, nxt in zip(stack, stack[1:]):
        assert nxt.msb.shape[0] == prev.msb.shape[1], \
            (nxt.msb.shape, prev.msb.shape)
    return stack


def plan_fused_stack(batch: int, stack, n_steps: int = 1):
    """Per-layer (TilePlan, MacroGeometry) for a stacked fused launch.

    Parameters
    ----------
    batch : flattened batch rows (shared by every layer of the stack).
    stack : a ``pack_kwn_stack`` result — per-layer packed weights whose
        widths chain (layer l's n_in == layer l-1's n_out).
    n_steps : time steps folded into the one stacked launch.

    Returns a list of ``(TilePlan, MacroGeometry)`` pairs, one per layer,
    via ``plan_fused_tiles`` (so tuned cache entries apply per layer —
    see ``docs/TILE_PLANS.md``).  Layer 0's plan is authoritative for the
    launch (row tiling + the host activity-map granularity); deeper
    layers' plans describe the in-kernel MAC tiling and the per-layer
    macro-invocation count the energy model charges.  Column padding in
    deep plans is advisory only — the stacked kernel keeps inter-layer
    widths exact (spikes never leave registers).
    """
    return [plan_fused_tiles(batch, fw, fw.msb.shape[1], n_steps)
            for fw in stack]


def fused_multi_seq(spikes: jax.Array, stack, vs, noises=None, *, ks,
                    drive_gain: float = 1.0, beta: float = 0.9,
                    v_th1: float = 1.0, v_th2: float = 0.6,
                    v_reset: float = 0.0, v_lim: float = 8.0,
                    use_snl: bool = True, ima_noise=None,
                    snl_amp: float = 0.0, gate: bool = True,
                    tile_shapes=None, seeds=None, step_offset=0):
    """A whole event sequence through L stacked KWN macro layers, fused.

    spikes (T, ..., I), stack a ``pack_kwn_stack`` result, vs/noises
    per-layer membranes / pre-drawn SNL tensors (noises=None selects the
    in-kernel counter streams), ks the per-layer winner counts, seeds the
    per-layer counter seeds (keep them distinct — the oracle chain uses
    the same ones).  One Pallas launch covers every layer and every time
    step; the inter-layer spike tensors never reach HBM (see the module
    docstring).  Returns ``kernels.ops.MultiSeqOut``.
    """
    from repro.kernels import ops as kernel_ops
    for fw in stack:
        assert fw.mode == "kwn", "the stacked fused path is KWN-only"
    s = ternary_lib.ternary_input_encode(spikes)
    return kernel_ops.fused_macro_multi_seq(
        s, [(fw.msb, fw.lsb, fw.boundaries, fw.levels, fw.scale)
            for fw in stack],
        vs, noises, ks=ks, drive_gain=drive_gain, beta=beta, v_th1=v_th1,
        v_th2=v_th2, v_reset=v_reset, v_lim=v_lim, use_snl=use_snl,
        ima_noise=ima_noise, snl_amp=snl_amp, gate=gate,
        tile_shapes=tile_shapes, seeds=seeds, step_offset=step_offset)


def fused_seq_vjp(spikes: jax.Array, w: jax.Array, scale: jax.Array,
                  cfg: CIMMacroConfig, v: jax.Array, *, k: int = 12,
                  drive_gain: float = 1.0, beta: float = 0.9,
                  v_th1: float = 1.0, v_th2: float = 0.6,
                  v_reset: float = 0.0, v_lim: float = 8.0,
                  use_snl: bool = True, noise: jax.Array | None = None,
                  snl_amp: float = 0.0, kwn_relax: float = 0.0,
                  surrogate_beta: float = 4.0, remat: bool = False,
                  gate: bool = True, seed=0.0):
    """Differentiable fused KWN sequence: the silicon-in-the-loop training
    forward, with the surrogate backward running as a Pallas kernel.

    spikes: (T, ..., I) event stream in {-1, 0, +1} (no gradient).
    w:      (I, N) f32 weight in *integer MAC units* — gradients flow to it
            straight through the twin-cell rounding; callers apply their
            own ternary-STE clip at the model layer (``repro.train.silicon``
            does, mirroring ``ternary.quantize_weights_ste``).
    scale:  (N,) per-column weight scale (stop-gradient semantics — the
            tangent treats it as a constant, like the software QAT path).
    cfg:    the macro config; ``cfg.ima_noise`` turns on the in-kernel
            Fig. 7 error model (noise-aware QAT) keyed on ``seed`` — pass a
            fresh ``seed`` per optimization step so every step sees a fresh
            silicon-noise draw.
    noise:  (T, ..., N) pre-drawn SNL noise for the *clean* path (PRBS
            parity with serving); None selects the in-kernel counter SNL
            stream at ``snl_amp`` on the noisy path (or no noise at all
            when ``use_snl`` is off).
    kwn_relax / surrogate_beta / remat: surrogate-backward knobs — loser
            gradient leak through the hard winner gate, SuperSpike
            sharpness, and the MAC residual-vs-recompute memory policy
            (see ``kernels.fused_macro_grad``).
    seed:   f32 scalar (traced) keying both counter noise streams.

    Returns (spikes_out (T, ..., N), v_out (..., N)), both differentiable.
    """
    from repro.kernels import ops as kernel_ops
    _, nlq = _codebooks(cfg)
    ima_kn = None
    if cfg.ima_noise is not None:
        ima_kn = ima_lib.kernel_noise_params(cfg.ima_noise, nlq)
    spec = kernel_ops.SeqVJPSpec(
        k=k, drive_gain=drive_gain, beta=beta, v_th1=v_th1, v_th2=v_th2,
        v_reset=v_reset, v_lim=v_lim, use_snl=use_snl, ima_noise=ima_kn,
        snl_amp=snl_amp, kwn_relax=kwn_relax, surrogate_beta=surrogate_beta,
        ste_lo=float(-cfg.mac_range - 0.5), ste_hi=float(cfg.mac_range + 0.5),
        remat=remat, gate=gate, has_noise=noise is not None)
    s = ternary_lib.ternary_input_encode(spikes)
    noise_arr = jnp.zeros((1,), jnp.float32) if noise is None else noise
    return kernel_ops.fused_macro_seq_vjp(
        spec, w, s, nlq.boundaries, nlq.levels,
        scale.reshape(-1).astype(jnp.float32), v, noise_arr,
        jnp.asarray(seed, jnp.float32))


def tiled_cim_mac(spikes: jax.Array, w_int: jax.Array,
                  cfg: CIMMacroConfig) -> tuple[jax.Array, MacroGeometry]:
    """Large-layer path: tile (I, N) onto the 256x128 macro grid.

    Row-tile partial sums are converted per tile then digitally accumulated —
    this loses precision exactly like the silicon does, so we model it: each
    row tile's analog MAC is IMA-quantized before the add.
    """
    n_in, n_out = w_int.shape
    geo = geometry(n_in, n_out)
    lin, _ = _codebooks(cfg)
    pad_i = geo.row_tiles * MACRO_ROWS - n_in
    pad_n = geo.col_tiles * MACRO_COLS - n_out
    s = jnp.pad(spikes, [(0, 0)] * (spikes.ndim - 1) + [(0, pad_i)])
    w = jnp.pad(w_int, [(0, pad_i), (0, pad_n)])
    s_t = s.reshape(s.shape[:-1] + (geo.row_tiles, MACRO_ROWS))
    w_t = w.reshape(geo.row_tiles, MACRO_ROWS, geo.col_tiles * MACRO_COLS)
    msb, lsb = ternary_lib.weight_decompose(w_t)
    w_eff = ternary_lib.weight_compose(msb, lsb)
    partial = jnp.einsum("...tr,trn->...tn", s_t, w_eff)
    partial_q = ima_lib.ima_quantize(partial, lin)
    out = jnp.sum(partial_q, axis=-2)
    return out[..., :n_out], geo
