"""Pseudo-Random Binary Sequence (PRBS) noise generator (paper C5, Fig. 5a).

The silicon uses an LFSR-based PRBS to produce the noise term n(t) in Eq. (1),
letting sensitive neurons fire probabilistically.  We implement a faithful
Fibonacci LFSR (PRBS-15: x^15 + x^14 + 1) in pure JAX (jit/scan friendly) plus
a convenience that maps the bitstream to symmetric integer noise amplitudes.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

PRBS15_TAPS = (15, 14)


def lfsr_init(seed: int, width: int = 15) -> jax.Array:
    """Non-zero LFSR state from an integer seed."""
    state = (seed % ((1 << width) - 1)) + 1
    return jnp.uint32(state)


def lfsr_step(state: jax.Array, width: int = 15,
              taps: Tuple[int, int] = PRBS15_TAPS) -> Tuple[jax.Array, jax.Array]:
    """One LFSR step; returns (new_state, output_bit)."""
    b1 = (state >> (taps[0] - 1)) & 1
    b2 = (state >> (taps[1] - 1)) & 1
    fb = b1 ^ b2
    new = ((state << 1) | fb) & jnp.uint32((1 << width) - 1)
    return new, fb


def prbs_bits(state: jax.Array, n: int, width: int = 15) -> Tuple[jax.Array, jax.Array]:
    """Generate n bits; returns (final_state, bits[n])."""
    def step(s, _):
        s, b = lfsr_step(s, width)
        return s, b
    final, bits = jax.lax.scan(step, state, None, length=n)
    return final, bits.astype(jnp.int32)


def prbs_noise(state: jax.Array, shape: Tuple[int, ...], amplitude: float,
               width: int = 15) -> Tuple[jax.Array, jax.Array]:
    """Symmetric two-level noise n(t) in {-amplitude, +amplitude}.

    This matches the hardware, where the PRBS bit selects the sign of a fixed
    injected charge on V_mem.
    """
    n = 1
    for d in shape:
        n *= int(d)
    state, bits = prbs_bits(state, n, width)
    noise = (2.0 * bits.astype(jnp.float32) - 1.0) * amplitude
    return state, noise.reshape(shape)
