"""Ternary quantization + twin-cell multi-bit weight composition (paper C1).

NeuDW-CIM stores a ternary value in a *twin 9T bit-cell* (two 6T cells encode
{-1, 0, +1}); a pair of +/- RWL pulses encodes a ternary input.  A 3-bit signed
weight is composed from two ternary cells living in separate multi-VDD banks:

    W = 2 * W_msb + W_lsb          W_msb, W_lsb in {-1, 0, +1}

because the MSB bank discharges with I_MSB = 2 * I_LSB (Fig. 3b/3c).  The
achievable signed range is therefore [-3, 3] (7 levels ~ "3-bit" in the paper's
counting).  Generalization to B banks with ratio r=3-ish is possible; the
silicon uses 2 banks / ratio 2, and so do we by default.

Everything here is differentiable-through via straight-through estimators (STE)
so CIM-mode layers can be trained with QAT.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# Number of multi-VDD banks and the MSB/LSB current ratio of the silicon.
N_BANKS = 2
CURRENT_RATIO = 2.0  # I_MSB / I_LSB
TERNARY_LEVELS = jnp.array([-1.0, 0.0, 1.0])


def ternary_quantize(x: jax.Array, scale: jax.Array | float = 1.0,
                     threshold: float = 0.5) -> jax.Array:
    """Hard ternarization: sign(x/scale) where |x/scale| > threshold, else 0."""
    xs = x / scale
    return jnp.where(jnp.abs(xs) > threshold, jnp.sign(xs), 0.0)


@jax.custom_vjp
def ternary_ste(x: jax.Array, scale: jax.Array) -> jax.Array:
    return ternary_quantize(x, scale)


def _ternary_fwd(x, scale):
    return ternary_ste(x, scale), (x, scale)


def _ternary_bwd(res, g):
    x, scale = res
    # Clipped STE: pass gradient only inside the representable range.
    mask = (jnp.abs(x / scale) <= 1.5).astype(g.dtype)
    return g * mask, jnp.zeros_like(scale)


ternary_ste.defvjp(_ternary_fwd, _ternary_bwd)


def ternary_input_encode(spikes: jax.Array) -> jax.Array:
    """Encode event-camera ON/OFF streams as ternary inputs.

    DVS pixels emit +1 (ON), -1 (OFF) or 0 events; the paper's +/- RWL pair
    carries exactly this.  Input must already be in {-1, 0, 1}; we validate by
    clipping (robust to soft inputs from the data pipeline).
    """
    return jnp.clip(jnp.round(spikes), -1, 1)


# ---------------------------------------------------------------------------
# Multi-bit weights from twin ternary cells (multi-VDD composition)
# ---------------------------------------------------------------------------

def weight_decompose(w_int: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Split an int weight in [-3, 3] into (msb, lsb) ternary planes.

    Balanced-ternary decomposition with digit set {-1,0,1}:
        w = 2*msb + lsb
    is unique for w in [-3, 3] when we pick lsb = w - 2*round(w/2) and
    msb = round(w/2) (both land in {-1,0,1}).
    """
    w = jnp.round(jnp.clip(w_int, -3, 3))
    msb = jnp.clip(jnp.round(w / 2.0), -1.0, 1.0)
    lsb = w - 2.0 * msb
    return msb, lsb


def weight_compose(msb: jax.Array, lsb: jax.Array,
                   ratio: float = CURRENT_RATIO) -> jax.Array:
    """Compose the effective weight the analog array realizes.

    With ideal VDDs the ratio is exactly 2; with supply droop / mismatch it
    deviates (Fig. 3c shows the MC spread).  ``ratio`` may be a per-column
    array to model that.
    """
    return ratio * msb + lsb


def quantize_weights_3bit(w: jax.Array, per_channel: bool = True) -> Tuple[jax.Array, jax.Array]:
    """QAT-style symmetric quantization of float weights to the [-3,3] grid.

    Returns (w_int, scale) with w ~= w_int * scale.  ``per_channel`` scales
    along the last axis (output channels = macro columns).
    """
    axis = tuple(range(w.ndim - 1)) if per_channel else None
    scale = jnp.max(jnp.abs(w), axis=axis, keepdims=True) / 3.0
    scale = jnp.maximum(scale, 1e-8)
    w_int = jnp.round(jnp.clip(w / scale, -3, 3))
    return w_int, scale


@jax.custom_vjp
def quantize_weights_ste(w: jax.Array) -> jax.Array:
    """Fake-quantize weights to the twin-cell grid, straight-through bwd."""
    w_int, scale = quantize_weights_3bit(w)
    return w_int * scale


def _qw_fwd(w):
    return quantize_weights_ste(w), (w,)


def _qw_bwd(res, g):
    (w,) = res
    axis = tuple(range(w.ndim - 1))
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=axis, keepdims=True) / 3.0, 1e-8)
    mask = (jnp.abs(w / scale) <= 3.5).astype(g.dtype)
    return (g * mask,)


quantize_weights_ste.defvjp(_qw_fwd, _qw_bwd)


# ---------------------------------------------------------------------------
# Analog variation model (Fig. 3c Monte-Carlo)
# ---------------------------------------------------------------------------

def sample_current_ratio(key: jax.Array, shape: Tuple[int, ...] = (),
                         sigma: float = 0.02,
                         nominal: float = CURRENT_RATIO) -> jax.Array:
    """MC sample of I_MSB/I_LSB.

    The paper reports "minimal fluctuation" of the ratio across MC runs; we
    model it as a ~2 % lognormal spread (a conservative read of Fig. 3c) so
    accuracy experiments can include it.
    """
    return nominal * jnp.exp(sigma * jax.random.normal(key, shape))


def effective_weights(msb: jax.Array, lsb: jax.Array, key: jax.Array | None = None,
                      sigma: float = 0.0) -> jax.Array:
    """Weights as realized by the macro, optionally with per-column ratio MC."""
    if key is None or sigma == 0.0:
        return weight_compose(msb, lsb)
    ratio = sample_current_ratio(key, msb.shape[-1:], sigma=sigma)
    return weight_compose(msb, lsb, ratio=ratio)


# ---------------------------------------------------------------------------
# Plane packing (used by the Pallas kernel's host-side prep)
# ---------------------------------------------------------------------------

def pack_ternary(x: jax.Array) -> jax.Array:
    """Map ternary {-1,0,1} -> int8 for compact storage/transport."""
    return jnp.round(x).astype(jnp.int8)


def unpack_ternary(x: jax.Array, dtype=jnp.float32) -> jax.Array:
    return x.astype(dtype)


def weight_implementation_cost(bits: int, scheme: str) -> Tuple[float, float]:
    """(latency_cycles, bitcell_count) per weight for Fig. 3d's comparison.

    - "twin" (ours): B = bits-1 ratio-2 ternary banks give a +-(2^B - 1) range
      (B=2 is the 3-bit silicon).  Model: one ramp step per bank ratio setting
      -> latency B, and B twin cells.
    - "pwm": single differential cell, pulse-width 2^(bits-1) steps ->
      latency 2^(bits-1), cells 1.
    - "mcl": 2^bits - 1 unary cells -> latency 1, cells 2^bits - 1.

    At 5 bits this reproduces the paper's Fig. 3d claims: latency 16/4 = 4x vs
    PWM and cells 31/4 = 7.75 ~ 7.8x vs MCL.  (The dual-rail silicon amortizes
    both banks of the 3-bit case into a single access; the projection model
    above is what matches the published 5-bit ratios.)
    """
    if scheme == "twin":
        n_banks = max(1, bits - 1)
        return float(n_banks), float(n_banks)
    if scheme == "pwm":
        return float(2 ** (bits - 1)), 1.0
    if scheme == "mcl":
        return 1.0, float(2 ** bits - 1)
    raise ValueError(f"unknown scheme {scheme!r}")
