"""Synthetic event-stream datasets (offline stand-ins for N-MNIST, DVS
Gesture, Quiroga — see DESIGN.md data caveat).

Each dataset produces ternary event tensors (T, N_in) in {-1, 0, +1} (OFF/
none/ON), exactly the +/- RWL input format of the macro, with class-dependent
spatio-temporal structure:

* nmnist-like: static class prototypes (digit-ish blob patterns on a 16x16x2
  retina) sampled as Poisson ON/OFF events with jitter -> 10 classes.
* dvs-gesture-like: *moving* prototypes (drifting blobs with class-specific
  velocity/rotation) -> temporal structure matters, 11 classes.
* quiroga-like: 1-D extracellular waveform with embedded spike templates of
  3 shapes + noise -> detection/sorting, ternary delta-encoded, 3 classes.

Spike rates are calibrated to the energy model's assumptions
(core/energy.py SPIKE_RATES) so pJ/SOP numbers and accuracy come from the
same streams.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class EventDataConfig:
    name: str
    n_in: int
    n_steps: int
    n_classes: int
    rate: float          # mean |event| probability per input per step
    seed: int = 0
    alpha: float = 0.45  # class-signal fraction (rest = shared background)
    noise_frac: float = 0.6  # random-event rate as a fraction of ``rate``


NMNIST = EventDataConfig("nmnist", 512, 20, 10, 0.029, alpha=0.55)
DVS_GESTURE = EventDataConfig("dvs_gesture", 512, 30, 11, 0.0096 * 3,
                              alpha=0.5, noise_frac=0.6)
QUIROGA = EventDataConfig("quiroga", 256, 24, 3, 0.0176 * 2, alpha=0.5)


def _prototypes(cfg: EventDataConfig) -> np.ndarray:
    """Class prototype intensity maps in [-1, 1], (classes, T, N)."""
    rng = np.random.default_rng(cfg.seed + 1234)
    protos = np.zeros((cfg.n_classes, cfg.n_steps, cfg.n_in), np.float32)
    side = int(np.sqrt(cfg.n_in // 2)) if cfg.name != "quiroga" else 0
    for c in range(cfg.n_classes):
        if cfg.name == "quiroga":
            # spike template: biphasic waveform at class-specific width/pos
            t0 = rng.integers(2, cfg.n_steps - 8)
            width = 2 + c
            wave = np.zeros((cfg.n_steps, cfg.n_in), np.float32)
            chans = rng.choice(cfg.n_in, cfg.n_in // 4, replace=False)
            for dt in range(width):
                wave[t0 + dt, chans] = np.sin(np.pi * (dt + 1) / (width + 1))
                wave[t0 + width + dt, chans] = -0.6 * np.sin(
                    np.pi * (dt + 1) / (width + 1))
            protos[c] = wave
        else:
            # blob(s) on a 2-channel retina; gestures move, digits are static
            n_blobs = 2 + (c % 3)
            xy = rng.uniform(2, side - 2, (n_blobs, 2))
            vel = (rng.uniform(-0.4, 0.4, (n_blobs, 2))
                   if cfg.name == "dvs_gesture" else np.zeros((n_blobs, 2)))
            vel += (c % 4 - 1.5) * 0.1 * (cfg.name == "dvs_gesture")
            for t in range(cfg.n_steps):
                grid = np.zeros((side, side, 2), np.float32)
                for b in range(n_blobs):
                    cx, cy = xy[b] + vel[b] * t
                    ys, xs = np.mgrid[0:side, 0:side]
                    blob = np.exp(-(((xs - cx) ** 2 + (ys - cy) ** 2)
                                    / (2.0 + 0.5 * b)))
                    grid[:, :, b % 2] += blob
                # channel 1 carries OFF polarity
                grid[:, :, 1] *= -1.0
                protos[c, t, : side * side * 2] = grid.reshape(-1)[: cfg.n_in]
    # normalize to +-1 peak
    peak = np.abs(protos).max(axis=(1, 2), keepdims=True) + 1e-6
    protos = protos / peak
    # difficulty: blend in a shared background pattern (classes overlap)
    bg = protos.mean(axis=0, keepdims=True)
    bg = bg / (np.abs(bg).max() + 1e-6)
    return cfg.alpha * protos + (1 - cfg.alpha) * bg


class EventDataset:
    def __init__(self, cfg: EventDataConfig):
        self.cfg = cfg
        self.protos = jnp.asarray(_prototypes(cfg))

    def sample(self, key: jax.Array, batch: int) -> Tuple[jax.Array, jax.Array]:
        """Returns (events (B, T, N) in {-1,0,1}, labels (B,))."""
        c = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        labels = jax.random.randint(k1, (batch,), 0, c.n_classes)
        proto = self.protos[labels]                       # (B, T, N)
        # per-sample gain + spatial jitter via roll
        gain = jax.random.uniform(k2, (batch, 1, 1), minval=0.7, maxval=1.3)
        p_evt = jnp.abs(proto) * gain * (c.rate / jnp.maximum(
            jnp.mean(jnp.abs(proto)), 1e-6))
        u = jax.random.uniform(k3, proto.shape)
        fire = (u < jnp.clip(p_evt, 0, 0.9)).astype(jnp.float32)
        pol = jnp.sign(proto)
        noise_u = jax.random.uniform(k4, proto.shape)
        noise = ((noise_u < c.rate * c.noise_frac).astype(jnp.float32)
                 * jnp.sign(noise_u - 0.5))
        ev = jnp.clip(fire * pol + noise, -1, 1)
        return ev, labels

    def measured_rate(self, key: jax.Array, batch: int = 64) -> float:
        ev, _ = self.sample(key, batch)
        return float(jnp.mean(jnp.abs(ev)))


DATASETS = {"nmnist": NMNIST, "dvs_gesture": DVS_GESTURE, "quiroga": QUIROGA}
