"""Deterministic synthetic LM data pipeline.

Stateless-by-step: ``batch_at(step)`` derives every batch from (seed, step),
so the pipeline state in a checkpoint is just the step counter — restart
resumes bitwise-identically on any topology (the fault-tolerance tests rely
on this).  Token streams are Zipf-distributed with injected n-gram structure
so the LM loss actually decreases (pure uniform noise has no learnable
signal).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram_rep: int = 8      # every token is copied this many steps later


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed Zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = jnp.asarray(probs / probs.sum(), jnp.float32)

    def batch_at(self, step: int, n_micro: int = 1) -> dict:
        c = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        toks = jax.random.categorical(
            key, jnp.log(self._probs)[None, :],
            shape=(c.global_batch, c.seq_len))
        # learnable structure: periodic copy (token[t] = token[t - rep])
        r = c.ngram_rep
        toks = toks.at[:, r::r].set(toks[:, : (c.seq_len - r) // r * r : r][:, :toks[:, r::r].shape[1]])
        toks = toks.astype(jnp.int32)
        if n_micro > 1:
            toks = toks.reshape(n_micro, c.global_batch // n_micro, c.seq_len)
            return {"tokens": toks}
        return {"tokens": toks}

    def state(self, step: int) -> dict:
        return {"step": jnp.asarray(step, jnp.int32),
                "seed": jnp.asarray(self.cfg.seed, jnp.int32)}
