"""Distribution strategies that live outside the model graph: pipeline
parallelism (GPipe schedule over a stage-sharded mesh axis)."""
