"""GPipe pipeline parallelism over one mesh axis.

Each device along ``stage_axis`` owns one pipeline stage's parameters
(leading stage dim sharded over the axis).  The schedule is the classic
GPipe fill/steady/drain loop: ``n_micro + n_stages - 1`` ticks, every tick
each stage runs its microbatch and ships the activation to the next stage
with a ring ``ppermute``.  The bubble is the fill+drain overhead —
``bubble_fraction`` below is the standard (S-1)/(M+S-1) accounting.

Numerics: the composed pipeline must equal running the stages sequentially
on one device — ``tests/test_pipeline.py`` pins that in a 2-simulated-device
subprocess.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """GPipe bubble overhead: (S-1) idle ticks out of (M+S-1) total."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def gpipe(stage_fn, params, x_mb: jax.Array, mesh: Mesh,
          stage_axis: str = "pod"):
    """Run ``n_micro`` microbatches through the stage pipeline.

    stage_fn:  (stage_params, x) -> y, the per-stage forward.
    params:    pytree whose leaves carry a leading (n_stages, ...) dim,
               sharded over ``stage_axis``.
    x_mb:      (n_micro, ...) microbatches, replicated.

    Returns (n_micro, ...) outputs after all stages, replicated.
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x_mb.shape[0]
    n_ticks = n_micro + n_stages - 1
    fwd_ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local_fn(p_loc, x_loc):
        stage = jax.lax.axis_index(stage_axis)
        p_my = jax.tree.map(lambda a: a[0], p_loc)

        def tick(t, carry):
            outs, recv = carry
            # Stage 0 injects microbatch t (clipped reads during drain are
            # computed but never reach the last stage inside the loop).
            inject = x_loc[jnp.clip(t, 0, n_micro - 1)]
            x_in = jnp.where(stage == 0, inject, recv)
            y = stage_fn(p_my, x_in)
            out_idx = t - (n_stages - 1)
            write = (stage == n_stages - 1) & (out_idx >= 0)
            slot = jnp.clip(out_idx, 0, n_micro - 1)
            outs = jnp.where(write, outs.at[slot].set(y), outs)
            recv = jax.lax.ppermute(y, stage_axis, perm=fwd_ring)
            return outs, recv

        outs0 = jnp.zeros_like(x_loc)
        outs, _ = jax.lax.fori_loop(0, n_ticks, tick,
                                    (outs0, jnp.zeros_like(x_loc[0])))
        # Only the last stage holds results; psum replicates them.
        outs = jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, stage_axis)

    fn = compat.shard_map(local_fn, mesh,
                          in_specs=(jax.tree.map(lambda _: P(stage_axis),
                                                 params), P()),
                          out_specs=P())
    return fn(params, x_mb)
