"""Pallas TPU kernels for the paper's compute hot-spots.

ternary_mac — packed twin-cell ternary GEMM (C1): 2x int8 planes decoded
              in-kernel, MXU f32 accumulation.
kwn_topk    — descending-ramp top-K with early stop (C3): emits mask +
              per-row ADC step counts for the latency/energy model.
lif_step    — fused leak/update/compare + SNL noise (C5): one VMEM pass.
nlq_lut     — NLQ boundary compare + one-hot LUT map-back (C2/C6).
fused_macro — the whole macro step (MAC -> IMA ramp -> KWN/NLD head -> LIF)
              in one kernel, VMEM-resident end to end: the inference hot
              path; bitwise-equal to the composed chain at f32.
flash_attention — online-softmax attention fwd with causal block skipping
              (beyond-paper: removes the 2x causal flops waste the roofline
              table shows for train/prefill attention; serving-prefill use).

``ops``  — jit'd wrappers (padding, batching, interpret switch).
``ref``  — pure-jnp oracles used by the allclose test sweeps.
"""

from repro.kernels import ops, ref  # noqa: F401
