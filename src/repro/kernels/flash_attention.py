"""Pallas TPU kernel: flash-attention forward with causal block skipping.

Why it exists here: the §Roofline table shows train/prefill attention in the
XLA path computes the full S x S score grid (masked) — a 2x flops waste on
causal shapes.  This kernel implements the standard online-softmax streaming
attention with the strictly-upper-triangular blocks *skipped* (pl.when), so
prefill compute approaches the causal-optimal S^2/2.

Layout: grid (B*H, n_q_blocks, n_kv_blocks), innermost kv dimension iterates
sequentially per q block; (acc, m, l) live in VMEM scratch across kv steps
(the canonical Pallas flash pattern).  Blocks are MXU-aligned (bq, bk
multiples of 128 on real TPU; smaller allowed in interpret mode for tests).

Forward-only: serving prefill needs no backward; training keeps the XLA
blockwise path (its backward is rematerialized chunk-wise already).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: block (qi, ki) is dead when its first k col > its last q row
    live = (ki * bk <= qi * bq + bq - 1) if causal else (ki >= 0)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                           # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, bq: int = 128, bk: int = 128,
                        interpret: bool = True) -> jax.Array:
    """q,k,v: (BH, S, D) -> (BH, S, D).  S % bq == S % bk == 0."""
    bh, s, d = q.shape
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    scale = 1.0 / (d ** 0.5)

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            _vmem_scratch((bq, d)),
            _vmem_scratch((bq, 1)),
            _vmem_scratch((bq, 1)),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem_scratch(shape):
    """VMEM f32 scratch accumulator spec (TPU memory space)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


def causal_flops_saving(s: int, bq: int, bk: int) -> float:
    """Fraction of block-pairs skipped by the causal gate."""
    nq, nk = s // bq, s // bk
    live = sum(1 for i in range(nq) for j in range(nk)
               if j * bk <= i * bq + bq - 1)
    return 1.0 - live / (nq * nk)
