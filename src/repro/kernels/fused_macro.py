"""Pallas TPU kernel: the fused macro step (MAC -> IMA -> mode head -> LIF).

The paper's efficiency story (0.8 pJ/SOP, -30 % IMA latency, 10x LIF latency)
comes from never leaving the macro: the analog MAC result stays on the RBLs,
the IMA converts it in place, the KWN controller gates which LIF updates run.
The composed kernel chain (``ternary_mac`` -> ``nlq_lut`` -> ``kwn_topk`` ->
``lif_step``) round-trips every intermediate through HBM — the exact
anti-pattern event-driven CIM accelerators exist to avoid.  This kernel is the
TPU-native equivalent of staying inside the macro: one grid step per
(row-tile, K-tile) performs

  1. twin-cell ternary MAC (int8 MSB/LSB planes decoded in VMEM, MXU f32
     accumulation across the K grid axis into the ``mac`` output block);
  2. IMA ramp conversion against the in-VMEM boundary set (linear / NLQ /
     NL-activation — the codebook is data, so one kernel serves all three
     ramp programs);
  3. the mode head: KWN descending-ramp top-K with early-stop step counts
     (``kwn`` mode) or the per-branch NL-activation + soma combine (``nld``
     mode);
  4. the digital LIF membrane update (leak/integrate/SNL/compare/reset),

all on VREG/VMEM-resident state.  Only the final (V_mem', spikes, mask,
adc_steps) — and the raw MAC for telemetry — touch HBM.

Kernel layout / VMEM budget
---------------------------
Grid is ``(M/bm, K/bk)`` with K innermost; per grid step the working set is
``bm*bk`` int8 activations, two ``bk*NC`` int8 weight planes, the
``(bm, NC)`` f32 MAC accumulator, the 2^code_bits-entry codebook, and the
``(bm, N)`` f32 LIF state — ~0.6 MB at the default bm=128, bk=256, N=128,
far under the ~16 MB VMEM budget, leaving room for double buffering.  In
``nld`` mode the weight planes carry all J branches side by side
(``NC = J*N``) so the branch MACs come out of a single MXU contraction.

When to prefer the fused step
-----------------------------
Inference hot loops (the SNN scan body, event-stream serving): everything the
composed path writes to HBM between stages is dead weight there.  Prefer the
composed path when you need the intermediates themselves (calibration sweeps,
the Fig. 6/7 codebook studies) or gradients (training uses the STE jnp path,
not these kernels).  ``kernels/ref.py::fused_macro_step_ref`` is the oracle:
bitwise-identical at f32 accumulation because every MAC partial is a small
integer (exactly representable, associativity-free) and the head is
compare/select/LUT arithmetic mirrored operation-for-operation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BK = 256  # the macro's row count: one K-tile == one physical macro

_LIF_STATICS = ("beta", "v_th1", "v_th2", "v_reset", "v_lim")


def _accumulate_mac(x_ref, msb_ref, lsb_ref, mac_ref, *, ratio: float):
    """Twin-cell decode + MXU MAC into the VMEM accumulator block."""
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        mac_ref[...] = jnp.zeros_like(mac_ref)

    x = x_ref[...].astype(jnp.float32)
    w = ratio * msb_ref[...].astype(jnp.float32) \
        + lsb_ref[...].astype(jnp.float32)
    mac_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def _ramp_codes(x: jax.Array, bounds: jax.Array) -> jax.Array:
    """Ramp conversion: code = #boundaries crossed (ripple-counter value)."""
    return jnp.sum((x[:, :, None] > bounds[None, None, :]),
                   axis=-1).astype(jnp.int32)


def _lut_reconstruct(codes: jax.Array, levels: jax.Array,
                     n_codes: int) -> jax.Array:
    """LUT map-back as one-hot contraction (MXU-friendly; no VPU gather)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_codes), 2)
    onehot = (codes[:, :, None] == iota).astype(jnp.float32)
    return jnp.sum(onehot * levels[None, None, :], axis=-1)


def _kwn_sweep(codes: jax.Array, k: int, n_codes: int):
    """Descending-ramp priority-encoded top-K (same algorithm as kwn_topk)."""
    bm, n = codes.shape

    def sweep(step, carry):
        n_found, mask, steps = carry
        level = n_codes - 1 - step                        # descending ramp
        crossing = (codes == level) & (mask == 0)
        order = jnp.cumsum(crossing.astype(jnp.int32), axis=-1)
        admit = crossing & ((n_found + order) <= k)       # priority encoder
        mask = mask + admit.astype(jnp.int32)
        n_found = n_found + jnp.sum(admit.astype(jnp.int32), axis=-1,
                                    keepdims=True)
        done_now = (n_found >= k) & (steps < 0)
        steps = jnp.where(done_now, step, steps)
        return n_found, mask, steps

    init = (jnp.zeros((bm, 1), jnp.int32), jnp.zeros((bm, n), jnp.int32),
            jnp.full((bm, 1), -1, jnp.int32))
    _, mask, steps = jax.lax.fori_loop(0, n_codes, sweep, init)
    return mask.astype(jnp.float32), jnp.where(steps < 0, n_codes - 1, steps)


def _lif_update(v, drive, mask, noise, *, beta, v_th1, v_th2, v_reset, v_lim,
                use_snl):
    """Eq. (1): winners leak+integrate, non-winners hold; SNL kick; compare."""
    v_new = jnp.where(mask > 0, beta * v + drive, v)
    if use_snl:
        snl = (v_new > v_th2) & (v_new < v_th1)
        v_new = jnp.where(snl, v_new + noise, v_new)
    v_new = jnp.clip(v_new, -v_lim, v_lim)      # 12-bit register saturation
    spike = (v_new >= v_th1).astype(jnp.float32)
    return jnp.where(spike > 0, v_reset, v_new), spike


def _fused_kwn_kernel(x_ref, msb_ref, lsb_ref, bounds_ref, levels_ref,
                      scale_ref, v_ref, noise_ref,
                      mac_ref, v_out_ref, spike_ref, mask_ref, steps_ref, *,
                      ratio, n_k, k, n_codes, beta, v_th1, v_th2, v_reset,
                      v_lim, use_snl, drive_gain):
    _accumulate_mac(x_ref, msb_ref, lsb_ref, mac_ref, ratio=ratio)

    @pl.when(pl.program_id(1) == n_k - 1)
    def _head():
        mac = mac_ref[...]                                # (bm, N) int-valued
        codes = _ramp_codes(mac, bounds_ref[...][0])
        maskf, steps = _kwn_sweep(codes, k, n_codes)
        recon = _lut_reconstruct(codes, levels_ref[...][0], n_codes)
        # Winner drive: LUT value x per-column weight scale, losers exactly 0.
        drive = recon * scale_ref[...] * maskf * drive_gain
        v_new, spike = _lif_update(
            v_ref[...], drive, maskf, noise_ref[...], beta=beta, v_th1=v_th1,
            v_th2=v_th2, v_reset=v_reset, v_lim=v_lim, use_snl=use_snl)
        v_out_ref[...] = v_new
        spike_ref[...] = spike
        mask_ref[...] = maskf
        steps_ref[...] = steps


def _fused_nld_kernel(x_ref, msb_ref, lsb_ref, bounds_ref, levels_ref,
                      scale_ref, w_dend_ref, v_ref, noise_ref,
                      mac_ref, v_out_ref, spike_ref, mask_ref, steps_ref, *,
                      ratio, n_k, n_codes, n_branches, beta, v_th1, v_th2,
                      v_reset, v_lim, drive_gain):
    _accumulate_mac(x_ref, msb_ref, lsb_ref, mac_ref, ratio=ratio)

    @pl.when(pl.program_id(1) == n_k - 1)
    def _head():
        mac = mac_ref[...] * scale_ref[...]               # (bm, J*N) float
        codes = _ramp_codes(mac, bounds_ref[...][0])
        act = _lut_reconstruct(codes, levels_ref[...][0], n_codes)
        bm = act.shape[0]
        n = v_ref.shape[-1]
        act3 = act.reshape(bm, n_branches, n)             # branch-major planes
        w_dend = w_dend_ref[...]                          # (J, N)
        drive = jnp.sum(act3 * w_dend[None, :, :], axis=1) * drive_gain
        ones = jnp.ones((bm, n), jnp.float32)             # dense LIF update
        v_new, spike = _lif_update(
            v_ref[...], drive, ones, noise_ref[...], beta=beta, v_th1=v_th1,
            v_th2=v_th2, v_reset=v_reset, v_lim=v_lim, use_snl=False)
        v_out_ref[...] = v_new
        spike_ref[...] = spike
        mask_ref[...] = ones
        steps_ref[...] = jnp.full((bm, 1), n_codes - 1, jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "mode", "k", "ratio", "drive_gain", "use_snl", "bm", "bk",
    "interpret") + _LIF_STATICS)
def fused_macro_step(x: jax.Array, msb: jax.Array, lsb: jax.Array,
                     boundaries: jax.Array, levels: jax.Array,
                     scale: jax.Array, v: jax.Array, noise: jax.Array,
                     w_dend: jax.Array | None = None, *,
                     mode: str = "kwn", k: int = 12, ratio: float = 2.0,
                     drive_gain: float = 1.0, beta: float = 0.9,
                     v_th1: float = 1.0, v_th2: float = 0.6,
                     v_reset: float = 0.0, v_lim: float = 8.0,
                     use_snl: bool = True, bm: int = DEFAULT_BM,
                     bk: int = DEFAULT_BK, interpret: bool = True):
    """One fused macro time step.

    x:           (M, K) int8 ternary inputs (encoded event spikes).
    msb/lsb:     (K, NC) int8 twin-cell planes.  ``kwn``: NC == N columns;
                 ``nld``: NC == J*N with branch-major column packing
                 (column j*N + p is branch j of output neuron p).
    boundaries:  (n_codes-1,) ramp decision thresholds.
    levels:      (n_codes,) LUT (KWN: 8-bit map-back values in integer MAC
                 units; NLD: f(x) samples).
    scale:       (NC,) per-column weight quantization scale.  Applied to the
                 winner drive after conversion in ``kwn`` mode (the ramp sees
                 integer-unit MACs); applied to the MAC before conversion in
                 ``nld`` mode (the activation ramp sees float-unit MACs).
    v, noise:    (M, N) f32 membrane state and pre-drawn PRBS noise.
    w_dend:      (J, N) soma combine weights (``nld`` only).

    Returns (mac (M, NC) f32, v_out (M, N) f32, spikes (M, N) f32,
    mask (M, N) f32, adc_steps (M, 1) i32).
    """
    m, kdim = x.shape
    kdim2, nc = msb.shape
    n = v.shape[-1]
    assert kdim == kdim2 and msb.shape == lsb.shape
    assert m % bm == 0 and kdim % bk == 0, (m, kdim, bm, bk)
    assert v.shape == noise.shape == (m, n)
    n_codes = levels.shape[0]
    assert boundaries.shape[0] == n_codes - 1
    grid = (m // bm, kdim // bk)

    row_spec = lambda shape: pl.BlockSpec(shape, lambda i, kk: (i, 0))
    const_spec = lambda shape: pl.BlockSpec(shape, lambda i, kk: (0, 0))
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, kk: (i, kk)),       # x
        pl.BlockSpec((bk, nc), lambda i, kk: (kk, 0)),       # msb
        pl.BlockSpec((bk, nc), lambda i, kk: (kk, 0)),       # lsb
        const_spec((1, n_codes - 1)),                        # boundaries
        const_spec((1, n_codes)),                            # levels
        const_spec((1, nc)),                                 # scale
    ]
    inputs = [x.astype(jnp.int8), msb.astype(jnp.int8), lsb.astype(jnp.int8),
              boundaries.astype(jnp.float32).reshape(1, -1),
              levels.astype(jnp.float32).reshape(1, -1),
              scale.astype(jnp.float32).reshape(1, -1)]

    if mode == "kwn":
        assert nc == n, (nc, n)
        kernel = functools.partial(
            _fused_kwn_kernel, ratio=ratio, n_k=grid[1], k=k, n_codes=n_codes,
            beta=beta, v_th1=v_th1, v_th2=v_th2, v_reset=v_reset, v_lim=v_lim,
            use_snl=use_snl, drive_gain=drive_gain)
    elif mode == "nld":
        assert w_dend is not None and nc % n == 0, (nc, n)
        n_branches = nc // n
        assert w_dend.shape == (n_branches, n)
        in_specs.append(const_spec((n_branches, n)))         # w_dend
        inputs.append(w_dend.astype(jnp.float32))
        kernel = functools.partial(
            _fused_nld_kernel, ratio=ratio, n_k=grid[1], n_codes=n_codes,
            n_branches=n_branches, beta=beta, v_th1=v_th1, v_th2=v_th2,
            v_reset=v_reset, v_lim=v_lim, drive_gain=drive_gain)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    in_specs += [row_spec((bm, n)), row_spec((bm, n))]       # v, noise
    inputs += [v.astype(jnp.float32), noise.astype(jnp.float32)]

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            row_spec((bm, nc)),                              # mac telemetry
            row_spec((bm, n)), row_spec((bm, n)), row_spec((bm, n)),
            row_spec((bm, 1)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, nc), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*inputs)
