"""Pallas TPU kernel: the fused macro step (MAC -> IMA -> mode head -> LIF),
tiled over a virtual macro grid and batched over time.

The paper's efficiency story (0.8 pJ/SOP, -30 % IMA latency, 10x LIF latency)
comes from never leaving the macro: the analog MAC result stays on the RBLs,
the IMA converts it in place, the KWN controller gates which LIF updates run.
The composed kernel chain (``ternary_mac`` -> ``nlq_lut`` -> ``kwn_topk`` ->
``lif_step``) round-trips every intermediate through HBM — the exact
anti-pattern event-driven CIM accelerators exist to avoid.  This kernel is the
TPU-native equivalent of staying inside the macro: one grid step per
(row-tile, time-step, col-tile, K-tile) performs

  1. twin-cell ternary MAC (int8 MSB/LSB planes decoded in VMEM, MXU f32
     accumulation across the K grid axis into the column tile's slice of the
     full-width ``mac`` accumulator — digital partial-sum accumulation, the
     way the silicon adds converted row-tile partials across macro
     instances);
  2. on the last (col-tile, K-tile) of a time step, IMA ramp conversion of
     the whole accumulator against the in-VMEM boundary set (linear / NLQ /
     NL-activation — the codebook is data, so one kernel serves all three
     ramp programs), optionally injecting the Fig. 7 silicon error model
     (INL + comparator offset + Gaussian thermal noise, in code LSBs)
     with per-step per-column draws generated *in kernel* by the
     counter-based Threefry PRNG (``core.ctrprng``) — no pre-drawn noise
     tensor, no composed-path fallback, and, because every draw is a pure
     function of ``(seed, step, absolute row, logical column)``, the noisy
     output is launch-shape-invariant and bitwise-equal to the
     ``kernels/ref.py`` oracle;
  3. the mode head: KWN descending-ramp top-K with early-stop step counts
     (``kwn`` mode) or the per-branch NL-activation + soma combine (``nld``
     mode);
  4. the digital LIF membrane update (leak/integrate/SNL/compare/reset),
     with the membrane carried in VMEM across the whole T axis,

all on VREG/VMEM-resident state.  Only the per-step (spikes, mask,
adc_steps) — and, when requested, the raw MAC for telemetry — touch HBM;
the LIF membrane is written back once per row tile, after the last time
step.

Activity-gated sparse execution
-------------------------------
The silicon's 0.8 pJ/SOP comes from *not* spending energy on inactive rows:
event tensors are a few percent dense, and the macro only charges RBLs for
rows that fire.  The kernel reproduces that with an ``activity`` occupancy
map: a cheap host-side pass over the ternary ``(T, M, K)`` input marks each
``(step, row-tile, K-tile)`` block that contains at least one event, and the
map rides into the kernel as a scalar-prefetch operand (SMEM-resident, read
before the block's compute issues).  An all-zero activation block can only
contribute an exactly-zero partial sum, so the int8 plane decode + MXU
contraction for it are ``pl.when``-skipped without changing a single output
bit — clean *and* noisy outputs stay equal to the ``kernels/ref.py``
oracles, because the Fig. 7 noise draws key on ``(seed, step, row, col)``
and are consumed at the ramp stage, which still runs every step.  The gated
path additionally turns the KWN early stop from telemetry into compute: the
descending one-hot sweep starts at the highest code actually present in the
tile and exits as soon as every row has its K winners (a bounded
``while_loop`` instead of the fixed 2^code_bits ``fori_loop``; skipped
levels have no crossings or no admission slots left, so mask/steps are
bit-identical).  Raw-MAC telemetry is opt-out (``mac_telemetry=False``
keeps the accumulator in VMEM scratch and never writes the ``(T, M, NC)``
stack to HBM — the serving default).

Kernel layout / VMEM budget
---------------------------
Grid is ``(M/bm, T, NC/bn, K/bk)`` with K innermost, then column tiles, then
time.  Per grid step the streamed working set is the ``bm x bk`` int8
activation block and two ``bk x bn`` int8 weight planes (the Pallas pipeline
double-buffers these across grid steps, so weight-plane DMA overlaps the MXU
contraction); resident across a time step are the full-width ``(bm, NC)``
f32 MAC accumulator (an HBM-backed output block when ``mac_telemetry`` is
on, a VMEM scratch buffer when off — same footprint either way), the
2^code_bits-entry codebook, and the ``(bm, N)`` f32 LIF membrane (resident
across the whole T axis).  The activity map adds ``T * (M/bm) * (K/bk)``
int32 words of SMEM (scalar prefetch) — a few KB even for long streams,
never a VMEM tenant.  At the defaults (bm=128, bk=256, bn=128) a
single-macro layer (NC=N=128) costs

    x        128*256      int8   =  32 KB   (x2 double buffered)
    planes 2*256*128      int8   =  64 KB   (x2 double buffered)
    mac      128*128      f32    =  64 KB   (output block or scratch)
    v + noise + outputs ~6*128*128 f32 ~ 384 KB

~0.7 MB, and each additional column tile adds only 64 KB of accumulator +
the same streamed 64 KB plane window — so a 256x512 layer (n_j=4) stays
near 1 MB, far under the ~16 MB VMEM budget.  The head's transient
``(bm, NC, 2^code_bits)`` one-hot compare (4 MB at NC=512, 5-bit codes) is
the real ceiling: NC beyond ~1-2k columns per kernel should split at the
model layer.  Folding T into the grid adds *no* VMEM (one time step is
resident at a time); it removes the per-step kernel launch + weight-plane
re-staging that dominates short-step event-stream serving.

Tile-shape / activity-granularity heuristic
-------------------------------------------
Occupancy is tracked per ``(step, row-tile, K-tile)`` block, so the tile
plan *is* the gating granularity: a block is skippable only if every one of
its ``bm x bk`` entries is zero.  ``plan_tiles`` therefore prefers the
smallest lane-aligned K tile that covers the layer (``bk =
ceil_to_128(K)`` when K < 256, the physical macro row count otherwise):
padding K up to an oversized tile would dilute real events across dead
zero columns and make blocks look occupied-by-construction, while an
aligned tile keeps every activity block dense with real rows.  Row tiles
follow the batch (``bm = min(128, ceil_to_8(M))``) so a batch the serving
engine packs by measured event density maps quiet requests onto quiet —
skippable — row tiles.

When to prefer the fused step
-----------------------------
Inference hot loops (the SNN scan body, event-stream serving): everything the
composed path writes to HBM between stages is dead weight there.  Prefer the
composed path when you need the intermediates themselves (calibration sweeps,
the Fig. 6/7 codebook studies) or gradients (training uses the STE jnp path,
not these kernels).  ``kernels/ref.py::fused_macro_step_ref`` (one step) and
``fused_macro_seq_ref`` (time-major) are the oracles: bitwise-identical at
f32 accumulation because every MAC partial is a small integer (exactly
representable, associativity-free — so row/col tiling cannot change the sum)
and the head is compare/select/LUT arithmetic mirrored
operation-for-operation.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ctrprng

DEFAULT_BM = 128
DEFAULT_BK = 256  # the macro's row count: one K-tile == one physical macro
DEFAULT_BN = 128  # the macro's column count: one col-tile == one macro width

_LIF_STATICS = ("beta", "v_th1", "v_th2", "v_reset", "v_lim")


# ---------------------------------------------------------------------------
# Tile planning
# ---------------------------------------------------------------------------

class TilePlan(NamedTuple):
    """Padded geometry + grid for one fused kernel launch.

    n_pad/nc_pad are the padded per-neuron / per-column widths (nc_pad ==
    n_branches * n_pad, branch-major).  ``n_valid`` is the number of real
    columns the KWN sweep may admit (padded columns are excluded from the
    ramp inside the kernel).  ``vmem_resident_bytes`` counts the blocks live
    in VMEM per grid step (x + double-buffered weight planes + accumulator +
    LIF state + per-step outputs), not the head's transient one-hots; the
    activity map is SMEM-resident (``activity_bytes``) and not counted.
    ``activity_shape`` is the occupancy-map geometry the gated kernel
    prefetches: one int32 word per (step, row-tile, K-tile) block.
    """

    bm: int
    bk: int
    bn: int
    m_pad: int
    k_pad: int
    n_pad: int
    nc_pad: int
    n_valid: int
    grid: tuple[int, int, int, int]   # (M/bm, T, NC/bn, K/bk)

    @property
    def vmem_resident_bytes(self) -> int:
        streamed = self.bm * self.bk + 2 * self.bk * self.bn     # int8, x2 buf
        resident = 4 * (self.bm * self.nc_pad                     # mac f32
                        + 5 * self.bm * self.n_pad)               # v/noise/out
        return 2 * streamed + resident

    @property
    def activity_shape(self) -> tuple[int, int, int]:
        """(T, row-tiles, K-tiles): one occupancy word per gateable block."""
        return (self.grid[1], self.grid[0], self.grid[3])

    @property
    def activity_bytes(self) -> int:
        """SMEM bytes the scalar-prefetched occupancy map occupies."""
        t, n_i, n_k = self.activity_shape
        return 4 * t * n_i * n_k


def _ceil_mult(n: int, m: int) -> int:
    return max(m, ((n + m - 1) // m) * m)


def cached_plan_blocks(m: int, k_dim: int, nc: int, n: int, t: int, *,
                       mode: str, density: float | None = None):
    """Tuned (bm, bk, bn) from the persistent plan cache, or None.

    The lazy import keeps the planner importable (and the heuristic fully
    functional) even if the tune package is broken or absent; any cache
    failure is the cache's to warn about and degrades to None here.
    """
    try:
        from repro.tune import cache as _plan_cache
        return _plan_cache.lookup(m, k_dim, nc, n, t, mode=mode,
                                  density=density)
    except Exception:   # noqa: BLE001 — the cache must never break planning
        return None


def plan_tiles(m: int, k_dim: int, nc: int, n: int, t: int = 1, *,
               mode: str = "kwn", n_branches: int = 1,
               bm: int | None = None, bk: int | None = None,
               bn: int | None = None, density: float | None = None,
               use_cache: bool = True) -> TilePlan:
    """Pick (bm, bk, bn) and padded shapes for a fused launch.

    The single tile-planning entry point: ``ops.fused_macro_seq`` (and its
    VJP), ``core.macro.plan_fused_tiles`` / ``plan_activity`` /
    ``plan_fused_stack``, and the autotuner all plan through here, so the
    occupancy map a host-side planner builds always matches the grid the
    kernel launches with.  See ``docs/TILE_PLANS.md`` for the full field
    and cache contract.

    Parameters
    ----------
    m, k_dim, nc, n : logical launch geometry — flattened batch rows, the
        contraction (input-event) width, total weight columns, and
        per-neuron output width.  ``nc == n`` in KWN mode; in NLD mode
        ``nc == n_branches * n`` (branch-major column planes).
    t : number of time steps folded into the kernel grid (1 = single step).
    mode : ``"kwn"`` or ``"nld"`` — NLD changes the column-padding rule.
    n_branches : dendritic branches per neuron (NLD only).
    bm, bk, bn : explicit block-size overrides.  Any non-None override
        pins that axis and **disables the cache lookup entirely** — an
        explicit plan is an explicit plan (the bench and tuner rely on
        this to measure exactly the plan they asked for).
    density : optional measured event density in [0, 1]; refines the cache
        key to a density bucket.  Callers that share a plan with a
        separately built activity map (the model/serving paths) must pass
        the same value at both sites — they pass None — because the cache
        entry chosen may differ per bucket.
    use_cache : False bypasses the persistent cache (tuner internals,
        A/B tests).  Cache misses and every cache failure mode fall
        through to the heuristic below; a cached plan can only change
        speed, never output bits (kernel parity contract).

    Returns a ``TilePlan``: the chosen blocks, padded shapes, ``n_valid``
    and the launch ``grid`` (see the class docstring).

    Heuristic (the fallback, and the baseline every tuned plan is gated
    against) — column tiling rules: a layer that fits one macro width
    (nc <= bn) runs a single unpadded column tile; wider layers tile at
    ``bn`` (default 128, the physical macro column count) with zero-padded
    tail columns.  In ``nld`` mode padding must not straddle the
    branch-major column layout, so the per-branch width n is padded to the
    smallest n_pad with ``n_branches * n_pad % bn == 0`` and the planes
    are re-packed per branch.  Zero weight columns are MAC-neutral; the
    KWN sweep additionally masks padded columns out of the ramp
    (``n_valid``) so they can never steal winner slots.

    K tiling aligns with the activity-map granularity (see the module
    docstring): layers narrower than the 256-row physical macro take the
    smallest lane-aligned tile that covers them (``ceil_to_128(K)``), so an
    occupancy block is never padded-zero by construction and per-block
    gating stays meaningful; layers at or past 256 rows tile at the
    physical macro row count.
    """
    if use_cache and bm is None and bk is None and bn is None:
        cached = cached_plan_blocks(m, k_dim, nc, n, t, mode=mode,
                                    density=density)
        if cached is not None:
            bm, bk, bn = cached
    bm_ = bm or min(DEFAULT_BM, _ceil_mult(m, 8))
    bk_ = bk or (DEFAULT_BK if k_dim >= DEFAULT_BK else _ceil_mult(k_dim, 128))
    bn_req = bn or DEFAULT_BN
    if nc <= bn_req:
        bn_ = nc
        n_pad, nc_pad = n, nc
    elif mode == "nld" and n_branches > 1:
        bn_ = bn_req
        step = bn_ // math.gcd(bn_, n_branches)
        n_pad = _ceil_mult(n, step)
        nc_pad = n_branches * n_pad
    else:
        bn_ = bn_req
        nc_pad = _ceil_mult(nc, bn_)
        n_pad = nc_pad
    m_pad = _ceil_mult(m, bm_)
    k_pad = _ceil_mult(k_dim, bk_)
    return TilePlan(bm=bm_, bk=bk_, bn=bn_, m_pad=m_pad, k_pad=k_pad,
                    n_pad=n_pad, nc_pad=nc_pad, n_valid=nc,
                    grid=(m_pad // bm_, t, nc_pad // bn_, k_pad // bk_))


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------

def _accumulate_mac_tile(x_ref, msb_ref, lsb_ref, mac_ref, *, ratio: float,
                         bn: int, occ=None):
    """Twin-cell decode + MXU MAC into this column tile's accumulator slice.

    With ``occ`` (the scalar-prefetched occupancy word for this
    (step, row-tile, K-tile) block), the decode + contraction are
    ``pl.when``-skipped for all-zero activation blocks: a skipped block's
    partial sum is exactly zero, so the (always-run) zero-init at the first
    K tile plus occupied-block adds reproduce the dense accumulator value
    bit-for-bit (every partial is a small exact integer; f32 addition of
    exact zeros is the identity).
    """
    j, kk = pl.program_id(2), pl.program_id(3)
    col = (pl.dslice(0, 1), pl.dslice(None), pl.dslice(j * bn, bn))

    def _decoded_part():
        x = x_ref[0].astype(jnp.float32)
        w = ratio * msb_ref[...].astype(jnp.float32) \
            + lsb_ref[...].astype(jnp.float32)
        return jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[None]

    if occ is None:                       # dense path: decode + MAC always
        part = _decoded_part()

        @pl.when(kk == 0)
        def _init():
            pl.store(mac_ref, col, jnp.zeros_like(part) + part)

        @pl.when(kk > 0)
        def _accumulate():
            pl.store(mac_ref, col, pl.load(mac_ref, col) + part)
        return

    @pl.when(kk == 0)
    def _zero():
        pl.store(mac_ref, col,
                 jnp.zeros((1, x_ref.shape[1], bn), jnp.float32))

    @pl.when(occ > 0)
    def _mac():
        pl.store(mac_ref, col, pl.load(mac_ref, col) + _decoded_part())


def _ramp_codes(x: jax.Array, bounds: jax.Array) -> jax.Array:
    """Ramp conversion: code = #boundaries crossed (ripple-counter value)."""
    return jnp.sum((x[:, :, None] > bounds[None, None, :]),
                   axis=-1).astype(jnp.int32)


def _lut_reconstruct(codes: jax.Array, levels: jax.Array,
                     n_codes: int) -> jax.Array:
    """LUT map-back as one-hot contraction (MXU-friendly; no VPU gather)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_codes), 2)
    onehot = (codes[:, :, None] == iota).astype(jnp.float32)
    return jnp.sum(onehot * levels[None, None, :], axis=-1)


def _kwn_sweep(codes: jax.Array, k: int, n_codes: int, bounded: bool = False):
    """Descending-ramp priority-encoded top-K (same algorithm as kwn_topk).

    ``bounded=True`` is the activity-gated variant: the sweep starts at the
    highest code actually present in the tile and exits once every row has
    its K winners — a data-bounded ``while_loop`` instead of the fixed
    2^code_bits ``fori_loop``.  Skipped head levels have no crossings and
    skipped tail levels have no admission slots left (``n_found == k``
    blocks every admit), so mask and early-stop step counts are
    bit-identical to the full sweep; only the work changes.
    """
    bm, n = codes.shape

    def descend(level, carry):
        n_found, mask, steps = carry
        crossing = (codes == level) & (mask == 0)
        order = jnp.cumsum(crossing.astype(jnp.int32), axis=-1)
        admit = crossing & ((n_found + order) <= k)       # priority encoder
        mask = mask + admit.astype(jnp.int32)
        n_found = n_found + jnp.sum(admit.astype(jnp.int32), axis=-1,
                                    keepdims=True)
        done_now = (n_found >= k) & (steps < 0)
        steps = jnp.where(done_now, n_codes - 1 - level, steps)
        return n_found, mask, steps

    init = (jnp.zeros((bm, 1), jnp.int32), jnp.zeros((bm, n), jnp.int32),
            jnp.full((bm, 1), -1, jnp.int32))
    if bounded:
        def body(carry):
            level, n_found, mask, steps = carry
            n_found, mask, steps = descend(level, (n_found, mask, steps))
            return level - 1, n_found, mask, steps

        def cond_fn(carry):
            level, n_found = carry[0], carry[1]
            return (level >= 0) & jnp.any(n_found < k)

        top = jnp.max(codes)              # occupied code range upper bound
        _, _, mask, steps = jax.lax.while_loop(cond_fn, body, (top, *init))
    else:
        _, mask, steps = jax.lax.fori_loop(
            0, n_codes,
            lambda step, carry: descend(n_codes - 1 - step, carry), init)
    return mask.astype(jnp.float32), jnp.where(steps < 0, n_codes - 1, steps)


def _lif_update(v, drive, mask, noise, *, beta, v_th1, v_th2, v_reset, v_lim,
                use_snl):
    """Eq. (1): winners leak+integrate, non-winners hold; SNL kick; compare.

    Returns (v_out, spike, v_clip): ``v_clip`` is the post-saturation,
    pre-reset membrane — the value the spike comparator actually reads.
    Training saves it per step (``train_trace``) because the SuperSpike
    surrogate and the saturation gradient gate are both functions of it.
    """
    v_new = jnp.where(mask > 0, beta * v + drive, v)
    if use_snl:
        snl = (v_new > v_th2) & (v_new < v_th1)
        v_new = jnp.where(snl, v_new + noise, v_new)
    v_new = jnp.clip(v_new, -v_lim, v_lim)      # 12-bit register saturation
    spike = (v_new >= v_th1).astype(jnp.float32)
    return jnp.where(spike > 0, v_reset, v_new), spike, v_new


def _mask_padded_columns(codes: jax.Array, n_valid: int) -> jax.Array:
    """Padded columns never cross the ramp (code -1 < every sweep level)."""
    if n_valid >= codes.shape[-1]:
        return codes
    col = jax.lax.broadcasted_iota(jnp.int32, codes.shape, 1)
    return jnp.where(col < n_valid, codes, -1)


def _noise_ids(shape, row0, per_branch: int, logical_n: int, rows=None):
    """Global (row, logical-column) counter words for the noise streams.

    Rows are absolute batch rows (``row0`` = row-tile offset, computed from
    ``program_id`` at kernel top level — interpret mode cannot lower
    ``program_id`` inside a ``pl.when`` sub-jaxpr).  Columns are *logical*:
    a padded branch-major layout stores branch j of column p at
    ``j * per_branch + p``, but the counter uses ``j * logical_n + p`` so
    the draw a real column receives is invariant to the tile plan's padding
    (``per_branch`` changes with (bn, J); ``logical_n`` never does).

    ``rows`` overrides the absolute-row basis with an explicit (bm, 1)
    per-row id vector (``row_ctl`` path: each batch row replays the stream
    of an arbitrary virtual row, e.g. row 0 of a batch-1 launch).
    """
    if rows is None:
        rows = jax.lax.broadcasted_iota(jnp.int32, shape, 0) + row0
    col = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    lcol = (col // per_branch) * logical_n + col % per_branch
    return rows, lcol


def _ima_noisy_codes(codes, x, seed, step, *, row0, per_branch, logical_n,
                     ima_noise, n_codes, rows=None):
    """Counter-PRNG Fig. 7 error injection on the full-width code plane."""
    rows, cols = _noise_ids(codes.shape, row0, per_branch, logical_n,
                            rows=rows)
    return ctrprng.noisy_ima_codes(codes, x, rows, cols, seed, step,
                                   ima_noise, n_codes)


def _lif_noise(noise_ref, rest_shape, seed, step, *, row0, logical_n,
               snl_amp, use_snl, rows=None):
    """SNL noise operand: streamed input (clean path, PRBS parity) or
    in-kernel counter sign noise (noisy path — nothing pre-drawn, nothing
    staged through HBM)."""
    if noise_ref is not None:
        return noise_ref[0]
    if not use_snl or snl_amp == 0.0:
        return jnp.zeros(rest_shape, jnp.float32)
    rows, cols = _noise_ids(rest_shape, row0, rest_shape[-1], logical_n,
                            rows=rows)
    sign = ctrprng.counter_sign(seed, step, rows, cols, ctrprng.TAG_SNL)
    return jnp.float32(snl_amp) * sign


def _row_stream_ids(ctl_ref, rc_ref, t):
    """Per-launch (scalar ctl) or per-row (row_ctl) noise-stream words.

    With ``row_ctl`` present each batch row carries its own
    ``(seed, step_offset, row_id)`` — seed/step come back as (bm, 1)
    columns that broadcast through the counter PRNG exactly like the
    scalar path, and ``row_id`` overrides the absolute-row coordinate so
    a slot can reproduce the stream of a batch-1 run bit-for-bit.
    """
    if rc_ref is None:
        return ctl_ref[0, 0], ctl_ref[0, 1] + t, None
    rc = rc_ref[...]
    return rc[:, 0:1], rc[:, 1:2] + t, rc[:, 2:3]


def _unpack_refs(refs, *, gated, has_noise_ref, has_w_dend, mac_out,
                 train_trace=False, has_row_ctl=False):
    """Positional-ref unpacking shared by both mode kernels.

    Ref order is (scalar prefetch), inputs, outputs, scratch:
    ``[occ?] x msb lsb bounds levels scale ctl [row_ctl?] [w_dend?] v0
    [noise?] [mac(out)?] v spike mask steps [vtrace?] [mac(scratch)?]``.
    """
    refs = list(refs)
    occ_ref = refs.pop(0) if gated else None
    names = ["x", "msb", "lsb", "bounds", "levels", "scale", "ctl"]
    if has_row_ctl:
        names.append("row_ctl")
    if has_w_dend:
        names.append("w_dend")
    names.append("v0")
    ins = dict(zip(names, refs[:len(names)]))
    rest = refs[len(names):]
    noise_ref = rest.pop(0) if has_noise_ref else None
    mac_ref = rest.pop(0) if mac_out else None
    v_ref, spike_ref, mask_ref, steps_ref = rest[:4]
    rest = rest[4:]
    vtrace_ref = rest.pop(0) if train_trace else None
    if not mac_out:
        mac_ref = rest.pop(0)                    # VMEM scratch accumulator
    return (occ_ref, ins, noise_ref, mac_ref, v_ref, spike_ref, mask_ref,
            steps_ref, vtrace_ref)


def _block_occupancy(occ_ref, *, i, t, kk, n_i, n_k):
    """This grid step's scalar-prefetched occupancy word (or None)."""
    if occ_ref is None:
        return None
    return occ_ref[(t * n_i + i) * n_k + kk]


def _seq_kwn_kernel(*refs, ratio, bm, bn, n_i, n_j, n_k, n_valid, k,
                    n_codes, beta, v_th1, v_th2, v_reset, v_lim, use_snl,
                    drive_gain, ima_noise, snl_amp, logical_n, has_noise_ref,
                    gated, mac_out, train_trace, has_row_ctl=False):
    (occ_ref, ins, noise_ref, mac_ref, v_ref, spike_ref, mask_ref,
     steps_ref, vtrace_ref) = _unpack_refs(refs, gated=gated,
                                           has_noise_ref=has_noise_ref,
                                           has_w_dend=False, mac_out=mac_out,
                                           train_trace=train_trace,
                                           has_row_ctl=has_row_ctl)
    x_ref, msb_ref, lsb_ref = ins["x"], ins["msb"], ins["lsb"]
    bounds_ref, levels_ref = ins["bounds"], ins["levels"]
    scale_ref, ctl_ref, v0_ref = ins["scale"], ins["ctl"], ins["v0"]
    i, t = pl.program_id(0), pl.program_id(1)
    j, kk = pl.program_id(2), pl.program_id(3)
    row0 = i * bm
    occ = _block_occupancy(occ_ref, i=i, t=t, kk=kk, n_i=n_i, n_k=n_k)

    @pl.when((t == 0) & (j == 0) & (kk == 0))
    def _load_membrane():
        v_ref[...] = v0_ref[...]

    _accumulate_mac_tile(x_ref, msb_ref, lsb_ref, mac_ref, ratio=ratio,
                         bn=bn, occ=occ)

    @pl.when((j == n_j - 1) & (kk == n_k - 1))
    def _head():
        seed, step, row_ids = _row_stream_ids(ctl_ref, ins.get("row_ctl"), t)
        mac = mac_ref[0]                                  # (bm, N) int-valued
        codes = _ramp_codes(mac, bounds_ref[...][0])
        if ima_noise is not None:
            # The NLQ ramp sees integer-unit MACs; inject the Fig. 7 error
            # (INL + offset + Gaussian, in code LSBs) before the sweep, so
            # winner selection, early stop, and the LUT map-back all see
            # the same noisy ripple-counter value the silicon registers.
            codes = _ima_noisy_codes(codes, mac, seed, step, row0=row0,
                                     per_branch=codes.shape[-1],
                                     logical_n=logical_n,
                                     ima_noise=ima_noise, n_codes=n_codes,
                                     rows=row_ids)
        codes = _mask_padded_columns(codes, n_valid)
        maskf, steps = _kwn_sweep(codes, k, n_codes, bounded=gated)
        recon = _lut_reconstruct(codes, levels_ref[...][0], n_codes)
        # Winner drive: LUT value x per-column weight scale, losers exactly 0.
        drive = recon * scale_ref[...] * maskf * drive_gain
        nz = _lif_noise(noise_ref, v_ref.shape, seed, step, row0=row0,
                        logical_n=logical_n, snl_amp=snl_amp, use_snl=use_snl,
                        rows=row_ids)
        v_new, spike, v_clip = _lif_update(
            v_ref[...], drive, maskf, nz, beta=beta, v_th1=v_th1,
            v_th2=v_th2, v_reset=v_reset, v_lim=v_lim, use_snl=use_snl)
        v_ref[...] = v_new
        spike_ref[0] = spike
        mask_ref[0] = maskf
        steps_ref[0] = steps
        if vtrace_ref is not None:
            vtrace_ref[0] = v_clip


def _seq_nld_kernel(*refs, ratio, bm, bn, n_i, n_j, n_k, n_codes,
                    n_branches, beta, v_th1, v_th2, v_reset, v_lim,
                    drive_gain, ima_noise, logical_n, has_noise_ref, gated,
                    mac_out, has_row_ctl=False):
    (occ_ref, ins, _, mac_ref, v_ref, spike_ref, mask_ref,
     steps_ref, _) = _unpack_refs(refs, gated=gated,
                                  has_noise_ref=has_noise_ref,
                                  has_w_dend=True, mac_out=mac_out,
                                  has_row_ctl=has_row_ctl)
    x_ref, msb_ref, lsb_ref = ins["x"], ins["msb"], ins["lsb"]
    bounds_ref, levels_ref = ins["bounds"], ins["levels"]
    scale_ref, ctl_ref = ins["scale"], ins["ctl"]
    w_dend_ref, v0_ref = ins["w_dend"], ins["v0"]
    i, t = pl.program_id(0), pl.program_id(1)
    j, kk = pl.program_id(2), pl.program_id(3)
    row0 = i * bm
    occ = _block_occupancy(occ_ref, i=i, t=t, kk=kk, n_i=n_i, n_k=n_k)

    @pl.when((t == 0) & (j == 0) & (kk == 0))
    def _load_membrane():
        v_ref[...] = v0_ref[...]

    _accumulate_mac_tile(x_ref, msb_ref, lsb_ref, mac_ref, ratio=ratio,
                         bn=bn, occ=occ)

    @pl.when((j == n_j - 1) & (kk == n_k - 1))
    def _head():
        seed, step, row_ids = _row_stream_ids(ctl_ref, ins.get("row_ctl"), t)
        mac = mac_ref[0] * scale_ref[...]                 # (bm, J*N) float
        codes = _ramp_codes(mac, bounds_ref[...][0])
        if ima_noise is not None:
            # NL-activation ramp: same conversion error, float-unit range.
            codes = _ima_noisy_codes(codes, mac, seed, step, row0=row0,
                                     per_branch=codes.shape[-1] // n_branches,
                                     logical_n=logical_n,
                                     ima_noise=ima_noise, n_codes=n_codes,
                                     rows=row_ids)
        act = _lut_reconstruct(codes, levels_ref[...][0], n_codes)
        bm_rows = act.shape[0]
        n = v_ref.shape[-1]
        act3 = act.reshape(bm_rows, n_branches, n)        # branch-major planes
        w_dend = w_dend_ref[...]                          # (J, N)
        drive = jnp.sum(act3 * w_dend[None, :, :], axis=1) * drive_gain
        ones = jnp.ones((bm_rows, n), jnp.float32)        # dense LIF update
        v_new, spike, _ = _lif_update(
            v_ref[...], drive, ones, jnp.zeros((bm_rows, n), jnp.float32),
            beta=beta, v_th1=v_th1, v_th2=v_th2, v_reset=v_reset,
            v_lim=v_lim, use_snl=False)
        v_ref[...] = v_new
        spike_ref[0] = spike
        mask_ref[0] = ones
        steps_ref[0] = jnp.full((bm_rows, 1), n_codes - 1, jnp.int32)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "mode", "k", "ratio", "drive_gain", "use_snl", "bm", "bk", "bn",
    "n_valid", "ima_noise", "snl_amp", "logical_n", "mac_telemetry",
    "train_trace", "interpret") + _LIF_STATICS)
def fused_macro_seq(x: jax.Array, msb: jax.Array, lsb: jax.Array,
                    boundaries: jax.Array, levels: jax.Array,
                    scale: jax.Array, v: jax.Array,
                    noise: jax.Array | None = None,
                    w_dend: jax.Array | None = None,
                    activity: jax.Array | None = None,
                    row_ctl: jax.Array | None = None, *,
                    mode: str = "kwn", k: int = 12, ratio: float = 2.0,
                    drive_gain: float = 1.0, beta: float = 0.9,
                    v_th1: float = 1.0, v_th2: float = 0.6,
                    v_reset: float = 0.0, v_lim: float = 8.0,
                    use_snl: bool = True, bm: int = DEFAULT_BM,
                    bk: int = DEFAULT_BK, bn: int | None = None,
                    n_valid: int | None = None, ima_noise=None,
                    snl_amp: float = 0.0, logical_n: int | None = None,
                    mac_telemetry: bool = True, train_trace: bool = False,
                    seed=0, step_offset=0, interpret: bool = True):
    """A whole fused event sequence: T macro time steps in one kernel.

    x:           (T, M, K) int8 ternary inputs (time-major encoded events).
    msb/lsb:     (K, NC) int8 twin-cell planes.  ``kwn``: NC == N columns;
                 ``nld``: NC == J*N with branch-major column packing
                 (column j*N + p is branch j of output neuron p).
    boundaries:  (n_codes-1,) ramp decision thresholds.
    levels:      (n_codes,) LUT (KWN: 8-bit map-back values in integer MAC
                 units; NLD: f(x) samples).
    scale:       (NC,) per-column weight quantization scale.  Applied to the
                 winner drive after conversion in ``kwn`` mode (the ramp sees
                 integer-unit MACs); applied to the MAC before conversion in
                 ``nld`` mode (the activation ramp sees float-unit MACs).
    v:           (M, N) f32 initial membrane state (carried across T in
                 VMEM).
    noise:       (T, M, N) f32 pre-drawn per-step SNL noise, or None to
                 generate SNL noise in-kernel from the counter PRNG
                 (amplitude ``snl_amp``) — the noisy-silicon path streams
                 *nothing* per step.
    w_dend:      (J, N) soma combine weights (``nld`` only).
    bn:          column tile width (None = full NC width, single tile).
    n_valid:     number of real (non-padded) columns for the KWN sweep.
    ima_noise:   ``ima.IMAKernelNoise`` (static, hashable) enabling the
                 Fig. 7 conversion-error model at the ramp stage: per-step
                 per-column Gaussian draws are generated *inside* the kernel
                 by the counter PRNG (``core.ctrprng``), keyed on
                 ``(seed, step_offset + t, absolute row, logical column)``
                 so the stream is invariant to the launch tiling and
                 bitwise-reproducible by ``ref.fused_macro_seq_ref``.
                 (The hardware ``pltpu.prng_random_bits`` stream is *not*
                 used precisely because it has neither property.)
    snl_amp:     in-kernel SNL noise amplitude (used only when noise=None).
    logical_n:   unpadded per-branch column count — the counter's column
                 coordinate basis (defaults to the padded width).
    activity:    (T, M/bm, K/bk) int32 occupancy map (nonzero = block has at
                 least one event), or None for dense execution.  Delivered
                 to the kernel via scalar prefetch; all-zero activation
                 blocks skip the plane decode + MXU contraction, and the
                 KWN ramp sweep is bounded to the occupied code range — both
                 without changing any output bit (see module docstring).
    mac_telemetry: emit the raw (T, M, NC) integer-unit MAC stack to HBM
                 (True, the historical default — needed by calibration and
                 codebook studies).  False keeps the accumulator in VMEM
                 scratch: nothing but the per-step (spikes, mask,
                 adc_steps) leaves the kernel — the serving default — and
                 the returned mac is None.
    train_trace: additionally emit the per-step membrane trace vtrace
                 (T, M, N) — the post-saturation, pre-reset V_mem the spike
                 comparator reads.  This is the residual the surrogate
                 backward (``kernels.fused_macro_grad``) consumes: the
                 SuperSpike derivative and the saturation gradient gate are
                 both functions of it.  KWN mode only.
    seed:        traced int32 scalar keying both noise streams.
    step_offset: traced int32 added to the grid time index (lets the
                 per-step launch cadence keep the seq-identical stream).
    row_ctl:     optional (M, 3) int32 per-row stream control
                 ``[seed, step_offset, row_id]``.  When present it
                 *replaces* the scalar ``seed``/``step_offset`` and the
                 absolute-row counter coordinate for that row, so every
                 batch row replays an independent noise stream — e.g. the
                 continuous-batching engine gives each slot the
                 ``(seed, steps_done, 0)`` of its request and the slot's
                 draws match a batch-1 one-shot run bit-for-bit.

    Returns (mac (T, M, NC) f32 or None, v_out (M, N) f32,
    spikes (T, M, N) f32, mask (T, M, N) f32, adc_steps (T, M, 1) i32),
    plus a trailing vtrace (T, M, N) f32 element when ``train_trace``.
    """
    t_steps, m, kdim = x.shape
    kdim2, nc = msb.shape
    n = v.shape[-1]
    bn = nc if bn is None else bn
    n_valid = nc if n_valid is None else n_valid
    logical_n = (nc if mode == "kwn" else n) if logical_n is None else \
        logical_n
    assert kdim == kdim2 and msb.shape == lsb.shape
    assert m % bm == 0 and kdim % bk == 0 and nc % bn == 0, \
        (m, kdim, nc, bm, bk, bn)
    assert v.shape == (m, n)
    assert noise is None or noise.shape == (t_steps, m, n)
    n_codes = levels.shape[0]
    assert boundaries.shape[0] == n_codes - 1
    grid = (m // bm, t_steps, nc // bn, kdim // bk)
    n_i, n_j, n_k = grid[0], grid[2], grid[3]
    has_noise_ref = noise is not None
    gated = activity is not None
    if gated:
        assert activity.shape == (t_steps, n_i, n_k), \
            (activity.shape, (t_steps, n_i, n_k))

    # Index maps take a trailing scalar-prefetch ref on the gated path.
    row_spec = lambda shape: pl.BlockSpec(shape,
                                          lambda i, t, j, kk, *_: (i, 0))
    step_spec = lambda shape: pl.BlockSpec(
        shape, lambda i, t, j, kk, *_: (t, i, 0))
    const_spec = lambda shape: pl.BlockSpec(shape,
                                            lambda i, t, j, kk, *_: (0, 0))
    ctl = jnp.stack([jnp.asarray(seed, jnp.int32),
                     jnp.asarray(step_offset, jnp.int32)]).reshape(1, 2)
    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda i, t, j, kk, *_: (t, i, kk)),  # x
        pl.BlockSpec((bk, bn), lambda i, t, j, kk, *_: (kk, j)),        # msb
        pl.BlockSpec((bk, bn), lambda i, t, j, kk, *_: (kk, j)),        # lsb
        const_spec((1, n_codes - 1)),                                # bounds
        const_spec((1, n_codes)),                                    # levels
        const_spec((1, nc)),                                         # scale
        const_spec((1, 2)),                                          # ctl
    ]
    inputs = [x.astype(jnp.int8), msb.astype(jnp.int8), lsb.astype(jnp.int8),
              boundaries.astype(jnp.float32).reshape(1, -1),
              levels.astype(jnp.float32).reshape(1, -1),
              scale.astype(jnp.float32).reshape(1, -1),
              ctl]
    has_row_ctl = row_ctl is not None
    if has_row_ctl:
        assert row_ctl.shape == (m, 3), (row_ctl.shape, m)
        in_specs.append(row_spec((bm, 3)))                           # row_ctl
        inputs.append(row_ctl.astype(jnp.int32))

    if mode == "kwn":
        assert nc == n, (nc, n)
        kernel = functools.partial(
            _seq_kwn_kernel, ratio=ratio, bm=bm, bn=bn, n_i=n_i, n_j=n_j,
            n_k=n_k, n_valid=n_valid, k=k, n_codes=n_codes, beta=beta,
            v_th1=v_th1, v_th2=v_th2, v_reset=v_reset, v_lim=v_lim,
            use_snl=use_snl, drive_gain=drive_gain, ima_noise=ima_noise,
            snl_amp=snl_amp, logical_n=logical_n,
            has_noise_ref=has_noise_ref, gated=gated,
            mac_out=mac_telemetry, train_trace=train_trace,
            has_row_ctl=has_row_ctl)
    elif mode == "nld":
        assert not train_trace, "train_trace is KWN-only (silicon training)"
        assert w_dend is not None and nc % n == 0, (nc, n)
        n_branches = nc // n
        assert w_dend.shape == (n_branches, n)
        in_specs.append(const_spec((n_branches, n)))                 # w_dend
        inputs.append(w_dend.astype(jnp.float32))
        kernel = functools.partial(
            _seq_nld_kernel, ratio=ratio, bm=bm, bn=bn, n_i=n_i, n_j=n_j,
            n_k=n_k, n_codes=n_codes, n_branches=n_branches, beta=beta,
            v_th1=v_th1, v_th2=v_th2, v_reset=v_reset, v_lim=v_lim,
            drive_gain=drive_gain, ima_noise=ima_noise,
            logical_n=logical_n, has_noise_ref=has_noise_ref, gated=gated,
            mac_out=mac_telemetry, has_row_ctl=has_row_ctl)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    in_specs.append(row_spec((bm, n)))                               # v0
    inputs.append(v.astype(jnp.float32))
    if has_noise_ref:
        in_specs.append(step_spec((1, bm, n)))                       # noise
        inputs.append(noise.astype(jnp.float32))

    out_specs = [
        row_spec((bm, n)),                               # carried V_mem
        step_spec((1, bm, n)), step_spec((1, bm, n)),
        step_spec((1, bm, 1)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((t_steps, m, n), jnp.float32),
        jax.ShapeDtypeStruct((t_steps, m, n), jnp.float32),
        jax.ShapeDtypeStruct((t_steps, m, 1), jnp.int32),
    ]
    if train_trace:
        out_specs.append(step_spec((1, bm, n)))          # membrane trace
        out_shape.append(
            jax.ShapeDtypeStruct((t_steps, m, n), jnp.float32))
    scratch_shapes = []
    if mac_telemetry:
        out_specs.insert(0, step_spec((1, bm, nc)))      # mac telemetry
        out_shape.insert(0,
                         jax.ShapeDtypeStruct((t_steps, m, nc), jnp.float32))
    else:
        # accumulator never leaves VMEM: same footprint, zero HBM traffic
        scratch_shapes = [pltpu.VMEM((1, bm, nc), jnp.float32)]

    if gated:
        outs = pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
                out_specs=out_specs, scratch_shapes=scratch_shapes),
            out_shape=out_shape,
            interpret=interpret,
        )(activity.reshape(-1).astype(jnp.int32), *inputs)
    else:
        outs = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            interpret=interpret,
        )(*inputs)
    outs = list(outs)
    mac = outs.pop(0) if mac_telemetry else None
    if train_trace:
        v_out, spikes, mask, steps, vtrace = outs
        return mac, v_out, spikes, mask, steps, vtrace
    v_out, spikes, mask, steps = outs
    return mac, v_out, spikes, mask, steps


# ---------------------------------------------------------------------------
# Stacked multi-layer sequence kernel
# ---------------------------------------------------------------------------

class LayerSpec(NamedTuple):
    """Static per-layer geometry of the stacked sequence kernel.

    Hashable (jit-static).  ``k_dim`` is the input width this layer's weight
    planes see: for layer 0 that is the padded event width the launch
    streams; for deeper layers it is the *unpadded* previous layer's column
    count — inter-layer spikes live in registers, so the stacked kernel
    needs no column padding at all.  ``bk``/``bn`` are the in-kernel MAC
    tile sizes (static Python loops over ragged-tail slices): ``bk`` is
    also the occupancy-gating granularity, mirroring the single-layer
    kernel's (step, row-tile, K-tile) blocks.
    """

    k_dim: int     # input rows of this layer's weight planes
    n: int         # output columns (== NC; the KWN stack is unpadded)
    k: int         # KWN winner count for this layer
    bk: int        # K-tile size (gating granularity; ragged tail allowed)
    bn: int        # column-tile size of the in-kernel MAC loop

    @property
    def n_k(self) -> int:
        """Number of K tiles (occupancy words per (step, row-tile))."""
        return -(-self.k_dim // self.bk)


def _multi_seq_kwn_kernel(*refs, specs, ratio, bm, n_i, n_codes, beta,
                          v_th1, v_th2, v_reset, v_lim, use_snl, drive_gain,
                          ima_noise, snl_amp, has_noise, gated):
    """L stacked KWN macro layers per (row-tile, time-step) grid step.

    The inter-layer ternary spike tensor never exists outside this kernel
    body: layer l's spike output is a register value fed straight into
    layer l+1's MAC.  Per-layer membranes are carried in VMEM output
    blocks across the whole T axis; per-layer weight planes are
    const-indexed full-array refs (layer-stationary — staged once for the
    launch, resident across every time step).

    Gating: layer 0 consumes the scalar-prefetched host occupancy map
    (events are host-visible, so the host plans them, exactly like the
    single-layer kernel); for layer l > 0 the previous layer's winner set
    IS the activity plan — occupancy of each K tile is computed *in
    kernel* from the register-resident spikes (``jnp.any(tile != 0)``),
    and all-zero tiles skip the plane decode + MXU contraction.  Skipped
    blocks contribute exactly-zero partials, so gating is bitwise-neutral
    (same argument as ``_accumulate_mac_tile``).  The per-layer occupied-
    block counts are emitted as telemetry — the multi-layer occupancy map
    leaves the kernel as counters, not as spike tensors.
    """
    refs = list(refs)
    occ_ref = refs.pop(0) if gated else None
    x_ref = refs.pop(0)
    ctl_ref = refs.pop(0)
    n_layers = len(specs)
    w_refs = [tuple(refs.pop(0) for _ in range(5)) for _ in range(n_layers)]
    v0_refs = [refs.pop(0) for _ in range(n_layers)]
    noise_refs = [refs.pop(0) if has_noise else None for _ in range(n_layers)]
    v_refs = refs[:n_layers]
    spike_ref, mask_ref = refs[n_layers], refs[n_layers + 1]
    steps_refs = refs[n_layers + 2:2 * n_layers + 2]
    cnt_refs = refs[2 * n_layers + 2:3 * n_layers + 2]
    occn_refs = refs[3 * n_layers + 2:4 * n_layers + 2]

    i, t = pl.program_id(0), pl.program_id(1)
    row0 = i * bm
    step = ctl_ref[0, n_layers] + t

    @pl.when(t == 0)
    def _load_membranes():
        for li in range(n_layers):
            v_refs[li][...] = v0_refs[li][...]

    cur = x_ref[0].astype(jnp.float32)                    # (bm, k_dim_0)
    last_mask = None
    for li, spec in enumerate(specs):
        msb_ref, lsb_ref, bounds_ref, levels_ref, scale_ref = w_refs[li]
        seed = ctl_ref[0, li]
        n_occ = jnp.int32(0 if gated else spec.n_k)
        tiles = []
        for j0 in range(0, spec.n, spec.bn):
            jw = min(spec.bn, spec.n - j0)
            acc = jnp.zeros((bm, jw), jnp.float32)
            for kk, k0 in enumerate(range(0, spec.k_dim, spec.bk)):
                kw = min(spec.bk, spec.k_dim - k0)
                xt = cur[:, k0:k0 + kw]

                def _part(a, xt=xt, k0=k0, kw=kw, j0=j0, jw=jw,
                          msb_ref=msb_ref, lsb_ref=lsb_ref):
                    w = (ratio
                         * msb_ref[k0:k0 + kw, j0:j0 + jw].astype(jnp.float32)
                         + lsb_ref[k0:k0 + kw,
                                   j0:j0 + jw].astype(jnp.float32))
                    return a + jax.lax.dot_general(
                        xt, w, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32)

                if not gated:
                    acc = _part(acc)
                else:
                    if li == 0:       # host-planned occupancy (events)
                        occ = occ_ref[(t * n_i + i) * spec.n_k + kk]
                    else:             # in-kernel: winners ARE the plan
                        occ = jnp.any(xt != 0).astype(jnp.int32)
                    acc = jax.lax.cond(occ > 0, _part, lambda a: a, acc)
                    if j0 == 0:       # occupancy is a K-tile property
                        n_occ = n_occ + (occ > 0).astype(jnp.int32)
            tiles.append(acc)
        mac = tiles[0] if len(tiles) == 1 else jnp.concatenate(tiles, -1)
        codes = _ramp_codes(mac, bounds_ref[...][0])
        if ima_noise is not None:
            codes = _ima_noisy_codes(codes, mac, seed, step, row0=row0,
                                     per_branch=spec.n, logical_n=spec.n,
                                     ima_noise=ima_noise, n_codes=n_codes)
        maskf, steps = _kwn_sweep(codes, spec.k, n_codes, bounded=gated)
        recon = _lut_reconstruct(codes, levels_ref[...][0], n_codes)
        drive = recon * scale_ref[...] * maskf * drive_gain
        nz = _lif_noise(noise_refs[li], (bm, spec.n), seed, step, row0=row0,
                        logical_n=spec.n, snl_amp=snl_amp, use_snl=use_snl)
        v_new, spike, _ = _lif_update(
            v_refs[li][...], drive, maskf, nz, beta=beta, v_th1=v_th1,
            v_th2=v_th2, v_reset=v_reset, v_lim=v_lim, use_snl=use_snl)
        v_refs[li][...] = v_new
        steps_refs[li][0] = steps
        cnt_refs[li][0] = jnp.sum(spike, axis=-1, keepdims=True)
        occn_refs[li][...] = jnp.reshape(n_occ, (1, 1, 1))
        last_mask = maskf
        cur = spike                   # register hand-off to the next layer
    spike_ref[0] = cur
    mask_ref[0] = last_mask


@functools.partial(jax.jit, static_argnames=(
    "specs", "ratio", "drive_gain", "use_snl", "bm", "ima_noise",
    "snl_amp", "has_noise", "gated", "interpret") + _LIF_STATICS)
def fused_macro_multi_seq(x: jax.Array, planes, v0s, noises=None,
                          activity: jax.Array | None = None, ctl=None, *,
                          specs: tuple, ratio: float = 2.0,
                          drive_gain: float = 1.0, beta: float = 0.9,
                          v_th1: float = 1.0, v_th2: float = 0.6,
                          v_reset: float = 0.0, v_lim: float = 8.0,
                          use_snl: bool = True, bm: int = DEFAULT_BM,
                          ima_noise=None, snl_amp: float = 0.0,
                          has_noise: bool = False, gated: bool = False,
                          interpret: bool = True):
    """L stacked KWN macro layers over a whole event sequence, one launch.

    x:       (T, M, K0) int8 ternary events (K0 padded to layer 0's K
             tiling; M padded to ``bm``).
    planes:  per-layer (msb, lsb, boundaries, levels, scale) tuples; the
             int8 twin-cell planes are (k_dim_l, n_l) *unpadded* for
             l > 0 (inter-layer spikes never leave registers, so the
             stacked kernel needs no column padding).
    v0s:     per-layer (M, n_l) f32 initial membranes.
    noises:  per-layer (T, M, n_l) pre-drawn SNL noise (clean-path PRBS
             parity) when ``has_noise``; None for in-kernel counter noise.
    activity: (T, M/bm, K0/bk0) int32 layer-0 occupancy map when
             ``gated`` (scalar-prefetched).  Deeper layers gate on the
             in-kernel winner sets — no host map exists for them.
    ctl:     (1, L+1) int32: per-layer counter seeds + the step offset.
    specs:   tuple of ``LayerSpec`` (static per-layer geometry).

    Returns (v_outs (per-layer (M, n_l)), spikes (T, M, n_L) — the FINAL
    layer only, mask (T, M, n_L), steps (per-layer (T, M, 1) i32),
    counts (per-layer (T, M, 1) f32 row-wise spike counts — the telemetry
    stand-in for the deep spike tensors that never reach HBM),
    occupancy (per-layer (T, M/bm, 1) i32 occupied-K-tile counts)).
    """
    t_steps, m, kdim = x.shape
    n_layers = len(specs)
    assert kdim == specs[0].k_dim and m % bm == 0, (x.shape, specs[0], bm)
    n_codes = planes[0][3].shape[-1]
    n_i = m // bm
    if gated:
        assert activity.shape == (t_steps, n_i, specs[0].n_k), \
            (activity.shape, (t_steps, n_i, specs[0].n_k))

    row_spec = lambda shape: pl.BlockSpec(shape, lambda i, t, *_: (i, 0))
    step_spec = lambda shape: pl.BlockSpec(shape, lambda i, t, *_: (t, i, 0))
    const_spec = lambda shape: pl.BlockSpec(
        shape, lambda i, t, *_: (0,) * len(shape))
    if ctl is None:
        ctl = jnp.zeros((1, n_layers + 1), jnp.int32)

    in_specs = [
        pl.BlockSpec((1, bm, kdim), lambda i, t, *_: (t, i, 0)),      # x
        const_spec((1, n_layers + 1)),                                # ctl
    ]
    inputs = [x.astype(jnp.int8), ctl.astype(jnp.int32)]
    for spec, (msb, lsb, bounds, levels, scale) in zip(specs, planes):
        assert msb.shape == (spec.k_dim, spec.n), (msb.shape, spec)
        in_specs += [const_spec((spec.k_dim, spec.n)),
                     const_spec((spec.k_dim, spec.n)),
                     const_spec((1, n_codes - 1)),
                     const_spec((1, n_codes)),
                     const_spec((1, spec.n))]
        inputs += [msb.astype(jnp.int8), lsb.astype(jnp.int8),
                   bounds.astype(jnp.float32).reshape(1, -1),
                   levels.astype(jnp.float32).reshape(1, -1),
                   scale.astype(jnp.float32).reshape(1, -1)]
    for spec, v0 in zip(specs, v0s):
        assert v0.shape == (m, spec.n), (v0.shape, spec)
        in_specs.append(row_spec((bm, spec.n)))
        inputs.append(v0.astype(jnp.float32))
    if has_noise:
        for spec, nz in zip(specs, noises):
            assert nz.shape == (t_steps, m, spec.n), (nz.shape, spec)
            in_specs.append(step_spec((1, bm, spec.n)))
            inputs.append(nz.astype(jnp.float32))

    n_last = specs[-1].n
    out_specs = [row_spec((bm, spec.n)) for spec in specs]            # v
    out_shape = [jax.ShapeDtypeStruct((m, spec.n), jnp.float32)
                 for spec in specs]
    out_specs += [step_spec((1, bm, n_last)), step_spec((1, bm, n_last))]
    out_shape += [jax.ShapeDtypeStruct((t_steps, m, n_last), jnp.float32),
                  jax.ShapeDtypeStruct((t_steps, m, n_last), jnp.float32)]
    out_specs += [step_spec((1, bm, 1)) for _ in specs]               # steps
    out_shape += [jax.ShapeDtypeStruct((t_steps, m, 1), jnp.int32)
                  for _ in specs]
    out_specs += [step_spec((1, bm, 1)) for _ in specs]               # counts
    out_shape += [jax.ShapeDtypeStruct((t_steps, m, 1), jnp.float32)
                  for _ in specs]
    out_specs += [pl.BlockSpec((1, 1, 1), lambda i, t, *_: (t, i, 0))
                  for _ in specs]                                     # occ
    out_shape += [jax.ShapeDtypeStruct((t_steps, n_i, 1), jnp.int32)
                  for _ in specs]

    kernel = functools.partial(
        _multi_seq_kwn_kernel, specs=specs, ratio=ratio, bm=bm, n_i=n_i,
        n_codes=n_codes, beta=beta, v_th1=v_th1, v_th2=v_th2,
        v_reset=v_reset, v_lim=v_lim, use_snl=use_snl,
        drive_gain=drive_gain, ima_noise=ima_noise, snl_amp=snl_amp,
        has_noise=has_noise, gated=gated)
    if gated:
        outs = pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1, grid=(n_i, t_steps),
                in_specs=in_specs, out_specs=out_specs),
            out_shape=out_shape,
            interpret=interpret,
        )(activity.reshape(-1).astype(jnp.int32), *inputs)
    else:
        outs = pl.pallas_call(
            kernel,
            grid=(n_i, t_steps),
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(*inputs)
    outs = list(outs)
    v_outs = tuple(outs[:n_layers])
    spikes, mask = outs[n_layers], outs[n_layers + 1]
    steps = tuple(outs[n_layers + 2:2 * n_layers + 2])
    counts = tuple(outs[2 * n_layers + 2:3 * n_layers + 2])
    occupancy = tuple(outs[3 * n_layers + 2:4 * n_layers + 2])
    return v_outs, spikes, mask, steps, counts, occupancy


def fused_macro_step(x: jax.Array, msb: jax.Array, lsb: jax.Array,
                     boundaries: jax.Array, levels: jax.Array,
                     scale: jax.Array, v: jax.Array,
                     noise: jax.Array | None = None,
                     w_dend: jax.Array | None = None,
                     activity: jax.Array | None = None, *,
                     mode: str = "kwn", k: int = 12, ratio: float = 2.0,
                     drive_gain: float = 1.0, beta: float = 0.9,
                     v_th1: float = 1.0, v_th2: float = 0.6,
                     v_reset: float = 0.0, v_lim: float = 8.0,
                     use_snl: bool = True, bm: int = DEFAULT_BM,
                     bk: int = DEFAULT_BK, bn: int | None = None,
                     n_valid: int | None = None, ima_noise=None,
                     snl_amp: float = 0.0, logical_n: int | None = None,
                     mac_telemetry: bool = True,
                     seed=0, step_offset=0, interpret: bool = True):
    """One fused macro time step: the T=1 degenerate of ``fused_macro_seq``.

    x (M, K), v/noise (M, N), activity (M/bm, K/bk); returns (mac (M, NC)
    or None, v_out, spikes, mask, adc_steps (M, 1)) exactly like the PR 1
    single-step kernel.  With ``ima_noise``, pass the scan index as
    ``step_offset`` to reproduce the one-launch sequence stream exactly.
    """
    mac, v_out, spikes, mask, steps = fused_macro_seq(
        x[None], msb, lsb, boundaries, levels, scale, v,
        None if noise is None else noise[None], w_dend,
        None if activity is None else activity[None],
        mode=mode, k=k, ratio=ratio, drive_gain=drive_gain, beta=beta,
        v_th1=v_th1, v_th2=v_th2, v_reset=v_reset, v_lim=v_lim,
        use_snl=use_snl, bm=bm, bk=bk, bn=bn, n_valid=n_valid,
        ima_noise=ima_noise, snl_amp=snl_amp, logical_n=logical_n,
        mac_telemetry=mac_telemetry,
        seed=seed, step_offset=step_offset, interpret=interpret)
    return (None if mac is None else mac[0], v_out, spikes[0], mask[0],
            steps[0])
