"""Pallas TPU kernel: the surrogate backward of the fused macro sequence.

This is the first backward-capable kernel in the repo: the time-reversed
BPTT pass of ``kernels.fused_macro.fused_macro_seq`` (KWN mode), so silicon
training runs its gradient step on the same tile plans — and the same
activity gating — the serving forward uses.  The gradient *semantics* are
defined by ``kernels.ref.fused_macro_seq_vjp_ref`` (the differentiable
oracle); this kernel must match ``jax.grad`` of that oracle.

Backward dataflow
-----------------
The forward LIF recurrence couples time steps per column; the MAC couples
columns per time step.  Given the forward residuals, the backward therefore
factors into

  1. an elementwise cotangent chain per (step, row-tile, col-tile) —
     SuperSpike surrogate through the spike comparator, hard cut at the
     V_mem saturation rails, the winner/loser leak-vs-hold split — feeding
     the reverse-time membrane cotangent ``g_v`` (carried in VMEM across
     the whole reversed T axis, exactly like the forward membrane);
  2. one MXU contraction per step: ``dW += x_t^T @ g_mac_t``, where
     ``g_mac`` is the elementwise chain's output gated by the (relaxed) KWN
     winner mask and the IMA ramp's straight-through window.

Grid is ``(M/bm, T, NC/bn)`` with the *time index maps reversed*
(grid step t reads forward step T-1-t), so the cotangent recurrence walks
the sequence backwards in one launch.  ``dW`` lives as a single
full-(K, NC) output block with a constant index map — revisited at every
grid step, so accumulation is pipeline-safe — which puts the VMEM ceiling
at ``4*K*NC`` bytes (512 KB for the 512x256 bench layer; layers beyond
~2-4 MB of weight gradient should split at the model layer, same ceiling
family as the forward head's one-hot transient).

Residual-vs-recompute policy
----------------------------
The elementwise chain needs the per-step membrane trace (``vtrace``, a new
opt-in forward output) and winner masks; the ramp's straight-through window
needs the *clean analog MAC*.  Two ways to get the MAC:

  * **residual** (default): the forward saves the (T, M, NC) MAC stack
    (``mac_telemetry=True``) and the backward streams it — one extra HBM
    tensor, no extra compute;
  * **recompute** (``mac`` absent, ``msb/lsb`` given): the backward re-runs
    the ternary MAC per (step, col-tile) on the MXU — the right trade when
    the residual stack would not fit (long sequences / wide layers), and
    exactly bitwise-equal to the residual because the MAC is small exact
    integers (associativity-free in f32), so the two policies produce
    *identical* gradients, not merely close ones.

Activity gating rides along: the reverse pass always runs the (cheap)
elementwise chain — the cotangent recurrence does not stop when events do —
but skips both MAC contractions for row-tile time steps whose forward
activity map is empty (an all-zero ``x_t`` block contributes exactly zero
to ``dW``), so sparse event streams train as cheaply as they serve.

Noise needs no special handling here: the Fig. 7 draws and the SNL kicks
shape the residuals (masks, membrane trace) in the forward, and the
straight-through tangent rides the clean MAC — so one backward kernel
serves the clean and the counter-PRNG noisy forward alike, and noisy
gradients are exactly reproducible from the forward seed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _seq_kwn_bwd_kernel(*refs, n_t, n_i, bn, ratio, drive_gain, beta, v_th1,
                        v_lim, kwn_relax, surrogate_beta, ste_lo, ste_hi,
                        has_mac, remat, gated):
    """One grid step: reversed time index ``ti`` -> forward step T-1-ti.

    Ref order is (scalar prefetch), inputs, outputs:
    ``[occ?] x scale g_vfin vtrace mask g_spk [mac?] [msb lsb?] dw dv0``.
    """
    refs = list(refs)
    occ_ref = refs.pop(0) if gated else None
    x_ref, scale_ref, g_vfin_ref, vtrace_ref, mask_ref, g_spk_ref = refs[:6]
    refs = refs[6:]
    mac_ref = refs.pop(0) if has_mac else None
    if remat:
        msb_ref, lsb_ref = refs.pop(0), refs.pop(0)
    dw_ref, dv0_ref = refs

    i, ti, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    t_fwd = (n_t - 1) - ti
    rows = pl.dslice(None)
    col = pl.dslice(j * bn, bn)

    @pl.when((i == 0) & (ti == 0) & (j == 0))
    def _zero_dw():
        dw_ref[...] = jnp.zeros(dw_ref.shape, jnp.float32)

    @pl.when(ti == 0)
    def _seed_carry():                       # g_v(T) = cotangent of v_out
        pl.store(dv0_ref, (rows, col), pl.load(g_vfin_ref, (rows, col)))

    # --- elementwise cotangent chain (always runs: g_v must flow) --------
    g_v = pl.load(dv0_ref, (rows, col))
    vt = vtrace_ref[0]                       # pre-reset saturated membrane
    m = mask_ref[0]
    spk = (vt >= v_th1).astype(jnp.float32)
    arg = surrogate_beta * (vt - v_th1)
    sg_spk = surrogate_beta / (1.0 + jnp.abs(arg)) ** 2   # SuperSpike
    g_vclip = g_v * (1.0 - spk) + g_spk_ref[0] * sg_spk
    inside = (jnp.abs(vt) < v_lim).astype(jnp.float32)    # rail cut
    g_v2 = g_vclip * inside                  # SNL add is grad-transparent
    pl.store(dv0_ref, (rows, col),
             g_v2 * (m * beta + (1.0 - m)))  # winners leak, losers hold

    # --- dW contraction (activity-gated: empty x_t blocks contribute 0) --
    def _contract():
        xf = x_ref[0].astype(jnp.float32)    # (bm, K)
        if mac_ref is not None:
            mac_t = mac_ref[0]
        else:                                # recompute: exact-int MAC
            wt = ratio * msb_ref[...].astype(jnp.float32) \
                + lsb_ref[...].astype(jnp.float32)
            mac_t = jax.lax.dot_general(
                xf, wt, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        in_ramp = ((mac_t >= ste_lo) & (mac_t <= ste_hi)) \
            .astype(jnp.float32)             # IMA straight-through window
        gate = m + kwn_relax * (1.0 - m)     # relaxed hard KWN gate
        g_mac = g_v2 * gate * scale_ref[...] * drive_gain * in_ramp
        part = jax.lax.dot_general(          # x_t^T @ g_mac: (K, bn)
            xf, g_mac, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        pl.store(dw_ref, (rows, col), pl.load(dw_ref, (rows, col)) + part)

    if gated:
        occ = occ_ref[t_fwd * n_i + i]

        @pl.when(occ > 0)
        def _gated_contract():
            _contract()
    else:
        _contract()


@functools.partial(jax.jit, static_argnames=(
    "ratio", "drive_gain", "beta", "v_th1", "v_lim", "kwn_relax",
    "surrogate_beta", "ste_lo", "ste_hi", "bm", "bn", "interpret"))
def fused_macro_seq_grad(x: jax.Array, scale: jax.Array, g_spk: jax.Array,
                         g_vfin: jax.Array, vtrace: jax.Array,
                         mask: jax.Array, mac: jax.Array | None = None,
                         msb: jax.Array | None = None,
                         lsb: jax.Array | None = None,
                         activity: jax.Array | None = None, *,
                         ratio: float = 2.0, drive_gain: float = 1.0,
                         beta: float = 0.9, v_th1: float = 1.0,
                         v_lim: float = 8.0, kwn_relax: float = 0.0,
                         surrogate_beta: float = 4.0,
                         ste_lo: float = -24.5, ste_hi: float = 24.5,
                         bm: int = 128, bn: int | None = None,
                         interpret: bool = True):
    """The fused surrogate backward: padded operands, one launch.

    x:        (T, M, K) int8 ternary inputs (the forward's, padded).
    scale:    (1, NC) per-column weight scale (padded columns zero — they
              self-mask out of ``dW``).
    g_spk:    (T, M, N) f32 cotangent of the per-step spike stack.
    g_vfin:   (M, N) f32 cotangent of the final membrane.
    vtrace:   (T, M, N) f32 membrane trace (forward ``train_trace`` output).
    mask:     (T, M, N) f32 KWN winner masks (forward output).
    mac:      (T, M, NC) f32 clean integer-unit MAC residual, or None to
              recompute it from ``msb``/``lsb`` (the remat policy — exactly
              gradient-identical, see module docstring).
    activity: (T, M/bm) int32 row-tile occupancy (any K-tile active), or
              None for dense execution.  Scalar-prefetched; empty blocks
              skip both MXU contractions.

    Returns (dw (K, NC) f32, dv0 (M, N) f32): the cotangents of the
    integer-unit weight and the initial membrane.
    """
    t_steps, m, kdim = x.shape
    n = vtrace.shape[-1]
    bn = n if bn is None else bn
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    assert g_spk.shape == (t_steps, m, n) and vtrace.shape == g_spk.shape
    assert mask.shape == g_spk.shape and g_vfin.shape == (m, n)
    has_mac = mac is not None
    remat = not has_mac
    if remat:
        assert msb is not None and lsb is not None
        assert msb.shape == (kdim, n) and lsb.shape == (kdim, n)
    else:
        assert mac.shape == (t_steps, m, n), (mac.shape,)
    gated = activity is not None
    n_i = m // bm
    if gated:
        assert activity.shape == (t_steps, n_i), (activity.shape,)
    grid = (n_i, t_steps, n // bn)
    rev = t_steps - 1

    in_specs = [
        pl.BlockSpec((1, bm, kdim), lambda i, t, j, *_: (rev - t, i, 0)),
        pl.BlockSpec((1, bn), lambda i, t, j, *_: (0, j)),          # scale
        pl.BlockSpec((bm, n), lambda i, t, j, *_: (i, 0)),          # g_vfin
        pl.BlockSpec((1, bm, bn), lambda i, t, j, *_: (rev - t, i, j)),
        pl.BlockSpec((1, bm, bn), lambda i, t, j, *_: (rev - t, i, j)),
        pl.BlockSpec((1, bm, bn), lambda i, t, j, *_: (rev - t, i, j)),
    ]
    inputs = [x.astype(jnp.int8), scale.astype(jnp.float32).reshape(1, -1),
              g_vfin.astype(jnp.float32), vtrace, mask, g_spk]
    if has_mac:
        in_specs.append(
            pl.BlockSpec((1, bm, bn), lambda i, t, j, *_: (rev - t, i, j)))
        inputs.append(mac)
    else:
        in_specs += [pl.BlockSpec((kdim, bn), lambda i, t, j, *_: (0, j)),
                     pl.BlockSpec((kdim, bn), lambda i, t, j, *_: (0, j))]
        inputs += [msb.astype(jnp.int8), lsb.astype(jnp.int8)]

    out_specs = [
        pl.BlockSpec((kdim, n), lambda i, t, j, *_: (0, 0)),        # dw
        pl.BlockSpec((bm, n), lambda i, t, j, *_: (i, 0)),          # dv0
    ]
    out_shape = [jax.ShapeDtypeStruct((kdim, n), jnp.float32),
                 jax.ShapeDtypeStruct((m, n), jnp.float32)]

    kernel = functools.partial(
        _seq_kwn_bwd_kernel, n_t=t_steps, n_i=n_i, bn=bn, ratio=ratio,
        drive_gain=drive_gain, beta=beta, v_th1=v_th1, v_lim=v_lim,
        kwn_relax=kwn_relax, surrogate_beta=surrogate_beta, ste_lo=ste_lo,
        ste_hi=ste_hi, has_mac=has_mac, remat=remat, gated=gated)

    if gated:
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
                out_specs=out_specs),
            out_shape=out_shape,
            interpret=interpret,
        )(activity.reshape(-1).astype(jnp.int32), *inputs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)
