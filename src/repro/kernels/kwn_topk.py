"""Pallas TPU kernel: KWN descending-ramp top-K with early stop (paper C3).

Implements the hardware algorithm literally: codes are swept from the highest
ramp level downward; columns whose quantized MAC equals the level "cross" and
are admitted in priority-encoder (index) order until K winners are found.  The
step index at which the K-th winner appears is the early-stop ADC cycle count
— emitted per row so the latency/energy model consumes *measured* statistics.

Block layout: rows tile over the grid; the lane dimension is the macro's 128
columns (one physical macro per block).  The level sweep is a fori_loop of
n_codes iterations (32 for the 5-bit IMA) over VREG-resident state — no HBM
traffic inside the sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128


def _kwn_kernel(mac_ref, bounds_ref, mask_ref, steps_ref, *, k: int,
                n_codes: int):
    mac = mac_ref[...]                                    # (bm, N)
    bounds = bounds_ref[...]                              # (1, n_codes-1)
    bm, n = mac.shape

    # Ramp conversion: code = #boundaries below the value.
    codes = jnp.sum((mac[:, :, None] > bounds[0][None, None, :]),
                    axis=-1).astype(jnp.int32)            # (bm, N)

    def sweep(step, carry):
        n_found, mask, steps = carry
        level = n_codes - 1 - step                        # descending ramp
        crossing = (codes == level) & (mask == 0)
        order = jnp.cumsum(crossing.astype(jnp.int32), axis=-1)
        admit = crossing & ((n_found + order) <= k)       # priority encoder
        mask = mask + admit.astype(jnp.int32)
        n_found = n_found + jnp.sum(admit.astype(jnp.int32), axis=-1,
                                    keepdims=True)
        # Early stop: record the first step where K winners exist.
        done_now = (n_found >= k) & (steps < 0)
        steps = jnp.where(done_now, step, steps)
        return n_found, mask, steps

    init = (jnp.zeros((bm, 1), jnp.int32), jnp.zeros((bm, n), jnp.int32),
            jnp.full((bm, 1), -1, jnp.int32))
    n_found, mask, steps = jax.lax.fori_loop(0, n_codes, sweep, init)
    steps = jnp.where(steps < 0, n_codes - 1, steps)

    mask_ref[...] = mask.astype(jnp.float32)
    steps_ref[...] = steps


@functools.partial(jax.jit, static_argnames=("k", "bm", "interpret"))
def kwn_topk(mac: jax.Array, boundaries: jax.Array, k: int,
             bm: int = DEFAULT_BM, interpret: bool = True):
    """mac: (M, N) f32; boundaries: (n_codes-1,) -> (mask (M,N) f32,
    adc_steps (M,1) i32).  M must be a multiple of bm; N is the macro width.
    """
    m, n = mac.shape
    assert m % bm == 0, (m, bm)
    n_codes = boundaries.shape[0] + 1
    grid = (m // bm,)

    return pl.pallas_call(
        functools.partial(_kwn_kernel, k=k, n_codes=n_codes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n_codes - 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.int32),
        ],
        interpret=interpret,
    )(mac, boundaries.reshape(1, -1))
