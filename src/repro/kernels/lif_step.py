"""Pallas TPU kernel: fused digital LIF step (paper C5, Eq. 1).

Fuses the silicon's 3-stage pipeline (leak -> update -> compare) plus the SNL
probabilistic-firing path into a single VMEM pass: one read of (v, drive,
mask, noise), one write of (v', spike).  Unfused, this chain is 4 HBM reads +
4 intermediate writes; fused it is memory-optimal (the LIF is purely
bandwidth-bound, so the fusion is the entire win).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256


def _lif_kernel(v_ref, drive_ref, mask_ref, noise_ref, v_out_ref, spike_ref,
                *, beta: float, v_th1: float, v_th2: float, v_reset: float,
                v_lim: float, use_snl: bool):
    v = v_ref[...]
    drive = drive_ref[...]
    mask = mask_ref[...]

    # Eq. (1): winners leak+integrate, non-winners hold.
    v_new = jnp.where(mask > 0, beta * v + drive, v)

    if use_snl:
        # SNL: neurons sitting in (v_th2, v_th1) get the PRBS kick.
        noise = noise_ref[...]
        snl = (v_new > v_th2) & (v_new < v_th1)
        v_new = jnp.where(snl, v_new + noise, v_new)

    v_new = jnp.clip(v_new, -v_lim, v_lim)      # 12-bit register saturation
    spike = (v_new >= v_th1).astype(jnp.float32)
    v_out_ref[...] = jnp.where(spike > 0, v_reset, v_new)
    spike_ref[...] = spike


@functools.partial(jax.jit, static_argnames=("beta", "v_th1", "v_th2",
                                             "v_reset", "v_lim", "use_snl",
                                             "bm", "interpret"))
def lif_step_fused(v: jax.Array, drive: jax.Array, mask: jax.Array,
                   noise: jax.Array, beta: float = 0.9, v_th1: float = 1.0,
                   v_th2: float = 0.6, v_reset: float = 0.0,
                   v_lim: float = 8.0, use_snl: bool = True,
                   bm: int = DEFAULT_BM, interpret: bool = True):
    """All inputs (M, N) f32; returns (v_out, spikes), both (M, N) f32."""
    m, n = v.shape
    assert m % bm == 0, (m, bm)
    grid = (m // bm,)
    spec = pl.BlockSpec((bm, n), lambda i: (i, 0))

    return pl.pallas_call(
        functools.partial(_lif_kernel, beta=beta, v_th1=v_th1, v_th2=v_th2,
                          v_reset=v_reset, v_lim=v_lim, use_snl=use_snl),
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((m, n), jnp.float32),
                   jax.ShapeDtypeStruct((m, n), jnp.float32)],
        interpret=interpret,
    )(v, drive, mask, noise)
