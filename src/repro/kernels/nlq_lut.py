"""Pallas TPU kernel: NLQ ramp conversion + LUT map-back (paper C2/C6).

The IMA's nonlinear ramp is a monotone boundary set; conversion is a compare-
and-count against the (n_codes-1) boundaries, and the KWN-mode LUT map-back is
a gather from the level table.  TPU adaptation: the boundary compare is a
broadcast over the 32-entry codebook held in VMEM (VREG-resident after first
use) and the LUT gather becomes a one-hot matmul — gathers are slow on the
VPU, but a (bm, 128, 32) one-hot contraction with a (32,) table hits the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256


def _nlq_kernel(x_ref, bounds_ref, levels_ref, code_ref, y_ref, *,
                n_codes: int):
    x = x_ref[...]                                     # (bm, N)
    bounds = bounds_ref[...][0]                        # (n_codes-1,)
    levels = levels_ref[...][0]                        # (n_codes,)

    # Ramp conversion: count boundaries crossed (ripple counter).
    code = jnp.sum((x[:, :, None] > bounds[None, None, :]), axis=-1
                   ).astype(jnp.int32)                 # (bm, N)

    # LUT map-back as one-hot (MXU-friendly; no VPU gather).
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_codes), 2)
    onehot = (code[:, :, None] == iota).astype(jnp.float32)
    y = jnp.sum(onehot * levels[None, None, :], axis=-1)

    code_ref[...] = code
    y_ref[...] = y


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def nlq_convert(x: jax.Array, boundaries: jax.Array, levels: jax.Array,
                bm: int = DEFAULT_BM, interpret: bool = True):
    """x: (M, N) f32 -> (codes (M,N) i32, reconstruction (M,N) f32)."""
    m, n = x.shape
    assert m % bm == 0, (m, bm)
    n_codes = levels.shape[0]
    grid = (m // bm,)

    return pl.pallas_call(
        functools.partial(_nlq_kernel, n_codes=n_codes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n_codes - 1), lambda i: (0, 0)),
            pl.BlockSpec((1, n_codes), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, boundaries.reshape(1, -1), levels.reshape(1, -1))
