"""jit'd public wrappers for the Pallas kernels: padding, dtype handling, and
the interpret-mode switch (CPU validation vs TPU execution).

`INTERPRET` defaults to True because this container is CPU-only; on real TPU
hardware set ``repro.kernels.ops.INTERPRET = False`` (or pass interpret=False).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ternary as _ternary
from repro.kernels import fused_macro as _fused
from repro.kernels import fused_macro_grad as _fused_grad
from repro.kernels import kwn_topk as _kwn
from repro.kernels import lif_step as _lif
from repro.kernels import nlq_lut as _nlq
from repro.kernels import ternary_mac as _tmac

INTERPRET = True


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def ternary_mac(x: jax.Array, msb: jax.Array, lsb: jax.Array,
                ratio: float = 2.0, bm: int | None = None,
                bn: int | None = None, bk: int | None = None) -> jax.Array:
    """Batched ternary MAC; x may have leading batch dims. Pads to tiles."""
    lead = x.shape[:-1]
    xm = x.reshape(-1, x.shape[-1])
    bm_, bn_, bk_ = (bm or min(128, _ceil_mult(xm.shape[0], 8)),
                     bn or 128, bk or 256)
    bm_ = min(bm_, 128)
    xm, m0 = _pad_to(xm, 0, bm_)
    xm, k0 = _pad_to(xm, 1, bk_)
    msb_p, _ = _pad_to(msb, 0, bk_)
    msb_p, n0 = _pad_to(msb_p, 1, bn_)
    lsb_p, _ = _pad_to(lsb, 0, bk_)
    lsb_p, _ = _pad_to(lsb_p, 1, bn_)
    out = _tmac.ternary_mac(xm.astype(jnp.int8), msb_p.astype(jnp.int8),
                            lsb_p.astype(jnp.int8), bm=bm_, bn=bn_, bk=bk_,
                            ratio=ratio, interpret=INTERPRET)
    return out[:m0, :n0].reshape(*lead, n0)


def _ceil_mult(n: int, m: int) -> int:
    return max(m, ((n + m - 1) // m) * m)


def kwn_topk(mac: jax.Array, boundaries: jax.Array, k: int):
    """Batched KWN; mac (..., N) -> (mask (..., N), adc_steps (...,))."""
    lead = mac.shape[:-1]
    xm = mac.reshape(-1, mac.shape[-1]).astype(jnp.float32)
    bm = min(128, _ceil_mult(xm.shape[0], 8))
    xm, m0 = _pad_to(xm, 0, bm)
    mask, steps = _kwn.kwn_topk(xm, boundaries.astype(jnp.float32), k, bm=bm,
                                interpret=INTERPRET)
    return (mask[:m0].reshape(*lead, mac.shape[-1]),
            steps[:m0, 0].reshape(lead))


def lif_step(v, drive, mask, noise, **params):
    """Batched fused LIF; all (..., N)."""
    lead = v.shape[:-1]
    n = v.shape[-1]
    flat = [a.reshape(-1, n).astype(jnp.float32) for a in (v, drive, mask, noise)]
    bm = min(256, _ceil_mult(flat[0].shape[0], 8))
    padded = []
    m0 = flat[0].shape[0]
    for a in flat:
        a, _ = _pad_to(a, 0, bm)
        padded.append(a)
    v_out, spikes = _lif.lif_step_fused(*padded, bm=bm, interpret=INTERPRET,
                                        **params)
    return v_out[:m0].reshape(*lead, n), spikes[:m0].reshape(*lead, n)


def _pad_cols(a, n: int, n_pad: int, n_branches: int):
    """Zero-pad the branch-major column axis (last) from J*n to J*n_pad."""
    if n_pad == n:
        return a
    lead = a.shape[:-1]
    branched = a.reshape(*lead, n_branches, n)
    widths = [(0, 0)] * (branched.ndim - 1) + [(0, n_pad - n)]
    return jnp.pad(branched, widths).reshape(*lead, n_branches * n_pad)


def _unpad_cols(a, n: int, n_pad: int, n_branches: int):
    """Inverse of ``_pad_cols`` for branch-major column outputs."""
    if n_pad == n:
        return a
    lead = a.shape[:-1]
    branched = a.reshape(*lead, n_branches, n_pad)
    return branched[..., :n].reshape(*lead, n_branches * n)


def event_stream_issues(events, n_in: int | None = None):
    """Host-side check of the fused kernels' event-tensor input contract.

    The kernels consume a ``(T, n_in)`` ternary tensor — finite values in
    {-1, 0, +1}, a real-number dtype, at least one time step.  Anything
    else either crashes the launch with an opaque shape error or, worse,
    flows through the MAC as silent garbage (NaNs propagate into every
    membrane the slot touches for the rest of the round).  This is the
    single source of truth the serving layer's submit-time validation
    (``serve.lifecycle.validate_events``) consults *before* any kernel
    launch is staged.

    Pure numpy (no device dispatch on the submit path).  Returns
    ``(ev, issues)``: the ``np.ndarray`` view of ``events`` (or None when
    the dtype cannot even be materialized) and a list of
    ``(code, message)`` pairs with codes ``dtype`` / ``shape`` / ``empty``
    / ``nonfinite`` / ``nonternary``; an empty list means the tensor is
    launchable as-is.
    """
    import numpy as np
    issues: list[tuple[str, str]] = []
    try:
        ev = np.asarray(events)
    except Exception as e:   # ragged lists, arbitrary objects
        return None, [("dtype", f"events not array-like ({e})")]
    if ev.dtype == object or ev.dtype.kind in "USVcM":
        return ev, [("dtype", f"events dtype {ev.dtype} is not a real "
                              f"number type")]
    if ev.ndim != 2:
        issues.append(("shape", f"events must be (T, n_in); got shape "
                                f"{ev.shape}"))
    elif n_in is not None and ev.shape[1] != n_in:
        issues.append(("shape", f"events width {ev.shape[1]} != engine "
                                f"n_in {n_in}"))
    if ev.size == 0:
        issues.append(("empty", f"zero-length event stream (shape "
                                f"{ev.shape})"))
        return ev, issues
    if ev.dtype.kind == "f" and not bool(np.isfinite(ev).all()):
        issues.append(("nonfinite", "events carry NaN/Inf values"))
        return ev, issues     # ternary test on NaNs would double-report
    if not bool(np.isin(ev, (-1.0, 0.0, 1.0)).all()):
        bad = ev[~np.isin(ev, (-1.0, 0.0, 1.0))]
        issues.append(("nonternary",
                       f"events must be ternary in {{-1, 0, +1}}; got "
                       f"{bad.flat[0]!r} (and {bad.size - 1} more)"))
    return ev, issues


def fused_activity_map(xm: jax.Array, plan) -> jax.Array:
    """Per-(step, row-tile, K-tile) occupancy of a padded time-major input.

    xm (T, m_pad, k_pad) ternary events, plan a ``fused_macro.TilePlan``;
    returns the (T, m_pad/bm, k_pad/bk) int32 map (1 = the block holds at
    least one event) the gated kernel consumes via scalar prefetch.  This
    is the whole host-side activity-planning pass: one any-reduce over the
    input, O(T*M*K) bit tests, negligible next to a single MAC step.
    """
    t = xm.shape[0]
    n_i, n_k = plan.m_pad // plan.bm, plan.k_pad // plan.bk
    occ = (xm != 0).reshape(t, n_i, plan.bm, n_k, plan.bk)
    return occ.any(axis=(2, 4)).astype(jnp.int32)


def fused_macro_seq(x, msb, lsb, boundaries, levels, scale, v, noise=None,
                    w_dend=None, *, mode: str = "kwn", k: int = 12,
                    ratio: float = 2.0, drive_gain: float = 1.0,
                    beta: float = 0.9, v_th1: float = 1.0, v_th2: float = 0.6,
                    v_reset: float = 0.0, v_lim: float = 8.0,
                    use_snl: bool = True, bm: int | None = None,
                    bk: int | None = None, bn: int | None = None,
                    ima_noise=None, snl_amp: float = 0.0,
                    gate: bool = True, activity=None,
                    mac_telemetry: bool = True, train_trace: bool = False,
                    seed=0, step_offset=0, row_ctl=None):
    """Batched time-major fused sequence; x (T, ..., K), v (..., N),
    noise (T, ..., N) or None for in-kernel counter noise.

    Pads the batch to the row tile, K to the macro row count, and — for
    layers wider than one macro — the column axis to the column tile (zero
    padding is MAC-neutral; padded columns are masked out of the KWN ramp
    and, in NLD mode, padded per branch so the branch-major layout
    survives).  Runs the whole sequence through one kernel launch with the
    LIF membrane carried in VMEM, then slices the padding back off.

    ``gate`` (default on — it cannot change any output bit) runs the
    activity-gated kernel: a per-(step, row-tile, K-tile) occupancy map is
    computed from the events (``fused_activity_map``; or pass a
    precomputed ``activity``) and scalar-prefetched into the kernel, which
    skips the plane decode + MXU contraction for all-zero blocks and
    bounds the KWN ramp sweep to the occupied code range.  ``gate=False``
    is the dense execution the pre-sparsity pipeline ran — kept as the
    benchmark baseline and for A/B parity tests.  ``mac_telemetry=False``
    keeps the raw MAC accumulator in VMEM scratch (no (T, ..., NC) HBM
    stack; the returned mac is None) — the serving default upstream.

    ``ima_noise`` (an ``ima.IMAKernelNoise``) turns on the in-kernel Fig. 7
    conversion-error model; the counter streams are keyed on *logical*
    (row, column) coordinates, so padding and tile choice cannot move a
    draw.  ``noise=None`` with ``snl_amp > 0`` generates the SNL sign noise
    in-kernel as well — the noisy path streams no per-step tensors at all.

    ``row_ctl`` (optional, (..., 3) int32 over the same batch lead dims as
    ``v``) gives every batch row its own ``[seed, step_offset, row_id]``
    noise-stream control, overriding the scalar ``seed``/``step_offset`` —
    the continuous-batching engine uses it so each slot replays the
    counter stream of an independent batch-1 run.

    ``train_trace=True`` (KWN only) appends the per-step membrane trace
    vtrace (T, ..., N) — the post-saturation, pre-reset V_mem — to the
    return tuple; it is the residual the surrogate backward kernel
    (``fused_macro_grad``) consumes.

    Returns (mac (T, ..., NC) or None, v_out (..., N), spikes (T, ..., N),
    mask (T, ..., N), adc_steps (T, ...)), plus vtrace (T, ..., N) when
    ``train_trace``.
    """
    t = x.shape[0]
    lead = x.shape[1:-1]
    kdim = x.shape[-1]
    n = v.shape[-1]
    nc = msb.shape[-1]
    n_branches = nc // n if mode == "nld" else 1
    xm = x.reshape(t, -1, kdim)
    vm = v.reshape(-1, n)
    m0 = xm.shape[1]
    plan = _fused.plan_tiles(m0, kdim, nc, n, t, mode=mode,
                             n_branches=n_branches, bm=bm, bk=bk, bn=bn)
    xm = jnp.pad(xm, ((0, 0), (0, plan.m_pad - m0), (0, plan.k_pad - kdim)))
    vm = jnp.pad(vm, ((0, plan.m_pad - m0), (0, plan.n_pad - n)))
    if not gate:
        activity = None
    elif activity is None:
        activity = fused_activity_map(xm, plan)
    nm = None
    if noise is not None:
        nm = noise.reshape(t, -1, n)
        nm = jnp.pad(nm, ((0, 0), (0, plan.m_pad - m0), (0, plan.n_pad - n)))
    msb_p = _pad_cols(jnp.pad(msb, ((0, plan.k_pad - kdim), (0, 0))),
                      n, plan.n_pad, n_branches)
    lsb_p = _pad_cols(jnp.pad(lsb, ((0, plan.k_pad - kdim), (0, 0))),
                      n, plan.n_pad, n_branches)
    scale_p = _pad_cols(scale.reshape(-1), n, plan.n_pad, n_branches)
    w_dend_p = w_dend
    if w_dend is not None and plan.n_pad != n:
        w_dend_p = jnp.pad(w_dend, ((0, 0), (0, plan.n_pad - n)))
    rc = None
    if row_ctl is not None:
        rc = jnp.pad(row_ctl.reshape(-1, 3), ((0, plan.m_pad - m0), (0, 0)))
    outs = _fused.fused_macro_seq(
        xm, msb_p, lsb_p, boundaries, levels, scale_p, vm, nm, w_dend_p,
        activity, rc,
        mode=mode, k=k, ratio=ratio, drive_gain=drive_gain, beta=beta,
        v_th1=v_th1, v_th2=v_th2, v_reset=v_reset, v_lim=v_lim,
        use_snl=use_snl, bm=plan.bm, bk=plan.bk, bn=plan.bn,
        n_valid=plan.n_valid, ima_noise=ima_noise, snl_amp=snl_amp,
        logical_n=n, mac_telemetry=mac_telemetry, train_trace=train_trace,
        seed=seed, step_offset=step_offset, interpret=INTERPRET)
    mac, v_out, spikes, mask, steps = outs[:5]
    if mac is not None:
        mac = _unpad_cols(mac[:, :m0], n, plan.n_pad, n_branches)
        mac = mac.reshape(t, *lead, nc)
    ret = (mac,
           v_out[:m0, :n].reshape(*lead, n),
           spikes[:, :m0, :n].reshape(t, *lead, n),
           mask[:, :m0, :n].reshape(t, *lead, n),
           steps[:, :m0, 0].reshape(t, *lead))
    if train_trace:
        ret += (outs[5][:, :m0, :n].reshape(t, *lead, n),)
    return ret


def fused_macro_step(x, msb, lsb, boundaries, levels, scale, v, noise=None,
                     w_dend=None, *, mode: str = "kwn", k: int = 12,
                     ratio: float = 2.0, drive_gain: float = 1.0,
                     beta: float = 0.9, v_th1: float = 1.0, v_th2: float = 0.6,
                     v_reset: float = 0.0, v_lim: float = 8.0,
                     use_snl: bool = True, bm: int | None = None,
                     bk: int | None = None, bn: int | None = None,
                     ima_noise=None, snl_amp: float = 0.0,
                     gate: bool = True, mac_telemetry: bool = True, seed=0,
                     step_offset=0):
    """Batched fused macro step; x (..., K), v/noise (..., N).

    The T=1 degenerate of ``fused_macro_seq`` (one kernel launch per time
    step), including its activity gating (``gate``) and optional raw-MAC
    telemetry (``mac_telemetry``).  With ``ima_noise``, pass the scan
    index as ``step_offset`` so a per-step cadence draws the same stream
    as the one-launch sequence.
    Returns (mac (..., NC) or None, v_out, spikes, mask (..., N),
    adc_steps (...,)).
    """
    mac, v_out, spikes, mask, steps = fused_macro_seq(
        x[None], msb, lsb, boundaries, levels, scale, v,
        None if noise is None else noise[None], w_dend,
        mode=mode, k=k, ratio=ratio, drive_gain=drive_gain, beta=beta,
        v_th1=v_th1, v_th2=v_th2, v_reset=v_reset, v_lim=v_lim,
        use_snl=use_snl, bm=bm, bk=bk, bn=bn, ima_noise=ima_noise,
        snl_amp=snl_amp, gate=gate, mac_telemetry=mac_telemetry, seed=seed,
        step_offset=step_offset)
    return (None if mac is None else mac[0], v_out, spikes[0], mask[0],
            steps[0])


class MultiSeqOut(NamedTuple):
    """Outputs of the stacked multi-layer fused sequence.

    ``spikes``/``mask`` are the FINAL layer's per-step stacks — the only
    spike tensors that exist in HBM.  Hidden-layer activity surfaces as
    telemetry instead: ``spike_counts`` (per-layer (T, ...) row-wise spike
    totals — what the SOP energy accounting needs from the inter-layer
    tensors that never leave the kernel) and ``occupancy`` (per-layer
    (T, row-tiles) occupied-K-tile counts from the in-kernel occupancy
    map; ``total_blocks`` is the denominator for the skipped-block ratio,
    summed over layers).
    """

    v_outs: tuple
    spikes: jax.Array
    mask: jax.Array
    steps: tuple
    spike_counts: tuple
    occupancy: tuple
    total_blocks: int


def fused_macro_multi_seq(x, stack, vs, noises=None, *, ks,
                          ratio: float = 2.0, drive_gain: float = 1.0,
                          beta: float = 0.9, v_th1: float = 1.0,
                          v_th2: float = 0.6, v_reset: float = 0.0,
                          v_lim: float = 8.0, use_snl: bool = True,
                          bm: int | None = None, tile_shapes=None,
                          ima_noise=None, snl_amp: float = 0.0,
                          gate: bool = True, seeds=None, step_offset=0):
    """L stacked KWN macro layers, batched: x (T, ..., K0), one launch.

    stack:  per-layer (msb, lsb, boundaries, levels, scale) operand tuples
            (``core.macro.FusedMacroWeights`` fields; KWN mode only — the
            planes are (k_dim_l, n_l) with k_dim_l == n_{l-1} for l > 0).
    vs:     per-layer (..., n_l) initial membranes.
    noises: per-layer (T, ..., n_l) pre-drawn SNL noise (clean-path PRBS
            parity), or None for the in-kernel counter streams.
    ks:     per-layer KWN winner counts.
    tile_shapes: per-layer (bk, bn) in-kernel MAC tile sizes, or None for
            defaults (bk = min(k_dim, 256) aligned via the layer-0 tile
            planner, bn = min(n, 128)); this is the "tile plan" of the
            stacked kernel — ``bk`` doubles as the occupancy-gating
            granularity.
    seeds:  per-layer int32 counter seeds (distinct per layer so the
            per-layer noise streams never collide), or None for zeros.

    Only layer 0 is padded (rows to the row tile, K to the layer-0 K
    tiling, both sliced back off); inter-layer widths stay exact because
    the spike hand-off happens in registers inside the kernel.  Returns a
    ``MultiSeqOut``.
    """
    t = x.shape[0]
    lead = x.shape[1:-1]
    kdim = x.shape[-1]
    n_layers = len(stack)
    widths = [s[0].shape[-1] for s in stack]
    assert len(ks) == n_layers
    if tile_shapes is None:
        tile_shapes = [(None, None)] * n_layers
    xm = x.reshape(t, -1, kdim)
    m0 = xm.shape[1]
    plan0 = _fused.plan_tiles(m0, kdim, widths[0], widths[0], t,
                              bm=bm, bk=tile_shapes[0][0])
    xm = jnp.pad(xm, ((0, 0), (0, plan0.m_pad - m0),
                      (0, plan0.k_pad - kdim)))
    activity = fused_activity_map(xm, plan0) if gate else None
    specs = []
    for li in range(n_layers):
        k_dim = plan0.k_pad if li == 0 else widths[li - 1]
        bk_l, bn_l = tile_shapes[li]
        if li == 0:
            bk_l = plan0.bk               # matches the host activity map
        elif bk_l is None and bn_l is None:
            # deep layers reuse any tuned single-layer plan for their
            # shape, capped to the layer (LayerSpec allows ragged tails)
            cb = _fused.cached_plan_blocks(
                m0, k_dim, widths[li], widths[li], t, mode="kwn")
            if cb is not None:
                bk_l, bn_l = min(cb.bk, k_dim), min(cb.bn, widths[li])
        specs.append(_fused.LayerSpec(
            k_dim=k_dim, n=widths[li], k=int(ks[li]),
            bk=int(bk_l or min(k_dim, _fused.DEFAULT_BK)),
            bn=int(bn_l or min(widths[li], _fused.DEFAULT_BN))))
    specs = tuple(specs)
    vs_p = tuple(jnp.pad(v.reshape(-1, w), ((0, plan0.m_pad - m0), (0, 0)))
                 for v, w in zip(vs, widths))
    noises_p = None
    if noises is not None:
        noises_p = tuple(
            jnp.pad(nz.reshape(t, -1, w), ((0, 0), (0, plan0.m_pad - m0),
                                           (0, 0)))
            for nz, w in zip(noises, widths))
    if seeds is None:
        seeds = jnp.zeros((n_layers,), jnp.int32)
    ctl = jnp.concatenate([
        jnp.asarray(seeds, jnp.int32).reshape(-1),
        jnp.asarray(step_offset, jnp.int32).reshape(1)]).reshape(1, -1)
    planes = [tuple(s[:5]) for s in stack]
    if plan0.k_pad != kdim:              # zero K rows are MAC-neutral
        msb0, lsb0 = planes[0][0], planes[0][1]
        pad_k = ((0, plan0.k_pad - kdim), (0, 0))
        planes[0] = (jnp.pad(msb0, pad_k), jnp.pad(lsb0, pad_k),
                     *planes[0][2:])
    planes = tuple(planes)
    v_outs, spikes, mask, steps, counts, occ = _fused.fused_macro_multi_seq(
        xm, planes, vs_p, noises_p, activity, ctl,
        specs=specs, ratio=ratio, drive_gain=drive_gain, beta=beta,
        v_th1=v_th1, v_th2=v_th2, v_reset=v_reset, v_lim=v_lim,
        use_snl=use_snl, bm=plan0.bm, ima_noise=ima_noise, snl_amp=snl_amp,
        has_noise=noises is not None, gated=gate, interpret=INTERPRET)
    n_i = plan0.m_pad // plan0.bm
    return MultiSeqOut(
        v_outs=tuple(v[:m0].reshape(*lead, w)
                     for v, w in zip(v_outs, widths)),
        spikes=spikes[:, :m0].reshape(t, *lead, widths[-1]),
        mask=mask[:, :m0].reshape(t, *lead, widths[-1]),
        steps=tuple(s[:, :m0, 0].reshape(t, *lead) for s in steps),
        spike_counts=tuple(c[:, :m0, 0].reshape(t, *lead) for c in counts),
        occupancy=tuple(o[:, :, 0] for o in occ),
        total_blocks=t * n_i * sum(spec.n_k for spec in specs))


# ---------------------------------------------------------------------------
# Differentiable fused sequence: the silicon-in-the-loop training primitive
# ---------------------------------------------------------------------------

class SeqVJPSpec(NamedTuple):
    """Static (hashable) configuration of ``fused_macro_seq_vjp``.

    Mirrors the fused forward's static kwargs plus the surrogate-backward
    knobs: ``kwn_relax`` (loser gradient leak through the hard winner gate),
    ``surrogate_beta`` (SuperSpike sharpness), ``ste_lo``/``ste_hi`` (the
    IMA ramp's straight-through window, in the ramp's input units), and
    ``remat`` (recompute the MAC in the backward instead of saving the
    (T, M, NC) residual stack — bit-identical gradients, see
    ``fused_macro_grad``).  ``has_noise`` says whether the streamed noise
    operand is live (clean-path PRBS SNL) or a dummy (in-kernel counter
    noise / SNL off).
    """

    k: int = 12
    ratio: float = 2.0
    drive_gain: float = 1.0
    beta: float = 0.9
    v_th1: float = 1.0
    v_th2: float = 0.6
    v_reset: float = 0.0
    v_lim: float = 8.0
    use_snl: bool = True
    ima_noise: object = None          # ima.IMAKernelNoise | None (hashable)
    snl_amp: float = 0.0
    kwn_relax: float = 0.0
    surrogate_beta: float = 4.0
    ste_lo: float = -24.5
    ste_hi: float = 24.5
    remat: bool = False
    gate: bool = True
    has_noise: bool = False
    bm: int | None = None
    bk: int | None = None
    bn: int | None = None


def _seq_vjp_forward(spec: SeqVJPSpec, w, x, boundaries, levels, scale, v,
                     noise, seed_f):
    """Silicon-exact forward: quantize ``w`` onto the twin-cell planes and
    run the fused kernel with the training residual outputs enabled."""
    msb, lsb = _ternary.weight_decompose(w)
    seed = seed_f.astype(jnp.int32)
    noise_arr = noise if spec.has_noise else None
    mac, v_out, spikes, mask, _, vtrace = fused_macro_seq(
        x, _ternary.pack_ternary(msb), _ternary.pack_ternary(lsb),
        boundaries, levels, scale, v, noise_arr, None,
        mode="kwn", k=spec.k, ratio=spec.ratio, drive_gain=spec.drive_gain,
        beta=spec.beta, v_th1=spec.v_th1, v_th2=spec.v_th2,
        v_reset=spec.v_reset, v_lim=spec.v_lim, use_snl=spec.use_snl,
        bm=spec.bm, bk=spec.bk, bn=spec.bn, ima_noise=spec.ima_noise,
        snl_amp=spec.snl_amp, gate=spec.gate,
        mac_telemetry=not spec.remat, train_trace=True, seed=seed)
    return (spikes, v_out), (w, x, scale, mask, vtrace, mac, noise)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def fused_macro_seq_vjp(spec: SeqVJPSpec, w, x, boundaries, levels, scale,
                        v, noise, seed_f):
    """``fused_macro_seq`` with a surrogate backward (KWN mode).

    The forward is the silicon-exact fused kernel — clean or counter-PRNG
    noisy, activity-gated — bitwise-equal to ``ref.fused_macro_seq_ref``;
    the backward is the time-reversed BPTT Pallas kernel
    (``fused_macro_grad.fused_macro_seq_grad``), whose gradient semantics
    are pinned by ``jax.grad`` of ``ref.fused_macro_seq_vjp_ref``.

    w:      (K, N) f32 weight in *integer MAC units* (values on the twin-
            cell [-3, 3] grid; non-grid values are rounded in the primal
            and straight-through in the tangent — callers put their own
            ternary-STE clip at the model layer).
    x:      (T, ..., K) f32 ternary events (no gradient; zero cotangent).
    v:      (..., N) f32 initial membrane (dv0 is returned).
    noise:  (T, ..., N) f32 streamed SNL noise when ``spec.has_noise``,
            else a dummy array (any shape).
    seed_f: f32 scalar counter-PRNG seed (< 2^24; kept float so the
            cotangent machinery never meets an integer primal).

    Returns (spikes (T, ..., N), v_out (..., N)).
    """
    out, _ = _seq_vjp_forward(spec, w, x, boundaries, levels, scale, v,
                              noise, seed_f)
    return out


def _seq_vjp_fwd(spec, w, x, boundaries, levels, scale, v, noise, seed_f):
    out, res = _seq_vjp_forward(spec, w, x, boundaries, levels, scale, v,
                                noise, seed_f)
    return out, res + (boundaries, levels)


def _seq_vjp_bwd(spec, res, cts):
    w, x, scale, mask, vtrace, mac, noise, boundaries, levels = res
    g_spk, g_vout = cts
    t = x.shape[0]
    lead = x.shape[1:-1]
    kdim = x.shape[-1]
    n = vtrace.shape[-1]
    xm = x.reshape(t, -1, kdim)
    m0 = xm.shape[1]
    plan = _fused.plan_tiles(m0, kdim, n, n, t, mode="kwn",
                             bm=spec.bm, bk=spec.bk, bn=spec.bn)
    xm = jnp.pad(xm, ((0, 0), (0, plan.m_pad - m0),
                      (0, plan.k_pad - kdim)))
    pad_n = [(0, 0), (0, plan.m_pad - m0), (0, plan.n_pad - n)]
    stack = lambda a: jnp.pad(a.reshape(t, m0, n), pad_n)
    g_spk_p = stack(g_spk)
    vtrace_p = stack(vtrace)
    mask_p = stack(mask)
    g_vfin_p = jnp.pad(g_vout.reshape(m0, n), pad_n[1:])
    scale_p = jnp.pad(scale.reshape(-1), (0, plan.n_pad - n)).reshape(1, -1)
    activity = None
    if spec.gate:
        activity = fused_activity_map(xm, plan).any(axis=2).astype(jnp.int32)
    if spec.remat:
        msb, lsb = _ternary.weight_decompose(w)
        msb_p = jnp.pad(msb, ((0, plan.k_pad - kdim), (0, plan.n_pad - n)))
        lsb_p = jnp.pad(lsb, ((0, plan.k_pad - kdim), (0, plan.n_pad - n)))
        mac_p = None
    else:
        msb_p = lsb_p = None
        mac_p = stack(mac)
    dw_p, dv0_p = _fused_grad.fused_macro_seq_grad(
        xm, scale_p, g_spk_p, g_vfin_p, vtrace_p, mask_p, mac_p,
        None if msb_p is None else _ternary.pack_ternary(msb_p),
        None if lsb_p is None else _ternary.pack_ternary(lsb_p),
        activity,
        ratio=spec.ratio, drive_gain=spec.drive_gain, beta=spec.beta,
        v_th1=spec.v_th1, v_lim=spec.v_lim, kwn_relax=spec.kwn_relax,
        surrogate_beta=spec.surrogate_beta, ste_lo=spec.ste_lo,
        ste_hi=spec.ste_hi, bm=plan.bm, bn=plan.bn, interpret=INTERPRET)
    dw = dw_p[:kdim, :n]
    dv0 = dv0_p[:m0, :n].reshape(*lead, n)
    return (dw, jnp.zeros_like(x), jnp.zeros_like(boundaries),
            jnp.zeros_like(levels), jnp.zeros_like(scale), dv0,
            jnp.zeros_like(noise), jnp.zeros((), jnp.float32))


fused_macro_seq_vjp.defvjp(_seq_vjp_fwd, _seq_vjp_bwd)


def nlq_convert(x, boundaries, levels):
    """Batched NLQ; x (..., N) -> (codes, reconstruction)."""
    lead = x.shape[:-1]
    n = x.shape[-1]
    xm = x.reshape(-1, n).astype(jnp.float32)
    bm = min(256, _ceil_mult(xm.shape[0], 8))
    xm, m0 = _pad_to(xm, 0, bm)
    codes, y = _nlq.nlq_convert(xm, boundaries.astype(jnp.float32),
                                levels.astype(jnp.float32), bm=bm,
                                interpret=INTERPRET)
    return codes[:m0].reshape(*lead, n), y[:m0].reshape(*lead, n)
