"""Pure-jnp oracles for every Pallas kernel (the correctness references).

These are deliberately written against the *core* library semantics so kernel
tests check kernels against the same code the SNN models execute.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ima as ima_lib
from repro.core import kwn as kwn_lib


def ternary_mac_ref(x: jax.Array, msb: jax.Array, lsb: jax.Array,
                    ratio: float = 2.0) -> jax.Array:
    """f32 GEMM against the decoded twin-cell weights."""
    w = ratio * msb.astype(jnp.float32) + lsb.astype(jnp.float32)
    return x.astype(jnp.float32) @ w


def kwn_topk_ref(mac: jax.Array, boundaries: jax.Array, k: int):
    """(mask, adc_steps) via the core ramp-scan semantics."""
    levels = jnp.concatenate([boundaries, boundaries[-1:]])  # placeholder levels
    cb = ima_lib.RampCodebook(levels=jnp.zeros(boundaries.shape[0] + 1),
                              boundaries=boundaries,
                              in_lo=float(boundaries[0]),
                              in_hi=float(boundaries[-1]))
    res = kwn_lib.kwn_select(mac, k, cb)
    return res.mask, res.adc_steps[..., None].astype(jnp.int32)


def lif_step_ref(v, drive, mask, noise, beta=0.9, v_th1=1.0, v_th2=0.6,
                 v_reset=0.0, v_lim=8.0, use_snl=True):
    v_new = jnp.where(mask > 0, beta * v + drive, v)
    if use_snl:
        snl = (v_new > v_th2) & (v_new < v_th1)
        v_new = jnp.where(snl, v_new + noise, v_new)
    v_new = jnp.clip(v_new, -v_lim, v_lim)
    spike = (v_new >= v_th1).astype(jnp.float32)
    return jnp.where(spike > 0, v_reset, v_new), spike


def nlq_convert_ref(x, boundaries, levels):
    code = jnp.searchsorted(boundaries, x, side="left").astype(jnp.int32)
    # kernel uses strict '>' compare: match searchsorted side for exact ties
    code = jnp.sum(x[..., None] > boundaries, axis=-1).astype(jnp.int32)
    return code, jnp.take(levels, code)
