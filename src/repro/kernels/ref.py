"""Pure-jnp oracles for every Pallas kernel (the correctness references).

These are deliberately written against the *core* library semantics so kernel
tests check kernels against the same code the SNN models execute.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ctrprng
from repro.core import ima as ima_lib
from repro.core import kwn as kwn_lib
from repro.core import ternary as ternary_lib


def _noise_ids(shape):
    """Global (row, column) counter words for a 2-D *unpadded* operand.

    The kernel's logical-column mapping collapses to the plain column index
    on unpadded layouts (KWN: branch 0 only; NLD branch-major: branch j of
    column p sits at ``j * n + p`` — exactly ``j * logical_n + p``), so the
    oracle's stream is the kernel's stream by construction.
    """
    rows = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    return rows, cols


def counter_snl_noise(shape, seed, step, amp: float) -> jax.Array:
    """The in-kernel SNL sign-noise stream (noisy-silicon path oracle)."""
    rows, cols = _noise_ids(shape)
    sign = ctrprng.counter_sign(seed, step, rows, cols, ctrprng.TAG_SNL)
    return jnp.float32(amp) * sign


def ternary_mac_ref(x: jax.Array, msb: jax.Array, lsb: jax.Array,
                    ratio: float = 2.0) -> jax.Array:
    """f32 GEMM against the decoded twin-cell weights."""
    w = ratio * msb.astype(jnp.float32) + lsb.astype(jnp.float32)
    return x.astype(jnp.float32) @ w


def kwn_topk_ref(mac: jax.Array, boundaries: jax.Array, k: int):
    """(mask, adc_steps) via the core ramp-scan semantics."""
    cb = ima_lib.RampCodebook(levels=jnp.zeros(boundaries.shape[0] + 1),
                              boundaries=boundaries,
                              in_lo=float(boundaries[0]),
                              in_hi=float(boundaries[-1]))
    res = kwn_lib.kwn_select(mac, k, cb)
    return res.mask, res.adc_steps[..., None].astype(jnp.int32)


def lif_step_ref(v, drive, mask, noise, beta=0.9, v_th1=1.0, v_th2=0.6,
                 v_reset=0.0, v_lim=8.0, use_snl=True):
    v_new = jnp.where(mask > 0, beta * v + drive, v)
    if use_snl:
        snl = (v_new > v_th2) & (v_new < v_th1)
        v_new = jnp.where(snl, v_new + noise, v_new)
    v_new = jnp.clip(v_new, -v_lim, v_lim)
    spike = (v_new >= v_th1).astype(jnp.float32)
    return jnp.where(spike > 0, v_reset, v_new), spike


def nlq_convert_ref(x, boundaries, levels):
    code = jnp.searchsorted(boundaries, x, side="left").astype(jnp.int32)
    # kernel uses strict '>' compare: match searchsorted side for exact ties
    code = jnp.sum(x[..., None] > boundaries, axis=-1).astype(jnp.int32)
    return code, jnp.take(levels, code)


def fused_head_ref(mac, boundaries, levels, scale, v, noise=None,
                   w_dend=None, *, mode: str = "kwn", k: int = 12,
                   drive_gain: float = 1.0, beta: float = 0.9,
                   v_th1: float = 1.0, v_th2: float = 0.6,
                   v_reset: float = 0.0, v_lim: float = 8.0,
                   use_snl: bool = True, ima_noise=None,
                   snl_amp: float = 0.0, seed=0, step=0):
    """The post-MAC stages of the fused step: IMA ramp conversion, mode head
    (KWN descending-ramp top-K / NLD branch activation + soma combine), and
    the LIF update.  Split out so tiled MAC oracles can reuse it verbatim.

    With ``ima_noise`` (an ``ima.IMAKernelNoise``), the Fig. 7 conversion
    error is injected through the *same* counter-PRNG function the kernel
    calls (``ctrprng.noisy_ima_codes``) keyed on ``(seed, step, row, col)``
    — the noisy oracle, bitwise-equal to the noisy kernel.  ``noise=None``
    selects the in-kernel SNL stream (``counter_snl_noise`` at ``snl_amp``)
    instead of a pre-drawn tensor; noisy mode requires 2-D operands (rows
    are counter words).
    """
    # in_lo/in_hi are only consumed by the noise model, not by
    # convert/reconstruct/select — keep the oracle jit-friendly.  (The
    # counter noise model carries its own range inside ``ima_noise``.)
    cb = ima_lib.RampCodebook(
        levels=jnp.asarray(levels, jnp.float32),
        boundaries=jnp.asarray(boundaries, jnp.float32),
        in_lo=0.0, in_hi=0.0)
    if ima_noise is not None:
        assert mac.ndim == 2, "noisy oracle needs (rows, cols) operands"
    if mode == "kwn":
        codes = ima_lib.ima_convert(mac, cb)
        if ima_noise is not None:
            rows, cols = _noise_ids(mac.shape)
            codes = ctrprng.noisy_ima_codes(codes, mac, rows, cols, seed,
                                            step, ima_noise, cb.n_codes)
            # Selection, early stop, and drive all read the *noisy* code —
            # rank on its reconstruction (convert∘reconstruct is identity
            # on codes, so kwn_select sees exactly the noisy ramp order).
            mac_rank = ima_lib.ima_reconstruct(codes, cb)
        else:
            mac_rank = mac
        res = kwn_lib.kwn_select(mac_rank, k, cb)
        mask, steps = res.mask, res.adc_steps[..., None]
        recon = ima_lib.ima_reconstruct(codes, cb)
        drive = recon * scale * mask * drive_gain
    elif mode == "nld":
        n_branches, n = w_dend.shape
        mac_f = mac * scale
        codes = ima_lib.ima_convert(mac_f, cb)
        if ima_noise is not None:
            rows, cols = _noise_ids(mac_f.shape)
            codes = ctrprng.noisy_ima_codes(codes, mac_f, rows, cols, seed,
                                            step, ima_noise, cb.n_codes)
        act = ima_lib.ima_reconstruct(codes, cb)
        act3 = act.reshape(act.shape[:-1] + (n_branches, n))
        drive = jnp.sum(act3 * w_dend, axis=-2) * drive_gain
        mask = jnp.ones(v.shape, jnp.float32)
        steps = jnp.full(v.shape[:-1] + (1,), cb.n_codes - 1, jnp.int32)
        use_snl = False
    else:
        raise ValueError(f"unknown mode {mode!r}")
    if noise is None:
        if use_snl and snl_amp != 0.0:
            noise = counter_snl_noise(v.shape, seed, step, snl_amp)
        else:
            noise = jnp.zeros(v.shape, jnp.float32)
    v_out, spikes = lif_step_ref(v, drive, mask, noise, beta=beta,
                                 v_th1=v_th1, v_th2=v_th2, v_reset=v_reset,
                                 v_lim=v_lim, use_snl=use_snl)
    return v_out, spikes, mask, steps


def fused_macro_step_ref(x, msb, lsb, boundaries, levels, scale, v,
                         noise=None, w_dend=None, *, mode: str = "kwn",
                         k: int = 12, ratio: float = 2.0,
                         drive_gain: float = 1.0, beta: float = 0.9,
                         v_th1: float = 1.0, v_th2: float = 0.6,
                         v_reset: float = 0.0, v_lim: float = 8.0,
                         use_snl: bool = True, ima_noise=None,
                         snl_amp: float = 0.0, seed=0, step=0):
    """Composed jnp oracle for the fused macro step (kernels/fused_macro.py).

    Same stage sequence — twin-cell MAC, IMA ramp conversion (optionally
    through the counter-PRNG Fig. 7 error model), mode head (KWN
    descending-ramp top-K / NLD branch activation + soma combine), LIF
    update — expressed through the core-library semantics, with every
    arithmetic step mirrored so the fused kernel matches *bitwise* at f32:
    the MAC partials are small integers (exact in f32, associativity-free),
    the head is compare/select/LUT arithmetic, and the noise draws come
    from the identical ``ctrprng`` counter functions.

    Returns (mac, v_out, spikes, mask, adc_steps) like the kernel, with
    adc_steps shaped (..., 1).
    """
    mac = ternary_mac_ref(x, msb, lsb, ratio=ratio)
    v_out, spikes, mask, steps = fused_head_ref(
        mac, boundaries, levels, scale, v, noise, w_dend, mode=mode, k=k,
        drive_gain=drive_gain, beta=beta, v_th1=v_th1, v_th2=v_th2,
        v_reset=v_reset, v_lim=v_lim, use_snl=use_snl, ima_noise=ima_noise,
        snl_amp=snl_amp, seed=seed, step=step)
    return mac, v_out, spikes, mask, steps


def tiled_ternary_mac_ref(x, msb, lsb, ratio: float = 2.0, *,
                          bk: int = 256, bn: int = 128) -> jax.Array:
    """Tiled-oracle MAC: explicit digital partial-sum accumulation.

    Computes the twin-cell GEMM the way the tiled kernel does — one
    ``(bk, bn)`` weight-plane tile per step, f32 partial sums added across
    the K tiles in order — to pin down that row/col tiling cannot move the
    result: every partial is a small exact integer, so f32 accumulation is
    associativity-free and any tiling equals the untiled ``ternary_mac_ref``
    bitwise.
    """
    kdim, nc = msb.shape
    w = ratio * msb.astype(jnp.float32) + lsb.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    cols = []
    for j0 in range(0, nc, bn):
        acc = None
        for k0 in range(0, kdim, bk):
            part = xf[..., k0:k0 + bk] @ w[k0:k0 + bk, j0:j0 + bn]
            acc = part if acc is None else acc + part
        cols.append(acc)
    return jnp.concatenate(cols, axis=-1)


def fused_macro_tiled_ref(x, msb, lsb, boundaries, levels, scale, v,
                          noise=None, w_dend=None, *, bk: int = 256,
                          bn: int = 128, mode: str = "kwn", k: int = 12,
                          ratio: float = 2.0, drive_gain: float = 1.0,
                          beta: float = 0.9, v_th1: float = 1.0,
                          v_th2: float = 0.6, v_reset: float = 0.0,
                          v_lim: float = 8.0, use_snl: bool = True,
                          ima_noise=None, snl_amp: float = 0.0, seed=0,
                          step=0):
    """Tiled oracle: ``tiled_ternary_mac_ref`` + the shared fused head.

    Must equal ``fused_macro_step_ref`` bitwise for any (bk, bn) — the
    property suite sweeps tilings against it.  The noise streams are
    counter-indexed on global element coordinates, so they are tiling
    oblivious by construction (same kwargs as the step oracle).
    """
    mac = tiled_ternary_mac_ref(x, msb, lsb, ratio=ratio, bk=bk, bn=bn)
    v_out, spikes, mask, steps = fused_head_ref(
        mac, boundaries, levels, scale, v, noise, w_dend, mode=mode, k=k,
        drive_gain=drive_gain, beta=beta, v_th1=v_th1, v_th2=v_th2,
        v_reset=v_reset, v_lim=v_lim, use_snl=use_snl, ima_noise=ima_noise,
        snl_amp=snl_amp, seed=seed, step=step)
    return mac, v_out, spikes, mask, steps


def fused_macro_seq_ref(x, msb, lsb, boundaries, levels, scale, v,
                        noise=None, w_dend=None, *, mode: str = "kwn",
                        k: int = 12, ratio: float = 2.0,
                        drive_gain: float = 1.0, beta: float = 0.9,
                        v_th1: float = 1.0, v_th2: float = 0.6,
                        v_reset: float = 0.0, v_lim: float = 8.0,
                        use_snl: bool = True, ima_noise=None,
                        snl_amp: float = 0.0, seed=0, step_offset=0):
    """Time-major oracle: left-fold of ``fused_macro_step_ref`` over T.

    x (T, ..., K) time-major, v (..., N) initial membrane, noise
    (T, ..., N) pre-drawn per-step noise — or None for the counter-based
    in-kernel streams (IMA conversion error via ``ima_noise``, SNL sign
    noise at ``snl_amp``), in which case the per-step counter word is
    ``step_offset + t``.  Returns per-step stacks (mac (T, ..., NC),
    spikes, mask, adc_steps (T, ..., 1)) plus the final membrane (..., N)
    — exactly the contract of the time-major kernel.
    """
    def step(v_carry, inp):
        t, xt, nt = inp[0], inp[1], (inp[2] if noise is not None else None)
        mac, v_out, spikes, mask, steps = fused_macro_step_ref(
            xt, msb, lsb, boundaries, levels, scale, v_carry, nt, w_dend,
            mode=mode, k=k, ratio=ratio, drive_gain=drive_gain, beta=beta,
            v_th1=v_th1, v_th2=v_th2, v_reset=v_reset, v_lim=v_lim,
            use_snl=use_snl, ima_noise=ima_noise, snl_amp=snl_amp,
            seed=seed, step=step_offset + t)
        return v_out, (mac, spikes, mask, steps)

    t_ix = jnp.arange(x.shape[0], dtype=jnp.int32)
    xs = (t_ix, x) if noise is None else (t_ix, x, noise)
    v_fin, (mac_t, spk_t, mask_t, steps_t) = jax.lax.scan(step, v, xs)
    return mac_t, v_fin, spk_t, mask_t, steps_t


def fused_macro_multi_seq_ref(x, stack, vs, noises=None, *, ks, seeds=None,
                              ratio: float = 2.0, drive_gain: float = 1.0,
                              beta: float = 0.9, v_th1: float = 1.0,
                              v_th2: float = 0.6, v_reset: float = 0.0,
                              v_lim: float = 8.0, use_snl: bool = True,
                              ima_noise=None, snl_amp: float = 0.0,
                              step_offset=0):
    """Composed per-layer oracle for the stacked fused kernel (KWN only).

    Chains ``fused_macro_seq_ref`` layer by layer: layer l's full spike
    stack becomes layer l+1's input sequence.  This layer-major order is
    *exactly* the stacked kernel's step-major order, because layer l+1 at
    step t depends only on (its own membrane after step t-1, layer l's
    step-t spikes) — the two schedules compute identical dataflow DAGs, so
    the comparison is bitwise, not approximate.  KWN spikes are {0, 1},
    which is its own ternary encoding, so spike stacks feed the next
    layer's MAC unmodified.

    stack:  per-layer (msb, lsb, boundaries, levels, scale) tuples.
    vs:     per-layer initial membranes; ks: per-layer winner counts.
    seeds:  per-layer counter seeds (must match the kernel's per-layer
            ctl words); noises: per-layer pre-drawn SNL tensors or None
            for the counter streams.

    Returns (v_fins (per-layer), spikes (T, ..., n_L) — final layer,
    mask (T, ..., n_L), steps (per-layer (T, ..., 1)),
    spike_counts (per-layer (T, ...) row-wise |spike| totals)).
    """
    cur = x.astype(jnp.float32)
    v_fins, steps_list, cnt_list = [], [], []
    spk_t = mask_t = None
    for li, (msb, lsb, bounds, levels, scale) in enumerate(stack):
        _, v_fin, spk_t, mask_t, steps_t = fused_macro_seq_ref(
            cur, msb, lsb, bounds, levels, scale, vs[li],
            None if noises is None else noises[li],
            mode="kwn", k=ks[li], ratio=ratio, drive_gain=drive_gain,
            beta=beta, v_th1=v_th1, v_th2=v_th2, v_reset=v_reset,
            v_lim=v_lim, use_snl=use_snl, ima_noise=ima_noise,
            snl_amp=snl_amp, seed=0 if seeds is None else seeds[li],
            step_offset=step_offset)
        v_fins.append(v_fin)
        steps_list.append(steps_t)
        cnt_list.append(jnp.sum(jnp.abs(spk_t), axis=-1))
        cur = spk_t
    return v_fins, spk_t, mask_t, steps_list, cnt_list


# ---------------------------------------------------------------------------
# Differentiable oracle: the surrogate-backward reference (silicon training)
# ---------------------------------------------------------------------------
#
# ``fused_macro_seq_vjp_ref`` is the *gradient semantics* oracle for the
# silicon-in-the-loop training subsystem: a pure-JAX function whose primal
# outputs are bitwise-equal to ``fused_macro_seq_ref`` (and therefore to the
# fused Pallas kernel) and whose ``jax.grad`` defines the reference surrogate
# gradient the Pallas backward kernel (``kernels.fused_macro_grad``) must
# reproduce.  The surrogate chain, expressed through STE-identity terms
# (``primal_exact + (surrogate - stop_grad(surrogate))`` — exactly zero in
# the primal, the surrogate's derivative in the tangent):
#
#   * **ternary MAC**: the tangent of the integer-unit MAC is ``x @ w`` (the
#     caller's float weight, straight through the round-to-ternary);
#   * **IMA ramp + LUT**: straight-through inside the ramp's representable
#     range (``[ste_lo, ste_hi]`` = levels span +-0.5 LSB, the same
#     saturation window ``ima._ima_ste_bwd`` uses); the Fig. 7 noise draws
#     perturb the primal codes only — the tangent passes through the clean
#     analog MAC;
#   * **KWN winner mask**: a hard gate with a *relaxed* STE — winners pass
#     gradient at weight 1, losers leak it at ``kwn_relax`` (the gradient a
#     loser would have received had it won, scaled down; ``kwn_relax=0`` is
#     the pure hard gate);
#   * **LIF spike**: the SuperSpike fast-sigmoid surrogate at
#     ``surrogate_beta`` (the same ``core.lif.spike_fn`` derivative);
#   * **V_mem saturation**: gradient passes strictly inside the register
#     range (``|v_clip| < v_lim``), and is cut at the rails — defined here
#     (not via ``jnp.clip``, whose tie-splitting at an exact-rail membrane
#     has no silicon meaning);
#   * **SNL noise / reset**: additive noise and the reset branch selection
#     are gradient-transparent and gradient-opaque respectively, exactly as
#     in the software BPTT path.


def _ste(exact: jax.Array, surrogate: jax.Array) -> jax.Array:
    """Primal = ``exact`` (bitwise); tangent = the surrogate's."""
    return jax.lax.stop_gradient(exact) + (
        surrogate - jax.lax.stop_gradient(surrogate))


@jax.custom_vjp
def _spike_surrogate(v: jax.Array, v_th: jax.Array,
                     sbeta: jax.Array) -> jax.Array:
    return (v >= v_th).astype(jnp.float32)


def _spike_surrogate_fwd(v, v_th, sbeta):
    return _spike_surrogate(v, v_th, sbeta), (v, v_th, sbeta)


def _spike_surrogate_bwd(res, g):
    v, v_th, sbeta = res
    x = sbeta * (v - v_th)
    sg = sbeta / (1.0 + jnp.abs(x)) ** 2          # SuperSpike fast sigmoid
    return g * sg, jnp.zeros_like(v_th), jnp.zeros_like(sbeta)


_spike_surrogate.defvjp(_spike_surrogate_fwd, _spike_surrogate_bwd)


@jax.custom_vjp
def _sat_clip(v: jax.Array, lim: jax.Array) -> jax.Array:
    """V_mem register saturation with a hard gradient cut at the rails.

    ``jnp.clip`` splits the cotangent 50/50 when the membrane lands exactly
    on a rail (lax.min/max balanced-tie JVP); the register has no such
    half-gradient state, so the backward here passes iff strictly inside."""
    return jnp.clip(v, -lim, lim)


def _sat_clip_fwd(v, lim):
    out = _sat_clip(v, lim)
    return out, (out, lim)


def _sat_clip_bwd(res, g):
    v_clip, lim = res
    inside = (jnp.abs(v_clip) < lim).astype(g.dtype)
    return g * inside, jnp.zeros_like(lim)


_sat_clip.defvjp(_sat_clip_fwd, _sat_clip_bwd)


def fused_macro_seq_vjp_ref(w, x, boundaries, levels, scale, v,
                            noise=None, *, k: int = 12, ratio: float = 2.0,
                            drive_gain: float = 1.0, beta: float = 0.9,
                            v_th1: float = 1.0, v_th2: float = 0.6,
                            v_reset: float = 0.0, v_lim: float = 8.0,
                            use_snl: bool = True, ima_noise=None,
                            snl_amp: float = 0.0, seed=0, step_offset=0,
                            kwn_relax: float = 0.0,
                            surrogate_beta: float = 4.0,
                            ste_lo: float | None = None,
                            ste_hi: float | None = None):
    """Differentiable time-major oracle for the fused KWN sequence.

    ``w`` is the *float* weight in integer MAC units (the primal rounds it
    onto the twin-cell [-3, 3] grid exactly like the packers, so passing an
    already-integer ``w`` reproduces ``fused_macro_seq_ref(x, msb, lsb, ...)``
    bitwise); gradients flow to ``w`` and ``v`` through the surrogate chain
    documented above.  ``x`` is the (T, M, K) ternary input as f32 (events
    carry no gradient).  ``ste_lo``/``ste_hi`` bound the straight-through
    window of the IMA ramp (default: levels span +-0.5 LSB).

    Returns (v_fin, spikes (T, M, N), mask (T, M, N), adc_steps (T, M, 1),
    vtrace (T, M, N)) — the same per-step stacks the training forward saves,
    with vtrace the pre-reset saturated membrane.
    """
    sg = jax.lax.stop_gradient
    w_int = ternary_lib.weight_decompose(sg(w))
    w_exact = ternary_lib.weight_compose(*w_int, ratio=ratio)
    cb = ima_lib.RampCodebook(
        levels=jnp.asarray(levels, jnp.float32),
        boundaries=jnp.asarray(boundaries, jnp.float32),
        in_lo=0.0, in_hi=0.0)
    if ste_lo is None:
        ste_lo = float(jnp.min(cb.levels)) - 0.5
    if ste_hi is None:
        ste_hi = float(jnp.max(cb.levels)) + 0.5
    sbeta = jnp.float32(surrogate_beta)
    lim = jnp.float32(v_lim)

    def step(v_carry, inp):
        t, xt = inp[0], inp[1]
        nzt = inp[2] if noise is not None else None
        mac_e = xt @ w_exact                       # exact integer-unit MAC
        mac = _ste(mac_e, xt @ w)
        codes = ima_lib.ima_convert(sg(mac_e), cb)
        if ima_noise is not None:
            rows, cols = _noise_ids(mac_e.shape)
            codes = ctrprng.noisy_ima_codes(codes, sg(mac_e), rows, cols,
                                            seed, step_offset + t, ima_noise,
                                            cb.n_codes)
            mac_rank = ima_lib.ima_reconstruct(codes, cb)
        else:
            mac_rank = sg(mac_e)
        res = kwn_lib.kwn_select(mac_rank, k, cb)
        maskf, steps = sg(res.mask), res.adc_steps[..., None]
        recon = ima_lib.ima_reconstruct(codes, cb)
        drive_exact = recon * scale * maskf * drive_gain
        rng = sg(((mac_e >= ste_lo) & (mac_e <= ste_hi))
                 .astype(jnp.float32))             # ramp saturation window
        drive_sur = mac * sg(scale) * drive_gain * rng
        drive_w = _ste(drive_exact, drive_sur)
        if kwn_relax != 0.0:
            leak = kwn_relax * drive_sur
            v_lose = v_carry + (leak - sg(leak))   # exactly v in the primal
        else:
            v_lose = v_carry
        v2 = jnp.where(maskf > 0, beta * v_carry + drive_w, v_lose)
        if use_snl:
            if nzt is None:
                nz = counter_snl_noise(v2.shape, seed, step_offset + t,
                                       snl_amp)
            else:
                nz = nzt
            snl = (sg(v2) > v_th2) & (sg(v2) < v_th1)
            v2 = jnp.where(snl, v2 + sg(nz), v2)
        v_clip = _sat_clip(v2, lim)
        s = _spike_surrogate(v_clip, jnp.float32(v_th1), sbeta)
        v_next = jnp.where(sg(s) > 0, v_reset, v_clip)
        return v_next, (s, maskf, steps, v_clip)

    t_ix = jnp.arange(x.shape[0], dtype=jnp.int32)
    xs = (t_ix, x) if noise is None else (t_ix, x, noise)
    v_fin, (spk_t, mask_t, steps_t, vtrace_t) = jax.lax.scan(step, v, xs)
    return v_fin, spk_t, mask_t, steps_t, vtrace_t
