"""Pure-jnp oracles for every Pallas kernel (the correctness references).

These are deliberately written against the *core* library semantics so kernel
tests check kernels against the same code the SNN models execute.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ima as ima_lib
from repro.core import kwn as kwn_lib


def ternary_mac_ref(x: jax.Array, msb: jax.Array, lsb: jax.Array,
                    ratio: float = 2.0) -> jax.Array:
    """f32 GEMM against the decoded twin-cell weights."""
    w = ratio * msb.astype(jnp.float32) + lsb.astype(jnp.float32)
    return x.astype(jnp.float32) @ w


def kwn_topk_ref(mac: jax.Array, boundaries: jax.Array, k: int):
    """(mask, adc_steps) via the core ramp-scan semantics."""
    levels = jnp.concatenate([boundaries, boundaries[-1:]])  # placeholder levels
    cb = ima_lib.RampCodebook(levels=jnp.zeros(boundaries.shape[0] + 1),
                              boundaries=boundaries,
                              in_lo=float(boundaries[0]),
                              in_hi=float(boundaries[-1]))
    res = kwn_lib.kwn_select(mac, k, cb)
    return res.mask, res.adc_steps[..., None].astype(jnp.int32)


def lif_step_ref(v, drive, mask, noise, beta=0.9, v_th1=1.0, v_th2=0.6,
                 v_reset=0.0, v_lim=8.0, use_snl=True):
    v_new = jnp.where(mask > 0, beta * v + drive, v)
    if use_snl:
        snl = (v_new > v_th2) & (v_new < v_th1)
        v_new = jnp.where(snl, v_new + noise, v_new)
    v_new = jnp.clip(v_new, -v_lim, v_lim)
    spike = (v_new >= v_th1).astype(jnp.float32)
    return jnp.where(spike > 0, v_reset, v_new), spike


def nlq_convert_ref(x, boundaries, levels):
    code = jnp.searchsorted(boundaries, x, side="left").astype(jnp.int32)
    # kernel uses strict '>' compare: match searchsorted side for exact ties
    code = jnp.sum(x[..., None] > boundaries, axis=-1).astype(jnp.int32)
    return code, jnp.take(levels, code)


def fused_macro_step_ref(x, msb, lsb, boundaries, levels, scale, v, noise,
                         w_dend=None, *, mode: str = "kwn", k: int = 12,
                         ratio: float = 2.0, drive_gain: float = 1.0,
                         beta: float = 0.9, v_th1: float = 1.0,
                         v_th2: float = 0.6, v_reset: float = 0.0,
                         v_lim: float = 8.0, use_snl: bool = True):
    """Composed jnp oracle for the fused macro step (kernels/fused_macro.py).

    Same stage sequence — twin-cell MAC, IMA ramp conversion, mode head
    (KWN descending-ramp top-K / NLD branch activation + soma combine),
    LIF update — expressed through the core-library semantics, with every
    arithmetic step mirrored so the fused kernel matches *bitwise* at f32:
    the MAC partials are small integers (exact in f32, associativity-free)
    and the head is compare/select/LUT arithmetic.

    Returns (mac, v_out, spikes, mask, adc_steps) like the kernel, with
    adc_steps shaped (..., 1).
    """
    # in_lo/in_hi are only consumed by the noise model, not by
    # convert/reconstruct/select — keep the oracle jit-friendly.
    cb = ima_lib.RampCodebook(
        levels=jnp.asarray(levels, jnp.float32),
        boundaries=jnp.asarray(boundaries, jnp.float32),
        in_lo=0.0, in_hi=0.0)
    mac = ternary_mac_ref(x, msb, lsb, ratio=ratio)
    if mode == "kwn":
        codes = ima_lib.ima_convert(mac, cb)
        res = kwn_lib.kwn_select(mac, k, cb)
        mask, steps = res.mask, res.adc_steps[..., None]
        recon = ima_lib.ima_reconstruct(codes, cb)
        drive = recon * scale * mask * drive_gain
    elif mode == "nld":
        n_branches, n = w_dend.shape
        mac_f = mac * scale
        act = ima_lib.ima_quantize(mac_f, cb)
        act3 = act.reshape(act.shape[:-1] + (n_branches, n))
        drive = jnp.sum(act3 * w_dend, axis=-2) * drive_gain
        mask = jnp.ones(v.shape, jnp.float32)
        steps = jnp.full(v.shape[:-1] + (1,), cb.n_codes - 1, jnp.int32)
        use_snl = False
    else:
        raise ValueError(f"unknown mode {mode!r}")
    v_out, spikes = lif_step_ref(v, drive, mask, noise, beta=beta,
                                 v_th1=v_th1, v_th2=v_th2, v_reset=v_reset,
                                 v_lim=v_lim, use_snl=use_snl)
    return mac, v_out, spikes, mask, steps
