"""Pallas TPU kernel: packed ternary MAC (twin 9T bit-cell GEMM, paper C1).

TPU adaptation of the analog macro: the MSB/LSB twin-cell planes are stored as
int8 ternary tensors and *decoded on the fly* inside the kernel
(``w = ratio * msb + lsb``), so HBM traffic is 2 int8 planes instead of a
dequantized bf16/f32 weight — a 2x (vs bf16) / 4x (vs f32) memory-bandwidth
saving, which is the TPU-native analogue of the macro's in-array multi-bit
composition.  The MAC itself runs on the MXU at f32 accumulation.

Tiling: grid (M/bm, N/bn, K/bk); the K dimension is innermost so the output
block accumulates in VMEM across K steps (revisiting semantics).  Block sizes
default to MXU-aligned (128) multiples; the natural bn is the macro's own
column count, 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 256  # the macro's row count: one K-tile == one physical macro


def _ternary_mac_kernel(x_ref, msb_ref, lsb_ref, o_ref, *, ratio: float,
                        n_k: int):
    """One (bm, bn) output tile; accumulates over the K grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    # Twin-cell decode: multi-VDD bank composition (I_MSB = ratio * I_LSB).
    w = ratio * msb_ref[...].astype(jnp.float32) + lsb_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "ratio",
                                             "interpret"))
def ternary_mac(x: jax.Array, msb: jax.Array, lsb: jax.Array,
                bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                bk: int = DEFAULT_BK, ratio: float = 2.0,
                interpret: bool = True) -> jax.Array:
    """x: (M, K) int8 ternary; msb/lsb: (K, N) int8 ternary -> (M, N) f32.

    Shapes must be multiples of the block sizes (``ops.py`` pads).
    """
    m, k = x.shape
    k2, n = msb.shape
    assert k == k2 and msb.shape == lsb.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)

    return pl.pallas_call(
        functools.partial(_ternary_mac_kernel, ratio=ratio, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, msb, lsb)
