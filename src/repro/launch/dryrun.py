import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
cell with ShapeDtypeStruct stand-ins (no allocation), record memory_analysis /
cost_analysis / collective wire bytes for the roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

train_4k lowers train_step (grad-accum microbatches + AdamW update);
prefill_32k lowers the serving prefill (last-logits + cache fill);
decode_32k / long_500k lower serve_step (one token against the full cache).
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPE_SKIPS, cells, get_config
from repro.dist import sharding
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.nn import module
from repro.roofline import analysis, flops_model
from repro.serve import engine
from repro.train import optim, train_loop

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "dryrun_results")


def opt_profile(cfg: lm.LMConfig) -> tuple[optim.AdamWConfig, object]:
    """Optimizer memory profile by model scale (documented in EXPERIMENTS.md):
    >100B params: bf16 moments; >400B: bf16 grad accumulation too."""
    n = cfg.param_count()
    ocfg = optim.AdamWConfig(
        moment_dtype="bfloat16" if n > 100e9 else "float32")
    grad_dtype = jnp.bfloat16 if n > 400e9 else jnp.float32
    return ocfg, grad_dtype


def n_micro_for(cfg: lm.LMConfig, shape: str, mesh) -> int:
    if shape != "train_4k":
        return 1
    gb = lm.SHAPES[shape]["batch"]
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    per_dev_seqs = gb // dp
    # target 1-2 sequences per device-row per microbatch
    return max(1, min(per_dev_seqs, 8))


def lower_cell(arch: str, shape: str, multi_pod: bool, do_compile: bool = True):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    sh = lm.SHAPES[shape]
    kind = sh["kind"]
    rules = sharding.rules_for(cfg)

    param_specs = lm.param_specs(cfg)
    params_abs = module.abstract(param_specs, dtype=cfg.compute_dtype)
    param_sh = module.shardings(param_specs, mesh, rules)
    batch_abs = lm.batch_specs(cfg, shape)
    batch_sh = sharding.batch_shardings(cfg, mesh, shape)
    rep = sharding.replicated(mesh)

    with mesh:
        if shape == "train_4k":
            ocfg, grad_dtype = opt_profile(cfg)
            nm = n_micro_for(cfg, shape, mesh)
            step = train_loop.build_train_step(cfg, mesh, n_micro=nm,
                                               opt_cfg=ocfg,
                                               grad_dtype=grad_dtype)
            # pre-microbatched batch: (n_micro, mb, ...), dim-1 batch-sharded
            batch_abs = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (nm, s.shape[0] // nm) + s.shape[1:], s.dtype), batch_abs)
            from jax.sharding import NamedSharding
            batch_sh = {
                k: NamedSharding(mesh, module.partition_spec(
                    tuple(batch_abs[k].shape),
                    (None,) + lm.batch_axes(cfg, shape)[k], mesh, rules))
                for k in batch_abs}
            opt_abs = jax.eval_shape(lambda p: optim.adamw_init(p, ocfg),
                                     params_abs)
            opt_sh = sharding.opt_shardings(cfg, mesh, param_sh)
            jitted = jax.jit(step,
                             in_shardings=(param_sh, opt_sh, batch_sh),
                             out_shardings=(param_sh, opt_sh, rep),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
            n_tokens = sh["batch"] * sh["seq"]
            extra = {"n_micro": nm, "moment_dtype": ocfg.moment_dtype,
                     "grad_dtype": str(grad_dtype.__name__)}
        elif kind == "prefill":
            def prefill_step(params, batch):
                logits, _, cache = lm.forward(params, batch, cfg, mesh,
                                              prefill=True)
                return logits, cache
            cache_sh, _ = None, None
            jitted = jax.jit(prefill_step,
                             in_shardings=(param_sh, batch_sh))
            lowered = jitted.lower(params_abs, batch_abs)
            n_tokens = sh["batch"] * sh["seq"]
            extra = {}
        else:  # decode / long-context decode
            s_max = sh["seq"]
            b = sh["batch"]
            cache_sh, cache_abs = sharding.cache_shardings(cfg, mesh, b, s_max)
            serve = engine.build_serve_step(cfg, mesh)

            def serve_step(params, cache, tokens, pos):
                return serve(params, cache, tokens, pos, None)

            jitted = jax.jit(
                serve_step,
                in_shardings=(param_sh, cache_sh, batch_sh["tokens"],
                              batch_sh["pos"]),
                out_shardings=(batch_sh["tokens"], rep, cache_sh),
                donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs,
                                   batch_abs["tokens"], batch_abs["pos"])
            n_tokens = b
            extra = {"cache_seq_len": s_max}

        result = {
            "arch": arch, "shape": shape,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_devices": n_dev, "kind": kind,
            "params_total": cfg.param_count(),
            "params_active": cfg.active_param_count(),
            **extra,
        }
        if not do_compile:
            result["lowered_only"] = True
            return result

        t0 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t0, 1)

        mem = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                result[attr] = int(v)
        result["bytes_per_device"] = (
            result.get("argument_size_in_bytes", 0)
            + result.get("temp_size_in_bytes", 0)
            - result.get("alias_size_in_bytes", 0))

        mf = analysis.model_flops(cfg.active_param_count(), n_tokens,
                                  "train" if kind == "train" else "serve",
                                  n_dev)
        roof = analysis.from_compiled(compiled, model_flops_per_device=mf)
        result["roofline_hlo"] = roof.as_dict()
        result["collectives_hlo"] = analysis.collective_bytes(compiled.as_text())

        # PRIMARY roofline: analytical model (cost_analysis counts while-loop
        # bodies once; see roofline/flops_model.py docstring).
        ocfg, grad_dtype = opt_profile(cfg)
        result["roofline"] = flops_model.analyze(
            cfg, shape, flops_model.mesh_for(multi_pod),
            n_micro=extra.get("n_micro", 1),
            grad_bytes=2 if grad_dtype == jnp.bfloat16 else 4,
            moment_bytes=2 if ocfg.moment_dtype == "bfloat16" else 4)
        return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(lm.SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--no-compile", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        todo = cells()
    else:
        assert args.arch and args.shape, "--arch + --shape (or --all)"
        if (args.arch, args.shape) in SHAPE_SKIPS:
            print(f"SKIP {args.arch} x {args.shape}: "
                  f"{SHAPE_SKIPS[(args.arch, args.shape)]}")
            return
        todo = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch, shape in todo:
        for multi in meshes:
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
            out_path = os.path.join(args.out, tag + ".json")
            if os.path.exists(out_path):
                print(f"[cached] {tag}")
                continue
            print(f"[lower+compile] {tag} ...", flush=True)
            try:
                t0 = time.time()
                res = lower_cell(arch, shape, multi,
                                 do_compile=not args.no_compile)
                res["wall_s"] = round(time.time() - t0, 1)
                with open(out_path, "w") as f:
                    json.dump(res, f, indent=1)
                r = res.get("roofline", {})
                print(f"  OK {res['wall_s']}s  dominant={r.get('dominant')} "
                      f"compute={r.get('compute_s', 0):.4f}s "
                      f"memory={r.get('memory_s', 0):.4f}s "
                      f"coll={r.get('collective_s', 0):.4f}s "
                      f"mem/dev={res.get('bytes_per_device', 0)/2**30:.2f}GiB",
                      flush=True)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"  FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall cells green")


if __name__ == "__main__":
    main()
