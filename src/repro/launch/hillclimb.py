import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver: hypothesis -> change -> re-lower/compile ->
measure -> confirmed/refuted, per EXPERIMENTS.md §Perf.

Three cells (chosen from the baseline table):
  kimi-k2-1t-a32b x train_4k   — paper-representative (router IS the KWN
                                  circuit) and most collective-bound;
  nemotron-4-340b x train_4k   — compute-bound dense giant;
  qwen2.5-32b x decode_32k     — memory-bound serving (worst *fixable*
                                  roofline fraction).

Each iteration applies a config transform, re-lowers + compiles on the
production mesh, records the analytical roofline terms AND the compiled
artifact's memory/HLO-collective cross-checks.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell kimi
"""

import argparse
import dataclasses
import json


from repro.configs import get_config
from repro.roofline import flops_model

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "perf_results")


def prior_guided_search(candidates, evaluate, *, prior=None, better=None,
                        patience=None):
    """Prior-ordered ladder search: the generic core of ``run_cell``.

    Visits ``candidates`` in ``prior`` order (an analytic cost estimate —
    cheapest-predicted first, so early stopping keeps the most promising
    measurements), calls ``evaluate(candidate) -> score`` on each, and
    keeps the best under ``better(new_score, best_score)`` (default: lower
    is better).  ``patience`` stops the ladder after that many consecutive
    non-improving measurements — the same confirmed/refuted discipline the
    perf ladders above apply by hand.  Returns
    ``(best_candidate, best_score, [(candidate, score), ...])`` over the
    candidates actually measured.  The tile-plan autotuner
    (``repro.tune.autotune``) drives this with a roofline prior.
    """
    if better is None:
        better = lambda a, b: a < b   # noqa: E731 — default objective
    ordered = sorted(candidates, key=prior) if prior is not None \
        else list(candidates)
    best = best_score = None
    results = []
    stall = 0
    for cand in ordered:
        score = evaluate(cand)
        results.append((cand, score))
        if best is None or better(score, best_score):
            best, best_score, stall = cand, score, 0
        else:
            stall += 1
            if patience is not None and stall >= patience:
                break
    return best, best_score, results


def _analyze(cfg, shape, multi_pod=False, n_micro=8):
    return flops_model.analyze(
        cfg, shape, flops_model.mesh_for(multi_pod),
        n_micro=n_micro if shape == "train_4k" else 1,
        grad_bytes=2 if cfg.param_count() > 400e9 else 4,
        moment_bytes=2 if cfg.param_count() > 100e9 else 4)


# Each ladder entry: (iteration name, hypothesis, config transform).
LADDERS = {
    "kimi": ("kimi-k2-1t-a32b", "train_4k", [
        ("baseline", "paper-faithful 2D-EP MoE, full remat, bf16 wire", {}),
        ("int8_a2a",
         "dispatch activations are NLQ-compressible (paper C2 on the wire): "
         "int8 a2a + int8 TP-gather halves the MoE fwd wire -> collective "
         "term -25-30%",
         {"moe_wire_dtype": "int8"}),
        ("cap_1.0",
         "capacity 1.25->1.0 drops 20% of a2a payload and expert flops; "
         "token drops are absorbed by the residual stream (known MoE "
         "result); wire -8%, compute -5%",
         {"moe_wire_dtype": "int8", "moe_capacity_factor": 1.0}),
        ("attn_only_remat",
         "remat only attention: MoE GEMMs+collectives run 2 passes not 3 -> "
         "wire -33% on the MoE share, compute -15%; memory grows by saved "
         "MoE activations (~150MB/layer/microbatch)",
         {"moe_wire_dtype": "int8", "moe_capacity_factor": 1.0,
          "remat_mode": "attn_only"}),
        ("save_moe_recv",
         "REVISED after attn_only_remat blew memory (scan saves per-layer "
         "MoE internals): pin ONLY the post-a2a gathered tokens "
         "(checkpoint_name + save_only_these_names) -> x-side a2a+gather "
         "skipped in recompute (-~1.3s wire), memory + ~71MB/layer",
         {"moe_wire_dtype": "int8", "moe_capacity_factor": 1.0,
          "remat_policy": "save_moe_recv"}),
        ("dots_remat",
         "save matmul outputs instead: SP collectives AND both a2a "
         "directions leave the recompute (wire passes 3->2, ~-30%), "
         "compute -20%; memory risk — expert GEMM outputs are saved per "
         "layer (measure before judging)",
         {"moe_wire_dtype": "int8", "moe_capacity_factor": 1.0,
          "remat_policy": "dots"}),
    ]),
    "nemotron": ("nemotron-4-340b", "train_4k", [
        ("baseline", "paper-faithful FSDP+TP+SP dense, full remat", {}),
        ("dots_remat",
         "save matmul outputs (dots policy): recompute only elementwise ops "
         "-> compute 4x->3.05x fwd-units (-24%), SP/FSDP collectives not "
         "recomputed (wire -33%); memory grows by saved dot outputs",
         {"remat_policy": "dots"}),
        ("dots_remat_mb16",
         "halve the microbatch (n_micro 8->16) to pay for the dots-policy "
         "memory; wire per-microbatch volume halves but count doubles "
         "(net ~0 wire), FSDP gathers x2 (worse) — expect small regression "
         "on wire, confirm memory recovery",
         {"remat_policy": "dots", "_n_micro": 16}),
    ]),
    "qwen": ("qwen2.5-32b", "decode_32k", [
        ("baseline", "paper-faithful bf16 KV cache, seq-sharded split-KV", {}),
        ("kv_int8",
         "decode is cache-read bound; NLQ-style int8 KV (payload + per-pos "
         "scale LUT, paper C2/C6 applied to serving) halves cache bytes -> "
         "memory term -:-2 minus the param-read floor",
         {"kv_quant": "int8"}),
        ("kv_int4",
         "4-bit KV (two nibbles/byte) quarters cache bytes; accuracy risk "
         "noted (needs eval on real workloads) -> memory term toward the "
         "param-read floor",
         {"kv_quant": "int4"}),
    ]),
}


def run_cell(cell: str, compile_variants: bool = True):
    # dryrun pulls the full lower/compile stack; import it only when a
    # ladder actually compiles variants so the search helpers above stay
    # importable from light-weight callers (the tile-plan autotuner).
    from repro.launch import dryrun
    arch, shape, ladder = LADDERS[cell]
    os.makedirs(OUT_DIR, exist_ok=True)
    results = []
    base_cfg = get_config(arch)
    for name, hypothesis, overrides in ladder:
        overrides = dict(overrides)
        n_micro = overrides.pop("_n_micro", 8)
        cfg = dataclasses.replace(base_cfg, **overrides) if overrides \
            else base_cfg
        entry = {"cell": cell, "arch": arch, "shape": shape, "name": name,
                 "hypothesis": hypothesis, "overrides": overrides,
                 "n_micro": n_micro}
        entry["analytical"] = _analyze(cfg, shape, n_micro=n_micro)
        if compile_variants:
            # monkey-patch the registry entry so dryrun picks the variant up
            import repro.configs as configs_mod
            old = configs_mod.ARCHS[arch]
            configs_mod.ARCHS[arch] = cfg
            try:
                res = dryrun.lower_cell(arch, shape, multi_pod=False)
                entry["compiled"] = {
                    "compile_s": res.get("compile_s"),
                    "bytes_per_device": res.get("bytes_per_device"),
                    "mem_gib": res.get("bytes_per_device", 0) / 2 ** 30,
                    "hlo_collectives": res.get("collectives_hlo"),
                }
            finally:
                configs_mod.ARCHS[arch] = old
        results.append(entry)
        a = entry["analytical"]
        print(f"[{cell}:{name}] compute={a['compute_s']:.3f}s "
              f"memory={a['memory_s']:.3f}s coll={a['collective_s']:.3f}s "
              f"dominant={a['dominant']} frac={a['roofline_frac']:.3f}"
              + (f" mem/dev={entry['compiled']['mem_gib']:.1f}GiB"
                 if compile_variants else ""), flush=True)

    path = os.path.join(OUT_DIR, f"{cell}.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=1, default=str)
    # verdicts: confirmed if the dominant term moved down vs the last
    # ACCEPTED iteration AND memory stayed feasible (<1.5x baseline —
    # compiled, not estimated); refuted otherwise and rolled back.
    base_mem = results[0].get("compiled", {}).get("mem_gib")
    accepted = results[0]
    results[0]["verdict"] = "baseline"
    for e in results[1:]:
        a = e["analytical"]
        dom = accepted["analytical"]["dominant"]
        before = accepted["analytical"][f"{dom}_s"]
        after = a[f"{dom}_s"]
        mem = e.get("compiled", {}).get("mem_gib")
        mem_ok = (mem is None or base_mem is None or mem < base_mem * 1.5)
        if after < before * 0.98 and mem_ok:
            e["verdict"] = "confirmed"
            accepted = e
        elif not mem_ok:
            e["verdict"] = "refuted (memory blow-up; rolled back)"
        else:
            e["verdict"] = "refuted (no dominant-term win; rolled back)"
        print(f"  {e['name']}: {dom} {before:.3f}s -> {after:.3f}s, "
              f"mem {mem} GiB [{e['verdict']}]")
    accepted["accepted_final"] = True
    print(f"  ACCEPTED: {accepted['name']} "
          f"(frac {accepted['analytical']['roofline_frac']:.3f})")
    with open(path, "w") as f:
        json.dump(results, f, indent=1, default=str)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(LADDERS) + ["all"], default="all")
    ap.add_argument("--no-compile", action="store_true")
    args = ap.parse_args()
    cells = list(LADDERS) if args.cell == "all" else [args.cell]
    for c in cells:
        run_cell(c, compile_variants=not args.no_compile)


if __name__ == "__main__":
    main()
