"""Production mesh definitions.

A function (not a module-level constant) so importing never touches jax device
state.  Single pod: 16x16 = 256 chips ("data","model").  Multi-pod: 2 pods of
256 = 512 chips ("pod","data","model"); DP spans ("pod","data"), and the "pod"
axis can alternatively drive pipeline stages (dist/pipeline.py) to keep
activation collectives intra-pod.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
