"""Serving driver: batched requests through the CIM-mode LM.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 8 --cim

The paper is an inference-efficiency design, so this is the end-to-end driver
of the paper's kind: a small model serving batched requests, optionally with
the NeuDW-CIM execution mode (ternary twin-cell weights + NLQ activations) on
every projection, and per-request latency/token accounting.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import lm
from repro.nn import module
from repro.serve.engine import BatchedEngine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--cim", action="store_true",
                    help="NeuDW-CIM mode: ternary weights + NLQ activations")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    if args.cim:
        cfg = dataclasses.replace(cfg, cim_linear=True)

    key = jax.random.PRNGKey(args.seed)
    params = module.materialize(lm.param_specs(cfg), key)
    engine = BatchedEngine(cfg, params, batch_slots=args.slots, s_max=128)

    rng = jax.random.PRNGKey(args.seed + 1)
    t0 = time.time()
    for uid in range(args.requests):
        rng, sub = jax.random.split(rng)
        prompt = [int(t) for t in jax.random.randint(
            sub, (4 + uid % 4,), 0, cfg.vocab_size)]
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=args.max_new))
    done = engine.run(max_rounds=256)
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"completed {len(done)}/{args.requests} requests, "
          f"{total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s) "
          f"cim_mode={args.cim}")
    for r in done[:4]:
        print(f"  req {r.uid}: prompt {len(r.prompt)} toks -> {r.generated}")
    return done


if __name__ == "__main__":
    main()
