"""Production-style training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
        --steps 20 --ckpt-dir /tmp/ckpt

Features exercised end-to-end (and by tests/test_fault_tolerance.py):
  * auto-resume: restarts continue from the newest atomic checkpoint,
    bitwise-identically (data pipeline is stateless-by-step);
  * per-step deadline watchdog (straggler posture: a step exceeding
    --step-deadline logs a straggler event; on real fleets this feeds the
    health controller that evicts/replaces the slow host);
  * checkpoint every N steps with keep-N garbage collection;
  * optional int8 gradient compression (--compress) [logged in metrics].
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs import get_config
from repro.configs.base import reduced
from repro.data.synthetic_lm import DataConfig, SyntheticLM
from repro.models import lm
from repro.nn import module
from repro.train import checkpoint, optim, train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--step-deadline", type=float, default=300.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--crash-at-step", type=int, default=-1,
                    help="fault injection: hard-exit at this step")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    ocfg = optim.AdamWConfig(lr=args.lr, warmup_steps=5,
                             total_steps=args.steps)

    key = jax.random.PRNGKey(args.seed)
    params = module.materialize(lm.param_specs(cfg), key)
    opt_state = optim.adamw_init(params, ocfg)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq_len,
                                  global_batch=args.global_batch,
                                  seed=args.seed))

    start_step = 0
    restored = checkpoint.restore_latest(
        args.ckpt_dir,
        {"params": params, "opt": opt_state})
    if restored is not None:
        state, meta = restored
        params, opt_state = state["params"], state["opt"]
        start_step = meta["step"]
        print(f"[resume] restored step {start_step} from {args.ckpt_dir}")

    step_fn = jax.jit(train_loop.build_train_step(
        cfg, None, n_micro=args.n_micro, opt_cfg=ocfg))

    history = []
    for step in range(start_step, args.steps):
        if args.crash_at_step == step:
            print(f"[fault-injection] hard exit at step {step}", flush=True)
            os._exit(42)
        t0 = time.time()
        batch = data.batch_at(step, n_micro=args.n_micro)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        dt = time.time() - t0
        if dt > args.step_deadline:
            print(f"[straggler] step {step} took {dt:.1f}s "
                  f"(deadline {args.step_deadline}s)", flush=True)
        loss = float(metrics["loss"])
        history.append({"step": step, "loss": loss, "sec": round(dt, 2)})
        print(f"step {step:4d} loss {loss:.4f} "
              f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s", flush=True)
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            path = checkpoint.save(args.ckpt_dir, step + 1,
                                   {"params": params, "opt": opt_state},
                                   meta={"arch": cfg.name,
                                         "data_step": step + 1})
            print(f"[ckpt] saved {path}", flush=True)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f)
    return history


if __name__ == "__main__":
    main()
