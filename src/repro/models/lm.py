"""Unified LM-family model: dense / MoE / local-global attention / xLSTM /
RG-LRU hybrid / encoder-only audio / VLM-backbone, assembled from a repeating
block *pattern* that is scanned over groups (compile-time O(pattern), not
O(layers)).

Paper integration points:
  * MoE router = KWN selection (nn/moe.py, paper C3);
  * optional KWN-FFN activation sparsity (``kwn_ffn_k``, Eq. 1 with FFN units
    as the 128-column neuron bank);
  * optional CIM-mode projections (``cim_linear``: ternary twin-cell weights +
    NLQ activations, paper C1/C2).

Modality frontends are stubs per the assignment: audio gets precomputed frame
embeddings, VLM gets precomputed ViT patch embeddings; both pass through a
learned projector.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn import attention, layers, moe, recurrent
from repro.nn.module import ParamSpec


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                     # moe|dense|audio|ssm|hybrid|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    activation: str = "silu"
    gated_ffn: bool = True
    qkv_bias: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    rope_theta: float = 10000.0
    pattern: tuple[str, ...] = ("attn",)   # attn | attn_local | mlstm | slstm | rglru
    window: int | None = None
    moe: bool = False
    n_experts: int = 0
    moe_top_k: int = 0
    moe_dense_residual: bool = False       # arctic: parallel dense FFN
    n_shared_experts: int = 0              # kimi: always-on experts
    encoder_only: bool = False
    frontend: str | None = None            # audio_frames | vision_patches
    frontend_dim: int = 0
    n_patches: int = 0
    tie_embeddings: bool = False
    scale_embed: bool = False
    post_norms: bool = False
    d_rnn: int = 0
    dtype: str = "bfloat16"
    remat: bool = True
    remat_mode: str = "group"        # group | attn_only  (§Perf knob)
    remat_policy: str = "nothing"    # nothing | dots     (§Perf knob)
    attn_chunk: int = 1024
    kv_quant: str | None = None      # None | int8 | int4 (§Perf: NLQ-for-KV)
    moe_wire_dtype: str = "bfloat16"  # bfloat16 | int8   (§Perf: a2a compression)
    moe_capacity_factor: float = 1.25
    cim_linear: bool = False
    kwn_ffn_k: int = 0
    sharding_overrides: dict | None = None
    supports_long_context: bool = False
    vocab_pad_to: int = 256

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        v, m = self.vocab_size, self.vocab_pad_to
        return ((v + m - 1) // m) * m

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail_pattern(self) -> tuple[str, ...]:
        r = self.n_layers % len(self.pattern)
        return self.pattern[:r]

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def param_count(self) -> int:
        from repro.nn.module import count_params
        return count_params(param_specs(self))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts count)."""
        total = self.param_count()
        if not self.moe:
            return total
        from repro.nn.module import count_params
        expert = moe.moe_specs(self.d_model, self.d_ff, self.n_experts)
        expert_total = count_params({k: v for k, v in expert.items()
                                     if k != "router"})
        n_moe_layers = sum(1 for _ in range(self.n_layers))
        dense_frac = (self.moe_top_k + self.n_shared_experts) / self.n_experts
        return int(total - expert_total * n_moe_layers * (1 - dense_frac))


# ===========================================================================
# Param specs
# ===========================================================================

def _ffn_specs(cfg: LMConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    s = {"w_in": layers.linear_spec(d, f, "embed", "ffn")}
    if cfg.gated_ffn:
        s["w_gate"] = layers.linear_spec(d, f, "embed", "ffn")
    s["w_out"] = layers.linear_spec(f, d, "ffn", "embed")
    return s


def _block_specs(cfg: LMConfig, kind: str) -> dict:
    d = cfg.d_model
    s: dict[str, Any] = {"norm1": layers.norm_spec(d)}
    if kind in ("attn", "attn_local"):
        s["attn"] = attention.attention_specs(d, cfg.n_heads, cfg.n_kv, cfg.hd,
                                              cfg.qkv_bias)
    elif kind == "mlstm":
        s["cell"] = recurrent.mlstm_specs(d, cfg.n_heads)
    elif kind == "slstm":
        s["cell"] = recurrent.slstm_specs(d, cfg.n_heads)
    elif kind == "rglru":
        s["cell"] = recurrent.rglru_specs(d, cfg.d_rnn or d)
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        s["norm1_post"] = layers.norm_spec(d)

    has_ffn = cfg.d_ff > 0 and kind not in ("mlstm", "slstm")
    if has_ffn:
        s["norm2"] = layers.norm_spec(d)
        if cfg.moe:
            s["moe"] = moe.moe_specs(d, cfg.d_ff, cfg.n_experts)
            if cfg.moe_dense_residual:
                s["ffn"] = _ffn_specs(cfg)
            if cfg.n_shared_experts:
                shared = dataclasses.replace(
                    cfg, d_ff=cfg.d_ff * cfg.n_shared_experts, moe=False)
                s["shared"] = _ffn_specs(shared)
        else:
            s["ffn"] = _ffn_specs(cfg)
        if cfg.post_norms:
            s["norm2_post"] = layers.norm_spec(d)
    return s


def _stack_specs(specs: dict, n: int) -> dict:
    """Prepend a layer-group dim to every leaf spec."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (None,) + s.axes, s.dtype,
                            s.init, s.scale),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_specs(cfg: LMConfig) -> dict:
    d = cfg.d_model
    p: dict[str, Any] = {}
    if cfg.frontend == "audio_frames":
        p["frontend_proj"] = layers.linear_spec(cfg.frontend_dim, d,
                                                "embed", None)
    if cfg.frontend == "vision_patches":
        p["patch_proj"] = layers.linear_spec(cfg.frontend_dim, d, None, "embed")
    if cfg.frontend != "audio_frames":
        p["embed"] = layers.embed_spec(cfg.padded_vocab, d)
    blocks = {}
    for j, kind in enumerate(cfg.pattern):
        blocks[f"b{j}"] = _stack_specs(_block_specs(cfg, kind), cfg.n_groups)
    p["layers"] = blocks
    for j, kind in enumerate(cfg.tail_pattern):
        p[f"tail{j}"] = _block_specs(cfg, kind)
    p["final_norm"] = layers.norm_spec(d)
    if cfg.encoder_only:
        p["head"] = layers.linear_spec(d, cfg.vocab_size, "embed", "classes")
    elif not cfg.tie_embeddings:
        p["head"] = layers.linear_spec(d, cfg.padded_vocab, "embed", "vocab")
    return p


# ===========================================================================
# Forward (train / prefill)
# ===========================================================================

def _ffn_apply(p: dict, x: jax.Array, cfg: LMConfig) -> jax.Array:
    lin = layers.cim_linear if cfg.cim_linear else layers.linear
    act = layers.ACTIVATIONS[cfg.activation]
    h = act(lin(p["w_in"], x))
    if cfg.gated_ffn:
        h = h * lin(p["w_gate"], x)
    if cfg.kwn_ffn_k > 0:
        # Eq. (1) on FFN units: keep top-k activations per token, zero rest.
        k = cfg.kwn_ffn_k
        thresh = jax.lax.top_k(jnp.abs(h), k)[0][..., -1:]
        h = jnp.where(jnp.abs(h) >= thresh, h, 0.0)
    return lin(p["w_out"], h)


def _moe_apply(p: dict, x: jax.Array, cfg: LMConfig, mesh, decode: bool):
    overrides = cfg.sharding_overrides or {}
    seq_sharded = overrides.get("seq") == "model"
    experts_rule = overrides.get("experts", "model")
    is_2d = experts_rule not in (None, "model")   # experts over DP rows
    if mesh is None:
        y, aux = moe.moe_ref(p["moe"], x, k=cfg.moe_top_k,
                             activation=cfg.activation)
    elif is_2d and not decode:
        y, aux = moe.moe_2d(p["moe"], x, k=cfg.moe_top_k, mesh=mesh,
                            activation=cfg.activation,
                            expert_axes=tuple(experts_rule),
                            capacity_factor=cfg.moe_capacity_factor,
                            wire_dtype=cfg.moe_wire_dtype)
    elif is_2d and decode:
        y, aux = moe.moe_dense_ep_2d(p["moe"], x, k=cfg.moe_top_k, mesh=mesh,
                                     activation=cfg.activation,
                                     expert_axes=tuple(experts_rule))
    elif decode:
        y, aux = moe.moe_dense_ep(p["moe"], x, k=cfg.moe_top_k, mesh=mesh,
                                  activation=cfg.activation)
    else:
        y, aux = moe.moe_a2a(p["moe"], x, k=cfg.moe_top_k, mesh=mesh,
                             activation=cfg.activation,
                             seq_sharded=seq_sharded)
    if cfg.moe_dense_residual:
        y = y + _ffn_apply(p["ffn"], x, cfg)
    if cfg.n_shared_experts:
        shared_cfg = dataclasses.replace(cfg, moe=False)
        y = y + _ffn_apply(p["shared"], x, shared_cfg)
    return y, aux


def _block_apply(kind: str, p: dict, x: jax.Array, positions, cfg: LMConfig,
                 mesh=None, prefill: bool = False):
    """Returns (x, aux_loss, cache_entry-or-None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    h = layers.rmsnorm(p["norm1"], x)
    if kind in ("attn", "attn_local"):
        window = cfg.window if kind == "attn_local" else None
        attn_fn = functools.partial(
            attention.mha, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv, head_dim=cfg.hd,
            causal=not cfg.encoder_only, window=window,
            attn_softcap=cfg.attn_softcap,
            rope_theta=cfg.rope_theta, chunk=cfg.attn_chunk,
            return_kv=prefill)
        if cfg.remat and cfg.remat_mode == "attn_only":
            # §Perf knob: remat ONLY attention; FFN/MoE residuals (incl. the
            # collective outputs) are saved -> the expensive MoE collectives
            # and GEMMs run 2 passes (fwd+bwd) instead of 3.
            attn_fn = jax.checkpoint(
                attn_fn, policy=jax.checkpoint_policies.nothing_saveable)
        h = attn_fn(p["attn"], h, positions)
        if prefill:
            h, (k, v) = h
            cache = attention.prefill_cache_from_kv(k, v, window)
            if cfg.kv_quant and "slot_pos" not in cache:
                from repro.nn import kvq
                kq, ks = kvq.quantize(cache["k"], cfg.kv_quant)
                vq, vs = kvq.quantize(cache["v"], cfg.kv_quant)
                cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    elif kind == "mlstm":
        h = recurrent.mlstm_forward(p["cell"], h, cfg.n_heads,
                                    return_state=prefill)
        if prefill:
            h, st = h
            cache = {"c": st.c, "n": st.n, "m": st.m}
    elif kind == "slstm":
        h = recurrent.slstm_forward(p["cell"], h, cfg.n_heads,
                                    return_state=prefill)
        if prefill:
            h, st = h
            cache = {"c": st.c, "n": st.n, "h": st.h, "m": st.m}
    elif kind == "rglru":
        h = recurrent.rglru_forward(p["cell"], h, return_state=prefill)
        if prefill:
            h, st = h
            cache = {"h": st.h, "conv": st.conv}
    if cfg.post_norms:
        h = layers.rmsnorm(p["norm1_post"], h)
    x = x + h

    if "norm2" in p:
        h = layers.rmsnorm(p["norm2"], x)
        if cfg.moe:
            h, aux = _moe_apply(p, h, cfg, mesh, decode=False)
        else:
            h = _ffn_apply(p["ffn"] if "ffn" in p else p, h, cfg)
        if cfg.post_norms:
            h = layers.rmsnorm(p["norm2_post"], h)
        x = x + h
    return x, aux, cache


def _constrain_acts(x: jax.Array, cfg: LMConfig, mesh):
    """Sequence-parallel activation constraint (Megatron SP): shard the
    residual stream (B, S, D) over ("pod","data") x "model"(seq) so per-layer
    scan carries stay sharded.  No-op when mesh is None, seq is not
    rule-mapped, or dims do not divide (partition_spec falls back)."""
    if mesh is None or x.ndim != 3 or x.shape[1] <= 1:
        return x
    overrides = cfg.sharding_overrides or {}
    if overrides.get("seq") != "model":
        return x
    from jax.sharding import NamedSharding
    from repro.nn import module as _m
    rules = dict(_m.DEFAULT_RULES)
    rules.update(overrides)
    spec = _m.partition_spec(tuple(x.shape), ("batch", "seq", None), mesh,
                             rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _embed_inputs(params, batch, cfg: LMConfig):
    if cfg.frontend == "audio_frames":
        x = layers.linear(params["frontend_proj"],
                          batch["frames"].astype(cfg.compute_dtype))
        return x
    x = layers.embed(params["embed"], batch["tokens"],
                     scale_by_dim=cfg.scale_embed).astype(cfg.compute_dtype)
    if cfg.frontend == "vision_patches":
        patches = layers.linear(params["patch_proj"],
                                batch["patches"].astype(cfg.compute_dtype))
        x = jnp.concatenate([patches, x], axis=1)
    return x


def forward(params: dict, batch: dict, cfg: LMConfig, mesh=None,
            prefill: bool = False):
    """Returns (logits, aux_loss[, cache]).

    prefill=True is the serving prefill: logits only for the LAST position
    (no (B,S,V) logits tensor) and the per-layer decode cache is returned
    (KV ring-ordered for local layers, recurrent states for ssm/hybrid)."""
    x = _embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    x = _constrain_acts(x, cfg, mesh)

    def group_fn(x, gp):
        aux_g = jnp.zeros((), jnp.float32)
        caches = {}
        for j, kind in enumerate(cfg.pattern):
            x, aux, c = _block_apply(kind, gp[f"b{j}"], x, positions, cfg,
                                     mesh, prefill=prefill)
            x = _constrain_acts(x, cfg, mesh)
            aux_g = aux_g + aux
            if prefill:
                caches[f"b{j}"] = c
        return x, aux_g, caches

    if cfg.remat and cfg.remat_mode == "group":
        if cfg.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        elif cfg.remat_policy == "save_moe_recv":
            policy = jax.checkpoint_policies.save_only_these_names(
                "moe_xfull")
        else:
            policy = jax.checkpoint_policies.nothing_saveable
        group_fn = jax.checkpoint(group_fn, policy=policy)

    def scan_body(carry, gp):
        x, aux_acc = carry
        x, aux_g, caches = group_fn(x, gp)
        return (x, aux_acc + aux_g), caches

    (x, aux), stacked_caches = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    cache = dict(stacked_caches) if prefill else None
    for j, kind in enumerate(cfg.tail_pattern):
        x, aux_t, c = _block_apply(kind, params[f"tail{j}"], x, positions, cfg,
                                   mesh, prefill=prefill)
        aux = aux + aux_t
        if prefill:
            cache[f"tail{j}"] = c

    x = layers.rmsnorm(params["final_norm"], x)
    if prefill:
        x = x[:, -1:]
    if cfg.encoder_only:
        logits = layers.linear(params["head"], x)
    elif cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.linear(params["head"], x)
    logits = layers.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if prefill:
        return logits[:, 0], aux, cache
    return logits, aux


def _head_and_ce(params: dict, x: jax.Array, batch: dict, cfg: LMConfig):
    """Unembed + cross-entropy, rematted as one unit so the (B, S, V) logits
    and softmax residuals are never saved for backward (recomputed instead) —
    without this the vocab-sized temporaries dominate training memory."""
    if cfg.encoder_only:
        logits = layers.linear(params["head"], x).astype(jnp.float32)
        targets = batch["targets"]
        mask = batch["loss_mask"].astype(jnp.float32)
        lse = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.take_along_axis(lse, targets[..., None], axis=-1)[..., 0]
        return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.linear(params["head"], x)
    logits = layers.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    tokens = batch["tokens"]
    n_prefix = cfg.n_patches if cfg.frontend == "vision_patches" else 0
    logits_txt = logits[:, n_prefix:]
    targets = tokens[:, 1:]
    lse = jax.nn.log_softmax(logits_txt[:, :-1], axis=-1)
    ce = -jnp.take_along_axis(lse, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        m = mask[:, 1:].astype(jnp.float32)
        return jnp.sum(ce * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(ce)


def features(params: dict, batch: dict, cfg: LMConfig, mesh=None):
    """Forward up to (but excluding) the unembedding: (x, aux)."""
    x = _embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = _constrain_acts(x, cfg, mesh)

    def group_fn(x, gp):
        aux_g = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(cfg.pattern):
            x, aux, _ = _block_apply(kind, gp[f"b{j}"], x, positions, cfg, mesh)
            x = _constrain_acts(x, cfg, mesh)
            aux_g = aux_g + aux
        return x, aux_g

    if cfg.remat and cfg.remat_mode == "group":
        if cfg.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        elif cfg.remat_policy == "save_moe_recv":
            policy = jax.checkpoint_policies.save_only_these_names(
                "moe_xfull")
        else:
            policy = jax.checkpoint_policies.nothing_saveable
        group_fn = jax.checkpoint(group_fn, policy=policy)

    def scan_body(carry, gp):
        x, aux_acc = carry
        x, aux_g = group_fn(x, gp)
        return (x, aux_acc + aux_g), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    for j, kind in enumerate(cfg.tail_pattern):
        x, aux_t, _ = _block_apply(kind, params[f"tail{j}"], x, positions,
                                   cfg, mesh)
        aux = aux + aux_t
    return layers.rmsnorm(params["final_norm"], x), aux


def loss_fn(params: dict, batch: dict, cfg: LMConfig, mesh=None) -> tuple[jax.Array, dict]:
    x, aux = features(params, batch, cfg, mesh)
    head_keys = [k for k in ("head", "embed") if k in params]
    head_params = {k: params[k] for k in head_keys}
    ce_fn = jax.checkpoint(
        functools.partial(_head_and_ce, cfg=cfg),
        policy=jax.checkpoint_policies.nothing_saveable)
    loss = ce_fn(head_params, x, batch)
    total = loss + 0.01 * aux
    return total, {"ce": loss, "aux": aux}


def _legacy_loss_fn(params: dict, batch: dict, cfg: LMConfig, mesh=None):
    logits, aux = forward(params, batch, cfg, mesh)
    if cfg.encoder_only:
        # Masked-prediction CE (HuBERT-style): loss on masked frames only.
        targets = batch["targets"]
        mask = batch["loss_mask"].astype(jnp.float32)
        lse = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.take_along_axis(lse, targets[..., None], axis=-1)[..., 0]
        loss = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        tokens = batch["tokens"]
        n_prefix = cfg.n_patches if cfg.frontend == "vision_patches" else 0
        logits_txt = logits[:, n_prefix:]
        targets = tokens[:, 1:]
        lse = jax.nn.log_softmax(logits_txt[:, :-1], axis=-1)
        ce = -jnp.take_along_axis(lse, targets[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        if mask is not None:
            m = mask[:, 1:].astype(jnp.float32)
            loss = jnp.sum(ce * m) / jnp.maximum(jnp.sum(m), 1.0)
        else:
            loss = jnp.mean(ce)
    total = loss + 0.01 * aux
    return total, {"ce": loss, "aux": aux}


# ===========================================================================
# Decode (serve_step)
# ===========================================================================

def _cache_spec_for(kind: str, cfg: LMConfig, batch: int, s_max: int):
    hd = cfg.hd
    if kind == "attn":
        if cfg.kv_quant:
            from repro.nn import kvq
            sd = kvq.storage_dtype(cfg.kv_quant)
            hs = kvq.storage_shape(hd, cfg.kv_quant)
            shape = (batch, s_max, cfg.n_kv, hs)
            sshape = (batch, s_max, cfg.n_kv, 1)
            return {"k": jnp.zeros(shape, sd), "v": jnp.zeros(shape, sd),
                    "k_scale": jnp.zeros(sshape, jnp.float32),
                    "v_scale": jnp.zeros(sshape, jnp.float32)}
        shape = (batch, s_max, cfg.n_kv, hd)
        return {"k": jnp.zeros(shape, cfg.compute_dtype),
                "v": jnp.zeros(shape, cfg.compute_dtype)}
    if kind == "attn_local":
        w = min(cfg.window or s_max, s_max)
        shape = (batch, w, cfg.n_kv, hd)
        return {"k": jnp.zeros(shape, cfg.compute_dtype),
                "v": jnp.zeros(shape, cfg.compute_dtype),
                "slot_pos": jnp.full((batch, w), -1, jnp.int32)}
    if kind == "mlstm":
        st = recurrent.mlstm_init_state(batch, cfg.n_heads,
                                        cfg.d_model // cfg.n_heads)
        return {"c": st.c, "n": st.n, "m": st.m}
    if kind == "slstm":
        st = recurrent.slstm_init_state(batch, cfg.n_heads,
                                        cfg.d_model // cfg.n_heads)
        return {"c": st.c, "n": st.n, "h": st.h, "m": st.m}
    if kind == "rglru":
        st = recurrent.rglru_init_state(batch, cfg.d_rnn or cfg.d_model)
        return {"h": st.h, "conv": st.conv}
    raise ValueError(kind)


def init_cache(cfg: LMConfig, batch: int, s_max: int) -> dict:
    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape),
                            tree)
    cache = {}
    for j, kind in enumerate(cfg.pattern):
        cache[f"b{j}"] = stack(_cache_spec_for(kind, cfg, batch, s_max),
                               cfg.n_groups)
    for j, kind in enumerate(cfg.tail_pattern):
        cache[f"tail{j}"] = _cache_spec_for(kind, cfg, batch, s_max)
    return cache


def pad_cache(cache: dict, cfg: LMConfig, s_max: int) -> dict:
    """Grow a prefill-produced cache (seq = prompt length) to ``s_max`` slots
    so decode can append: global-attention K/V (+scales) are zero-padded on
    the sequence dim; ring buffers and recurrent states are already final."""
    def pad_entry(entry: dict) -> dict:
        if not isinstance(entry, dict) or "slot_pos" in entry \
                or "k" not in entry:
            return entry
        out = {}
        for key, v in entry.items():
            seq_dim = v.ndim - 3  # (..., B, S, G, hd|1)
            cur = v.shape[seq_dim]
            if cur >= s_max:
                out[key] = v
            else:
                widths = [(0, 0)] * v.ndim
                widths[seq_dim] = (0, s_max - cur)
                out[key] = jnp.pad(v, widths)
        return out

    return {name: pad_entry(entry) for name, entry in cache.items()}


def cache_axes(cfg: LMConfig) -> dict:
    """Logical axes per cache leaf (for sharding)."""
    def axes_for(kind):
        if kind == "attn":
            kv = {"k": (None, "batch", "cache_seq", "cache_heads", None),
                  "v": (None, "batch", "cache_seq", "cache_heads", None)}
            if cfg.kv_quant:
                kv["k_scale"] = (None, "batch", "cache_seq", "cache_heads",
                                 None)
                kv["v_scale"] = (None, "batch", "cache_seq", "cache_heads",
                                 None)
            return kv
        if kind == "attn_local":
            return {"k": (None, "batch", "cache_seq", "cache_heads", None),
                    "v": (None, "batch", "cache_seq", "cache_heads", None),
                    "slot_pos": (None, "batch", None)}
        if kind == "mlstm":
            return {"c": (None, "batch", None, None, None),
                    "n": (None, "batch", None, None),
                    "m": (None, "batch", None)}
        if kind == "slstm":
            return {k: (None, "batch", None, None) for k in "cnhm"}
        if kind == "rglru":
            return {"h": (None, "batch", "ffn"),
                    "conv": (None, "batch", None, "ffn")}
        raise ValueError(kind)

    ax = {}
    for j, kind in enumerate(cfg.pattern):
        ax[f"b{j}"] = axes_for(kind)
    for j, kind in enumerate(cfg.tail_pattern):
        ax[f"tail{j}"] = jax.tree.map(lambda a: a[1:], axes_for(kind),
                                      is_leaf=lambda x: isinstance(x, tuple))
    return ax


def _block_decode(kind: str, p: dict, x, cache: dict, pos, cfg: LMConfig,
                  mesh=None):
    h = layers.rmsnorm(p["norm1"], x)
    if kind == "attn":
        if cfg.kv_quant:
            h, cache = attention.mha_decode_quant(
                p["attn"], h, cache, pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv, head_dim=cfg.hd, kv_quant=cfg.kv_quant,
                attn_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta)
        else:
            kv = attention.KVCache(cache["k"], cache["v"])
            h, kv = attention.mha_decode(p["attn"], h, kv, pos,
                                         n_heads=cfg.n_heads,
                                         n_kv=cfg.n_kv, head_dim=cfg.hd,
                                         attn_softcap=cfg.attn_softcap,
                                         rope_theta=cfg.rope_theta)
            cache = {"k": kv.k, "v": kv.v}
    elif kind == "attn_local":
        h, cache = _ring_decode(p["attn"], h, cache, pos, cfg)
    elif kind == "mlstm":
        st = recurrent.MLSTMState(cache["c"], cache["n"], cache["m"])
        h, st = recurrent.mlstm_decode_step(p["cell"], h, st, cfg.n_heads)
        cache = {"c": st.c, "n": st.n, "m": st.m}
    elif kind == "slstm":
        st = recurrent.SLSTMState(cache["c"], cache["n"], cache["h"], cache["m"])
        h, st = recurrent.slstm_decode_step(p["cell"], h, st, cfg.n_heads)
        cache = {"c": st.c, "n": st.n, "h": st.h, "m": st.m}
    elif kind == "rglru":
        st = recurrent.RGLRUState(cache["h"], cache["conv"])
        h, st = recurrent.rglru_decode_step(p["cell"], h, st)
        cache = {"h": st.h, "conv": st.conv}
    if cfg.post_norms:
        h = layers.rmsnorm(p["norm1_post"], h)
    x = x + h
    if "norm2" in p:
        h = layers.rmsnorm(p["norm2"], x)
        if cfg.moe:
            h, _ = _moe_apply(p, h, cfg, mesh, decode=True)
        else:
            h = _ffn_apply(p["ffn"] if "ffn" in p else p, h, cfg)
        if cfg.post_norms:
            h = layers.rmsnorm(p["norm2_post"], h)
        x = x + h
    return x, cache


def _ring_decode(p, x, cache, pos, cfg: LMConfig):
    """Sliding-window ring-buffer decode for local attention layers."""
    b = x.shape[0]
    w = cache["k"].shape[1]
    q = attention._split_heads(layers.linear(p["wq"], x), cfg.n_heads, cfg.hd)
    k_new = attention._split_heads(layers.linear(p["wk"], x), cfg.n_kv, cfg.hd)
    v_new = attention._split_heads(layers.linear(p["wv"], x), cfg.n_kv, cfg.hd)
    q = layers.rope(q, pos[:, None], cfg.rope_theta)
    k_new = layers.rope(k_new, pos[:, None], cfg.rope_theta)

    slot = pos % w
    onehot = jax.nn.one_hot(slot, w, dtype=cache["k"].dtype)           # (B,W)
    k_c = cache["k"] * (1 - onehot[..., None, None]) + onehot[..., None, None] * k_new
    v_c = cache["v"] * (1 - onehot[..., None, None]) + onehot[..., None, None] * v_new
    slot_pos = (cache["slot_pos"] * (1 - onehot.astype(jnp.int32))
                + onehot.astype(jnp.int32) * pos[:, None])

    n_rep = cfg.n_heads // cfg.n_kv
    kk, vv = attention._repeat_kv(k_c, n_rep), attention._repeat_kv(v_c, n_rep)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) / (cfg.hd ** 0.5)
    s = layers.softcap(s, cfg.attn_softcap)
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None]) & \
        (slot_pos > pos[:, None] - (cfg.window or w))
    s = jnp.where(valid[:, None, None, :], s, attention.NEG_INF)
    wts = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", wts.astype(vv.dtype), vv)
    out = layers.linear(p["wo"], o.reshape(b, 1, cfg.n_heads * cfg.hd))
    return out, {"k": k_c, "v": v_c, "slot_pos": slot_pos}


def decode_step(params: dict, cache: dict, tokens: jax.Array, pos: jax.Array,
                cfg: LMConfig, mesh=None):
    """One token: tokens (B, 1), pos (B,). Returns (logits (B, V), new cache)."""
    assert not cfg.encoder_only, "encoder-only archs have no decode step"
    x = layers.embed(params["embed"], tokens,
                     scale_by_dim=cfg.scale_embed).astype(cfg.compute_dtype)

    new_cache = {}

    def scan_body(x, xs):
        gp, gc = xs
        ncs = {}
        for j, kind in enumerate(cfg.pattern):
            x, nc = _block_decode(kind, gp[f"b{j}"], x,
                                  jax.tree.map(lambda t: t, gc[f"b{j}"]),
                                  pos, cfg, mesh)
            ncs[f"b{j}"] = nc
        return x, ncs

    group_cache = {k: cache[k] for k in cache if k.startswith("b")}
    x, stacked_new = jax.lax.scan(scan_body, x,
                                  (params["layers"], group_cache))
    new_cache.update(stacked_new)
    for j, kind in enumerate(cfg.tail_pattern):
        x, nc = _block_decode(kind, params[f"tail{j}"], x, cache[f"tail{j}"],
                              pos, cfg, mesh)
        new_cache[f"tail{j}"] = nc

    x = layers.rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.linear(params["head"], x)
    logits = layers.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits[:, 0], new_cache


# ===========================================================================
# Input specs (dry-run stand-ins; no allocation)
# ===========================================================================

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def batch_specs(cfg: LMConfig, shape_name: str, batch_override: int | None = None,
                seq_override: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for the data batch of a given shape cell."""
    sh = SHAPES[shape_name]
    b = batch_override or sh["batch"]
    s = seq_override or sh["seq"]
    f32, i32 = jnp.float32, jnp.int32
    if sh["kind"] in ("train", "prefill"):
        if cfg.frontend == "audio_frames":
            return {"frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), f32),
                    "targets": jax.ShapeDtypeStruct((b, s), i32),
                    "loss_mask": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.frontend == "vision_patches":
            return {"tokens": jax.ShapeDtypeStruct((b, s - cfg.n_patches), i32),
                    "patches": jax.ShapeDtypeStruct(
                        (b, cfg.n_patches, cfg.frontend_dim), f32)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    # decode
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32)}


def batch_axes(cfg: LMConfig, shape_name: str) -> dict:
    sh = SHAPES[shape_name]
    if sh["kind"] in ("train", "prefill"):
        if cfg.frontend == "audio_frames":
            return {"frames": ("batch", "seq", None),
                    "targets": ("batch", "seq"), "loss_mask": ("batch", "seq")}
        if cfg.frontend == "vision_patches":
            return {"tokens": ("batch", "seq"),
                    "patches": ("batch", None, None)}
        return {"tokens": ("batch", "seq")}
    return {"tokens": ("batch", None), "pos": ("batch",)}
