"""The paper's SNNs: event input -> CIM hidden layer (KWN or NLD mode) ->
LIF -> spike-count readout, with surrogate-gradient training (BPTT through
lax.scan) and quantization-aware training for the twin-cell weight grid and
the NLQ ramp.

Inference runs through the macro simulator with the silicon noise models, so
the accuracy benchmarks (Figs. 5b / 6c / 8) exercise the same mechanisms the
chip measures: KWN top-K sparse V_mem updates + SNL/PRBS rescue + NLQ LUT,
vs NLD dendritic nonlinearities.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dendrite as dendrite_lib
from repro.core import ima as ima_lib
from repro.core import kwn as kwn_lib
from repro.core import lif as lif_lib
from repro.core import macro as macro_lib
from repro.core import prbs as prbs_lib
from repro.core import ternary as ternary_lib
from repro.obs import trace as obs_trace


@dataclasses.dataclass(frozen=True)
class SNNConfig:
    n_in: int
    n_hidden: int = 128           # the macro's 128 columns
    n_classes: int = 10
    n_steps: int = 20
    mode: str = "kwn"             # kwn | nld
    k: int = 12                   # KWN winners
    n_branches: int = 2           # NLD dendritic branches
    activation: str = "quadratic" # NLD activation f()
    code_bits: int = 5
    mac_range: float = 24.0      # NLQ full scale, in *integer MAC* units
    dend_range: float = 4.0      # NLD branch-MAC full scale (float units)
    drive_gain: float = 0.25     # V_mem LSBs per unit drive
    beta: float = 0.9
    v_th1: float = 1.0
    v_th2: float = 0.6
    noise_amp: float = 0.05
    use_snl: bool = True
    train_nlq: bool = True        # NLQ-aware training (Fig. 6c)
    weight_qat: bool = True       # twin-cell 3-bit QAT
    # Layer stack (multi-layer fused networks, KWN only).  None keeps the
    # single-layer network the paper measures; a tuple of widths chains L
    # macro layers (n_hidden is forced to the last width — the readout
    # reads the final layer).  k_layers optionally sets per-layer winner
    # counts (default: cfg.k for every layer).  The config stays hashable
    # (jit-static), so the fields are coerced to tuples.
    hidden_layers: tuple[int, ...] | None = None
    k_layers: tuple[int, ...] | None = None

    def __post_init__(self):
        if self.hidden_layers is not None:
            hl = tuple(int(h) for h in self.hidden_layers)
            if not hl:
                raise ValueError("hidden_layers must be a non-empty tuple")
            if self.mode == "nld" and len(hl) > 1:
                raise ValueError("multi-layer stacks are KWN-only; the NLD "
                                 "stack is a roadmap follow-up")
            object.__setattr__(self, "hidden_layers", hl)
            object.__setattr__(self, "n_hidden", hl[-1])
        if self.k_layers is not None:
            kl = tuple(int(x) for x in self.k_layers)
            if len(kl) != len(self.layer_widths):
                raise ValueError(f"k_layers has {len(kl)} entries for "
                                 f"{len(self.layer_widths)} layers")
            object.__setattr__(self, "k_layers", kl)

    @property
    def layer_widths(self) -> tuple:
        """Hidden-layer widths, last one feeding the readout."""
        return self.hidden_layers or (self.n_hidden,)

    @property
    def layer_k(self) -> tuple:
        """Per-layer KWN winner counts."""
        return self.k_layers or (self.k,) * len(self.layer_widths)


def init_params(cfg: SNNConfig, key: jax.Array) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict[str, Any] = {
        "w_out": jax.random.normal(k3, (cfg.n_hidden, cfg.n_classes))
        / jnp.sqrt(cfg.n_hidden),
    }
    widths = cfg.layer_widths
    if cfg.mode == "nld":
        p["dend"] = dendrite_lib.dendrite_init(k1, cfg.n_in, cfg.n_hidden,
                                               cfg.n_branches)
    elif len(widths) == 1:
        # single layer: the historical RNG stream (cached models depend
        # on it byte-for-byte), w_hid a bare array
        p["w_hid"] = jax.random.normal(k1, (cfg.n_in, cfg.n_hidden)) \
            / jnp.sqrt(cfg.n_in) * 3.0
    else:
        fan_ins = (cfg.n_in,) + widths[:-1]
        keys = jax.random.split(k1, len(widths))
        p["w_hid"] = [
            jax.random.normal(kk, (f_in, w)) / jnp.sqrt(f_in) * 3.0
            for kk, f_in, w in zip(keys, fan_ins, widths)]
    return p


def _nlq_cb(cfg: SNNConfig):
    return ima_lib.nlq_codebook(cfg.code_bits, -cfg.mac_range, cfg.mac_range)


def _act_cb(cfg: SNNConfig):
    f = ima_lib.DENDRITE_ACTIVATIONS[cfg.activation]
    return ima_lib.activation_codebook(cfg.code_bits, f, -cfg.dend_range,
                                       cfg.dend_range)


def _hidden_drive_train(p, spikes, cfg: SNNConfig):
    """Differentiable (QAT/STE) hidden-layer drive for one time step.

    The NLQ ramp digitizes the *integer* MAC (twin-cell units), so the float
    MAC is divided by the per-column quantization scale before the STE ramp
    and multiplied back after — the exact silicon dataflow."""
    if cfg.mode == "nld":
        f = ima_lib.DENDRITE_ACTIVATIONS[cfg.activation]
        if cfg.train_nlq:
            return dendrite_lib.dendrite_mac(p["dend"], spikes, f=f,
                                             nl_cb=_act_cb(cfg), quantize=True)
        return dendrite_lib.dendrite_mac(p["dend"], spikes, f=f)
    return _kwn_drive_train(p["w_hid"], spikes, cfg)


def _kwn_drive_train(w_full, spikes, cfg: SNNConfig):
    """One KWN layer's QAT/STE MAC drive, for any layer of a stack."""
    w = w_full
    if cfg.weight_qat:
        w = ternary_lib.quantize_weights_ste(w)
    mac = spikes @ w
    if cfg.train_nlq:
        scale = jax.lax.stop_gradient(
            ternary_lib.quantize_weights_3bit(w_full)[1][0])  # (N,)
        mac = ima_lib.ima_quantize_ste(mac / scale, _nlq_cb(cfg)) * scale
    return mac


def forward_train(p, events, cfg: SNNConfig):
    """BPTT forward: events (B, T, N_in) -> logits (B, classes).

    Training uses dense LIF updates (top-K masking is applied at inference;
    training with the dense objective + QAT is how the silicon was trained).
    With a ``cfg.hidden_layers`` stack, each step chains the layer drives
    spike->MAC->LIF->spike; the readout reads the last layer's counts.
    Spike counts are normalized by the *actual* sequence length
    ``events.shape[1]`` (not ``cfg.n_steps``), so logits are invariant to
    the configured step count when callers pass shorter/longer sequences."""
    b, t_steps = events.shape[0], events.shape[1]
    widths = cfg.layer_widths
    multi = cfg.mode != "nld" and len(widths) > 1

    def step(carry, ev):
        vs, spk_acc = carry
        if not multi:
            drive = _hidden_drive_train(p, ev, cfg) * cfg.drive_gain
            v = cfg.beta * vs[0] + drive
            s = lif_lib.spike_fn(v, jnp.asarray(cfg.v_th1))
            v = jnp.where(s > 0, 0.0, v)
            return ((v,), spk_acc + s), None
        cur, new_vs = ev, []
        for li in range(len(widths)):
            drive = _kwn_drive_train(p["w_hid"][li], cur, cfg) * cfg.drive_gain
            v = cfg.beta * vs[li] + drive
            cur = lif_lib.spike_fn(v, jnp.asarray(cfg.v_th1))
            new_vs.append(jnp.where(cur > 0, 0.0, v))
        return (tuple(new_vs), spk_acc + cur), None

    init = (tuple(jnp.zeros((b, w)) for w in widths)
            if multi else (jnp.zeros((b, cfg.n_hidden)),),
            jnp.zeros((b, cfg.n_hidden)))
    (_, counts), _ = jax.lax.scan(step, init, jnp.moveaxis(events, 1, 0))
    return (counts / t_steps) @ p["w_out"]


def _quantized_weights(p, cfg: SNNConfig):
    w_int, scale = ternary_lib.quantize_weights_3bit(p["w_hid"])
    return w_int, scale


def forward_silicon(p, events, cfg: SNNConfig, key: jax.Array,
                    mode: str | None = None, k: int | None = None,
                    use_snl: bool | None = None,
                    noise: ima_lib.IMANoiseModel | None = None,
                    fused: bool | str = False,
                    mac_telemetry: bool = False):
    """Inference through the macro simulator (KWN Eq. 1 / NLD Eq. 2).

    ``fused`` selects the execution path:

    * ``False`` — the composed stage chain (HBM-visible intermediates);
    * ``True`` / ``"seq"`` — the time-major fused kernel: the *whole* event
      sequence runs in one Pallas launch (MAC -> IMA -> mode head -> LIF in
      one VMEM pass per step, LIF membrane carried in VMEM across T), with
      any virtual-macro tiling the layer shape needs picked automatically
      by the kernel-side tile planner;
    * ``"step"`` — the PR 1 behaviour: one fused kernel launch per scan
      step (kept for launch-overhead benchmarking).

    All fused variants are bitwise-equal to the composed path at f32 in KWN
    mode; in NLD mode they additionally quantize the branch weights onto
    the twin-cell grid (the silicon storage format), so accuracies can
    differ slightly from the float-weight composed path.

    With ``noise`` (the Fig. 7 ``IMANoiseModel``), the fused paths stay
    fused: the per-step per-column conversion-error draws — and the SNL
    sign noise — are generated *inside* the kernel by the counter PRNG,
    keyed on a seed derived from ``key``, with no pre-drawn noise tensor
    and no composed-path fallback.  Noisy ``"step"`` and ``"seq"`` draw the
    identical stream (the scan index is the counter's step word), and both
    are bitwise-equal to ``kernels.ref.fused_macro_seq_ref`` with the same
    parameters.  The noisy *composed* path keeps its historical
    ``jax.random``/PRBS draws, so noisy composed and noisy fused are
    statistically — not bitwise — equivalent.

    The fused paths are *activity-gated*: the occupancy plan of the event
    sequence is built once per sequence (``macro.plan_activity``) and the
    kernel skips MAC work for all-zero (step, row-tile, K-tile) blocks and
    bounds the KWN ramp sweep — output bits are unchanged, so gating has
    no off switch here (benchmarks A/B it at the ops layer).  Raw-MAC
    telemetry is *opt-in* (``mac_telemetry=True``): by default the fused
    kernel keeps the accumulator in VMEM scratch and never writes the
    (T, B, NC) MAC stack to HBM — inference consumes spikes and masks,
    not raw MACs, and that write was the fused step's largest dead output.

    Stacked configs (``cfg.hidden_layers`` with more than one width) route
    every ``fused`` choice through the multi-layer machinery: ``"seq"`` /
    ``"step"`` use the stacked kernel (one launch chains all layers, the
    inter-layer ternary spike tensor never leaves the chip, layer l's KWN
    winner set is layer l+1's activity plan), ``False`` composes the stage
    chain per layer.  All three agree bitwise in KWN mode; NLD stacks and
    ``mac_telemetry=True`` on stacks are unsupported (ValueError).

    Returns (logits, telemetry) where telemetry carries adc_steps per time
    step (early-stop latency), LIF update counts, SOP counts for the
    energy model, and — on the fused paths — the skipped-block ratio of
    the activity plan (the fraction of MAC blocks gating elided).  All
    rates normalize by the *actual* sequence length ``events.shape[1]``,
    never ``cfg.n_steps``.
    """
    mode = mode or cfg.mode
    k = k or cfg.k
    use_snl = cfg.use_snl if use_snl is None else use_snl
    if fused is True:
        fused = "seq"
    b, t_steps = events.shape[0], events.shape[1]
    multi = len(cfg.layer_widths) > 1
    if multi and mode != "kwn":
        raise ValueError("multi-layer stacks are KWN-only")
    mcfg = macro_lib.CIMMacroConfig(
        code_bits=cfg.code_bits,
        mac_range=cfg.mac_range if mode == "kwn" else cfg.dend_range,
        ima_noise=noise)
    lif_p = lif_lib.LIFParams(beta=cfg.beta, v_th1=cfg.v_th1, v_th2=cfg.v_th2,
                              noise_amp=cfg.noise_amp if use_snl else 0.0)
    if multi:
        ks = cfg.k_layers or (k,) * len(cfg.layer_widths)
        if fused in ("seq", "step"):
            if mac_telemetry:
                raise ValueError("mac_telemetry is single-layer only: the "
                                 "stacked kernel never writes MACs to HBM")
            return _forward_silicon_fused_multi(p, events, cfg, ks, use_snl,
                                                mcfg, lif_p, key, fused)
        if fused is not False:
            raise ValueError(f"unknown fused={fused!r}; expected False, "
                             f"True, 'step', or 'seq'")
        return _forward_silicon_composed_multi(p, events, cfg, ks, use_snl,
                                               mcfg, lif_p, key, noise)
    if fused == "seq":
        return _forward_silicon_fused_seq(p, events, cfg, mode, k, use_snl,
                                          mcfg, lif_p, key, mac_telemetry)
    if fused == "step":
        return _forward_silicon_fused(p, events, cfg, mode, k, use_snl, mcfg,
                                      lif_p, key, mac_telemetry)
    if fused is not False:
        raise ValueError(f"unknown fused={fused!r}; expected False, True, "
                         f"'step', or 'seq'")
    if mode == "kwn":
        w_int, scale = _quantized_weights(p, cfg)
        nlq = _nlq_cb(cfg)

    def step(carry, inp):
        state, spk_acc, tele = carry
        ev, kk = inp
        if mode == "nld":
            drive = macro_lib.nld_forward(ev, p["dend"], mcfg,
                                          activation=cfg.activation,
                                          quantize=True)
            mask = None
            adc_steps = jnp.full((b,), nlq_steps_full(cfg), jnp.int32)
            n_upd = jnp.full((b,), cfg.n_hidden, jnp.int32)
        else:
            mac_int = macro_lib.cim_mac(ev, w_int, mcfg, key=kk)  # int units
            if noise is not None:
                codes = ima_lib.ima_convert_noisy(mac_int, nlq, kk, noise)
                mac_q = ima_lib.ima_reconstruct(codes, nlq)
            else:
                mac_q = ima_lib.ima_quantize(mac_int, nlq)
            res = kwn_lib.kwn_select(mac_q, k, nlq)
            drive = (mac_q * scale[0]) * res.mask                 # LUT x scale
            mask = res.mask
            adc_steps = res.adc_steps
            n_upd = jnp.full((b,), k, jnp.int32)
        state, s = lif_lib.lif_step(
            state, drive * cfg.drive_gain, lif_p,
            update_mask=mask, use_snl=use_snl and mode == "kwn")
        sops = jnp.sum(jnp.abs(ev), axis=-1) * cfg.n_hidden
        tele = {
            "adc_steps": tele["adc_steps"] + adc_steps.astype(jnp.float32),
            "lif_updates": tele["lif_updates"] + n_upd.astype(jnp.float32),
            "sops": tele["sops"] + sops,
        }
        return (state, spk_acc + s, tele), None

    tele0 = {"adc_steps": jnp.zeros((b,)), "lif_updates": jnp.zeros((b,)),
             "sops": jnp.zeros((b,))}
    init = (lif_lib.lif_init((b, cfg.n_hidden)), jnp.zeros((b, cfg.n_hidden)),
            tele0)
    keys = jax.random.split(key, t_steps)
    (state, counts, tele), _ = jax.lax.scan(
        step, init, (jnp.moveaxis(events, 1, 0), keys))
    logits = (counts / t_steps) @ p["w_out"]
    tele = jax.tree.map(lambda x: x / t_steps, tele)  # per-step means
    return logits, tele


def _quantized_weight_stack(p, cfg: SNNConfig):
    """Per-layer (w_int, scale) for a ``hidden_layers`` stack."""
    return [ternary_lib.quantize_weights_3bit(w) for w in p["w_hid"]]


def _forward_silicon_composed_multi(p, events, cfg: SNNConfig, ks, use_snl,
                                    mcfg, lif_p, key, noise):
    """Composed multi-layer inference: the per-layer HBM round-trip path.

    Each time step runs the layer chain through the composed stage
    pipeline (cim_mac -> IMA -> KWN -> LIF per layer), with every
    inter-layer spike tensor materialized — the baseline the stacked fused
    kernel is benchmarked against, and (clean) its bitwise oracle at the
    model level.  Per-layer noise keys are ``fold_in(step_key, layer)``.
    """
    b, t_steps = events.shape[0], events.shape[1]
    widths = cfg.layer_widths
    w_stack = _quantized_weight_stack(p, cfg)
    nlq = _nlq_cb(cfg)

    def step(carry, inp):
        states, spk_acc, tele = carry
        ev, kk = inp
        cur, new_states = ev, []
        adc = jnp.zeros((b,), jnp.float32)
        sops = jnp.zeros((b,), jnp.float32)
        for li, (w_int, scale) in enumerate(w_stack):
            kl = jax.random.fold_in(kk, li)
            mac_int = macro_lib.cim_mac(cur, w_int, mcfg, key=kl)
            if noise is not None:
                codes = ima_lib.ima_convert_noisy(mac_int, nlq, kl, noise)
                mac_q = ima_lib.ima_reconstruct(codes, nlq)
            else:
                mac_q = ima_lib.ima_quantize(mac_int, nlq)
            res = kwn_lib.kwn_select(mac_q, ks[li], nlq)
            drive = (mac_q * scale[0]) * res.mask
            state, s = lif_lib.lif_step(
                states[li], drive * cfg.drive_gain, lif_p,
                update_mask=res.mask, use_snl=use_snl)
            new_states.append(state)
            adc = adc + res.adc_steps.astype(jnp.float32)
            sops = sops + jnp.sum(jnp.abs(cur), axis=-1) * widths[li]
            cur = s
        tele = {
            "adc_steps": tele["adc_steps"] + adc,
            "lif_updates": tele["lif_updates"] + float(sum(ks)),
            "sops": tele["sops"] + sops,
        }
        return (tuple(new_states), spk_acc + cur, tele), None

    tele0 = {"adc_steps": jnp.zeros((b,)), "lif_updates": jnp.zeros((b,)),
             "sops": jnp.zeros((b,))}
    init = (tuple(lif_lib.lif_init((b, w)) for w in widths),
            jnp.zeros((b, cfg.n_hidden)), tele0)
    keys = jax.random.split(key, t_steps)
    (_, counts, tele), _ = jax.lax.scan(
        step, init, (jnp.moveaxis(events, 1, 0), keys))
    logits = (counts / t_steps) @ p["w_out"]
    tele = jax.tree.map(lambda x: x / t_steps, tele)
    return logits, tele


def _pack_fused(p, cfg: SNNConfig, mode: str, mcfg):
    if mode == "kwn":
        w_int, scale = _quantized_weights(p, cfg)
        return macro_lib.pack_kwn_weights(w_int, scale.reshape(-1), mcfg)
    return macro_lib.pack_nld_weights(p["dend"], mcfg,
                                      activation=cfg.activation)


def _noise_seed(key: jax.Array) -> jax.Array:
    """Counter-PRNG seed word derived from the caller's JAX key."""
    return jax.random.randint(key, (), 0, jnp.iinfo(jnp.int32).max,
                              dtype=jnp.int32)


def _forward_silicon_fused(p, events, cfg: SNNConfig, mode: str, k: int,
                           use_snl: bool, mcfg, lif_p, key,
                           mac_telemetry: bool = False):
    """Per-step fused inference scan body.

    Mirrors the composed ``forward_silicon`` step exactly in the clean case
    (same PRBS state threading, same telemetry), one fused Pallas kernel
    per time step.  With ``mcfg.ima_noise`` the per-step launches pass the
    scan index as the counter step word, so the stream — and therefore
    every spike — is bitwise-identical to the one-launch ``seq`` path.
    Kept for launch-overhead benchmarking; the serving default is the
    time-major ``_forward_silicon_fused_seq``.  Each per-step launch gates
    on its own step's activity map (the T=1 slice of the sequence plan),
    so the reported skipped-block ratio matches the seq path exactly.
    """
    b = events.shape[0]
    fw = _pack_fused(p, cfg, mode, mcfg)
    snl_active = use_snl and mode == "kwn"
    noisy = mcfg.ima_noise is not None
    ima_kn = macro_lib.fused_kernel_noise(fw, mcfg)
    seed = _noise_seed(key) if noisy else jnp.int32(0)

    def step(carry, inp):
        v, prbs_state, spk_acc, tele = carry
        ev, t = inp
        if noisy:
            nz = None           # SNL noise comes from the in-kernel counter
        elif snl_active:
            prbs_state, nz = prbs_lib.prbs_noise(prbs_state, v.shape,
                                                 lif_p.noise_amp)
        else:
            nz = jnp.zeros_like(v)
        v, s, mask, steps, _ = macro_lib.fused_step(
            ev, fw, v, nz, k=k, drive_gain=cfg.drive_gain, beta=cfg.beta,
            v_th1=cfg.v_th1, v_th2=cfg.v_th2, v_reset=lif_p.v_reset,
            v_lim=lif_lib.vmem_limit(lif_p.vmem_bits),
            use_snl=snl_active, ima_noise=ima_kn,
            snl_amp=lif_p.noise_amp if (noisy and snl_active) else 0.0,
            mac_telemetry=mac_telemetry, seed=seed, step_offset=t)
        n_upd = float(k if mode == "kwn" else cfg.n_hidden)
        tele = {
            "adc_steps": tele["adc_steps"] + steps.astype(jnp.float32),
            "lif_updates": tele["lif_updates"] + n_upd,
            "sops": tele["sops"] + jnp.sum(jnp.abs(ev), -1) * cfg.n_hidden,
        }
        return (v, prbs_state, spk_acc + s, tele), None

    tele0 = {"adc_steps": jnp.zeros((b,)), "lif_updates": jnp.zeros((b,)),
             "sops": jnp.zeros((b,))}
    st0 = lif_lib.lif_init((b, cfg.n_hidden))
    init = (st0.v_mem, st0.prbs_state, jnp.zeros((b, cfg.n_hidden)), tele0)
    t_steps = events.shape[1]
    (_, _, counts, tele), _ = jax.lax.scan(
        step, init, (jnp.moveaxis(events, 1, 0),
                     jnp.arange(t_steps, dtype=jnp.int32)))
    logits = (counts / t_steps) @ p["w_out"]
    tele = jax.tree.map(lambda x: x / t_steps, tele)
    tele["skipped_block_ratio"] = _skipped_block_ratio(events, fw, cfg)
    return logits, tele


def _skipped_block_ratio(events, fw, cfg: SNNConfig) -> jax.Array:
    """Fraction of (step, row-tile, K-tile) MAC blocks gating elides,
    broadcast per request (the plan is a batch-level property — requests
    share row tiles)."""
    act = macro_lib.plan_activity(jnp.moveaxis(events, 1, 0), fw,
                                  cfg.n_hidden)
    # clip: f32 mean of an all-ones map can land a ULP past 1.0
    ratio = jnp.clip(1.0 - jnp.mean(act.astype(jnp.float32)), 0.0, 1.0)
    return jnp.full((events.shape[0],), ratio)


def _forward_silicon_fused_seq(p, events, cfg: SNNConfig, mode: str, k: int,
                               use_snl: bool, mcfg, lif_p, key,
                               mac_telemetry: bool = False):
    """Time-major fused inference: the whole event sequence in one launch.

    The T axis is folded into the Pallas grid (``macro.fused_seq``), so the
    LIF membrane never leaves VMEM between steps and the weight planes are
    staged once per sequence instead of once per step — the serving
    engine's dominant launch overhead.  In the clean case PRBS noise is
    pre-drawn with the exact LFSR sequence the per-step path threads
    through its scan, and the per-step output stacks are left-folded in
    scan order, so logits and telemetry stay bitwise-equal to the composed
    and per-step paths.  In the noisy case (``mcfg.ima_noise``) *nothing*
    is pre-drawn: both the IMA conversion error and the SNL sign noise
    come from the in-kernel counter PRNG, and the launch streams only the
    events themselves.

    The activity plan is built once per sequence here and shared between
    the kernel (scalar-prefetched occupancy gating) and the telemetry
    (skipped-block ratio) — one host-side pass over the events per batch.
    """
    b, t_steps = events.shape[0], events.shape[1]
    fw = _pack_fused(p, cfg, mode, mcfg)
    snl_active = use_snl and mode == "kwn"
    noisy = mcfg.ima_noise is not None
    ima_kn = macro_lib.fused_kernel_noise(fw, mcfg)
    seed = _noise_seed(key) if noisy else jnp.int32(0)
    ev_t = jnp.moveaxis(events, 1, 0)                      # (T, B, N_in)
    activity = macro_lib.plan_activity(ev_t, fw, cfg.n_hidden)
    st0 = lif_lib.lif_init((b, cfg.n_hidden))
    if noisy:
        noise_t = None          # all noise is generated inside the kernel
    elif snl_active:
        def draw(s, _):
            s, nz = prbs_lib.prbs_noise(s, (b, cfg.n_hidden), lif_p.noise_amp)
            return s, nz
        _, noise_t = jax.lax.scan(draw, st0.prbs_state, None, length=t_steps)
    else:
        noise_t = jnp.zeros((t_steps, b, cfg.n_hidden))
    _, spk_t, _, steps_t, _ = macro_lib.fused_seq(
        ev_t, fw, st0.v_mem, noise_t, k=k, drive_gain=cfg.drive_gain,
        beta=cfg.beta, v_th1=cfg.v_th1, v_th2=cfg.v_th2,
        v_reset=lif_p.v_reset,
        v_lim=lif_lib.vmem_limit(lif_p.vmem_bits),
        use_snl=snl_active, ima_noise=ima_kn,
        snl_amp=lif_p.noise_amp if (noisy and snl_active) else 0.0,
        activity=activity, mac_telemetry=mac_telemetry, seed=seed)
    n_upd = float(k if mode == "kwn" else cfg.n_hidden)
    sops_t = jnp.sum(jnp.abs(ev_t), axis=-1) * cfg.n_hidden   # (T, B)

    def fold(acc, xs):
        counts, tele = acc
        spk, steps, sops = xs
        tele = {
            "adc_steps": tele["adc_steps"] + steps.astype(jnp.float32),
            "lif_updates": tele["lif_updates"] + n_upd,
            "sops": tele["sops"] + sops,
        }
        return (counts + spk, tele), None

    tele0 = {"adc_steps": jnp.zeros((b,)), "lif_updates": jnp.zeros((b,)),
             "sops": jnp.zeros((b,))}
    (counts, tele), _ = jax.lax.scan(
        fold, (jnp.zeros((b, cfg.n_hidden)), tele0),
        (spk_t, steps_t, sops_t))
    logits = (counts / t_steps) @ p["w_out"]
    tele = jax.tree.map(lambda x: x / t_steps, tele)
    tele["skipped_block_ratio"] = jnp.full(
        (b,), jnp.clip(1.0 - jnp.mean(activity.astype(jnp.float32)),
                       0.0, 1.0))
    return logits, tele


class SiliconStreamState(NamedTuple):
    """Device-resident per-slot state for step-resumable fused inference.

    One row per serving slot; this is the SNN analog of an LM engine's
    KV cache.  ``v`` is the LIF membrane the fused kernel carries in VMEM
    within a round and this struct carries *across* rounds; the remaining
    fields are the per-request accumulators and noise-stream bookkeeping
    that let a request's results come out bitwise-identical to a one-shot
    batch-1 ``forward_silicon(fused="seq")`` run no matter how many rounds
    its sequence was split over or which requests shared the batch.
    """

    v: jax.Array           # (S, N) f32 LIF membrane
    prbs: jax.Array        # (S,) uint32 per-slot PRBS LFSR state (clean SNL)
    counts: jax.Array      # (S, N) f32 spike-count accumulator
    adc: jax.Array         # (S,) f32 summed early-stop ADC ramp steps
    sops: jax.Array        # (S,) f32 summed synaptic operations
    skip_acc: jax.Array    # (S,) f32 summed per-step skipped-block ratio
    steps_done: jax.Array  # (S,) i32 time steps completed
    length: jax.Array      # (S,) i32 request sequence length
    seed: jax.Array        # (S,) i32 per-request counter-PRNG seed word


def silicon_stream_init(cfg: SNNConfig, slots: int) -> SiliconStreamState:
    """Fresh all-idle slot state for ``forward_silicon_stream``."""
    n = cfg.n_hidden
    zf = jnp.zeros((slots,), jnp.float32)
    return SiliconStreamState(
        v=jnp.zeros((slots, n), jnp.float32),
        prbs=jnp.full((slots,), prbs_lib.lfsr_init(1)),
        counts=jnp.zeros((slots, n), jnp.float32),
        adc=zf, sops=zf, skip_acc=zf,
        steps_done=jnp.zeros((slots,), jnp.int32),
        length=jnp.zeros((slots,), jnp.int32),
        seed=jnp.zeros((slots,), jnp.int32))


@jax.jit
def silicon_stream_admit(state: SiliconStreamState, mask, lengths,
                         seeds) -> SiliconStreamState:
    """Reset the masked slots for newly admitted requests.

    ``mask`` (S,) bool selects the slots being (re)admitted; their
    membrane, accumulators, and PRBS state return to the exact
    ``lif_init`` starting point a one-shot run begins from.  ``lengths``
    and ``seeds`` are full (S,) vectors (non-admitted slots just carry
    their previous values through).
    """
    mask = jnp.asarray(mask)
    m1 = mask[:, None]
    zf = jnp.float32(0.0)
    return SiliconStreamState(
        v=jnp.where(m1, zf, state.v),
        prbs=jnp.where(mask, prbs_lib.lfsr_init(1), state.prbs),
        counts=jnp.where(m1, zf, state.counts),
        adc=jnp.where(mask, zf, state.adc),
        sops=jnp.where(mask, zf, state.sops),
        skip_acc=jnp.where(mask, zf, state.skip_acc),
        steps_done=jnp.where(mask, 0, state.steps_done),
        length=jnp.asarray(lengths, jnp.int32),
        seed=jnp.asarray(seeds, jnp.int32))


class SlotCheckpoint(NamedTuple):
    """Host-side snapshot of one serving slot's mid-flight stream state.

    Everything a preempted request needs to resume bitwise-exactly, pulled
    off device with ``silicon_stream_save`` and pushed back with
    ``silicon_stream_restore`` — into *any* free slot, not necessarily the
    one it left.  Relocatability holds because nothing in the stream's
    noise keying sees the physical slot index: the noisy counter-PRNG
    stream is keyed on ``(seed, absolute step, row 0)`` through the
    kernel's ``row_ctl`` lane (``macro.stream_row_ctl``), and the clean
    SNL stream is the per-slot PRBS LFSR word captured here.  The
    membrane ``v`` and the accumulators are exact f32/i32 copies, so a
    restore followed by the remaining rounds reproduces the uninterrupted
    run bit for bit.
    """

    v: np.ndarray          # (N,) f32 LIF membrane at the preemption point
    prbs: int              # uint32 PRBS LFSR word (clean-path SNL stream)
    counts: np.ndarray     # (N,) f32 spike-count accumulator
    adc: float             # summed early-stop ADC ramp steps so far
    sops: float            # summed synaptic operations so far
    skip_acc: float        # summed per-step skipped-block ratio so far
    steps_done: int        # absolute stream offset to resume at
    length: int            # request sequence length
    seed: int              # per-request counter-PRNG seed word


def silicon_stream_save(state: SiliconStreamState,
                        slot: int) -> SlotCheckpoint:
    """Checkpoint slot ``slot`` to host memory (one device->host pull).

    The slot's rows are copied out as-is; the device state is left
    untouched (the engine re-admits over the stale rows, which
    ``silicon_stream_admit`` / ``silicon_stream_restore`` fully reset).

    The pull is wrapped in a ``checkpoint_save`` span on the
    ``transfer`` track (with the payload byte count) — host<->device
    checkpoint traffic is the ROADMAP's named TPU bottleneck candidate,
    so it gets a first-class lane in every exported trace.
    """
    tr = obs_trace.get_tracer()
    span = tr.begin("checkpoint_save", track="transfer")
    ckpt = SlotCheckpoint(
        v=np.asarray(state.v[slot]),
        prbs=int(np.asarray(state.prbs[slot])),
        counts=np.asarray(state.counts[slot]),
        adc=float(np.asarray(state.adc[slot])),
        sops=float(np.asarray(state.sops[slot])),
        skip_acc=float(np.asarray(state.skip_acc[slot])),
        steps_done=int(np.asarray(state.steps_done[slot])),
        length=int(np.asarray(state.length[slot])),
        seed=int(np.asarray(state.seed[slot])))
    if span is not None:
        tr.end(span, args={"slot": int(slot),
                           "bytes": checkpoint_nbytes(ckpt),
                           "direction": "device_to_host"})
    return ckpt


def checkpoint_nbytes(ckpt: SlotCheckpoint) -> int:
    """Payload size of one slot checkpoint in bytes (arrays + scalars).

    Scalars travel as one machine word each; this is the quantity the
    transfer spans report and the engine's bandwidth math would use on a
    real part, so it lives next to the checkpoint type rather than being
    re-derived in tooling.
    """
    scalar_bytes = 8 * (len(ckpt) - 2)   # all fields except the two arrays
    return int(ckpt.v.nbytes + ckpt.counts.nbytes + scalar_bytes)


@jax.jit
def _stream_restore(state: SiliconStreamState, slot, v, prbs, counts, adc,
                    sops, skip_acc, steps_done, length,
                    seed) -> SiliconStreamState:
    return SiliconStreamState(
        v=state.v.at[slot].set(v),
        prbs=state.prbs.at[slot].set(prbs),
        counts=state.counts.at[slot].set(counts),
        adc=state.adc.at[slot].set(adc),
        sops=state.sops.at[slot].set(sops),
        skip_acc=state.skip_acc.at[slot].set(skip_acc),
        steps_done=state.steps_done.at[slot].set(steps_done),
        length=state.length.at[slot].set(length),
        seed=state.seed.at[slot].set(seed))


def silicon_stream_restore(state: SiliconStreamState, slot: int,
                           ckpt: SlotCheckpoint) -> SiliconStreamState:
    """Restore a ``SlotCheckpoint`` into slot ``slot`` (any free slot).

    The inverse of ``silicon_stream_save``: one jitted scatter writes the
    checkpoint's membrane, PRBS word, accumulators, and stream position
    into the slot's rows.  The next ``forward_silicon_stream`` round picks
    the stream up at ``ckpt.steps_done`` — the ``row_ctl`` lane replays
    the noisy counter stream from exactly that offset and the restored
    LFSR word continues the clean SNL stream, so the request's final
    results are bitwise-identical to never having been preempted
    (pinned by tests/test_serve_preempt.py across slots, co-residents,
    and non-round-aligned offsets).

    Wrapped in a ``checkpoint_restore`` span on the ``transfer`` track,
    mirroring ``silicon_stream_save`` — note the span covers the
    host->device *dispatch* (the scatter is jitted and asynchronous), so
    on real hardware the device-side cost shows up in the XLA trace the
    optional ``jax.profiler`` passthrough lines spans up with.
    """
    tr = obs_trace.get_tracer()
    span = tr.begin("checkpoint_restore", track="transfer")
    state = _stream_restore(
        state, jnp.int32(slot), jnp.asarray(ckpt.v, jnp.float32),
        jnp.uint32(ckpt.prbs), jnp.asarray(ckpt.counts, jnp.float32),
        jnp.float32(ckpt.adc), jnp.float32(ckpt.sops),
        jnp.float32(ckpt.skip_acc), jnp.int32(ckpt.steps_done),
        jnp.int32(ckpt.length), jnp.int32(ckpt.seed))
    if span is not None:
        tr.end(span, args={"slot": int(slot),
                           "bytes": checkpoint_nbytes(ckpt),
                           "direction": "host_to_device"})
    return state


@functools.partial(jax.jit, static_argnames=("cfg", "noise"))
def forward_silicon_stream(p, events, cfg: SNNConfig,
                           state: SiliconStreamState,
                           noise: ima_lib.IMANoiseModel | None = None
                           ) -> SiliconStreamState:
    """One continuous-batching round: advance every slot by R time steps.

    ``events`` is the *time-major* (R, S, N_in) round block the engine
    staged — slot s carries steps ``[steps_done[s], steps_done[s] + R)``
    of its request's event stream, zero-padded past the request's end.
    R is whatever leading extent the caller staged: the engine's regular
    cadence uses ``round_steps``, and *partial* rounds (R <
    ``round_steps``, the preemption path that stops a stream at a
    non-round-aligned offset) are the same launch at a shorter extent —
    each distinct R compiles one jit entry, bounded by ``round_steps``.
    Runs one fused time-major kernel launch (LIF membrane in VMEM within
    the round, carried across rounds through ``state.v``) and folds this
    round's spikes/ADC-steps/SOPs into the per-slot accumulators, masking
    out steps beyond each request's true length so every statistic
    normalizes by the request's own sequence — never the round count.

    Bitwise parity with one-shot ``forward_silicon(..., fused="seq")`` on
    a batch of one, clean and noisy, is by construction:

    * noise streams are per-slot — the counter PRNG is keyed through the
      kernel's ``row_ctl`` path on ``(state.seed, absolute step, row 0)``
      and the clean-path SNL PRBS is a per-slot LFSR drawing
      ``cfg.n_hidden`` bits per step from the ``lif_init`` seed — so each
      slot consumes exactly the stream a batch-1 run would;
    * every accumulated quantity (spike counts, ADC steps, SOPs) is an
      integer-valued f32 well under 2^24, so fold order cannot change a
      bit.

    The per-round activity plan spans all co-resident slots (gating is
    output-invariant; only the work changes), and ``skip_acc`` integrates
    the plan's skipped-block ratio over each request's active steps.
    Single-layer configs only — the engine serves multi-layer stacks
    through the legacy drain path.
    """
    if len(cfg.layer_widths) > 1:
        raise ValueError("forward_silicon_stream is single-layer only; "
                         "serve stacks through the legacy drain path")
    mode, k = cfg.mode, cfg.k
    mcfg = macro_lib.CIMMacroConfig(
        code_bits=cfg.code_bits,
        mac_range=cfg.mac_range if mode == "kwn" else cfg.dend_range,
        ima_noise=noise)
    lif_p = lif_lib.LIFParams(
        beta=cfg.beta, v_th1=cfg.v_th1, v_th2=cfg.v_th2,
        noise_amp=cfg.noise_amp if cfg.use_snl else 0.0)
    fw = _pack_fused(p, cfg, mode, mcfg)
    snl_active = cfg.use_snl and mode == "kwn"
    noisy = noise is not None
    ima_kn = macro_lib.fused_kernel_noise(fw, mcfg)
    r, slots = events.shape[0], events.shape[1]
    activity = macro_lib.plan_activity(events, fw, cfg.n_hidden)
    new_prbs = state.prbs
    if noisy:
        noise_t = None          # all noise is generated inside the kernel
    elif snl_active:
        def slot_draw(s0):
            def draw(s, _):
                s, nz = prbs_lib.prbs_noise(s, (cfg.n_hidden,),
                                            lif_p.noise_amp)
                return s, nz
            return jax.lax.scan(draw, s0, None, length=r)
        new_prbs, nz = jax.vmap(slot_draw)(state.prbs)
        noise_t = jnp.moveaxis(nz, 0, 1)                   # (R, S, N)
    else:
        noise_t = jnp.zeros((r, slots, cfg.n_hidden))
    # Per-slot noise-stream control: each slot replays the stream of its
    # own batch-1 run — its request seed, its absolute step, row id 0.
    row_ctl = macro_lib.stream_row_ctl(state.seed, state.steps_done)
    v_out, spk_t, _, steps_t, _ = macro_lib.fused_seq(
        events, fw, state.v, noise_t, k=k, drive_gain=cfg.drive_gain,
        beta=cfg.beta, v_th1=cfg.v_th1, v_th2=cfg.v_th2,
        v_reset=lif_p.v_reset,
        v_lim=lif_lib.vmem_limit(lif_p.vmem_bits),
        use_snl=snl_active, ima_noise=ima_kn,
        snl_amp=lif_p.noise_amp if (noisy and snl_active) else 0.0,
        activity=activity, mac_telemetry=False, row_ctl=row_ctl)
    iota = jnp.arange(r, dtype=jnp.int32)[:, None]
    active = (state.steps_done[None, :] + iota) < state.length[None, :]
    af = active.astype(jnp.float32)                        # (R, S)
    counts = state.counts + jnp.sum(spk_t * af[:, :, None], axis=0)
    adc = state.adc + jnp.sum(steps_t.astype(jnp.float32) * af, axis=0)
    sops = state.sops + jnp.sum(
        jnp.sum(jnp.abs(events), -1) * af, axis=0) * cfg.n_hidden
    ratio = jnp.clip(1.0 - jnp.mean(activity.astype(jnp.float32)), 0.0, 1.0)
    skip_acc = state.skip_acc + ratio * jnp.sum(af, axis=0)
    steps_done = jnp.minimum(state.steps_done + r, state.length)
    return SiliconStreamState(v=v_out, prbs=new_prbs, counts=counts,
                              adc=adc, sops=sops, skip_acc=skip_acc,
                              steps_done=steps_done, length=state.length,
                              seed=state.seed)


def _pack_fused_stack(p, cfg: SNNConfig, mcfg):
    w_ints, scales = [], []
    for w_int, scale in _quantized_weight_stack(p, cfg):
        w_ints.append(w_int)
        scales.append(scale.reshape(-1))
    return macro_lib.pack_kwn_stack(w_ints, scales, mcfg)


def _noise_seeds(key: jax.Array, n_layers: int) -> jax.Array:
    """Per-layer counter seeds: distinct words so layer noise streams
    never collide (the stacked kernel's ctl row)."""
    return jax.random.randint(key, (n_layers,), 0,
                              jnp.iinfo(jnp.int32).max, dtype=jnp.int32)


def _forward_silicon_fused_multi(p, events, cfg: SNNConfig, ks, use_snl,
                                 mcfg, lif_p, key, cadence: str):
    """Stacked fused inference: L macro layers chained on-chip.

    ``cadence="seq"`` runs the whole sequence and the whole stack in ONE
    Pallas launch (``macro.fused_multi_seq``): per-layer membranes live in
    VMEM across time steps and the inter-layer ternary spike tensors never
    reach HBM — layer l's KWN winner set IS layer l+1's activity plan,
    evaluated in-kernel (only layer 0 gates on the host occupancy map).
    ``cadence="step"`` launches the stack once per time step (launch-
    overhead benchmarking); both draw identical noise streams and are
    bitwise-equal.

    Hidden-layer activity is reported through telemetry only (per-layer
    spike counts for SOPs, per-layer occupancy counters for the
    skipped-block ratio) — the spike planes themselves stay on-chip.
    """
    b, t_steps = events.shape[0], events.shape[1]
    widths = cfg.layer_widths
    n_layers = len(widths)
    stack = _pack_fused_stack(p, cfg, mcfg)
    snl_active = use_snl
    noisy = mcfg.ima_noise is not None
    ima_kn = macro_lib.fused_kernel_noise(stack[0], mcfg)
    seeds = (_noise_seeds(key, n_layers) if noisy
             else jnp.zeros((n_layers,), jnp.int32))
    ev_t = jnp.moveaxis(events, 1, 0)                     # (T, B, N_in)
    v0s = [lif_lib.lif_init((b, w)).v_mem for w in widths]
    if noisy or not snl_active:
        noises = None if noisy else [jnp.zeros((t_steps, b, w))
                                     for w in widths]
        prbs0 = None
    else:
        # pre-draw each layer's PRBS stream exactly as the composed path's
        # per-layer LIF states thread it (bitwise parity in the clean case)
        noises, prbs0 = [], []
        for w in widths:
            st = lif_lib.lif_init((b, w))
            prbs0.append(st.prbs_state)

            def draw(s, _, w=w):
                s, nz = prbs_lib.prbs_noise(s, (b, w), lif_p.noise_amp)
                return s, nz

            _, nz_t = jax.lax.scan(draw, st.prbs_state, None, length=t_steps)
            noises.append(nz_t)
    kw = dict(ks=tuple(ks), drive_gain=cfg.drive_gain, beta=cfg.beta,
              v_th1=cfg.v_th1, v_th2=cfg.v_th2, v_reset=lif_p.v_reset,
              v_lim=lif_lib.vmem_limit(lif_p.vmem_bits), use_snl=snl_active,
              ima_noise=ima_kn,
              snl_amp=lif_p.noise_amp if (noisy and snl_active) else 0.0,
              seeds=seeds)
    if cadence == "seq":
        out = macro_lib.fused_multi_seq(ev_t, stack, v0s, noises, **kw)
        spk_t = out.spikes                                  # (T, B, N_last)
        steps_t = [s for s in out.steps]                    # L x (T, B)
        cnts_t = [c for c in out.spike_counts]              # L x (T, B)
        occ_total = sum(jnp.sum(o) for o in out.occupancy)
        total_blocks = out.total_blocks
    else:
        spk_steps, steps_steps, cnts_steps = [], [], []
        occ_total, total_blocks = jnp.int32(0), 0
        vs, prbs = v0s, prbs0
        for t in range(t_steps):
            if noises is None:
                nz = None
            elif prbs is None:
                nz = [n[t:t + 1] for n in noises]
            else:
                nz, new_prbs = [], []
                for li, w in enumerate(widths):
                    s, nz_l = prbs_lib.prbs_noise(prbs[li], (b, w),
                                                  lif_p.noise_amp)
                    new_prbs.append(s)
                    nz.append(nz_l[None])
                prbs = new_prbs
            out = macro_lib.fused_multi_seq(ev_t[t:t + 1], stack, vs, nz,
                                            step_offset=t, **kw)
            vs = list(out.v_outs)
            spk_steps.append(out.spikes[0])
            steps_steps.append([s[0] for s in out.steps])
            cnts_steps.append([c[0] for c in out.spike_counts])
            occ_total = occ_total + sum(jnp.sum(o) for o in out.occupancy)
            total_blocks += out.total_blocks
        spk_t = jnp.stack(spk_steps)
        steps_t = [jnp.stack([s[li] for s in steps_steps])
                   for li in range(n_layers)]
        cnts_t = [jnp.stack([c[li] for c in cnts_steps])
                  for li in range(n_layers)]
    counts = jnp.sum(spk_t, axis=0)
    logits = (counts / t_steps) @ p["w_out"]
    adc = sum(jnp.sum(s.astype(jnp.float32), axis=0) for s in steps_t)
    sops = jnp.sum(jnp.sum(jnp.abs(ev_t), axis=-1).astype(jnp.float32),
                   axis=0) * widths[0]
    for li in range(1, n_layers):
        sops = sops + jnp.sum(cnts_t[li - 1], axis=0) * widths[li]
    tele = {
        "adc_steps": adc / t_steps,
        "lif_updates": jnp.full((b,), float(sum(ks))),
        "sops": sops / t_steps,
        "skipped_block_ratio": jnp.full(
            (b,), jnp.clip(1.0 - occ_total.astype(jnp.float32)
                           / total_blocks, 0.0, 1.0)),
    }
    return logits, tele


def nlq_steps_full(cfg: SNNConfig) -> int:
    return 2 ** cfg.code_bits - 1


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

def loss_fn(p, events, labels, cfg: SNNConfig, seed=None, *,
            silicon: bool = False, noise: ima_lib.IMANoiseModel | None = None,
            kwn_relax: float | None = None, remat: bool = False):
    """Cross-entropy loss; ``silicon=True`` differentiates *through* the
    fused macro kernel (surrogate backward) instead of the dense-f32
    software path — see ``repro.train.silicon``.  ``seed`` (f32 scalar)
    keys the in-kernel counter noise on the silicon path; ``noise`` (the
    Fig. 7 model) makes it noise-aware QAT."""
    if silicon:
        from repro.train import silicon as silicon_lib
        if kwn_relax is None:
            kwn_relax = silicon_lib.DEFAULT_KWN_RELAX
        logits = silicon_lib.forward_logits(
            p, events, cfg,
            jnp.float32(0.0) if seed is None else seed,
            noise=noise, kwn_relax=kwn_relax, remat=remat)
    else:
        logits = forward_train(p, events, cfg)
    lse = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lse, labels[:, None], 1))


@functools.partial(jax.jit, static_argnames=(
    "cfg", "silicon", "noise", "kwn_relax", "remat"), donate_argnums=(0, 1))
def train_step(p, opt_m, events, labels, cfg: SNNConfig, lr, seed=None, *,
               silicon: bool = False, noise=None, kwn_relax=None,
               remat: bool = False):
    """One SGD-momentum step.  ``p``/``opt_m`` are donated: the optimizer
    state updates in place instead of copying every buffer per step (the
    donation engages on TPU/GPU; the CPU test container aliases where it
    can).  Callers must rebind both, as ``train`` does."""
    loss, g = jax.value_and_grad(loss_fn)(
        p, events, labels, cfg, seed, silicon=silicon, noise=noise,
        kwn_relax=kwn_relax, remat=remat)
    opt_m = jax.tree.map(lambda m, gg: 0.9 * m + gg, opt_m, g)
    p = jax.tree.map(lambda pp, m: pp - lr * m, p, opt_m)
    return p, opt_m, loss


def train(cfg: SNNConfig, dataset, n_steps: int = 300, batch: int = 64,
          seed: int = 0, lr: float = 0.05, *, silicon: bool = False,
          noise: ima_lib.IMANoiseModel | None = None,
          kwn_relax: float | None = None, remat: bool = False,
          params=None):
    """Plain SGD-momentum.  NOTE: the quadratic-NLD cell degrades if trained
    far past convergence (ramp-knee gradient spikes), so callers use per-cell
    step budgets (benchmarks/_snn_cache.py) instead of decay/clipping — both
    were tried and slowed the well-behaved cells more than they helped
    (recorded in EXPERIMENTS.md).

    ``silicon=True`` trains through the fused macro kernel with the
    surrogate backward (KWN mode only); with ``noise`` it is noise-aware
    QAT — every optimization step draws a fresh counter seed, so the model
    sees a fresh silicon-noise instance per step.  ``params`` warm-starts
    from an existing parameter tree (the software pre-train -> silicon
    fine-tune recipe of ``examples/train_snn_events.py``); the tree is
    copied first because ``train_step`` donates its arguments.

    Losses are accumulated as device arrays and converted once at the end —
    the old per-step ``float(loss)`` forced a host sync on every iteration,
    serializing dispatch against compute.
    """
    key = jax.random.PRNGKey(seed)
    if params is None:
        p = init_params(cfg, key)
    else:
        p = jax.tree.map(jnp.asarray, params)
        p = jax.tree.map(lambda x: x + 0, p)   # fresh buffers (donation-safe)
    opt_m = jax.tree.map(jnp.zeros_like, p)
    losses = []
    for i in range(n_steps):
        key, sub = jax.random.split(key)
        step_seed = None
        if silicon:
            # Split the *batch* key further rather than consuming more of
            # the main stream: the legacy (software-path) batch sequence
            # for a given seed must stay byte-identical to pre-silicon
            # runs (cached models, recorded accuracies).
            from repro.train import silicon as silicon_lib
            sub, kseed = jax.random.split(sub)
            step_seed = silicon_lib.step_seed(kseed)
        ev, lab = dataset.sample(sub, batch)
        p, opt_m, loss = train_step(p, opt_m, ev, lab, cfg,
                                    jnp.float32(lr), step_seed,
                                    silicon=silicon, noise=noise,
                                    kwn_relax=kwn_relax, remat=remat)
        losses.append(loss)                    # device array: no host sync
    return p, [float(x) for x in jnp.stack(losses)]


def evaluate(p, cfg: SNNConfig, dataset, key: jax.Array, n_batches: int = 10,
             batch: int = 128, **silicon_kwargs):
    accs, teles = [], []
    for i in range(n_batches):
        key, k1, k2 = jax.random.split(key, 3)
        ev, lab = dataset.sample(k1, batch)
        logits, tele = forward_silicon(p, ev, cfg, k2, **silicon_kwargs)
        accs.append(float(jnp.mean(jnp.argmax(logits, -1) == lab)))
        teles.append(tele)
    tele = jax.tree.map(lambda *xs: float(jnp.mean(jnp.stack(xs))), *teles)
    return sum(accs) / len(accs), tele
