"""GQA attention: chunked online-softmax for long-context train/prefill,
cache-based decode (split-KV friendly), local sliding-window variant,
logit softcap (gemma2), QKV bias (qwen2.5).

Memory behaviour: training/prefill attention is *blockwise* — a lax.scan over
KV chunks carrying (acc, row-max, row-sum) — so the (S, S) score matrix never
materializes; peak activation is O(S * chunk).  For sliding-window layers the
chunk equals the window and only the diagonal + previous block are computed
(flops-optimal for w <= chunk).

Decode attends a single query against the full cache; with the cache sequence
dim sharded over "model" (dist rules: "cache_seq"), GSPMD turns the softmax
into the FlashDecoding-style split-KV pattern (partial max/sum + all-reduce).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.nn import layers

NEG_INF = -1e30


def attention_specs(d_model: int, n_heads: int, n_kv: int, head_dim: int,
                    qkv_bias: bool = False, dtype=jnp.float32) -> dict:
    return {
        "wq": layers.linear_spec(d_model, n_heads * head_dim, "embed", "heads",
                                 bias=qkv_bias, dtype=dtype),
        "wk": layers.linear_spec(d_model, n_kv * head_dim, "embed", "kv_heads",
                                 bias=qkv_bias, dtype=dtype),
        "wv": layers.linear_spec(d_model, n_kv * head_dim, "embed", "kv_heads",
                                 bias=qkv_bias, dtype=dtype),
        "wo": layers.linear_spec(n_heads * head_dim, d_model, "heads", "embed",
                                 dtype=dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=-2)


def _chunk_attn_step(q, k_c, v_c, mask, softcap, scale):
    """q: (B, cq, H, hd); k_c/v_c: (B, ck, H, hd); mask: (cq, ck) or None.
    Returns unnormalized (scores_exp @ v, row_max, row_sum)."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_c).astype(jnp.float32) * scale
    s = layers.softcap(s, softcap)
    if mask is not None:
        s = jnp.where(mask[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                               # (B,H,cq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v_c.dtype), v_c)
    return o, m, l


def blockwise_attention(q, k, v, *, causal: bool = True,
                        window: int | None = None,
                        softcap: float | None = None,
                        chunk: int = 1024) -> jax.Array:
    """q,k,v: (B, S, H, hd) (kv already head-repeated). Returns (B, S, H, hd)."""
    b, s, h, hd = q.shape
    scale = 1.0 / (hd ** 0.5)

    if window is not None and window < s:
        return _sliding_window_attention(q, k, v, window=window,
                                         softcap=softcap, scale=scale)

    if s <= chunk:
        mask = None
        if causal:
            mask = jnp.tril(jnp.ones((s, s), bool))
        o, m, l = _chunk_attn_step(q, k, v, mask, softcap, scale)
        return o / jnp.transpose(l, (0, 2, 1))[..., None].astype(o.dtype)

    n_chunks = s // chunk
    assert s % chunk == 0, (s, chunk)
    qc = q.reshape(b, n_chunks, chunk, h, hd)
    kc = k.reshape(b, n_chunks, chunk, h, hd)
    vc = v.reshape(b, n_chunks, chunk, h, hd)

    q_pos = jnp.arange(chunk)

    def outer(qi, q_blk):
        """Online softmax over all KV chunks for one Q chunk."""
        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def inner(carry, kv):
            acc, m_run, l_run = carry
            kj, (k_blk, v_blk) = kv
            if causal:
                # whole-block relationship: kj < qi full, kj == qi diagonal,
                # kj > qi masked out entirely.
                pos_mask = (qi * chunk + q_pos[:, None]) >= (kj * chunk + q_pos[None, :])
            else:
                pos_mask = jnp.ones((chunk, chunk), bool)
            o, m, l = _chunk_attn_step(q_blk, k_blk, v_blk, pos_mask, softcap,
                                       scale)
            m_new = jnp.maximum(m_run, m)
            alpha = jnp.exp(m_run - m_new)
            beta = jnp.exp(m - m_new)
            l_new = l_run * alpha + l * beta
            acc = (acc * jnp.transpose(alpha, (0, 2, 1))[..., None].astype(acc.dtype)
                   + o * jnp.transpose(beta, (0, 2, 1))[..., None].astype(o.dtype))
            return (acc, m_new, l_new), None

        init = (jnp.zeros((b, chunk, h, hd), q.dtype),
                jnp.full((b, h, chunk), NEG_INF, jnp.float32),
                jnp.zeros((b, h, chunk), jnp.float32))
        (acc, m_run, l_run), _ = jax.lax.scan(
            inner, init, (jnp.arange(n_chunks), (jnp.moveaxis(kc, 1, 0),
                                                 jnp.moveaxis(vc, 1, 0))))
        return acc / jnp.transpose(l_run, (0, 2, 1))[..., None].astype(acc.dtype)

    out = jax.lax.map(jax.checkpoint(lambda args: outer(*args)),
                      (jnp.arange(n_chunks), jnp.moveaxis(qc, 1, 0)))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)


def _sliding_window_attention(q, k, v, *, window: int, softcap, scale):
    """Exact sliding-window causal attention for w <= block: each block
    attends to itself (causal) + the previous block (banded)."""
    b, s, h, hd = q.shape
    blk = window
    assert s % blk == 0, (s, blk)
    n = s // blk
    qb = q.reshape(b, n, blk, h, hd)
    kb = k.reshape(b, n, blk, h, hd)
    vb = v.reshape(b, n, blk, h, hd)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    prev_valid = jnp.arange(n) > 0                        # block 0 has no prev

    i = jnp.arange(blk)
    # diagonal block: causal within block
    diag_mask = i[:, None] >= i[None, :]
    # previous block: position q_i attends k_j when (q_i + blk - k_j) < window
    prev_mask = (i[:, None] + blk - i[None, :]) < window

    @jax.checkpoint
    def per_block(args):
        q_blk, k_d, v_d, k_p, v_p, has_prev = args
        o1, m1, l1 = _chunk_attn_step(q_blk, k_d, v_d, diag_mask, softcap, scale)
        pm = prev_mask & has_prev
        o2, m2, l2 = _chunk_attn_step(q_blk, k_p, v_p, pm, softcap, scale)
        m = jnp.maximum(m1, m2)
        a1, a2 = jnp.exp(m1 - m), jnp.exp(m2 - m)
        l = l1 * a1 + l2 * a2
        o = (o1 * jnp.transpose(a1, (0, 2, 1))[..., None].astype(o1.dtype)
             + o2 * jnp.transpose(a2, (0, 2, 1))[..., None].astype(o2.dtype))
        return o / jnp.transpose(l, (0, 2, 1))[..., None].astype(o.dtype)

    out = jax.lax.map(per_block, (jnp.moveaxis(qb, 1, 0), jnp.moveaxis(kb, 1, 0),
                                  jnp.moveaxis(vb, 1, 0), jnp.moveaxis(k_prev, 1, 0),
                                  jnp.moveaxis(v_prev, 1, 0), prev_valid))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)


# ---------------------------------------------------------------------------
# Full layer forward (train/prefill)
# ---------------------------------------------------------------------------

def mha(p: dict, x: jax.Array, positions: jax.Array, *, n_heads: int,
        n_kv: int, head_dim: int, causal: bool = True,
        window: int | None = None, attn_softcap: float | None = None,
        rope_theta: float = 10000.0, chunk: int = 1024,
        use_rope: bool = True, return_kv: bool = False):
    q = _split_heads(layers.linear(p["wq"], x), n_heads, head_dim)
    k = _split_heads(layers.linear(p["wk"], x), n_kv, head_dim)
    v = _split_heads(layers.linear(p["wv"], x), n_kv, head_dim)
    if use_rope:
        q = layers.rope(q, positions, rope_theta)
        k = layers.rope(k, positions, rope_theta)
    kv = (k, v)
    n_rep = n_heads // n_kv
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    o = blockwise_attention(q, k, v, causal=causal, window=window,
                            softcap=attn_softcap, chunk=chunk)
    out = layers.linear(p["wo"], o.reshape(*x.shape[:-1], n_heads * head_dim))
    if return_kv:
        return out, kv
    return out


def prefill_cache_from_kv(k: jax.Array, v: jax.Array,
                          window: int | None) -> dict:
    """Turn prefill-computed (roped) K/V into the decode cache layout.

    Global layers: the cache is just (k, v).  Local layers: keep the last
    ``window`` positions arranged in ring-buffer order (slot = pos % window)
    with their absolute positions, matching _ring_decode."""
    s = k.shape[1]
    if window is None or window >= s:
        return {"k": k, "v": v}
    pos = jnp.arange(s - window, s)
    slots = pos % window
    inv = jnp.argsort(slots)
    k_ring = k[:, s - window:][:, inv]
    v_ring = v[:, s - window:][:, inv]
    slot_pos = jnp.broadcast_to(pos[inv], (k.shape[0], window)).astype(jnp.int32)
    return {"k": k_ring, "v": v_ring, "slot_pos": slot_pos}


def mha_decode_quant(p: dict, x: jax.Array, cache: dict, pos: jax.Array, *,
                     n_heads: int, n_kv: int, head_dim: int,
                     kv_quant: str, attn_softcap: float | None = None,
                     rope_theta: float = 10000.0) -> tuple[jax.Array, dict]:
    """Decode against a quantized KV cache (§Perf: NLQ-for-KV, int8/int4).

    Payload + per-(pos, head) scale are stored; K/V dequantize to bf16 right
    before the attention einsums.  HBM traffic for the cache drops 2x/4x —
    the dominant term of the decode roofline."""
    from repro.nn import kvq
    b = x.shape[0]
    s_max = cache["k"].shape[1]
    q = _split_heads(layers.linear(p["wq"], x), n_heads, head_dim)
    k_new = _split_heads(layers.linear(p["wk"], x), n_kv, head_dim)
    v_new = _split_heads(layers.linear(p["wv"], x), n_kv, head_dim)
    q = layers.rope(q, pos[:, None], rope_theta)
    k_new = layers.rope(k_new, pos[:, None], rope_theta)

    kq_new, ks_new = kvq.quantize(k_new, kv_quant)     # (B,1,G,hs),(B,1,G,1)
    vq_new, vs_new = kvq.quantize(v_new, kv_quant)
    onehot = jax.nn.one_hot(pos, s_max, dtype=jnp.float32)  # (B,S)
    oh_i = onehot[..., None, None]

    def upd(buf, new):
        return (buf.astype(jnp.float32) * (1.0 - oh_i)
                + oh_i * new.astype(jnp.float32)).astype(buf.dtype)

    cache = {"k": upd(cache["k"], kq_new), "v": upd(cache["v"], vq_new),
             "k_scale": upd(cache["k_scale"], ks_new),
             "v_scale": upd(cache["v_scale"], vs_new)}

    kk = kvq.dequantize(cache["k"], cache["k_scale"], kv_quant)
    vv = kvq.dequantize(cache["v"], cache["v_scale"], kv_quant)
    n_rep = n_heads // n_kv
    kk, vv = _repeat_kv(kk, n_rep), _repeat_kv(vv, n_rep)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32)
    s = s / (head_dim ** 0.5)
    s = layers.softcap(s, attn_softcap)
    span = jnp.arange(s_max)
    valid = span[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w.astype(vv.dtype), vv)
    out = layers.linear(p["wo"], o.reshape(b, 1, n_heads * head_dim))
    return out, cache


# ---------------------------------------------------------------------------
# Decode (single new token against a cache)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array          # (B, S_max, n_kv, hd)
    v: jax.Array          # (B, S_max, n_kv, hd)


def mha_decode(p: dict, x: jax.Array, cache: KVCache, pos: jax.Array, *,
               n_heads: int, n_kv: int, head_dim: int,
               window: int | None = None,
               attn_softcap: float | None = None,
               rope_theta: float = 10000.0,
               use_rope: bool = True) -> tuple[jax.Array, KVCache]:
    """x: (B, 1, D); pos: (B,) current length. Returns (out, new_cache)."""
    b = x.shape[0]
    s_max = cache.k.shape[1]
    q = _split_heads(layers.linear(p["wq"], x), n_heads, head_dim)   # (B,1,H,hd)
    k_new = _split_heads(layers.linear(p["wk"], x), n_kv, head_dim)  # (B,1,G,hd)
    v_new = _split_heads(layers.linear(p["wv"], x), n_kv, head_dim)
    if use_rope:
        q = layers.rope(q, pos[:, None], rope_theta)
        k_new = layers.rope(k_new, pos[:, None], rope_theta)

    # Scatter the new KV at each row's position (one-hot to stay GSPMD-friendly
    # on a sequence-sharded cache: a matmul-like update, no gather/DUS).
    onehot = jax.nn.one_hot(pos, s_max, dtype=cache.k.dtype)          # (B,S)
    k_cache = cache.k * (1.0 - onehot[..., None, None]) + \
        onehot[..., None, None] * k_new
    v_cache = cache.v * (1.0 - onehot[..., None, None]) + \
        onehot[..., None, None] * v_new

    n_rep = n_heads // n_kv
    kk = _repeat_kv(k_cache, n_rep)                                   # (B,S,H,hd)
    vv = _repeat_kv(v_cache, n_rep)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32)
    s = s / (head_dim ** 0.5)
    s = layers.softcap(s, attn_softcap)
    span = jnp.arange(s_max)
    valid = span[None, :] <= pos[:, None]                             # causal fill
    if window is not None:
        valid = valid & (span[None, :] > pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w.astype(vv.dtype), vv)
    out = layers.linear(p["wo"], o.reshape(b, 1, n_heads * head_dim))
    return out, KVCache(k_cache, v_cache)
