"""KV-cache quantization — the paper's NLQ idea (C2/C6) applied to serving.

The macro digitizes MACs to 5 bits over an 8-bit range because activations
are tightly distributed; decode-time K/V activations have the same property,
so the same move (low-bit codes + per-vector scale "LUT") cuts the
memory-bound decode term by 2x (int8) or 4x (int4, two nibbles per byte).

Symmetric per-(position, head) scaling: q = round(x / s), s = max|x| / Q.
int4 packs adjacent head-dim pairs into one uint8.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array, mode: str):
    """x: (..., hd) -> (payload, scale (..., 1))."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32)
    if mode == "int8":
        s = jnp.maximum(scale, 1e-8) / 127.0
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127
                     ).astype(jnp.int8)
        return q, s
    if mode == "int4":
        s = jnp.maximum(scale, 1e-8) / 7.0
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -7, 7
                     ).astype(jnp.int8)
        hi = q[..., 1::2]
        lo = q[..., 0::2]
        packed = ((hi + 8) << 4 | (lo + 8)).astype(jnp.uint8)
        return packed, s
    raise ValueError(mode)


def dequantize(q: jax.Array, scale: jax.Array, mode: str, dtype=jnp.bfloat16):
    if mode == "int8":
        return (q.astype(jnp.float32) * scale).astype(dtype)
    if mode == "int4":
        lo = (q & 0xF).astype(jnp.int32) - 8
        hi = (q >> 4).astype(jnp.int32) - 8
        out = jnp.stack([lo, hi], axis=-1).reshape(*q.shape[:-1],
                                                   q.shape[-1] * 2)
        return (out.astype(jnp.float32) * scale).astype(dtype)
    raise ValueError(mode)


def storage_shape(hd: int, mode: str) -> int:
    return hd // 2 if mode == "int4" else hd


def storage_dtype(mode: str):
    return jnp.uint8 if mode == "int4" else jnp.int8
