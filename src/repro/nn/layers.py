"""Shared layers: linear/embedding/norm/rope + the CIM-mode linear (paper C1/C2
applied to LM projections)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ima as ima_lib
from repro.core import ternary as ternary_lib
from repro.nn.module import ParamSpec


# --- param-spec builders ----------------------------------------------------

def linear_spec(d_in: int, d_out: int, in_axis: str | None, out_axis: str | None,
                bias: bool = False, dtype=jnp.float32) -> dict:
    s = {"w": ParamSpec((d_in, d_out), (in_axis, out_axis), dtype)}
    if bias:
        s["b"] = ParamSpec((d_out,), (out_axis,), dtype, init="zeros")
    return s


def embed_spec(vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"table": ParamSpec((vocab, d), ("vocab", "embed"), dtype,
                               init="embed")}


def norm_spec(d: int, dtype=jnp.float32) -> dict:
    return {"scale": ParamSpec((d,), (None,), dtype, init="zeros")}


# --- forward ops ------------------------------------------------------------

def linear(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def cim_linear(p: dict, x: jax.Array, code_bits: int = 5,
               nlq_gamma: float = 2.0) -> jax.Array:
    """CIM-mode linear: ternary twin-cell weights (QAT STE) + NLQ activations.

    This is the paper's macro applied to an LM projection: weights fake-quant
    to the [-3,3] twin-cell grid, outputs through the NLQ ramp (companding
    codebook sized to the running activation scale).
    """
    w_q = ternary_lib.quantize_weights_ste(p["w"].astype(jnp.float32))
    y = x.astype(jnp.float32) @ w_q
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(jax.lax.stop_gradient(y))), 1e-3)
    cb = ima_lib.nlq_codebook(code_bits, -1.0, 1.0, nlq_gamma)
    y = ima_lib.ima_quantize_ste(y / scale, cb) * scale
    return y.astype(x.dtype)


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6,
            plus_one: bool = True) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    scale = p["scale"].astype(jnp.float32)
    scale = 1.0 + scale if plus_one else scale
    return (xf * scale).astype(dt)


def embed(p: dict, ids: jax.Array, scale_by_dim: bool = False) -> jax.Array:
    table = p["table"]
    y = jnp.take(table, ids, axis=0)
    if scale_by_dim:
        y = y * jnp.sqrt(jnp.asarray(table.shape[-1], y.dtype))
    return y


def unembed(p: dict, x: jax.Array) -> jax.Array:
    return x @ p["table"].T.astype(x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# --- rotary position embedding ----------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --- activations --------------------------------------------------------------

def squared_relu(x):
    r = jnp.maximum(x, 0.0)
    return r * r


ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "squared_relu": squared_relu,
}
