"""Param-spec micro-framework: shapes + logical sharding axes, no magic.

Models are pure functions over nested dicts of arrays.  ``param_specs``
builders return the same nested structure holding :class:`ParamSpec` leaves;
from that single source of truth we derive
  * ``materialize``  — real initialized arrays (smoke tests / real training),
  * ``abstract``     — ShapeDtypeStruct tree (dry-run: no allocation),
  * ``shardings``    — NamedSharding tree via logical-axis rules with
                       divisibility fallback (a mesh axis that does not divide
                       the dim is dropped, never errors — this is what keeps
                       batch=1 / kv_heads=1 / odd-vocab cases legal).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Tree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis name per dim
    dtype: Any = jnp.float32
    init: str = "normal"                  # normal | zeros | ones | embed
    scale: float | None = None            # None -> fan-in 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(spec: ParamSpec, key: jax.Array, dtype_override=None) -> jax.Array:
    dtype = dtype_override or spec.dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape) * (spec.scale or 0.02)).astype(dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape) * scale).astype(dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def materialize(specs: Tree, key: jax.Array, dtype=None) -> Tree:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)])


def abstract(specs: Tree, dtype=None) -> Tree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype),
        specs, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Logical axis -> mesh sharding
# ---------------------------------------------------------------------------

DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "vocab": "model",
    "heads": "model",        # fused n_heads*head_dim projection dim
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "expert_in": None,
    "expert_ffn": None,
    "cache_seq": "model",    # decode KV cache sequence dim (split-KV)
    "cache_heads": None,
    "conv": None,
    "state": None,
    "classes": "model",
}


def _mesh_axes_for(logical: str | None, rules: dict, mesh: Mesh) -> tuple[str, ...]:
    if logical is None:
        return ()
    r = rules.get(logical, None)
    if r is None:
        return ()
    axes = (r,) if isinstance(r, str) else tuple(r)
    return tuple(a for a in axes if a in mesh.shape)


def partition_spec(shape: tuple[int, ...], axes: tuple[str | None, ...],
                   mesh: Mesh, rules: dict | None = None) -> P:
    """Build a PartitionSpec, dropping any mesh axis that does not divide the
    dim (GSPMD refuses uneven in/out shardings) and never using a mesh axis
    twice."""
    rules = {**DEFAULT_RULES, **(rules or {})}
    used: set[str] = set()
    parts = []
    for dim, logical in zip(shape, axes):
        mesh_axes = _mesh_axes_for(logical, rules, mesh)
        chosen: list[str] = []
        prod = 1
        for a in mesh_axes:
            if a in used:
                continue
            if dim % (prod * mesh.shape[a]) == 0:
                chosen.append(a)
                prod *= mesh.shape[a]
        used.update(chosen)
        if not chosen:
            parts.append(None)
        elif len(chosen) == 1:
            parts.append(chosen[0])
        else:
            parts.append(tuple(chosen))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shardings(specs: Tree, mesh: Mesh, rules: dict | None = None) -> Tree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, partition_spec(s.shape, s.axes, mesh, rules)),
        specs, is_leaf=is_spec)


def shardings_like(tree: Tree, axes_tree: Tree, mesh: Mesh,
                   rules: dict | None = None) -> Tree:
    """Shardings for an arbitrary array tree given a parallel tree of logical
    axis tuples (used for caches / batches)."""
    return jax.tree.map(
        lambda x, ax: NamedSharding(
            mesh, partition_spec(tuple(x.shape), ax, mesh, rules)),
        tree, axes_tree, is_leaf=lambda x: hasattr(x, "shape"))


def count_params(specs: Tree) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)
