"""Mixture-of-Experts with expert parallelism.

The router *is* a K-winner circuit (paper C3): top-k expert selection over the
router logits is exactly the KWN selection the macro performs over its 128
columns, and we expose the same knobs — including an optional SNL-style
probabilistic rescue of near-threshold experts (beyond-paper ablation).

Two execution paths:

* ``moe_a2a``   — production EP: shard_map over ("data","model") with tokens
  sharded over data and *sliced* over model, capacity-based dispatch, two
  all_to_alls over the model axis, batched per-expert GEMMs.  Used for
  train/prefill shapes (many tokens per device).
* ``moe_dense_ep`` — small-token fallback (decode): every model shard runs its
  local experts on all local tokens, combines with the routing mask, psum over
  model.  Redundant by E_local/k flops but collective-light; right for T_loc
  of a few tokens.

Both are numerically equal to the reference dense formulation (``moe_ref``)
up to capacity drops (a2a path with cf < inf).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.nn import layers
from repro.nn.module import ParamSpec


def moe_specs(d_model: int, d_ff: int, n_experts: int, dtype=jnp.float32,
              router_dtype=jnp.float32) -> dict:
    return {
        "router": ParamSpec((d_model, n_experts), ("embed", None), router_dtype,
                            scale=0.02),
        "w_in": ParamSpec((n_experts, d_model, d_ff),
                          ("experts", "expert_in", "expert_ffn"), dtype),
        "w_gate": ParamSpec((n_experts, d_model, d_ff),
                            ("experts", "expert_in", "expert_ffn"), dtype),
        "w_out": ParamSpec((n_experts, d_ff, d_model),
                           ("experts", "expert_ffn", "expert_in"), dtype),
    }


def router_topk(logits: jax.Array, k: int, *, snl_rescue: float = 0.0,
                rng: jax.Array | None = None):
    """KWN selection over expert logits.

    snl_rescue > 0 enables the SNL analogue: experts whose softmax prob lands
    within ``snl_rescue`` of the k-th winner get a probabilistic chance to
    displace it (PRBS noise -> here a gumbel kick on the boundary band).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if snl_rescue > 0.0 and rng is not None:
        kth = jnp.sort(probs, axis=-1)[..., -k][..., None]
        band = (probs > kth - snl_rescue) & (probs < kth + snl_rescue)
        kick = snl_rescue * jax.random.gumbel(rng, probs.shape) * 0.5
        probs = jnp.where(band, probs + kick, probs)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
    return gate, idx, probs


def _expert_ffn(w_in, w_gate, w_out, x, activation):
    act = layers.ACTIVATIONS[activation]
    h = act(jnp.einsum("ecd,edf->ecf", x, w_in.astype(x.dtype)))
    g = jnp.einsum("ecd,edf->ecf", x, w_gate.astype(x.dtype))
    return jnp.einsum("ecf,efd->ecd", h * g, w_out.astype(x.dtype))


def aux_load_balance_loss(probs: jax.Array, idx: jax.Array, n_experts: int,
                          k: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    me = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    assign = jax.nn.one_hot(idx, n_experts).sum(-2)
    ce = jnp.mean(assign, axis=tuple(range(assign.ndim - 1))) / k
    return n_experts * jnp.sum(me * ce)


# ---------------------------------------------------------------------------
# Reference (single-device semantics; also the smoke-test path)
# ---------------------------------------------------------------------------

def moe_ref(p: dict, x: jax.Array, *, k: int, activation: str = "silu",
            snl_rescue: float = 0.0, rng=None):
    """Dense-combine reference: computes every expert on every token.
    x: (..., D).  Only for small configs (tests / smoke)."""
    gate, idx, probs = router_topk(x @ p["router"].astype(x.dtype), k,
                                   snl_rescue=snl_rescue, rng=rng)
    n_experts = p["w_in"].shape[0]
    lead = x.shape[:-1]
    xt = x.reshape(1, -1, x.shape[-1])                      # (1, T, D)
    outs = _expert_ffn(p["w_in"], p["w_gate"], p["w_out"],
                       jnp.broadcast_to(xt, (n_experts,) + xt.shape[1:]),
                       activation)                          # (E, T, D)
    combine = jax.nn.one_hot(idx, n_experts, dtype=x.dtype) * gate[..., None].astype(x.dtype)
    combine = combine.sum(-2).reshape(-1, n_experts)        # (T, E)
    y = jnp.einsum("te,etd->td", combine, outs)
    aux = aux_load_balance_loss(probs, idx, n_experts, k)
    return y.reshape(*lead, x.shape[-1]), aux


# ---------------------------------------------------------------------------
# Expert-parallel paths (inside shard_map)
# ---------------------------------------------------------------------------

def _dispatch_onehot(idx, gate, n_experts, capacity, dtype):
    """Capacity-based dispatch/combine tensors from top-k routing.

    idx/gate: (T, k).  Returns dispatch (T, E, C) {0,1}, combine (T, E, C)."""
    t, k = idx.shape
    e_oh = jax.nn.one_hot(idx, n_experts, dtype=jnp.int32)          # (T,k,E)
    flat = e_oh.reshape(t * k, n_experts)
    # position of each assignment within its expert queue (token-major order)
    pos = jnp.cumsum(flat, axis=0) - flat                            # (T*k,E)
    pos = (pos * flat).sum(-1).reshape(t, k)                         # (T,k)
    keep = pos < capacity
    pos_oh = jax.nn.one_hot(pos, capacity, dtype=dtype) * keep[..., None].astype(dtype)
    disp = jnp.einsum("tke,tkc->tec", e_oh.astype(dtype), pos_oh)
    comb = jnp.einsum("tke,tkc->tec",
                      (e_oh.astype(dtype) * gate[..., None].astype(dtype)),
                      pos_oh)
    return disp, comb


def moe_a2a(p: dict, x: jax.Array, *, k: int, mesh: Mesh,
            activation: str = "silu", capacity_factor: float = 1.25,
            token_axes=("pod", "data"), expert_axis: str = "model",
            seq_sharded: bool = False, snl_rescue: float = 0.0, rng=None):
    """Expert-parallel MoE via all_to_all. x: (B, S, D) -> (B, S, D), aux.

    Inside shard_map: tokens are sharded over ``token_axes`` and additionally
    over ``expert_axis`` (via the caller's sequence sharding when
    ``seq_sharded``, else by an explicit axis_index slice), sent to expert
    owners with all_to_all, computed as batched per-expert GEMMs, returned.
    """
    b, s, d = x.shape
    n_experts = p["w_in"].shape[0]
    taxes = tuple(a for a in token_axes if a in mesh.shape)
    tp = mesh.shape[expert_axis]
    if seq_sharded and s % tp != 0:
        seq_sharded = False   # fall back to the slice path

    def local_fn(router, w_in, w_gate, w_out, xl):
        bl, sl, dl = xl.shape
        t_loc = bl * sl
        xt = xl.reshape(t_loc, dl)
        if seq_sharded:
            xs = xt                                   # already sliced by spec
            t_slice = t_loc
        else:
            my = jax.lax.axis_index(expert_axis)
            assert t_loc % tp == 0, (t_loc, tp)
            t_slice = t_loc // tp
            xs = jax.lax.dynamic_slice_in_dim(xt, my * t_slice, t_slice, 0)

        gate, idx, probs = router_topk(xs @ router.astype(xs.dtype), k,
                                       snl_rescue=snl_rescue, rng=rng)
        capacity = max(1, int(math.ceil(t_slice * k / n_experts
                                        * capacity_factor)))
        disp, comb = _dispatch_onehot(idx, gate, n_experts, capacity, xs.dtype)
        x_send = jnp.einsum("tec,td->ecd", disp, xs)          # (E, C, D)
        # exchange: every device sends each expert-owner its (E_loc, C, D)
        x_recv = jax.lax.all_to_all(x_send, expert_axis, split_axis=0,
                                    concat_axis=1, tiled=True)  # (E_loc, tp*C, D)
        y_loc = _expert_ffn(w_in, w_gate, w_out, x_recv, activation)
        y_send = jax.lax.all_to_all(y_loc, expert_axis, split_axis=1,
                                    concat_axis=0, tiled=True)  # (E, C, D)
        ys = jnp.einsum("ecd,tec->td", y_send, comb)            # (T_slice, D)
        if not seq_sharded:
            # reassemble the full local token set across the expert axis
            ys = jax.lax.all_gather(ys, expert_axis, axis=0, tiled=True)
        aux = aux_load_balance_loss(probs, idx, n_experts, k)
        aux = jax.lax.pmean(aux, expert_axis)
        for ax in taxes:
            aux = jax.lax.pmean(aux, ax)
        return ys.reshape(bl, sl, dl), aux

    tspec = P(taxes if len(taxes) > 1 else (taxes[0] if taxes else None))
    seq_spec = expert_axis if seq_sharded else None
    in_specs = (P(), P(expert_axis), P(expert_axis), P(expert_axis),
                P(*tspec, seq_spec, None))
    out_specs = (P(*tspec, seq_spec, None), P())
    fn = compat.shard_map(local_fn, mesh, in_specs=in_specs,
                          out_specs=out_specs)
    return fn(p["router"], p["w_in"], p["w_gate"], p["w_out"], x)


def _wire_quantize(x: jax.Array):
    """Per-(expert, slot) int8 quantization for dispatch payloads (§Perf:
    collective compression — the paper's NLQ idea applied to the wire)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True),
                        1e-8).astype(jnp.float32) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def _wire_dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def moe_2d(p: dict, x: jax.Array, *, k: int, mesh: Mesh,
           activation: str = "silu", capacity_factor: float = 1.25,
           expert_axes=("pod", "data"), tp_axis: str = "model",
           wire_dtype: str = "bfloat16",
           snl_rescue: float = 0.0, rng=None):
    """2D expert + tensor parallelism (the 1T-scale path).

    Expert weights are sharded over BOTH grid axes: experts over
    ``expert_axes`` (the DP rows) and the expert FFN dim over ``tp_axis`` —
    so a 1T-param MoE's weights/grads/moments divide by all 256/512 chips.

    Dataflow per layer (tokens arrive sharded batch x seq = rows x model):
      1. local routing on each device's token slice (distinct per device);
      2. all_to_all over the expert rows -> tokens reach their expert's row;
      3. all_gather over ``tp_axis`` (every model shard needs the full token
         set for TP), batched expert GEMMs on the local F-slice;
      4. psum_scatter over ``tp_axis`` -> finished tokens back to their
         sender's model shard;
      5. reverse all_to_all; combine with gates.
    """
    b, s, d = x.shape
    n_experts = p["w_in"].shape[0]
    eaxes = tuple(a for a in expert_axes if a in mesh.shape)
    tp = mesh.shape[tp_axis]
    seq_ok = s % tp == 0

    def local_fn(router, w_in, w_gate, w_out, xl):
        bl, sl, dl = xl.shape
        xs = xl.reshape(bl * sl, dl)
        t_slice = xs.shape[0]
        gate, idx, probs = router_topk(xs @ router.astype(xs.dtype), k,
                                       snl_rescue=snl_rescue, rng=rng)
        capacity = max(1, int(math.ceil(t_slice * k / n_experts
                                        * capacity_factor)))
        disp, comb = _dispatch_onehot(idx, gate, n_experts, capacity, xs.dtype)
        x_send = jnp.einsum("tec,td->ecd", disp, xs)            # (E, C, D)
        if wire_dtype == "int8":
            # quantize once; stays int8 through the a2a AND the TP gather
            xq, xscale = _wire_quantize(x_send)
            xq = jax.lax.all_to_all(xq, eaxes, split_axis=0,
                                    concat_axis=1, tiled=True)
            xscale = jax.lax.all_to_all(xscale, eaxes, split_axis=0,
                                        concat_axis=1, tiled=True)
            xq = jax.lax.all_gather(xq, tp_axis, axis=1, tiled=True)
            xscale = jax.lax.all_gather(xscale, tp_axis, axis=1, tiled=True)
            x_full = _wire_dequantize(xq, xscale, x_send.dtype)
        else:
            x_recv = jax.lax.all_to_all(x_send, eaxes, split_axis=0,
                                        concat_axis=1, tiled=True)  # (E_loc, R*C, D)
            # TP over the expert FFN dim: gather tokens for the F-slice GEMMs.
            x_full = jax.lax.all_gather(x_recv, tp_axis, axis=1, tiled=True)
        # name the post-communication tensor so a remat policy can pin it
        # (save_only_these_names -> the x-side a2a+gather is not re-run in
        # the backward recompute; §Perf "save_moe_recv" iteration)
        from jax.ad_checkpoint import checkpoint_name
        x_full = checkpoint_name(x_full, "moe_xfull")
        ACT = layers.ACTIVATIONS[activation]
        h = ACT(jnp.einsum("ecd,edf->ecf", x_full, w_in.astype(x_full.dtype)))
        g = jnp.einsum("ecd,edf->ecf", x_full, w_gate.astype(x_full.dtype))
        y_part = jnp.einsum("ecf,efd->ecd", h * g, w_out.astype(x_full.dtype))
        y_loc = jax.lax.psum_scatter(y_part, tp_axis, scatter_dimension=1,
                                     tiled=True)                # (E_loc, R*C, D)
        if wire_dtype == "int8":
            yq, yscale = _wire_quantize(y_loc)
            yq = jax.lax.all_to_all(yq, eaxes, split_axis=1,
                                    concat_axis=0, tiled=True)
            yscale = jax.lax.all_to_all(yscale, eaxes, split_axis=1,
                                        concat_axis=0, tiled=True)
            y_send = _wire_dequantize(yq, yscale, y_loc.dtype)
        else:
            y_send = jax.lax.all_to_all(y_loc, eaxes, split_axis=1,
                                        concat_axis=0, tiled=True)  # (E, C, D)
        ys = jnp.einsum("ecd,tec->td", y_send, comb)
        aux = aux_load_balance_loss(probs, idx, n_experts, k)
        aux = jax.lax.pmean(aux, eaxes + (tp_axis,))
        return ys.reshape(bl, sl, dl), aux

    row_spec = eaxes if len(eaxes) > 1 else (eaxes[0] if eaxes else None)
    seq_spec = tp_axis if seq_ok else None
    in_specs = (P(),
                P(row_spec, None, tp_axis),     # w_in  (E, D, F)
                P(row_spec, None, tp_axis),     # w_gate
                P(row_spec, tp_axis, None),     # w_out (E, F, D)
                P(row_spec, seq_spec, None))
    out_specs = (P(row_spec, seq_spec, None), P())
    fn = compat.shard_map(local_fn, mesh, in_specs=in_specs,
                          out_specs=out_specs)
    return fn(p["router"], p["w_in"], p["w_gate"], p["w_out"], x)


def moe_dense_ep_2d(p: dict, x: jax.Array, *, k: int, mesh: Mesh,
                    activation: str = "silu", expert_axes=("pod", "data"),
                    tp_axis: str = "model", snl_rescue: float = 0.0, rng=None):
    """Decode-shape path for 2D-sharded experts: all-gather the (tiny) token
    batch over the expert rows, run every local expert's F-slice on all
    tokens, psum over model (TP) then over rows (expert combine), slice back.
    """
    b, s, d = x.shape
    n_experts = p["w_in"].shape[0]
    eaxes = tuple(a for a in expert_axes if a in mesh.shape)
    n_rows = 1
    for a in eaxes:
        n_rows *= mesh.shape[a]
    e_loc = n_experts // n_rows

    def local_fn(router, w_in, w_gate, w_out, xl):
        bl, sl, dl = xl.shape
        xt = xl.reshape(-1, dl)
        x_all = jax.lax.all_gather(xt, eaxes, axis=0, tiled=True)  # (T, D)
        gate, idx, probs = router_topk(x_all @ router.astype(x_all.dtype), k,
                                       snl_rescue=snl_rescue, rng=rng)
        row = jax.lax.axis_index(eaxes[0]) if len(eaxes) == 1 else (
            jax.lax.axis_index(eaxes[0]) * mesh.shape[eaxes[1]]
            + jax.lax.axis_index(eaxes[1]))
        ACT = layers.ACTIVATIONS[activation]
        xb = jnp.broadcast_to(x_all[None], (e_loc,) + x_all.shape)
        h = ACT(jnp.einsum("ecd,edf->ecf", xb, w_in.astype(xb.dtype)))
        g = jnp.einsum("ecd,edf->ecf", xb, w_gate.astype(xb.dtype))
        y = jnp.einsum("ecf,efd->ecd", h * g, w_out.astype(xb.dtype))
        combine = (jax.nn.one_hot(idx, n_experts, dtype=xt.dtype)
                   * gate[..., None].astype(xt.dtype)).sum(-2)   # (T, E)
        local_comb = jax.lax.dynamic_slice_in_dim(combine, row * e_loc,
                                                  e_loc, axis=1)
        y_tok = jnp.einsum("te,etd->td", local_comb, y)
        y_tok = jax.lax.psum(y_tok, (tp_axis,) + eaxes)
        # slice my batch rows back out of the gathered order
        t_loc = xt.shape[0]
        y_mine = jax.lax.dynamic_slice_in_dim(y_tok, row * t_loc, t_loc, 0)
        aux = aux_load_balance_loss(probs, idx, n_experts, k)
        aux = jax.lax.pmean(aux, eaxes + (tp_axis,))
        return y_mine.reshape(bl, sl, dl), aux

    row_spec = eaxes if len(eaxes) > 1 else (eaxes[0] if eaxes else None)
    in_specs = (P(), P(row_spec, None, tp_axis), P(row_spec, None, tp_axis),
                P(row_spec, tp_axis, None), P(row_spec, None, None))
    out_specs = (P(row_spec, None, None), P())
    fn = compat.shard_map(local_fn, mesh, in_specs=in_specs,
                          out_specs=out_specs)
    return fn(p["router"], p["w_in"], p["w_gate"], p["w_out"], x)


def moe_dense_ep(p: dict, x: jax.Array, *, k: int, mesh: Mesh,
                 activation: str = "silu", token_axes=("pod", "data"),
                 expert_axis: str = "model", snl_rescue: float = 0.0,
                 rng=None):
    """Decode-shape EP: all local experts on all local tokens, psum combine."""
    b, s, d = x.shape
    n_experts = p["w_in"].shape[0]
    tp = mesh.shape[expert_axis]
    e_loc = n_experts // tp
    taxes = tuple(a for a in token_axes if a in mesh.shape)

    def local_fn(router, w_in, w_gate, w_out, xl):
        bl, sl, dl = xl.shape
        xt = xl.reshape(-1, dl)                               # (T_loc, D)
        gate, idx, probs = router_topk(xt @ router.astype(xt.dtype), k,
                                       snl_rescue=snl_rescue, rng=rng)
        my = jax.lax.axis_index(expert_axis)
        outs = _expert_ffn(w_in, w_gate, w_out,
                           jnp.broadcast_to(xt[None], (e_loc,) + xt.shape),
                           activation)                        # (E_loc, T, D)
        combine = (jax.nn.one_hot(idx, n_experts, dtype=xt.dtype)
                   * gate[..., None].astype(xt.dtype)).sum(-2)  # (T, E)
        local_combine = jax.lax.dynamic_slice_in_dim(
            combine, my * e_loc, e_loc, axis=1)               # (T, E_loc)
        y = jnp.einsum("te,etd->td", local_combine, outs)
        y = jax.lax.psum(y, expert_axis)
        aux = aux_load_balance_loss(probs, idx, n_experts, k)
        for ax in taxes:
            aux = jax.lax.pmean(aux, ax)
        return y.reshape(bl, sl, dl), aux

    tspec = P(taxes if len(taxes) > 1 else (taxes[0] if taxes else None))
    in_specs = (P(), P(expert_axis), P(expert_axis), P(expert_axis),
                P(*tspec, None, None))
    out_specs = (P(*tspec, None, None), P())
    fn = compat.shard_map(local_fn, mesh, in_specs=in_specs,
                          out_specs=out_specs)
    return fn(p["router"], p["w_in"], p["w_gate"], p["w_out"], x)
