"""Recurrent blocks: xLSTM (chunked mLSTM + sLSTM) and Griffin's RG-LRU.

mLSTM — matrix-memory cell with exponential gating, implemented *chunkwise*
(FLA-style): intra-chunk attention in log-gate space + inter-chunk recurrent
state (C, n, m) with max-stabilizers, so training never materializes per-step
d x d states and the sequential depth is S/chunk, not S.

sLSTM — scalar-memory cell with h_{t-1} feedback in the gates (true
recurrence; not parallelizable) — lax.scan over time with stabilized
exponential gating.

RG-LRU — Griffin's gated linear recurrence; diagonal -> jax.lax.associative_scan
over time (parallel depth log S, the TPU-native realization).  Sub-quadratic,
which is why recurrentgemma/xlstm are the long_500k architectures.

All cells expose a single-step form for decode.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.nn import layers
from repro.nn.module import ParamSpec

NEG_INF = -1e30


# ===========================================================================
# mLSTM (xLSTM matrix cell)
# ===========================================================================

def mlstm_specs(d_model: int, n_heads: int, dtype=jnp.float32) -> dict:
    return {
        "wq": layers.linear_spec(d_model, d_model, "embed", "heads", dtype=dtype),
        "wk": layers.linear_spec(d_model, d_model, "embed", "heads", dtype=dtype),
        "wv": layers.linear_spec(d_model, d_model, "embed", "heads", dtype=dtype),
        "wi": layers.linear_spec(d_model, n_heads, "embed", None, dtype=dtype),
        "wf": layers.linear_spec(d_model, n_heads, "embed", None, dtype=dtype),
        "wo_gate": layers.linear_spec(d_model, d_model, "embed", "heads", dtype=dtype),
        "wo": layers.linear_spec(d_model, d_model, "heads", "embed", dtype=dtype),
    }


class MLSTMState(NamedTuple):
    c: jax.Array   # (B, H, hd, hd) stabilized matrix memory
    n: jax.Array   # (B, H, hd) stabilized normalizer
    m: jax.Array   # (B, H) log-stabilizer


def mlstm_init_state(b: int, n_heads: int, hd: int, dtype=jnp.float32):
    return MLSTMState(jnp.zeros((b, n_heads, hd, hd), dtype),
                      jnp.zeros((b, n_heads, hd), dtype),
                      jnp.full((b, n_heads), -1e9, dtype))


def _mlstm_chunk(q, k, v, log_f, log_i, state: MLSTMState):
    """One chunk. q,k,v: (B, W, H, hd); log_f/log_i: (B, W, H)."""
    b, w, h, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    a = jnp.cumsum(log_f, axis=1)                       # (B,W,H) inclusive
    total_a = a[:, -1]                                  # (B,H)

    # intra-chunk decay matrix: D[t,s] = a_t - a_s + log_i_s  (s <= t)
    d_mat = (a[:, :, None, :] - a[:, None, :, :]
             + log_i[:, None, :, :])                    # (B,T,S,H)
    tri = jnp.tril(jnp.ones((w, w), bool))
    d_mat = jnp.where(tri[None, :, :, None], d_mat, NEG_INF)

    m_intra = jnp.max(d_mat, axis=2)                    # (B,T,H)
    m_inter = state.m[:, None, :] + a                   # (B,T,H)
    m_t = jnp.maximum(m_intra, m_inter)

    s_qk = jnp.einsum("bthd,bshd->btsh", q, k) * scale  # (B,T,S,H)
    p = jnp.exp(d_mat - m_t[:, :, None, :])
    num_intra = jnp.einsum("btsh,btsh,bshd->bthd", s_qk, p, v)
    # normalizer: sum_s p[t,s] * (q_t . k_s)
    den_intra = jnp.einsum("btsh,btsh->bth", s_qk, p)

    w_inter = jnp.exp(m_inter - m_t)                    # (B,T,H)
    num_inter = jnp.einsum("bthd,bhde->bthe", q, state.c) * scale
    den_inter = jnp.einsum("bthd,bhd->bth", q, state.n) * scale
    num = num_intra + num_inter * w_inter[..., None]
    den = den_intra + den_inter * w_inter
    h_out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # state update to end of chunk
    b_decay = total_a[:, None, :] - a + log_i           # (B,S,H)
    m_new = jnp.maximum(state.m + total_a, jnp.max(b_decay, axis=1))
    w_old = jnp.exp(state.m + total_a - m_new)          # (B,H)
    w_s = jnp.exp(b_decay - m_new[:, None, :])          # (B,S,H)
    c_new = (state.c * w_old[..., None, None]
             + jnp.einsum("bsh,bshd,bshe->bhde", w_s, k, v))
    n_new = state.n * w_old[..., None] + jnp.einsum("bsh,bshd->bhd", w_s, k)
    return h_out, MLSTMState(c_new, n_new, m_new)


def mlstm_forward(p: dict, x: jax.Array, n_heads: int,
                  chunk: int = 256, return_state: bool = False):
    """x: (B, S, D) -> (B, S, D) [, final MLSTMState]."""
    b, s, d = x.shape
    hd = d // n_heads
    q = layers.linear(p["wq"], x).reshape(b, s, n_heads, hd)
    k = layers.linear(p["wk"], x).reshape(b, s, n_heads, hd)
    v = layers.linear(p["wv"], x).reshape(b, s, n_heads, hd)
    log_i = layers.linear(p["wi"], x).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(layers.linear(p["wf"], x).astype(jnp.float32))

    w = min(chunk, s)
    assert s % w == 0, (s, w)
    nc = s // w
    def to_chunks(t):
        return jnp.moveaxis(t.reshape(b, nc, w, *t.shape[2:]), 1, 0)

    def step(state, inp):
        qc, kc, vc, fc, ic = inp
        h, state = _mlstm_chunk(qc, kc, vc, fc, ic, state)
        return state, h

    state = mlstm_init_state(b, n_heads, hd, jnp.float32)
    final_state, hs = jax.lax.scan(step, state,
                                   (to_chunks(q.astype(jnp.float32)),
                                    to_chunks(k.astype(jnp.float32)),
                                    to_chunks(v.astype(jnp.float32)),
                                    to_chunks(log_f), to_chunks(log_i)))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    o = jax.nn.sigmoid(layers.linear(p["wo_gate"], x))
    out = layers.linear(p["wo"], h * o)
    if return_state:
        return out, final_state
    return out


def mlstm_decode_step(p: dict, x: jax.Array, state: MLSTMState,
                      n_heads: int) -> tuple[jax.Array, MLSTMState]:
    """x: (B, 1, D)."""
    b, _, d = x.shape
    hd = d // n_heads
    out, new_state = _mlstm_chunk(
        layers.linear(p["wq"], x).reshape(b, 1, n_heads, hd).astype(jnp.float32),
        layers.linear(p["wk"], x).reshape(b, 1, n_heads, hd).astype(jnp.float32),
        layers.linear(p["wv"], x).reshape(b, 1, n_heads, hd).astype(jnp.float32),
        jax.nn.log_sigmoid(layers.linear(p["wf"], x).astype(jnp.float32)),
        layers.linear(p["wi"], x).astype(jnp.float32),
        state)
    h = out.reshape(b, 1, d).astype(x.dtype)
    o = jax.nn.sigmoid(layers.linear(p["wo_gate"], x))
    return layers.linear(p["wo"], h * o), new_state


# ===========================================================================
# sLSTM (xLSTM scalar cell, true recurrence)
# ===========================================================================

def slstm_specs(d_model: int, n_heads: int, dtype=jnp.float32) -> dict:
    return {
        "wx": layers.linear_spec(d_model, 4 * d_model, "embed", "heads", dtype=dtype),
        "r": ParamSpec((n_heads, d_model // n_heads, 4 * (d_model // n_heads)),
                       (None, None, None), dtype, scale=0.02),  # block-diag recurrence
        "wo": layers.linear_spec(d_model, d_model, "heads", "embed", dtype=dtype),
    }


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, H, hd)
    n: jax.Array   # (B, H, hd)
    h: jax.Array   # (B, H, hd)
    m: jax.Array   # (B, H, hd)


def slstm_init_state(b: int, n_heads: int, hd: int):
    z = jnp.zeros((b, n_heads, hd), jnp.float32)
    return SLSTMState(z, z, z, jnp.full((b, n_heads, hd), -1e9, jnp.float32))


def _slstm_cell(state: SLSTMState, gates_x, r):
    """gates_x: (B, H, hd, 4) pre-activations from x; r: (H, hd, 4*hd)."""
    rec = jnp.einsum("bhd,hdk->bhk", state.h, r)
    rec = rec.reshape(*state.h.shape[:-1], state.h.shape[-1], 4)
    gz, gi, gf, go = [gates_x[..., j] + rec[..., j] for j in range(4)]
    z = jnp.tanh(gz)
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + state.m, gi)
    c = jnp.exp(log_f + state.m - m_new) * state.c + jnp.exp(gi - m_new) * z
    n = jnp.exp(log_f + state.m - m_new) * state.n + jnp.exp(gi - m_new)
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c, n, h, m_new)


def slstm_forward(p: dict, x: jax.Array, n_heads: int,
                  return_state: bool = False):
    b, s, d = x.shape
    hd = d // n_heads
    gx = layers.linear(p["wx"], x).astype(jnp.float32)
    gx = gx.reshape(b, s, n_heads, hd, 4)

    def step(state, g):
        state = _slstm_cell(state, g, p["r"].astype(jnp.float32))
        return state, state.h

    final, hs = jax.lax.scan(step, slstm_init_state(b, n_heads, hd),
                             jnp.moveaxis(gx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    out = layers.linear(p["wo"], h)
    if return_state:
        return out, final
    return out


def slstm_decode_step(p: dict, x: jax.Array, state: SLSTMState, n_heads: int):
    b, _, d = x.shape
    hd = d // n_heads
    gx = layers.linear(p["wx"], x).astype(jnp.float32).reshape(b, n_heads, hd, 4)
    state = _slstm_cell(state, gx, p["r"].astype(jnp.float32))
    h = state.h.reshape(b, 1, d).astype(x.dtype)
    return layers.linear(p["wo"], h), state


# ===========================================================================
# RG-LRU (Griffin / RecurrentGemma)
# ===========================================================================

def rglru_specs(d_model: int, d_rnn: int, conv_width: int = 4,
                dtype=jnp.float32) -> dict:
    return {
        "w_in": layers.linear_spec(d_model, d_rnn, "embed", "ffn", dtype=dtype),
        "w_gate_branch": layers.linear_spec(d_model, d_rnn, "embed", "ffn", dtype=dtype),
        "conv": ParamSpec((conv_width, d_rnn), ("conv", "ffn"), dtype, scale=0.1),
        "w_a": layers.linear_spec(d_rnn, d_rnn, "ffn", None, dtype=dtype),
        "w_x": layers.linear_spec(d_rnn, d_rnn, "ffn", None, dtype=dtype),
        "lam": ParamSpec((d_rnn,), (None,), dtype, init="ones", scale=1.0),
        "w_out": layers.linear_spec(d_rnn, d_model, "ffn", "embed", dtype=dtype),
    }


class RGLRUState(NamedTuple):
    h: jax.Array          # (B, d_rnn) recurrent state
    conv: jax.Array       # (B, conv_width-1, d_rnn) conv tail


def rglru_init_state(b: int, d_rnn: int, conv_width: int = 4):
    return RGLRUState(jnp.zeros((b, d_rnn), jnp.float32),
                      jnp.zeros((b, conv_width - 1, d_rnn), jnp.float32))


_C_RGLRU = 8.0


def _rglru_gates(p, u):
    r = jax.nn.sigmoid(layers.linear(p["w_a"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(layers.linear(p["w_x"], u).astype(jnp.float32))
    log_a = -_C_RGLRU * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * u.astype(jnp.float32))
    return a, gated


def _causal_conv(p, u, state_tail=None):
    """u: (B, S, d_rnn); depthwise causal conv width K."""
    k = p["conv"].shape[0]
    if state_tail is None:
        pad = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    else:
        pad = state_tail.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * p["conv"][i].astype(u.dtype)
              for i in range(k))
    return out, up[:, -(k - 1):]


def rglru_forward(p: dict, x: jax.Array, return_state: bool = False):
    """Griffin recurrent block: in-proj -> causal conv -> RG-LRU, gated merge."""
    u_in = layers.linear(p["w_in"], x)
    u, tail = _causal_conv(p, u_in)
    a, gated = _rglru_gates(p, u)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    branch = jax.nn.gelu(layers.linear(p["w_gate_branch"], x))
    out = layers.linear(p["w_out"], h.astype(x.dtype) * branch)
    if return_state:
        return out, RGLRUState(h[:, -1], tail.astype(jnp.float32))
    return out


def rglru_decode_step(p: dict, x: jax.Array, state: RGLRUState
                      ) -> tuple[jax.Array, RGLRUState]:
    """x: (B, 1, D)."""
    u = layers.linear(p["w_in"], x)
    u, tail = _causal_conv(p, u, state.conv)
    a, gated = _rglru_gates(p, u)
    h = a[:, 0] * state.h + gated[:, 0]
    branch = jax.nn.gelu(layers.linear(p["w_gate_branch"], x))
    out = layers.linear(p["w_out"], h[:, None].astype(x.dtype) * branch)
    return out, RGLRUState(h, tail.astype(jnp.float32))
