"""Observability layer: span tracing + process-local metrics.

``repro.obs`` sits *below* ``serve/`` in the layer map and imports
nothing above ``core/`` — in fact both modules here are stdlib-only at
import time (``trace.py`` touches ``jax.profiler`` lazily, and only
when TraceAnnotation passthrough is explicitly requested), so the
package is importable in the minimal container without JAX.

- :mod:`repro.obs.trace` — lightweight span tracer (context-manager +
  explicit begin/end API, monotonic clocks, thread-safe ring buffer,
  zero-cost when disabled) with a Chrome/Perfetto ``trace_event`` JSON
  exporter.
- :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket
  histograms with labeled series, exported as JSON or Prometheus text.
"""

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import Tracer, get_tracer, set_tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Tracer",
    "get_tracer",
    "set_tracer",
]
