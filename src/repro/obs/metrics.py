"""Process-local metrics: counters, gauges, fixed-bucket histograms.

A :class:`MetricsRegistry` holds labeled series — each series is keyed
by ``(name, sorted(labels))`` so ``terminal_total{state="completed"}``
and ``terminal_total{state="expired"}`` are independent counters under
one logical name.  Everything is stdlib-only and mergeable: histograms
use *fixed* bucket edges (``value <= edge``, Prometheus ``le``
semantics) so two registries from different runs can be summed
bucket-by-bucket without rebinning.

Exports: :meth:`MetricsRegistry.to_dict` (JSON-friendly) and
:meth:`MetricsRegistry.to_prometheus` (text exposition format, the
cumulative-``le`` flavor), both consumed by ``tools/obs_report.py``.
"""

from __future__ import annotations

import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_MS_BUCKETS"]

# Latency-ish default edges (ms): wide dynamic range because interpret
# mode is ~100x slower than compiled, and both must land in-range.
DEFAULT_MS_BUCKETS = (0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
                      100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0)


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n

    def to_dict(self) -> dict:
        return {"type": "counter", "value": self.value}

    def merge(self, other: "Counter") -> None:
        self.value += other.value


class Gauge:
    """Point-in-time value (queue depth, slot occupancy)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1) -> None:
        self.value += n

    def dec(self, n: float = 1) -> None:
        self.value -= n

    def to_dict(self) -> dict:
        return {"type": "gauge", "value": self.value}

    def merge(self, other: "Gauge") -> None:
        # Last-writer-wins has no meaning across runs; keep the max so a
        # merged report still answers "how deep did the queue ever get".
        self.value = max(self.value, other.value)


class Histogram:
    """Fixed-bucket histogram with ``value <= edge`` (le) semantics.

    ``counts[i]`` is the number of observations with
    ``value <= buckets[i]`` and ``> buckets[i-1]``; ``overflow`` counts
    observations above the last edge (Prometheus ``+Inf`` bucket).
    Fixed edges make two histograms mergeable by elementwise sum.
    """

    __slots__ = ("buckets", "counts", "overflow", "total", "sum",
                 "min", "max")

    def __init__(self, buckets=DEFAULT_MS_BUCKETS):
        edges = tuple(float(b) for b in buckets)
        if not edges or list(edges) != sorted(set(edges)):
            raise ValueError(f"bucket edges must be sorted/unique: {buckets}")
        self.buckets = edges
        self.counts = [0] * len(edges)
        self.overflow = 0
        self.total = 0
        self.sum = 0.0
        self.min = None
        self.max = None

    def observe(self, v: float) -> None:
        self.total += 1
        self.sum += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v
        for i, edge in enumerate(self.buckets):
            if v <= edge:
                self.counts[i] += 1
                return
        self.overflow += 1

    def quantile(self, q: float) -> float | None:
        """Estimate the q-quantile from bucket edges (upper-edge bias).

        Returns the smallest bucket edge whose cumulative count covers
        rank ``ceil(q * total)``; ``max`` for observations beyond the
        last edge; ``None`` when empty.  Coarse by construction — the
        engine keeps exact samples where precision matters (slack
        estimation) and uses this for reporting.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0,1], got {q}")
        if self.total == 0:
            return None
        rank = max(1, int(q * self.total + 0.9999999))
        cum = 0
        for i, edge in enumerate(self.buckets):
            cum += self.counts[i]
            if cum >= rank:
                return edge
        return self.max

    def to_dict(self) -> dict:
        return {"type": "histogram", "buckets": list(self.buckets),
                "counts": list(self.counts), "overflow": self.overflow,
                "count": self.total, "sum": self.sum,
                "min": self.min, "max": self.max}

    def merge(self, other: "Histogram") -> None:
        if other.buckets != self.buckets:
            raise ValueError("cannot merge histograms with different edges")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.overflow += other.overflow
        self.total += other.total
        self.sum += other.sum
        if other.min is not None:
            self.min = other.min if self.min is None \
                else min(self.min, other.min)
        if other.max is not None:
            self.max = other.max if self.max is None \
                else max(self.max, other.max)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class MetricsRegistry:
    """Labeled series of counters/gauges/histograms, one per process
    component (each engine owns its own registry, so ledger/counter
    cross-checks compare like with like)."""

    def __init__(self):
        self._series: dict[tuple, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, labels: dict, factory):
        key = (name, _label_key(labels))
        inst = self._series.get(key)
        if inst is None:
            with self._lock:
                inst = self._series.get(key)
                if inst is None:
                    inst = factory()
                    self._series[key] = inst
        return inst

    def counter(self, name: str, **labels) -> Counter:
        c = self._get(name, labels, Counter)
        if not isinstance(c, Counter):
            raise TypeError(f"{name} already registered as {type(c).__name__}")
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        g = self._get(name, labels, Gauge)
        if not isinstance(g, Gauge):
            raise TypeError(f"{name} already registered as {type(g).__name__}")
        return g

    def histogram(self, name: str, buckets=None, **labels) -> Histogram:
        h = self._get(name, labels,
                      lambda: Histogram(buckets or DEFAULT_MS_BUCKETS))
        if not isinstance(h, Histogram):
            raise TypeError(f"{name} already registered as {type(h).__name__}")
        return h

    def value(self, name: str, **labels):
        """Current value of a counter/gauge series; 0 if never touched.

        The chaos cross-check reads counters it *expects* to exist; a
        scenario where nothing was shed must read ``shed_total == 0``
        without creating noise in the export, hence no registration.
        """
        inst = self._series.get((name, _label_key(labels)))
        if inst is None:
            return 0
        return inst.value

    def series(self) -> list[tuple[str, dict, object]]:
        """Snapshot: (name, labels-dict, instrument) sorted by name."""
        with self._lock:
            items = list(self._series.items())
        return sorted(((name, dict(lk), inst) for (name, lk), inst in items),
                      key=lambda t: (t[0], sorted(t[1].items())))

    def merge(self, other: "MetricsRegistry") -> None:
        for name, labels, inst in other.series():
            key = (name, _label_key(labels))
            mine = self._series.get(key)
            if mine is None:
                # Deep-copy via to_dict-free path: new instrument, merge in.
                if isinstance(inst, Counter):
                    mine = Counter()
                elif isinstance(inst, Gauge):
                    mine = Gauge()
                else:
                    mine = Histogram(inst.buckets)
                self._series[key] = mine
            mine.merge(inst)

    # -- export -------------------------------------------------------

    def to_dict(self) -> dict:
        """JSON-friendly: {"metrics": [{name, labels, ...instrument}]}."""
        out = []
        for name, labels, inst in self.series():
            rec = {"name": name, "labels": labels}
            rec.update(inst.to_dict())
            out.append(rec)
        return {"metrics": out}

    def to_prometheus(self) -> str:
        """Prometheus text exposition (cumulative ``le`` histograms)."""
        lines = []
        typed: set[str] = set()
        for name, labels, inst in self.series():
            kind = ("counter" if isinstance(inst, Counter)
                    else "gauge" if isinstance(inst, Gauge) else "histogram")
            if name not in typed:
                lines.append(f"# TYPE {name} {kind}")
                typed.add(name)
            lbl = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
            if isinstance(inst, Histogram):
                cum = 0
                for edge, cnt in zip(inst.buckets, inst.counts):
                    cum += cnt
                    le = f'le="{edge:g}"'
                    inner = f"{lbl},{le}" if lbl else le
                    lines.append(f"{name}_bucket{{{inner}}} {cum}")
                inner = f'{lbl},le="+Inf"' if lbl else 'le="+Inf"'
                lines.append(f"{name}_bucket{{{inner}}} {inst.total}")
                suffix = f"{{{lbl}}}" if lbl else ""
                lines.append(f"{name}_sum{suffix} {inst.sum:g}")
                lines.append(f"{name}_count{suffix} {inst.total}")
            else:
                suffix = f"{{{lbl}}}" if lbl else ""
                lines.append(f"{name}{suffix} {inst.value:g}")
        return "\n".join(lines) + "\n"
