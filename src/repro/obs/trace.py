"""Lightweight span tracer with a Chrome/Perfetto ``trace_event`` exporter.

Design constraints, in priority order:

1. **Zero-cost when disabled.**  The serving engine calls into the
   tracer on every tick phase and every slot transition; the <2 %
   bench-overhead gate (tools/check_bench.py) only holds if the
   disabled path allocates nothing.  ``span()`` on a disabled tracer
   returns a module-level singleton null context manager; ``begin()``
   returns ``None`` and ``end(None)`` is a single attribute check.
2. **Monotonic clocks.**  All timestamps come from
   ``time.perf_counter_ns()``; wall-clock never enters span math, so
   traces are immune to NTP steps.  Export normalizes to microseconds
   relative to the first recorded event (Perfetto renders absolute
   epoch offsets poorly).
3. **Thread-safe ring buffer.**  Completed spans land in a
   ``collections.deque(maxlen=capacity)`` under a lock — a long chaos
   run keeps the newest ``capacity`` spans instead of growing without
   bound.  Open span handles live on the caller's stack, not in shared
   state, so ``begin``/``end`` pairs may cross threads.

Tracks map to Perfetto threads: every distinct ``track`` string gets a
stable tid (insertion order) and a ``thread_name`` metadata event, so
the UI shows one named lane per slot / scheduler / transfer stream.

Optional ``jax.profiler.TraceAnnotation`` passthrough (constructor flag
``jax_annotations=True``) mirrors each span into the XLA profiler so
engine phases line up with device traces on real hardware.  The import
is lazy and failure-tolerant: this module stays stdlib-only unless the
feature is switched on.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque

__all__ = ["Tracer", "get_tracer", "set_tracer"]

_PID = 1  # single-process tool; Perfetto wants *a* pid, any constant works


class _NullSpan:
    """Shared no-op context manager returned by a disabled tracer."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """An open span handle: ``with tracer.span(...)`` or begin/end."""

    __slots__ = ("tracer", "name", "track", "args", "t0_ns", "_annotation")

    def __init__(self, tracer, name, track, args):
        self.tracer = tracer
        self.name = name
        self.track = track
        self.args = args
        self.t0_ns = time.perf_counter_ns()
        self._annotation = None
        if tracer._jax_annotations:
            self._annotation = tracer._enter_annotation(name)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.tracer.end(self)
        return False


class Tracer:
    """Span recorder with a bounded buffer and a Perfetto JSON exporter.

    Parameters
    ----------
    enabled:
        When ``False`` every call is a no-op returning shared
        singletons; flip on via ``tracer.enabled = True`` at any time.
    capacity:
        Ring-buffer size in completed spans; the oldest spans are
        dropped first.
    jax_annotations:
        Mirror spans into ``jax.profiler.TraceAnnotation`` so they
        appear inside XLA device traces.  Lazily imports jax; silently
        disabled if jax is unavailable.
    """

    def __init__(self, enabled: bool = True, capacity: int = 65536,
                 jax_annotations: bool = False):
        self.enabled = enabled
        self._events = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._tracks: dict[str, int] = {}
        self._dropped = 0
        self._jax_annotations = False
        self._annotation_cls = None
        if jax_annotations:
            try:
                from jax.profiler import TraceAnnotation
                self._annotation_cls = TraceAnnotation
                self._jax_annotations = True
            except Exception:
                pass  # no jax in this environment: spans still record

    # -- recording ----------------------------------------------------

    def span(self, name: str, track: str | None = None, args=None):
        """Context manager covering a span; null singleton when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, track, args)

    def begin(self, name: str, track: str | None = None, args=None):
        """Explicit-API start: returns a handle for :meth:`end`.

        Returns ``None`` when disabled; ``end(None)`` is a no-op, so
        call sites never need their own enabled check.
        """
        if not self.enabled:
            return None
        return _Span(self, name, track, args)

    def end(self, handle, args=None) -> None:
        """Close a span handle; merges ``args`` into the span's args."""
        if handle is None or handle is _NULL_SPAN:
            return
        dur_ns = time.perf_counter_ns() - handle.t0_ns
        if handle._annotation is not None:
            self._exit_annotation(handle._annotation)
        if args:
            merged = dict(handle.args) if handle.args else {}
            merged.update(args)
            handle.args = merged
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self._dropped += 1
            self._events.append(
                (handle.name, handle.track, handle.t0_ns, dur_ns,
                 handle.args))

    def instant(self, name: str, track: str | None = None, args=None) -> None:
        """Record a zero-duration marker event."""
        if not self.enabled:
            return
        now = time.perf_counter_ns()
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self._dropped += 1
            self._events.append((name, track, now, 0, args))

    # -- jax passthrough ----------------------------------------------

    def _enter_annotation(self, name):
        try:
            ann = self._annotation_cls(name)
            ann.__enter__()
            return ann
        except Exception:
            return None

    @staticmethod
    def _exit_annotation(ann) -> None:
        try:
            ann.__exit__(None, None, None)
        except Exception:
            pass

    # -- inspection / export ------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    @property
    def dropped(self) -> int:
        """Spans evicted from the ring buffer since construction."""
        return self._dropped

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0

    def spans(self) -> list[tuple]:
        """Snapshot of recorded spans as (name, track, t0_ns, dur_ns, args)."""
        with self._lock:
            return list(self._events)

    def _tid(self, track: str | None) -> int:
        # tid 0 is the default lane; named tracks get 1..N in first-seen
        # order so Perfetto's lane ordering matches program structure.
        if track is None:
            return 0
        tid = self._tracks.get(track)
        if tid is None:
            tid = len(self._tracks) + 1
            self._tracks[track] = tid
        return tid

    def to_chrome_trace(self) -> dict:
        """Render the buffer as a Chrome/Perfetto trace_event document.

        Complete ("X") events carry ``ts``/``dur`` in microseconds
        relative to the earliest recorded span; metadata ("M") events
        name the process and one thread per track.  The result loads
        directly in ui.perfetto.dev or chrome://tracing.
        """
        events = self.spans()
        t_base = min((e[2] for e in events), default=0)
        trace = []
        for name, track, t0_ns, dur_ns, args in events:
            ev = {
                "name": name,
                "cat": track or "default",
                "ph": "X",
                "ts": (t0_ns - t_base) / 1e3,
                "dur": dur_ns / 1e3,
                "pid": _PID,
                "tid": self._tid(track),
            }
            if args:
                ev["args"] = args
            trace.append(ev)
        meta = [{
            "name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
            "ts": 0, "args": {"name": "repro"},
        }, {
            "name": "thread_name", "ph": "M", "pid": _PID, "tid": 0,
            "ts": 0, "args": {"name": "main"},
        }]
        for track, tid in sorted(self._tracks.items(), key=lambda kv: kv[1]):
            meta.append({
                "name": "thread_name", "ph": "M", "pid": _PID, "tid": tid,
                "ts": 0, "args": {"name": track},
            })
        return {"traceEvents": meta + trace, "displayTimeUnit": "ms"}

    def export(self, path: str) -> int:
        """Write the Perfetto JSON document to ``path``; returns span count."""
        doc = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f)
        return sum(1 for e in doc["traceEvents"] if e["ph"] == "X")


# -- module-global tracer ---------------------------------------------
#
# Library code (models/snn.py, tune/measure.py) that has no natural
# object to hang a tracer on reads the process-global here.  It starts
# disabled, so by default every library call site takes the null path.

_GLOBAL = Tracer(enabled=False)


def get_tracer() -> Tracer:
    """The process-global tracer (disabled unless someone enabled it)."""
    return _GLOBAL


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` as the process-global; returns the previous one."""
    global _GLOBAL
    prev = _GLOBAL
    _GLOBAL = tracer
    return prev
