"""Roofline terms from a compiled dry-run artifact.

compute   = HLO_FLOPs_per_device / peak_FLOPs_per_chip
memory    = HLO_bytes_per_device / HBM_bandwidth
collective= wire_bytes_per_device / ICI_link_bandwidth

``cost_analysis()`` on an SPMD-compiled executable reports *per-device*
flops/bytes (verified against a hand-computed matmul).  Collective bytes are
NOT in cost_analysis, so we parse the post-SPMD HLO: every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
result shape, with a wire-cost factor per op kind (ring model: all-reduce
moves ~2x its payload, the others ~1x).

Collectives inside while loops (lax.scan over layer groups / microbatches)
appear ONCE in the HLO but execute trip-count times; we attribute trip counts
by locating each while op's condition computation and extracting its loop
bound constant.  Nested loops multiply.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_WIRE_FACTOR = {
    "all-reduce": 2.0,          # ring: reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\([^)]*\)\s*->", re.M)
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)"
    r"|while\(.*?\)[^\n]*?body=%?([\w\.\-]+)[^\n]*?condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict[str, str]:
    """Split module text into named computations."""
    comps: dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        m = _COMP_RE.match(line.strip()) if ("->" in line and "(" in line
                                             and not line.strip().startswith("%param")) else None
        if m and (line.startswith("ENTRY") or not line.startswith(" ")):
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name = m.group(1)
            cur_lines = [line]
        else:
            cur_lines.append(line)
    if cur_name is not None:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def _loop_bound(cond_text: str) -> int:
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    consts = [c for c in consts if 1 < c < 100000]
    return max(consts) if consts else 1


def _body_multipliers(hlo: str, comps: dict[str, str]) -> dict[str, int]:
    """Map computation name -> total trip multiplier (nested loops compose)."""
    # direct body -> bound
    parent: dict[str, tuple[str, int]] = {}
    for comp_name, text in comps.items():
        for m in _WHILE_RE.finditer(text):
            cond = m.group(1) or m.group(4)
            body = m.group(2) or m.group(3)
            if body and cond and cond in comps:
                parent[body] = (comp_name, _loop_bound(comps[cond]))

    mult: dict[str, int] = {}

    def resolve(name: str, seen=()) -> int:
        if name in mult:
            return mult[name]
        if name in seen:
            return 1
        if name in parent:
            pname, bound = parent[name]
            m = bound * resolve(pname, seen + (name,))
        else:
            m = 1
        mult[name] = m
        return m

    for name in comps:
        resolve(name)
    return mult


def collective_bytes(hlo: str) -> dict[str, float]:
    """Wire bytes per device by collective kind, loop-trip-count weighted."""
    comps = _split_computations(hlo)
    mults = _body_multipliers(hlo, comps)
    out: dict[str, float] = {k: 0.0 for k in _WIRE_FACTOR}
    counts: dict[str, int] = {k: 0 for k in _WIRE_FACTOR}
    for comp_name, text in comps.items():
        m = mults.get(comp_name, 1)
        for match in _COLL_RE.finditer(text):
            shape_str, kind = match.group(1), match.group(2)
            b = _shape_bytes(shape_str)
            out[kind] += b * _WIRE_FACTOR[kind] * m
            counts[kind] += m
    out_named = {f"{k}_bytes": v for k, v in out.items()}
    out_named.update({f"{k}_count": counts[k] for k in counts})
    out_named["total_wire_bytes"] = sum(out.values())
    return out_named


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops_per_device: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        if self.flops_per_device == 0:
            return 0.0
        return self.model_flops_per_device / self.flops_per_device

    @property
    def roofline_frac(self) -> float:
        """Fraction of the chip's peak the step would achieve if it runs at
        the dominant-term bound and only model_flops count as useful."""
        if self.bound_s == 0:
            return 0.0
        return (self.model_flops_per_device / PEAK_FLOPS) / self.bound_s

    def as_dict(self) -> dict[str, Any]:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "model_flops_per_device": self.model_flops_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def model_flops(n_active_params: int, n_tokens: int, kind: str,
                n_devices: int) -> float:
    """6*N*D rule (fwd+bwd) for train; 2*N*D for inference steps."""
    per_tok = 6 * n_active_params if kind == "train" else 2 * n_active_params
    return per_tok * n_tokens / n_devices


def from_compiled(compiled, lowered_text: str | None = None,
                  model_flops_per_device: float = 0.0) -> Roofline:
    from repro import compat
    ca = compat.cost_analysis_dict(compiled)
    hlo = lowered_text or compiled.as_text()
    coll = collective_bytes(hlo)
    return Roofline(
        flops_per_device=float(ca.get("flops", 0.0)),
        bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        wire_bytes_per_device=float(coll["total_wire_bytes"]),
        model_flops_per_device=model_flops_per_device,
    )
