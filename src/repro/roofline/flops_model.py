"""Analytical per-device FLOPs / HBM-bytes / wire-bytes model.

Why analytical: XLA's ``cost_analysis()`` counts a while-loop body ONCE, so a
model scanned over L layer groups and M microbatches under-reports flops by
~L*M; unrolling for the counter is not compilable at 512 devices.  The model
below reproduces exactly what the implementation executes (including its known
wastes: causal masking computed over full S, MoE capacity padding, remat
recompute), is validated against cost_analysis on small unrolled configs
(tests/test_roofline.py), and is the instrument the perf loop iterates on.

All numbers are per device per step.  Breakdown dicts let §Perf attribute each
change to a term.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.models import lm
from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS

BF16 = 2
F32 = 4


@dataclasses.dataclass
class MeshShape:
    pod: int = 1
    data: int = 16
    model: int = 16

    @property
    def dp(self) -> int:
        return self.pod * self.data

    @property
    def n_dev(self) -> int:
        return self.pod * self.data * self.model


def _attn_kv_per_query(cfg: lm.LMConfig, kind: str, block: str, s: int) -> tuple[float, float]:
    """(impl_kv_len, useful_kv_len) the implementation touches per query."""
    if kind == "decode":
        if block == "attn_local":
            w = min(cfg.window or s, s)
            return w, w
        return s, s
    if block == "attn_local" and cfg.window and cfg.window < s:
        return 2.0 * cfg.window, cfg.window  # diag + prev block vs true window
    if cfg.encoder_only:
        return s, s
    return float(s), s / 2.0               # full-S blockwise vs causal optimal


def _block_fwd_flops_per_token(cfg: lm.LMConfig, block: str, kind: str,
                               s: int) -> tuple[float, float]:
    """(impl_flops, useful_flops) of one block, forward, per token."""
    d, hd = cfg.d_model, cfg.hd
    h, g = cfg.n_heads, cfg.n_kv
    impl = useful = 0.0
    if block in ("attn", "attn_local"):
        proj = 2 * d * (h * hd) * 2 + 2 * d * (g * hd) * 2  # q,o + k,v
        kv_i, kv_u = _attn_kv_per_query(cfg, kind, block, s)
        attn_i = 2 * (h * hd) * kv_i * 2                    # qk^T + pv
        attn_u = 2 * (h * hd) * kv_u * 2
        impl += proj + attn_i
        useful += proj + attn_u
    elif block == "mlstm":
        proj = 2 * d * d * 5                                # q,k,v,ogate,out
        w = min(256, s)
        intra_i = 2 * d * w * 2                             # chunk attention
        intra_u = 2 * d * (w / 2) * 2
        state = 2 * d * hd * 2                              # kv^T outer + qC
        impl += proj + intra_i + state
        useful += proj + intra_u + state
    elif block == "slstm":
        proj = 2 * d * 4 * d + 2 * d * d                    # wx + wo
        rec = 2 * h * hd * 4 * hd                           # block-diag R
        impl += proj + rec
        useful += proj + rec
    elif block == "rglru":
        dr = cfg.d_rnn or d
        proj = 2 * d * dr * 3                               # in, gate-branch, out
        gates = 2 * dr * dr * 2                             # w_a, w_x
        scan = 10 * dr                                      # assoc-scan elementwise
        impl += proj + gates + scan
        useful += proj + gates + scan

    # FFN / MoE
    if cfg.d_ff > 0 and block not in ("mlstm", "slstm"):
        n_mat = 3 if cfg.gated_ffn else 2
        dense = 2 * d * cfg.d_ff * n_mat
        if cfg.moe:
            router = 2 * d * cfg.n_experts
            if kind == "decode":
                # dense-EP fallback: every local expert runs on every token
                per_dev_experts = cfg.n_experts / 16  # model axis
                moe_i = dense * per_dev_experts
                moe_u = dense * cfg.moe_top_k
            else:
                cf = 1.25
                moe_i = dense * cfg.moe_top_k * cf
                moe_u = dense * cfg.moe_top_k
            shared = dense * cfg.n_shared_experts
            resid = dense if cfg.moe_dense_residual else 0.0
            impl += router + moe_i + shared + resid
            useful += router + moe_u + shared + resid
        else:
            impl += dense
            useful += dense
    return impl, useful


def _per_layer_blocks(cfg: lm.LMConfig):
    blocks = list(cfg.pattern) * cfg.n_groups + list(cfg.tail_pattern)
    assert len(blocks) == cfg.n_layers
    return blocks


def fwd_flops_per_token(cfg: lm.LMConfig, kind: str, s: int,
                        with_full_head: bool) -> tuple[float, float]:
    impl = useful = 0.0
    for b in _per_layer_blocks(cfg):
        i, u = _block_fwd_flops_per_token(cfg, b, kind, s)
        impl += i
        useful += u
    if with_full_head:
        head = 2 * cfg.d_model * cfg.padded_vocab
        impl += head
        useful += 2 * cfg.d_model * cfg.vocab_size
    return impl, useful


def analyze(cfg: lm.LMConfig, shape_name: str, mesh: MeshShape,
            n_micro: int = 1, grad_bytes: int = F32,
            moment_bytes: int = F32,
            remat_factor: float | None = None) -> dict[str, Any]:
    sh = lm.SHAPES[shape_name]
    kind = sh["kind"]
    b_glob, s = sh["batch"], sh["seq"]
    n_dev = mesh.n_dev
    d = cfg.d_model
    nl = cfg.n_layers

    overrides = cfg.sharding_overrides or {}
    fsdp = overrides.get("embed") is not None     # dense weights over DP too
    moe_2d = cfg.moe and overrides.get("experts", "model") != "model"
    p_total = cfg.param_count()
    expert_p = _expert_params(cfg) if cfg.moe else 0.0
    dense_p = p_total - expert_p
    # local parameter bytes: TP always; FSDP/2D-EP divide by DP as well
    if moe_2d:
        p_local = expert_p / n_dev + dense_p / (n_dev if fsdp else mesh.model)
    else:
        p_local = p_total / (n_dev if fsdp else mesh.model)

    # remat knobs (§Perf): "group"+nothing = full recompute (4x fwd-unit);
    # "attn_only" recomputes just attention; "dots" saves matmul outputs.
    if remat_factor is None:
        if not cfg.remat:
            remat_factor = 3.0
        elif cfg.remat_mode == "attn_only":
            attn_i = sum(_block_fwd_flops_per_token(
                dataclasses.replace(cfg, d_ff=0), b, kind, s)[0]
                for b in _per_layer_blocks(cfg))
            total_i = fwd_flops_per_token(cfg, kind, s, True)[0]
            remat_factor = 3.0 + attn_i / max(total_i, 1.0)
        elif cfg.remat_policy == "dots":
            remat_factor = 3.05
        else:
            remat_factor = 4.0
    if not cfg.remat:
        wire_passes = 2.0
    elif cfg.remat_mode == "attn_only" or cfg.remat_policy == "dots":
        wire_passes = 2.0       # saved outputs -> collectives not recomputed
    else:
        wire_passes = 3.0

    if kind == "train":
        tokens = b_glob * s
        fwd_i, fwd_u = fwd_flops_per_token(cfg, kind, s, with_full_head=True)
        rf = remat_factor
        flops = tokens * fwd_i * rf / n_dev
        useful = tokens * fwd_u * 3.0 / n_dev          # fwd+bwd, no recompute
        model_f = 6 * cfg.active_param_count() * tokens / n_dev

        tokens_mb_dev = tokens / n_micro / mesh.dp     # per device-row
        passes = 3.0                                   # fwd + recompute + bwd
        act_bytes = 10 * nl * tokens_mb_dev * d * BF16 * passes * n_micro
        weight_bytes = 3 * p_local * BF16 * n_micro    # re-read each microbatch
        grad_acc_bytes = 2 * p_local * grad_bytes * n_micro
        opt_bytes = p_local * (BF16 * 2 + grad_bytes + moment_bytes * 4)
        logits_bytes = 3 * (tokens / n_micro / n_dev) * cfg.padded_vocab \
            * F32 * n_micro
        hbm = act_bytes + weight_bytes + grad_acc_bytes + opt_bytes \
            + logits_bytes

        # wire: TP activation collectives per layer per microbatch (+ MoE)
        tok_row = tokens / n_micro / mesh.dp           # per device-row
        tok_dev = tok_row / mesh.model                 # per device (seq-sharded)
        seq_sharded = overrides.get("seq") == "model"
        per_layer = (2.0 if seq_sharded else 4.0) * tok_row * d * BF16
        wire = per_layer * nl * n_micro * wire_passes
        if cfg.moe:
            cf = cfg.moe_capacity_factor
            wb = 1 if cfg.moe_wire_dtype == "int8" else BF16
            # a2a over the expert rows: send + receive each token's activation
            a2a = 2 * tok_dev * cfg.moe_top_k * cf * d * wb
            # 2D path adds the TP gather (wire dtype) + psum-scatter (bf16)
            tp_gs = (tok_dev * cfg.moe_top_k * cf * d * (wb + BF16)
                     if moe_2d else 0.0)
            # bwd runs the transposed collectives at bf16 (gradients)
            a2a_bwd = 2 * tok_dev * cfg.moe_top_k * cf * d * BF16
            tp_gs_bwd = (2 * tok_dev * cfg.moe_top_k * cf * d * BF16
                         if moe_2d else 0.0)
            fwd_passes = wire_passes - 1.0             # fwd (+ recompute)
            if cfg.remat_policy == "save_moe_recv" and cfg.remat:
                # x-side a2a + TP gather pinned: not re-run in the recompute
                # (the y-side path and all transposes still run)
                x_side = a2a / 2 + (tp_gs / 2 if moe_2d else 0.0)
                wire += ((a2a + tp_gs) * fwd_passes - x_side * (fwd_passes - 1)
                         + (a2a_bwd + tp_gs_bwd)) * nl * n_micro
            else:
                wire += ((a2a + tp_gs) * fwd_passes
                         + (a2a_bwd + tp_gs_bwd)) * nl * n_micro
        fsdp_dense = (dense_p if moe_2d else p_total) if fsdp else 0.0
        if fsdp:
            # FSDP on the dense weights: all-gather per pass per microbatch
            # (receive ~ the full row share) + one grad reduce-scatter.
            row_share = fsdp_dense / mesh.model * BF16
            wire += (wire_passes) * row_share * n_micro
            wire += fsdp_dense / mesh.model * grad_bytes   # grad RS over dp
        else:
            wire += 2 * p_local * grad_bytes           # DP grad all-reduce
        # 2D-EP expert grads/moments are fully local (no DP reduction).
    elif kind == "prefill":
        tokens = b_glob * s
        fwd_i, fwd_u = fwd_flops_per_token(cfg, kind, s, with_full_head=False)
        head = 2 * d * cfg.padded_vocab * b_glob       # last position only
        flops = (tokens * fwd_i + head) / n_dev
        useful = (tokens * fwd_u + head) / n_dev
        model_f = 2 * cfg.active_param_count() * tokens / n_dev
        tok_dev = tokens / mesh.dp
        seq_sharded = overrides.get("seq") == "model"
        act_bytes = 8 * nl * tok_dev * d * BF16
        cache_bytes = nl * tok_dev * cfg.n_kv * cfg.hd * 2 * BF16
        hbm = p_local * BF16 + act_bytes + cache_bytes
        per_layer = (2.0 if seq_sharded else 4.0) * tok_dev * d * BF16
        wire = per_layer * nl
        if cfg.moe:
            tok_disp = tokens / n_dev          # dispatch slice per device
            cf = 1.25
            wire += (2 + (2 if moe_2d else 0)) * tok_disp * cfg.moe_top_k \
                * cf * d * BF16 * nl
        if fsdp:
            wire += ((dense_p if moe_2d else p_total) / mesh.model) * BF16
    else:  # decode
        tokens = b_glob
        fwd_i, fwd_u = fwd_flops_per_token(cfg, kind, s, with_full_head=True)
        flops = tokens * fwd_i / n_dev
        useful = tokens * fwd_u / n_dev
        model_f = 2 * cfg.active_param_count() * tokens / n_dev
        # memory: every param + the whole cache is read once per token
        kv_scale = {None: 1.0, "int8": 0.5 + 2.0 / cfg.hd,
                    "int4": 0.25 + 2.0 / cfg.hd}[cfg.kv_quant]
        cache_total = _cache_bytes_total(cfg, b_glob, s) * kv_scale
        hbm = p_local * BF16 + cache_total / n_dev * 2.5  # r/w + one-hot upd
        b_dev = b_glob / mesh.dp
        wire = (4.0 * b_dev * d * BF16) * nl              # TP per layer
        wire += nl * b_dev * cfg.n_heads * cfg.hd * F32 * 2  # split-KV LSE
        if cfg.moe:
            wire += 2 * 2 * b_dev * d * BF16 * nl         # dense-EP psum

    return {
        "flops_per_device": flops,
        "useful_flops_per_device": useful,
        "model_flops_per_device": model_f,
        "bytes_per_device": hbm,
        "wire_bytes_per_device": wire,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm / HBM_BW,
        "collective_s": wire / ICI_BW,
        "dominant": max((flops / PEAK_FLOPS, "compute"),
                        (hbm / HBM_BW, "memory"),
                        (wire / ICI_BW, "collective"))[1],
        "bound_s": max(flops / PEAK_FLOPS, hbm / HBM_BW, wire / ICI_BW),
        "model_over_hlo": model_f / flops if flops else 0.0,
        "roofline_frac": (model_f / PEAK_FLOPS)
        / max(flops / PEAK_FLOPS, hbm / HBM_BW, wire / ICI_BW),
    }


def _expert_params(cfg: lm.LMConfig) -> float:
    """Total MoE expert-bank parameters (w_in + w_gate + w_out, all layers)."""
    per_layer = cfg.n_experts * 3 * cfg.d_model * cfg.d_ff
    return float(per_layer * cfg.n_layers)


def _cache_bytes_total(cfg: lm.LMConfig, b: int, s: int) -> float:
    total = 0.0
    for blk in _per_layer_blocks(cfg):
        if blk == "attn":
            total += b * s * cfg.n_kv * cfg.hd * 2 * BF16
        elif blk == "attn_local":
            w = min(cfg.window or s, s)
            total += b * w * cfg.n_kv * cfg.hd * 2 * BF16
        elif blk == "mlstm":
            hd = cfg.d_model // cfg.n_heads
            total += b * cfg.n_heads * (hd * hd + hd) * F32
        elif blk == "slstm":
            total += b * cfg.d_model * 4 * F32
        elif blk == "rglru":
            total += b * (cfg.d_rnn or cfg.d_model) * 4 * F32
    return total


def mesh_for(multi_pod: bool) -> MeshShape:
    return MeshShape(pod=2 if multi_pod else 1)
