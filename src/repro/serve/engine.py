"""Serving: jit-able serve_step (one decode token for a batch of requests), a
small batched engine (prompt queue -> prefill -> decode rounds) used by the
serving example and tests, and a batched event-stream engine that runs SNN
inference through the fused macro-step kernel.

serve_step is what the decode_32k / long_500k dry-run cells lower: one new
token against a KV cache of the cell's sequence length.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy as energy_lib
from repro.models import lm
from repro.models import snn as snn_lib


def build_serve_step(cfg: lm.LMConfig, mesh=None, *, temperature: float = 0.0):
    """Returns step(params, cache, tokens, pos, rng) ->
    (next_tokens (B,1), logits (B,V), cache)."""

    def serve_step(params, cache, tokens, pos, rng):
        logits, cache = lm.decode_step(params, cache, tokens, pos, cfg, mesh)
        logits = logits[:, :cfg.vocab_size]
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, cache

    return serve_step


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


@dataclasses.dataclass
class EventRequest:
    """One event-stream classification request: events (T, N_in) in {-1,0,1}."""

    uid: int
    events: Any                 # (T, N_in) array-like
    label: int | None = None
    logits: Any = None
    pred: int | None = None
    adc_steps: float | None = None   # mean early-stop ramp steps per time step
    density: float | None = None     # measured |event| rate (set on submit)
    skipped_block_ratio: float | None = None  # batch activity-plan skip rate
    _order: int | None = dataclasses.field(default=None, repr=False,
                                           compare=False)  # submission index


class SNNEventEngine:
    """Batched event-stream inference on the fused macro kernel.

    The hot loop is one jitted ``forward_silicon(fused=...)`` call per full
    batch.  With ``time_major=True`` (default) the *entire* event sequence
    of the batch runs in a single time-major Pallas launch: the T axis is
    folded into the kernel grid, the LIF membrane stays in VMEM across
    steps, and weight planes are staged once per sequence — serving cost
    per request is one kernel launch per batch, with no HBM-visible
    intermediates and no per-step launch overhead.  ``time_major=False``
    keeps the PR 1 per-step launch cadence (one fused kernel per time
    step), useful for measuring exactly that overhead.  Layers wider than
    one 256x128 macro are tiled inside the kernel either way.  Requests are
    padded to fixed ``batch_slots`` (dummy rows are all-zero event streams)
    so the jit cache holds exactly one entry.

    ``noise`` (an ``ima.IMANoiseModel``) serves through the *noisy* silicon
    model — the Fig. 7 conversion-error draws are generated inside the
    fused kernel by the counter PRNG, so noisy serving keeps the exact same
    one-launch-per-batch cost profile as clean serving (no pre-drawn noise
    tensors, no composed fallback), while every batch still gets fresh,
    reproducible draws from the engine's key stream.

    The fused kernel is activity-gated: MAC blocks with no events are
    skipped, at per-(step, row-tile) granularity.  Because requests in a
    batch share row tiles, one near-silent stream batched with busy ones
    inherits their occupancy — so with ``pack_by_density=True`` (default)
    the engine drains the queue in measured-event-density order, packing
    quiet requests with quiet: batches become density-homogeneous and the
    skipped-block ratio (reported per request, next to the early-stop
    ``adc_steps``) approaches what each stream would get alone.  Results
    are unchanged either way — gating is output-invariant; only the work
    moves.  Raw-MAC telemetry stays off on this hot path
    (``forward_silicon`` default).
    """

    def __init__(self, cfg: snn_lib.SNNConfig, params, batch_slots: int = 64,
                 seed: int = 0, time_major: bool = True, noise=None,
                 pack_by_density: bool = True):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.time_major = time_major
        self.noise = noise
        self.pack_by_density = pack_by_density
        self.pending: list[EventRequest] = []
        self.completed: list[EventRequest] = []
        self._submitted = 0
        self._key = jax.random.PRNGKey(seed)
        fused = "seq" if time_major else "step"
        self._fwd = jax.jit(
            lambda p, ev, key: snn_lib.forward_silicon(p, ev, cfg, key,
                                                       fused=fused,
                                                       noise=noise))

    def submit(self, req: EventRequest):
        if req.density is None:
            # host-side numpy: no device dispatch/sync on the submit path
            ev = np.asarray(req.events)
            req.density = float(np.count_nonzero(ev)) / ev.size
        req._order = self._submitted
        self._submitted += 1
        self.pending.append(req)

    def _run_batch(self, reqs: list[EventRequest]):
        ev = jnp.stack([jnp.asarray(r.events, jnp.float32) for r in reqs])
        pad = self.b - ev.shape[0]
        if pad:
            ev = jnp.concatenate(
                [ev, jnp.zeros((pad,) + ev.shape[1:], ev.dtype)])
        self._key, sub = jax.random.split(self._key)
        logits, tele = self._fwd(self.params, ev, sub)
        preds = jnp.argmax(logits, axis=-1)
        skipped = tele.get("skipped_block_ratio")
        for i, req in enumerate(reqs):
            req.logits = logits[i]
            req.pred = int(preds[i])
            req.adc_steps = float(tele["adc_steps"][i])
            if skipped is not None:
                req.skipped_block_ratio = float(skipped[i])
            self.completed.append(req)

    def run(self) -> list[EventRequest]:
        """Drain the queue in fixed-size batches; returns completed requests
        in submission order.

        Density packing reorders the *batches* (quiet requests run with
        quiet), but the returned list is always sorted back to the order
        the requests were submitted in — callers that zip results against
        their submission sequence must not see the packing permutation.
        """
        if self.pack_by_density:
            self.pending.sort(key=lambda r: (r.density or 0.0, r.uid))
        while self.pending:
            batch, self.pending = self.pending[:self.b], self.pending[self.b:]
            self._run_batch(batch)
        self.completed.sort(
            key=lambda r: r._order if r._order is not None else r.uid)
        return self.completed

    def energy_report(self, dataset: str) -> dict:
        """Serving-side energy estimate from *measured* early-stop statistics.

        Uses the calibrated per-component model (core.energy) but replaces
        the analytic early-stop saving with the mean ADC step count the KWN
        controller actually reported for the served traffic.

        Every statistic in the report — ADC steps, energy, and the
        skipped-block ratio — is computed over the same population: the
        completed requests that carry measured ``adc_steps``.  Returns
        ``{}`` (documented contract, not an error) when there is nothing
        to report: no completed KWN request with measured ADC statistics,
        or the engine serves NLD mode, whose ramp always runs all
        2**code_bits - 1 steps so there is no measured early-stop to
        report.
        """
        done = [r for r in self.completed if r.adc_steps is not None]
        if not done or self.cfg.mode != "kwn":
            return {}
        if dataset not in energy_lib.SPIKE_RATES:
            raise ValueError(
                f"unknown dataset {dataset!r} for the calibrated spike rate; "
                f"expected one of {sorted(energy_lib.SPIKE_RATES)}")
        mean_steps = sum(r.adc_steps for r in done) / len(done)
        full = 2 ** self.cfg.code_bits - 1
        spike_rate = energy_lib.SPIKE_RATES[dataset]
        bd = energy_lib.kwn_step_energy(self.cfg.k, spike_rate,
                                        adc_steps=mean_steps)
        rep = {
            "requests": len(done),
            "mean_adc_steps": mean_steps,
            "measured_adc_saving": 1.0 - mean_steps / full,
            "pj_per_step": bd.total,
            "pj_per_sop": bd.total / energy_lib.sops_per_step(spike_rate),
        }
        # same population as the ADC/energy stats above — a request that
        # carries a skip ratio but no adc_steps must not dilute the mean
        skipped = [r.skipped_block_ratio for r in done
                   if r.skipped_block_ratio is not None]
        if skipped:
            # measured activity-plan saving, next to the early-stop saving
            rep["mean_skipped_block_ratio"] = sum(skipped) / len(skipped)
        return rep


class BatchedEngine:
    """Minimal continuous-batching engine: fixed B slots, requests are
    admitted as slots free, prefill runs token-by-token through the decode
    path (teacher forcing), then decode until each request completes."""

    def __init__(self, cfg: lm.LMConfig, params, batch_slots: int = 4,
                 s_max: int = 256, mesh=None):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.s_max = s_max
        self.step_fn = jax.jit(build_serve_step(cfg, mesh))
        self.cache = lm.init_cache(cfg, batch_slots, s_max)
        self.pos = jnp.zeros((batch_slots,), jnp.int32)
        self.slots: list[Request | None] = [None] * batch_slots
        self.pending: list[Request] = []
        self.completed: list[Request] = []
        self._next_token = jnp.zeros((batch_slots, 1), jnp.int32)
        self._rng = jax.random.PRNGKey(0)

    def submit(self, req: Request):
        self.pending.append(req)

    def _admit(self):
        for i in range(self.b):
            if self.slots[i] is None and self.pending:
                req = self.pending.pop(0)
                self.slots[i] = req
                # prefill: feed prompt tokens through decode path
                for t, tok in enumerate(req.prompt):
                    toks = self._next_token.at[i, 0].set(tok)
                    pos = self.pos.at[i].set(t)
                    nxt, _, self.cache = self.step_fn(
                        self.params, self.cache, toks, pos, self._rng)
                    self._next_token = self._next_token.at[i].set(nxt[i])
                self.pos = self.pos.at[i].set(len(req.prompt))

    def run(self, max_rounds: int = 64):
        while (self.pending or any(self.slots)) and max_rounds > 0:
            max_rounds -= 1
            self._admit()
            if not any(self.slots):
                break
            self._rng, sub = jax.random.split(self._rng)
            nxt, _, self.cache = self.step_fn(self.params, self.cache,
                                              self._next_token, self.pos, sub)
            self._next_token = nxt
            self.pos = self.pos + jnp.array(
                [1 if s is not None else 0 for s in self.slots], jnp.int32)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                req.generated.append(int(nxt[i, 0]))
                if req.done or int(self.pos[i]) >= self.s_max - 1:
                    self.completed.append(req)
                    self.slots[i] = None
        return self.completed
