"""Serving: jit-able serve_step (one decode token for a batch of requests) and
a small batched engine (prompt queue -> prefill -> decode rounds) used by the
serving example and tests.

serve_step is what the decode_32k / long_500k dry-run cells lower: one new
token against a KV cache of the cell's sequence length.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import lm


def build_serve_step(cfg: lm.LMConfig, mesh=None, *, temperature: float = 0.0):
    """Returns step(params, cache, tokens, pos, rng) ->
    (next_tokens (B,1), logits (B,V), cache)."""

    def serve_step(params, cache, tokens, pos, rng):
        logits, cache = lm.decode_step(params, cache, tokens, pos, cfg, mesh)
        logits = logits[:, :cfg.vocab_size]
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, cache

    return serve_step


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class BatchedEngine:
    """Minimal continuous-batching engine: fixed B slots, requests are
    admitted as slots free, prefill runs token-by-token through the decode
    path (teacher forcing), then decode until each request completes."""

    def __init__(self, cfg: lm.LMConfig, params, batch_slots: int = 4,
                 s_max: int = 256, mesh=None):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.s_max = s_max
        self.step_fn = jax.jit(build_serve_step(cfg, mesh))
        self.cache = lm.init_cache(cfg, batch_slots, s_max)
        self.pos = jnp.zeros((batch_slots,), jnp.int32)
        self.slots: list[Request | None] = [None] * batch_slots
        self.pending: list[Request] = []
        self.completed: list[Request] = []
        self._next_token = jnp.zeros((batch_slots, 1), jnp.int32)
        self._rng = jax.random.PRNGKey(0)

    def submit(self, req: Request):
        self.pending.append(req)

    def _admit(self):
        for i in range(self.b):
            if self.slots[i] is None and self.pending:
                req = self.pending.pop(0)
                self.slots[i] = req
                # prefill: feed prompt tokens through decode path
                for t, tok in enumerate(req.prompt):
                    toks = self._next_token.at[i, 0].set(tok)
                    pos = self.pos.at[i].set(t)
                    nxt, _, self.cache = self.step_fn(
                        self.params, self.cache, toks, pos, self._rng)
                    self._next_token = self._next_token.at[i].set(nxt[i])
                self.pos = self.pos.at[i].set(len(req.prompt))

    def run(self, max_rounds: int = 64):
        while (self.pending or any(self.slots)) and max_rounds > 0:
            max_rounds -= 1
            self._admit()
            if not any(self.slots):
                break
            self._rng, sub = jax.random.split(self._rng)
            nxt, _, self.cache = self.step_fn(self.params, self.cache,
                                              self._next_token, self.pos, sub)
            self._next_token = nxt
            self.pos = self.pos + jnp.array(
                [1 if s is not None else 0 for s in self.slots], jnp.int32)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                req.generated.append(int(nxt[i, 0]))
                if req.done or int(self.pos[i]) >= self.s_max - 1:
                    self.completed.append(req)
                    self.slots[i] = None
        return self.completed
