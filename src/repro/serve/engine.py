"""Serving: jit-able serve_step (one decode token for a batch of requests), a
small batched engine (prompt queue -> prefill -> decode rounds) used by the
serving example and tests, and a batched event-stream engine that runs SNN
inference through the fused macro-step kernel.

serve_step is what the decode_32k / long_500k dry-run cells lower: one new
token against a KV cache of the cell's sequence length.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy as energy_lib
from repro.models import lm
from repro.models import snn as snn_lib
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve import lifecycle

# Round-time estimation (see _round_ms_estimate).  The EMA keeps a
# cheap running estimate for the first few rounds; once at least
# ROUND_MS_P95_MIN_SAMPLES kernel rounds have been timed, deadline-risk
# slack switches to the exact p95 of the recent-sample window — an EMA
# tracks the *center* of a jittery distribution, while admission slack
# needs the *tail* (an optimistic estimate admits requests that then
# blow their deadline; ROADMAP flagged the EMA as near-meaningless in
# interpret mode for exactly this reason).
ROUND_MS_EMA_DECAY = 0.9          # weight on history per EMA update
ROUND_MS_P95_MIN_SAMPLES = 8      # exact-p95 takes over at this depth
ROUND_MS_SAMPLE_WINDOW = 512      # recent rounds kept for exact quantiles

# Fixed bucket edges for the per-request metric histograms.  ADC sweep
# depth is bounded by the ramp (2**code_bits - 1 = 15 for the paper's
# 4-bit code); ratios live in [0, 1]; modeled pJ/SOP lands near the
# paper's 0.8 headline.
ADC_STEP_BUCKETS = (1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 10.0, 12.0,
                    14.0, 15.0)
RATIO_BUCKETS = (0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0)
PJ_PER_SOP_BUCKETS = (0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 2.0, 3.0, 5.0)


def build_serve_step(cfg: lm.LMConfig, mesh=None, *, temperature: float = 0.0):
    """Returns step(params, cache, tokens, pos, rng) ->
    (next_tokens (B,1), logits (B,V), cache)."""

    def serve_step(params, cache, tokens, pos, rng):
        logits, cache = lm.decode_step(params, cache, tokens, pos, cfg, mesh)
        logits = logits[:, :cfg.vocab_size]
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, cache

    return serve_step


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


@dataclasses.dataclass
class EventRequest:
    """One event-stream classification request: events (T, N_in) in {-1,0,1}.

    ``priority`` (higher wins) and ``deadline_ms`` (wall milliseconds from
    submission) feed the preemptive scheduler; both default to "no
    opinion", under which the engine behaves exactly like the plain
    continuous-batching engine (no preemption ever triggers).  ``state``
    walks the ``serve.lifecycle`` machine and always ends in a terminal
    state — COMPLETED, EXPIRED, or REJECTED.
    """

    uid: int
    events: Any                 # (T, N_in) array-like
    label: int | None = None
    logits: Any = None
    pred: int | None = None
    adc_steps: float | None = None   # mean early-stop ramp steps per time step
    density: float | None = None     # measured |event| rate (set on submit)
    skipped_block_ratio: float | None = None  # batch activity-plan skip rate
    key: Any = None                  # per-request PRNG key (continuous path)
    latency_ms: float | None = None  # submit -> eviction wall time
    sops: float | None = None        # measured synaptic ops per time step
    priority: int = 0                # scheduler priority (higher preempts)
    deadline_ms: float | None = None  # SLO deadline, wall ms from submit
    state: str = lifecycle.QUEUED    # lifecycle state (see serve.lifecycle)
    preemptions: int = 0             # times this request was checkpointed out
    preempted_ms: float = 0.0        # total wall ms spent checkpointed out
    deadline_missed: bool | None = None  # completed after its deadline?
    _order: int | None = dataclasses.field(default=None, repr=False,
                                           compare=False)  # submission index
    _t_submit: float | None = dataclasses.field(default=None, repr=False,
                                                compare=False)
    _ckpt: Any = dataclasses.field(default=None, repr=False, compare=False)
    _not_before: int = dataclasses.field(default=0, repr=False, compare=False)
    _t_preempt_out: float | None = dataclasses.field(default=None, repr=False,
                                                     compare=False)
    _span: Any = dataclasses.field(default=None, repr=False, compare=False)


@functools.lru_cache(maxsize=None)
def _legacy_forward(cfg, fused: str, noise):
    """One jitted drain-path forward per (config, cadence, noise model).

    Module-level cache so every engine instance over the same config
    shares one compiled executable (a per-instance ``jax.jit(lambda ...)``
    would recompile per engine — ruinous for the serve benchmarks' warm
    trials).  ``cfg`` (frozen dataclass) and ``noise`` (NamedTuple) are
    hashable, so they can key the cache and close over the trace.
    """
    return jax.jit(lambda p, ev, key: snn_lib.forward_silicon(
        p, ev, cfg, key, fused=fused, noise=noise))


class SNNEventEngine:
    """Event-stream inference on the fused macro kernel, served either by
    step-granularity *continuous batching* (default) or by legacy
    drain-the-queue batches.

    **Continuous path** (``continuous=True``, auto-selected for
    time-major single-layer configs).  The engine keeps ``batch_slots``
    persistent serving slots whose LIF membrane — the SNN analog of an LM
    engine's KV cache — lives on device in a
    ``snn.SiliconStreamState`` and is carried across rounds.  Each round
    advances every occupied slot by ``round_steps`` time steps through
    one time-major fused kernel launch; between rounds, finished requests
    are evicted (their slot's accumulators are normalized by *their own*
    stream length, never the round count) and waiting requests are
    admitted into the freed slots mid-flight, with the slot state reset
    on admit.  Mixed stream lengths batch naturally — the batch shape is
    always ``(round_steps, batch_slots)``, so the jit cache holds one
    entry regardless of the traffic's length mix.

    Noise is *per-request* on this path: each request's counter-PRNG seed
    (from ``req.key``, folded from the engine seed by submission index)
    rides the kernel's ``row_ctl`` lane, and the clean-path SNL PRBS is a
    per-slot LFSR.  Served logits and ADC telemetry are therefore
    bitwise-identical to a one-shot batch-1
    ``forward_silicon(fused="seq")`` of the same request — independent of
    co-batched traffic, admission order, or scheduling policy.

    With ``pack_by_density=True`` the admission scheduler uses measured
    event density as its cost model: it fills free slots with the pending
    requests closest to the resident batch's mean density (quietest-first
    into an empty batch), so activity-gated block skipping — which is
    per row-*tile*, shared across co-resident slots — survives batching.
    Results are unchanged either way; only the work moves.

    **Legacy path** (``continuous=False``, and the automatic fallback for
    ``time_major=False`` or multi-layer stacks).  One jitted
    ``forward_silicon(fused=...)`` call per fixed-size batch of whole
    sequences, padded to ``batch_slots`` rows; batches are bucketed by
    stream length (one jit entry per distinct T served).  ``noise`` draws
    then come from the engine's per-batch key stream, as before.

    **Robustness layer** (this is what turns the round loop into something
    that can face real traffic; see ``docs/SERVING.md``):

    * *Validation*: ``submit()`` rejects malformed event tensors with the
      typed ``serve.lifecycle`` errors before anything is staged for a
      kernel launch (``validate=False`` opts out for trusted callers).
    * *Load shedding*: with ``max_pending`` set, the admission queue is
      bounded — an overflowing submit sheds the lowest-priority (then
      newest) queued request with the terminal ``REJECTED`` state instead
      of growing without bound.
    * *Deadlines*: a queued request whose ``deadline_ms`` passes before it
      can be admitted is retired with the terminal ``EXPIRED`` state
      (resident requests always run to completion — finishing beats
      killing mid-stream).
    * *Preemption* (continuous path, ``preemptive=True``): when the queue
      holds a higher-priority or deadline-at-risk request and no slot is
      free, the scheduler checkpoints the longest-running lowest-priority
      slot to host memory (``snn.SlotCheckpoint``) and admits the urgent
      request.  The victim re-enters the queue with exponential backoff
      (``backoff_rounds * 2**(preemptions-1)`` scheduling ticks) and
      resumes from its checkpoint — in any free slot, at its exact step
      offset — bitwise-identical to an uninterrupted run.  Thrash guards:
      a slot must be resident ``preempt_quantum`` rounds before it is a
      victim, a request is never preempted more than ``max_preemptions``
      times, and at most one preemption happens per scheduling tick.

    Raw-MAC telemetry stays off on both hot paths.
    """

    def __init__(self, cfg: snn_lib.SNNConfig, params, batch_slots: int = 64,
                 seed: int = 0, time_major: bool = True, noise=None,
                 pack_by_density: bool = True,
                 continuous: bool | None = None, round_steps: int = 8,
                 max_pending: int | None = None, preemptive: bool = True,
                 preempt_quantum: int = 1, max_preemptions: int = 3,
                 backoff_rounds: int = 1, risk_margin_ms: float | None = None,
                 validate: bool = True, tracer=None, metrics=None):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.time_major = time_major
        self.noise = noise
        self.pack_by_density = pack_by_density
        self.pending: list[EventRequest] = []
        self.completed: list[EventRequest] = []
        self.rejected: list[EventRequest] = []
        self.expired: list[EventRequest] = []
        self._submitted = 0
        self._key = jax.random.PRNGKey(seed)
        self._base_key = jax.random.PRNGKey(seed)
        self._fused = "seq" if time_major else "step"
        supported = time_major and len(cfg.layer_widths) == 1
        if continuous is None:
            continuous = supported
        elif continuous and not supported:
            raise ValueError(
                "continuous batching needs the time-major fused kernel and "
                "a single-layer config; pass continuous=False (or leave it "
                "None to auto-select) for per-step cadence or stacks")
        self.continuous = continuous
        self.round_steps = round_steps
        self.max_pending = max_pending
        self.preemptive = preemptive
        self.preempt_quantum = preempt_quantum
        self.max_preemptions = max_preemptions
        self.backoff_rounds = backoff_rounds
        # deadline-risk margin: a deadline-bearing candidate counts as
        # at-risk when its estimated slack falls under this many wall ms.
        # None = auto (two rounds at the measured EMA round time).
        self.risk_margin_ms = risk_margin_ms
        self.validate = validate
        self.preemption_count = 0        # total preemptions (policy + forced)
        self._rounds_total = 0           # monotonic scheduling-tick counter
        self._round_ms = 0.0             # EMA wall ms per round (estimates)
        self._round_samples: deque[float] = deque(
            maxlen=ROUND_MS_SAMPLE_WINDOW)
        # observability: spans go to the engine tracer (falls back to the
        # process-global, which starts disabled — the zero-cost default);
        # metrics are always recorded into a per-engine registry so the
        # chaos harness can cross-check counters against *this* engine's
        # ledgers without bleed from other engines in the process.
        self._tracer = tracer
        self.metrics = metrics if metrics is not None \
            else obs_metrics.MetricsRegistry()
        m = self.metrics
        self._m_rounds = m.counter("rounds_total")
        self._m_round_ms = m.histogram("round_ms")
        self._m_admitted = m.counter("admitted_total")
        self._m_evicted = m.counter("evicted_total")
        self._m_preempted = m.counter("preempted_total")
        self._m_shed = m.counter("shed_total")
        self._m_expired = m.counter("expired_total")
        self._m_queue = m.gauge("queue_depth")
        self._m_occupancy = m.gauge("slot_occupancy")
        self._m_terminal = {
            s: m.counter("terminal_total", state=s)
            for s in sorted(lifecycle.TERMINAL_STATES)}
        self._m_latency = m.histogram("request_latency_ms")
        self._m_adc = m.histogram("request_adc_steps",
                                  buckets=ADC_STEP_BUCKETS)
        self._m_skip = m.histogram("request_skipped_block_ratio",
                                   buckets=RATIO_BUCKETS)
        self._m_pj = m.histogram("request_pj_per_sop",
                                 buckets=PJ_PER_SOP_BUCKETS)
        # continuous-path slot table (host shadows of the device state)
        self._state = (snn_lib.silicon_stream_init(cfg, batch_slots)
                       if continuous else None)
        self._slot_req: list[EventRequest | None] = [None] * batch_slots
        self._slot_len = np.zeros(batch_slots, np.int32)
        self._slot_done = np.zeros(batch_slots, np.int32)
        self._slot_seed = np.zeros(batch_slots, np.int32)
        self._slot_admit_round = np.zeros(batch_slots, np.int64)

    @property
    def tracer(self) -> obs_trace.Tracer:
        """Engine tracer: the one passed at construction, else the
        process-global (resolved per access so ``set_tracer`` after
        engine construction still takes effect)."""
        t = self._tracer
        return t if t is not None else obs_trace.get_tracer()

    def _record_terminal(self, req: EventRequest) -> None:
        """Exactly-one-increment bookkeeping for a terminal transition.

        Every code path that appends to a terminal ledger (completed /
        rejected / expired) calls this exactly once, so
        ``terminal_total{state=...}`` always equals the ledger lengths —
        the invariant tests/test_obs.py and the chaos harness assert.
        """
        self._m_terminal[req.state].inc()

    def _observe_completed(self, req: EventRequest) -> None:
        """Feed the per-request telemetry histograms at completion."""
        if req.latency_ms is not None:
            self._m_latency.observe(req.latency_ms)
        if req.adc_steps is not None:
            self._m_adc.observe(req.adc_steps)
        if req.skipped_block_ratio is not None:
            self._m_skip.observe(req.skipped_block_ratio)
        if req.adc_steps is not None and self.cfg.mode == "kwn" \
                and req.density:
            # modeled pJ/SOP for *this* request: the calibrated component
            # model evaluated at the request's measured early-stop depth,
            # with its measured event density standing in for the
            # dataset spike rate (the engine does not know the dataset;
            # energy_report recomputes with the calibrated rate)
            bd = energy_lib.kwn_step_energy(self.cfg.k, req.density,
                                            adc_steps=req.adc_steps)
            self._m_pj.observe(
                bd.total / energy_lib.sops_per_step(req.density))

    def submit(self, req: EventRequest) -> EventRequest:
        """Enqueue a request; returns it with ``state`` set.

        Raises a typed ``serve.lifecycle`` error (``EmptyEventError`` /
        ``EventDtypeError`` / ``EventShapeError`` / ``NonFiniteEventError``
        / ``NonTernaryEventError``) if the event tensor violates the kernel
        input contract — nothing malformed ever reaches a launch.  With a
        bounded queue (``max_pending``), an overflowing submit sheds the
        lowest-priority / newest request instead: the shed request (which
        may be ``req`` itself) gets the terminal ``REJECTED`` state and is
        recorded in ``self.rejected``.
        """
        if self.validate:
            lifecycle.validate_events(req.events, self.cfg.n_in)
        if req.density is None:
            # host-side numpy: no device dispatch/sync on the submit path
            ev = np.asarray(req.events)
            req.density = float(np.count_nonzero(ev)) / ev.size
        req._order = self._submitted
        req._t_submit = time.perf_counter()
        req.state = lifecycle.QUEUED
        self._submitted += 1
        if self.max_pending is not None and \
                len(self.pending) >= self.max_pending:
            # shed the least valuable: lowest priority, then newest arrival
            # (never shed a preempted request holding a checkpoint — its
            # work would be lost; shedding fresh work is strictly cheaper)
            victims = [r for r in self.pending + [req] if r._ckpt is None]
            victim = min(victims or [req],
                         key=lambda r: (r.priority, -r._order))
            victim.state = lifecycle.REJECTED
            self.rejected.append(victim)
            self._m_shed.inc()
            self._record_terminal(victim)
            tr = self.tracer
            if tr.enabled:
                tr.instant(f"shed req{victim.uid}", track="scheduler",
                           args={"uid": victim.uid,
                                 "priority": victim.priority})
            if victim is req:
                return req
            self.pending.remove(victim)
        self.pending.append(req)
        self._m_queue.set(len(self.pending))
        return req

    # ------------------------------------------------------------------
    # Legacy drain path (continuous=False): fixed batches, whole sequences
    # ------------------------------------------------------------------

    def _run_batch(self, reqs: list[EventRequest]) -> list[EventRequest]:
        tr = self.tracer
        batch_span = tr.begin("legacy_batch", track="scheduler")
        ev = jnp.stack([jnp.asarray(r.events, jnp.float32) for r in reqs])
        pad = self.b - ev.shape[0]
        if pad:
            ev = jnp.concatenate(
                [ev, jnp.zeros((pad,) + ev.shape[1:], ev.dtype)])
        self._key, sub = jax.random.split(self._key)
        fwd = _legacy_forward(self.cfg, self._fused, self.noise)
        logits, tele = fwd(self.params, ev, sub)
        preds = jnp.argmax(logits, axis=-1)
        skipped = tele.get("skipped_block_ratio")
        t_done = time.perf_counter()
        for i, req in enumerate(reqs):
            req.logits = logits[i]
            req.pred = int(preds[i])
            req.adc_steps = float(tele["adc_steps"][i])
            req.sops = float(tele["sops"][i])
            if skipped is not None:
                req.skipped_block_ratio = float(skipped[i])
            if req._t_submit is not None:
                req.latency_ms = (t_done - req._t_submit) * 1e3
            req.state = lifecycle.COMPLETED
            if req.deadline_ms is not None and req.latency_ms is not None:
                req.deadline_missed = req.latency_ms > req.deadline_ms
            self.completed.append(req)
            self._record_terminal(req)
            self._observe_completed(req)
        tr.end(batch_span,
               args={"batch": len(reqs)} if batch_span is not None else None)
        return reqs

    def _take_bucket(self) -> list[EventRequest]:
        """Next batch off the queue: up to ``b`` requests sharing one T.

        The legacy launch stacks whole sequences, so a batch must be
        rectangular; bucketing by stream length (instead of the old
        ``jnp.stack`` crash) keeps results exact.  Each distinct T compiles
        its own jit entry — the engine's cache holds one entry *per stream
        length served*, not one total.
        """
        t0 = np.asarray(self.pending[0].events).shape[0]
        batch = [r for r in self.pending
                 if np.asarray(r.events).shape[0] == t0][:self.b]
        taken = {id(r) for r in batch}
        self.pending = [r for r in self.pending if id(r) not in taken]
        return batch

    def _run_legacy(self) -> list[EventRequest]:
        self._expire_pending()
        if self.pack_by_density:
            self.pending.sort(key=lambda r: (r.density or 0.0, r.uid))
        drained: list[EventRequest] = []
        while self.pending:
            drained.extend(self._run_batch(self._take_bucket()))
        drained.sort(key=lambda r: r._order if r._order is not None
                     else r.uid)
        return drained

    # ------------------------------------------------------------------
    # Continuous path: step-granularity rounds over persistent slots
    # ------------------------------------------------------------------

    def _request_seed(self, req: EventRequest) -> int:
        """Per-request counter-PRNG seed word, assigned at admission.

        Each request gets its own key (folded from the engine seed by
        submission index unless the caller set ``req.key``), so its noise
        stream — and therefore its logits — are a pure function of the
        request, independent of co-batched traffic or admission order.
        A one-shot ``forward_silicon(p, ev[None], cfg, req.key,
        fused="seq", noise=...)`` reproduces the served result bitwise.
        """
        if req.key is None:
            req.key = jax.random.fold_in(self._base_key, req._order)
        if self.noise is None:
            return 0              # clean serving never reads the seed word
        return int(snn_lib._noise_seed(req.key))

    # --- deadline bookkeeping -----------------------------------------

    def _expire_pending(self) -> None:
        """Retire queued requests whose deadline has already passed.

        Only *queued* requests expire — a resident request always runs to
        completion (its work is already partly paid for; finishing late
        beats discarding mid-stream).  Expired requests reach the terminal
        ``EXPIRED`` state and land in ``self.expired``.
        """
        if not any(r.deadline_ms is not None for r in self.pending):
            return
        now = time.perf_counter()
        keep: list[EventRequest] = []
        tr = self.tracer
        for r in self.pending:
            if r.deadline_ms is not None and r._t_submit is not None and \
                    (now - r._t_submit) * 1e3 > r.deadline_ms:
                r.state = lifecycle.EXPIRED
                self.expired.append(r)
                self._m_expired.inc()
                self._record_terminal(r)
                if tr.enabled:
                    tr.instant(f"expire req{r.uid}", track="scheduler",
                               args={"uid": r.uid,
                                     "deadline_ms": r.deadline_ms})
            else:
                keep.append(r)
        self.pending = keep

    def _round_ms_estimate(self) -> float:
        """Round-time estimate feeding the deadline-risk slack math.

        Exact p95 of the recent-round sample window once at least
        ``ROUND_MS_P95_MIN_SAMPLES`` kernel rounds have been timed — the
        pessimistic tail is what slack estimation needs — falling back
        to the EMA while the window is still warming up.
        """
        n = len(self._round_samples)
        if n >= ROUND_MS_P95_MIN_SAMPLES:
            s = sorted(self._round_samples)
            return s[min(n - 1, int(n * 0.95))]
        return self._round_ms

    def _slack_ms(self, req: EventRequest, now: float) -> float:
        """Estimated deadline slack in wall ms (+inf if no deadline).

        slack = deadline - elapsed - (remaining rounds x estimated round
        time; p95 of recent rounds once warm, EMA before that — see
        ``_round_ms_estimate``).  A checkpointed request's remaining
        work starts at its recorded step offset, so a mostly-done
        preempted request reads as *less* at-risk than a fresh one with
        the same deadline.
        """
        if req.deadline_ms is None or req._t_submit is None:
            return math.inf
        elapsed = (now - req._t_submit) * 1e3
        if req._ckpt is not None:
            t, done = req._ckpt.length, req._ckpt.steps_done
        else:
            t, done = np.asarray(req.events).shape[0], 0
        est = math.ceil((t - done) / self.round_steps) \
            * self._round_ms_estimate()
        return req.deadline_ms - elapsed - est

    # --- admission ----------------------------------------------------

    def _admit(self) -> None:
        free = [i for i, r in enumerate(self._slot_req) if r is None]
        if not free or not self.pending:
            return
        # backoff gate: a freshly preempted request sits out its
        # exponential-backoff window (measured in scheduling ticks, which
        # advance even on idle rounds, so the window always expires)
        eligible = [r for r in self.pending
                    if r._not_before <= self._rounds_total]
        if not eligible:
            return
        scheduled = any(r.priority != 0 or r.deadline_ms is not None
                        or r._ckpt is not None for r in eligible)
        if scheduled:
            # urgency order: priority first, then tightest deadline slack,
            # then submission order (total order -> deterministic)
            now = time.perf_counter()
            eligible.sort(key=lambda r: (-r.priority,
                                         self._slack_ms(r, now), r._order))
        elif self.pack_by_density:
            active = [r.density or 0.0
                      for r in self._slot_req if r is not None]
            if active:
                # keep rounds density-homogeneous: nearest-density first
                target = sum(active) / len(active)
                eligible.sort(
                    key=lambda r: (abs((r.density or 0.0) - target),
                                   r._order))
            else:
                # empty batch: start from the quietest traffic
                eligible.sort(key=lambda r: (r.density or 0.0, r._order))
        chosen = eligible[:len(free)]
        taken = {id(r) for r in chosen}
        self.pending = [r for r in self.pending if id(r) not in taken]
        mask = np.zeros(self.b, bool)
        tr = self.tracer
        for slot, req in zip(free, chosen):
            self._slot_req[slot] = req
            self._slot_admit_round[slot] = self._rounds_total
            req.state = lifecycle.RUNNING
            self._m_admitted.inc()
            if tr.enabled:
                # residency span: one lane per slot, open until the
                # request leaves the slot (evict or preempt)
                req._span = tr.begin(
                    f"req{req.uid}", track=f"slot{slot:02d}",
                    args={"uid": req.uid, "priority": req.priority,
                          "resumed": req._ckpt is not None})
            if req._ckpt is not None:
                if req._t_preempt_out is not None:
                    # checkpoint dwell: wall time spent off-device since
                    # the preemption that produced this checkpoint
                    req.preempted_ms += (time.perf_counter() -
                                         req._t_preempt_out) * 1e3
                    req._t_preempt_out = None
                # re-admission: update the host shadows *first*, then push
                # the checkpoint into the slot.  Order matters — the
                # masked admit below rewrites the full length/seed vectors
                # from these shadows, so they must already carry the
                # restored values when fresh admits share this pass.
                ck = req._ckpt
                self._slot_len[slot] = ck.length
                self._slot_done[slot] = ck.steps_done
                self._slot_seed[slot] = ck.seed
                self._state = snn_lib.silicon_stream_restore(
                    self._state, slot, ck)
                req._ckpt = None
            else:
                self._slot_len[slot] = np.asarray(req.events).shape[0]
                self._slot_done[slot] = 0
                self._slot_seed[slot] = self._request_seed(req)
                mask[slot] = True
        if mask.any():
            self._state = snn_lib.silicon_stream_admit(
                self._state, mask, self._slot_len, self._slot_seed)

    # --- preemption ---------------------------------------------------

    def _preempt_slot(self, slot: int, backoff: bool = True) -> EventRequest:
        """Checkpoint slot ``slot`` to host memory and requeue its request."""
        req = self._slot_req[slot]
        req._ckpt = snn_lib.silicon_stream_save(self._state, slot)
        req.state = lifecycle.PREEMPTED
        req.preemptions += 1
        req._t_preempt_out = time.perf_counter()
        self.preemption_count += 1
        self._m_preempted.inc()
        if req._span is not None:
            self.tracer.end(req._span, args={"outcome": "preempted",
                                             "steps_done":
                                                 int(self._slot_done[slot])})
            req._span = None
        if backoff:
            req._not_before = (self._rounds_total + self.backoff_rounds *
                               2 ** (req.preemptions - 1))
        self._slot_req[slot] = None
        self.pending.append(req)
        return req

    def _maybe_preempt(self) -> None:
        """One scheduling decision: preempt at most one slot per tick.

        Fires only when the batch is full, the best eligible queued
        request outranks the weakest resident one (strictly higher
        priority, or deadline-at-risk at >= priority), and the victim has
        been resident at least ``preempt_quantum`` ticks with fewer than
        ``max_preemptions`` prior preemptions.  The one-per-tick cap plus
        quantum plus exponential backoff is the anti-thrash budget.
        """
        if not (self.preemptive and self.continuous and self.pending):
            return
        if any(r is None for r in self._slot_req):
            return                      # a free slot: admission handles it
        eligible = [r for r in self.pending
                    if r._not_before <= self._rounds_total]
        if not eligible:
            return
        now = time.perf_counter()
        cand = min(eligible, key=lambda r: (-r.priority,
                                            self._slack_ms(r, now),
                                            r._order))
        victims = [(i, r) for i, r in enumerate(self._slot_req)
                   if self._rounds_total - self._slot_admit_round[i]
                   >= self.preempt_quantum
                   and r.preemptions < self.max_preemptions]
        if not victims:
            return
        # weakest resident: lowest priority, then longest resident
        slot, victim = min(victims,
                           key=lambda iv: (iv[1].priority,
                                           self._slot_admit_round[iv[0]],
                                           iv[1]._order))
        margin = (2.0 * self._round_ms_estimate()
                  if self.risk_margin_ms is None else self.risk_margin_ms)
        at_risk = self._slack_ms(cand, now) < margin
        if cand.priority > victim.priority or \
                (at_risk and cand.priority >= victim.priority):
            self._preempt_slot(slot)

    def preempt_request(self, uid: int, at_step: int | None = None,
                        backoff: bool = True) -> EventRequest:
        """Force-preempt a resident request (fault-injection / test hook).

        With ``at_step`` the stream is first advanced to exactly that
        absolute offset — including offsets that are *not* multiples of
        ``round_steps`` — by running partial rounds (the whole batch
        advances together, so every co-resident slot stays bitwise-exact;
        see ``forward_silicon_stream``).  The slot is then checkpointed to
        host memory and the request requeued (``PREEMPTED``).  Call it
        from a ``run(round_hook=...)`` callback to inject preemptions at
        randomized offsets mid-serve.
        """
        if not self.continuous:
            raise RuntimeError("preemption requires the continuous path")
        slot = next((i for i, r in enumerate(self._slot_req)
                     if r is not None and r.uid == uid), None)
        if slot is None:
            raise KeyError(f"request {uid} is not resident in any slot")
        if at_step is not None:
            done, length = int(self._slot_done[slot]), \
                int(self._slot_len[slot])
            if not done <= at_step < length:
                raise ValueError(
                    f"at_step={at_step} outside [{done}, {length}) for "
                    f"request {uid}")
            while int(self._slot_done[slot]) < at_step:
                self._round(min(self.round_steps,
                                at_step - int(self._slot_done[slot])))
        return self._preempt_slot(slot, backoff=backoff)

    def _round(self, r: int | None = None) -> None:
        """Advance every occupied slot by ``r`` time steps (one launch).

        ``r`` defaults to the regular ``round_steps`` cadence; smaller
        values are the *partial rounds* the preemption path uses to stop a
        stream at a non-round-aligned offset (each distinct ``r`` compiles
        one jit entry, bounded by ``round_steps``).
        """
        r = self.round_steps if r is None else r
        span = self.tracer.begin("round", track="scheduler")
        ev = np.zeros((r, self.b, self.cfg.n_in), np.float32)
        for i, req in enumerate(self._slot_req):
            if req is None:
                continue
            chunk = np.asarray(req.events,
                               np.float32)[self._slot_done[i]:
                                           self._slot_done[i] + r]
            ev[:chunk.shape[0], i, :] = chunk
        self._state = snn_lib.forward_silicon_stream(
            self.params, jnp.asarray(ev), self.cfg, self._state,
            noise=self.noise)
        self._slot_done = np.minimum(self._slot_done + r, self._slot_len)
        self._m_rounds.inc()
        if span is not None:
            self.tracer.end(span, args={"steps": r, "active": self.active})

    def _evict(self) -> list[EventRequest]:
        out: list[EventRequest] = []
        w_out = self.params["w_out"]
        for i, req in enumerate(self._slot_req):
            if req is None or self._slot_done[i] < self._slot_len[i]:
                continue
            length = float(self._slot_len[i])
            # batch-1 shaped readout: bitwise-matches the one-shot path
            logits = (self._state.counts[i][None] / length) @ w_out
            req.logits = logits[0]
            req.pred = int(jnp.argmax(logits, axis=-1)[0])
            # f32 division: matches the one-shot telemetry normalization bit
            # for bit (tele / t_steps runs in f32 inside the jitted forward)
            lf = np.float32(length)
            req.adc_steps = float(np.float32(self._state.adc[i]) / lf)
            req.sops = float(np.float32(self._state.sops[i]) / lf)
            req.skipped_block_ratio = float(
                np.float32(self._state.skip_acc[i]) / lf)
            if req._t_submit is not None:
                req.latency_ms = (time.perf_counter() -
                                  req._t_submit) * 1e3
            req.state = lifecycle.COMPLETED
            if req.deadline_ms is not None and req.latency_ms is not None:
                req.deadline_missed = req.latency_ms > req.deadline_ms
            self._slot_req[i] = None
            self.completed.append(req)
            self._m_evicted.inc()
            self._record_terminal(req)
            self._observe_completed(req)
            if req._span is not None:
                self.tracer.end(req._span,
                                args={"outcome": "completed",
                                      "latency_ms": req.latency_ms,
                                      "preemptions": req.preemptions})
                req._span = None
            out.append(req)
        return out

    @property
    def active(self) -> int:
        """Occupied slot count (continuous path)."""
        return sum(r is not None for r in self._slot_req)

    def run(self, max_rounds: int | None = None,
            round_hook: Callable[["SNNEventEngine"], None] | None = None
            ) -> list[EventRequest]:
        """Serve the queue; returns the requests completed by *this* call,
        in submission order.

        Continuous path (default): rounds of ``round_steps`` time steps
        over the persistent slot batch — new requests are admitted into
        free slots *between rounds* (density-aware when
        ``pack_by_density``, urgency-ordered when any queued request
        carries a priority/deadline), finished requests are evicted as
        soon as their own stream ends, and the per-slot LIF membrane
        carries across rounds on device.  Each tick also expires
        dead-on-arrival queued requests and makes at most one preemption
        decision (see ``_maybe_preempt``).  ``max_rounds`` bounds this
        call (leaving unfinished requests resident for the next
        ``run()``).  ``round_hook(engine)``, if given, fires after every
        tick's eviction — the chaos harness uses it to inject forced
        preemptions at arbitrary step offsets mid-serve.

        Legacy path (``continuous=False``): drains in fixed whole-sequence
        batches, bucketed by stream length.

        Either way the returned list covers only requests drained by this
        call — history accumulates in ``self.completed`` (and
        ``self.expired`` / ``self.rejected`` for the shed paths) — and
        scheduling never leaks into result order (always submission
        order) or result values (noise is per-request on the continuous
        path; the legacy key stream is per-batch as before).
        """
        if not self.continuous:
            return self._run_legacy()
        drained: list[EventRequest] = []
        tr = self.tracer
        rounds = 0
        while self.pending or self.active:
            if max_rounds is not None and rounds >= max_rounds:
                break
            tick = tr.begin("tick", track="scheduler")
            h = tr.begin("expire", track="scheduler")
            self._expire_pending()
            tr.end(h)
            if not (self.pending or self.active):
                tr.end(tick)
                break
            h = tr.begin("preempt", track="scheduler")
            self._maybe_preempt()
            tr.end(h)
            h = tr.begin("admit", track="scheduler")
            self._admit()
            tr.end(h)
            self._m_queue.set(len(self.pending))
            self._m_occupancy.set(self.active)
            ran = self.active > 0
            t0 = time.perf_counter()
            if ran:
                self._round()
            h = tr.begin("evict", track="scheduler")
            drained.extend(self._evict())
            tr.end(h)
            if ran:
                # round-time estimators, fed only by ticks that launched
                # a kernel (idle ticks are microseconds and would poison
                # the slack estimates): EMA for warmup, an exact sample
                # window for p50/p95, and the mergeable histogram export
                dt = (time.perf_counter() - t0) * 1e3
                self._round_ms = (
                    dt if self._round_ms == 0.0
                    else ROUND_MS_EMA_DECAY * self._round_ms +
                    (1.0 - ROUND_MS_EMA_DECAY) * dt)
                self._round_samples.append(dt)
                self._m_round_ms.observe(dt)
            if round_hook is not None:
                round_hook(self)
                drained.extend(self._evict())
            # tick advances even when idle: backoff windows are measured
            # in ticks and must expire with zero active slots too
            self._rounds_total += 1
            rounds += 1
            tr.end(tick)
        drained.sort(key=lambda r: r._order if r._order is not None
                     else r.uid)
        return drained

    def energy_report(self, dataset: str) -> dict:
        """Serving-side energy estimate from *measured* early-stop statistics.

        Uses the calibrated per-component model (core.energy) but replaces
        the analytic early-stop saving with the mean ADC step count the KWN
        controller actually reported for the served traffic.

        Every statistic in the report — ADC steps, energy, and the
        skipped-block ratio — is computed over the same population: the
        completed requests that carry measured ``adc_steps``.  Returns
        ``{}`` (documented contract, not an error) when there is nothing
        to report: no completed KWN request with measured ADC statistics,
        or the engine serves NLD mode, whose ramp always runs all
        2**code_bits - 1 steps so there is no measured early-stop to
        report.

        Besides the population means, the report carries a
        ``per_request`` table (one row per completed request: uid,
        latency, measured ADC steps, per-request pJ/SOP from *that
        request's* early-stop statistics, density) and — when latencies
        were measured — the serving SLO summary ``latency_ms_mean`` /
        ``latency_ms_p50`` / ``latency_ms_p95``.
        """
        done = [r for r in self.completed if r.adc_steps is not None]
        if not done or self.cfg.mode != "kwn":
            return {}
        if dataset not in energy_lib.SPIKE_RATES:
            raise ValueError(
                f"unknown dataset {dataset!r} for the calibrated spike rate; "
                f"expected one of {sorted(energy_lib.SPIKE_RATES)}")
        mean_steps = sum(r.adc_steps for r in done) / len(done)
        full = 2 ** self.cfg.code_bits - 1
        spike_rate = energy_lib.SPIKE_RATES[dataset]
        bd = energy_lib.kwn_step_energy(self.cfg.k, spike_rate,
                                        adc_steps=mean_steps)
        rep = {
            "requests": len(done),
            "mean_adc_steps": mean_steps,
            "measured_adc_saving": 1.0 - mean_steps / full,
            "pj_per_step": bd.total,
            "pj_per_sop": bd.total / energy_lib.sops_per_step(spike_rate),
        }
        # same population as the ADC/energy stats above — a request that
        # carries a skip ratio but no adc_steps must not dilute the mean
        skipped = [r.skipped_block_ratio for r in done
                   if r.skipped_block_ratio is not None]
        if skipped:
            # measured activity-plan saving, next to the early-stop saving
            rep["mean_skipped_block_ratio"] = sum(skipped) / len(skipped)
        sops_ps = energy_lib.sops_per_step(spike_rate)
        rep["per_request"] = [
            {"uid": r.uid,
             "latency_ms": r.latency_ms,
             # checkpoint dwell: wall ms spent checkpointed off-device.
             # latency_ms includes it, so fairness analysis can separate
             # "ran slowly" from "sat preempted" per request.
             "preempted_ms": r.preempted_ms,
             "adc_steps": r.adc_steps,
             "pj_per_sop": energy_lib.kwn_step_energy(
                 self.cfg.k, spike_rate,
                 adc_steps=r.adc_steps).total / sops_ps,
             "density": r.density}
            for r in done]
        lat = sorted(r.latency_ms for r in done if r.latency_ms is not None)
        if lat:
            rep["latency_ms_mean"] = sum(lat) / len(lat)
            rep["latency_ms_p50"] = lat[len(lat) // 2]
            rep["latency_ms_p95"] = lat[min(len(lat) - 1,
                                            int(len(lat) * 0.95))]
        if self._round_samples:
            # exact quantiles over the recent kernel-round window (the
            # same samples that feed the round_ms histogram metric and
            # the deadline-slack p95) — replaces squinting at the EMA
            rs = sorted(self._round_samples)
            rep["round_ms_p50"] = rs[len(rs) // 2]
            rep["round_ms_p95"] = rs[min(len(rs) - 1,
                                         int(len(rs) * 0.95))]
        # serving SLO ledger: every submission's fate is visible here
        rep["preemptions"] = self.preemption_count
        rep["rejected"] = len(self.rejected)
        rep["expired"] = len(self.expired)
        rep["deadline_misses"] = sum(
            1 for r in self.completed if r.deadline_missed)
        return rep


class BatchedEngine:
    """Minimal continuous-batching engine: fixed B slots, requests are
    admitted as slots free, prefill runs token-by-token through the decode
    path (teacher forcing), then decode until each request completes."""

    def __init__(self, cfg: lm.LMConfig, params, batch_slots: int = 4,
                 s_max: int = 256, mesh=None):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.s_max = s_max
        self.step_fn = jax.jit(build_serve_step(cfg, mesh))
        self.cache = lm.init_cache(cfg, batch_slots, s_max)
        self.pos = jnp.zeros((batch_slots,), jnp.int32)
        self.slots: list[Request | None] = [None] * batch_slots
        self.pending: list[Request] = []
        self.completed: list[Request] = []
        self._next_token = jnp.zeros((batch_slots, 1), jnp.int32)
        self._rng = jax.random.PRNGKey(0)

    def submit(self, req: Request):
        self.pending.append(req)

    def _admit(self):
        for i in range(self.b):
            if self.slots[i] is None and self.pending:
                req = self.pending.pop(0)
                self.slots[i] = req
                # prefill: feed prompt tokens through decode path, one
                # fresh key per step (sampling temperature > 0 must not
                # see the same draw at every prompt position)
                for t, tok in enumerate(req.prompt):
                    self._rng, sub = jax.random.split(self._rng)
                    toks = self._next_token.at[i, 0].set(tok)
                    pos = self.pos.at[i].set(t)
                    nxt, _, self.cache = self.step_fn(
                        self.params, self.cache, toks, pos, sub)
                    self._next_token = self._next_token.at[i].set(nxt[i])
                self.pos = self.pos.at[i].set(len(req.prompt))

    def run(self, max_rounds: int = 64):
        # max_rounds budgets *decode* rounds — admission/prefill work is
        # never charged against it
        rounds = 0
        while self.pending or any(self.slots):
            self._admit()
            if not any(self.slots):
                break
            if rounds >= max_rounds:
                break
            rounds += 1
            self._rng, sub = jax.random.split(self._rng)
            nxt, _, self.cache = self.step_fn(self.params, self.cache,
                                              self._next_token, self.pos, sub)
            self._next_token = nxt
            self.pos = self.pos + jnp.array(
                [1 if s is not None else 0 for s in self.slots], jnp.int32)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                req.generated.append(int(nxt[i, 0]))
                if req.done or int(self.pos[i]) >= self.s_max - 1:
                    self.completed.append(req)
                    self.slots[i] = None
        return self.completed
