"""Serving: jit-able serve_step (one decode token for a batch of requests), a
small batched engine (prompt queue -> prefill -> decode rounds) used by the
serving example and tests, and a batched event-stream engine that runs SNN
inference through the fused macro-step kernel.

serve_step is what the decode_32k / long_500k dry-run cells lower: one new
token against a KV cache of the cell's sequence length.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy as energy_lib
from repro.models import lm
from repro.models import snn as snn_lib


def build_serve_step(cfg: lm.LMConfig, mesh=None, *, temperature: float = 0.0):
    """Returns step(params, cache, tokens, pos, rng) ->
    (next_tokens (B,1), logits (B,V), cache)."""

    def serve_step(params, cache, tokens, pos, rng):
        logits, cache = lm.decode_step(params, cache, tokens, pos, cfg, mesh)
        logits = logits[:, :cfg.vocab_size]
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, cache

    return serve_step


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


@dataclasses.dataclass
class EventRequest:
    """One event-stream classification request: events (T, N_in) in {-1,0,1}."""

    uid: int
    events: Any                 # (T, N_in) array-like
    label: int | None = None
    logits: Any = None
    pred: int | None = None
    adc_steps: float | None = None   # mean early-stop ramp steps per time step
    density: float | None = None     # measured |event| rate (set on submit)
    skipped_block_ratio: float | None = None  # batch activity-plan skip rate
    key: Any = None                  # per-request PRNG key (continuous path)
    latency_ms: float | None = None  # submit -> eviction wall time
    sops: float | None = None        # measured synaptic ops per time step
    _order: int | None = dataclasses.field(default=None, repr=False,
                                           compare=False)  # submission index
    _t_submit: float | None = dataclasses.field(default=None, repr=False,
                                                compare=False)


@functools.lru_cache(maxsize=None)
def _legacy_forward(cfg, fused: str, noise):
    """One jitted drain-path forward per (config, cadence, noise model).

    Module-level cache so every engine instance over the same config
    shares one compiled executable (a per-instance ``jax.jit(lambda ...)``
    would recompile per engine — ruinous for the serve benchmarks' warm
    trials).  ``cfg`` (frozen dataclass) and ``noise`` (NamedTuple) are
    hashable, so they can key the cache and close over the trace.
    """
    return jax.jit(lambda p, ev, key: snn_lib.forward_silicon(
        p, ev, cfg, key, fused=fused, noise=noise))


class SNNEventEngine:
    """Event-stream inference on the fused macro kernel, served either by
    step-granularity *continuous batching* (default) or by legacy
    drain-the-queue batches.

    **Continuous path** (``continuous=True``, auto-selected for
    time-major single-layer configs).  The engine keeps ``batch_slots``
    persistent serving slots whose LIF membrane — the SNN analog of an LM
    engine's KV cache — lives on device in a
    ``snn.SiliconStreamState`` and is carried across rounds.  Each round
    advances every occupied slot by ``round_steps`` time steps through
    one time-major fused kernel launch; between rounds, finished requests
    are evicted (their slot's accumulators are normalized by *their own*
    stream length, never the round count) and waiting requests are
    admitted into the freed slots mid-flight, with the slot state reset
    on admit.  Mixed stream lengths batch naturally — the batch shape is
    always ``(round_steps, batch_slots)``, so the jit cache holds one
    entry regardless of the traffic's length mix.

    Noise is *per-request* on this path: each request's counter-PRNG seed
    (from ``req.key``, folded from the engine seed by submission index)
    rides the kernel's ``row_ctl`` lane, and the clean-path SNL PRBS is a
    per-slot LFSR.  Served logits and ADC telemetry are therefore
    bitwise-identical to a one-shot batch-1
    ``forward_silicon(fused="seq")`` of the same request — independent of
    co-batched traffic, admission order, or scheduling policy.

    With ``pack_by_density=True`` the admission scheduler uses measured
    event density as its cost model: it fills free slots with the pending
    requests closest to the resident batch's mean density (quietest-first
    into an empty batch), so activity-gated block skipping — which is
    per row-*tile*, shared across co-resident slots — survives batching.
    Results are unchanged either way; only the work moves.

    **Legacy path** (``continuous=False``, and the automatic fallback for
    ``time_major=False`` or multi-layer stacks).  One jitted
    ``forward_silicon(fused=...)`` call per fixed-size batch of whole
    sequences, padded to ``batch_slots`` rows; batches are bucketed by
    stream length (one jit entry per distinct T served).  ``noise`` draws
    then come from the engine's per-batch key stream, as before.

    Raw-MAC telemetry stays off on both hot paths.
    """

    def __init__(self, cfg: snn_lib.SNNConfig, params, batch_slots: int = 64,
                 seed: int = 0, time_major: bool = True, noise=None,
                 pack_by_density: bool = True,
                 continuous: bool | None = None, round_steps: int = 8):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.time_major = time_major
        self.noise = noise
        self.pack_by_density = pack_by_density
        self.pending: list[EventRequest] = []
        self.completed: list[EventRequest] = []
        self._submitted = 0
        self._key = jax.random.PRNGKey(seed)
        self._base_key = jax.random.PRNGKey(seed)
        self._fused = "seq" if time_major else "step"
        supported = time_major and len(cfg.layer_widths) == 1
        if continuous is None:
            continuous = supported
        elif continuous and not supported:
            raise ValueError(
                "continuous batching needs the time-major fused kernel and "
                "a single-layer config; pass continuous=False (or leave it "
                "None to auto-select) for per-step cadence or stacks")
        self.continuous = continuous
        self.round_steps = round_steps
        # continuous-path slot table (host shadows of the device state)
        self._state = (snn_lib.silicon_stream_init(cfg, batch_slots)
                       if continuous else None)
        self._slot_req: list[EventRequest | None] = [None] * batch_slots
        self._slot_len = np.zeros(batch_slots, np.int32)
        self._slot_done = np.zeros(batch_slots, np.int32)
        self._slot_seed = np.zeros(batch_slots, np.int32)

    def submit(self, req: EventRequest):
        if req.density is None:
            # host-side numpy: no device dispatch/sync on the submit path
            ev = np.asarray(req.events)
            req.density = float(np.count_nonzero(ev)) / ev.size
        req._order = self._submitted
        req._t_submit = time.perf_counter()
        self._submitted += 1
        self.pending.append(req)

    # ------------------------------------------------------------------
    # Legacy drain path (continuous=False): fixed batches, whole sequences
    # ------------------------------------------------------------------

    def _run_batch(self, reqs: list[EventRequest]) -> list[EventRequest]:
        ev = jnp.stack([jnp.asarray(r.events, jnp.float32) for r in reqs])
        pad = self.b - ev.shape[0]
        if pad:
            ev = jnp.concatenate(
                [ev, jnp.zeros((pad,) + ev.shape[1:], ev.dtype)])
        self._key, sub = jax.random.split(self._key)
        fwd = _legacy_forward(self.cfg, self._fused, self.noise)
        logits, tele = fwd(self.params, ev, sub)
        preds = jnp.argmax(logits, axis=-1)
        skipped = tele.get("skipped_block_ratio")
        t_done = time.perf_counter()
        for i, req in enumerate(reqs):
            req.logits = logits[i]
            req.pred = int(preds[i])
            req.adc_steps = float(tele["adc_steps"][i])
            req.sops = float(tele["sops"][i])
            if skipped is not None:
                req.skipped_block_ratio = float(skipped[i])
            if req._t_submit is not None:
                req.latency_ms = (t_done - req._t_submit) * 1e3
            self.completed.append(req)
        return reqs

    def _take_bucket(self) -> list[EventRequest]:
        """Next batch off the queue: up to ``b`` requests sharing one T.

        The legacy launch stacks whole sequences, so a batch must be
        rectangular; bucketing by stream length (instead of the old
        ``jnp.stack`` crash) keeps results exact.  Each distinct T compiles
        its own jit entry — the engine's cache holds one entry *per stream
        length served*, not one total.
        """
        t0 = np.asarray(self.pending[0].events).shape[0]
        batch = [r for r in self.pending
                 if np.asarray(r.events).shape[0] == t0][:self.b]
        taken = {id(r) for r in batch}
        self.pending = [r for r in self.pending if id(r) not in taken]
        return batch

    def _run_legacy(self) -> list[EventRequest]:
        if self.pack_by_density:
            self.pending.sort(key=lambda r: (r.density or 0.0, r.uid))
        drained: list[EventRequest] = []
        while self.pending:
            drained.extend(self._run_batch(self._take_bucket()))
        drained.sort(key=lambda r: r._order if r._order is not None
                     else r.uid)
        return drained

    # ------------------------------------------------------------------
    # Continuous path: step-granularity rounds over persistent slots
    # ------------------------------------------------------------------

    def _request_seed(self, req: EventRequest) -> int:
        """Per-request counter-PRNG seed word, assigned at admission.

        Each request gets its own key (folded from the engine seed by
        submission index unless the caller set ``req.key``), so its noise
        stream — and therefore its logits — are a pure function of the
        request, independent of co-batched traffic or admission order.
        A one-shot ``forward_silicon(p, ev[None], cfg, req.key,
        fused="seq", noise=...)`` reproduces the served result bitwise.
        """
        if req.key is None:
            req.key = jax.random.fold_in(self._base_key, req._order)
        if self.noise is None:
            return 0              # clean serving never reads the seed word
        return int(snn_lib._noise_seed(req.key))

    def _admit(self) -> None:
        free = [i for i, r in enumerate(self._slot_req) if r is None]
        if not free or not self.pending:
            return
        if self.pack_by_density:
            active = [r.density or 0.0
                      for r in self._slot_req if r is not None]
            if active:
                # keep rounds density-homogeneous: nearest-density first
                target = sum(active) / len(active)
                self.pending.sort(
                    key=lambda r: (abs((r.density or 0.0) - target),
                                   r._order))
            else:
                # empty batch: start from the quietest traffic
                self.pending.sort(key=lambda r: (r.density or 0.0, r._order))
        chosen, self.pending = (self.pending[:len(free)],
                                self.pending[len(free):])
        mask = np.zeros(self.b, bool)
        for slot, req in zip(free, chosen):
            self._slot_req[slot] = req
            self._slot_len[slot] = np.asarray(req.events).shape[0]
            self._slot_done[slot] = 0
            self._slot_seed[slot] = self._request_seed(req)
            mask[slot] = True
        self._state = snn_lib.silicon_stream_admit(
            self._state, mask, self._slot_len, self._slot_seed)

    def _round(self) -> None:
        r = self.round_steps
        ev = np.zeros((r, self.b, self.cfg.n_in), np.float32)
        for i, req in enumerate(self._slot_req):
            if req is None:
                continue
            chunk = np.asarray(req.events,
                               np.float32)[self._slot_done[i]:
                                           self._slot_done[i] + r]
            ev[:chunk.shape[0], i, :] = chunk
        self._state = snn_lib.forward_silicon_stream(
            self.params, jnp.asarray(ev), self.cfg, self._state,
            noise=self.noise)
        self._slot_done = np.minimum(self._slot_done + r, self._slot_len)

    def _evict(self) -> list[EventRequest]:
        out: list[EventRequest] = []
        w_out = self.params["w_out"]
        for i, req in enumerate(self._slot_req):
            if req is None or self._slot_done[i] < self._slot_len[i]:
                continue
            length = float(self._slot_len[i])
            # batch-1 shaped readout: bitwise-matches the one-shot path
            logits = (self._state.counts[i][None] / length) @ w_out
            req.logits = logits[0]
            req.pred = int(jnp.argmax(logits, axis=-1)[0])
            # f32 division: matches the one-shot telemetry normalization bit
            # for bit (tele / t_steps runs in f32 inside the jitted forward)
            lf = np.float32(length)
            req.adc_steps = float(np.float32(self._state.adc[i]) / lf)
            req.sops = float(np.float32(self._state.sops[i]) / lf)
            req.skipped_block_ratio = float(
                np.float32(self._state.skip_acc[i]) / lf)
            if req._t_submit is not None:
                req.latency_ms = (time.perf_counter() -
                                  req._t_submit) * 1e3
            self._slot_req[i] = None
            self.completed.append(req)
            out.append(req)
        return out

    @property
    def active(self) -> int:
        """Occupied slot count (continuous path)."""
        return sum(r is not None for r in self._slot_req)

    def run(self, max_rounds: int | None = None) -> list[EventRequest]:
        """Serve the queue; returns the requests completed by *this* call,
        in submission order.

        Continuous path (default): rounds of ``round_steps`` time steps
        over the persistent slot batch — new requests are admitted into
        free slots *between rounds* (density-aware when
        ``pack_by_density``), finished requests are evicted as soon as
        their own stream ends, and the per-slot LIF membrane carries
        across rounds on device.  ``max_rounds`` bounds this call (leaving
        unfinished requests resident for the next ``run()``).

        Legacy path (``continuous=False``): drains in fixed whole-sequence
        batches, bucketed by stream length.

        Either way the returned list covers only requests drained by this
        call — history accumulates in ``self.completed`` — and density
        scheduling never leaks into result order (always submission
        order) or result values (noise is per-request on the continuous
        path; the legacy key stream is per-batch as before).
        """
        if not self.continuous:
            return self._run_legacy()
        drained: list[EventRequest] = []
        rounds = 0
        while self.pending or self.active:
            if max_rounds is not None and rounds >= max_rounds:
                break
            self._admit()
            self._round()
            drained.extend(self._evict())
            rounds += 1
        drained.sort(key=lambda r: r._order if r._order is not None
                     else r.uid)
        return drained

    def energy_report(self, dataset: str) -> dict:
        """Serving-side energy estimate from *measured* early-stop statistics.

        Uses the calibrated per-component model (core.energy) but replaces
        the analytic early-stop saving with the mean ADC step count the KWN
        controller actually reported for the served traffic.

        Every statistic in the report — ADC steps, energy, and the
        skipped-block ratio — is computed over the same population: the
        completed requests that carry measured ``adc_steps``.  Returns
        ``{}`` (documented contract, not an error) when there is nothing
        to report: no completed KWN request with measured ADC statistics,
        or the engine serves NLD mode, whose ramp always runs all
        2**code_bits - 1 steps so there is no measured early-stop to
        report.

        Besides the population means, the report carries a
        ``per_request`` table (one row per completed request: uid,
        latency, measured ADC steps, per-request pJ/SOP from *that
        request's* early-stop statistics, density) and — when latencies
        were measured — the serving SLO summary ``latency_ms_mean`` /
        ``latency_ms_p50`` / ``latency_ms_p95``.
        """
        done = [r for r in self.completed if r.adc_steps is not None]
        if not done or self.cfg.mode != "kwn":
            return {}
        if dataset not in energy_lib.SPIKE_RATES:
            raise ValueError(
                f"unknown dataset {dataset!r} for the calibrated spike rate; "
                f"expected one of {sorted(energy_lib.SPIKE_RATES)}")
        mean_steps = sum(r.adc_steps for r in done) / len(done)
        full = 2 ** self.cfg.code_bits - 1
        spike_rate = energy_lib.SPIKE_RATES[dataset]
        bd = energy_lib.kwn_step_energy(self.cfg.k, spike_rate,
                                        adc_steps=mean_steps)
        rep = {
            "requests": len(done),
            "mean_adc_steps": mean_steps,
            "measured_adc_saving": 1.0 - mean_steps / full,
            "pj_per_step": bd.total,
            "pj_per_sop": bd.total / energy_lib.sops_per_step(spike_rate),
        }
        # same population as the ADC/energy stats above — a request that
        # carries a skip ratio but no adc_steps must not dilute the mean
        skipped = [r.skipped_block_ratio for r in done
                   if r.skipped_block_ratio is not None]
        if skipped:
            # measured activity-plan saving, next to the early-stop saving
            rep["mean_skipped_block_ratio"] = sum(skipped) / len(skipped)
        sops_ps = energy_lib.sops_per_step(spike_rate)
        rep["per_request"] = [
            {"uid": r.uid,
             "latency_ms": r.latency_ms,
             "adc_steps": r.adc_steps,
             "pj_per_sop": energy_lib.kwn_step_energy(
                 self.cfg.k, spike_rate,
                 adc_steps=r.adc_steps).total / sops_ps,
             "density": r.density}
            for r in done]
        lat = sorted(r.latency_ms for r in done if r.latency_ms is not None)
        if lat:
            rep["latency_ms_mean"] = sum(lat) / len(lat)
            rep["latency_ms_p50"] = lat[len(lat) // 2]
            rep["latency_ms_p95"] = lat[min(len(lat) - 1,
                                            int(len(lat) * 0.95))]
        return rep


class BatchedEngine:
    """Minimal continuous-batching engine: fixed B slots, requests are
    admitted as slots free, prefill runs token-by-token through the decode
    path (teacher forcing), then decode until each request completes."""

    def __init__(self, cfg: lm.LMConfig, params, batch_slots: int = 4,
                 s_max: int = 256, mesh=None):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.s_max = s_max
        self.step_fn = jax.jit(build_serve_step(cfg, mesh))
        self.cache = lm.init_cache(cfg, batch_slots, s_max)
        self.pos = jnp.zeros((batch_slots,), jnp.int32)
        self.slots: list[Request | None] = [None] * batch_slots
        self.pending: list[Request] = []
        self.completed: list[Request] = []
        self._next_token = jnp.zeros((batch_slots, 1), jnp.int32)
        self._rng = jax.random.PRNGKey(0)

    def submit(self, req: Request):
        self.pending.append(req)

    def _admit(self):
        for i in range(self.b):
            if self.slots[i] is None and self.pending:
                req = self.pending.pop(0)
                self.slots[i] = req
                # prefill: feed prompt tokens through decode path, one
                # fresh key per step (sampling temperature > 0 must not
                # see the same draw at every prompt position)
                for t, tok in enumerate(req.prompt):
                    self._rng, sub = jax.random.split(self._rng)
                    toks = self._next_token.at[i, 0].set(tok)
                    pos = self.pos.at[i].set(t)
                    nxt, _, self.cache = self.step_fn(
                        self.params, self.cache, toks, pos, sub)
                    self._next_token = self._next_token.at[i].set(nxt[i])
                self.pos = self.pos.at[i].set(len(req.prompt))

    def run(self, max_rounds: int = 64):
        # max_rounds budgets *decode* rounds — admission/prefill work is
        # never charged against it
        rounds = 0
        while self.pending or any(self.slots):
            self._admit()
            if not any(self.slots):
                break
            if rounds >= max_rounds:
                break
            rounds += 1
            self._rng, sub = jax.random.split(self._rng)
            nxt, _, self.cache = self.step_fn(self.params, self.cache,
                                              self._next_token, self.pos, sub)
            self._next_token = nxt
            self.pos = self.pos + jnp.array(
                [1 if s is not None else 0 for s in self.slots], jnp.int32)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                req.generated.append(int(nxt[i, 0]))
                if req.done or int(self.pos[i]) >= self.s_max - 1:
                    self.completed.append(req)
                    self.slots[i] = None
        return self.completed
