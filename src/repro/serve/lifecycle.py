"""Request lifecycle and admission-control types for ``SNNEventEngine``.

The serving layer promises that **every submission reaches exactly one
terminal state** — there is no silent-drop path and no unbounded queue.
The state machine (documented in ``docs/SERVING.md``):

    QUEUED ──admit──> RUNNING ──stream ends──> COMPLETED
      │  ▲              │
      │  └──re-admit────┤ (checkpoint restored at its step offset)
      │                 └─preempt──> PREEMPTED ──backoff──> (eligible again)
      ├──deadline passes before admission──> EXPIRED
      └──queue full, lowest priority──> REJECTED      (typed, at submit time)

plus the submit-time *typed* validation errors below, which reject a
malformed event tensor before it can reach a kernel launch (where it would
otherwise surface as an opaque shape error or silent garbage mid-round).

``RUNNING -> PREEMPTED -> RUNNING`` is invisible in the results: a
preempted request's slot state is checkpointed to host memory
(``snn.SlotCheckpoint``) and restored on re-admission at its recorded step
offset, and the fused kernel's ``row_ctl`` lane replays its noise streams
from exactly that offset — so the final logits/telemetry are bitwise
identical to a run that was never preempted.
"""

from __future__ import annotations

import numpy as np

# --- terminal + transient request states (EventRequest.state) --------------

QUEUED = "queued"          # submitted, waiting for a slot
RUNNING = "running"        # resident in a slot, advancing
PREEMPTED = "preempted"    # checkpointed to host, waiting for re-admission
COMPLETED = "completed"    # terminal: served, logits/telemetry populated
EXPIRED = "expired"        # terminal: deadline passed before completion
REJECTED = "rejected"      # terminal: shed by the bounded admission queue

TERMINAL_STATES = frozenset({COMPLETED, EXPIRED, REJECTED})


# --- typed submit-time validation errors -----------------------------------

class InvalidEventError(ValueError):
    """Base for submit-time event-tensor rejections (never reaches a kernel)."""


class EmptyEventError(InvalidEventError):
    """Zero-length event stream (T == 0, or an empty tensor)."""


class EventDtypeError(InvalidEventError):
    """Event tensor dtype the fused kernels cannot consume."""


class EventShapeError(InvalidEventError):
    """Event tensor is not (T, n_in) for this engine's config."""


class NonFiniteEventError(InvalidEventError):
    """Event tensor carries NaN/Inf values."""


class NonTernaryEventError(InvalidEventError):
    """Event values outside the ternary alphabet {-1, 0, +1}."""


class QueueFullError(RuntimeError):
    """Raised only by ``submit(..., shed=False)``; the default sheds instead."""


_ISSUE_ERRORS = {
    "dtype": EventDtypeError,
    "shape": EventShapeError,
    "empty": EmptyEventError,
    "nonfinite": NonFiniteEventError,
    "nonternary": NonTernaryEventError,
}


def validate_events(events, n_in: int | None = None) -> np.ndarray:
    """Validate one request's event tensor against the kernel contract.

    Delegates the actual checks to ``kernels.ops.event_stream_issues`` (the
    kernels own their input contract) and maps each issue code onto the
    typed exception hierarchy above, most severe first (dtype > shape >
    empty > nonfinite > nonternary).  Returns the host-side ``np.ndarray``
    view so callers can reuse it without re-materializing.
    """
    from repro.kernels import ops as ops_lib   # late: keep import DAG thin
    ev, issues = ops_lib.event_stream_issues(events, n_in=n_in)
    for code in ("dtype", "shape", "empty", "nonfinite", "nonternary"):
        for got, detail in issues:
            if got == code:
                raise _ISSUE_ERRORS[code](detail)
    return ev
