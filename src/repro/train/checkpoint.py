"""Fault-tolerant checkpointing.

Design goals (1000+ node posture, scaled to this container):
  * ATOMIC: write to a temp dir, fsync, then os.rename — a crash mid-save
    never corrupts the latest checkpoint (failure-injection test covers this).
  * ELASTIC: leaves are stored unsharded (gathered) with tree-path keys; any
    mesh can load any checkpoint — restoring shards per the *current* mesh's
    shardings (device_put).  Changing dp/tp between runs "just works", which
    is the restart path for elastic scaling after node loss.
  * SELF-CONTAINED: optimizer state, step counter and data-pipeline state are
    in the same checkpoint, so a resumed run is bitwise-continuous.
  * keep_n garbage collection, never deleting the newest good checkpoint.

At real 1T scale the gather-to-host would be replaced by per-shard files +
an index (same API; swap _save_arrays) — documented in DESIGN.md.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

Tree = Any

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree: Tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template: Tree, flat: dict[str, np.ndarray]) -> Tree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in paths:
        key = jax.tree_util.keystr(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        want_shape = tuple(tmpl.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {want_shape}")
        leaves.append(arr.astype(tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, state: dict[str, Tree],
         meta: dict | None = None, keep_n: int = 3) -> str:
    """state: name -> pytree (e.g. {"params":..., "opt":..., "data":...})."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=ckpt_dir)
    try:
        for name, tree in state.items():
            np.savez(os.path.join(tmp, f"{name}.npz"), **_flatten(tree))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **(meta or {})}, f)
        # fsync directory contents for crash consistency
        for fn in os.listdir(tmp):
            with open(os.path.join(tmp, fn), "rb") as f:
                os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep_n)
    return final


def _gc(ckpt_dir: str, keep_n: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_n]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
    # sweep orphaned temp dirs from crashed saves
    for fn in os.listdir(ckpt_dir):
        if fn.startswith(".tmp_"):
            shutil.rmtree(os.path.join(ckpt_dir, fn), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for fn in os.listdir(ckpt_dir):
        m = _STEP_RE.match(fn)
        if m and os.path.exists(os.path.join(ckpt_dir, fn, "meta.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, templates: dict[str, Tree],
            shardings: dict[str, Tree] | None = None) -> tuple[dict[str, Tree], dict]:
    """Restore named trees; templates give structure/shape/dtype.  With
    ``shardings`` (same names), leaves are device_put per the CURRENT mesh —
    this is the elastic-rescale path."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    out = {}
    for name, tmpl in templates.items():
        with np.load(os.path.join(path, f"{name}.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(tmpl, flat)
        if shardings and name in shardings and shardings[name] is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings[name])
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        out[name] = tree
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return out, meta


def restore_latest(ckpt_dir: str, templates, shardings=None):
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    return restore(ckpt_dir, step, templates, shardings)
