"""Gradient compression with error feedback (distributed-optimization trick).

int8 quantization of gradients before the DP all-reduce with per-leaf scales
and an error-feedback accumulator (residual carried to the next step) —
1-bit-Adam / PowerSGD-family technique that cuts DP wire volume 4x (f32) /
2x (bf16) with provably bounded bias when error feedback is on.

Usage inside a train step:
    comp, efb = compress(grads, efb)          # quantize + update residual
    comp = psum(comp) ...                     # cheap all-reduce
    grads = decompress(comp)

The roofline model credits compressed wire volume when enabled (perf knob in
§Perf).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Tree = Any


class Compressed(NamedTuple):
    q: Tree        # int8 tree
    scale: Tree    # f32 scalar per leaf


def init_error_feedback(params: Tree) -> Tree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads: Tree, error_fb: Tree | None = None
             ) -> tuple[Compressed, Tree]:
    def one(g, e):
        gf = g.astype(jnp.float32) + (e if e is not None else 0.0)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        err = gf - q.astype(jnp.float32) * scale
        return q, scale, err

    if error_fb is None:
        error_fb = jax.tree.map(lambda g: None, grads,
                                is_leaf=lambda x: x is None)
    out = jax.tree.map(one, grads, error_fb,
                       is_leaf=lambda x: x is None)
    is_t = lambda x: isinstance(x, tuple) and len(x) == 3
    q = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
    scale = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
    err = jax.tree.map(lambda o: o[2], out, is_leaf=is_t)
    return Compressed(q, scale), err


def decompress(comp: Compressed) -> Tree:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        comp.q, comp.scale)


def compression_ratio(grads: Tree) -> float:
    """Wire-bytes ratio vs f32 (int8 payload + negligible scales)."""
    total = sum(x.size for x in jax.tree.leaves(grads))
    return (total * 1) / (total * 4)
