"""Optimizers in plain JAX (no external deps): AdamW + SGD-momentum, global
gradient-norm clipping, cosine/linear schedules.  Optimizer states inherit the
parameter sharding (moments are elementwise), so ZeRO-style state sharding
falls out of the param sharding rules for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Tree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"   # "bfloat16" halves optimizer memory


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Tree
    nu: Tree


def adamw_init(params: Tree, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree: Tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Tree, max_norm: float):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm


def adamw_apply(grads: Tree, mu: Tree, nu: Tree, params: Tree,
                step: jax.Array, lr: jax.Array, cfg: AdamWConfig):
    """Pure elementwise AdamW application (clipping/schedule done upstream)."""
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        m_hat = m_new / (1 - b1 ** step.astype(jnp.float32))
        v_hat = v_new / (1 - b2 ** step.astype(jnp.float32))
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, grads, mu, nu, params)
    is_t = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda o: o[0], out, is_leaf=is_t),
            jax.tree.map(lambda o: o[1], out, is_leaf=is_t),
            jax.tree.map(lambda o: o[2], out, is_leaf=is_t))


def adamw_update(grads: Tree, state: AdamWState, params: Tree,
                 cfg: AdamWConfig, scan_subtrees: tuple[str, ...] = ()):
    """Full update.  Subtree names in ``scan_subtrees`` (e.g. the stacked
    "layers" dict) are updated via lax.scan over their leading (group) dim —
    bounding the f32 optimizer temporaries to one layer group instead of the
    whole stacked parameter tensor (matters at 100B+ scales)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(state.step, cfg)

    scan_keys = [k for k in scan_subtrees
                 if isinstance(params, dict) and k in params]
    direct_p = {k: v for k, v in params.items() if k not in scan_keys} \
        if isinstance(params, dict) else params
    direct_g = {k: grads[k] for k in direct_p} if isinstance(params, dict) else grads
    direct_m = {k: state.mu[k] for k in direct_p} if isinstance(params, dict) else state.mu
    direct_v = {k: state.nu[k] for k in direct_p} if isinstance(params, dict) else state.nu

    p_new, mu, nu = adamw_apply(direct_g, direct_m, direct_v, direct_p,
                                step, lr, cfg)
    if isinstance(params, dict):
        for k in scan_keys:
            def body(_, xs):
                g, m, v, p = xs
                return None, adamw_apply(g, m, v, p, step, lr, cfg)
            _, (pk, mk, vk) = jax.lax.scan(
                body, None, (grads[k], state.mu[k], state.nu[k], params[k]))
            p_new[k], mu[k], nu[k] = pk, mk, vk
    return p_new, AdamWState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}


# --- SGD (for the SNN experiments) -----------------------------------------

class SGDState(NamedTuple):
    step: jax.Array
    momentum: Tree


def sgd_init(params: Tree) -> SGDState:
    return SGDState(jnp.zeros((), jnp.int32),
                    jax.tree.map(jnp.zeros_like, params))


def sgd_update(grads: Tree, state: SGDState, params: Tree, lr: float = 1e-2,
               momentum: float = 0.9):
    mom = jax.tree.map(lambda m, g: momentum * m + g, state.momentum, grads)
    params = jax.tree.map(lambda p, m: p - lr * m, params, mom)
    return params, SGDState(state.step + 1, mom)
