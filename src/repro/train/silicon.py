"""Silicon-in-the-loop SNN training: differentiate *through* the fused macro.

``models.snn.train`` historically back-propagated through the dense-f32
``forward_train`` path: gradients never saw the IMA code quantization, the
KWN winner mask, the V_mem register saturation, or the Fig. 7 conversion
noise — so trained models were systematically mis-calibrated for the fused
silicon path they are served on.  This module closes that loop: the training
forward IS the serving forward (the fused Pallas kernel, clean or
counter-PRNG noisy, activity-gated), and the backward is the time-reversed
surrogate BPTT pass (``kernels.fused_macro_grad``) wired up through
``jax.custom_vjp`` (``kernels.ops.fused_macro_seq_vjp`` via
``core.macro.fused_seq_vjp``).

What is exact and what is surrogate
-----------------------------------
*Exact (bitwise)*: every primal value — MAC, codes, winner masks, spikes,
membrane — matches ``ref.fused_macro_seq_ref`` and therefore the serving
kernel; evaluating a just-trained model on the silicon path costs no
re-calibration.  *Surrogate (backward only)*: SuperSpike through the spike
comparator, straight-through inside the IMA ramp window, straight-through
with clip through the twin-cell ternary rounding, a relaxed straight-through
hard gate through the KWN winner mask (``kwn_relax`` leaks a fraction of
the loser gradient — the pure hard gate starves non-winner columns), and a
hard cut at the V_mem saturation rails.  The reference semantics live in
``ref.fused_macro_seq_vjp_ref``; the Pallas backward matches its
``jax.grad``.

Noise-aware QAT
---------------
Passing an ``ima.IMANoiseModel`` trains against the in-kernel Fig. 7 error
draws; a fresh counter seed per optimization step (``train`` handles this)
makes each step a fresh silicon instance, which is what closes the
clean->noisy accuracy gap at serving time (the reduced Fig. 8 experiment in
``examples/train_snn_events.py``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lif as lif_lib
from repro.core import macro as macro_lib
from repro.core import prbs as prbs_lib
from repro.core import ternary as ternary_lib

# Loser-gradient leak through the hard KWN winner gate.  0 is the pure hard
# gate (only winner columns learn — the rich get richer and quiet columns
# starve); a small leak keeps every column trainable while the primal stays
# exactly top-K.  0.1 was picked on the N-MNIST stand-in: large enough that
# fine-tuning recovers noisy accuracy, small enough not to wash out the
# winner signal.
DEFAULT_KWN_RELAX = 0.1


def quantized_weight_ste(w_hid: jax.Array):
    """Integer-unit weight with ternary-STE tangent + its per-column scale.

    Primal: exactly ``quantize_weights_3bit(w_hid)[0]`` (the twin-cell grid
    values the packers store).  Tangent: ``d w / d w_hid = clip_mask /
    scale`` — the same clipped straight-through ``ternary.quantize_weights_
    ste`` uses, re-expressed in integer MAC units so it composes with the
    kernel VJP's integer-unit ``dW``.  Returns (w (I, N), scale (N,)), the
    scale stop-gradiented (matching the software QAT path).
    """
    sg = jax.lax.stop_gradient
    w_int, scale2 = ternary_lib.quantize_weights_3bit(w_hid)
    scale2 = sg(scale2)                                   # (1, N)
    w_sur = w_hid / scale2
    clip_mask = (jnp.abs(w_sur) <= 3.5).astype(w_hid.dtype)
    w = sg(w_int) + (w_sur - sg(w_sur)) * clip_mask
    return w, sg(scale2.reshape(-1))


def forward_logits(p, events, cfg, seed, *, noise=None,
                   kwn_relax: float = DEFAULT_KWN_RELAX,
                   remat: bool = False):
    """Differentiable silicon forward: events (B, T, N_in) -> logits.

    The spike stacks are bit-identical to ``snn.forward_silicon(p, ...,
    fused=True)`` with the same counter seed; gradients flow to ``w_hid``
    (through the surrogate chain) and ``w_out`` (ordinary autodiff over the
    spike-count readout).  ``noise`` is the Fig. 7 ``IMANoiseModel`` for
    noise-aware QAT; ``seed`` an f32 scalar keying the counter streams.
    KWN mode only — NLD training stays on the software path.
    """
    if cfg.mode != "kwn":
        raise ValueError(
            f"silicon-in-the-loop training supports mode='kwn' only "
            f"(got {cfg.mode!r}); NLD trains on the software STE path")
    if isinstance(p["w_hid"], (list, tuple)):
        raise NotImplementedError(
            "silicon-in-the-loop training is single-layer only for now; "
            "the multi-layer surrogate backward is a roadmap follow-up "
            "(train stacks on the software path, forward_train)")
    b, t_steps = events.shape[0], events.shape[1]
    w, scale = quantized_weight_ste(p["w_hid"])
    mcfg = macro_lib.CIMMacroConfig(code_bits=cfg.code_bits,
                                    mac_range=cfg.mac_range,
                                    ima_noise=noise)
    lif_p = lif_lib.LIFParams(beta=cfg.beta, v_th1=cfg.v_th1,
                              v_th2=cfg.v_th2,
                              noise_amp=cfg.noise_amp if cfg.use_snl else 0.0)
    noisy = noise is not None
    ev_t = jnp.moveaxis(events, 1, 0)                     # (T, B, N_in)
    st0 = lif_lib.lif_init((b, cfg.n_hidden))
    if noisy or not cfg.use_snl:
        noise_t = None                 # in-kernel counter SNL (or none)
    else:
        def draw(s, _):
            s, nz = prbs_lib.prbs_noise(s, (b, cfg.n_hidden),
                                        lif_p.noise_amp)
            return s, nz
        _, noise_t = jax.lax.scan(draw, st0.prbs_state, None,
                                  length=t_steps)
    spk_t, _ = macro_lib.fused_seq_vjp(
        ev_t, w, scale, mcfg, st0.v_mem, k=cfg.k,
        drive_gain=cfg.drive_gain, beta=cfg.beta, v_th1=cfg.v_th1,
        v_th2=cfg.v_th2, v_reset=lif_p.v_reset,
        v_lim=lif_lib.vmem_limit(lif_p.vmem_bits), use_snl=cfg.use_snl,
        noise=noise_t, snl_amp=lif_p.noise_amp if noisy else 0.0,
        kwn_relax=kwn_relax, remat=remat, seed=seed)
    counts = jnp.sum(spk_t, axis=0)
    # normalize by the actual sequence length, not cfg.n_steps: logits must
    # match the inference paths for any T the caller feeds
    return (counts / t_steps) @ p["w_out"]


def loss_fn(p, events, labels, cfg, seed, *, noise=None,
            kwn_relax: float = DEFAULT_KWN_RELAX, remat: bool = False):
    """Cross-entropy over the differentiable silicon forward."""
    logits = forward_logits(p, events, cfg, seed, noise=noise,
                            kwn_relax=kwn_relax, remat=remat)
    lse = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lse, labels[:, None], 1))


def step_seed(key: jax.Array) -> jax.Array:
    """Fresh f32 counter seed for one optimization step (noise-aware QAT).

    Bounded under 2^23 so the float carrier is exact (the VJP keeps the
    seed float-typed to spare the cotangent machinery an integer primal).
    """
    return jax.random.randint(key, (), 0, 2 ** 23).astype(jnp.float32)
