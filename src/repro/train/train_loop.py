"""Training step builder: gradient accumulation via lax.scan over microbatches,
mixed precision, AdamW update, optional int8 gradient compression on the DP
all-reduce (dist/compression hook is applied by GSPMD through the shard_map
wrapper when enabled).

``build_train_step(cfg, mesh, ...)`` returns a function
    step(params, opt_state, batch) -> (params, opt_state, metrics)
ready for jax.jit with the shardings from dist/sharding.py.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.train import optim

Tree = Any


def microbatch(batch: Tree, n_micro: int) -> Tree:
    """(B, ...) -> (n_micro, B/n_micro, ...) for scan.

    NOTE: do this OUTSIDE jit (data pipeline / input specs).  Reshaping a
    batch-sharded (B, ...) array inside jit makes GSPMD replicate it (the
    microbatch dim doesn't divide by the dp axis), silently multiplying
    activation memory by the dp size.  train_step therefore *expects* the
    batch already shaped (n_micro, mb, ...) with dim-1 batch-sharded."""
    def r(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree.map(r, batch)


def build_train_step(cfg: lm.LMConfig, mesh=None, *, n_micro: int = 1,
                     opt_cfg: optim.AdamWConfig | None = None,
                     grad_dtype=jnp.float32):
    opt_cfg = opt_cfg or optim.AdamWConfig()

    def loss_for(params, mb):
        loss, parts = lm.loss_fn(params, mb, cfg, mesh)
        return loss, parts

    def train_step(params, opt_state, batch):
        # batch is pre-microbatched: every leaf (n_micro, mb, ...).
        mbs = batch
        lead = jax.tree.leaves(batch)[0].shape[0]
        assert lead == n_micro, (lead, n_micro)

        def acc_step(carry, mb):
            g_acc, l_acc = carry
            (loss, parts), grads = jax.value_and_grad(loss_for, has_aux=True)(
                params, mb)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(grad_dtype), g_acc, grads)
            return (g_acc, l_acc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dtype), params)
        (grads, loss_sum), _ = jax.lax.scan(acc_step, (g0, jnp.zeros(())), mbs)
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        loss = loss_sum / n_micro

        # NOTE: scan_subtrees=("layers",) bounds optimizer f32 temporaries to
        # one layer group but defeats donation aliasing through the while
        # loop (net +33GB/dev at kimi scale on the CPU-backend analysis), so
        # the direct update wins here; revisit on real TPU.
        params, opt_state, stats = optim.adamw_update(grads, opt_state,
                                                      params, opt_cfg)
        metrics = {"loss": loss, **stats}
        return params, opt_state, metrics

    return train_step


def build_eval_step(cfg: lm.LMConfig, mesh=None):
    def eval_step(params, batch):
        loss, parts = lm.loss_fn(params, batch, cfg, mesh)
        return {"loss": loss, **parts}
    return eval_step
