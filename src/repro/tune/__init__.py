"""Tile-plan autotuning for the fused macro pipeline.

Three pieces (see ``docs/TILE_PLANS.md`` for the full contract):

* ``repro.tune.cache``    — the persistent plan cache: a JSON file of tuned
  ``(bm, bk, bn)`` tile plans keyed on (op, shape, density bucket, mode,
  device kind), consumed transparently by ``kernels.fused_macro.plan_tiles``
  with the PR 4 heuristic as the fallback.
* ``repro.tune.measure``  — the bench timing loop (median-of-iters wall
  time) and the bursty event-stream generator, shared with
  ``benchmarks/bench_fused_macro.py`` so tuner medians and bench medians
  are the same instrument.
* ``repro.tune.autotune`` — the search: enumerate candidate plans, prune
  with the roofline prior, measure each candidate's latency (and modeled
  kernel-energy pJ/SOP), pick the winner under the requested objective,
  and persist it.  The heuristic plan is always in the candidate set, so a
  tuned plan can only meet or beat it at selection time.

``tools/tune_plans.py`` (``make tune`` / ``make tune-smoke``) is the CLI
that regenerates the cache; re-measuring for a new backend is a cache
regeneration, not a code change.
"""

from repro.tune import autotune, cache, measure  # noqa: F401
