"""Tile-plan autotuner: measure candidate (bm, bk, bn) plans, persist winners.

The search space is small and structured — every legal plan is a triple of
lane/sublane-aligned block sizes, and ``plan_tiles`` normalizes each triple
to a canonical ``TilePlan`` — so the tuner is an exhaustive prior-ordered
ladder (``launch.hillclimb.prior_guided_search``), not a stochastic search:

1. **Enumerate** candidate triples around the heuristic (bm in sublane
   multiples up to the batch, bk/bn in lane multiples up to one-big-tile).
   The heuristic's own triple is always a candidate, which is what makes
   "tuned meets or beats heuristic" an invariant of the subsystem rather
   than a hope: at selection time the winner scored no worse than the
   heuristic under the same instrument.
2. **Prior-rank** with the roofline model (``repro.roofline.analysis``
   peak/bandwidth constants + a per-grid-iteration overhead term):
   cheapest-predicted first, so ``patience`` early-stopping keeps the
   promising measurements.  The prior also models activity gating — the
   probability a (bm, bk) block of a density-d stream is occupied — since
   coarse blocks on sparse streams defeat the gate.
3. **Measure** each candidate with the bench stopwatch
   (``measure.median_us``) on a jitted ``ops.fused_macro_seq`` launch in
   the serving configuration (gated, no MAC telemetry), operands passed as
   arguments (never closed over — XLA constant-folds captured f32 operands
   with different FMA contraction).  Correctness is *not* re-derived here:
   every plan is bitwise-identical to the ``ref.py`` oracles by the kernel
   parity contract (tests enforce it through the cache path), so the tuner
   only ever trades speed.
4. **Score** under the requested objective — ``ms`` (median latency),
   ``pj_per_sop`` (the modeled kernel-energy proxy: MAC energy charged per
   *executed* occupied-block element so pad dilution and gating
   granularity cost energy, ADC from the measured early-stop step counts,
   LIF fixed), or ``blend`` (geometric mix) — and persist the winner via
   ``repro.tune.cache``.

``CANONICAL_CELLS`` covers the shapes the bench tracks; ``tune()`` is what
``tools/tune_plans.py`` / ``make tune`` runs.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy, ima as ima_lib
from repro.kernels import fused_macro as _fused, ops
from repro.tune import cache, measure

K_WIN = 12
CODE_BITS = 5
DRIVE_GAIN = 0.25

# Per-grid-iteration launch overhead (seconds) for the prior.  In interpret
# mode the Pallas grid is a host-level loop, so iteration count dominates
# wall time and this term decides most orderings; on a compiled backend it
# shrinks to core scheduling overhead but keeps one-big-tile and many-small-
# tile plans comparable.  Only the *ranking* matters — measurement decides.
GRID_ITER_OVERHEAD_S = 1e-4

OBJECTIVES = ("ms", "pj_per_sop", "blend")


class TuneCell(NamedTuple):
    """One autotuning workload: a launch shape + event density + mode."""

    m: int
    k_dim: int
    nc: int
    n: int
    t: int
    density: float
    mode: str = "kwn"
    k: int = K_WIN


# The shapes the bench tracks: the physical-macro layer and the 2x2
# virtual-macro layer, each at the bench's standard event rate, plus the
# sparse (1 %) point where gating granularity matters most.
CANONICAL_CELLS = (
    TuneCell(128, 256, 128, 128, 32, 0.05),
    TuneCell(128, 256, 128, 128, 32, 0.01),
    TuneCell(128, 512, 256, 256, 32, 0.05),
    TuneCell(128, 512, 256, 256, 32, 0.01),
)


def heuristic_blocks(cell: TuneCell) -> tuple[int, int, int]:
    """The PR 4 heuristic's (bm, bk, bn) for this cell (cache bypassed)."""
    p = _fused.plan_tiles(cell.m, cell.k_dim, cell.nc, cell.n, cell.t,
                          mode=cell.mode, use_cache=False)
    return (p.bm, p.bk, p.bn)


def enumerate_candidates(cell: TuneCell) -> list[tuple[int, int, int]]:
    """Legal (bm, bk, bn) triples, deduped by the normalized plan.

    bm sweeps sublane multiples (32/64/128) up to the padded batch; bk
    sweeps lane multiples up to one-big-tile over K (a single K tile kills
    per-K-tile gating but also kills grid iterations — which wins is
    exactly what measurement decides); bn sweeps lane multiples up to the
    single-column-tile collapse.  The heuristic triple is always included.
    """
    m_pad8 = _fused._ceil_mult(cell.m, 8)
    bms = sorted({min(b, m_pad8) for b in (32, 64, 128)})
    k_ceil = _fused._ceil_mult(cell.k_dim, 128)
    bks = [b for b in range(128, k_ceil + 1, 128) if k_ceil % b == 0]
    n_ceil = _fused._ceil_mult(cell.nc, 128)
    bns = [b for b in range(128, n_ceil + 1, 128) if n_ceil % b == 0]
    triples = {heuristic_blocks(cell)}
    triples.update((bm, bk, bn) for bm in bms for bk in bks for bn in bns)
    # dedupe by the plan each triple normalizes to (e.g. every bn >= nc
    # collapses to the same single-column-tile plan)
    by_plan = {}
    for tr in sorted(triples):
        p = _fused.plan_tiles(cell.m, cell.k_dim, cell.nc, cell.n, cell.t,
                              mode=cell.mode, bm=tr[0], bk=tr[1], bn=tr[2],
                              use_cache=False)
        by_plan.setdefault((p.bm, p.bk, p.bn, p.grid), tr)
    return sorted(by_plan.values())


# --- roofline prior --------------------------------------------------------

def occupied_fraction(density: float, bm: int, bk: int, t: int) -> float:
    """Expected fraction of (bm, bk) activity blocks with >= 1 event.

    Mirrors the bursty stream model in ``measure.event_stream``: below the
    in-burst rate a step is active w.p. d / IN_BURST_DENSITY and active
    steps fire at the in-burst rate, so block occupancy factors into
    P(step active) * P(block hit | active).  Coarser blocks saturate toward
    1.0 faster — the prior's penalty for defeating the gate.
    """
    burst = measure.IN_BURST_DENSITY
    if t > 1 and density < burst:
        p_step, d_in = density / burst, burst
    else:
        p_step, d_in = 1.0, min(density, 1.0)
    return p_step * (1.0 - (1.0 - d_in) ** (bm * bk))


def prior_seconds(cell: TuneCell, blocks: tuple[int, int, int]) -> float:
    """Analytic cost estimate used only to *order* candidates."""
    from repro.roofline.analysis import HBM_BW, PEAK_FLOPS
    p = _fused.plan_tiles(cell.m, cell.k_dim, cell.nc, cell.n, cell.t,
                          mode=cell.mode, bm=blocks[0], bk=blocks[1],
                          bn=blocks[2], use_cache=False)
    occ = occupied_fraction(cell.density, p.bm, p.bk, cell.t)
    flops = 2.0 * p.m_pad * p.k_pad * p.nc_pad * cell.t * occ
    n_col = p.nc_pad // p.bn
    # streamed bytes: events once, weight planes re-streamed per column
    # tile and (gating aside) per occupied row/K block
    bytes_ = (cell.t * p.m_pad * p.k_pad
              + 2 * p.k_pad * p.nc_pad * n_col * max(occ, 1.0 / n_col)
              + 4 * cell.t * p.m_pad * p.n_pad)
    grid_iters = p.grid[0] * p.grid[1] * p.grid[2] * p.grid[3]
    return max(flops / PEAK_FLOPS, bytes_ / HBM_BW) \
        + GRID_ITER_OVERHEAD_S * grid_iters


# --- modeled kernel-energy objective ---------------------------------------

def modeled_pj_per_sop(cell: TuneCell, blocks: tuple[int, int, int],
                       x, adc_steps_mean: float) -> float:
    """Plan-dependent kernel-energy proxy (pJ per true synaptic op).

    Outputs are bitwise plan-invariant, so a faithful per-SOP figure could
    never discriminate plans; this proxy charges the energy of the work the
    *kernel* actually does under the plan: MAC energy per executed element
    of occupied (bm, bk) blocks times the padded column width (pad dilution
    and coarse gating both cost energy), ADC energy over padded columns at
    the *measured* mean early-stop step count, and the digital LIF update.
    Divided by true SOPs (events x fan-out), so the unit stays comparable
    to ``core.energy``'s calibrated figures even though the absolute level
    reflects the TPU launch, not the 65-nm macro.
    """
    p = _fused.plan_tiles(cell.m, cell.k_dim, cell.nc, cell.n, cell.t,
                          mode=cell.mode, bm=blocks[0], bk=blocks[1],
                          bn=blocks[2], use_cache=False)
    xm = np.asarray(x).reshape(cell.t, -1, cell.k_dim)
    xm = np.pad(xm, ((0, 0), (0, p.m_pad - xm.shape[1]),
                     (0, p.k_pad - cell.k_dim)))
    occ = (xm != 0).reshape(cell.t, p.m_pad // p.bm, p.bm,
                            p.k_pad // p.bk, p.bk).any(axis=(2, 4))
    executed = float(occ.sum()) * p.bm * p.bk * p.nc_pad
    true_sops = float(np.count_nonzero(np.asarray(x))) * cell.nc
    e_mac = executed * energy.E_MAC_PER_SOP
    e_adc = (cell.t * p.m_pad * p.nc_pad * float(adc_steps_mean)
             * energy.E_ADC_PER_STEP_COL)
    e_lif = cell.t * p.m_pad * cell.k * energy.E_LIF_PER_UPDATE
    return (e_mac + e_adc + e_lif) / max(true_sops, 1.0)


# --- measurement -----------------------------------------------------------

def _operands(cell: TuneCell, seed: int = 0):
    """Bench-style operands for one cell; events from the shared stream."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = measure.event_stream(ks[0], cell.density,
                             (cell.t, cell.m, cell.k_dim))
    tern = lambda k, s: jax.random.randint(k, s, -1, 2).astype(jnp.int8)
    msb = tern(ks[1], (cell.k_dim, cell.nc))
    lsb = tern(ks[2], (cell.k_dim, cell.nc))
    cb = ima_lib.nlq_codebook(CODE_BITS, -24, 24)
    scale = jax.random.uniform(ks[3], (cell.nc,), minval=0.05, maxval=0.3)
    v = jax.random.normal(ks[4], (cell.m, cell.n)) * 0.5
    return x, msb, lsb, cb, scale, v


def _runner(cell: TuneCell, blocks: tuple[int, int, int]):
    """Jitted serving-config launch with the plan pinned explicitly."""
    return jax.jit(functools.partial(
        ops.fused_macro_seq, mode=cell.mode, k=cell.k,
        drive_gain=DRIVE_GAIN, gate=True, mac_telemetry=False,
        bm=blocks[0], bk=blocks[1], bn=blocks[2]))


class Measurement(NamedTuple):
    blocks: tuple[int, int, int]
    median_ms: float
    pj_per_sop: float


def measure_candidate(cell: TuneCell, blocks: tuple[int, int, int],
                      operands, iters: int) -> Measurement:
    x, msb, lsb, cb, scale, v = operands
    run = _runner(cell, blocks)
    args = (x, msb, lsb, cb.boundaries, cb.levels, scale, v)
    out = run(*args)                        # adc telemetry for the energy term
    adc_mean = float(jnp.mean(out[4]))
    ms = measure.median_us(
        run, args, iters=iters,
        label=f"candidate {blocks[0]}x{blocks[1]}x{blocks[2]} "
              f"@ {cell.m}x{cell.k_dim}x{cell.n}") * 1e-3
    return Measurement(blocks, ms,
                       modeled_pj_per_sop(cell, blocks, x, adc_mean))


# --- the search ------------------------------------------------------------

def _score(meas: Measurement, heur: Measurement, objective: str,
           blend_weight: float) -> float:
    if objective == "ms":
        return meas.median_ms
    if objective == "pj_per_sop":
        return meas.pj_per_sop
    # geometric blend of the two ratios vs the heuristic, so the two axes
    # are unit-free and a blend_weight of 0/1 recovers the pure objectives
    r_ms = meas.median_ms / heur.median_ms
    r_pj = meas.pj_per_sop / heur.pj_per_sop
    return (r_ms ** (1.0 - blend_weight)) * (r_pj ** blend_weight)


def autotune_cell(cell: TuneCell, *, objective: str = "ms",
                  blend_weight: float = 0.5, iters: int = 9,
                  patience: int | None = None, seed: int = 0,
                  verbose: bool = True) -> dict:
    """Search one cell; returns a cache entry dict (not yet persisted)."""
    if objective not in OBJECTIVES:
        raise ValueError(f"objective {objective!r} not in {OBJECTIVES}")
    from repro.launch.hillclimb import prior_guided_search
    operands = _operands(cell, seed=seed)
    heur_blocks = heuristic_blocks(cell)
    candidates = enumerate_candidates(cell)
    heur = measure_candidate(cell, heur_blocks, operands, iters)
    measured = {heur_blocks: heur}

    def evaluate(blocks):
        if blocks not in measured:
            measured[blocks] = measure_candidate(cell, blocks, operands,
                                                 iters)
        m = measured[blocks]
        s = _score(m, heur, objective, blend_weight)
        if verbose:
            print(f"  bm={blocks[0]:>3} bk={blocks[1]:>3} bn={blocks[2]:>3}"
                  f"  {m.median_ms:8.2f} ms  {m.pj_per_sop:8.2f} pJ/SOP"
                  f"  score={s:.4g}"
                  + ("  [heuristic]" if blocks == heur_blocks else ""),
                  flush=True)
        return s

    best_blocks, _, _ = prior_guided_search(
        candidates, evaluate,
        prior=lambda b: prior_seconds(cell, b), patience=patience)
    best = measured[best_blocks]
    return {
        "op": "fused_macro_seq",
        "shape": cache.shape_key(cell.m, cell.k_dim, cell.nc, cell.n,
                                 cell.t),
        "mode": cell.mode,
        "density_bucket": cache.density_bucket(cell.density),
        "device_kind": cache.device_kind(),
        "plan": {"bm": best_blocks[0], "bk": best_blocks[1],
                 "bn": best_blocks[2]},
        "objective": objective,
        "score": round(_score(best, heur, objective, blend_weight), 6),
        "median_ms": round(best.median_ms, 4),
        "pj_per_sop": round(best.pj_per_sop, 4),
        "heuristic_median_ms": round(heur.median_ms, 4),
        "speedup_vs_heuristic": round(heur.median_ms / best.median_ms, 4),
        "n_candidates": len(measured),
    }


def _any_entries(entries: list[dict]) -> list[dict]:
    """Per (op, shape, mode, device) group, the best entry re-keyed 'any'.

    Serving paths look plans up with ``density=None`` (event density is
    data-dependent); persisting the group's best-speedup winner under the
    ``any`` bucket makes that lookup a direct hit instead of a scan.
    """
    groups: dict = {}
    for e in entries:
        g = (e["op"], e["shape"], e["mode"], e["device_kind"])
        cur = groups.get(g)
        if cur is None or (e["speedup_vs_heuristic"],
                           e["density_bucket"]) > \
                (cur["speedup_vs_heuristic"], cur["density_bucket"]):
            groups[g] = e
    return [{**groups[g], "density_bucket": cache.ANY_BUCKET}
            for g in sorted(groups)]


def tune(cells=CANONICAL_CELLS, *, objective: str = "ms",
         blend_weight: float = 0.5, iters: int = 9,
         patience: int | None = None, path: str | None = None,
         merge: bool = True, verbose: bool = True):
    """Autotune every cell and persist winners (+ 'any' rollups).

    Returns (entries, path_written).  ``merge=True`` (default) keeps
    existing cache entries for keys not re-tuned — e.g. another device
    kind's plans survive a CPU retune.
    """
    entries = []
    for cell in cells:
        if verbose:
            print(f"[tune] {cache.shape_key(cell.m, cell.k_dim, cell.nc, cell.n, cell.t)}"
                  f" d={cell.density} mode={cell.mode}"
                  f" objective={objective}", flush=True)
        entries.append(autotune_cell(
            cell, objective=objective, blend_weight=blend_weight,
            iters=iters, patience=patience, verbose=verbose))
    entries += _any_entries(entries)
    out = cache.save_entries(entries, path=path, merge=merge)
    if verbose:
        print(f"[tune] wrote {len(entries)} entries -> {out}", flush=True)
    return entries, out
