"""Persistent tile-plan cache: tuned (bm, bk, bn) per (op, shape, density
bucket, mode, device kind).

The cache is a single JSON file (default ``PLAN_CACHE_fused_macro.json`` at
the repo root, next to ``BENCH_fused_macro.json``) written by the autotuner
(``repro.tune.autotune`` / ``tools/tune_plans.py``) and consumed by
``kernels.fused_macro.plan_tiles`` as a lookup fast path in front of the
lane-alignment heuristic.  Design constraints, in order:

1. **A cache problem can never become a launch problem.**  Every failure
   mode — missing file, corrupt JSON, wrong format/version, a stale entry
   whose blocks no longer satisfy the kernel's alignment rules — degrades
   to the heuristic with a one-shot ``RuntimeWarning``, never an exception.
   Cached plans only choose *which* bitwise-equivalent launch geometry
   runs, so a fallback is a perf event, not a correctness event.
2. **Lookups are deterministic.**  Two call sites that plan the same
   logical launch (e.g. ``plan_activity`` building the occupancy map and
   ``ops.fused_macro_seq`` planning the kernel) must resolve to the same
   plan, or the map's shape would not match the grid.  Given identical
   arguments the lookup is a pure function of the cache file contents.
3. **Cheap on the hot path.**  The file is parsed once and memoized;
   subsequent lookups are a dict probe.  The memo is invalidated on
   (path, mtime, size) change, so ``make tune`` takes effect without a
   process restart.

File schema (``CACHE_VERSION`` gates compatibility — bump it whenever key
semantics or required entry fields change, and old files degrade to the
heuristic instead of being misread)::

    {
      "format": "neudw-plan-cache",
      "version": 1,
      "entries": [
        {"op": "fused_macro_seq",
         "shape": "128x256x128x128x32",          # MxKxNCxNxT
         "mode": "kwn",
         "density_bucket": "d02-07",             # see density_bucket()
         "device_kind": "cpu:interpret",         # see device_kind()
         "plan": {"bm": 128, "bk": 256, "bn": 128},
         "objective": "ms",
         "score": 11.4,                          # objective value (winner)
         "median_ms": 11.4,
         "pj_per_sop": 1.38,                     # modeled kernel-energy proxy
         "heuristic_median_ms": 12.9,
         "speedup_vs_heuristic": 1.13,
         "n_candidates": 6},
        ...
      ]
    }

Key semantics (the contract ``docs/TILE_PLANS.md`` documents for the TPU
port): an entry matches a ``lookup()`` when op, shape string, mode, and
device kind are equal AND the density bucket matches.  With
``density=None`` (the serving paths — event density is data-dependent and
not worth a host sync) the bucket ``"any"`` is preferred; failing that the
group's entry with the highest ``speedup_vs_heuristic`` wins (that field is
normalized per-bucket, so it is the one cross-bucket-comparable score),
ties broken by bucket name for determinism.
"""

from __future__ import annotations

import json
import os
import tempfile
import warnings
from typing import NamedTuple

CACHE_FORMAT = "neudw-plan-cache"
CACHE_VERSION = 1
DEFAULT_BASENAME = "PLAN_CACHE_fused_macro.json"

ENV_PATH = "REPRO_PLAN_CACHE_PATH"     # override the cache file location
ENV_DISABLE = "REPRO_PLAN_CACHE"       # "0"/"off"/"" disables all lookups

# Density buckets: half-open [lo, hi) ranges over the measured |event| rate.
# Edges follow the bench's density sweep structure (1/5/10/25/50/100 %) so
# each benched point lands in its own bucket; names are stable cache-key
# material — changing them invalidates every persisted entry, so treat them
# like a schema field (bump CACHE_VERSION if they ever move).
DENSITY_EDGES = (0.02, 0.075, 0.15, 0.35, 0.75)
DENSITY_BUCKETS = ("d00-02", "d02-07", "d07-15", "d15-35", "d35-75",
                   "d75-100")
ANY_BUCKET = "any"

REQUIRED_ENTRY_FIELDS = ("op", "shape", "mode", "density_bucket",
                         "device_kind", "plan")


class PlanBlocks(NamedTuple):
    """The tuned block sizes an entry carries — what ``plan_tiles`` needs."""

    bm: int
    bk: int
    bn: int


def density_bucket(density: float) -> str:
    """Map a measured event density in [0, 1] to its stable bucket name."""
    d = float(density)
    if not 0.0 <= d <= 1.0:
        raise ValueError(f"density {d} not in [0, 1]")
    for edge, name in zip(DENSITY_EDGES, DENSITY_BUCKETS):
        if d < edge:
            return name
    return DENSITY_BUCKETS[-1]


def shape_key(m: int, k_dim: int, nc: int, n: int, t: int) -> str:
    """Canonical shape string: batch x K x NC x N x T (logical, unpadded)."""
    return f"{m}x{k_dim}x{nc}x{n}x{t}"


def device_kind() -> str:
    """Cache-key device identity: JAX device kind + the interpret switch.

    Interpret-mode timings (CPU validation) and real-hardware timings live
    in different performance regimes, so an interpret-tuned plan must never
    be served to a Mosaic-lowered launch: the suffix keeps them apart.  On
    real TPU this returns e.g. ``"tpu v5 lite"``; regenerating the cache
    there (``make tune``) is the whole TPU-port story for tile planning.
    """
    import jax
    kind = jax.devices()[0].device_kind.lower()
    from repro.kernels import ops as _ops   # late: avoid import cycles
    return f"{kind}:interpret" if _ops.INTERPRET else kind


def default_path() -> str:
    """Resolve the cache file path: env override, else the repo root."""
    env = os.environ.get(ENV_PATH)
    if env:
        return env
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))  # src/../
    return os.path.join(root, DEFAULT_BASENAME)


def disabled() -> bool:
    return os.environ.get(ENV_DISABLE, "1").lower() in ("0", "off", "")


# --- load / memoize --------------------------------------------------------

# path -> (mtime_ns, size, index | None).  index maps the 5-tuple key to the
# raw entry dict; None records a load failure so we neither retry the parse
# nor re-warn on every plan_tiles call.
_MEMO: dict = {}
_WARNED: set = set()


def clear_memo() -> None:
    """Drop the in-process cache state (tests; after in-place rewrites)."""
    _MEMO.clear()
    _WARNED.clear()


def _warn_once(path: str, reason: str) -> None:
    if (path, reason) not in _WARNED:
        _WARNED.add((path, reason))
        warnings.warn(
            f"tile-plan cache {path!r}: {reason}; falling back to the "
            f"heuristic planner (regenerate with `make tune`)",
            RuntimeWarning, stacklevel=3)


def _entry_key(e: dict) -> tuple:
    return (e["op"], e["shape"], e["mode"], e["device_kind"],
            e["density_bucket"])


def _valid_blocks(plan: dict, nc: int | None = None) -> bool:
    """Alignment rules a cached plan must still satisfy (stale-entry gate).

    Mirrors what the heuristic guarantees: row tiles on the f32 sublane
    (bm % 8), K tiles on the lane (bk % 128) so activity blocks stay
    lane-aligned, and column tiles either lane-aligned or wide enough that
    ``plan_tiles`` collapses the layer to a single unpadded tile
    (``bn >= nc``).  An entry tuned under older rules that no longer
    passes degrades to the heuristic rather than reaching Pallas.
    """
    try:
        bm, bk, bn = int(plan["bm"]), int(plan["bk"]), int(plan["bn"])
    except (KeyError, TypeError, ValueError):
        return False
    if not (bm > 0 and bm % 8 == 0 and bk > 0 and bk % 128 == 0 and bn > 0):
        return False
    return bn % 128 == 0 or nc is None or bn >= nc


def _index(doc: object, path: str):
    if not isinstance(doc, dict) or doc.get("format") != CACHE_FORMAT:
        _warn_once(path, "not a plan-cache file")
        return None
    if doc.get("version") != CACHE_VERSION:
        _warn_once(path, f"version {doc.get('version')!r} != "
                         f"{CACHE_VERSION} (stale format)")
        return None
    entries = doc.get("entries")
    if not isinstance(entries, list):
        _warn_once(path, "entries: want a list")
        return None
    idx = {}
    for e in entries:
        if not isinstance(e, dict) or \
                any(f not in e for f in REQUIRED_ENTRY_FIELDS):
            _warn_once(path, "entry missing required fields (skipped)")
            continue
        idx[_entry_key(e)] = e
    return idx


def _load(path: str):
    """Memoized parse of the cache file; None on any failure (warned once)."""
    try:
        st = os.stat(path)
        stamp = (st.st_mtime_ns, st.st_size)
    except OSError:
        return None                      # no file: a silent miss, not an error
    memo = _MEMO.get(path)
    if memo is not None and memo[0] == stamp:
        return memo[1]
    try:
        with open(path) as f:
            doc = json.load(f)
        idx = _index(doc, path)
    except (OSError, ValueError) as e:
        _warn_once(path, f"unreadable ({e.__class__.__name__})")
        idx = None
    _MEMO[path] = (stamp, idx)
    return idx


# --- lookup ----------------------------------------------------------------

def lookup(m: int, k_dim: int, nc: int, n: int, t: int, *,
           mode: str = "kwn", density: float | None = None,
           op: str = "fused_macro_seq",
           path: str | None = None) -> PlanBlocks | None:
    """Tuned blocks for a launch, or None (-> heuristic).  Never raises."""
    try:
        if disabled():
            return None
        path = path or default_path()
        idx = _load(path)
        if not idx:
            return None
        group = (op, shape_key(m, k_dim, nc, n, t), mode, device_kind())
        entry = None
        if density is not None:
            entry = idx.get(group + (density_bucket(density),)) \
                or idx.get(group + (ANY_BUCKET,))
        else:
            entry = idx.get(group + (ANY_BUCKET,))
            if entry is None:
                in_group = [e for k, e in sorted(idx.items())
                            if k[:4] == group]
                if in_group:
                    entry = max(
                        in_group,
                        key=lambda e: (float(e.get("speedup_vs_heuristic",
                                                   0.0)),
                                       e["density_bucket"]))
        if entry is None:
            return None
        if not _valid_blocks(entry["plan"], nc=nc):
            _warn_once(path, f"stale plan for {group} (alignment rules)")
            return None
        p = entry["plan"]
        return PlanBlocks(int(p["bm"]), int(p["bk"]), int(p["bn"]))
    except Exception as e:   # noqa: BLE001 — the cache must never break planning
        _warn_once(path or "<unresolved>",
                   f"lookup failed ({e.__class__.__name__}: {e})")
        return None


# --- write -----------------------------------------------------------------

def save_entries(entries: list[dict], path: str | None = None,
                 merge: bool = True) -> str:
    """Persist tuner results; merge-by-key with any existing file by default.

    Each entry must carry ``REQUIRED_ENTRY_FIELDS``; ``merge=False`` starts
    the file fresh (drops every previously persisted plan).  Returns the
    path written.  The in-process memo is invalidated so the next
    ``lookup`` sees the new contents.

    The write is **atomic**: the document is serialized to a temp file in
    the same directory, fsynced, and ``os.replace``d over the target.  A
    crash (or a concurrent reader) mid-write can therefore never leave a
    truncated/corrupt cache on disk — readers see either the old complete
    file or the new complete file.  (A corrupt cache would only cost the
    heuristic fallback, but a half-written file on every ``make tune``
    interrupt is still a self-inflicted wound worth designing out.)
    """
    path = path or default_path()
    for e in entries:
        missing = [f for f in REQUIRED_ENTRY_FIELDS if f not in e]
        if missing:
            raise ValueError(f"entry missing fields {missing}: {e}")
        nc = int(str(e["shape"]).split("x")[2])
        if not _valid_blocks(e["plan"], nc=nc):
            raise ValueError(f"entry plan violates alignment rules: {e}")
    merged: dict = {}
    if merge:
        existing = _load(path)
        if existing:
            merged.update(existing)
    for e in entries:
        merged[_entry_key(e)] = e
    doc = {"format": CACHE_FORMAT, "version": CACHE_VERSION,
           "entries": [merged[k] for k in sorted(merged)]}
    # same-directory temp file: os.replace must not cross filesystems
    fd, tmp = tempfile.mkstemp(
        prefix=os.path.basename(path) + ".", suffix=".tmp",
        dir=os.path.dirname(os.path.abspath(path)) or ".")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        # never leave a stray temp file next to the cache
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    _MEMO.pop(path, None)
    return path
