"""Shared measurement instruments: the bench timing loop + event streams.

``benchmarks/bench_fused_macro.py`` and the autotuner must agree on what a
"median latency" is — a tuned plan picked under one stopwatch and gated
under another would let clock-skew masquerade as a tuning win.  So the
timing loop and the bursty event-stream generator live here, and the bench
aliases them (``bench_fused_macro._time`` *is* ``measure.median_us``).

Both functions are exactly the instruments the bench has carried since
PR 4; moving them is a relocation, not a re-derivation.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace

# Per-element event rate inside an active (burst) step of a DVS-like
# stream.  Shared constant: the bench's density sweep and the tuner's
# candidate measurements must synthesize the same temporal structure,
# because the activity planner's skipped-block ratio (and therefore the
# measured latency ordering of candidate plans) depends on it.
IN_BURST_DENSITY = 0.2


def median_us(fn, args, iters: int = 20, label: str | None = None) -> float:
    """Median per-call wall time in microseconds (median over ``iters``
    timed calls — robust to the scheduler hiccups a mean would absorb).

    Each call emits one ``measure`` span on the ``measure`` track of the
    process-global tracer (when enabled), covering warmup + all timed
    iterations and carrying the resulting median — so a traced bench or
    autotune run (``--trace-out``) renders every candidate measurement
    as its own block on the timeline.  ``label`` names the span
    (e.g. the autotuner's candidate tile plan); the measurement itself
    is unchanged.
    """
    tr = obs_trace.get_tracer()
    span = tr.begin(label or "measure", track="measure")
    out = fn(*args)                       # compile + warm up
    jax.block_until_ready(out)
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        samples.append(time.perf_counter() - t0)
    us = float(np.median(samples)) * 1e6
    if span is not None:
        tr.end(span, args={"iters": iters, "median_us": us})
    return us


def median_ms(fn, args, iters: int = 20, label: str | None = None) -> float:
    """``median_us`` in milliseconds — the unit the plan cache persists."""
    return median_us(fn, args, iters=iters, label=label) * 1e-3


def event_stream(key, density, shape):
    """Density-d ternary events; bursty (DVS-like) when time-major.

    A (T, M, K) stream at density < IN_BURST_DENSITY is modelled as silent
    steps plus active steps firing at the in-burst rate (saccade/gesture
    streams are temporally clustered, which is exactly the structure the
    per-(step, row-tile, K-tile) activity planner converts into skipped
    blocks); at or above the in-burst rate every step is active with
    uniform per-element density.  2-D (single-step) shapes are uniform —
    one step has no temporal structure to exploit.
    """
    k_val, k_el, k_step = jax.random.split(key, 3)
    tern = jax.random.randint(k_val, shape, -1, 2).astype(jnp.int8)
    if len(shape) == 3 and density < IN_BURST_DENSITY:
        active = jax.random.uniform(k_step, (shape[0], 1, 1)) \
            < (density / IN_BURST_DENSITY)
        sparse = (jax.random.uniform(k_el, shape) < IN_BURST_DENSITY) & active
    else:
        sparse = jax.random.uniform(k_el, shape) < density
    return (tern * sparse).astype(jnp.int8)
