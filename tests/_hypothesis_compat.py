"""Optional-dependency shim for ``hypothesis``.

The property-based tests are a bonus tier: when ``hypothesis`` is installed
they run for real; when it is not (the minimal CI image), the ``@given`` tests
are collected and skipped instead of blowing up the whole module at import
time.  Import ``given``/``settings``/``st`` from here, never from
``hypothesis`` directly.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only on minimal images
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any strategy constructor call; the value is never drawn."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None
            return _strategy

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            # *args-only signature so pytest requests no fixtures for the
            # original hypothesis-driven parameters.
            def wrapper(*args, **kwargs):
                pytest.skip("hypothesis not installed")
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco
