"""Shared test setup: deterministic seeding, JAX platform config, and the
``slow``/``fast`` markers for the test tiers.

Run with ``PYTHONPATH=src python -m pytest -x -q``; deselect the slow tier
with ``-m "not slow"`` for a faster inner loop, or run the <60 s tier-1
smoke subset with ``-m "fast and not slow"`` (also ``make smoke``).  The
fast tier is curated by module below — parity/property suites can grow in
the default tier without bloating the smoke loop.
"""

from __future__ import annotations

import os
import random

# Platform setup must happen before jax initializes a backend: this repo's
# CI container is CPU-only, and the kernels run with interpret=True there.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

import jax
import numpy as np
import pytest

# All oracles/kernels are specified at f32 accumulation; keep x64 off so a
# user-level JAX_ENABLE_X64 cannot silently change parity tolerances.
jax.config.update("jax_enable_x64", False)

SLOW_MODULES = {
    # subprocess multi-device simulations + full training loops
    "test_dist.py",
    "test_pipeline.py",
    "test_system.py",
    "test_fault_tolerance.py",
}

FAST_MODULES = {
    # the <60 s tier-1 smoke set: core semantics, golden regressions (incl.
    # the fused-kernel tiling/time-major invariance checks), roofline.
    # Full composed-kernel parity (test_kernels, test_fused_macro*) lives
    # in the default tier — it's worth real minutes, not smoke seconds.
    # test_ima_noise.py curates its own smoke subset with explicit
    # ``@pytest.mark.fast`` markers (one noisy-parity shape, seeded
    # determinism, the Fig. 7a moments golden) so the tier stays <60 s;
    # its wide moment sweep is marked ``slow``.
    "test_core.py",
    "test_golden_regression.py",
    "test_roofline.py",
}


def pytest_collection_modifyitems(config, items):
    for item in items:
        base = os.path.basename(str(item.fspath))
        if base in SLOW_MODULES:
            item.add_marker(pytest.mark.slow)
        if base in FAST_MODULES:
            item.add_marker(pytest.mark.fast)


@pytest.fixture(autouse=True)
def _deterministic_seeds():
    """Seed the non-JAX RNGs per test (JAX keys are explicit everywhere)."""
    random.seed(0)
    np.random.seed(0)
    yield


@pytest.fixture
def rng():
    """Canonical per-test PRNG key."""
    return jax.random.PRNGKey(0)
