"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, assert output shapes + finite values; decode step
for decoder archs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPE_SKIPS
from repro.configs.base import reduced
from repro.models import lm
from repro.nn import module

ARCH_IDS = sorted(ARCHS)


def _smoke_batch(cfg, key, b=2, s=64):
    ks = jax.random.split(key, 4)
    if cfg.frontend == "audio_frames":
        return {"frames": jax.random.normal(ks[0], (b, s, cfg.frontend_dim)),
                "targets": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
                "loss_mask": (jax.random.uniform(ks[2], (b, s)) < 0.3
                              ).astype(jnp.int32)}
    if cfg.frontend == "vision_patches":
        return {"tokens": jax.random.randint(ks[0], (b, s - cfg.n_patches), 0,
                                             cfg.vocab_size),
                "patches": jax.random.normal(
                    ks[1], (b, cfg.n_patches, cfg.frontend_dim))}
    return {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_train_step(arch_id):
    cfg = reduced(ARCHS[arch_id])
    key = jax.random.PRNGKey(hash(arch_id) % 2 ** 31)
    params = module.materialize(lm.param_specs(cfg), key)
    batch = _smoke_batch(cfg, jax.random.fold_in(key, 1))

    logits, aux = lm.forward(params, batch, cfg)
    b, s = 2, 64
    if cfg.encoder_only:
        assert logits.shape == (b, s, cfg.vocab_size)
    else:
        assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), "NaN/inf in logits"

    # one SGD step: loss must be finite and gradients sane
    def loss(p):
        return lm.loss_fn(p, batch, cfg)[0]

    l0, g = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                         for x in jax.tree.leaves(g)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    p2 = jax.tree.map(lambda p, gg: p - 1e-3 * gg, params, g)
    l1 = loss(p2)
    assert bool(jnp.isfinite(l1))


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if not ARCHS[a].encoder_only])
def test_decode_step(arch_id):
    cfg = reduced(ARCHS[arch_id])
    key = jax.random.PRNGKey(0)
    params = module.materialize(lm.param_specs(cfg), key)
    b, s_max = 2, 64
    cache = lm.init_cache(cfg, b, s_max)
    tokens = jax.random.randint(key, (b, 1), 0, cfg.vocab_size)
    pos = jnp.zeros((b,), jnp.int32)
    logits, cache = lm.decode_step(params, cache, tokens, pos, cfg)
    assert logits.shape == (b, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # a few more steps: cache must evolve without NaNs
    for t in range(1, 4):
        nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
        logits, cache = lm.decode_step(params, cache, nxt,
                                       jnp.full((b,), t, jnp.int32), cfg)
        assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch_id", ["gemma2-2b", "qwen2.5-32b"])
def test_prefill_decode_consistency(arch_id):
    """Teacher-forced decode must match the parallel forward (same logits)."""
    cfg = reduced(ARCHS[arch_id])
    key = jax.random.PRNGKey(7)
    params = module.materialize(lm.param_specs(cfg), key)
    b, s = 2, 8
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full_logits, _ = lm.forward(params, {"tokens": tokens}, cfg)

    cache = lm.init_cache(cfg, b, 64)
    for t in range(s):
        step_logits, cache = lm.decode_step(
            params, cache, tokens[:, t:t + 1],
            jnp.full((b,), t, jnp.int32), cfg)
        np.testing.assert_allclose(step_logits, full_logits[:, t],
                                   rtol=2e-3, atol=2e-3)


def test_skips_documented():
    # every skipped cell carries a reason
    for (a, s), why in SHAPE_SKIPS.items():
        assert a in ARCHS and why
