"""Unit tests for the paper-core modules (ternary / IMA / KWN / LIF / NLD /
macro / energy), each pinned to a paper claim where one exists."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dendrite, energy, ima, kwn, lif, macro, prbs, ternary


class TestTernary:
    def test_decompose_compose_roundtrip(self):
        w = jnp.arange(-3, 4, dtype=jnp.float32)
        msb, lsb = ternary.weight_decompose(w)
        assert jnp.all(jnp.isin(msb, jnp.array([-1.0, 0.0, 1.0])))
        assert jnp.all(jnp.isin(lsb, jnp.array([-1.0, 0.0, 1.0])))
        np.testing.assert_array_equal(ternary.weight_compose(msb, lsb), w)

    def test_quantize_3bit_range(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        w_int, scale = ternary.quantize_weights_3bit(w)
        assert float(jnp.max(jnp.abs(w_int))) <= 3
        err = jnp.abs(w - w_int * scale)
        assert float(jnp.max(err)) <= float(jnp.max(scale)) * 0.51

    def test_ste_gradient_passthrough(self):
        g = jax.grad(lambda w: jnp.sum(ternary.quantize_weights_ste(w) ** 2))(
            jnp.ones((8, 8)) * 0.3)
        assert bool(jnp.all(jnp.isfinite(g))) and float(jnp.sum(jnp.abs(g))) > 0

    def test_mc_current_ratio_spread(self):
        # Fig. 3c: minimal fluctuation around the nominal 2x ratio.
        r = ternary.sample_current_ratio(jax.random.PRNGKey(1), (10000,), sigma=0.02)
        assert abs(float(jnp.mean(r)) - 2.0) < 0.02
        assert float(jnp.std(r)) < 0.1

    def test_fig3d_5bit_advantages(self):
        # Paper: 4x latency vs PWM, 7.8x bit-cell count vs MCL at 5-bit.
        lat_t, cells_t = ternary.weight_implementation_cost(5, "twin")
        lat_p, _ = ternary.weight_implementation_cost(5, "pwm")
        _, cells_m = ternary.weight_implementation_cost(5, "mcl")
        assert lat_p / lat_t == pytest.approx(4.0)
        assert cells_m / cells_t == pytest.approx(7.8, abs=0.1)


class TestIMA:
    def test_linear_codebook_roundtrip(self):
        cb = ima.linear_codebook(5, -64, 64)
        xs = cb.levels
        np.testing.assert_array_equal(ima.ima_convert(xs, cb),
                                      jnp.arange(cb.n_codes))
        np.testing.assert_allclose(ima.ima_quantize(xs, cb), xs, atol=1e-5)

    def test_nlq_denser_near_zero(self):
        cb = ima.nlq_codebook(5, -64, 64, gamma=2.0)
        gaps = jnp.diff(cb.levels)
        mid = cb.n_codes // 2
        assert float(gaps[mid - 1]) < float(gaps[0])  # fine near 0, coarse at tail

    def test_nlq_5bit_covers_8bit_range(self):
        # Paper: 5-bit ADC for 8-bit range via NLQ + LUT map-back.
        cb = ima.nlq_codebook(5, -128, 127)
        assert cb.n_codes == 32
        assert float(cb.levels[0]) == -128 and float(cb.levels[-1]) == 127

    def test_fig7a_transfer_error(self):
        cb = ima.nlq_codebook(5, -64, 64)
        m = ima.measure_transfer_error(cb, jax.random.PRNGKey(0))
        assert m["mean_lsb"] == pytest.approx(0.41, abs=0.08)
        assert m["std_lsb"] == pytest.approx(1.34, abs=0.12)

    def test_fig7b_inl(self):
        cb = ima.activation_codebook(5, ima.quadratic, -8, 8)
        v = ima.measure_inl(cb, ima.quadratic, key=jax.random.PRNGKey(0),
                            noise=ima.IMANoiseModel())
        assert v == pytest.approx(0.91, abs=0.1)

    def test_activation_codebook_matches_f(self):
        cb = ima.activation_codebook(6, ima.quadratic, -8, 8)
        xs = jnp.linspace(-8, 8, 257)
        err = jnp.abs(ima.ima_quantize(xs, cb) - ima.quadratic(xs))
        lsb = (jnp.max(cb.levels) - jnp.min(cb.levels)) / (cb.n_codes - 1)
        assert float(jnp.mean(err)) < float(lsb)

    def test_ste_grad(self):
        cb = ima.nlq_codebook(5, -4, 4)
        g = jax.grad(lambda x: jnp.sum(ima.ima_quantize_ste(x, cb)))(
            jnp.linspace(-3, 3, 16))
        assert bool(jnp.all(g >= 0)) and float(jnp.sum(g)) > 0


class TestKWN:
    def setup_method(self):
        self.cb = ima.nlq_codebook(5, -64, 64)
        self.mac = jax.random.normal(jax.random.PRNGKey(3), (6, 128)) * 20

    def test_topk_and_ramp_agree(self):
        for k in (1, 3, 12, 32):
            a = kwn.kwn_select(self.mac, k, self.cb)
            b = kwn.kwn_ramp_scan(self.mac, k, self.cb)
            np.testing.assert_array_equal(a.mask, b.mask)
            np.testing.assert_array_equal(a.adc_steps, b.adc_steps)

    def test_mask_has_k_winners(self):
        r = kwn.kwn_select(self.mac, 12, self.cb)
        np.testing.assert_array_equal(r.mask.sum(-1), 12.0)

    def test_winners_are_largest_codes(self):
        r = kwn.kwn_select(self.mac, 12, self.cb)
        codes_all = ima.ima_convert(self.mac, self.cb)
        kth = jnp.min(jnp.take_along_axis(codes_all, r.indices, -1), -1)
        losers = jnp.where(r.mask == 0, codes_all, -1)
        assert bool(jnp.all(jnp.max(losers, -1) <= kth))

    def test_early_stop_fewer_steps_small_k(self):
        s3 = kwn.kwn_select(self.mac, 3, self.cb).adc_steps
        s32 = kwn.kwn_select(self.mac, 32, self.cb).adc_steps
        assert bool(jnp.all(s3 <= s32))

    def test_latency_claims(self):
        d = kwn.lif_latency_updates(12, 128)
        assert d["speedup"] == pytest.approx(10.67, abs=0.1)  # paper: 10x


class TestLIF:
    def test_integrate_and_fire(self):
        st = lif.lif_init((4,))
        p = lif.LIFParams(beta=0.9, v_th1=1.0, noise_amp=0.0)
        drive = jnp.full((10, 4), 0.4)
        st2, spikes = lif.lif_run(st, drive, p)
        assert float(spikes.sum()) > 0  # must fire with sustained drive

    def test_hold_branch_eq1(self):
        # Eq. (1): non-winners keep V_mem exactly.
        st = lif.LIFState(jnp.array([0.3, 0.3]), prbs.lfsr_init(1))
        p = lif.LIFParams(noise_amp=0.0)
        mask = jnp.array([1.0, 0.0])
        st2, _ = lif.lif_step(st, jnp.array([0.2, 0.2]), p, update_mask=mask)
        assert st2.v_mem[1] == pytest.approx(0.3)
        assert st2.v_mem[0] == pytest.approx(0.9 * 0.3 + 0.2)

    def test_snl_noise_only_in_band(self):
        p = lif.LIFParams(v_th1=1.0, v_th2=0.6, noise_amp=0.05)
        st = lif.LIFState(jnp.array([0.1, 0.8]), prbs.lfsr_init(7))
        st2, _ = lif.lif_step(st, jnp.zeros(2), p,
                              update_mask=jnp.zeros(2), use_snl=True)
        assert st2.v_mem[0] == pytest.approx(0.1)          # below band: untouched
        assert abs(float(st2.v_mem[1]) - 0.8) == pytest.approx(0.05, abs=1e-6)

    def test_surrogate_grad_nonzero(self):
        g = jax.grad(lambda v: jnp.sum(lif.spike_fn(v, jnp.float32(1.0))))(
            jnp.array([0.9, 1.1]))
        assert float(jnp.sum(jnp.abs(g))) > 0

    def test_prbs_period_and_balance(self):
        st = prbs.lfsr_init(123)
        _, bits = prbs.prbs_bits(st, 2 ** 15 - 1)
        # Maximal-length PRBS-15: balanced within 1 bit over a full period.
        assert abs(int(bits.sum()) * 2 - (2 ** 15 - 1)) == 1


class TestMacroEnergy:
    def test_tiled_matches_dense_high_res(self):
        key = jax.random.PRNGKey(0)
        s = jnp.sign(jax.random.normal(key, (3, 600)))
        w = jnp.round(jax.random.normal(jax.random.PRNGKey(1), (600, 200)) * 2
                      ).clip(-3, 3)
        cfg = macro.CIMMacroConfig(code_bits=12, mac_range=1024.0)
        out, geo = macro.tiled_cim_mac(s, w, cfg)
        ref = s @ w
        assert geo.n_macros == 3 * 2
        np.testing.assert_allclose(out, ref, atol=2.0)

    def test_kwn_forward_sparsity(self):
        key = jax.random.PRNGKey(2)
        s = jnp.sign(jax.random.normal(key, (4, 256)))
        w = jnp.round(jax.random.normal(jax.random.PRNGKey(3), (256, 128)) * 2
                      ).clip(-3, 3)
        drive, mask, res = macro.kwn_forward(s, w, 12, macro.CIMMacroConfig())
        assert bool(jnp.all((drive != 0).sum(-1) <= 12))
        np.testing.assert_array_equal(mask.sum(-1), 12)

    def test_table1_energy_numbers(self):
        t = energy.table1_energy_entries()
        assert t["kwn_nmnist_pj_per_sop"] == pytest.approx(0.8, abs=0.05)
        assert t["kwn_dvs_pj_per_sop"] == pytest.approx(1.5, abs=0.08)
        assert t["nld_nmnist_pj_per_sop"] == pytest.approx(1.8, abs=0.09)
        assert t["nld_dvs_pj_per_sop"] == pytest.approx(2.3, abs=0.12)
        assert t["nld_quiroga_pj_per_sop"] == pytest.approx(2.1, abs=0.11)

    def test_sota_improvement(self):
        assert energy.improvement_vs_sota() == pytest.approx(1.6, abs=0.05)

    def test_early_stop_30pct_at_k12(self):
        assert energy.early_stop_saving(12) == pytest.approx(0.30, abs=0.01)

    def test_vdd_scaling_monotone(self):
        ee = energy.ee_vs_vdd()
        vals = [ee[f"{v:.1f}V"]["kwn_k3_nmnist"] for v in (0.7, 0.8, 0.9, 1.0)]
        assert vals == sorted(vals)  # best EE at lowest VDD (Fig. 9b)


class TestDendrite:
    def test_no_parameter_overhead(self):
        # Paper: NLD adds no parameter overhead vs dense (sparse branches).
        p = dendrite.dendrite_init(jax.random.PRNGKey(0), 256, 128, 4)
        n_syn = float(p.mask.sum())
        assert n_syn == pytest.approx(256 * 128, rel=0.1)

    def test_eq2_shapes_and_grad(self):
        p = dendrite.dendrite_init(jax.random.PRNGKey(1), 64, 32, 4)
        s = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (5, 64)))

        def loss(wd):
            out = dendrite.dendrite_mac(p._replace(w_dend=wd), s, f=ima.quadratic)
            return jnp.sum(out ** 2)

        g = jax.grad(loss)(p.w_dend)
        assert g.shape == (4, 32) and bool(jnp.all(jnp.isfinite(g)))

    def test_quantized_path_close_to_ideal(self):
        p = dendrite.dendrite_init(jax.random.PRNGKey(1), 64, 32, 2)
        s = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), (5, 64)))
        cb = ima.activation_codebook(7, ima.quadratic, -16, 16)
        ideal = dendrite.dendrite_mac(p, s, f=ima.quadratic)
        quant = dendrite.dendrite_mac(p, s, nl_cb=cb, quantize=True)
        # scale-aware: mean quantization error under 6% of the signal scale
        err = float(jnp.mean(jnp.abs(ideal - quant)))
        scale = float(jnp.max(jnp.abs(ideal)))
        assert err < 0.06 * scale, (err, scale)
