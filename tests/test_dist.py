"""Distribution-layer tests.

The MoE expert-parallel paths (a2a / 2D / dense-EP) must match the dense
reference numerically — run on 8 simulated host devices in a subprocess
(device count is locked at jax init, so the main test process stays at 1).
Sharding-rule unit tests run in-process.

Triage note (PR 2): the long-standing failure here was NOT a numerical
tolerance issue — the subprocess crashed at mesh construction on jax
versions without ``jax.sharding.AxisType`` / ``jax.shard_map`` before any
comparison ran.  With the ``repro.compat`` shims, all four EP paths match
the dense reference within the original 2e-4 tolerances on both jax
generations; no tolerance was loosened and no accumulation-order change was
needed.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.nn import module

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_MOE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat
    from repro.nn import moe, module

    mesh = compat.make_mesh((2, 4), ("data", "model"))
    E, D, F, K = 8, 16, 32, 2
    B, S = 4, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    p = {
        "router": jax.random.normal(ks[0], (D, E)) * 0.5,
        "w_in": jax.random.normal(ks[1], (E, D, F)) / jnp.sqrt(D),
        "w_gate": jax.random.normal(ks[2], (E, D, F)) / jnp.sqrt(D),
        "w_out": jax.random.normal(ks[3], (E, F, D)) / jnp.sqrt(F),
    }
    x = jax.random.normal(ks[4], (B, S, D))

    ref, aux_ref = moe.moe_ref(p, x, k=K)

    # capacity high enough that nothing drops -> exact match expected
    y1, aux1 = jax.jit(lambda p, x: moe.moe_a2a(
        p, x, k=K, mesh=mesh, capacity_factor=8.0))(p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    # aux is a shard-local estimator of the global balance loss: same scale,
    # not bitwise equal (mean-of-shard-products vs global product).
    assert abs(float(aux1) - float(aux_ref)) / float(aux_ref) < 0.5
    print("moe_a2a OK")

    y2, aux2 = jax.jit(lambda p, x: moe.moe_2d(
        p, x, k=K, mesh=mesh, capacity_factor=8.0,
        expert_axes=("data",)))(p, x)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("moe_2d OK")

    y3, aux3 = jax.jit(lambda p, x: moe.moe_dense_ep(
        p, x, k=K, mesh=mesh))(p, x)
    np.testing.assert_allclose(np.asarray(y3), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("moe_dense_ep OK")

    y4, aux4 = jax.jit(lambda p, x: moe.moe_dense_ep_2d(
        p, x, k=K, mesh=mesh, expert_axes=("data",)))(p, x)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("moe_dense_ep_2d OK")

    # gradients flow through the a2a path
    def loss(p):
        y, aux = moe.moe_2d(p, x, k=K, mesh=mesh, capacity_factor=8.0,
                            expert_axes=("data",))
        return jnp.sum(y ** 2) + 0.01 * aux
    g = jax.jit(jax.grad(loss))(p)
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))
    gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert gn > 0
    print("moe grads OK")
""")


def test_moe_ep_paths_match_reference():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", _MOE_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    for tag in ("moe_a2a OK", "moe_2d OK", "moe_dense_ep OK",
                "moe_dense_ep_2d OK", "moe grads OK"):
        assert tag in r.stdout


class TestShardingRules:
    def _mesh(self):
        return jax.make_mesh((1,), ("model",))

    def test_divisibility_fallback(self):
        mesh = jax.make_mesh((1,), ("model",))
        # 7 not divisible by anything > 1 -> always falls back cleanly
        spec = module.partition_spec((7, 8), ("vocab", "ffn"), mesh, {})
        assert spec == jax.sharding.PartitionSpec("model",) or True

    def test_no_axis_reuse(self):
        mesh = jax.make_mesh((1,), ("model",))
        spec = module.partition_spec((8, 8), ("vocab", "ffn"), mesh, {})
        used = [s for s in spec if s is not None]
        assert len(used) == len(set(used))

    def test_multi_axis_rule(self):
        mesh = jax.make_mesh((1,), ("data",))
        spec = module.partition_spec(
            (16,), ("batch",), mesh, {"batch": ("pod", "data")})
        # pod missing from mesh -> silently dropped
        assert spec in (jax.sharding.PartitionSpec("data"),
                        jax.sharding.PartitionSpec(("data",)))

    def test_batch_one_unshardable(self):
        # a size-1 mesh axis trivially divides everything (no-op sharding);
        # what matters is that a >1 axis is never forced onto batch=1 — that
        # path is exercised by the long_500k dry-run cells (real 16-way mesh).
        mesh = jax.make_mesh((1,), ("data",))
        spec = module.partition_spec((1, 128), ("batch", None), mesh, {})
        assert spec in (jax.sharding.PartitionSpec(),
                        jax.sharding.PartitionSpec("data"))


class TestParamSpecs:
    def test_abstract_matches_materialize(self):
        from repro.configs import ARCHS
        from repro.configs.base import reduced
        from repro.models import lm
        cfg = reduced(ARCHS["gemma2-2b"])
        specs = lm.param_specs(cfg)
        abs_tree = module.abstract(specs)
        mat = module.materialize(specs, jax.random.PRNGKey(0))
        ja, jm = jax.tree.leaves(abs_tree), jax.tree.leaves(mat)
        assert len(ja) == len(jm)
        for a, m in zip(ja, jm):
            assert a.shape == m.shape and a.dtype == m.dtype


class TestOptimizedProfile:
    def test_optimized_profile_smoke(self):
        """The §Perf-accepted knobs must train on every family (reduced)."""
        import dataclasses
        import jax
        import jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.configs.base import optimized, reduced
        from repro.models import lm
        from repro.train import optim
        for arch in ("kimi-k2-1t-a32b", "nemotron-4-340b"):
            cfg = optimized(reduced(ARCHS[arch]))
            # reduced configs have remat off; re-enable to exercise the policy
            cfg = dataclasses.replace(cfg, remat=True)
            params = module.materialize(lm.param_specs(cfg),
                                        jax.random.PRNGKey(0))
            batch = {"tokens": jax.random.randint(
                jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)}
            loss, grads = jax.value_and_grad(
                lambda p: lm.loss_fn(p, batch, cfg)[0])(params)
            assert bool(jnp.isfinite(loss))
            assert float(optim.global_norm(grads)) > 0
