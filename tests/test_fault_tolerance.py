"""Fault-tolerance tests: atomic checkpointing, crash-resume bitwise
continuity (failure injection via subprocess hard-exit), elastic resharding,
and gradient compression."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint, compression

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.int32)}}
        checkpoint.save(str(tmp_path), 5, {"state": tree})
        out, meta = checkpoint.restore(str(tmp_path), 5, {"state": tree})
        assert meta["step"] == 5
        np.testing.assert_array_equal(out["state"]["a"], tree["a"])
        np.testing.assert_array_equal(out["state"]["b"]["c"], tree["b"]["c"])

    def test_keep_n_gc(self, tmp_path):
        tree = {"x": jnp.zeros(3)}
        for s in range(6):
            checkpoint.save(str(tmp_path), s, {"s": tree}, keep_n=2)
        assert checkpoint.all_steps(str(tmp_path)) == [4, 5]

    def test_atomicity_partial_write_invisible(self, tmp_path):
        # a stale temp dir (crashed save) must not be listed or loaded
        tree = {"x": jnp.zeros(3)}
        checkpoint.save(str(tmp_path), 1, {"s": tree})
        os.makedirs(tmp_path / ".tmp_step_2_junk")
        (tmp_path / ".tmp_step_2_junk" / "s.npz").write_bytes(b"garbage")
        os.makedirs(tmp_path / "step_3")  # no meta.json -> incomplete
        assert checkpoint.all_steps(str(tmp_path)) == [1]
        assert checkpoint.latest_step(str(tmp_path)) == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        checkpoint.save(str(tmp_path), 1, {"s": {"x": jnp.zeros((2, 3))}})
        with pytest.raises(ValueError):
            checkpoint.restore(str(tmp_path), 1, {"s": {"x": jnp.zeros((3, 3))}})

    def test_elastic_restore_with_shardings(self, tmp_path):
        # restore onto an explicit (degenerate) mesh sharding — the elastic
        # rescale path; on 1 device this exercises the device_put branch.
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((1,), ("data",))
        tree = {"w": jnp.arange(8.0).reshape(2, 4)}
        checkpoint.save(str(tmp_path), 2, {"params": tree})
        sh = {"params": {"w": NamedSharding(mesh, P("data", None))}}
        out, _ = checkpoint.restore(str(tmp_path), 2, {"params": tree}, sh)
        np.testing.assert_array_equal(out["params"]["w"], tree["w"])
        assert out["params"]["w"].sharding == sh["params"]["w"]


class TestCrashResume:
    def _run(self, ckpt_dir, metrics, steps=8, crash_at=-1):
        cmd = [sys.executable, "-m", "repro.launch.train",
               "--arch", "smollm-135m", "--smoke",
               "--steps", str(steps), "--global-batch", "4",
               "--seq-len", "32", "--n-micro", "2",
               "--ckpt-dir", ckpt_dir, "--ckpt-every", "2",
               "--metrics-out", metrics,
               "--crash-at-step", str(crash_at)]
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        return subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=540)

    def test_crash_restart_bitwise_resume(self, tmp_path):
        # golden: uninterrupted run
        gold = self._run(str(tmp_path / "gold"), str(tmp_path / "gold.json"))
        assert gold.returncode == 0, gold.stderr[-2000:]
        # crashed run: SIGKILL-style exit at step 5 (after ckpt at step 4)
        r1 = self._run(str(tmp_path / "ft"), str(tmp_path / "ft1.json"),
                       crash_at=5)
        assert r1.returncode == 42
        assert checkpoint.latest_step(str(tmp_path / "ft")) == 4
        # restart: must resume from step 4 and reproduce the golden losses
        r2 = self._run(str(tmp_path / "ft"), str(tmp_path / "ft2.json"))
        assert r2.returncode == 0, r2.stderr[-2000:]
        gold_h = json.load(open(tmp_path / "gold.json"))
        resumed = json.load(open(tmp_path / "ft2.json"))
        gold_by_step = {h["step"]: h["loss"] for h in gold_h}
        assert resumed[0]["step"] == 4
        for h in resumed:
            assert h["loss"] == pytest.approx(gold_by_step[h["step"]],
                                              rel=1e-6), \
                f"divergence at step {h['step']}"


class TestCompression:
    def test_roundtrip_error_bounded(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
        comp, err = compression.compress(g)
        out = compression.decompress(comp)
        scale = float(comp.scale["w"])
        assert float(jnp.max(jnp.abs(out["w"] - g["w"]))) <= scale * 0.5 + 1e-6

    def test_error_feedback_reduces_bias(self):
        # with error feedback, the accumulated compressed sum tracks the true
        # gradient sum much more closely than without
        key = jax.random.PRNGKey(1)
        efb = {"w": jnp.zeros((32, 32))}
        acc_fb, acc_raw, acc_true = (jnp.zeros((32, 32)),) * 3
        for i in range(20):
            key, sub = jax.random.split(key)
            g = {"w": jax.random.normal(sub, (32, 32)) * 0.01 + 0.005}
            comp_fb, efb = compression.compress(g, efb)
            comp_raw, _ = compression.compress(g)
            acc_fb = acc_fb + compression.decompress(comp_fb)["w"]
            acc_raw = acc_raw + compression.decompress(comp_raw)["w"]
            acc_true = acc_true + g["w"]
        err_fb = float(jnp.mean(jnp.abs(acc_fb - acc_true)))
        err_raw = float(jnp.mean(jnp.abs(acc_raw - acc_true)))
        assert err_fb <= err_raw * 1.05

    def test_wire_ratio(self):
        g = {"w": jnp.zeros((128, 128))}
        assert compression.compression_ratio(g) == 0.25


class TestDataPipeline:
    def test_stateless_by_step(self):
        from repro.data.synthetic_lm import DataConfig, SyntheticLM
        d1 = SyntheticLM(DataConfig(256, 64, 8, seed=3))
        d2 = SyntheticLM(DataConfig(256, 64, 8, seed=3))
        b1 = d1.batch_at(17)
        b2 = d2.batch_at(17)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = d1.batch_at(18)
        assert not np.array_equal(b1["tokens"], b3["tokens"])
