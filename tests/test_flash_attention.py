"""Flash-attention Pallas kernel: allclose vs the naive oracle across
shape/dtype/causality sweeps + the causal block-skip accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (causal_flops_saving,
                                           flash_attention_fwd)


def _naive(q, k, v, causal):
    s = q.shape[1]
    d = q.shape[-1]
    sc = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / jnp.sqrt(d)
    if causal:
        m = jnp.tril(jnp.ones((s, s), bool))
        sc = jnp.where(m[None], sc, -1e30)
    return jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(sc, -1),
                      v.astype(jnp.float32))


@pytest.mark.parametrize("s,bq,bk", [(128, 32, 32), (256, 64, 64),
                                     (128, 64, 32), (192, 64, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_matches_naive(s, bq, bk, causal):
    if s % bq or s % bk:
        pytest.skip("non-divisible")
    key = jax.random.PRNGKey(s + bq)
    q, k, v = [jax.random.normal(jax.random.fold_in(key, i), (2, s, 16))
               for i in range(3)]
    out = flash_attention_fwd(q, k, v, causal=causal, bq=bq, bk=bk)
    ref = _naive(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_bf16_inputs():
    key = jax.random.PRNGKey(9)
    q, k, v = [jax.random.normal(jax.random.fold_in(key, i), (2, 128, 32)
                                 ).astype(jnp.bfloat16) for i in range(3)]
    out = flash_attention_fwd(q, k, v, causal=True, bq=64, bk=64)
    ref = _naive(q, k, v, True)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(ref), rtol=2e-2, atol=2e-2)


def test_causal_block_saving_approaches_half():
    # at 32k with 512-blocks the skip fraction is within 1% of the S^2/2 ideal
    assert causal_flops_saving(32768, 512, 512) == pytest.approx(0.5, abs=0.01)
    assert causal_flops_saving(4096, 1024, 1024) == pytest.approx(0.375,
                                                                  abs=0.01)


def test_numerical_stability_large_logits():
    key = jax.random.PRNGKey(11)
    q, k, v = [jax.random.normal(jax.random.fold_in(key, i), (1, 128, 16)) * 30
               for i in range(3)]
    out = flash_attention_fwd(q, k, v, causal=True, bq=32, bk=32)
    assert bool(jnp.all(jnp.isfinite(out)))
    ref = _naive(q, k, v, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
