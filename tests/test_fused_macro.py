"""Fused macro-step kernel vs the composed ref.py oracle.

The fused kernel (MAC -> IMA ramp -> KWN/NLD head -> LIF in one Pallas
kernel, interpret=True on CPU CI) must match ``ref.fused_macro_step_ref``
*bitwise* at f32 accumulation: the MAC partials are small exact integers and
the head mirrors the oracle operation-for-operation.  The oracle is jitted so
both sides get identical XLA arithmetic contraction (FMA) treatment.

Covers: both modes (kwn/nld), all three IMA curves (linear / NLQ /
NL-activation), odd shapes (n_in not a multiple of 256, n_out not a multiple
of 128, batch not a multiple of 8), SNL on/off, multi-macro tiling (layers
wider than 256x128 stay fused — no composed-path fallback), time-major
sequences (T folded into the kernel grid, membrane carried in VMEM), and the
model/serving layers built on top (forward_silicon(fused=True/"step"/"seq"),
SNNEventEngine time-major batching).  The exhaustive shape sweeps live in
tests/test_fused_macro_properties.py.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ima as ima_lib
from repro.core import macro as macro_lib
from repro.kernels import ops, ref


def _tern(key, shape, rate=0.2):
    sparse = jax.random.uniform(jax.random.fold_in(key, 1), shape) < rate
    vals = jax.random.randint(key, shape, -1, 2)
    return (vals * sparse).astype(jnp.int8)


def _codebook(kind, bits=5, rng=24.0):
    if kind == "lin":
        return ima_lib.linear_codebook(bits, -rng, rng)
    if kind == "nlq":
        return ima_lib.nlq_codebook(bits, -rng, rng)
    return ima_lib.activation_codebook(bits, ima_lib.quadratic, -rng, rng)


def _ref_jit(**static):
    return jax.jit(functools.partial(ref.fused_macro_step_ref, **static))


def _assert_bitwise(out, want, n):
    names = ("mac", "v_mem", "spikes", "mask", "adc_steps")
    want = list(want)
    want[4] = want[4][..., 0]
    for name, a, b in zip(names, out, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{name} mismatch")


class TestFusedKwnParity:
    @pytest.mark.parametrize("m,n_in,n_out", [
        (16, 256, 128),           # one physical macro
        (128, 512, 128),          # two row tiles
        (9, 256, 128),            # batch padding
        (16, 300, 130),           # n_in % 256 != 0, n_out % 128 != 0
        (5, 100, 40),             # tiny odd everything
    ])
    @pytest.mark.parametrize("curve", ["lin", "nlq"])
    def test_matches_ref(self, m, n_in, n_out, curve):
        keys = jax.random.split(jax.random.PRNGKey(m * 31 + n_in + n_out), 6)
        x = _tern(keys[0], (m, n_in))
        msb, lsb = _tern(keys[1], (n_in, n_out)), _tern(keys[2], (n_in, n_out))
        cb = _codebook(curve)
        scale = jax.random.uniform(keys[3], (n_out,), minval=0.05, maxval=0.3)
        v = jax.random.normal(keys[4], (m, n_out)) * 0.5
        noise = 0.05 * jnp.sign(jax.random.normal(keys[5], (m, n_out)))
        k = min(12, n_out)
        kw = dict(mode="kwn", k=k, drive_gain=0.25)
        out = ops.fused_macro_step(x, msb, lsb, cb.boundaries, cb.levels,
                                   scale, v, noise, **kw)
        want = _ref_jit(**kw)(x, msb, lsb, cb.boundaries, cb.levels, scale,
                              v, noise)
        _assert_bitwise(out, want, n_out)

    @pytest.mark.parametrize("k", [1, 3, 12, 127])
    def test_k_sweep(self, k):
        keys = jax.random.split(jax.random.PRNGKey(k), 6)
        x = _tern(keys[0], (16, 256))
        msb, lsb = _tern(keys[1], (256, 128)), _tern(keys[2], (256, 128))
        cb = _codebook("nlq")
        scale = jax.random.uniform(keys[3], (128,), minval=0.05, maxval=0.3)
        v = jax.random.normal(keys[4], (16, 128)) * 0.5
        noise = 0.05 * jnp.sign(jax.random.normal(keys[5], (16, 128)))
        kw = dict(mode="kwn", k=k, drive_gain=0.25)
        out = ops.fused_macro_step(x, msb, lsb, cb.boundaries, cb.levels,
                                   scale, v, noise, **kw)
        want = _ref_jit(**kw)(x, msb, lsb, cb.boundaries, cb.levels, scale,
                              v, noise)
        _assert_bitwise(out, want, 128)
        assert bool(jnp.all(out[3].sum(-1) == k))

    @pytest.mark.parametrize("use_snl", [True, False])
    def test_snl_toggle(self, use_snl):
        keys = jax.random.split(jax.random.PRNGKey(7), 6)
        x = _tern(keys[0], (16, 256))
        msb, lsb = _tern(keys[1], (256, 128)), _tern(keys[2], (256, 128))
        cb = _codebook("nlq")
        scale = jax.random.uniform(keys[3], (128,), minval=0.1, maxval=0.3)
        # park membranes inside the SNL band so the toggle matters
        v = 0.8 * jnp.ones((16, 128))
        noise = 0.3 * jnp.sign(jax.random.normal(keys[5], (16, 128)))
        kw = dict(mode="kwn", k=12, drive_gain=0.25, use_snl=use_snl)
        out = ops.fused_macro_step(x, msb, lsb, cb.boundaries, cb.levels,
                                   scale, v, noise, **kw)
        want = _ref_jit(**kw)(x, msb, lsb, cb.boundaries, cb.levels, scale,
                              v, noise)
        _assert_bitwise(out, want, 128)

    def test_batched_leading_dims(self):
        keys = jax.random.split(jax.random.PRNGKey(3), 6)
        x = _tern(keys[0], (2, 5, 256))
        msb, lsb = _tern(keys[1], (256, 128)), _tern(keys[2], (256, 128))
        cb = _codebook("nlq")
        scale = jnp.full((128,), 0.1)
        v = jax.random.normal(keys[4], (2, 5, 128)) * 0.5
        noise = jnp.zeros((2, 5, 128))
        out = ops.fused_macro_step(x, msb, lsb, cb.boundaries, cb.levels,
                                   scale, v, noise, mode="kwn", k=12)
        assert out[1].shape == (2, 5, 128) and out[4].shape == (2, 5)
        flat = ops.fused_macro_step(x.reshape(10, 256), msb, lsb,
                                    cb.boundaries, cb.levels, scale,
                                    v.reshape(10, 128),
                                    noise.reshape(10, 128), mode="kwn", k=12)
        np.testing.assert_array_equal(np.asarray(out[1]).reshape(10, 128),
                                      np.asarray(flat[1]))


class TestFusedSeqParity:
    """Tiled multi-macro + time-major acceptance: big layers and long
    streams run through the fused path bitwise-equal to the seq oracle."""

    def _operands(self, t, m, n_in, n_out, seed=0):
        keys = jax.random.split(jax.random.PRNGKey(seed), 6)
        x = _tern(keys[0], (t, m, n_in))
        msb = _tern(keys[1], (n_in, n_out))
        lsb = _tern(keys[2], (n_in, n_out))
        cb = _codebook("nlq")
        scale = jax.random.uniform(keys[3], (n_out,), minval=0.05,
                                   maxval=0.3)
        v = jax.random.normal(keys[4], (m, n_out)) * 0.5
        noise = 0.05 * jnp.sign(jax.random.normal(keys[5], (t, m, n_out)))
        return x, msb, lsb, cb, scale, v, noise

    def _assert_seq(self, t, m, n_in, n_out):
        x, msb, lsb, cb, scale, v, noise = self._operands(t, m, n_in, n_out)
        kw = dict(mode="kwn", k=12, drive_gain=0.25)
        out = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                  scale, v, noise, **kw)
        want = jax.jit(functools.partial(ref.fused_macro_seq_ref, **kw))(
            x, msb, lsb, cb.boundaries, cb.levels, scale, v, noise)
        want = list(want)
        want[4] = want[4][..., 0]
        for name, a, b in zip(("mac", "v_mem", "spikes", "mask",
                               "adc_steps"), out, want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{name} mismatch")

    def test_large_layer_stays_fused(self):
        """M>128 (two row tiles), K>256 (two K tiles), N>128 (two col
        tiles): the whole virtual macro grid runs inside one kernel."""
        self._assert_seq(t=2, m=144, n_in=512, n_out=256)

    def test_long_stream_time_major(self):
        """T=16 event stream in a single launch, membrane carried in
        VMEM."""
        self._assert_seq(t=16, m=16, n_in=256, n_out=128)

    def test_long_stream_large_layer(self):
        """Both at once: the acceptance shape for this PR."""
        self._assert_seq(t=16, m=8, n_in=512, n_out=256)

    def test_t1_seq_equals_step(self):
        """T=1 degenerate: seq and step entry points agree bitwise."""
        x, msb, lsb, cb, scale, v, noise = self._operands(1, 16, 256, 128)
        kw = dict(mode="kwn", k=12, drive_gain=0.25)
        seq = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                  scale, v, noise, **kw)
        step = ops.fused_macro_step(x[0], msb, lsb, cb.boundaries,
                                    cb.levels, scale, v, noise[0], **kw)
        np.testing.assert_array_equal(np.asarray(seq[0][0]),
                                      np.asarray(step[0]))
        np.testing.assert_array_equal(np.asarray(seq[1]),
                                      np.asarray(step[1]))
        np.testing.assert_array_equal(np.asarray(seq[4][0]),
                                      np.asarray(step[4]))


class TestTilePlanner:
    """plan_tiles / plan_fused_tiles: padded geometry the kernel asserts
    on, branch-aligned NLD padding, and the macro accounting the energy
    model consumes."""

    def test_single_macro_is_one_tile(self):
        from repro.kernels import fused_macro
        plan = fused_macro.plan_tiles(16, 256, 128, 128, t=4)
        assert plan.grid == (1, 4, 1, 1)
        assert plan.bn == 128 and plan.nc_pad == 128 and plan.n_pad == 128

    def test_large_layer_grid_and_divisibility(self):
        from repro.kernels import fused_macro
        plan = fused_macro.plan_tiles(144, 512, 256, 256, t=2)
        assert plan.grid == (2, 2, 2, 2)
        assert plan.m_pad % plan.bm == 0
        assert plan.k_pad % plan.bk == 0
        assert plan.nc_pad % plan.bn == 0
        assert plan.n_valid == 256
        assert plan.vmem_resident_bytes > 0

    def test_nld_padding_is_branch_aligned(self):
        from repro.kernels import fused_macro
        # J=3 branches, n=130: nc=390 > 128 so columns tile; padding must
        # keep J * n_pad a multiple of bn so tiles never split a ragged pad
        plan = fused_macro.plan_tiles(8, 256, 390, 130, mode="nld",
                                      n_branches=3)
        assert plan.nc_pad == 3 * plan.n_pad
        assert plan.nc_pad % plan.bn == 0
        assert plan.n_pad >= 130

    def test_macro_plan_counts_physical_macros(self):
        cb = _codebook("nlq")
        fw = macro_lib.FusedMacroWeights(
            msb=jnp.zeros((512, 256), jnp.int8),
            lsb=jnp.zeros((512, 256), jnp.int8),
            scale=jnp.ones((256,)), boundaries=cb.boundaries,
            levels=cb.levels, w_dend=None, mode="kwn")
        plan, geo = macro_lib.plan_fused_tiles(128, fw, 256, n_steps=16)
        assert geo.n_macros == 4                 # 2 row x 2 col 256x128 tiles
        assert plan.grid == (1, 16, 2, 2)


class TestFusedNldParity:
    @pytest.mark.parametrize("m,n_in,n_out,j", [
        (16, 256, 128, 2),
        (16, 300, 130, 2),        # odd shapes
        (9, 256, 64, 3),          # three branches, batch padding
    ])
    @pytest.mark.parametrize("act", ["quadratic", "relu"])
    def test_matches_ref(self, m, n_in, n_out, j, act):
        keys = jax.random.split(jax.random.PRNGKey(m + n_out + j), 7)
        x = _tern(keys[0], (m, n_in))
        msb = _tern(keys[1], (n_in, j * n_out))
        lsb = _tern(keys[2], (n_in, j * n_out))
        cb = ima_lib.activation_codebook(
            5, ima_lib.DENDRITE_ACTIVATIONS[act], -4.0, 4.0)
        scale = jax.random.uniform(keys[3], (j * n_out,), minval=0.01,
                                   maxval=0.05)
        w_dend = jax.random.normal(keys[4], (j, n_out)) / np.sqrt(j)
        v = jax.random.normal(keys[5], (m, n_out)) * 0.5
        noise = jnp.zeros((m, n_out))
        kw = dict(mode="nld", drive_gain=0.25)
        out = ops.fused_macro_step(x, msb, lsb, cb.boundaries, cb.levels,
                                   scale, v, noise, w_dend=w_dend, **kw)
        want = _ref_jit(**kw)(x, msb, lsb, cb.boundaries, cb.levels, scale,
                              v, noise, w_dend)
        _assert_bitwise(out, want, n_out)
        # NLD: dense LIF update, full ramp every step
        np.testing.assert_array_equal(np.asarray(out[3]),
                                      np.ones((m, n_out), np.float32))
        np.testing.assert_array_equal(np.asarray(out[4]),
                                      np.full((m,), 31, np.int32))


class TestForwardSiliconFused:
    """The model-level wiring: fused scan body == composed scan body."""

    def _setup(self, mode):
        from repro.data import events as ev_lib
        from repro.models import snn
        dcfg = ev_lib.NMNIST
        ds = ev_lib.EventDataset(dcfg)
        cfg = snn.SNNConfig(n_in=dcfg.n_in, n_steps=dcfg.n_steps,
                            n_classes=dcfg.n_classes, mode=mode, k=12)
        p = snn.init_params(cfg, jax.random.PRNGKey(0))
        ev, _ = ds.sample(jax.random.PRNGKey(1), 8)
        return snn, p, ev, cfg

    @pytest.mark.parametrize("use_snl", [True, False])
    def test_kwn_bitwise_vs_composed(self, use_snl):
        snn, p, ev, cfg = self._setup("kwn")
        key = jax.random.PRNGKey(2)
        lc, tc = snn.forward_silicon(p, ev, cfg, key, use_snl=use_snl)
        lf, tf = snn.forward_silicon(p, ev, cfg, key, use_snl=use_snl,
                                     fused=True)
        np.testing.assert_array_equal(np.asarray(lc), np.asarray(lf))
        for name in tc:
            np.testing.assert_array_equal(np.asarray(tc[name]),
                                          np.asarray(tf[name]),
                                          err_msg=f"telemetry {name}")

    def test_step_and_seq_paths_agree(self):
        """Per-step launches vs one time-major launch: bitwise-identical
        logits and telemetry (time-major batching is invisible)."""
        snn, p, ev, cfg = self._setup("kwn")
        key = jax.random.PRNGKey(2)
        ls, ts = snn.forward_silicon(p, ev, cfg, key, fused="step")
        lq, tq = snn.forward_silicon(p, ev, cfg, key, fused="seq")
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lq))
        for name in ts:
            np.testing.assert_array_equal(np.asarray(ts[name]),
                                          np.asarray(tq[name]),
                                          err_msg=f"telemetry {name}")

    def test_nld_runs_and_reports_full_ramp(self):
        snn, p, ev, cfg = self._setup("nld")
        logits, tele = snn.forward_silicon(p, ev, cfg, jax.random.PRNGKey(2),
                                           fused=True)
        assert logits.shape == (8, cfg.n_classes)
        np.testing.assert_allclose(np.asarray(tele["adc_steps"]), 31.0)
        np.testing.assert_allclose(np.asarray(tele["lif_updates"]), 128.0)

    def test_noise_model_stays_fused(self):
        """noise=IMANoiseModel() no longer forces the composed path: the
        noisy step and seq cadences draw the identical in-kernel counter
        stream (bitwise-equal logits), and the draws actually perturb the
        clean result.  Full noisy-oracle parity: tests/test_ima_noise.py."""
        snn, p, ev, cfg = self._setup("kwn")
        noisy = ima_lib.IMANoiseModel()
        la, ta = snn.forward_silicon(p, ev, cfg, jax.random.PRNGKey(2),
                                     noise=noisy, fused="step")
        lb, tb = snn.forward_silicon(p, ev, cfg, jax.random.PRNGKey(2),
                                     noise=noisy, fused="seq")
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        for name in ta:
            np.testing.assert_array_equal(np.asarray(ta[name]),
                                          np.asarray(tb[name]),
                                          err_msg=f"telemetry {name}")
        clean, _ = snn.forward_silicon(p, ev, cfg, jax.random.PRNGKey(2),
                                       fused="seq")
        assert not np.array_equal(np.asarray(lb), np.asarray(clean))


class TestSNNEventEngine:
    def test_serves_queue_matches_direct_forward(self):
        from repro.data import events as ev_lib
        from repro.models import snn
        from repro.serve.engine import EventRequest, SNNEventEngine
        dcfg = ev_lib.NMNIST
        ds = ev_lib.EventDataset(dcfg)
        cfg = snn.SNNConfig(n_in=dcfg.n_in, n_steps=dcfg.n_steps,
                            n_classes=dcfg.n_classes, mode="kwn", k=12)
        p = snn.init_params(cfg, jax.random.PRNGKey(0))
        ev, lab = ds.sample(jax.random.PRNGKey(1), 10)

        # pack_by_density=False: this test pins the FIFO batch composition
        # so the direct-forward recomputation below sees the same batch
        # (density packing itself is covered in tests/test_fused_sparsity.py);
        # continuous=False pins the legacy drain path's per-batch key stream
        engine = SNNEventEngine(cfg, p, batch_slots=4, seed=5,
                                pack_by_density=False, continuous=False)
        for i in range(10):   # 2 full batches + 1 partial (padding path)
            engine.submit(EventRequest(uid=i, events=ev[i], label=int(lab[i])))
        done = engine.run()
        assert len(done) == 10 and not engine.pending
        assert all(r.pred is not None and 0 <= r.pred < cfg.n_classes
                   for r in done)
        assert all(0.0 <= r.adc_steps <= 31.0 for r in done)
        assert all(0.0 <= r.skipped_block_ratio <= 1.0 for r in done)
        assert all(0.0 <= r.density <= 1.0 for r in done)

        # padded dummy rows must not perturb real requests: recompute one
        # batch directly with the same key sequence
        key = jax.random.split(jax.random.PRNGKey(5))[1]
        full = jnp.stack([jnp.asarray(ev[i]) for i in range(4)])
        logits, _ = jax.jit(lambda pp, e, kk: snn.forward_silicon(
            pp, e, cfg, kk, fused=True))(p, full, key)
        np.testing.assert_array_equal(np.asarray(logits[0]),
                                      np.asarray(done[0].logits))

        rep = engine.energy_report("nmnist")
        assert rep["requests"] == 10 and rep["pj_per_sop"] > 0

    def test_time_major_and_per_step_engines_agree(self):
        from repro.data import events as ev_lib
        from repro.models import snn
        from repro.serve.engine import EventRequest, SNNEventEngine
        dcfg = ev_lib.NMNIST
        ds = ev_lib.EventDataset(dcfg)
        cfg = snn.SNNConfig(n_in=dcfg.n_in, n_steps=dcfg.n_steps,
                            n_classes=dcfg.n_classes, mode="kwn", k=12)
        p = snn.init_params(cfg, jax.random.PRNGKey(0))
        ev, lab = ds.sample(jax.random.PRNGKey(1), 3)

        results = {}
        for time_major in (True, False):
            # continuous=False: the per-step cadence has no continuous
            # path, and batch-level PRBS threading only matches between
            # the two legacy cadences
            engine = SNNEventEngine(cfg, p, batch_slots=4, seed=5,
                                    time_major=time_major, continuous=False)
            for i in range(3):
                engine.submit(EventRequest(uid=i, events=ev[i],
                                           label=int(lab[i])))
            results[time_major] = engine.run()
        for a, b in zip(results[True], results[False]):
            np.testing.assert_array_equal(np.asarray(a.logits),
                                          np.asarray(b.logits))
            assert a.pred == b.pred and a.adc_steps == b.adc_steps
