"""Property-based parity for the tiled, time-major fused macro kernel.

Three oracles pin the kernel down (``kernels/ref.py``):

* ``fused_macro_step_ref``  — composed single-step semantics;
* ``fused_macro_tiled_ref`` — explicit digital partial-sum tiling, must be
  bitwise-identical to the untiled oracle for ANY (bk, bn) because every
  MAC partial is a small exact integer (associativity-free in f32);
* ``fused_macro_seq_ref``   — left-fold of the step oracle over T.

The hypothesis strategies sweep modes (kwn/nld), ramp curves (linear / NLQ /
NL-activation), odd M/K/N/T (non-multiples of bm/bk/bn included) and the
T=1 degenerate; the seeded sweep below them re-runs a fixed sample of the
same space so the parity property is exercised even on images without
hypothesis (where ``@given`` tests skip via tests/_hypothesis_compat.py).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ima as ima_lib
from repro.kernels import ops, ref
from tests._hypothesis_compat import given, settings, st


def _tern(key, shape, rate=0.25):
    sparse = jax.random.uniform(jax.random.fold_in(key, 1), shape) < rate
    vals = jax.random.randint(key, shape, -1, 2)
    return (vals * sparse).astype(jnp.int8)


def _codebook(curve, mode):
    if mode == "nld":
        return ima_lib.activation_codebook(5, ima_lib.quadratic, -4.0, 4.0)
    if curve == "lin":
        return ima_lib.linear_codebook(5, -24.0, 24.0)
    return ima_lib.nlq_codebook(5, -24.0, 24.0)


def _operands(seed, t, m, n_in, n_out, mode, curve, j=2):
    keys = jax.random.split(jax.random.PRNGKey(seed), 7)
    nc = n_out if mode == "kwn" else j * n_out
    x = _tern(keys[0], (t, m, n_in))
    msb, lsb = _tern(keys[1], (n_in, nc)), _tern(keys[2], (n_in, nc))
    cb = _codebook(curve, mode)
    hi = 0.3 if mode == "kwn" else 0.05
    scale = jax.random.uniform(keys[3], (nc,), minval=0.01, maxval=hi)
    v = jax.random.normal(keys[4], (m, n_out)) * 0.5
    noise = 0.05 * jnp.sign(jax.random.normal(keys[5], (t, m, n_out)))
    w_dend = (None if mode == "kwn"
              else jax.random.normal(keys[6], (j, n_out)) / np.sqrt(j))
    return x, msb, lsb, cb, scale, v, noise, w_dend


def _assert_seq_matches_oracle(seed, t, m, n_in, n_out, mode, curve, k, j=2):
    x, msb, lsb, cb, scale, v, noise, w_dend = _operands(
        seed, t, m, n_in, n_out, mode, curve, j)
    kw = dict(mode=mode, k=min(k, n_out), drive_gain=0.25)
    out = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels, scale,
                              v, noise, w_dend=w_dend, **kw)
    want = jax.jit(functools.partial(ref.fused_macro_seq_ref, **kw))(
        x, msb, lsb, cb.boundaries, cb.levels, scale, v, noise, w_dend)
    want = list(want)
    want[4] = want[4][..., 0]
    for name, a, b in zip(("mac", "v_mem", "spikes", "mask", "adc_steps"),
                          out, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{name} mismatch @ seed="
                                              f"{seed} t={t} m={m} "
                                              f"k_in={n_in} n={n_out} "
                                              f"{mode}/{curve}")
    if mode == "kwn":
        # KWN invariants: exactly min(k, n) winners; steps inside the ramp.
        mask = np.asarray(out[3])
        assert (mask.sum(-1) == min(k, n_out)).all()
        steps = np.asarray(out[4])
        assert ((steps >= 0) & (steps <= cb.n_codes - 1)).all()


# ---------------------------------------------------------------------------
# Hypothesis tier (runs where hypothesis is installed; skips elsewhere)
# ---------------------------------------------------------------------------

_shape_kwargs = dict(
    t=st.integers(min_value=1, max_value=4),
    m=st.integers(min_value=1, max_value=12),
    n_in=st.integers(min_value=1, max_value=320),
    n_out=st.integers(min_value=1, max_value=150),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)


@settings(max_examples=12, deadline=None)
@given(curve=st.sampled_from(["lin", "nlq"]),
       k=st.integers(min_value=1, max_value=16), **_shape_kwargs)
def test_kwn_seq_matches_oracle_property(curve, k, t, m, n_in, n_out, seed):
    _assert_seq_matches_oracle(seed, t, m, n_in, n_out, "kwn", curve, k)


@settings(max_examples=8, deadline=None)
@given(j=st.integers(min_value=1, max_value=3), **_shape_kwargs)
def test_nld_seq_matches_oracle_property(j, t, m, n_in, n_out, seed):
    _assert_seq_matches_oracle(seed, t, m, n_in, min(n_out, 80), "nld",
                               "act", 12, j)


@settings(max_examples=16, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       m=st.integers(min_value=1, max_value=8),
       n_in=st.integers(min_value=1, max_value=520),
       n_out=st.integers(min_value=1, max_value=260),
       bk=st.sampled_from([32, 64, 128, 256]),
       bn=st.sampled_from([16, 32, 64, 128]))
def test_tiled_oracle_equals_untiled_property(seed, m, n_in, n_out, bk, bn):
    """Digital partial-sum tiling is bitwise-invisible at f32 for any tile."""
    x, msb, lsb, cb, scale, v, noise, _ = _operands(
        seed, 1, m, n_in, n_out, "kwn", "nlq")
    kw = dict(mode="kwn", k=min(12, n_out), drive_gain=0.25)
    a = jax.jit(functools.partial(ref.fused_macro_step_ref, **kw))(
        x[0], msb, lsb, cb.boundaries, cb.levels, scale, v, noise[0])
    b = jax.jit(functools.partial(ref.fused_macro_tiled_ref, bk=bk, bn=bn,
                                  **kw))(
        x[0], msb, lsb, cb.boundaries, cb.levels, scale, v, noise[0])
    for name, aa, bb in zip(("mac", "v_mem", "spikes", "mask", "adc_steps"),
                            a, b):
        np.testing.assert_array_equal(np.asarray(aa), np.asarray(bb),
                                      err_msg=f"{name} tiling-variant")


# ---------------------------------------------------------------------------
# Seeded sweep (always runs; fixed sample of the same property space)
# ---------------------------------------------------------------------------

def _sweep_cases():
    """Fixed random sample over (T, M, K, N, mode, curve, k) incl. odd
    non-multiples of bm/bk/bn and the T=1 degenerate."""
    rng = np.random.RandomState(7)
    cases = [
        # pinned corners: T=1 degenerate, exact-tile, and maximal-oddness
        (1, 8, 256, 128, "kwn", "nlq", 12),
        (1, 16, 512, 256, "kwn", "lin", 12),
        (3, 9, 300, 130, "kwn", "nlq", 5),
        (2, 9, 300, 130, "nld", "act", 12),
        (1, 5, 100, 40, "nld", "act", 12),
    ]
    for _ in range(5):
        t = int(rng.randint(1, 5))
        m = int(rng.randint(1, 14))
        n_in = int(rng.randint(1, 400))
        n_out = int(rng.randint(1, 150))
        mode = rng.choice(["kwn", "nld"])
        curve = rng.choice(["lin", "nlq"]) if mode == "kwn" else "act"
        k = int(rng.randint(1, 17))
        cases.append((t, m, n_in, n_out, str(mode), str(curve), k))
    return cases


@pytest.mark.parametrize("t,m,n_in,n_out,mode,curve,k", _sweep_cases())
def test_seq_matches_oracle_sweep(t, m, n_in, n_out, mode, curve, k):
    _assert_seq_matches_oracle(m * 131 + n_in + n_out + t, t, m, n_in, n_out,
                               mode, curve, k)


@pytest.mark.parametrize("bk,bn", [(64, 32), (256, 128), (128, 64)])
def test_tiled_oracle_equals_untiled_sweep(bk, bn):
    x, msb, lsb, cb, scale, v, noise, _ = _operands(
        3, 1, 8, 384, 192, "kwn", "nlq")
    kw = dict(mode="kwn", k=12, drive_gain=0.25)
    a = jax.jit(functools.partial(ref.fused_macro_step_ref, **kw))(
        x[0], msb, lsb, cb.boundaries, cb.levels, scale, v, noise[0])
    b = jax.jit(functools.partial(ref.fused_macro_tiled_ref, bk=bk, bn=bn,
                                  **kw))(
        x[0], msb, lsb, cb.boundaries, cb.levels, scale, v, noise[0])
    for name, aa, bb in zip(("mac", "v_mem", "spikes", "mask", "adc_steps"),
                            a, b):
        np.testing.assert_array_equal(np.asarray(aa), np.asarray(bb),
                                      err_msg=f"{name} tiling-variant")


def test_seq_equals_iterated_step():
    """Time-major batching is bitwise-invisible: one T-step launch equals T
    single-step launches threading the membrane through HBM."""
    t, m, n_in, n_out = 5, 8, 512, 256
    x, msb, lsb, cb, scale, v, noise, _ = _operands(
        11, t, m, n_in, n_out, "kwn", "nlq")
    kw = dict(mode="kwn", k=12, drive_gain=0.25)
    seq = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels, scale,
                              v, noise, **kw)
    v_c = v
    for step in range(t):
        mac, v_c, spk, mask, steps = ops.fused_macro_step(
            x[step], msb, lsb, cb.boundaries, cb.levels, scale, v_c,
            noise[step], **kw)
        for name, a, b in zip(("mac", "spikes", "mask", "adc_steps"),
                              (mac, spk, mask, steps),
                              (seq[0][step], seq[2][step], seq[3][step],
                               seq[4][step])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{name} @ t={step}")
    np.testing.assert_array_equal(np.asarray(v_c), np.asarray(seq[1]))
