"""Activity-gated sparse execution: gated == dense, bitwise, always.

The gated fused kernel (scalar-prefetched occupancy map, ``pl.when``-skipped
MAC blocks, bounded KWN ramp sweep, optional raw-MAC telemetry) is a pure
execution optimization: an all-zero activation block contributes an exactly
zero partial sum, and the bounded sweep only skips levels with no crossings
or no admission slots left.  So every output must equal the dense path — and
the ``ref.py`` oracles — bit for bit, at every event density, in both modes,
clean and noisy, per-step and time-major, for any tile plan.  This suite
sweeps that whole matrix; a tolerance here is a bug.

A curated ``@pytest.mark.fast`` subset (one dense-vs-gated sweep point per
axis) keeps ``make smoke`` under its 60 s budget; the full matrix runs in
the default tier.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ima as ima_lib
from repro.core import macro as macro_lib
from repro.kernels import fused_macro as fused_kernel
from repro.kernels import ops, ref

DENSITIES = (0.0, 0.01, 0.1, 1.0)
OUT_NAMES = ("v_mem", "spikes", "mask", "adc_steps")

# >= 2 tile plans: the default planner pick, and an explicit multi-tile
# override that forces row/K/column tiling (finer activity granularity)
TILE_PLANS = ({}, {"bm": 8, "bk": 128, "bn": 64})


def _events(key, shape, density):
    """Ternary events at the given density; density 0.0 = fully silent."""
    vals = jax.random.randint(key, shape, -1, 2)
    sparse = jax.random.uniform(jax.random.fold_in(key, 1), shape) < density
    return (vals * sparse).astype(jnp.int8)


def _operands(mode, t=3, m=16, n_in=256, n_out=128, j=2, density=0.1,
              seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 7)
    nc = n_out if mode == "kwn" else j * n_out
    x = _events(keys[0], (t, m, n_in), density)
    msb = _events(keys[1], (n_in, nc), 0.5)
    lsb = _events(keys[2], (n_in, nc), 0.5)
    if mode == "kwn":
        cb = ima_lib.nlq_codebook(5, -24, 24)
        scale = jax.random.uniform(keys[3], (nc,), minval=0.05, maxval=0.3)
        w_dend = None
    else:
        cb = ima_lib.activation_codebook(5, ima_lib.quadratic, -4.0, 4.0)
        scale = jax.random.uniform(keys[3], (nc,), minval=0.01, maxval=0.05)
        w_dend = jax.random.normal(keys[4], (j, n_out)) / np.sqrt(j)
    v = jax.random.normal(keys[5], (m, n_out)) * 0.5
    noise = 0.05 * jnp.sign(jax.random.normal(keys[6], (t, m, n_out)))
    return x, msb, lsb, cb, scale, v, noise, w_dend


def _assert_equal(got, want, context):
    for name, a, b in zip(OUT_NAMES, got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{name} mismatch ({context})")


def _run_pair(mode, density, cadence, noisy, tiles, seed=0):
    """Dense vs gated (and vs oracle) for one sweep point."""
    x, msb, lsb, cb, scale, v, noise, w_dend = _operands(
        mode, density=density, seed=seed)
    kw = dict(mode=mode, k=12, drive_gain=0.25, **tiles)
    if noisy:
        kw.update(ima_noise=ima_lib.kernel_noise_params(
            ima_lib.IMANoiseModel(), cb), snl_amp=0.05, seed=7)
        noise = None
    if cadence == "step":
        x, noise = x[0], None if noise is None else noise[0]
        run = ops.fused_macro_step
        oracle = ref.fused_macro_step_ref
    else:
        run = ops.fused_macro_seq
        oracle = ref.fused_macro_seq_ref
    args = (x, msb, lsb, cb.boundaries, cb.levels, scale, v, noise, w_dend)
    dense = run(*args, gate=False, **kw)
    gated = run(*args, gate=True, **kw)
    gated_dark = run(*args, gate=True, mac_telemetry=False, **kw)
    okw = {k: v_ for k, v_ in kw.items() if k not in ("bm", "bk", "bn")}
    want = jax.jit(functools.partial(oracle, **okw))(*args)
    ctx = f"{mode}/{cadence}/d={density}/noisy={noisy}/tiles={tiles}"
    # gated == dense, bitwise, including the raw MAC telemetry
    _assert_equal(gated[1:], dense[1:], ctx)
    np.testing.assert_array_equal(np.asarray(gated[0]), np.asarray(dense[0]),
                                  err_msg=f"mac mismatch ({ctx})")
    # telemetry-off returns mac=None but identical outputs
    assert gated_dark[0] is None
    _assert_equal(gated_dark[1:], dense[1:], ctx + "/mac_telemetry=False")
    # gated == composed oracle
    _assert_equal(gated[1:], (want[1], want[2], want[3], want[4][..., 0]),
                  ctx + "/oracle")


class TestGatedParitySweep:
    """The acceptance matrix: density x mode x cadence x noise x tiling."""

    @pytest.mark.parametrize("density", [
        pytest.param(0.0, marks=pytest.mark.fast), 0.01,
        pytest.param(0.1, marks=pytest.mark.fast), 1.0])
    def test_kwn_seq_clean_density_sweep(self, density):
        _run_pair("kwn", density, "seq", noisy=False, tiles={})

    @pytest.mark.parametrize("density", DENSITIES)
    @pytest.mark.parametrize("mode", ["kwn", "nld"])
    @pytest.mark.parametrize("cadence", ["step", "seq"])
    @pytest.mark.parametrize("noisy", [False, True])
    def test_full_matrix_default_tiles(self, density, mode, cadence, noisy):
        _run_pair(mode, density, cadence, noisy, tiles={})

    @pytest.mark.parametrize("density", [0.0, 0.1])
    @pytest.mark.parametrize("mode", ["kwn", "nld"])
    @pytest.mark.parametrize("noisy", [False, True])
    def test_multi_tile_plan(self, density, mode, noisy):
        _run_pair(mode, density, "seq", noisy, tiles=TILE_PLANS[1])

    @pytest.mark.fast
    def test_fast_cross_section(self):
        """One noisy multi-tile point for the smoke tier (the remaining
        axes — nld, step cadence, full tile sweep — run in the default
        tier via the matrix above)."""
        _run_pair("kwn", 0.1, "seq", noisy=True, tiles=TILE_PLANS[1])


class TestActivityMap:
    @pytest.mark.fast
    def test_map_matches_brute_force(self):
        x = _events(jax.random.PRNGKey(3), (5, 24, 300), 0.02)
        plan = fused_kernel.plan_tiles(24, 300, 128, 128, t=5, bm=8)
        xm = jnp.pad(x, ((0, 0), (0, plan.m_pad - 24),
                         (0, plan.k_pad - 300)))
        occ = np.asarray(ops.fused_activity_map(xm, plan))
        n_i, n_k = plan.m_pad // plan.bm, plan.k_pad // plan.bk
        assert occ.shape == (5, n_i, n_k)
        for t in range(5):
            for i in range(n_i):
                for kk in range(n_k):
                    blk = np.asarray(xm[t, i * plan.bm:(i + 1) * plan.bm,
                                        kk * plan.bk:(kk + 1) * plan.bk])
                    assert occ[t, i, kk] == int((blk != 0).any())

    @pytest.mark.fast
    def test_plan_activity_matches_ops_map(self):
        """macro.plan_activity must hand the kernel the exact map
        ops.fused_macro_seq would build itself (same tile plan)."""
        cfg_nc = 128
        keys = jax.random.split(jax.random.PRNGKey(4), 3)
        spikes = (jax.random.randint(keys[0], (4, 10, 300), -1, 2) *
                  (jax.random.uniform(keys[1], (4, 10, 300)) < 0.05))
        cb = ima_lib.nlq_codebook(5, -24, 24)
        fw = macro_lib.FusedMacroWeights(
            msb=jnp.zeros((300, cfg_nc), jnp.int8),
            lsb=jnp.zeros((300, cfg_nc), jnp.int8),
            scale=jnp.ones((cfg_nc,)), boundaries=cb.boundaries,
            levels=cb.levels, w_dend=None, mode="kwn")
        act = macro_lib.plan_activity(spikes, fw, cfg_nc)
        plan, _ = macro_lib.plan_fused_tiles(10, fw, cfg_nc, n_steps=4)
        xm = jnp.pad(spikes.astype(jnp.int8),
                     ((0, 0), (0, plan.m_pad - 10), (0, plan.k_pad - 300)))
        np.testing.assert_array_equal(np.asarray(act),
                                      np.asarray(ops.fused_activity_map(
                                          xm, plan)))

    @pytest.mark.fast
    def test_plan_prefers_aligned_k_tiles(self):
        """The activity-granularity heuristic: K < 256 takes the smallest
        lane-aligned tile instead of padding up to the macro row count."""
        assert fused_kernel.plan_tiles(16, 100, 128, 128).bk == 128
        assert fused_kernel.plan_tiles(16, 100, 128, 128).k_pad == 128
        assert fused_kernel.plan_tiles(16, 256, 128, 128).bk == 256
        assert fused_kernel.plan_tiles(16, 512, 128, 128).bk == 256
        plan = fused_kernel.plan_tiles(16, 256, 128, 128, t=7)
        assert plan.activity_shape == (7, 1, 1)
        assert plan.activity_bytes == 28


class TestModelAndServingTelemetry:
    def _setup(self):
        from repro.data import events as ev_lib
        from repro.models import snn
        dcfg = ev_lib.NMNIST
        ds = ev_lib.EventDataset(dcfg)
        cfg = snn.SNNConfig(n_in=dcfg.n_in, n_steps=dcfg.n_steps,
                            n_classes=dcfg.n_classes, mode="kwn", k=12)
        p = snn.init_params(cfg, jax.random.PRNGKey(0))
        ev, lab = ds.sample(jax.random.PRNGKey(1), 6)
        return snn, p, ev, lab, cfg

    def test_forward_silicon_reports_skipped_blocks(self):
        snn, p, ev, _, cfg = self._setup()
        _, tele = snn.forward_silicon(p, ev, cfg, jax.random.PRNGKey(2),
                                      fused="seq")
        r = np.asarray(tele["skipped_block_ratio"])
        assert r.shape == (6,)
        assert np.all((0.0 <= r) & (r <= 1.0))
        # silent streams skip every block
        _, tele0 = snn.forward_silicon(p, jnp.zeros_like(ev), cfg,
                                       jax.random.PRNGKey(2), fused="seq")
        np.testing.assert_allclose(
            np.asarray(tele0["skipped_block_ratio"]), 1.0)

    def test_step_and_seq_report_identical_ratio(self):
        snn, p, ev, _, cfg = self._setup()
        _, ts = snn.forward_silicon(p, ev, cfg, jax.random.PRNGKey(2),
                                    fused="step")
        _, tq = snn.forward_silicon(p, ev, cfg, jax.random.PRNGKey(2),
                                    fused="seq")
        np.testing.assert_array_equal(
            np.asarray(ts["skipped_block_ratio"]),
            np.asarray(tq["skipped_block_ratio"]))

    def test_mac_telemetry_opt_in_is_output_invariant(self):
        snn, p, ev, _, cfg = self._setup()
        key = jax.random.PRNGKey(2)
        l_off, t_off = snn.forward_silicon(p, ev, cfg, key, fused="seq")
        l_on, t_on = snn.forward_silicon(p, ev, cfg, key, fused="seq",
                                         mac_telemetry=True)
        np.testing.assert_array_equal(np.asarray(l_off), np.asarray(l_on))
        for name in t_off:
            np.testing.assert_array_equal(np.asarray(t_off[name]),
                                          np.asarray(t_on[name]),
                                          err_msg=f"telemetry {name}")

    def test_engine_packs_by_density(self):
        from repro.serve.engine import EventRequest, SNNEventEngine
        snn, p, ev, lab, cfg = self._setup()
        engine = SNNEventEngine(cfg, p, batch_slots=2, seed=5)
        # submit busy-then-quiet so FIFO order is density-inverted
        dens = np.asarray(jnp.mean(jnp.abs(ev) > 0, axis=(1, 2)))
        order = list(np.argsort(dens)[::-1])
        for i in order:
            engine.submit(EventRequest(uid=int(i), events=ev[int(i)],
                                       label=int(lab[int(i)])))
        done = engine.run()
        assert len(done) == 6
        # results come back in SUBMISSION order (here density-descending);
        # the density sort only reorders the internal batches
        got_dens = [r.density for r in done]
        assert got_dens == sorted(got_dens, reverse=True)
        assert [r.uid for r in done] == [int(i) for i in order]
        assert all(r.skipped_block_ratio is not None for r in done)
        rep = engine.energy_report("nmnist")
        assert 0.0 <= rep["mean_skipped_block_ratio"] <= 1.0

    def test_engine_density_packing_is_output_invariant(self):
        """Packing moves requests between batches; every request's logits
        must not change.  SNL off: the PRBS rescue stream is threaded
        across the whole batch (row position keys the draw — silicon
        behaviour), so only the noiseless LIF path is batch-composition
        invariant."""
        from repro.serve.engine import EventRequest, SNNEventEngine
        snn, p, ev, lab, cfg = self._setup()
        import dataclasses
        cfg = dataclasses.replace(cfg, use_snl=False)
        results = {}
        for pack in (False, True):
            engine = SNNEventEngine(cfg, p, batch_slots=2, seed=5,
                                    pack_by_density=pack)
            for i in range(6):
                engine.submit(EventRequest(uid=i, events=ev[i],
                                           label=int(lab[i])))
            results[pack] = {r.uid: r for r in engine.run()}
        for uid in range(6):
            np.testing.assert_array_equal(
                np.asarray(results[False][uid].logits),
                np.asarray(results[True][uid].logits),
                err_msg=f"uid {uid}")
            assert results[False][uid].pred == results[True][uid].pred
