"""Golden regressions: KWN early-stop step counts and the calibrated energy
model must reproduce these exact numbers.

Both feed the paper-table reproductions (Fig. 9 / Table I / the -30 % ADC and
10x LIF latency claims); silent numeric drift in either silently invalidates
every benchmark figure, so these fail loudly on any change.  The fixtures are
fixed-seed, fixed-input, and the expectations are exact (integer histograms)
or tight-tolerance (float energies at 1e-6 relative).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy, ima as ima_lib, kwn as kwn_lib
from repro.kernels import ops


def _golden_mac():
    """Fixed sparse event MAC: seed 42, 5 % spike rate, 256x128 macro."""
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 3)
    sparse = jax.random.uniform(ks[0], (64, 256)) < 0.05
    x = (jax.random.randint(ks[1], (64, 256), -1, 2) * sparse
         ).astype(jnp.float32)
    w = jax.random.randint(ks[2], (256, 128), -3, 4).astype(jnp.float32)
    return x @ w


# Exact per-row early-stop histogram for the golden MAC (K=12, 5-bit NLQ
# ramp over +-24): bins 0..31, 64 rows total.
GOLDEN_STEP_HIST = [0, 0, 0, 0, 0, 1, 25, 28, 9, 1] + [0] * 22
GOLDEN_MEAN_STEPS = 6.75


class TestKwnEarlyStopGolden:
    def _cb(self):
        return ima_lib.nlq_codebook(5, -24.0, 24.0)

    def test_select_step_histogram(self):
        res = kwn_lib.kwn_select(_golden_mac(), 12, self._cb())
        steps = np.asarray(res.adc_steps)
        np.testing.assert_array_equal(np.bincount(steps, minlength=32),
                                      GOLDEN_STEP_HIST)
        assert float(steps.mean()) == GOLDEN_MEAN_STEPS

    def test_ramp_scan_agrees(self):
        """The literal hardware emulation must produce the same histogram."""
        mac = _golden_mac()
        cb = self._cb()
        sel = kwn_lib.kwn_select(mac, 12, cb)
        scan = kwn_lib.kwn_ramp_scan(mac, 12, cb)
        np.testing.assert_array_equal(np.asarray(scan.adc_steps),
                                      np.asarray(sel.adc_steps))
        np.testing.assert_array_equal(np.asarray(scan.mask),
                                      np.asarray(sel.mask))

    def test_kernel_agrees(self):
        """The Pallas kernel's step counts are the energy model's input —
        pin them to the same golden histogram."""
        cb = self._cb()
        _, steps = ops.kwn_topk(_golden_mac(), cb.boundaries, 12)
        np.testing.assert_array_equal(
            np.bincount(np.asarray(steps), minlength=32), GOLDEN_STEP_HIST)


class TestTilingInvarianceGolden:
    """Tiling and time-major batching must not move the measured early-stop
    statistics (or the pJ/SOP figures derived from them): the same golden
    MAC inputs produce the identical PR 1 step histogram whether the fused
    kernel runs one step on one macro-wide tile, a forced multi-tile grid,
    or a whole time-major sequence."""

    K_WIN = 12

    def _operands(self):
        from repro.core import ternary as ternary_lib
        key = jax.random.PRNGKey(42)
        ks = jax.random.split(key, 3)
        sparse = jax.random.uniform(ks[0], (64, 256)) < 0.05
        x = (jax.random.randint(ks[1], (64, 256), -1, 2) * sparse
             ).astype(jnp.int8)
        w = jax.random.randint(ks[2], (256, 128), -3, 4).astype(jnp.float32)
        msb, lsb = ternary_lib.weight_decompose(w)
        cb = ima_lib.nlq_codebook(5, -24.0, 24.0)
        scale = jnp.ones((128,))
        v = jnp.zeros((64, 128))
        return x, msb.astype(jnp.int8), lsb.astype(jnp.int8), cb, scale, v

    def _hist(self, steps):
        return np.bincount(np.asarray(steps).reshape(-1), minlength=32)

    def test_fused_step_histogram_matches_golden(self):
        x, msb, lsb, cb, scale, v = self._operands()
        out = ops.fused_macro_step(x, msb, lsb, cb.boundaries, cb.levels,
                                   scale, v, jnp.zeros_like(v),
                                   mode="kwn", k=self.K_WIN)
        np.testing.assert_array_equal(self._hist(out[4]), GOLDEN_STEP_HIST)

    def test_forced_tiling_histogram_invariant(self):
        """bk=64, bn=32 forces a 4x4 (K, col) tile grid over the same
        macro: digital partial-sum accumulation must not move a single
        histogram bin."""
        x, msb, lsb, cb, scale, v = self._operands()
        out = ops.fused_macro_step(x, msb, lsb, cb.boundaries, cb.levels,
                                   scale, v, jnp.zeros_like(v),
                                   mode="kwn", k=self.K_WIN, bk=64, bn=32)
        np.testing.assert_array_equal(self._hist(out[4]), GOLDEN_STEP_HIST)
        assert float(np.asarray(out[4]).mean()) == GOLDEN_MEAN_STEPS

    def test_time_major_histogram_invariant(self):
        """The same events at every time step must report the golden
        histogram at every time step (adc_steps depend only on the MAC, not
        the carried membrane)."""
        x, msb, lsb, cb, scale, v = self._operands()
        t = 4
        xs = jnp.broadcast_to(x, (t,) + x.shape)
        noise = jnp.zeros((t,) + v.shape)
        out = ops.fused_macro_seq(xs, msb, lsb, cb.boundaries, cb.levels,
                                  scale, v, noise, mode="kwn", k=self.K_WIN)
        for step in range(t):
            np.testing.assert_array_equal(self._hist(out[4][step]),
                                          GOLDEN_STEP_HIST)

    def test_pj_per_sop_invariant_under_tiling(self):
        """The serving energy figure is derived from measured mean steps;
        identical histograms must give bit-identical pJ/SOP under tiling
        and time-major batching."""
        x, msb, lsb, cb, scale, v = self._operands()
        ref_steps = kwn_lib.kwn_select(_golden_mac(), self.K_WIN,
                                       cb).adc_steps
        variants = [
            ops.fused_macro_step(x, msb, lsb, cb.boundaries, cb.levels,
                                 scale, v, jnp.zeros_like(v), mode="kwn",
                                 k=self.K_WIN, bk=64, bn=32)[4],
            ops.fused_macro_seq(jnp.broadcast_to(x, (2,) + x.shape), msb,
                                lsb, cb.boundaries, cb.levels, scale, v,
                                jnp.zeros((2,) + v.shape), mode="kwn",
                                k=self.K_WIN)[4][1],
        ]
        rate = energy.SPIKE_RATES["nmnist"]
        want = energy.kwn_step_energy(
            self.K_WIN, rate,
            adc_steps=float(np.asarray(ref_steps).mean())).total
        for steps in variants:
            got = energy.kwn_step_energy(
                self.K_WIN, rate,
                adc_steps=float(np.asarray(steps).mean())).total
            assert got == want


class TestEnergyModelGolden:
    """Calibrated pJ/SOP figures (Table I cells).  The model was calibrated
    once against the paper's measured silicon; any code change that moves
    these numbers is re-calibration and must update the goldens knowingly."""

    GOLDEN_TABLE1 = {
        "kwn_nmnist_pj_per_sop": 0.799770,     # paper: 0.8
        "kwn_dvs_pj_per_sop": 1.495826,        # paper: 1.5
        "nld_nmnist_pj_per_sop": 1.800011,     # paper: 1.8
        "nld_dvs_pj_per_sop": 2.291911,        # paper: 2.3
        "nld_quiroga_pj_per_sop": 2.098011,    # paper: 2.1
    }

    def test_table1_entries(self):
        got = energy.table1_energy_entries()
        assert got.keys() == self.GOLDEN_TABLE1.keys()
        for name, want in self.GOLDEN_TABLE1.items():
            assert got[name] == pytest.approx(want, rel=1e-6), name

    def test_early_stop_saving_calibration(self):
        assert energy.early_stop_saving(3) == pytest.approx(0.516, rel=1e-9)
        assert energy.early_stop_saving(12) == pytest.approx(0.300, rel=1e-9)

    def test_improvement_vs_sota(self):
        assert energy.improvement_vs_sota() == pytest.approx(1.625468,
                                                             rel=1e-6)

    def test_kwn_k3_breakdown(self):
        bd = energy.kwn_step_energy(3, energy.SPIKE_RATES["nmnist"])
        assert bd.mac == pytest.approx(473.497600, rel=1e-6)
        assert bd.adc == pytest.approx(153.640960, rel=1e-6)
        assert bd.lif == pytest.approx(3.0, rel=1e-9)
        assert bd.control == pytest.approx(127.239517, rel=1e-6)
        # KWN control logic share is a paper-measured constant: 16.8 %
        assert bd.as_dict()["frac"]["control"] == pytest.approx(0.168,
                                                                rel=1e-9)
