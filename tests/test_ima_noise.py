"""In-kernel stochastic IMA: the Fig. 7 error model inside the fused kernel.

Three contracts, in increasing altitude:

* **bitwise oracle parity** — the noisy fused kernel (counter-PRNG draws
  generated inside the Pallas body) equals ``kernels.ref``'s counter-based
  noisy oracle exactly, in both modes, including multi-macro tiled layers;
* **seeded determinism / launch-shape invariance** — the same seed gives
  bitwise-identical spikes across runs *and across tile plans* (every draw
  is a pure function of ``(seed, step, absolute row, logical column)``, so
  (bm, bk, bn) choices and padding cannot move the stream);
* **statistics goldens** — the counter stream reproduces the paper's
  measured conversion-error moments (Fig. 7a: mu ~ 0.41 LSB, sigma ~ 1.34
  LSB) through the same calibration the composed ``jax.random`` model uses.

The wide statistical sweep is marked ``slow``; everything else is smoke-tier
(<60 s budget, see conftest FAST_MODULES).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ctrprng
from repro.core import ima as ima_lib
from repro.kernels import ops, ref


def _tern(key, shape, rate=0.2):
    sparse = jax.random.uniform(jax.random.fold_in(key, 1), shape) < rate
    vals = jax.random.randint(key, shape, -1, 2)
    return (vals * sparse).astype(jnp.int8)


def _kwn_operands(t, m, n_in, n_out, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = _tern(keys[0], (t, m, n_in))
    msb, lsb = _tern(keys[1], (n_in, n_out)), _tern(keys[2], (n_in, n_out))
    cb = ima_lib.nlq_codebook(5, -24.0, 24.0)
    scale = jax.random.uniform(keys[3], (n_out,), minval=0.05, maxval=0.3)
    v = jax.random.normal(keys[4], (m, n_out)) * 0.5
    return x, msb, lsb, cb, scale, v


def _noise_params(cb):
    return ima_lib.kernel_noise_params(ima_lib.IMANoiseModel(), cb)


def _assert_all_equal(out, want, msg=""):
    names = ("mac", "v_mem", "spikes", "mask", "adc_steps")
    for name, a, b in zip(names, out, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{msg}{name} mismatch")


class TestNoisyOracleParity:
    """Noisy fused kernel == counter-based ref.py oracle, bitwise."""

    @pytest.mark.parametrize("t,m,n_in,n_out", [
        pytest.param(1, 16, 256, 128,
                     marks=pytest.mark.fast),   # one macro: smoke tier
        (3, 8, 300, 130),         # odd everything (padding in m, k, n)
        (2, 24, 512, 256),        # 2x2 virtual macro grid, multi-tile
    ])
    def test_kwn(self, t, m, n_in, n_out):
        x, msb, lsb, cb, scale, v = _kwn_operands(t, m, n_in, n_out)
        nz = _noise_params(cb)
        kw = dict(mode="kwn", k=12, drive_gain=0.25, ima_noise=nz,
                  snl_amp=0.05, seed=31)
        out = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                  scale, v, None, **kw)
        want = jax.jit(functools.partial(ref.fused_macro_seq_ref, **kw))(
            x, msb, lsb, cb.boundaries, cb.levels, scale, v, None)
        want = list(want)
        want[4] = want[4][..., 0]
        _assert_all_equal(out, want)

    @pytest.mark.parametrize("j,n_out", [(2, 128), (3, 130)])
    def test_nld(self, j, n_out):
        keys = jax.random.split(jax.random.PRNGKey(j), 6)
        t, m, n_in = 2, 9, 300
        x = _tern(keys[0], (t, m, n_in))
        msb = _tern(keys[1], (n_in, j * n_out))
        lsb = _tern(keys[2], (n_in, j * n_out))
        cb = ima_lib.activation_codebook(5, ima_lib.quadratic, -4.0, 4.0)
        scale = jax.random.uniform(keys[3], (j * n_out,), minval=0.01,
                                   maxval=0.05)
        w_dend = jax.random.normal(keys[4], (j, n_out)) / np.sqrt(j)
        v = jax.random.normal(keys[5], (m, n_out)) * 0.5
        nz = _noise_params(cb)
        kw = dict(mode="nld", drive_gain=0.25, ima_noise=nz, seed=17)
        out = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                  scale, v, None, w_dend=w_dend, **kw)
        want = jax.jit(functools.partial(ref.fused_macro_seq_ref, **kw))(
            x, msb, lsb, cb.boundaries, cb.levels, scale, v, None, w_dend)
        want = list(want)
        want[4] = want[4][..., 0]
        _assert_all_equal(out, want)

    def test_noise_perturbs_clean_result(self):
        """The injected error must actually change winners/spikes (a no-op
        noise path would pass every parity test vacuously)."""
        x, msb, lsb, cb, scale, v = _kwn_operands(4, 16, 256, 128)
        kw = dict(mode="kwn", k=12, drive_gain=0.25)
        clean = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                    scale, v, None, **kw)
        noisy = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                    scale, v, None, ima_noise=_noise_params(cb),
                                    seed=3, **kw)
        assert not np.array_equal(np.asarray(clean[3]), np.asarray(noisy[3]))


class TestSeededDeterminism:
    """Same seed -> bitwise-identical spikes, for any launch shape."""

    @pytest.mark.fast
    def test_identical_across_runs(self):
        x, msb, lsb, cb, scale, v = _kwn_operands(4, 16, 256, 128)
        kw = dict(mode="kwn", k=12, drive_gain=0.25,
                  ima_noise=_noise_params(cb), snl_amp=0.05, seed=99)
        a = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                scale, v, None, **kw)
        b = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                scale, v, None, **kw)
        _assert_all_equal(a, b, msg="rerun ")

    def test_identical_across_tile_plans(self):
        """(bm, bk, bn) sweeps must not move a single draw: counters are
        global element coordinates, not tile-local ones."""
        x, msb, lsb, cb, scale, v = _kwn_operands(2, 24, 512, 256)
        kw = dict(mode="kwn", k=12, drive_gain=0.25,
                  ima_noise=_noise_params(cb), snl_amp=0.05, seed=5)
        base = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                   scale, v, None, **kw)
        for bm, bk, bn in ((8, 256, 128), (128, 512, 256), (16, 512, 128)):
            out = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                      scale, v, None, bm=bm, bk=bk, bn=bn,
                                      **kw)
            _assert_all_equal(base, out, msg=f"plan {(bm, bk, bn)}: ")

    @pytest.mark.fast
    def test_step_offset_reproduces_seq_stream(self):
        """A per-step launch cadence feeding the scan index as step_offset
        draws the exact one-launch sequence stream."""
        x, msb, lsb, cb, scale, v = _kwn_operands(4, 16, 256, 128)
        kw = dict(mode="kwn", k=12, drive_gain=0.25,
                  ima_noise=_noise_params(cb), snl_amp=0.05, seed=21)
        seq = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                  scale, v, None, **kw)
        vv, spk = v, []
        for t in range(4):
            _, vv, s, _, _ = ops.fused_macro_step(
                x[t], msb, lsb, cb.boundaries, cb.levels, scale, vv, None,
                step_offset=t, **kw)
            spk.append(np.asarray(s))
        np.testing.assert_array_equal(np.stack(spk), np.asarray(seq[2]))
        np.testing.assert_array_equal(np.asarray(vv), np.asarray(seq[1]))

    def test_seeds_decorrelate(self):
        x, msb, lsb, cb, scale, v = _kwn_operands(2, 16, 256, 128)
        kw = dict(mode="kwn", k=12, drive_gain=0.25,
                  ima_noise=_noise_params(cb))
        a = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                scale, v, None, seed=1, **kw)
        b = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                scale, v, None, seed=2, **kw)
        assert not np.array_equal(np.asarray(a[3]), np.asarray(b[3]))


class TestForwardSiliconNoisy:
    """Model + serving layers: noisy evaluation never leaves the fused path."""

    def _setup(self, mode="kwn"):
        from repro.data import events as ev_lib
        from repro.models import snn
        dcfg = ev_lib.NMNIST
        ds = ev_lib.EventDataset(dcfg)
        cfg = snn.SNNConfig(n_in=dcfg.n_in, n_steps=dcfg.n_steps,
                            n_classes=dcfg.n_classes, mode=mode, k=12)
        p = snn.init_params(cfg, jax.random.PRNGKey(0))
        ev, lab = ds.sample(jax.random.PRNGKey(1), 6)
        return snn, p, ev, lab, cfg

    def test_noisy_seq_is_deterministic_per_key(self):
        snn, p, ev, _, cfg = self._setup()
        noisy = ima_lib.IMANoiseModel()
        la, _ = snn.forward_silicon(p, ev, cfg, jax.random.PRNGKey(4),
                                    noise=noisy, fused="seq")
        lb, _ = snn.forward_silicon(p, ev, cfg, jax.random.PRNGKey(4),
                                    noise=noisy, fused="seq")
        lc, _ = snn.forward_silicon(p, ev, cfg, jax.random.PRNGKey(5),
                                    noise=noisy, fused="seq")
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        assert not np.array_equal(np.asarray(la), np.asarray(lc))

    def test_noisy_nld_seq_runs(self):
        snn, p, ev, _, cfg = self._setup("nld")
        logits, tele = snn.forward_silicon(
            p, ev, cfg, jax.random.PRNGKey(2), noise=ima_lib.IMANoiseModel(),
            fused="seq")
        assert logits.shape == (6, cfg.n_classes)
        np.testing.assert_allclose(np.asarray(tele["adc_steps"]), 31.0)

    def test_noisy_engine_serves_batches(self):
        from repro.serve.engine import EventRequest, SNNEventEngine
        snn, p, ev, lab, cfg = self._setup()
        # pack_by_density=False + continuous=False pin the legacy FIFO
        # batches so the direct-forward recomputation below sees the
        # engine's exact first batch and per-batch key stream
        engine = SNNEventEngine(cfg, p, batch_slots=4, seed=5,
                                noise=ima_lib.IMANoiseModel(),
                                pack_by_density=False, continuous=False)
        for i in range(6):
            engine.submit(EventRequest(uid=i, events=ev[i],
                                       label=int(lab[i])))
        done = engine.run()
        assert len(done) == 6 and not engine.pending
        assert all(0.0 <= r.adc_steps <= 31.0 for r in done)
        # same key sequence as the engine's first batch, straight through
        # forward_silicon: the engine adds nothing on top of the model path
        key = jax.random.split(jax.random.PRNGKey(5))[1]
        logits, _ = jax.jit(lambda pp, e, kk: snn.forward_silicon(
            pp, e, cfg, kk, fused="seq",
            noise=ima_lib.IMANoiseModel()))(p, ev[:4], key)
        np.testing.assert_array_equal(np.asarray(logits[0]),
                                      np.asarray(done[0].logits))


class TestNoiseStatisticsGolden:
    """The counter stream reproduces the Fig. 7a measured moments."""

    @pytest.mark.fast
    def test_fig7a_moments(self):
        cb = ima_lib.nlq_codebook(5, -64, 64)
        m = ima_lib.measure_transfer_error_ctr(cb, n_points=4096, n_steps=4)
        assert m["mean_lsb"] == pytest.approx(0.41, abs=0.08)
        assert m["std_lsb"] == pytest.approx(1.34, abs=0.12)

    def test_gaussian_moments(self):
        rows = jnp.arange(256, dtype=jnp.int32)[:, None]
        cols = jnp.arange(512, dtype=jnp.int32)[None, :]
        g = ctrprng.counter_normal(7, 3, rows, cols, ctrprng.TAG_IMA)
        assert float(jnp.mean(g)) == pytest.approx(0.0, abs=0.01)
        assert float(jnp.std(g)) == pytest.approx(1.0, abs=0.01)

    def test_sign_noise_is_two_level(self):
        rows = jnp.arange(64, dtype=jnp.int32)[:, None]
        cols = jnp.arange(128, dtype=jnp.int32)[None, :]
        s = ctrprng.counter_sign(7, 3, rows, cols, ctrprng.TAG_SNL)
        assert set(np.unique(np.asarray(s))) == {-1.0, 1.0}
        assert abs(float(jnp.mean(s))) < 0.05

    @pytest.mark.slow
    def test_fig7a_moment_sweep(self):
        """Wide seed x step sweep of the measured moments (slow tier)."""
        cb = ima_lib.nlq_codebook(5, -64, 64)
        for seed in (0, 11, 1234):
            m = ima_lib.measure_transfer_error_ctr(cb, seed=seed,
                                                   n_points=8192, n_steps=16)
            assert m["mean_lsb"] == pytest.approx(0.41, abs=0.06), seed
            assert m["std_lsb"] == pytest.approx(1.34, abs=0.08), seed
