"""Per-kernel validation: shape/dtype sweeps, allclose vs the ref.py oracles,
and hypothesis property tests on the kernel invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import ima as ima_lib
from repro.kernels import ops, ref


def _tern(key, shape):
    return jax.random.randint(key, shape, -1, 2).astype(jnp.int8)


class TestTernaryMac:
    @pytest.mark.parametrize("m,k,n", [
        (8, 256, 128), (128, 256, 128), (64, 512, 256),
        (17, 300, 130),            # non-aligned: exercises padding
        (256, 1024, 384), (1, 256, 128),
    ])
    def test_matches_ref(self, m, k, n):
        keys = jax.random.split(jax.random.PRNGKey(m * 7 + n), 3)
        x = _tern(keys[0], (m, k))
        msb = _tern(keys[1], (k, n))
        lsb = _tern(keys[2], (k, n))
        out = ops.ternary_mac(x, msb, lsb)
        want = ref.ternary_mac_ref(x, msb, lsb)
        np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)

    def test_batched_leading_dims(self):
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        x = _tern(keys[0], (2, 5, 256))
        msb, lsb = _tern(keys[1], (256, 128)), _tern(keys[2], (256, 128))
        out = ops.ternary_mac(x, msb, lsb)
        want = ref.ternary_mac_ref(x.reshape(-1, 256), msb, lsb).reshape(2, 5, 128)
        np.testing.assert_allclose(out, want, rtol=1e-6)

    def test_nondefault_ratio(self):
        keys = jax.random.split(jax.random.PRNGKey(1), 3)
        x, msb, lsb = (_tern(keys[0], (16, 256)), _tern(keys[1], (256, 128)),
                       _tern(keys[2], (256, 128)))
        out = ops.ternary_mac(x, msb, lsb, ratio=3.0)
        want = ref.ternary_mac_ref(x, msb, lsb, ratio=3.0)
        np.testing.assert_allclose(out, want, rtol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 40), st.integers(1, 300), st.integers(1, 200),
           st.integers(0, 2 ** 31 - 1))
    def test_property_exact_integer_gemm(self, m, k, n, seed):
        # Ternary x ternary-plane GEMM is exact in f32 for any shape.
        keys = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = _tern(keys[0], (m, k))
        msb, lsb = _tern(keys[1], (k, n)), _tern(keys[2], (k, n))
        out = ops.ternary_mac(x, msb, lsb)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(ref.ternary_mac_ref(x, msb, lsb)))


class TestKwnTopk:
    @pytest.mark.parametrize("m,n,k,bits", [
        (8, 128, 3, 5), (64, 128, 12, 5), (16, 128, 1, 5),
        (9, 128, 12, 5),           # padding rows
        (32, 256, 16, 6), (8, 128, 127, 5),
    ])
    def test_matches_ref(self, m, n, k, bits):
        cb = ima_lib.nlq_codebook(bits, -64, 64)
        mac = jax.random.normal(jax.random.PRNGKey(m + n + k), (m, n)) * 20
        mask, steps = ops.kwn_topk(mac, cb.boundaries, k)
        want_mask, want_steps = ref.kwn_topk_ref(mac, cb.boundaries, k)
        np.testing.assert_array_equal(mask, want_mask)
        np.testing.assert_array_equal(steps, want_steps[..., 0])

    def test_batched(self):
        cb = ima_lib.nlq_codebook(5, -64, 64)
        mac = jax.random.normal(jax.random.PRNGKey(5), (3, 7, 128)) * 20
        mask, steps = ops.kwn_topk(mac, cb.boundaries, 12)
        assert mask.shape == (3, 7, 128) and steps.shape == (3, 7)
        np.testing.assert_array_equal(mask.sum(-1), 12.0)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
    def test_property_k_winners_and_dominance(self, m, k, seed):
        cb = ima_lib.nlq_codebook(5, -64, 64)
        mac = jax.random.normal(jax.random.PRNGKey(seed), (m, 128)) * 25
        mask, steps = ops.kwn_topk(mac, cb.boundaries, k)
        assert bool(jnp.all(mask.sum(-1) == k))
        # Every winner's code >= every loser's code (ramp dominance).
        codes = ima_lib.ima_convert(mac, cb)
        wmin = jnp.min(jnp.where(mask > 0, codes, 10 ** 6), -1)
        lmax = jnp.max(jnp.where(mask == 0, codes, -1), -1)
        assert bool(jnp.all(lmax <= wmin))
        # Early stop: steps = distance from top code down to the K-th winner.
        assert bool(jnp.all((steps >= 0) & (steps <= cb.n_codes - 1)))


class TestLifStep:
    @pytest.mark.parametrize("shape", [(8, 128), (64, 128), (33, 100), (256, 512)])
    @pytest.mark.parametrize("use_snl", [True, False])
    def test_matches_ref(self, shape, use_snl):
        keys = jax.random.split(jax.random.PRNGKey(shape[0]), 4)
        v = jax.random.normal(keys[0], shape)
        drive = jax.random.normal(keys[1], shape)
        mask = (jax.random.uniform(keys[2], shape) < 0.1).astype(jnp.float32)
        noise = 0.05 * jnp.sign(jax.random.normal(keys[3], shape))
        out_v, out_s = ops.lif_step(v, drive, mask, noise, use_snl=use_snl)
        want_v, want_s = ref.lif_step_ref(v, drive, mask, noise, use_snl=use_snl)
        np.testing.assert_allclose(out_v, want_v, atol=1e-6)
        np.testing.assert_array_equal(out_s, want_s)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 50), st.integers(1, 200), st.integers(0, 2 ** 31 - 1))
    def test_property_hold_and_reset(self, m, n, seed):
        keys = jax.random.split(jax.random.PRNGKey(seed), 3)
        v = jax.random.normal(keys[0], (m, n)) * 0.3 - 0.5  # below SNL band
        drive = jax.random.normal(keys[1], (m, n))
        mask = jnp.zeros((m, n))
        out_v, out_s = ops.lif_step(v, drive, mask, jnp.zeros((m, n)))
        # With no winners and V below the SNL band, state must hold exactly.
        np.testing.assert_allclose(out_v, jnp.where(v >= 1.0, 0.0, v), atol=1e-6)
        # Spiked neurons are reset.
        assert bool(jnp.all(jnp.where(out_s > 0, out_v == 0.0, True)))


class TestNlq:
    @pytest.mark.parametrize("m,n,bits,kind", [
        (8, 128, 5, "nlq"), (64, 128, 5, "lin"), (16, 256, 6, "nlq"),
        (9, 130, 5, "nlq"), (128, 128, 4, "act"),
    ])
    def test_matches_ref(self, m, n, bits, kind):
        if kind == "nlq":
            cb = ima_lib.nlq_codebook(bits, -64, 64)
        elif kind == "lin":
            cb = ima_lib.linear_codebook(bits, -64, 64)
        else:
            cb = ima_lib.activation_codebook(bits, ima_lib.quadratic, -8, 8)
        x = jax.random.normal(jax.random.PRNGKey(m * 3 + n), (m, n)) * 30
        codes, y = ops.nlq_convert(x, cb.boundaries, cb.levels)
        want_c, want_y = ref.nlq_convert_ref(x, cb.boundaries, cb.levels)
        np.testing.assert_array_equal(codes, want_c)
        np.testing.assert_allclose(y, want_y, rtol=1e-6)

    def test_matches_core_ima(self):
        cb = ima_lib.nlq_codebook(5, -64, 64)
        x = jax.random.normal(jax.random.PRNGKey(9), (32, 128)) * 30
        codes, y = ops.nlq_convert(x, cb.boundaries, cb.levels)
        np.testing.assert_array_equal(codes, ima_lib.ima_convert(x, cb))
        np.testing.assert_allclose(y, ima_lib.ima_quantize(x, cb), rtol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 7), st.integers(0, 2 ** 31 - 1))
    def test_property_monotone_and_bounded(self, bits, seed):
        cb = ima_lib.nlq_codebook(bits, -64, 64)
        x = jnp.sort(jax.random.normal(jax.random.PRNGKey(seed), (4, 64)) * 40, -1)
        codes, y = ops.nlq_convert(x, cb.boundaries, cb.levels)
        assert bool(jnp.all(jnp.diff(codes, axis=-1) >= 0))  # monotone codes
        assert bool(jnp.all((codes >= 0) & (codes < cb.n_codes)))
