"""KV-cache quantization (§Perf knob) correctness: quantized decode must
track the bf16 decode closely, and the prefill->decode handoff must work in
quantized mode too."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import reduced
from repro.models import lm
from repro.nn import module


@pytest.mark.parametrize("mode,tol", [("int8", 0.08), ("int4", 0.6)])
def test_quantized_decode_tracks_fp(mode, tol):
    cfg = reduced(ARCHS["qwen2.5-32b"])
    cfg_q = dataclasses.replace(cfg, kv_quant=mode)
    params = module.materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    cache = lm.init_cache(cfg, b, 16)
    cache_q = lm.init_cache(cfg_q, b, 16)
    max_rel = 0.0
    for t in range(s):
        pos = jnp.full((b,), t, jnp.int32)
        lg, cache = lm.decode_step(params, cache, toks[:, t:t + 1], pos, cfg)
        lq, cache_q = lm.decode_step(params, cache_q, toks[:, t:t + 1], pos,
                                     cfg_q)
        rel = float(jnp.max(jnp.abs(lq - lg))
                    / (jnp.max(jnp.abs(lg)) + 1e-9))
        max_rel = max(max_rel, rel)
    assert max_rel < tol, max_rel
    # ranking agreement on the final step (what sampling actually uses)
    agree = float(jnp.mean((jnp.argmax(lq, -1) == jnp.argmax(lg, -1))))
    assert agree >= 0.5


def test_quantized_cache_structure():
    cfg = dataclasses.replace(reduced(ARCHS["qwen2.5-32b"]), kv_quant="int4")
    cache = lm.init_cache(cfg, 2, 16)
    blk = cache["b0"]
    assert blk["k"].dtype == jnp.uint8
    assert blk["k"].shape[-1] == cfg.hd // 2       # packed nibbles
    assert "k_scale" in blk and "v_scale" in blk


def test_quantized_prefill_handoff():
    cfg = dataclasses.replace(reduced(ARCHS["smollm-135m"]), kv_quant="int8")
    params = module.materialize(lm.param_specs(cfg), jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                              cfg.vocab_size)
    logits, _, cache = lm.forward(params, {"tokens": toks}, cfg, prefill=True)
    assert cache["b0"]["k"].dtype == jnp.int8
    # grow to decode length and continue from the quantized prefill cache
    cache = lm.pad_cache(cache, cfg, 16)
    assert cache["b0"]["k"].shape[2] == 16
    nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
    lg2, cache = lm.decode_step(params, cache, nxt,
                                jnp.full((1,), 8, jnp.int32), cfg)
    assert bool(jnp.all(jnp.isfinite(lg2)))


def test_prefill_pad_then_decode_matches_pure_decode():
    cfg = reduced(ARCHS["smollm-135m"])
    params = module.materialize(lm.param_specs(cfg), jax.random.PRNGKey(5))
    toks = jax.random.randint(jax.random.PRNGKey(6), (1, 6), 0,
                              cfg.vocab_size)
    # path A: prefill then one decode step
    lg_a, _, cache = lm.forward(params, {"tokens": toks}, cfg, prefill=True)
    cache = lm.pad_cache(cache, cfg, 12)
    nxt = toks[:, -1:]  # arbitrary next token
    la, _ = lm.decode_step(params, cache, nxt, jnp.full((1,), 6, jnp.int32),
                           cfg)
    # path B: pure step-by-step decode over the same 7 tokens
    cache_b = lm.init_cache(cfg, 1, 12)
    seq = jnp.concatenate([toks, nxt], axis=1)
    for t in range(7):
        lb, cache_b = lm.decode_step(params, cache_b, seq[:, t:t + 1],
                                     jnp.full((1,), t, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=2e-3,
                               atol=2e-3)
