"""Multi-layer fused stacks + the sequence-length / telemetry-population
regressions the depth change exposed.

Kernel level: the stacked one-launch kernel (inter-layer spikes never in
HBM, deep layers gated by the in-kernel occupancy of the previous layer's
winner set) must be bitwise-equal to the composed per-layer oracle chain
(``ref.fused_macro_multi_seq_ref``) — clean and noisy, across tile plans.

Model level: composed / fused-seq / fused-step 2-layer forwards agree
bitwise, and every forward normalizes by the events' actual T (not
``cfg.n_steps``).  Engine level: ``run()`` returns submission order and
``energy_report`` draws all stats from one population.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ima as ima_lib
from repro.core import macro as macro_lib
from repro.kernels import ops, ref
from repro.models import snn as snn_lib

KW = dict(drive_gain=0.25, beta=0.9, v_th1=1.0, v_th2=0.6, v_reset=0.0,
          v_lim=8.0)


def _tern(key, shape, rate=0.2):
    sparse = jax.random.uniform(jax.random.fold_in(key, 1), shape) < rate
    vals = jax.random.randint(key, shape, -1, 2)
    return (vals * sparse).astype(jnp.int8)


def _stack(key, n_in=96, widths=(64, 48), mcfg=None):
    mcfg = mcfg or macro_lib.CIMMacroConfig(mac_range=24.0)
    ks = jax.random.split(key, 2 * len(widths))
    w_ints, scales, f_in = [], [], n_in
    for li, w in enumerate(widths):
        w_ints.append(jax.random.randint(ks[2 * li], (f_in, w), -3, 4))
        scales.append(jnp.abs(jax.random.normal(ks[2 * li + 1], (w,)))
                      * 0.1 + 0.05)
        f_in = w
    return macro_lib.pack_kwn_stack(w_ints, scales, mcfg)


class TestMultiSeqKernelParity:
    """Stacked kernel vs composed per-layer oracle chain, bitwise."""

    T, M, N_IN = 6, 16, 96
    WIDTHS, KS = (64, 48), (7, 5)
    # default tiling + a ragged per-layer override: two distinct tile plans
    PLANS = (None, ((32, 32), (16, 24)))

    def _operands(self):
        key = jax.random.PRNGKey(0)
        x = _tern(jax.random.fold_in(key, 3), (self.T, self.M, self.N_IN),
                  0.15)
        stack = _stack(jax.random.fold_in(key, 4), self.N_IN, self.WIDTHS)
        planes = [(fw.msb, fw.lsb, fw.boundaries, fw.levels, fw.scale)
                  for fw in stack]
        vs = [jnp.zeros((self.M, w)) for w in self.WIDTHS]
        return x, stack, planes, vs

    @pytest.mark.fast
    @pytest.mark.parametrize("gate", [True, False])
    @pytest.mark.parametrize("tiles", PLANS)
    def test_clean_matches_oracle_chain(self, gate, tiles):
        x, _, planes, vs = self._operands()
        out = ops.fused_macro_multi_seq(
            x, planes, vs, None, ks=self.KS, use_snl=False, gate=gate,
            tile_shapes=tiles, **KW)
        v_fins, spk, mask, steps, cnts = ref.fused_macro_multi_seq_ref(
            x, planes, vs, None, ks=self.KS, use_snl=False, **KW)
        np.testing.assert_array_equal(np.asarray(out.spikes), np.asarray(spk))
        np.testing.assert_array_equal(np.asarray(out.mask), np.asarray(mask))
        for got, want in zip(out.v_outs, v_fins):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        for got, want in zip(out.steps, steps):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want[..., 0]))
        for got, want in zip(out.spike_counts, cnts):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.fast
    @pytest.mark.parametrize("tiles", PLANS)
    def test_noisy_matches_oracle_chain(self, tiles):
        """In-kernel IMA conversion noise + SNL, per-layer counter seeds."""
        x, stack, planes, vs = self._operands()
        mcfg = macro_lib.CIMMacroConfig(mac_range=24.0,
                                        ima_noise=ima_lib.IMANoiseModel())
        ima_kn = macro_lib.fused_kernel_noise(stack[0], mcfg)
        seeds = jnp.asarray([11, 22], jnp.int32)
        out = ops.fused_macro_multi_seq(
            x, planes, vs, None, ks=self.KS, use_snl=True, ima_noise=ima_kn,
            snl_amp=0.05, seeds=seeds, step_offset=3, gate=True,
            tile_shapes=tiles, **KW)
        v_fins, spk, _, steps, _ = ref.fused_macro_multi_seq_ref(
            x, planes, vs, None, ks=self.KS, use_snl=True, ima_noise=ima_kn,
            snl_amp=0.05, seeds=[11, 22], step_offset=3, **KW)
        np.testing.assert_array_equal(np.asarray(out.spikes), np.asarray(spk))
        for got, want in zip(out.v_outs, v_fins):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        for got, want in zip(out.steps, steps):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want[..., 0]))

    @pytest.mark.fast
    def test_step_cadence_matches_seq(self):
        """T=1 launches with carried membranes == the one-launch sequence."""
        x, stack, planes, vs = self._operands()
        mcfg = macro_lib.CIMMacroConfig(mac_range=24.0,
                                        ima_noise=ima_lib.IMANoiseModel())
        ima_kn = macro_lib.fused_kernel_noise(stack[0], mcfg)
        seeds = jnp.asarray([11, 22], jnp.int32)
        nkw = dict(ks=self.KS, use_snl=True, ima_noise=ima_kn, snl_amp=0.05,
                   seeds=seeds, gate=True, **KW)
        spk_steps, vs_c = [], vs
        for t in range(self.T):
            o = ops.fused_macro_multi_seq(x[t:t + 1], planes, vs_c, None,
                                          step_offset=t, **nkw)
            vs_c = list(o.v_outs)
            spk_steps.append(o.spikes[0])
        seq = ops.fused_macro_multi_seq(x, planes, vs, None, step_offset=0,
                                        **nkw)
        np.testing.assert_array_equal(np.asarray(jnp.stack(spk_steps)),
                                      np.asarray(seq.spikes))
        for got, want in zip(vs_c, seq.v_outs):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.fast
    def test_occupancy_counts_deep_layer_winner_gating(self):
        """The deep layer's occupancy is its in-kernel activity plan: with
        k winners per row, at most the winner-bearing K-tiles are occupied,
        and an all-zero input occupies nothing anywhere."""
        x, _, planes, vs = self._operands()
        out = ops.fused_macro_multi_seq(
            x, planes, vs, None, ks=self.KS, use_snl=False, gate=True,
            tile_shapes=((32, 32), (16, 24)), **KW)
        assert out.total_blocks > 0
        occ1 = np.asarray(out.occupancy[1])          # (T, row-tiles, 1)
        n_k1 = -(-self.WIDTHS[0] // 16)              # layer-1 K-tiles
        assert occ1.max() <= n_k1
        zero = ops.fused_macro_multi_seq(
            jnp.zeros_like(x), planes, vs, None, ks=self.KS, use_snl=False,
            gate=True, **KW)
        assert sum(int(jnp.sum(o)) for o in zero.occupancy) == 0


class TestMultiLayerModel:
    """2-layer SNNConfig stacks through every forward path."""

    def _setup(self):
        key = jax.random.PRNGKey(0)
        cfg = snn_lib.SNNConfig(n_in=64, hidden_layers=(48, 32), n_classes=5,
                                n_steps=20, k=7, k_layers=(7, 5))
        p = snn_lib.init_params(cfg, key)
        ev = _tern(jax.random.fold_in(key, 7), (4, 5, 64),
                   0.25).astype(jnp.float32)
        return cfg, p, ev, jax.random.fold_in(key, 9)

    def test_config_stack_fields(self):
        cfg, p, _, _ = self._setup()
        assert cfg.n_hidden == 32
        assert cfg.layer_widths == (48, 32)
        assert cfg.layer_k == (7, 5)
        assert [w.shape for w in p["w_hid"]] == [(64, 48), (48, 32)]
        with pytest.raises(ValueError):
            snn_lib.SNNConfig(n_in=8, hidden_layers=(16, 8), mode="nld")
        with pytest.raises(ValueError):
            snn_lib.SNNConfig(n_in=8, hidden_layers=(16, 8), k_layers=(3,))

    def test_single_layer_params_unchanged(self):
        """hidden_layers=(n,) must reproduce the legacy RNG stream."""
        key = jax.random.PRNGKey(3)
        a = snn_lib.init_params(snn_lib.SNNConfig(n_in=32, n_hidden=16), key)
        b = snn_lib.init_params(
            snn_lib.SNNConfig(n_in=32, hidden_layers=(16,)), key)
        np.testing.assert_array_equal(np.asarray(a["w_hid"]),
                                      np.asarray(b["w_hid"]))

    @pytest.mark.fast
    def test_composed_equals_fused_seq_and_step(self):
        cfg, p, ev, key = self._setup()
        lc, tc = snn_lib.forward_silicon(p, ev, cfg, key)
        ls, ts = snn_lib.forward_silicon(p, ev, cfg, key, fused="seq")
        lp, tp = snn_lib.forward_silicon(p, ev, cfg, key, fused="step")
        np.testing.assert_array_equal(np.asarray(lc), np.asarray(ls))
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lp))
        for name in ("adc_steps", "sops", "lif_updates"):
            np.testing.assert_array_equal(np.asarray(tc[name]),
                                          np.asarray(ts[name]),
                                          err_msg=f"telemetry {name}")
            np.testing.assert_array_equal(np.asarray(ts[name]),
                                          np.asarray(tp[name]),
                                          err_msg=f"telemetry {name}")
        np.testing.assert_array_equal(
            np.asarray(ts["skipped_block_ratio"]),
            np.asarray(tp["skipped_block_ratio"]))
        assert np.all(np.asarray(ts["skipped_block_ratio"]) >= 0.0)

    def test_noisy_seq_equals_step(self):
        cfg, p, ev, key = self._setup()
        noise = ima_lib.IMANoiseModel()
        ls, ts = snn_lib.forward_silicon(p, ev, cfg, key, fused="seq",
                                         noise=noise)
        lp, tp = snn_lib.forward_silicon(p, ev, cfg, key, fused="step",
                                         noise=noise)
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lp))
        np.testing.assert_array_equal(np.asarray(ts["adc_steps"]),
                                      np.asarray(tp["adc_steps"]))

    def test_forward_train_multi_runs_and_differs_per_depth(self):
        cfg, p, ev, _ = self._setup()
        logits = snn_lib.forward_train(p, ev, cfg)
        assert logits.shape == (4, 5)
        assert np.all(np.isfinite(np.asarray(logits)))

    def test_silicon_training_guard(self):
        from repro.train import silicon as silicon_lib
        cfg, p, ev, _ = self._setup()
        with pytest.raises(NotImplementedError):
            silicon_lib.forward_logits(p, ev, cfg, jnp.float32(0.0))

    def test_mac_telemetry_rejected_on_stacks(self):
        cfg, p, ev, key = self._setup()
        with pytest.raises(ValueError):
            snn_lib.forward_silicon(p, ev, cfg, key, fused="seq",
                                    mac_telemetry=True)


class TestSequenceLengthNormalization:
    """Logits must be invariant to cfg.n_steps when the events' T differs
    (the counts are normalized by events.shape[1]).  These pins fail on
    the pre-fix code, which divided by cfg.n_steps everywhere."""

    def _setup(self, **over):
        key = jax.random.PRNGKey(0)
        cfg = snn_lib.SNNConfig(n_in=64, n_hidden=48, n_classes=5,
                                n_steps=20, k=7, **over)
        p = snn_lib.init_params(cfg, key)
        ev = _tern(jax.random.fold_in(key, 7), (4, 5, 64),
                   0.25).astype(jnp.float32)
        return cfg, p, ev, jax.random.fold_in(key, 9)

    @pytest.mark.fast
    @pytest.mark.parametrize("fused", [False, "seq", "step"])
    def test_forward_silicon_invariant_to_cfg_n_steps(self, fused):
        cfg, p, ev, key = self._setup()
        cfg2 = dataclasses.replace(cfg, n_steps=12)
        a, ta = snn_lib.forward_silicon(p, ev, cfg, key, fused=fused)
        b, tb = snn_lib.forward_silicon(p, ev, cfg2, key, fused=fused)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(ta["adc_steps"]),
                                      np.asarray(tb["adc_steps"]))

    @pytest.mark.fast
    def test_forward_train_invariant_to_cfg_n_steps(self):
        cfg, p, ev, _ = self._setup()
        cfg2 = dataclasses.replace(cfg, n_steps=12)
        np.testing.assert_array_equal(
            np.asarray(snn_lib.forward_train(p, ev, cfg)),
            np.asarray(snn_lib.forward_train(p, ev, cfg2)))

    def test_silicon_forward_logits_invariant_to_cfg_n_steps(self):
        from repro.train import silicon as silicon_lib
        cfg, p, ev, _ = self._setup()
        cfg2 = dataclasses.replace(cfg, n_steps=12)
        a = silicon_lib.forward_logits(p, ev, cfg, jnp.float32(0.0))
        b = silicon_lib.forward_logits(p, ev, cfg2, jnp.float32(0.0))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("fused", [False, "seq", "step"])
    def test_multilayer_invariant_to_cfg_n_steps(self, fused):
        cfg, p, ev, key = self._setup(hidden_layers=(48, 32))
        cfg2 = dataclasses.replace(cfg, n_steps=12)
        a, _ = snn_lib.forward_silicon(p, ev, cfg, key, fused=fused)
        b, _ = snn_lib.forward_silicon(p, ev, cfg2, key, fused=fused)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestEngineRegressions:
    """energy_report population consistency + submission-order returns."""

    def _engine(self, mode="kwn"):
        from repro.serve.engine import SNNEventEngine
        cfg = snn_lib.SNNConfig(n_in=8, n_hidden=8, n_classes=2, mode=mode,
                                n_branches=2)
        p = snn_lib.init_params(cfg, jax.random.PRNGKey(0))
        return SNNEventEngine(cfg, p, batch_slots=2)

    @pytest.mark.fast
    def test_energy_report_single_population(self):
        """A completed request with a skip ratio but no adc_steps must not
        dilute mean_skipped_block_ratio — one population for all stats."""
        from repro.serve.engine import EventRequest
        engine = self._engine()
        engine.completed.extend([
            EventRequest(uid=0, events=None, adc_steps=10.0,
                         skipped_block_ratio=0.2),
            EventRequest(uid=1, events=None, adc_steps=12.0,
                         skipped_block_ratio=0.4),
            EventRequest(uid=2, events=None, adc_steps=None,
                         skipped_block_ratio=1.0),
        ])
        rep = engine.energy_report("nmnist")
        assert rep["requests"] == 2
        assert rep["mean_adc_steps"] == pytest.approx(11.0)
        assert rep["mean_skipped_block_ratio"] == pytest.approx(0.3)

    @pytest.mark.fast
    def test_energy_report_empty_contract(self):
        """{} for no measured requests, and for NLD mode (no early stop)."""
        from repro.serve.engine import EventRequest
        assert self._engine().energy_report("nmnist") == {}
        nld = self._engine(mode="nld")
        nld.completed.append(EventRequest(uid=0, events=None, adc_steps=31.0))
        assert nld.energy_report("nmnist") == {}

    def test_run_returns_submission_order(self):
        from repro.serve.engine import EventRequest, SNNEventEngine
        key = jax.random.PRNGKey(0)
        cfg = snn_lib.SNNConfig(n_in=32, n_hidden=16, n_classes=3, n_steps=4,
                                k=4, use_snl=False)
        p = snn_lib.init_params(cfg, key)
        ev = _tern(jax.random.fold_in(key, 1), (6, 4, 32),
                   0.3).astype(jnp.float32)
        # densities vary per request; submit in an arbitrary fixed order
        uids = [3, 0, 5, 1, 4, 2]
        engine = SNNEventEngine(cfg, p, batch_slots=2, pack_by_density=True)
        for u in uids:
            engine.submit(EventRequest(uid=u, events=ev[u]))
        done = engine.run()
        assert [r.uid for r in done] == uids
        assert all(r.logits is not None for r in done)
