"""Numerical oracles for the nn layer implementations:
blockwise online-softmax attention vs naive softmax(QK^T)V; sliding-window
blocked attention vs naive masked attention; chunked mLSTM vs naive
sequential recurrence; RG-LRU associative scan vs sequential scan; KV
quantization roundtrip; MoE dispatch invariants (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.nn import attention, kvq, moe, recurrent


def _naive_attention(q, k, v, causal=True, window=None, softcap=None):
    b, s, h, hd = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(hd)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    i, j = jnp.arange(s)[:, None], jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= i >= j
    if window is not None:
        mask &= (i - j) < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


class TestBlockwiseAttention:
    @pytest.mark.parametrize("s,chunk", [(64, 16), (128, 32), (96, 32),
                                         (64, 64)])
    def test_causal_matches_naive(self, s, chunk):
        key = jax.random.PRNGKey(s)
        q, k, v = [jax.random.normal(jax.random.fold_in(key, i), (2, s, 3, 8))
                   for i in range(3)]
        out = attention.blockwise_attention(q, k, v, causal=True, chunk=chunk)
        ref = _naive_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_bidirectional_matches_naive(self):
        key = jax.random.PRNGKey(1)
        q, k, v = [jax.random.normal(jax.random.fold_in(key, i), (2, 64, 2, 8))
                   for i in range(3)]
        out = attention.blockwise_attention(q, k, v, causal=False, chunk=16)
        ref = _naive_attention(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_softcap_matches_naive(self):
        key = jax.random.PRNGKey(2)
        q, k, v = [jax.random.normal(jax.random.fold_in(key, i), (1, 64, 2, 8))
                   * 3 for i in range(3)]
        out = attention.blockwise_attention(q, k, v, causal=True, chunk=16,
                                            softcap=10.0)
        ref = _naive_attention(q, k, v, causal=True, softcap=10.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)

    @pytest.mark.parametrize("window", [16, 32])
    def test_sliding_window_matches_naive(self, window):
        key = jax.random.PRNGKey(3)
        s = 96 if window == 32 else 64
        q, k, v = [jax.random.normal(jax.random.fold_in(key, i), (2, s, 2, 8))
                   for i in range(3)]
        out = attention.blockwise_attention(q, k, v, causal=True,
                                            window=window)
        ref = _naive_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_grads_match_naive(self):
        key = jax.random.PRNGKey(4)
        q, k, v = [jax.random.normal(jax.random.fold_in(key, i), (1, 64, 2, 8))
                   for i in range(3)]

        g1 = jax.grad(lambda q: attention.blockwise_attention(
            q, k, v, causal=True, chunk=16).sum())(q)
        g2 = jax.grad(lambda q: _naive_attention(q, k, v, causal=True).sum())(q)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-4)


class TestMLSTMOracle:
    def _naive_mlstm(self, q, k, v, log_f, log_i):
        """Sequential stabilized mLSTM recurrence (the definition)."""
        b, s, h, hd = q.shape
        scale = 1.0 / np.sqrt(hd)
        c = jnp.zeros((b, h, hd, hd))
        n = jnp.zeros((b, h, hd))
        m = jnp.full((b, h), -1e9)
        outs = []
        for t in range(s):
            m_new = jnp.maximum(log_f[:, t] + m, log_i[:, t])
            c = (jnp.exp(log_f[:, t] + m - m_new)[..., None, None] * c
                 + jnp.exp(log_i[:, t] - m_new)[..., None, None]
                 * jnp.einsum("bhd,bhe->bhde", k[:, t], v[:, t]))
            n = (jnp.exp(log_f[:, t] + m - m_new)[..., None] * n
                 + jnp.exp(log_i[:, t] - m_new)[..., None] * k[:, t])
            m = m_new
            num = jnp.einsum("bhd,bhde->bhe", q[:, t], c) * scale
            den = jnp.abs(jnp.einsum("bhd,bhd->bh", q[:, t], n)) * scale
            outs.append(num / jnp.maximum(den, jnp.exp(-m))[..., None])
        return jnp.stack(outs, axis=1)  # (B,S,H,hd)

    @pytest.mark.parametrize("s,chunk", [(16, 4), (32, 8), (32, 32)])
    def test_chunked_matches_sequential(self, s, chunk):
        key = jax.random.PRNGKey(7)
        b, h, hd = 2, 2, 4
        ks = jax.random.split(key, 5)
        q, k, v = [jax.random.normal(ks[i], (b, s, h, hd)) for i in range(3)]
        log_f = jax.nn.log_sigmoid(jax.random.normal(ks[3], (b, s, h)) + 1.0)
        log_i = jax.random.normal(ks[4], (b, s, h)) * 0.5

        ref = self._naive_mlstm(q, k, v, log_f, log_i)

        state = recurrent.mlstm_init_state(b, h, hd, jnp.float32)
        outs = []
        for c0 in range(0, s, chunk):
            sl = slice(c0, c0 + chunk)
            o, state = recurrent._mlstm_chunk(q[:, sl], k[:, sl], v[:, sl],
                                              log_f[:, sl], log_i[:, sl],
                                              state)
            outs.append(o)
        out = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


class TestRGLRUOracle:
    def test_assoc_scan_matches_sequential(self):
        key = jax.random.PRNGKey(9)
        b, s, d = 2, 24, 8
        a = jax.nn.sigmoid(jax.random.normal(key, (b, s, d)))
        x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d))

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        _, h_par = jax.lax.associative_scan(combine, (a, x), axis=1)
        h_seq = []
        h = jnp.zeros((b, d))
        for t in range(s):
            h = a[:, t] * h + x[:, t]
            h_seq.append(h)
        np.testing.assert_allclose(np.asarray(h_par),
                                   np.asarray(jnp.stack(h_seq, 1)),
                                   rtol=1e-5, atol=1e-5)

    def test_decode_continues_forward(self):
        """rglru_forward final state must continue identically step-by-step."""
        key = jax.random.PRNGKey(11)
        d = 8
        p = {k: v for k, v in zip(
            ["w_in", "w_gate_branch", "conv", "w_a", "w_x", "lam", "w_out"],
            [None] * 7)}
        from repro.nn.module import materialize
        from repro.nn.recurrent import rglru_specs
        p = materialize(rglru_specs(d, d), key)
        x = jax.random.normal(jax.random.fold_in(key, 1), (1, 12, d))
        out_full, st = recurrent.rglru_forward(p, x, return_state=True)
        # replay the last step from the state after s-1 steps
        out_prefix, st_prefix = recurrent.rglru_forward(p, x[:, :-1],
                                                        return_state=True)
        out_step, _ = recurrent.rglru_decode_step(p, x[:, -1:], st_prefix)
        np.testing.assert_allclose(np.asarray(out_step),
                                   np.asarray(out_full[:, -1:]),
                                   rtol=1e-4, atol=1e-4)


class TestKVQuant:
    @pytest.mark.parametrize("mode,tol", [("int8", 0.012), ("int4", 0.16)])
    def test_roundtrip_error(self, mode, tol):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 32))
        q, s = kvq.quantize(x, mode)
        out = kvq.dequantize(q, s, mode, jnp.float32)
        rel = float(jnp.max(jnp.abs(out - x)) / jnp.max(jnp.abs(x)))
        assert rel < tol

    def test_int4_packing_shape(self):
        x = jnp.ones((2, 8, 2, 64))
        q, s = kvq.quantize(x, "int4")
        assert q.shape == (2, 8, 2, 32) and q.dtype == jnp.uint8

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from(["int8", "int4"]))
    def test_property_scale_invariance(self, seed, mode):
        # quantize(c*x) == c * quantize(x) up to quantization error
        x = jax.random.normal(jax.random.PRNGKey(seed), (4, 16))
        for c in (0.01, 100.0):
            q1, s1 = kvq.quantize(x, mode)
            q2, s2 = kvq.quantize(x * c, mode)
            a = kvq.dequantize(q1, s1, mode, jnp.float32) * c
            b = kvq.dequantize(q2, s2, mode, jnp.float32)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=float(
                                           jnp.max(jnp.abs(x)) * c * 0.2))


class TestMoEDispatchInvariants:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 40), st.integers(2, 16), st.integers(1, 4),
           st.integers(0, 2 ** 31 - 1))
    def test_dispatch_combine_conservation(self, t, e, k, seed):
        k = min(k, e)
        key = jax.random.PRNGKey(seed)
        logits = jax.random.normal(key, (t, e))
        gate, idx, _ = moe.router_topk(logits, k)
        cap = t  # no drops
        disp, comb = moe._dispatch_onehot(idx, gate, e, cap, jnp.float32)
        # each (token, slot) used at most once; each token dispatched k times
        assert bool(jnp.all(disp.sum(axis=(1, 2)) == k))
        # each expert slot holds at most one token
        assert bool(jnp.all(disp.sum(axis=0) <= 1.0))
        # combine weights sum to 1 per token (gates renormalized, no drops)
        np.testing.assert_allclose(np.asarray(comb.sum(axis=(1, 2))),
                                   np.ones(t), rtol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(4, 32), st.integers(0, 2 ** 31 - 1))
    def test_capacity_drops_monotone(self, t, seed):
        e, k = 4, 2
        logits = jax.random.normal(jax.random.PRNGKey(seed), (t, e))
        gate, idx, _ = moe.router_topk(logits, k)
        kept = []
        for cap in (1, 2, t):
            disp, _ = moe._dispatch_onehot(idx, gate, e, cap, jnp.float32)
            kept.append(float(disp.sum()))
        assert kept[0] <= kept[1] <= kept[2]
        assert kept[2] == t * k  # cap=t keeps everything
