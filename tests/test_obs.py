"""Observability subsystem tests: tracer invariants, the disabled-tracer
fast path, histogram bucket semantics, Perfetto schema round-trip, and the
engine-integration terminal-counter invariant.

The pure-python tests carry ``@pytest.mark.fast`` (they cost
milliseconds); the engine-integration tests live in the default tier —
``make obs-smoke`` covers the traced-engine path in CI.
"""

from __future__ import annotations

import json
import threading

import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

fast = pytest.mark.fast


# --- tracer ----------------------------------------------------------------

@fast
def test_span_records_name_track_duration():
    tr = obs_trace.Tracer()
    with tr.span("work", track="lane", args={"x": 1}):
        pass
    (name, track, t0, dur, args), = tr.spans()
    assert name == "work" and track == "lane" and args == {"x": 1}
    assert t0 > 0 and dur >= 0


@fast
def test_span_nesting_and_ordering():
    """A child span closes first but sits inside the parent's interval."""
    tr = obs_trace.Tracer()
    with tr.span("outer", track="t"):
        with tr.span("inner", track="t"):
            pass
    spans = {s[0]: s for s in tr.spans()}
    assert list(spans) == ["inner", "outer"]   # completion order
    _, _, t0_out, dur_out, _ = spans["outer"]
    _, _, t0_in, dur_in, _ = spans["inner"]
    assert t0_out <= t0_in
    assert t0_in + dur_in <= t0_out + dur_out
    assert dur_in <= dur_out


@fast
def test_begin_end_explicit_api_merges_args():
    tr = obs_trace.Tracer()
    h = tr.begin("step", track="lane", args={"a": 1})
    tr.end(h, args={"b": 2})
    (_, _, _, _, args), = tr.spans()
    assert args == {"a": 1, "b": 2}


@fast
def test_disabled_tracer_is_null_and_allocation_free():
    tr = obs_trace.Tracer(enabled=False)
    # span() returns the shared singleton — no per-call object
    s1, s2 = tr.span("a"), tr.span("b", track="t")
    assert s1 is s2
    with s1:
        pass
    # begin() returns None; end(None) is a no-op
    h = tr.begin("a")
    assert h is None
    tr.end(h)
    tr.instant("marker")
    assert len(tr) == 0 and tr.spans() == []


@fast
def test_ring_buffer_caps_and_counts_drops():
    tr = obs_trace.Tracer(capacity=4)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert len(tr) == 4
    assert tr.dropped == 6
    assert [s[0] for s in tr.spans()] == ["s6", "s7", "s8", "s9"]


@fast
def test_tracer_thread_safety():
    tr = obs_trace.Tracer(capacity=10_000)

    def worker(k):
        for i in range(100):
            with tr.span(f"w{k}.{i}", track=f"thread{k}"):
                pass

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr) == 400


@fast
def test_global_tracer_swap():
    mine = obs_trace.Tracer()
    prev = obs_trace.set_tracer(mine)
    try:
        assert obs_trace.get_tracer() is mine
    finally:
        obs_trace.set_tracer(prev)
    assert obs_trace.get_tracer() is prev


# --- Perfetto export -------------------------------------------------------

@fast
def test_chrome_trace_schema_round_trip(tmp_path):
    tr = obs_trace.Tracer()
    with tr.span("outer", track="scheduler"):
        with tr.span("inner", track="scheduler", args={"k": "v"}):
            pass
    with tr.span("resident", track="slot00"):
        pass
    path = tmp_path / "trace.json"
    n = tr.export(str(path))
    assert n == 3
    doc = json.loads(path.read_text())           # loads in plain json
    assert isinstance(doc["traceEvents"], list)
    assert doc["displayTimeUnit"] == "ms"
    for ev in doc["traceEvents"]:
        for key in ("ph", "ts", "pid", "tid"):   # required event keys
            assert key in ev, f"missing {key} in {ev}"
    xs = [ev for ev in doc["traceEvents"] if ev["ph"] == "X"]
    assert len(xs) == 3
    for ev in xs:
        assert isinstance(ev["dur"], float) and ev["ts"] >= 0
    # one thread_name metadata event per named track
    names = {ev["args"]["name"] for ev in doc["traceEvents"]
             if ev["ph"] == "M" and ev["name"] == "thread_name"}
    assert {"scheduler", "slot00"} <= names
    # distinct tracks get distinct tids; same track shares one
    tids = {ev["cat"]: ev["tid"] for ev in xs}
    assert tids["scheduler"] != tids["slot00"]


@fast
def test_export_validates_with_obs_report(tmp_path):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "obs_report", "tools/obs_report.py")
    obs_report = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(obs_report)
    tr = obs_trace.Tracer()
    with tr.span("a", track="t"):
        pass
    path = tmp_path / "t.json"
    tr.export(str(path))
    assert obs_report.check_trace(str(path)) == []
    # corrupt: drop a required key
    doc = json.loads(path.read_text())
    del doc["traceEvents"][-1]["tid"]
    path.write_text(json.dumps(doc))
    assert obs_report.check_trace(str(path))


# --- metrics ---------------------------------------------------------------

@fast
def test_counter_monotonic():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("hits")
    c.inc()
    c.inc(4)
    assert reg.value("hits") == 5
    with pytest.raises(ValueError):
        c.inc(-1)


@fast
def test_labeled_series_are_independent():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("terminal_total", state="completed").inc(3)
    reg.counter("terminal_total", state="expired").inc()
    assert reg.value("terminal_total", state="completed") == 3
    assert reg.value("terminal_total", state="expired") == 1
    assert reg.value("terminal_total", state="rejected") == 0  # untouched


@fast
def test_histogram_bucket_edges_le_semantics():
    h = obs_metrics.Histogram(buckets=(1.0, 2.0, 5.0))
    for v in (0.5, 1.0, 1.5, 2.0, 2.1, 5.0, 7.0):
        h.observe(v)
    # le semantics: a value exactly on an edge lands in that bucket
    assert h.counts == [2, 2, 2]      # (.5,1) (1.5,2) (2.1,5)
    assert h.overflow == 1            # 7.0 beyond the last edge
    assert h.total == 7
    assert h.min == 0.5 and h.max == 7.0
    assert h.sum == pytest.approx(19.1)


@fast
def test_histogram_rejects_unsorted_edges():
    with pytest.raises(ValueError):
        obs_metrics.Histogram(buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        obs_metrics.Histogram(buckets=())


@fast
def test_histogram_quantiles():
    h = obs_metrics.Histogram(buckets=(1.0, 2.0, 4.0, 8.0))
    for v in [0.5] * 50 + [3.0] * 45 + [10.0] * 5:
        h.observe(v)
    assert h.quantile(0.5) == 1.0      # rank 50 is in the first bucket
    assert h.quantile(0.95) == 4.0
    assert h.quantile(1.0) == 10.0     # overflow -> exact max
    assert obs_metrics.Histogram().quantile(0.5) is None


@fast
def test_histogram_merge():
    a = obs_metrics.Histogram(buckets=(1.0, 2.0))
    b = obs_metrics.Histogram(buckets=(1.0, 2.0))
    a.observe(0.5)
    b.observe(1.5)
    b.observe(9.0)
    a.merge(b)
    assert a.counts == [1, 1] and a.overflow == 1
    assert a.total == 3 and a.min == 0.5 and a.max == 9.0
    with pytest.raises(ValueError):
        a.merge(obs_metrics.Histogram(buckets=(3.0,)))


@fast
def test_registry_merge_and_exports():
    a = obs_metrics.MetricsRegistry()
    b = obs_metrics.MetricsRegistry()
    a.counter("n").inc(2)
    b.counter("n").inc(3)
    b.gauge("depth").set(7)
    b.histogram("ms", buckets=(1.0, 10.0)).observe(0.5)
    a.merge(b)
    assert a.value("n") == 5
    assert a.value("depth") == 7
    doc = a.to_dict()
    assert {s["name"] for s in doc["metrics"]} == {"n", "depth", "ms"}
    json.dumps(doc)                    # JSON-safe
    prom = a.to_prometheus()
    assert "# TYPE n counter" in prom
    assert 'ms_bucket{le="1"} 1' in prom
    assert 'ms_bucket{le="+Inf"} 1' in prom
    assert "ms_count 1" in prom


@fast
def test_registry_type_conflicts_raise():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("x").inc()
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(TypeError):
        reg.histogram("x")


# --- engine integration ----------------------------------------------------

def _tiny_engine(**kw):
    import jax
    from repro.models import snn as snn_lib
    from repro.serve.engine import SNNEventEngine
    cfg = snn_lib.SNNConfig(n_in=16, n_hidden=8, n_classes=3, n_steps=6,
                            k=3)
    params = snn_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, SNNEventEngine(cfg, params, batch_slots=2,
                                       round_steps=3, seed=1, **kw)


def _req(uid, t=6, n_in=16, **kw):
    from repro.serve.engine import EventRequest
    rng = np.random.default_rng(uid)
    ev = (rng.random((t, n_in)) < 0.3).astype(np.float32)
    return EventRequest(uid=uid, events=ev, **kw)


def test_every_terminal_state_increments_exactly_one_counter():
    """The PR 9 'exactly one terminal state' invariant, now countable:
    completed + rejected + expired counters == submissions, per state."""
    from repro.serve import lifecycle
    cfg, params, eng = _tiny_engine(max_pending=3)
    # the dead-on-arrival request goes first so shedding (newest-first)
    # never touches it: it must reach EXPIRED, not REJECTED
    subs = [eng.submit(_req(90, deadline_ms=0.0))]
    subs += [eng.submit(_req(i)) for i in range(5)]         # 3 shed
    eng.run()
    m = eng.metrics
    by_state = {s: m.value("terminal_total", state=s)
                for s in lifecycle.TERMINAL_STATES}
    assert by_state["completed"] == len(eng.completed)
    assert by_state["rejected"] == len(eng.rejected) == 3
    assert by_state["expired"] == len(eng.expired)
    assert sum(by_state.values()) == len(subs)
    for r in subs:
        assert r.state in lifecycle.TERMINAL_STATES
    assert m.value("shed_total") == len(eng.rejected)
    assert m.value("expired_total") == len(eng.expired)


def test_engine_trace_renders_residency_and_phases(tmp_path):
    tracer = obs_trace.Tracer()
    cfg, params, eng = _tiny_engine(tracer=tracer)
    for i in range(3):
        eng.submit(_req(i))
    eng.run()
    path = tmp_path / "engine_trace.json"
    tracer.export(str(path))
    doc = json.loads(path.read_text())
    xs = [ev for ev in doc["traceEvents"] if ev["ph"] == "X"]
    cats = {ev["cat"] for ev in xs}
    names = {ev["name"] for ev in xs}
    assert "scheduler" in cats and "slot00" in cats and "slot01" in cats
    assert {"tick", "expire", "preempt", "admit", "round", "evict"} <= names
    # request residency spans carry the lifecycle outcome
    res = [ev for ev in xs if ev["cat"].startswith("slot")]
    assert len(res) == 3
    assert all(ev["args"]["outcome"] == "completed" for ev in res)
    # a residency span contains at least one whole round span in time
    rounds = [ev for ev in xs if ev["name"] == "round"]
    r0 = res[0]
    assert any(r0["ts"] <= ev["ts"] and
               ev["ts"] + ev["dur"] <= r0["ts"] + r0["dur"] + 1e-3
               for ev in rounds)


def test_preemption_stamps_dwell_time_and_counters():
    tracer = obs_trace.Tracer()
    cfg, params, eng = _tiny_engine(tracer=tracer)
    reqs = [eng.submit(_req(i, t=6)) for i in range(2)]
    eng.run(max_rounds=1)
    victim = next(r for r in eng._slot_req if r is not None)
    eng.preempt_request(victim.uid, backoff=False)
    assert victim.preempted_ms == 0.0          # still checkpointed out
    eng.run()
    assert victim.preempted_ms > 0.0           # dwell stamped on restore
    assert victim.latency_ms > victim.preempted_ms
    m = eng.metrics
    assert m.value("preempted_total") == eng.preemption_count == 1
    assert m.value("terminal_total", state="completed") == len(reqs)
    # the preempted residency shows as two spans on slot tracks
    res = [s for s in tracer.spans() if s[1] and s[1].startswith("slot")
           and f"req{victim.uid}" == s[0]]
    assert len(res) == 2
    outcomes = [s[4]["outcome"] for s in res]
    assert outcomes.count("preempted") == 1
    assert outcomes.count("completed") == 1


def test_per_request_table_carries_preempted_ms():
    cfg, params, eng = _tiny_engine()
    for i in range(2):
        eng.submit(_req(i, t=6))
    eng.run(max_rounds=1)
    victim = next(r for r in eng._slot_req if r is not None)
    eng.preempt_request(victim.uid, backoff=False)
    eng.run()
    rep = eng.energy_report("dvs_gesture")
    rows = {row["uid"]: row for row in rep["per_request"]}
    assert rows[victim.uid]["preempted_ms"] > 0.0
    other = next(uid for uid in rows if uid != victim.uid)
    assert rows[other]["preempted_ms"] == 0.0
    # satellite: round-time quantiles from the measured sample window
    assert 0.0 < rep["round_ms_p50"] <= rep["round_ms_p95"]


def test_round_ms_estimate_prefers_p95_when_warm():
    from repro.serve import engine as engine_mod
    cfg, params, eng = _tiny_engine()
    eng._round_ms = 1.0                         # EMA says 1 ms
    eng._round_samples.extend([1.0] * 7)
    assert eng._round_ms_estimate() == 1.0      # < 8 samples: EMA wins
    eng._round_samples.append(50.0)             # tail the EMA would hide
    assert len(eng._round_samples) == \
        engine_mod.ROUND_MS_P95_MIN_SAMPLES
    assert eng._round_ms_estimate() == 50.0     # p95 of the window
    assert engine_mod.ROUND_MS_EMA_DECAY == 0.9


def test_transfer_spans_carry_byte_counts():
    from repro.models import snn as snn_lib
    tracer = obs_trace.Tracer()
    prev = obs_trace.set_tracer(tracer)
    try:
        cfg, params, eng = _tiny_engine()
        for i in range(2):
            eng.submit(_req(i, t=6))
        eng.run(max_rounds=1)
        victim = next(r for r in eng._slot_req if r is not None)
        eng.preempt_request(victim.uid, backoff=False)
        want = snn_lib.checkpoint_nbytes(victim._ckpt)
        eng.run()
    finally:
        obs_trace.set_tracer(prev)
    transfers = [s for s in tracer.spans() if s[1] == "transfer"]
    names = [s[0] for s in transfers]
    assert "checkpoint_save" in names and "checkpoint_restore" in names
    for s in transfers:
        assert s[4]["bytes"] == want
        assert s[4]["direction"] in ("device_to_host", "host_to_device")


def test_disabled_tracing_leaves_engine_results_bitwise_identical():
    """Tracing must observe, never perturb: logits with a live tracer are
    bitwise-equal to the default (disabled) run."""
    import jax.numpy as jnp
    cfg, params, eng_off = _tiny_engine()
    reqs_off = [eng_off.submit(_req(i)) for i in range(3)]
    eng_off.run()
    cfg, params, eng_on = _tiny_engine(tracer=obs_trace.Tracer())
    reqs_on = [eng_on.submit(_req(i)) for i in range(3)]
    eng_on.run()
    for a, b in zip(reqs_off, reqs_on):
        assert jnp.array_equal(a.logits, b.logits)
        assert a.adc_steps == b.adc_steps
