"""Pipeline-parallelism test: GPipe over a 2-stage axis must equal the
sequential composition of the stages (subprocess with 2 simulated devices).

Triage note (PR 2): the long-standing failure here was NOT a numerical
tolerance issue — the subprocess died on ``jax.sharding.AxisType``
(missing on the container jax) and on the then-missing
``repro.dist.pipeline`` module.  With ``repro.compat.make_mesh`` and the
GPipe implementation in place, the pipeline matches the sequential
reference within the original 1e-5 tolerances; nothing numerical changed.
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import compat
    from repro.dist.pipeline import gpipe, bubble_fraction

    mesh = compat.make_mesh((2,), ("pod",))
    D = 8
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (2, D, D)) / jnp.sqrt(D)   # one W per stage

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    params = {"w": w}
    x_mb = jax.random.normal(jax.random.fold_in(key, 1), (4, 3, D))

    y = jax.jit(lambda p, x: gpipe(stage_fn, p, x, mesh))(params, x_mb)
    # sequential reference
    ref = x_mb
    for s in range(2):
        ref = jnp.tanh(ref @ w[s])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert abs(bubble_fraction(4, 2) - 1/5) < 1e-9
    print("gpipe OK")
""")


def test_gpipe_matches_sequential():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "gpipe OK" in r.stdout
