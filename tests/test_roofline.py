"""Roofline machinery tests: HLO collective parser (shapes, wire factors,
while-loop trip attribution) and flops-model sanity across every cell."""

import pytest

from repro.roofline import analysis, flops_model


class TestCollectiveParser:
    def test_shape_bytes(self):
        assert analysis._shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
        assert analysis._shape_bytes("bf16[2,3]{1,0}") == 12
        assert analysis._shape_bytes("(f32[4]{0}, s8[8]{0})") == 16 + 8
        assert analysis._shape_bytes("pred[]") == 0 or True  # scalar: no dims

    def test_parse_real_compiled_module(self):
        # build a tiny 2-device module with a real all-reduce
        import os
        import subprocess
        import sys
        import textwrap
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro import compat
            from repro.roofline import analysis
            mesh = compat.make_mesh((4,), ("model",))
            w = jax.ShapeDtypeStruct((512, 256), jnp.float32)
            x = jax.ShapeDtypeStruct((8, 512), jnp.float32)
            f = lambda w, x: jnp.sum(x @ w)
            c = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P("model", None)),
                NamedSharding(mesh, P(None, "model")))).lower(w, x).compile()
            coll = analysis.collective_bytes(c.as_text())
            # contraction dim sharded -> partial sums all-reduced
            assert coll["all-reduce_count"] >= 1, coll
            assert coll["all-reduce_bytes"] > 0
            print("parser OK", coll["all-reduce_bytes"])
        """)
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
        r = subprocess.run([sys.executable, "-c", script], env=env,
                           capture_output=True, text=True, timeout=540)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "parser OK" in r.stdout

    def test_loop_bound_extraction(self):
        cond = "compare(s32[] %x, s32[] constant(61)), direction=LT"
        assert analysis._loop_bound(cond) == 61


class TestFlopsModel:
    @pytest.mark.parametrize("multi", [False, True])
    def test_all_cells_finite_and_positive(self, multi):
        from repro.configs import cells, get_config
        for arch, shape in cells():
            cfg = get_config(arch)
            r = flops_model.analyze(cfg, shape, flops_model.mesh_for(multi),
                                    n_micro=8 if shape == "train_4k" else 1)
            for k in ("compute_s", "memory_s", "collective_s"):
                assert r[k] >= 0.0, (arch, shape, k)
            assert r["bound_s"] > 0
            assert 0 <= r["roofline_frac"] <= 1.2, (arch, shape, r)

    def test_multi_pod_scales_compute_down(self):
        from repro.configs import get_config
        cfg = get_config("qwen2.5-32b")
        s1 = flops_model.analyze(cfg, "train_4k", flops_model.mesh_for(False),
                                 n_micro=8)
        s2 = flops_model.analyze(cfg, "train_4k", flops_model.mesh_for(True),
                                 n_micro=8)
        assert s2["compute_s"] == pytest.approx(s1["compute_s"] / 2, rel=0.01)

    def test_kv_quant_reduces_decode_memory(self):
        import dataclasses
        from repro.configs import get_config
        cfg = get_config("qwen2.5-32b")
        base = flops_model.analyze(cfg, "decode_32k",
                                   flops_model.mesh_for(False))
        q8 = flops_model.analyze(dataclasses.replace(cfg, kv_quant="int8"),
                                 "decode_32k", flops_model.mesh_for(False))
        q4 = flops_model.analyze(dataclasses.replace(cfg, kv_quant="int4"),
                                 "decode_32k", flops_model.mesh_for(False))
        assert q8["memory_s"] < base["memory_s"] * 0.65
        assert q4["memory_s"] < q8["memory_s"]

    def test_useful_flops_below_impl(self):
        from repro.configs import get_config
        cfg = get_config("nemotron-4-340b")
        r = flops_model.analyze(cfg, "train_4k", flops_model.mesh_for(False),
                                n_micro=8)
        assert r["useful_flops_per_device"] <= r["flops_per_device"]
        assert r["model_flops_per_device"] <= r["flops_per_device"]
