"""Continuous-batching SNNEventEngine + serving-path regressions.

Tentpole coverage: mid-flight admission/eviction with persistent slot
membranes must give every request results bitwise-identical to a one-shot
batch-1 ``forward_silicon(fused="seq")`` run — clean (PRBS SNL) and noisy
(in-kernel counter streams via the ``row_ctl`` lane) — independent of slot
placement, co-batched traffic, round size, or the admission policy.

Bugfix pins (each fails on the pre-fix engine): ``run()`` returning the
cumulative history instead of this call's drainage, ``_run_batch`` crashing
on mixed event-stream lengths, and ``BatchedEngine``'s unsplit prefill key /
admission-charged round budget.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ima as ima_lib
from repro.models import snn as snn_lib
from repro.serve.engine import EventRequest, SNNEventEngine


@pytest.fixture(scope="module", autouse=True)
def _drop_compile_caches():
    """Release this module's compiled executables at teardown.

    The parity matrix here jit-compiles dozens of interpret-mode Pallas
    variants (one one-shot entry per distinct stream length, stream
    rounds per (slots, round_steps), per-T legacy buckets).  Leaving all
    of them resident has been observed to push jaxlib 0.4.36's CPU
    compiler into a segfault when a later module (test_system's LM
    remat backward) compiles its largest graph in the same process —
    the full suite died at the same test deterministically, and passed
    with this module excluded.  Dropping the caches once the module is
    done keeps the suite's peak compiler state at the pre-PR level; the
    few shared entries later modules recompile cost seconds.
    """
    yield
    jax.clear_caches()


def _cfg(**kw):
    base = dict(n_in=32, n_hidden=16, n_classes=3, n_steps=8, k=4)
    base.update(kw)
    return snn_lib.SNNConfig(**base)


def _events(key, t, n_in=32, rate=0.25):
    return np.asarray(jax.random.bernoulli(key, rate, (t, n_in)), np.float32)


def _setup(**kw):
    cfg = _cfg(**kw)
    p = snn_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, p


def _one_shot(p, cfg, req, noise=None):
    logits, tele = snn_lib.forward_silicon(
        p, jnp.asarray(req.events)[None], cfg, req.key, fused="seq",
        noise=noise)
    return logits[0], float(tele["adc_steps"][0])


class TestContinuousParity:
    """Served results == one-shot batch-1 forward_silicon, bitwise."""

    @pytest.mark.fast
    def test_clean_snl_mixed_lengths_bitwise(self):
        cfg, p = _setup()           # use_snl=True default: PRBS SNL active
        key = jax.random.PRNGKey(3)
        lengths = [8, 12, 6, 16, 8, 10]
        engine = SNNEventEngine(cfg, p, batch_slots=2, seed=9, round_steps=4)
        assert engine.continuous
        reqs = [EventRequest(uid=i, events=_events(jax.random.fold_in(key, i),
                                                   t))
                for i, t in enumerate(lengths)]
        for r in reqs:
            engine.submit(r)
        done = engine.run()
        assert [r.uid for r in done] == list(range(6))
        for r in done:
            ref_logits, ref_adc = _one_shot(p, cfg, r)
            np.testing.assert_array_equal(np.asarray(r.logits),
                                          np.asarray(ref_logits),
                                          err_msg=f"uid {r.uid}")
            assert r.adc_steps == ref_adc
            assert r.latency_ms is not None and r.latency_ms >= 0.0
            assert 0.0 <= r.skipped_block_ratio <= 1.0

    @pytest.mark.fast
    def test_noisy_bitwise_per_request(self):
        """Per-request counter streams (row_ctl): noisy served logits are a
        pure function of the request, reproducible from req.key alone."""
        cfg, p = _setup()
        noise = ima_lib.IMANoiseModel()
        key = jax.random.PRNGKey(4)
        engine = SNNEventEngine(cfg, p, batch_slots=3, seed=11, noise=noise,
                                round_steps=4)
        reqs = [EventRequest(uid=i, events=_events(jax.random.fold_in(key, i),
                                                   t))
                for i, t in enumerate([8, 12, 8, 6, 10])]
        for r in reqs:
            engine.submit(r)
        done = engine.run()
        assert len(done) == 5
        for r in done:
            ref_logits, ref_adc = _one_shot(p, cfg, r, noise=noise)
            np.testing.assert_array_equal(np.asarray(r.logits),
                                          np.asarray(ref_logits),
                                          err_msg=f"uid {r.uid}")
            assert r.adc_steps == ref_adc

    @pytest.mark.fast
    def test_density_vs_fifo_parity(self):
        """The admission policy moves requests between rounds, never bits."""
        cfg, p = _setup()
        key = jax.random.PRNGKey(5)
        evs = [_events(jax.random.fold_in(key, i), 8,
                       rate=[0.05, 0.4, 0.1, 0.3, 0.02, 0.2][i])
               for i in range(6)]
        results = {}
        for pack in (False, True):
            engine = SNNEventEngine(cfg, p, batch_slots=2, seed=7,
                                    pack_by_density=pack, round_steps=4)
            for i, e in enumerate(evs):
                engine.submit(EventRequest(uid=i, events=e))
            results[pack] = {r.uid: r for r in engine.run()}
        for uid in range(6):
            np.testing.assert_array_equal(
                np.asarray(results[False][uid].logits),
                np.asarray(results[True][uid].logits),
                err_msg=f"uid {uid}")
            assert results[False][uid].adc_steps == \
                results[True][uid].adc_steps

    @pytest.mark.fast
    def test_membrane_reset_on_slot_reuse(self):
        """A single slot serving the same stream twice in a row must produce
        identical results: admission fully resets membrane, PRBS LFSR, and
        accumulators."""
        cfg, p = _setup()
        ev = _events(jax.random.PRNGKey(6), 10)
        engine = SNNEventEngine(cfg, p, batch_slots=1, seed=2, round_steps=4)
        a = EventRequest(uid=0, events=ev, key=jax.random.PRNGKey(42))
        b = EventRequest(uid=1, events=ev, key=jax.random.PRNGKey(42))
        engine.submit(a)
        engine.submit(b)
        done = engine.run()
        assert [r.uid for r in done] == [0, 1]
        np.testing.assert_array_equal(np.asarray(done[0].logits),
                                      np.asarray(done[1].logits))
        assert done[0].adc_steps == done[1].adc_steps


class TestContinuousScheduling:
    """Mid-flight admission/eviction mechanics and round accounting."""

    @pytest.mark.fast
    def test_midflight_admission_and_eviction_order(self):
        """Short requests leave early and free their slots for waiting
        traffic while long requests stay resident."""
        cfg, p = _setup()
        key = jax.random.PRNGKey(8)
        lengths = [4, 16, 4, 4, 4]
        engine = SNNEventEngine(cfg, p, batch_slots=2, seed=1, round_steps=4,
                                pack_by_density=False)
        for i, t in enumerate(lengths):
            engine.submit(EventRequest(uid=i,
                                       events=_events(
                                           jax.random.fold_in(key, i), t)))
        # round 1 serves uids 0 (len 4) and 1 (len 16): uid 0 evicts first
        first = engine.run(max_rounds=1)
        assert [r.uid for r in first] == [0]
        assert engine.active == 1              # uid 1 still resident
        assert len(engine.pending) == 3
        rest = engine.run()
        assert [r.uid for r in rest] == [1, 2, 3, 4]
        assert engine.active == 0 and not engine.pending
        # long request was mid-flight across both calls: still bitwise
        ref_logits, _ = _one_shot(p, cfg, rest[0])
        np.testing.assert_array_equal(np.asarray(rest[0].logits),
                                      np.asarray(ref_logits))

    @pytest.mark.fast
    def test_run_returns_only_newly_drained(self):
        """Bugfix pin: a second run() after new submits must not re-return
        (or re-count) the first call's results."""
        cfg, p = _setup()
        key = jax.random.PRNGKey(9)
        for continuous in (True, False):
            engine = SNNEventEngine(cfg, p, batch_slots=2, seed=3,
                                    continuous=continuous)
            engine.submit(EventRequest(uid=0, events=_events(key, 8)))
            first = engine.run()
            assert [r.uid for r in first] == [0]
            engine.submit(EventRequest(uid=1,
                                       events=_events(
                                           jax.random.fold_in(key, 1), 8)))
            second = engine.run()
            assert [r.uid for r in second] == [1], \
                f"continuous={continuous}: run() re-returned history"
            # history still accumulates for energy_report
            assert [r.uid for r in engine.completed] == [0, 1]

    @pytest.mark.fast
    def test_legacy_mixed_lengths_bucketed(self):
        """Bugfix pin: the legacy drain path used to crash in jnp.stack on
        mixed event-stream lengths; now batches bucket by T."""
        cfg, p = _setup()
        key = jax.random.PRNGKey(10)
        engine = SNNEventEngine(cfg, p, batch_slots=2, seed=3,
                                continuous=False, pack_by_density=False)
        lengths = [8, 12, 8, 12, 6]
        for i, t in enumerate(lengths):
            engine.submit(EventRequest(uid=i,
                                       events=_events(
                                           jax.random.fold_in(key, i), t)))
        done = engine.run()
        assert [r.uid for r in done] == list(range(5))
        assert all(r.logits is not None for r in done)
        # bucketed batches stay exact: same-length pairs ran together
        for r in done:
            assert 0.0 <= r.adc_steps <= 2 ** cfg.code_bits - 1

    @pytest.mark.fast
    def test_continuous_rejects_unsupported_configs(self):
        cfg, p = _setup()
        with pytest.raises(ValueError):
            SNNEventEngine(cfg, p, time_major=False, continuous=True)
        # auto-select falls back instead of raising
        eng = SNNEventEngine(cfg, p, time_major=False)
        assert not eng.continuous
        cfg2 = snn_lib.SNNConfig(n_in=16, n_hidden=8, n_classes=2,
                                 hidden_layers=(8, 8), k_layers=(2, 2))
        p2 = snn_lib.init_params(cfg2, jax.random.PRNGKey(0))
        eng2 = SNNEventEngine(cfg2, p2, batch_slots=2)
        assert not eng2.continuous        # stacks serve via the drain path

    @pytest.mark.fast
    def test_energy_report_per_request_columns(self):
        cfg, p = _setup()
        key = jax.random.PRNGKey(12)
        engine = SNNEventEngine(cfg, p, batch_slots=2, round_steps=4)
        for i in range(4):
            engine.submit(EventRequest(
                uid=i, events=_events(jax.random.fold_in(key, i), 8)))
        engine.run()
        rep = engine.energy_report("nmnist")
        assert rep["requests"] == 4
        assert len(rep["per_request"]) == 4
        for row in rep["per_request"]:
            assert row["latency_ms"] > 0.0
            assert row["pj_per_sop"] > 0.0
            assert 0.0 <= row["density"] <= 1.0
        assert rep["latency_ms_p50"] <= rep["latency_ms_p95"]


class TestRowCtlKernel:
    """kernel-level row_ctl lane: per-row streams == batch-1 scalar runs."""

    @pytest.mark.fast
    def test_row_ctl_matches_scalar_ctl_batch1(self):
        key = jax.random.PRNGKey(13)
        t, m, kdim, n = 4, 3, 32, 16
        x = np.asarray(jax.random.randint(key, (t, m, kdim), -1, 2), np.int8)
        w = jax.random.randint(jax.random.fold_in(key, 1), (kdim, n), -3, 4)
        from repro.core import macro as macro_lib
        mcfg = macro_lib.CIMMacroConfig(mac_range=24.0,
                                        ima_noise=ima_lib.IMANoiseModel())
        fw = macro_lib.pack_kwn_weights(w, jnp.ones((n,)), mcfg)
        ima_kn = macro_lib.fused_kernel_noise(fw, mcfg)
        kw = dict(k=4, drive_gain=0.25, beta=0.9, v_th1=1.0, v_th2=0.6,
                  v_reset=0.0, v_lim=8.0, use_snl=True, ima_noise=ima_kn,
                  snl_amp=0.05, mac_telemetry=False)
        seeds = [101, 202, 303]
        # batched launch with per-row (seed, step_offset=0, row_id=0)
        row_ctl = jnp.asarray([[s, 0, 0] for s in seeds], jnp.int32)
        v0 = jnp.zeros((m, n), jnp.float32)
        _, spk_b, _, steps_b, _ = macro_lib.fused_seq(
            jnp.asarray(x, jnp.float32), fw, v0, None, row_ctl=row_ctl, **kw)
        # three scalar-ctl batch-1 launches
        for i, s in enumerate(seeds):
            _, spk_1, _, steps_1, _ = macro_lib.fused_seq(
                jnp.asarray(x[:, i:i + 1], jnp.float32), fw, v0[:1], None,
                seed=s, **kw)
            np.testing.assert_array_equal(np.asarray(spk_b[:, i]),
                                          np.asarray(spk_1[:, 0]),
                                          err_msg=f"row {i}")
            np.testing.assert_array_equal(np.asarray(steps_b[:, i]),
                                          np.asarray(steps_1[:, 0]))


class TestBatchedEngineLM:
    """BatchedEngine prefill key splitting + decode-round budgeting."""

    def _engine(self, temperature=0.0):
        from repro.configs import ARCHS
        from repro.configs.base import reduced
        from repro.models import lm
        from repro.nn import module
        from repro.serve import engine as engine_lib
        cfg = reduced(ARCHS["smollm-135m"])
        params = module.materialize(lm.param_specs(cfg),
                                    jax.random.PRNGKey(0))
        eng = engine_lib.BatchedEngine(cfg, params, batch_slots=2, s_max=32)
        if temperature > 0.0:
            eng.step_fn = jax.jit(engine_lib.build_serve_step(
                cfg, temperature=temperature))
        return eng

    @pytest.mark.fast
    def test_prefill_splits_rng_per_step(self):
        """Bugfix pin: sampling prefill must consume a fresh key per prompt
        token — the engine's rng state advances during _admit."""
        from repro.serve.engine import Request
        eng = self._engine(temperature=1.0)
        rng_before = np.asarray(eng._rng)
        eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=1))
        eng._admit()
        assert not np.array_equal(np.asarray(eng._rng), rng_before), \
            "prefill fed the same unsplit key to every step"

    @pytest.mark.fast
    def test_max_rounds_charges_decode_only(self):
        """Bugfix pin: a request needing N decode rounds completes with
        max_rounds=N even though admission/prefill also ran."""
        from repro.serve.engine import Request
        eng = self._engine()
        eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4))
        done = eng.run(max_rounds=4)
        assert len(done) == 1 and len(done[0].generated) == 4


class TestStreamStateUnit:
    """silicon_stream_* primitives behave as documented."""

    @pytest.mark.fast
    def test_admit_resets_only_masked_slots(self):
        cfg, _ = _setup()
        st = snn_lib.silicon_stream_init(cfg, 3)
        st = st._replace(v=jnp.ones_like(st.v),
                         counts=jnp.full_like(st.counts, 5.0),
                         adc=jnp.full_like(st.adc, 7.0),
                         steps_done=jnp.full_like(st.steps_done, 4))
        st2 = snn_lib.silicon_stream_admit(
            st, np.array([True, False, False]),
            np.array([6, 9, 9], np.int32), np.array([1, 2, 3], np.int32))
        assert float(st2.v[0].sum()) == 0.0
        assert float(st2.v[1].sum()) == cfg.n_hidden
        assert float(st2.adc[0]) == 0.0 and float(st2.adc[2]) == 7.0
        assert int(st2.steps_done[0]) == 0 and int(st2.steps_done[1]) == 4
        assert list(np.asarray(st2.length)) == [6, 9, 9]

    @pytest.mark.fast
    def test_stream_rejects_stacks(self):
        cfg = snn_lib.SNNConfig(n_in=16, n_hidden=8, n_classes=2,
                                hidden_layers=(8, 8), k_layers=(2, 2))
        p = snn_lib.init_params(cfg, jax.random.PRNGKey(0))
        st = snn_lib.silicon_stream_init(cfg, 2)
        with pytest.raises(ValueError):
            snn_lib.forward_silicon_stream(
                p, jnp.zeros((4, 2, 16)), cfg, st)
