"""Preemptive serving: checkpoint/restore parity, scheduler policy, shedding.

The contract under test (docs/SERVING.md): a request that is preempted —
checkpointed to host memory at an arbitrary step offset, possibly restored
into a *different* slot, possibly preempted again — produces logits and ADC
telemetry **bitwise identical** to an uninterrupted one-shot batch-1
``forward_silicon(fused="seq")`` run, clean and noisy.  Plus the policy
layer around it: typed submit-time validation, bounded-queue load shedding,
deadline expiry, priority preemption with quantum/backoff/max-preemption
budgets, and submission-order results under every scheduling order.

The randomized sweeps are seeded and parametrized so they always run; the
``@given`` properties upgrade them when hypothesis is installed (see
tests/_hypothesis_compat.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ima as ima_lib
from repro.models import snn as snn_lib
from repro.serve import lifecycle
from repro.serve.engine import EventRequest, SNNEventEngine

from tests._hypothesis_compat import given, settings, st


@pytest.fixture(scope="module", autouse=True)
def _drop_compile_caches():
    """Release this module's compiled executables at teardown.

    Same rationale as tests/test_serve_engine.py: the parity matrix here
    compiles many interpret-mode Pallas entries (one-shot per stream
    length, stream rounds per extent R including partial rounds), and
    jaxlib 0.4.36's CPU compiler has segfaulted when a later module
    compiles its largest graph on top of all of them.
    """
    yield
    jax.clear_caches()


def _cfg(**kw):
    base = dict(n_in=32, n_hidden=16, n_classes=3, n_steps=8, k=4)
    base.update(kw)
    return snn_lib.SNNConfig(**base)


def _events(key, t, n_in=32, rate=0.25):
    return np.asarray(jax.random.bernoulli(key, rate, (t, n_in)), np.float32)


def _setup(**kw):
    cfg = _cfg(**kw)
    p = snn_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, p


def _one_shot(p, cfg, req, noise=None):
    logits, tele = snn_lib.forward_silicon(
        p, jnp.asarray(req.events)[None], cfg, req.key, fused="seq",
        noise=noise)
    return logits[0], float(tele["adc_steps"][0])


def _assert_parity(engine, p, cfg, reqs, noise=None):
    for r in reqs:
        assert r.state == lifecycle.COMPLETED
        ref_logits, ref_adc = _one_shot(p, cfg, r, noise=noise)
        np.testing.assert_array_equal(np.asarray(r.logits),
                                      np.asarray(ref_logits))
        assert r.adc_steps == ref_adc


_NOISE = ima_lib.IMANoiseModel()


class TestCheckpointRestore:
    """snn.SlotCheckpoint round-trips, including cross-slot relocation."""

    @pytest.mark.fast
    def test_save_restore_same_slot_roundtrip(self):
        cfg, p = _setup()
        state = snn_lib.silicon_stream_init(cfg, 4)
        state = snn_lib.silicon_stream_admit(
            state, np.array([False, True, False, False]),
            np.array([0, 12, 0, 0], np.int32),
            np.array([0, 77, 0, 0], np.int32))
        ev = np.zeros((4, 4, cfg.n_in), np.float32)
        ev[:, 1] = _events(jax.random.PRNGKey(1), 4)
        state = snn_lib.forward_silicon_stream(p, jnp.asarray(ev), cfg, state)
        ck = snn_lib.silicon_stream_save(state, 1)
        assert ck.steps_done == 4 and ck.length == 12 and ck.seed == 77
        restored = snn_lib.silicon_stream_restore(state, 1, ck)
        for a, b in zip(restored, state):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.fast
    @pytest.mark.parametrize("noise", [None, _NOISE],
                             ids=["clean", "noisy"])
    def test_cross_slot_restore_is_bitwise(self, noise):
        """Finish a stream half in slot 0, half in slot 3: same answer.

        Relocatability is the row_ctl row-id-0 property — nothing in the
        noise keying sees the physical slot index.
        """
        cfg, p = _setup()
        t = 14
        req = EventRequest(uid=0, events=_events(jax.random.PRNGKey(5), t),
                           key=jax.random.fold_in(jax.random.PRNGKey(9), 0))
        seed = 0 if noise is None else int(snn_lib._noise_seed(req.key))

        def _admit_one(state, slot, length):
            mask = np.zeros(4, bool)
            mask[slot] = True
            lens = np.zeros(4, np.int32)
            lens[slot] = length
            seeds = np.zeros(4, np.int32)
            seeds[slot] = seed
            return snn_lib.silicon_stream_admit(state, mask, lens, seeds)

        def _step(state, slot, lo, hi):
            ev = np.zeros((hi - lo, 4, cfg.n_in), np.float32)
            ev[:, slot] = np.asarray(req.events)[lo:hi]
            return snn_lib.forward_silicon_stream(
                p, jnp.asarray(ev), cfg, state, noise=noise)

        # uninterrupted run, slot 0
        ref = _step(_admit_one(snn_lib.silicon_stream_init(cfg, 4), 0, t),
                    0, 0, t)
        # preempted at step 6 (not a multiple of anything), moved to slot 3
        state = _step(_admit_one(snn_lib.silicon_stream_init(cfg, 4), 0, t),
                      0, 0, 6)
        ck = snn_lib.silicon_stream_save(state, 0)
        state = snn_lib.silicon_stream_restore(
            snn_lib.silicon_stream_init(cfg, 4), 3, ck)
        state = _step(state, 3, 6, t)
        for field in ("v", "counts", "adc", "sops"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, field)[0]),
                np.asarray(getattr(state, field)[3]), err_msg=field)


class TestPreemptionParity:
    """Engine-level: preempted-and-resumed == never-preempted, bitwise."""

    @pytest.mark.fast
    @pytest.mark.parametrize("noise", [None, _NOISE],
                             ids=["clean", "noisy"])
    def test_forced_preempt_nonaligned_offset(self, noise):
        """Preempt mid-round at a non-multiple of round_steps; resume."""
        cfg, p = _setup()
        key = jax.random.PRNGKey(2)
        lengths = [16, 12, 20, 8, 14]
        engine = SNNEventEngine(cfg, p, batch_slots=2, seed=4, round_steps=4,
                                noise=noise)
        reqs = [EventRequest(uid=i, events=_events(
            jax.random.fold_in(key, i), t)) for i, t in enumerate(lengths)]
        for r in reqs:
            engine.submit(r)
        fired = []

        def hook(eng):
            # once: stop request 0 at absolute step 6 (round cadence is 4)
            if not fired and any(r is not None and r.uid == 0
                                 for r in eng._slot_req):
                if int(eng._slot_done[[s is not None and s.uid == 0
                                       for s in eng._slot_req].index(True)]
                       ) >= 4:
                    victim = eng.preempt_request(0, at_step=6, backoff=False)
                    assert victim.state == lifecycle.PREEMPTED
                    assert victim._ckpt.steps_done == 6
                    fired.append(True)

        done = engine.run(round_hook=hook)
        assert fired and engine.preemption_count == 1
        assert [r.uid for r in done] == [0, 1, 2, 3, 4]
        _assert_parity(engine, p, cfg, reqs, noise=noise)

    @pytest.mark.parametrize("noise", [None, _NOISE],
                             ids=["clean", "noisy"])
    @pytest.mark.parametrize("case", range(4))
    def test_randomized_offsets_sweep(self, noise, case):
        """Seeded fuzz: random lengths, random victims, random offsets."""
        cfg, p = _setup()
        rng = np.random.default_rng(100 + case)
        key = jax.random.PRNGKey(40 + case)
        n = 6
        lengths = rng.integers(5, 24, size=n)
        engine = SNNEventEngine(cfg, p, batch_slots=3,
                                seed=int(rng.integers(0, 99)), round_steps=4,
                                noise=noise)
        reqs = [EventRequest(uid=i, events=_events(
            jax.random.fold_in(key, i), int(t)))
            for i, t in enumerate(lengths)]
        order = rng.permutation(n)          # randomized admission order
        for i in order:
            engine.submit(reqs[i])
        budget = [2]                        # up to two forced preemptions

        def hook(eng):
            if not budget[0]:
                return
            live = [(i, r) for i, r in enumerate(eng._slot_req)
                    if r is not None]
            if not live:
                return
            slot, victim = live[int(rng.integers(0, len(live)))]
            done, length = int(eng._slot_done[slot]), int(eng._slot_len[slot])
            if done >= length - 1:
                return                      # nothing left to preempt
            at = int(rng.integers(done, length))  # any offset, incl. done
            if at == done:
                eng.preempt_request(victim.uid, backoff=False)
            else:
                eng.preempt_request(victim.uid, at_step=at, backoff=False)
            budget[0] -= 1

        done = engine.run(round_hook=hook)
        # results come back in *submission* order — here, the permutation
        assert [r.uid for r in done] == [int(i) for i in order]
        _assert_parity(engine, p, cfg, reqs, noise=noise)

    @pytest.mark.fast
    def test_double_preemption_same_request(self):
        """Preempt the same stream twice (two checkpoints) — still exact."""
        cfg, p = _setup()
        engine = SNNEventEngine(cfg, p, batch_slots=2, seed=1, round_steps=4,
                                noise=_NOISE)
        reqs = [EventRequest(uid=i, events=_events(
            jax.random.fold_in(jax.random.PRNGKey(8), i), t))
            for i, t in enumerate([18, 9, 7])]
        for r in reqs:
            engine.submit(r)
        hits = []

        def hook(eng):
            if len(hits) >= 2:
                return
            slot = next((i for i, r in enumerate(eng._slot_req)
                         if r is not None and r.uid == 0), None)
            if slot is None:
                return
            done = int(eng._slot_done[slot])
            at = 5 if not hits else 11
            if done < at < int(eng._slot_len[slot]):
                eng.preempt_request(0, at_step=at, backoff=False)
                hits.append(at)

        engine.run(round_hook=hook)
        assert hits == [5, 11] and reqs[0].preemptions == 2
        _assert_parity(engine, p, cfg, reqs, noise=_NOISE)

    @given(offset=st.integers(min_value=1, max_value=15),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    @settings(max_examples=10, deadline=None)
    def test_property_any_offset_bitwise(self, offset, seed):
        """Hypothesis upgrade of the sweep: arbitrary (offset, seed)."""
        cfg, p = _setup()
        engine = SNNEventEngine(cfg, p, batch_slots=2, seed=seed,
                                round_steps=4, noise=_NOISE)
        req = EventRequest(uid=0, events=_events(jax.random.PRNGKey(seed),
                                                 16))
        engine.submit(req)
        fired = []

        def hook(eng):
            if not fired and 0 in [getattr(r, "uid", None)
                                   for r in eng._slot_req]:
                slot = [getattr(r, "uid", None)
                        for r in eng._slot_req].index(0)
                if int(eng._slot_done[slot]) <= offset:
                    eng.preempt_request(0, at_step=max(
                        offset, int(eng._slot_done[slot])), backoff=False)
                    fired.append(True)

        engine.run(round_hook=hook)
        _assert_parity(engine, p, cfg, [req], noise=_NOISE)


class TestSchedulerPolicy:
    """Priority preemption, budgets, backoff, deadline handling."""

    @pytest.mark.fast
    def test_priority_preempts_and_both_complete(self):
        cfg, p = _setup()
        engine = SNNEventEngine(cfg, p, batch_slots=1, seed=0, round_steps=4,
                                preempt_quantum=1, backoff_rounds=1)
        hog = EventRequest(uid=0, events=_events(jax.random.PRNGKey(0), 40))
        urgent = EventRequest(uid=1, priority=5,
                              events=_events(jax.random.PRNGKey(1), 8))
        engine.submit(hog)
        engine.run(max_rounds=2)            # hog resident, mid-stream
        engine.submit(urgent)
        done = engine.run()
        assert engine.preemption_count >= 1
        assert hog.preemptions >= 1
        # urgent finished before the preempted hog resumed to completion
        assert [r.uid for r in engine.completed] == [1, 0] or \
            engine.completed[0].uid == 1
        assert {r.uid for r in done} == {0, 1}
        _assert_parity(engine, p, cfg, [hog, urgent])

    @pytest.mark.fast
    def test_no_priorities_means_no_preemption(self):
        """Back-compat: plain traffic never triggers the preemptor."""
        cfg, p = _setup()
        engine = SNNEventEngine(cfg, p, batch_slots=2, seed=0, round_steps=4)
        for i in range(6):
            engine.submit(EventRequest(uid=i, events=_events(
                jax.random.fold_in(jax.random.PRNGKey(3), i), 10)))
        engine.run()
        assert engine.preemption_count == 0
        assert len(engine.completed) == 6

    @pytest.mark.fast
    def test_max_preemptions_budget(self):
        """A request is never preempted more than max_preemptions times."""
        cfg, p = _setup()
        engine = SNNEventEngine(cfg, p, batch_slots=1, seed=0, round_steps=2,
                                max_preemptions=1, preempt_quantum=1,
                                backoff_rounds=1)
        hog = EventRequest(uid=0, events=_events(jax.random.PRNGKey(0), 30))
        engine.submit(hog)
        engine.run(max_rounds=2)
        for i in range(4):
            engine.submit(EventRequest(uid=1 + i, priority=9, events=_events(
                jax.random.fold_in(jax.random.PRNGKey(1), i), 6)))
        engine.run()
        assert hog.preemptions == 1        # budget capped it despite 4 vips
        assert len(engine.completed) == 5
        _assert_parity(engine, p, cfg, [hog])

    @pytest.mark.fast
    def test_quantum_blocks_immediate_revictimization(self):
        """preempt_quantum=3: a fresh admit is safe for 3 ticks."""
        cfg, p = _setup()
        engine = SNNEventEngine(cfg, p, batch_slots=1, seed=0, round_steps=2,
                                preempt_quantum=3, backoff_rounds=1)
        a = EventRequest(uid=0, events=_events(jax.random.PRNGKey(0), 12))
        engine.submit(a)
        engine.run(max_rounds=1)
        admit_tick = int(engine._slot_admit_round[0])
        engine.submit(EventRequest(uid=1, priority=7,
                                   events=_events(jax.random.PRNGKey(1), 4)))
        engine.run(max_rounds=2)
        # inside the quantum window nothing may be preempted
        assert engine.preemption_count == 0 or \
            engine._rounds_total - admit_tick >= 3
        engine.run()
        assert len(engine.completed) == 2

    @pytest.mark.fast
    def test_backoff_is_exponential_and_expires(self):
        cfg, p = _setup()
        engine = SNNEventEngine(cfg, p, batch_slots=1, seed=0, round_steps=2,
                                backoff_rounds=2, max_preemptions=8)
        hog = EventRequest(uid=0, events=_events(jax.random.PRNGKey(0), 24))
        engine.submit(hog)
        engine.run(max_rounds=1)
        engine.preempt_request(0)          # policy-style: with backoff
        assert hog._not_before == engine._rounds_total + 2   # 2 * 2**0
        # drain: backoff must expire (ticks advance even while idle)
        done = engine.run()
        assert [r.uid for r in done] == [0]
        assert hog.state == lifecycle.COMPLETED
        _assert_parity(engine, p, cfg, [hog])

    @pytest.mark.fast
    def test_deadline_expiry_typed_outcome(self):
        cfg, p = _setup()
        engine = SNNEventEngine(cfg, p, batch_slots=1, seed=0, round_steps=4)
        late = EventRequest(uid=0, deadline_ms=0.0,
                            events=_events(jax.random.PRNGKey(0), 8))
        ok = EventRequest(uid=1, events=_events(jax.random.PRNGKey(1), 8))
        engine.submit(late)
        engine.submit(ok)
        done = engine.run()
        assert late.state == lifecycle.EXPIRED
        assert late in engine.expired and late.logits is None
        assert [r.uid for r in done] == [1]
        _assert_parity(engine, p, cfg, [ok])

    @pytest.mark.fast
    def test_completed_after_deadline_flags_miss(self):
        cfg, p = _setup()
        engine = SNNEventEngine(cfg, p, batch_slots=1, seed=0, round_steps=4)
        req = EventRequest(uid=0, deadline_ms=1e9,
                           events=_events(jax.random.PRNGKey(0), 8))
        engine.submit(req)
        engine.run()
        assert req.state == lifecycle.COMPLETED
        assert req.deadline_missed is False


class TestLoadShedding:
    """Bounded queue: overflow sheds with a typed terminal outcome."""

    @pytest.mark.fast
    def test_overflow_sheds_lowest_priority_newest(self):
        cfg, p = _setup()
        engine = SNNEventEngine(cfg, p, batch_slots=1, max_pending=2,
                                round_steps=4)
        keep = [EventRequest(uid=i, priority=5, events=_events(
            jax.random.fold_in(jax.random.PRNGKey(0), i), 8))
            for i in range(2)]
        for r in keep:
            engine.submit(r)
        shed = engine.submit(EventRequest(
            uid=9, priority=0, events=_events(jax.random.PRNGKey(7), 8)))
        assert shed.state == lifecycle.REJECTED
        assert shed in engine.rejected and len(engine.pending) == 2
        done = engine.run()
        assert {r.uid for r in done} == {0, 1}
        _assert_parity(engine, p, cfg, keep)

    @pytest.mark.fast
    def test_high_priority_submit_sheds_queued_low(self):
        cfg, p = _setup()
        engine = SNNEventEngine(cfg, p, batch_slots=1, max_pending=1,
                                round_steps=4)
        low = engine.submit(EventRequest(
            uid=0, priority=0, events=_events(jax.random.PRNGKey(0), 8)))
        high = engine.submit(EventRequest(
            uid=1, priority=3, events=_events(jax.random.PRNGKey(1), 8)))
        assert low.state == lifecycle.REJECTED
        assert high.state == lifecycle.QUEUED and high in engine.pending

    @pytest.mark.fast
    def test_shedding_never_drops_checkpointed_work(self):
        cfg, p = _setup()
        engine = SNNEventEngine(cfg, p, batch_slots=1, max_pending=1,
                                round_steps=4)
        hog = EventRequest(uid=0, events=_events(jax.random.PRNGKey(0), 24))
        engine.submit(hog)
        engine.run(max_rounds=1)
        engine.preempt_request(0, backoff=False)   # hog queued with _ckpt
        fresh = engine.submit(EventRequest(
            uid=1, events=_events(jax.random.PRNGKey(1), 8)))
        # the fresh request is shed, not the checkpoint holder
        assert fresh.state == lifecycle.REJECTED
        assert hog in engine.pending
        engine.run()
        assert hog.state == lifecycle.COMPLETED
        _assert_parity(engine, p, cfg, [hog])


class TestSubmitValidation:
    """Typed rejection of malformed tensors before any kernel launch."""

    def _engine(self):
        cfg, p = _setup()
        return SNNEventEngine(cfg, p, batch_slots=1)

    @pytest.mark.fast
    def test_empty_stream(self):
        with pytest.raises(lifecycle.EmptyEventError):
            self._engine().submit(EventRequest(
                uid=0, events=np.zeros((0, 32), np.float32)))

    @pytest.mark.fast
    def test_wrong_width(self):
        with pytest.raises(lifecycle.EventShapeError):
            self._engine().submit(EventRequest(
                uid=0, events=np.zeros((4, 33), np.float32)))

    @pytest.mark.fast
    def test_wrong_rank(self):
        with pytest.raises(lifecycle.EventShapeError):
            self._engine().submit(EventRequest(
                uid=0, events=np.zeros((4,), np.float32)))

    @pytest.mark.fast
    def test_nan_events(self):
        ev = np.zeros((4, 32), np.float32)
        ev[2, 5] = np.nan
        with pytest.raises(lifecycle.NonFiniteEventError):
            self._engine().submit(EventRequest(uid=0, events=ev))

    @pytest.mark.fast
    def test_non_ternary(self):
        ev = np.zeros((4, 32), np.float32)
        ev[1, 1] = 0.5
        with pytest.raises(lifecycle.NonTernaryEventError):
            self._engine().submit(EventRequest(uid=0, events=ev))

    @pytest.mark.fast
    def test_bad_dtype(self):
        with pytest.raises(lifecycle.EventDtypeError):
            self._engine().submit(EventRequest(
                uid=0, events=np.array([["a"] * 32] * 4)))

    @pytest.mark.fast
    def test_ternary_negatives_accepted(self):
        eng = self._engine()
        ev = np.zeros((8, 32), np.float32)
        ev[0, 0], ev[1, 1] = -1.0, 1.0
        req = eng.submit(EventRequest(uid=0, events=ev))
        assert req.state == lifecycle.QUEUED

    @pytest.mark.fast
    def test_validate_false_opts_out(self):
        cfg, p = _setup()
        eng = SNNEventEngine(cfg, p, batch_slots=1, validate=False)
        ev = np.full((4, 32), 0.5, np.float32)   # non-ternary but trusted
        assert eng.submit(EventRequest(uid=0, events=ev)).state == \
            lifecycle.QUEUED
