"""Silicon-in-the-loop training: forward exactness + surrogate-gradient
parity of the fused-VJP subsystem (``kernels.fused_macro_grad``,
``ops.fused_macro_seq_vjp``, ``train.silicon``).

The contract under test, from ISSUE 5:

* the custom-VJP **forward** is the silicon-exact fused kernel — bitwise-
  equal to ``ref.fused_macro_seq_ref`` (and the differentiable oracle's
  primal), clean and counter-PRNG noisy, across tile plans;
* the custom-VJP **backward** (the time-reversed Pallas kernel) matches
  ``jax.grad`` of the pure-JAX oracle ``ref.fused_macro_seq_vjp_ref`` —
  allclose for the surrogate pieces, across >=2 tile plans, clean and
  noisy, hard-gate and relaxed;
* the **remat** (recompute-MAC) backward is *bitwise* identical to the
  residual-stack backward (the MAC is exact integers);
* noisy gradients are a pure function of the seed (reproducible, and
  distinct seeds give distinct draws);
* a 20-step ``train(cfg, silicon=True)`` run decreases the silicon loss
  (the tier-1 train-smoke gate).

The fast-marked subset (one parity shape, determinism, the train smoke) is
what ``make train-smoke`` runs in CI; the tiled-plan sweeps and the reduced
Fig. 8 fine-tune experiment live in the default/slow tiers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ima as ima_lib
from repro.kernels import ops, ref
from repro.models import snn
from repro.train import silicon as silicon_lib

KW = dict(k=12, drive_gain=0.25, use_snl=True, snl_amp=0.05)
STE = dict(ste_lo=-24.5, ste_hi=24.5)

# Two tile plans: single-tile (one macro column width, one K tile) and a
# 2x2 virtual macro grid (two K tiles x two column tiles, padded batch).
PLANS = {
    "single": dict(t=5, m=8, k_dim=256, n=128),
    "tiled": dict(t=4, m=12, k_dim=512, n=256),
}


def _operands(plan, seed=0):
    p = PLANS[plan]
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    t, m, k_dim, n = p["t"], p["m"], p["k_dim"], p["n"]
    tern = lambda kk, s: jax.random.randint(kk, s, -1, 2).astype(jnp.float32)
    x = tern(ks[0], (t, m, k_dim)) \
        * (jax.random.uniform(ks[5], (t, m, k_dim)) < 0.12)
    w = jax.random.randint(ks[1], (k_dim, n), -3, 4).astype(jnp.float32)
    cb = ima_lib.nlq_codebook(5, -24, 24)
    scale = jax.random.uniform(ks[3], (n,), minval=0.05, maxval=0.3)
    v = jax.random.normal(ks[4], (m, n)) * 0.5
    return x, w, cb, scale, v


def _spec(**kw):
    return ops.SeqVJPSpec(**{**KW, **STE, **kw})


_DUMMY = jnp.zeros((1,), jnp.float32)


def _vjp_outputs(spec, w, x, cb, scale, v, seed=7.0):
    return ops.fused_macro_seq_vjp(spec, w, x, cb.boundaries, cb.levels,
                                   scale, v, _DUMMY, jnp.float32(seed))


def _oracle_outputs(w, x, cb, scale, v, seed=7, **kw):
    return ref.fused_macro_seq_vjp_ref(w, x, cb.boundaries, cb.levels,
                                       scale, v, None, seed=seed,
                                       **{**KW, **STE, **kw})


def _noise_params(cb):
    return ima_lib.kernel_noise_params(ima_lib.IMANoiseModel(), cb)


def _grads(fn, w, v, g_spk, g_vfin):
    def loss(w, v):
        out = fn(w, v)
        return jnp.sum(out[0] * g_spk) + jnp.sum(out[1] * g_vfin)
    return jax.grad(loss, argnums=(0, 1))(w, v)


# ---------------------------------------------------------------------------
# Forward exactness
# ---------------------------------------------------------------------------

@pytest.mark.fast
@pytest.mark.parametrize("noisy", [False, True])
def test_vjp_forward_bitwise_vs_seq_ref(noisy):
    """The training forward IS the serving forward: primal spikes and final
    membrane equal ``fused_macro_seq_ref`` bitwise, clean and noisy."""
    from repro.core import ternary as ternary_lib
    x, w, cb, scale, v = _operands("single")
    kn = _noise_params(cb) if noisy else None
    spec = _spec(ima_noise=kn)
    spk, vfin = _vjp_outputs(spec, w, x, cb, scale, v)
    msb, lsb = ternary_lib.weight_decompose(w)
    want = ref.fused_macro_seq_ref(x, msb, lsb, cb.boundaries, cb.levels,
                                   scale, v, None, mode="kwn", seed=7,
                                   ima_noise=kn, **KW)
    assert jnp.array_equal(spk, want[2])
    assert jnp.array_equal(vfin, want[1])


@pytest.mark.parametrize("plan", list(PLANS))
@pytest.mark.parametrize("noisy", [False, True])
def test_vjp_forward_bitwise_vs_oracle(plan, noisy):
    """The differentiable oracle's primal is the kernel's primal — the STE
    identity terms vanish exactly — for every tile plan, clean and noisy."""
    x, w, cb, scale, v = _operands(plan)
    kn = _noise_params(cb) if noisy else None
    spec = _spec(ima_noise=kn, kwn_relax=0.1)
    spk, vfin = _vjp_outputs(spec, w, x, cb, scale, v)
    vfin_r, spk_r, _, _, _ = _oracle_outputs(w, x, cb, scale, v,
                                             ima_noise=kn, kwn_relax=0.1)
    assert jnp.array_equal(spk, spk_r)
    assert jnp.array_equal(vfin, vfin_r)


@pytest.mark.parametrize("noisy", [False, True])
def test_vtrace_matches_oracle(noisy):
    """The membrane-trace residual (pre-reset, post-saturation V_mem) the
    backward consumes equals the oracle's, bitwise."""
    x, w, cb, scale, v = _operands("single")
    from repro.core import ternary as ternary_lib
    kn = _noise_params(cb) if noisy else None
    msb, lsb = ternary_lib.weight_decompose(w)
    outs = ops.fused_macro_seq(
        x, msb.astype(jnp.int8), lsb.astype(jnp.int8), cb.boundaries,
        cb.levels, scale, v, None, mode="kwn", ima_noise=kn,
        mac_telemetry=False, train_trace=True, seed=7, **KW)
    vtrace_r = _oracle_outputs(w, x, cb, scale, v, ima_noise=kn)[4]
    assert jnp.array_equal(outs[5], vtrace_r)


# ---------------------------------------------------------------------------
# Gradient parity vs the oracle VJP
# ---------------------------------------------------------------------------

@pytest.mark.fast
def test_grad_parity_single_plan_clean():
    """Tier-1: fused-VJP gradients match jax.grad of the oracle."""
    _grad_parity_case("single", noisy=False, kwn_relax=0.1, remat=False)


@pytest.mark.parametrize("plan", list(PLANS))
@pytest.mark.parametrize("noisy", [False, True])
@pytest.mark.parametrize("kwn_relax", [0.0, 0.25])
def test_grad_parity_matrix(plan, noisy, kwn_relax):
    """Full matrix: both tile plans x clean/noisy x hard/relaxed gate."""
    _grad_parity_case(plan, noisy=noisy, kwn_relax=kwn_relax, remat=False)


def _grad_parity_case(plan, *, noisy, kwn_relax, remat):
    x, w, cb, scale, v = _operands(plan)
    kn = _noise_params(cb) if noisy else None
    spec = _spec(ima_noise=kn, kwn_relax=kwn_relax, remat=remat)
    shapes = _vjp_outputs(spec, w, x, cb, scale, v)
    g_spk = jax.random.normal(jax.random.PRNGKey(3), shapes[0].shape)
    g_vfin = jax.random.normal(jax.random.PRNGKey(4), shapes[1].shape)
    dw_k, dv_k = _grads(
        lambda w, v: _vjp_outputs(spec, w, x, cb, scale, v),
        w, v, g_spk, g_vfin)
    def oracle_fn(w, v):
        v_fin, spk_t, *_ = _oracle_outputs(w, x, cb, scale, v, ima_noise=kn,
                                           kwn_relax=kwn_relax)
        return spk_t, v_fin

    dw_r, dv_r = _grads(oracle_fn, w, v, g_spk, g_vfin)
    np.testing.assert_allclose(dw_k, dw_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dv_k, dv_r, rtol=1e-5, atol=1e-6)


def test_remat_gradients_bitwise_equal_residual():
    """The recompute-MAC backward is *bitwise* the residual-stack backward:
    the MAC is small exact integers, so recomputation cannot move a bit."""
    x, w, cb, scale, v = _operands("tiled")
    kn = _noise_params(cb)
    g_spk = jax.random.normal(jax.random.PRNGKey(3),
                              (x.shape[0], x.shape[1], v.shape[-1]))
    g_vfin = jax.random.normal(jax.random.PRNGKey(4), v.shape)
    grads = {}
    for remat in (False, True):
        spec = _spec(ima_noise=kn, kwn_relax=0.1, remat=remat)
        grads[remat] = _grads(
            lambda w, v, spec=spec: _vjp_outputs(spec, w, x, cb, scale, v),
            w, v, g_spk, g_vfin)
    assert jnp.array_equal(grads[False][0], grads[True][0])
    assert jnp.array_equal(grads[False][1], grads[True][1])


@pytest.mark.fast
def test_noisy_gradients_seeded_deterministic():
    """Noisy-QAT gradients are a pure function of the counter seed."""
    x, w, cb, scale, v = _operands("single")
    spec = _spec(ima_noise=_noise_params(cb), kwn_relax=0.1)
    g_spk = jax.random.normal(jax.random.PRNGKey(3),
                              (x.shape[0], x.shape[1], v.shape[-1]))

    def dw(seed):
        return jax.grad(lambda w: jnp.sum(
            _vjp_outputs(spec, w, x, cb, scale, v, seed=seed)[0]
            * g_spk))(w)

    assert jnp.array_equal(dw(11.0), dw(11.0))
    assert not jnp.array_equal(dw(11.0), dw(12.0))


def test_gate_off_matches_gated_gradients():
    """Activity gating of the backward contraction is output-invariant."""
    x, w, cb, scale, v = _operands("single")
    g_spk = jax.random.normal(jax.random.PRNGKey(3),
                              (x.shape[0], x.shape[1], v.shape[-1]))
    grads = {}
    for gate in (False, True):
        spec = _spec(kwn_relax=0.1, gate=gate)
        grads[gate] = jax.grad(lambda w, spec=spec: jnp.sum(
            _vjp_outputs(spec, w, x, cb, scale, v)[0] * g_spk))(w)
    assert jnp.array_equal(grads[False], grads[True])


# ---------------------------------------------------------------------------
# Model layer
# ---------------------------------------------------------------------------

def _nmnist_setup(k=12, n_steps=12, n_in=256):
    from repro.data import events as ev_lib
    cfg = snn.SNNConfig(n_in=n_in, n_steps=n_steps, n_classes=10,
                        mode="kwn", k=k)
    dcfg = ev_lib.EventDataConfig("nmnist", n_in, n_steps, 10, 0.03,
                                  alpha=0.55)
    return cfg, ev_lib.EventDataset(dcfg)


def test_clean_training_forward_equals_serving_forward():
    """``silicon.forward_logits`` (clean) is bitwise the fused serving
    forward — trained models need no re-calibration for the serving path."""
    cfg, ds = _nmnist_setup()
    p = snn.init_params(cfg, jax.random.PRNGKey(0))
    ev, _ = ds.sample(jax.random.PRNGKey(2), 16)
    logits_serve, _ = snn.forward_silicon(p, ev, cfg, jax.random.PRNGKey(3),
                                          fused=True)
    logits_train = silicon_lib.forward_logits(p, ev, cfg, jnp.float32(0.0))
    assert jnp.array_equal(logits_serve, logits_train)


def test_silicon_loss_grad_reaches_both_params():
    cfg, ds = _nmnist_setup()
    p = snn.init_params(cfg, jax.random.PRNGKey(0))
    ev, lab = ds.sample(jax.random.PRNGKey(2), 16)
    g = jax.grad(snn.loss_fn)(p, ev, lab, cfg, jnp.float32(3.0),
                              silicon=True, noise=ima_lib.IMANoiseModel())
    assert float(jnp.max(jnp.abs(g["w_hid"]))) > 0.0
    assert float(jnp.max(jnp.abs(g["w_out"]))) > 0.0
    assert np.isfinite(np.asarray(g["w_hid"])).all()


def test_silicon_training_rejects_nld():
    cfg, ds = _nmnist_setup()
    cfg = snn.SNNConfig(n_in=cfg.n_in, n_steps=cfg.n_steps, mode="nld")
    p = snn.init_params(cfg, jax.random.PRNGKey(0))
    ev, lab = ds.sample(jax.random.PRNGKey(2), 4)
    with pytest.raises(ValueError, match="kwn"):
        snn.loss_fn(p, ev, lab, cfg, jnp.float32(0.0), silicon=True)


@pytest.mark.fast
def test_train_smoke_silicon_loss_decreases():
    """20 noise-aware QAT steps through the fused kernel: loss decreases.
    (The tier-1 train-smoke gate; fully seeded, so deterministic.)"""
    from repro.data import events as ev_lib
    ds = ev_lib.EventDataset(ev_lib.DATASETS["nmnist"])
    cfg = snn.SNNConfig(n_in=512, n_steps=20, n_classes=10, mode="kwn",
                        k=12)
    _, losses = snn.train(cfg, ds, n_steps=20, batch=64, lr=0.3,
                          silicon=True, noise=ima_lib.IMANoiseModel())
    assert len(losses) == 20 and all(np.isfinite(losses))
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


@pytest.mark.slow
def test_finetune_recovers_noisy_accuracy():
    """The reduced Fig. 8 robustness experiment (the acceptance criterion):
    software pre-train, then silicon fine-tune with noise-aware QAT; the
    fine-tuned model's *noisy* fused accuracy must be at least the
    software-trained baseline's on both event-dataset stand-ins."""
    from repro.data import events as ev_lib
    nm = ima_lib.IMANoiseModel()
    for name, k, ft_lr in (("nmnist", 3, 0.01), ("dvs_gesture", 12, 0.02)):
        dcfg = ev_lib.DATASETS[name]
        ds = ev_lib.EventDataset(dcfg)
        cfg = snn.SNNConfig(n_in=dcfg.n_in, n_steps=dcfg.n_steps,
                            n_classes=dcfg.n_classes, mode="kwn", k=k)
        p, _ = snn.train(cfg, ds, n_steps=150, batch=64)
        base_noisy, _ = snn.evaluate(p, cfg, ds, jax.random.PRNGKey(1),
                                     n_batches=8, noise=nm, fused=True)
        p_ft, losses = snn.train(cfg, ds, n_steps=60, batch=64, lr=ft_lr,
                                 seed=5, silicon=True, noise=nm, params=p)
        ft_noisy, _ = snn.evaluate(p_ft, cfg, ds, jax.random.PRNGKey(1),
                                   n_batches=8, noise=nm, fused=True)
        assert np.isfinite(losses).all()
        assert ft_noisy >= base_noisy, (name, base_noisy, ft_noisy)


def test_train_losses_are_floats_once():
    """Satellite: ``train`` returns host floats built from one stacked
    device array (no per-step host sync), and warm-starting from an
    existing tree leaves the caller's buffers alive (donation safety)."""
    cfg, ds = _nmnist_setup(n_steps=8)
    p0 = snn.init_params(cfg, jax.random.PRNGKey(0))
    p, losses = snn.train(cfg, ds, n_steps=3, batch=8, params=p0)
    assert isinstance(losses, list) and len(losses) == 3
    assert all(isinstance(x, float) for x in losses)
    # p0 must still be usable after the donating train loop copied it
    assert np.isfinite(float(jnp.sum(p0["w_hid"])))
