"""End-to-end system tests: SNN learns + silicon modes behave per the paper's
claims; LM training loss decreases; serving engine completes batched
requests; analytical roofline model is validated against XLA cost_analysis on
an unrolled config."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.configs.base import reduced
from repro.data import events as ev_lib
from repro.data.synthetic_lm import DataConfig, SyntheticLM
from repro.models import lm, snn
from repro.nn import module
from repro.serve.engine import BatchedEngine, Request
from repro.train import optim, train_loop


class TestSNNSystem:
    @pytest.fixture(scope="class")
    def trained(self):
        ds = ev_lib.EventDataset(ev_lib.NMNIST)
        cfg = snn.SNNConfig(n_in=512, n_steps=20, n_classes=10, mode="kwn",
                            k=12)
        p, losses = snn.train(cfg, ds, n_steps=200, batch=64, lr=0.08)
        return p, cfg, ds, losses

    def test_loss_decreases(self, trained):
        _, _, _, losses = trained
        assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])

    def test_silicon_kwn_beats_chance(self, trained):
        p, cfg, ds, _ = trained
        acc, tele = snn.evaluate(p, cfg, ds, jax.random.PRNGKey(1),
                                 n_batches=3)
        assert acc > 0.5  # 10 classes, chance = 0.1
        assert tele["lif_updates"] == cfg.k  # Eq. 1 sparse update

    def test_early_stop_saves_ramp_steps(self, trained):
        p, cfg, ds, _ = trained
        _, tele = snn.evaluate(p, cfg, ds, jax.random.PRNGKey(2), n_batches=2)
        assert tele["adc_steps"] < 31  # early stop engaged

    def test_kwn_k_sweep_monotone_updates(self, trained):
        p, cfg, ds, _ = trained
        for k in (3, 12, 32):
            _, tele = snn.evaluate(p, cfg, ds, jax.random.PRNGKey(3),
                                   n_batches=1, k=k)
            assert tele["lif_updates"] == k


class TestLMTraining:
    def test_loss_decreases_smoke(self):
        cfg = reduced(ARCHS["smollm-135m"])
        ocfg = optim.AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=12)
        params = module.materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
        opt = optim.adamw_init(params, ocfg)
        data = SyntheticLM(DataConfig(cfg.vocab_size, 64, 8, seed=1))
        step = jax.jit(train_loop.build_train_step(cfg, None, n_micro=2,
                                                   opt_cfg=ocfg))
        losses = []
        for i in range(10):
            params, opt, m = step(params, opt, data.batch_at(i, n_micro=2))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses

    def test_kwn_ffn_sparsity_trains(self):
        # Eq. (1) applied to FFN units: top-k winner masking must train
        cfg = dataclasses.replace(reduced(ARCHS["qwen2.5-32b"]), kwn_ffn_k=16)
        params = module.materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                              0, cfg.vocab_size)}
        loss, grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, batch, cfg)[0])(params)
        assert bool(jnp.isfinite(loss))
        gn = optim.global_norm(grads)
        assert bool(jnp.isfinite(gn)) and float(gn) > 0

    def test_cim_linear_mode_trains(self):
        # paper C1/C2 as LM projections: ternary weights + NLQ activations
        cfg = dataclasses.replace(reduced(ARCHS["smollm-135m"]),
                                  cim_linear=True)
        params = module.materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                              0, cfg.vocab_size)}
        loss, grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, batch, cfg)[0])(params)
        assert bool(jnp.isfinite(loss)) and float(optim.global_norm(grads)) > 0


class TestServing:
    def test_batched_engine_completes(self):
        cfg = reduced(ARCHS["smollm-135m"])
        params = module.materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
        eng = BatchedEngine(cfg, params, batch_slots=2, s_max=64)
        for uid in range(4):
            eng.submit(Request(uid=uid, prompt=[1, 2, 3], max_new_tokens=5))
        done = eng.run(max_rounds=64)
        assert len(done) == 4
        assert all(len(r.generated) == 5 for r in done)
        assert all(0 <= t < cfg.vocab_size for r in done for t in r.generated)

    def test_prefill_returns_cache_and_last_logits(self):
        cfg = reduced(ARCHS["recurrentgemma-9b"])
        params = module.materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                    cfg.vocab_size)
        logits, aux, cache = lm.forward(params, {"tokens": tokens}, cfg,
                                        prefill=True)
        assert logits.shape == (2, cfg.padded_vocab)
        assert any(k.startswith("b") for k in cache)
        assert "tail0" in cache  # 38 = 12*3 + 2 tail blocks

    def test_prefill_cache_matches_decode_path(self):
        """Prefill-then-decode must equal pure step-by-step decode."""
        import numpy as np
        cfg = reduced(ARCHS["qwen2.5-32b"])
        params = module.materialize(lm.param_specs(cfg), jax.random.PRNGKey(3))
        toks = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0,
                                  cfg.vocab_size)
        # path A: teacher-forced decode for 8 tokens
        cache_a = lm.init_cache(cfg, 1, 8)
        for t in range(8):
            logits_a, cache_a = lm.decode_step(
                params, cache_a, toks[:, t:t + 1],
                jnp.full((1,), t, jnp.int32), cfg)
        # path B: prefill over the 8 tokens
        logits_b, _, cache_b = lm.forward(params, {"tokens": toks}, cfg,
                                          prefill=True)
        np.testing.assert_allclose(np.asarray(logits_a),
                                   np.asarray(logits_b), rtol=2e-3, atol=2e-3)


class TestRooflineModelValidation:
    def test_flops_model_matches_cost_analysis_unrolled(self):
        """On a 1-group config with n_micro=1 (no while loops hiding flops),
        the analytical flops model must agree with XLA's counter within 35%
        (XLA fuses/simplifies; the model includes what XLA may elide)."""
        from repro.roofline import flops_model
        base = ARCHS["smollm-135m"]
        cfg = dataclasses.replace(
            reduced(base, n_layers=1, d_model=128, vocab=512),
            remat=False, dtype="float32")
        params = module.materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
        b, s = 4, 256
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s),
                                              0, cfg.vocab_size)}

        def train_like(p, bt):
            return jax.value_and_grad(lambda pp: lm.loss_fn(pp, bt, cfg)[0])(p)

        compiled = jax.jit(train_like).lower(params, batch).compile()
        from repro import compat
        ca = compat.cost_analysis_dict(compiled)
        hlo_flops = float(ca["flops"])

        fwd_i, _ = flops_model.fwd_flops_per_token(cfg, "train", s,
                                                   with_full_head=True)
        model_flops = fwd_i * b * s * 3.0   # fwd + bwd(2x), no remat
        ratio = model_flops / hlo_flops
        assert 0.65 < ratio < 1.5, (model_flops, hlo_flops, ratio)
