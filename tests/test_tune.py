"""Tile-plan cache + autotuner: the contract docs/TILE_PLANS.md documents.

What must hold (and what these tests pin):

* the cache is a *pure perf* layer — a hit changes which launch geometry
  runs, never an output bit (parity through the cache-consuming default
  path vs the ``ref.py`` oracle, under multiple cached plans);
* every cache failure mode (missing file, corrupt JSON, stale version,
  misaligned entry) degrades to the PR 4 heuristic with a one-shot
  ``RuntimeWarning`` — never an exception, never a behavior change;
* lookups are deterministic and keyed exactly as documented (density
  bucketing goldens, density=None semantics, device-kind isolation);
* explicit block overrides and ``use_cache=False`` bypass the cache
  entirely (the tuner and bench measure exactly the plan they name);
* the tuner's winner meets or beats the heuristic by construction (the
  heuristic is always a candidate).

The fast subset is curated with explicit ``@pytest.mark.fast`` markers
(cache semantics are pure-host dict work; the kernel-parity and tuner
tests pay interpret-mode launches and stay in the default tier).
"""

from __future__ import annotations

import json
import os
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import ima as ima_lib
from repro.kernels import fused_macro, ops, ref
from repro.tune import autotune, cache, measure


@pytest.fixture
def cache_path(tmp_path, monkeypatch):
    """Point the plan cache at a per-test file; never the repo-root cache."""
    path = str(tmp_path / "plan_cache.json")
    monkeypatch.setenv(cache.ENV_PATH, path)
    monkeypatch.delenv(cache.ENV_DISABLE, raising=False)
    cache.clear_memo()
    yield path
    cache.clear_memo()


def _entry(blocks, *, m=8, k_dim=256, nc=128, n=128, t=3, mode="kwn",
           bucket=cache.ANY_BUCKET, speedup=1.1, device=None):
    return {
        "op": "fused_macro_seq",
        "shape": cache.shape_key(m, k_dim, nc, n, t),
        "mode": mode,
        "density_bucket": bucket,
        "device_kind": device or cache.device_kind(),
        "plan": {"bm": blocks[0], "bk": blocks[1], "bn": blocks[2]},
        "speedup_vs_heuristic": speedup,
    }


# ---------------------------------------------------------------------------
# density bucketing
# ---------------------------------------------------------------------------

@pytest.mark.fast
class TestDensityBuckets:
    def test_golden_bucket_map(self):
        """Bucket names are cache-key material: this mapping is frozen.

        Moving an edge or renaming a bucket invalidates every persisted
        entry, so such a change must bump CACHE_VERSION — and this golden.
        """
        want = {
            0.0: "d00-02", 0.01: "d00-02", 0.019: "d00-02",
            0.02: "d02-07", 0.05: "d02-07",
            0.075: "d07-15", 0.10: "d07-15",
            0.15: "d15-35", 0.25: "d15-35",
            0.35: "d35-75", 0.50: "d35-75",
            0.75: "d75-100", 1.0: "d75-100",
        }
        got = {d: cache.density_bucket(d) for d in want}
        assert got == want

    def test_bench_densities_land_in_distinct_buckets(self):
        """Each bench sweep point gets its own bucket (the edges' point)."""
        buckets = [cache.density_bucket(d)
                   for d in (0.01, 0.05, 0.10, 0.25, 0.50, 1.0)]
        assert len(set(buckets)) == len(buckets)

    def test_out_of_range_rejected(self):
        with pytest.raises(ValueError):
            cache.density_bucket(1.5)
        with pytest.raises(ValueError):
            cache.density_bucket(-0.1)


# ---------------------------------------------------------------------------
# cache round-trip + lookup semantics
# ---------------------------------------------------------------------------

@pytest.mark.fast
class TestCacheRoundTrip:
    def test_save_then_lookup_hit(self, cache_path):
        cache.save_entries([_entry((8, 128, 128), bucket="d02-07")])
        hit = cache.lookup(8, 256, 128, 128, 3, mode="kwn", density=0.05)
        assert hit == cache.PlanBlocks(8, 128, 128)

    def test_miss_on_different_shape_and_mode(self, cache_path):
        cache.save_entries([_entry((8, 128, 128))])
        assert cache.lookup(8, 256, 128, 128, 4, mode="kwn") is None   # t
        assert cache.lookup(16, 256, 128, 128, 3, mode="kwn") is None  # m
        assert cache.lookup(8, 256, 128, 128, 3, mode="nld") is None   # mode

    def test_miss_on_other_device_kind(self, cache_path):
        cache.save_entries([_entry((8, 128, 128), device="tpu v5 lite")])
        assert cache.lookup(8, 256, 128, 128, 3, mode="kwn") is None

    def test_density_none_prefers_any_bucket(self, cache_path):
        cache.save_entries([
            _entry((8, 128, 128), bucket="d02-07", speedup=2.0),
            _entry((8, 256, 128), bucket=cache.ANY_BUCKET, speedup=1.2),
        ])
        assert cache.lookup(8, 256, 128, 128, 3, mode="kwn") \
            == cache.PlanBlocks(8, 256, 128)

    def test_density_none_falls_back_to_best_speedup(self, cache_path):
        cache.save_entries([
            _entry((8, 128, 128), bucket="d02-07", speedup=1.1),
            _entry((8, 256, 128), bucket="d15-35", speedup=1.7),
        ])
        assert cache.lookup(8, 256, 128, 128, 3, mode="kwn") \
            == cache.PlanBlocks(8, 256, 128)

    def test_exact_bucket_beats_any(self, cache_path):
        cache.save_entries([
            _entry((8, 128, 128), bucket="d02-07"),
            _entry((8, 256, 128), bucket=cache.ANY_BUCKET),
        ])
        assert cache.lookup(8, 256, 128, 128, 3, mode="kwn",
                            density=0.05) == cache.PlanBlocks(8, 128, 128)
        assert cache.lookup(8, 256, 128, 128, 3, mode="kwn",
                            density=0.25) == cache.PlanBlocks(8, 256, 128)

    def test_merge_keeps_existing_keys(self, cache_path):
        cache.save_entries([_entry((8, 128, 128), bucket="d02-07")])
        cache.save_entries([_entry((8, 256, 128), bucket="d15-35")])
        assert cache.lookup(8, 256, 128, 128, 3, mode="kwn",
                            density=0.05) == cache.PlanBlocks(8, 128, 128)
        cache.save_entries([_entry((8, 256, 128), bucket="d15-35")],
                           merge=False)
        assert cache.lookup(8, 256, 128, 128, 3, mode="kwn",
                            density=0.05) is None

    def test_kill_switch_env(self, cache_path, monkeypatch):
        cache.save_entries([_entry((8, 128, 128))])
        monkeypatch.setenv(cache.ENV_DISABLE, "0")
        assert cache.lookup(8, 256, 128, 128, 3, mode="kwn") is None
        monkeypatch.setenv(cache.ENV_DISABLE, "1")
        assert cache.lookup(8, 256, 128, 128, 3, mode="kwn") is not None

    def test_save_rejects_malformed_entries(self, cache_path):
        with pytest.raises(ValueError):
            cache.save_entries([{"op": "fused_macro_seq"}])
        with pytest.raises(ValueError):           # bk not lane-aligned
            cache.save_entries([_entry((8, 100, 128))])

    def test_save_is_atomic_interrupted_write_keeps_old_file(
            self, cache_path):
        """A crash mid-serialization leaves the previous complete file.

        The write goes to a same-directory temp file that is os.replace'd
        over the target only once fully flushed — so a failure inside
        json.dump can neither truncate the cache nor leak a *.tmp next
        to it.
        """
        cache.save_entries([_entry((8, 128, 128))])
        before = open(cache_path).read()

        def boom(*a, **kw):
            raise OSError("disk full")
        orig_dump = cache.json.dump
        cache.json.dump = boom     # patch by hand: monkeypatch.undo() would
        try:                       # also roll back the fixture's ENV_PATH
            with pytest.raises(OSError):
                cache.save_entries([_entry((8, 256, 128))], merge=False)
        finally:
            cache.json.dump = orig_dump
        assert open(cache_path).read() == before      # old file intact
        leftovers = [f for f in os.listdir(os.path.dirname(cache_path))
                     if f.endswith(".tmp")]
        assert leftovers == []                        # no temp droppings
        cache.clear_memo()
        assert cache.lookup(8, 256, 128, 128, 3,
                            mode="kwn") == cache.PlanBlocks(8, 128, 128)


# ---------------------------------------------------------------------------
# failure modes: degrade to heuristic with a warning, never a crash
# ---------------------------------------------------------------------------

@pytest.mark.fast
class TestCacheFallback:
    def _heuristic(self):
        return fused_macro.plan_tiles(8, 256, 128, 128, 3, use_cache=False)

    def test_missing_file_is_silent_miss(self, cache_path):
        with warnings.catch_warnings():
            warnings.simplefilter("error")        # any warning -> failure
            plan = fused_macro.plan_tiles(8, 256, 128, 128, 3)
        assert plan == self._heuristic()

    def test_corrupt_json_warns_once_then_heuristic(self, cache_path):
        with open(cache_path, "w") as f:
            f.write("{not json")
        with pytest.warns(RuntimeWarning, match="unreadable"):
            plan = fused_macro.plan_tiles(8, 256, 128, 128, 3)
        assert plan == self._heuristic()
        with warnings.catch_warnings():           # warned once, not per call
            warnings.simplefilter("error")
            fused_macro.plan_tiles(8, 256, 128, 128, 3)

    def test_stale_version_warns_then_heuristic(self, cache_path):
        doc = {"format": cache.CACHE_FORMAT, "version": cache.CACHE_VERSION
               + 1, "entries": [_entry((8, 128, 128))]}
        with open(cache_path, "w") as f:
            json.dump(doc, f)
        with pytest.warns(RuntimeWarning, match="version"):
            plan = fused_macro.plan_tiles(8, 256, 128, 128, 3)
        assert plan == self._heuristic()

    def test_wrong_format_field_warns_then_heuristic(self, cache_path):
        with open(cache_path, "w") as f:
            json.dump({"format": "something-else", "version": 1}, f)
        with pytest.warns(RuntimeWarning, match="not a plan-cache"):
            plan = fused_macro.plan_tiles(8, 256, 128, 128, 3)
        assert plan == self._heuristic()

    def test_misaligned_entry_warns_then_heuristic(self, cache_path):
        # bypass save_entries validation: simulate a stale file tuned
        # under looser alignment rules than the current kernel's
        e = _entry((8, 128, 128))
        e["plan"]["bk"] = 100
        doc = {"format": cache.CACHE_FORMAT, "version": cache.CACHE_VERSION,
               "entries": [e]}
        with open(cache_path, "w") as f:
            json.dump(doc, f)
        with pytest.warns(RuntimeWarning, match="stale plan"):
            plan = fused_macro.plan_tiles(8, 256, 128, 128, 3)
        assert plan == self._heuristic()

    def test_rewrite_invalidates_memo(self, cache_path):
        cache.save_entries([_entry((8, 128, 128))])
        assert fused_macro.plan_tiles(8, 256, 128, 128, 3).bk == 128
        cache.save_entries([_entry((8, 256, 128))], merge=False)
        assert fused_macro.plan_tiles(8, 256, 128, 128, 3).bk == 256


# ---------------------------------------------------------------------------
# plan_tiles integration: hit / override / bypass
# ---------------------------------------------------------------------------

@pytest.mark.fast
class TestPlanTilesCachePath:
    def test_cache_hit_changes_blocks(self, cache_path):
        cache.save_entries([_entry((8, 128, 128))])
        plan = fused_macro.plan_tiles(8, 256, 128, 128, 3)
        assert (plan.bm, plan.bk, plan.bn) == (8, 128, 128)
        assert plan.grid == (1, 3, 1, 2)          # two K tiles now

    def test_explicit_override_bypasses_cache(self, cache_path):
        cache.save_entries([_entry((8, 128, 128))])
        plan = fused_macro.plan_tiles(8, 256, 128, 128, 3, bk=256)
        assert plan.bk == 256 and plan.bm == 8    # heuristic bm, pinned bk

    def test_use_cache_false_bypasses(self, cache_path):
        cache.save_entries([_entry((8, 128, 128))])
        plan = fused_macro.plan_tiles(8, 256, 128, 128, 3, use_cache=False)
        assert plan.bk == 256

    def test_activity_map_matches_cached_plan(self, cache_path):
        """plan_activity and the kernel's internal planner must agree
        under a cache hit exactly as they do under the heuristic."""
        from repro.core import macro as macro_lib
        cache.save_entries([_entry((8, 128, 128))])
        cb = ima_lib.nlq_codebook(5, -24, 24)
        fw = macro_lib.FusedMacroWeights(
            msb=jnp.zeros((256, 128), jnp.int8),
            lsb=jnp.zeros((256, 128), jnp.int8),
            scale=jnp.ones((128,)), boundaries=cb.boundaries,
            levels=cb.levels, w_dend=None, mode="kwn")
        spikes = jnp.zeros((3, 8, 256))
        act = macro_lib.plan_activity(spikes, fw, 128)
        plan, _ = macro_lib.plan_fused_tiles(8, fw, 128, n_steps=3)
        assert (plan.bm, plan.bk, plan.bn) == (8, 128, 128)
        assert act.shape == plan.activity_shape


# ---------------------------------------------------------------------------
# bitwise parity through the cache-consuming path
# ---------------------------------------------------------------------------

class TestCachedPlanParity:
    """Outputs must be bit-identical to the oracle under every cached plan.

    Two distinct cached plans (a two-K-tile split and a coarse-row split)
    are installed in turn; the *default* call path — ``ops.fused_macro_seq``
    with no block overrides, exactly what the model/serving layers run —
    must resolve each plan and still match ``ref.fused_macro_seq_ref``
    bitwise.  Also pins that the cache actually engaged (the grid moved).
    """

    M, K_DIM, NC, T = 8, 256, 128, 3
    PLANS = ((8, 128, 128), (16, 256, 128))

    def _operands(self):
        ks = jax.random.split(jax.random.PRNGKey(42), 5)
        x = measure.event_stream(ks[0], 0.1, (self.T, self.M, self.K_DIM))
        tern = lambda k, s: jax.random.randint(k, s, -1, 2).astype(jnp.int8)
        msb = tern(ks[1], (self.K_DIM, self.NC))
        lsb = tern(ks[2], (self.K_DIM, self.NC))
        cb = ima_lib.nlq_codebook(5, -24, 24)
        scale = jax.random.uniform(ks[3], (self.NC,), minval=0.05,
                                   maxval=0.3)
        v = jax.random.normal(ks[4], (self.M, self.NC)) * 0.5
        return x, msb, lsb, cb, scale, v

    @pytest.mark.parametrize("blocks", PLANS)
    def test_default_path_matches_oracle_under_cached_plan(
            self, cache_path, blocks):
        x, msb, lsb, cb, scale, v = self._operands()
        cache.save_entries([_entry(blocks, m=self.M, k_dim=self.K_DIM,
                                   nc=self.NC, n=self.NC, t=self.T)],
                           merge=False)
        plan = fused_macro.plan_tiles(self.M, self.K_DIM, self.NC, self.NC,
                                      self.T)
        assert (plan.bm, plan.bk, plan.bn) == blocks    # the cache engaged
        kw = dict(mode="kwn", k=12, drive_gain=0.25)
        got = ops.fused_macro_seq(x, msb, lsb, cb.boundaries, cb.levels,
                                  scale, v, None, mac_telemetry=False, **kw)
        want = ref.fused_macro_seq_ref(x, msb, lsb, cb.boundaries,
                                       cb.levels, scale, v, None, **kw)
        want = (want[1], want[2], want[3], want[4][..., 0])
        for a, b in zip(got[1:], want):
            assert jnp.array_equal(a, b)

    def test_both_cached_plans_agree_bitwise(self, cache_path):
        x, msb, lsb, cb, scale, v = self._operands()
        kw = dict(mode="kwn", k=12, drive_gain=0.25)
        outs = []
        for blocks in self.PLANS:
            cache.save_entries([_entry(blocks, m=self.M, k_dim=self.K_DIM,
                                       nc=self.NC, n=self.NC, t=self.T)],
                               merge=False)
            outs.append(ops.fused_macro_seq(
                x, msb, lsb, cb.boundaries, cb.levels, scale, v, None,
                mac_telemetry=False, **kw))
        for a, b in zip(outs[0][1:], outs[1][1:]):
            assert jnp.array_equal(a, b)


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------

class TestAutotune:
    CELL = autotune.TuneCell(8, 256, 128, 128, 3, 0.1)

    def test_candidates_include_heuristic_and_are_deduped(self):
        cands = autotune.enumerate_candidates(self.CELL)
        assert autotune.heuristic_blocks(self.CELL) in cands
        plans = [fused_macro.plan_tiles(
            self.CELL.m, self.CELL.k_dim, self.CELL.nc, self.CELL.n,
            self.CELL.t, bm=c[0], bk=c[1], bn=c[2], use_cache=False)
            for c in cands]
        assert len({(p.bm, p.bk, p.bn, p.grid) for p in plans}) == len(cands)

    def test_autotune_cell_entry_contract(self, cache_path):
        entry = autotune.autotune_cell(self.CELL, iters=2, verbose=False)
        for f in cache.REQUIRED_ENTRY_FIELDS:
            assert f in entry
        # the heuristic was measured as a candidate, so the winner meets
        # or beats it under the shared stopwatch — the >= 1.0 invariant
        assert entry["speedup_vs_heuristic"] >= 1.0
        assert entry["n_candidates"] >= 2         # 256-K shape splits
        assert entry["device_kind"] == cache.device_kind()

    def test_tune_round_trips_into_plan_tiles(self, cache_path):
        entries, path = autotune.tune((self.CELL,), iters=2, verbose=False)
        assert path == cache_path
        buckets = {e["density_bucket"] for e in entries}
        assert cache.ANY_BUCKET in buckets        # the serving-key rollup
        cache.clear_memo()
        plan = fused_macro.plan_tiles(
            self.CELL.m, self.CELL.k_dim, self.CELL.nc, self.CELL.n,
            self.CELL.t)
        won = next(e for e in entries
                   if e["density_bucket"] == cache.ANY_BUCKET)["plan"]
        assert (plan.bm, plan.bk, plan.bn) \
            == (won["bm"], won["bk"], won["bn"])

    def test_objectives_score_shapes(self):
        h = autotune.Measurement((8, 256, 128), 2.0, 10.0)
        m = autotune.Measurement((8, 128, 128), 1.0, 20.0)
        assert autotune._score(m, h, "ms", 0.5) == 1.0
        assert autotune._score(m, h, "pj_per_sop", 0.5) == 20.0
        blend = autotune._score(m, h, "blend", 0.5)
        assert blend == pytest.approx((0.5 ** 0.5) * (2.0 ** 0.5))
        with pytest.raises(ValueError):
            autotune.autotune_cell(self.CELL, objective="nope")

    @pytest.mark.fast
    def test_prior_is_finite_and_orders_candidates(self):
        cands = autotune.enumerate_candidates(self.CELL)
        scores = [autotune.prior_seconds(self.CELL, c) for c in cands]
        assert all(s > 0 and s < float("inf") for s in scores)

    @pytest.mark.fast
    def test_prior_guided_search_patience(self):
        from repro.launch.hillclimb import prior_guided_search
        calls = []
        best, score, results = prior_guided_search(
            [3, 1, 2, 5, 4], lambda c: calls.append(c) or float(c),
            prior=lambda c: c, patience=2)
        assert (best, score) == (1, 1.0)
        assert calls == [1, 2, 3]                 # stopped after 2 stalls

    @pytest.mark.fast
    def test_modeled_energy_penalizes_pad_dilution(self):
        """A plan that pads K 2x must charge more MAC energy per SOP."""
        cell = autotune.TuneCell(8, 128, 128, 128, 2, 1.0)
        x = measure.event_stream(jax.random.PRNGKey(0), 1.0, (2, 8, 128))
        tight = autotune.modeled_pj_per_sop(cell, (8, 128, 128), x, 20.0)
        padded = autotune.modeled_pj_per_sop(cell, (8, 256, 128), x, 20.0)
        assert padded > tight

    @pytest.mark.fast
    def test_modeled_energy_rewards_fine_gating(self):
        """Events confined to one K tile: fine blocks skip, coarse pay."""
        cell = autotune.TuneCell(8, 512, 128, 128, 2, 0.05)
        x = jnp.zeros((2, 8, 512), jnp.int8).at[:, :, :128].set(1)
        fine = autotune.modeled_pj_per_sop(cell, (8, 128, 128), x, 20.0)
        coarse = autotune.modeled_pj_per_sop(cell, (8, 512, 128), x, 20.0)
        assert fine < coarse
