"""Chaos harness for the preemptive SNNEventEngine: adversarial traffic
with hard assertions.

Each scenario throws a deliberately hostile trace at a live engine and
asserts the serving invariants the repo promises (docs/SERVING.md):

  burst_shed       oversized burst into a bounded queue -> typed REJECTED
                   outcomes, every submission reaches a terminal state,
                   accepted requests still serve with bitwise parity.
  malformed        NaN / non-ternary / wrong-shape / empty tensors -> the
                   typed lifecycle errors, and the engine keeps serving
                   clean traffic afterwards (no poisoned slot state).
  random_preempt   forced preemptions at randomized step offsets
                   (including non-multiples of round_steps), clean and
                   noisy -> results bitwise-identical to uninterrupted
                   one-shot runs, returned in submission order.
  hog_shorts       hog streams + prioritized shorts -> with preemption the
                   shorts' p95 latency is no worse than without it
                   (fairness SLO), and the hogs still finish exactly.
  deadline_storm   a storm of impossible + feasible deadlines -> expired
                   requests get the typed EXPIRED outcome, feasible ones
                   complete, nothing is silently dropped.

Any violated assertion exits nonzero — this is a gate, not a demo.

Usage:
  PYTHONPATH=src python tools/chaos_serve.py --smoke        # make chaos-smoke
  PYTHONPATH=src python tools/chaos_serve.py --seed 7
  PYTHONPATH=src python tools/chaos_serve.py --scenario random_preempt
"""

from __future__ import annotations

import argparse
import sys
import time


def _setup():
    import jax
    from repro.models import snn as snn_lib
    cfg = snn_lib.SNNConfig(n_in=32, n_hidden=16, n_classes=3, n_steps=8,
                            k=4)
    params = snn_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _events(rng, t, n_in=32, rate=0.25):
    import numpy as np
    return (rng.random((t, n_in)) < rate).astype(np.float32)


def _one_shot(params, cfg, req, noise=None):
    import jax.numpy as jnp
    import numpy as np
    from repro.models import snn as snn_lib
    logits, tele = snn_lib.forward_silicon(
        params, jnp.asarray(req.events)[None], cfg, req.key, fused="seq",
        noise=noise)
    return np.asarray(logits[0]), float(tele["adc_steps"][0])


def _check_parity(params, cfg, reqs, noise=None):
    import numpy as np
    from repro.serve import lifecycle
    for r in reqs:
        assert r.state == lifecycle.COMPLETED, \
            f"uid {r.uid}: state {r.state!r}, want completed"
        ref_logits, ref_adc = _one_shot(params, cfg, r, noise=noise)
        assert np.array_equal(np.asarray(r.logits), ref_logits), \
            f"uid {r.uid}: served logits != one-shot (bitwise)"
        assert r.adc_steps == ref_adc, \
            f"uid {r.uid}: adc_steps {r.adc_steps} != one-shot {ref_adc}"


def _terminal_ledger(engine, submitted):
    """Every submission must sit in exactly one terminal ledger."""
    from repro.serve import lifecycle
    fates = {id(r): r.state for r in
             engine.completed + engine.rejected + engine.expired}
    for r in submitted:
        st = fates.get(id(r))
        assert st in lifecycle.TERMINAL_STATES, \
            f"uid {r.uid}: no terminal state (got {st!r})"
    total = (len(engine.completed) + len(engine.rejected) +
             len(engine.expired))
    assert total == len(submitted), \
        f"ledger holds {total} requests, submitted {len(submitted)}"
    _metrics_match_ledgers(engine)


def _metrics_match_ledgers(engine):
    """The engine's metric counters must agree with its ledgers exactly.

    Chaos runs double as the observability gate: every scenario already
    drives terminal transitions hard, so if a code path ever bumps a
    ledger without its counter (or vice versa) it fails here for free.
    """
    m = engine.metrics
    for state, ledger in (("completed", engine.completed),
                          ("rejected", engine.rejected),
                          ("expired", engine.expired)):
        got = m.value("terminal_total", state=state)
        assert got == len(ledger), \
            f"terminal_total{{state={state}}} = {got}, " \
            f"ledger holds {len(ledger)}"
    assert m.value("shed_total") == len(engine.rejected), \
        f"shed_total {m.value('shed_total')} != {len(engine.rejected)}"
    assert m.value("expired_total") == len(engine.expired), \
        f"expired_total {m.value('expired_total')} != {len(engine.expired)}"
    assert m.value("preempted_total") == engine.preemption_count, \
        f"preempted_total {m.value('preempted_total')} != " \
        f"{engine.preemption_count}"


# --- scenarios -------------------------------------------------------------

def scenario_burst_shed(rng, smoke):
    from repro.serve.engine import EventRequest, SNNEventEngine
    cfg, params = _setup()
    n = 12 if smoke else 48
    cap = 4
    engine = SNNEventEngine(cfg, params, batch_slots=2, round_steps=4,
                            max_pending=cap, seed=3)
    reqs = [EventRequest(uid=i, priority=int(rng.integers(0, 3)),
                         events=_events(rng, int(rng.integers(4, 16))))
            for i in range(n)]
    for r in reqs:
        engine.submit(r)          # one giant burst, no draining between
    assert len(engine.pending) <= cap, "bounded queue overflowed"
    assert engine.rejected, "oversized burst shed nothing"
    engine.run()
    _terminal_ledger(engine, reqs)
    _check_parity(params, cfg, [r for r in reqs if r in engine.completed])
    return f"{len(engine.rejected)} shed, {len(engine.completed)} served"


def scenario_malformed(rng, smoke):
    import numpy as np
    from repro.serve import lifecycle
    from repro.serve.engine import EventRequest, SNNEventEngine
    cfg, params = _setup()
    engine = SNNEventEngine(cfg, params, batch_slots=2, round_steps=4)
    nan_ev = np.zeros((6, 32), np.float32)
    nan_ev[3, 7] = np.nan
    hostile = [
        (np.zeros((0, 32), np.float32), lifecycle.EmptyEventError),
        (np.zeros((4, 31), np.float32), lifecycle.EventShapeError),
        (np.zeros((4,), np.float32), lifecycle.EventShapeError),
        (nan_ev, lifecycle.NonFiniteEventError),
        (np.full((4, 32), 0.5, np.float32), lifecycle.NonTernaryEventError),
        (np.array([["x"] * 32] * 4), lifecycle.EventDtypeError),
    ]
    for i, (ev, want) in enumerate(hostile):
        try:
            engine.submit(EventRequest(uid=100 + i, events=ev))
        except want:
            pass
        else:
            raise AssertionError(
                f"hostile tensor #{i} not rejected with {want.__name__}")
    # the engine must still serve clean traffic exactly afterwards
    clean = [EventRequest(uid=i, events=_events(rng, 8)) for i in range(4)]
    for r in clean:
        engine.submit(r)
    engine.run()
    _check_parity(params, cfg, clean)
    _metrics_match_ledgers(engine)
    return f"{len(hostile)} hostile tensors rejected, engine healthy"


def scenario_random_preempt(rng, smoke):
    from repro.core import ima as ima_lib
    from repro.serve.engine import EventRequest, SNNEventEngine
    cfg, params = _setup()
    cases = 2 if smoke else 6
    summary = []
    for case in range(cases):
        noise = None if case % 2 == 0 else ima_lib.IMANoiseModel()
        n = 5 if smoke else 8
        engine = SNNEventEngine(cfg, params, batch_slots=3, round_steps=4,
                                seed=int(rng.integers(0, 99)), noise=noise)
        reqs = [EventRequest(uid=i,
                             events=_events(rng, int(rng.integers(5, 24))))
                for i in range(n)]
        for r in reqs:
            engine.submit(r)
        budget = [3]

        def hook(eng):
            if not budget[0] or rng.random() < 0.4:
                return
            live = [(i, r) for i, r in enumerate(eng._slot_req)
                    if r is not None]
            if not live:
                return
            slot, victim = live[int(rng.integers(0, len(live)))]
            done = int(eng._slot_done[slot])
            length = int(eng._slot_len[slot])
            if done >= length - 1:
                return
            at = int(rng.integers(done + 1, length))  # any offset
            eng.preempt_request(victim.uid, at_step=at, backoff=False)
            budget[0] -= 1

        done = engine.run(round_hook=hook)
        assert [r.uid for r in done] == [r.uid for r in reqs], \
            "results not in submission order"
        _check_parity(params, cfg, reqs, noise=noise)
        _metrics_match_ledgers(engine)
        summary.append(engine.preemption_count)
    return f"preemptions per case: {summary}, all bitwise-exact"


def _hog_shorts_trace(rng, smoke):
    import numpy as np
    hog_t, short_t = (48, 6) if smoke else (96, 8)
    n_hogs, n_shorts = (2, 6) if smoke else (2, 12)
    rng = np.random.default_rng(rng)
    hogs = [_events(rng, hog_t) for _ in range(n_hogs)]
    shorts = [_events(rng, short_t) for _ in range(n_shorts)]
    return hogs, shorts


def _run_hog_shorts(params, cfg, hogs, shorts, preemptive):
    import numpy as np
    from repro.serve.engine import EventRequest, SNNEventEngine
    engine = SNNEventEngine(cfg, params, batch_slots=2, round_steps=4,
                            preemptive=preemptive, preempt_quantum=1,
                            backoff_rounds=1, seed=5)
    hog_reqs = [EventRequest(uid=i, priority=0, events=ev)
                for i, ev in enumerate(hogs)]
    for r in hog_reqs:
        engine.submit(r)
    engine.run(max_rounds=1)      # hogs take residence first
    short_reqs = [EventRequest(uid=100 + i, priority=1, events=ev)
                  for i, ev in enumerate(shorts)]
    for r in short_reqs:
        engine.submit(r)
    engine.run()
    lat = sorted(r.latency_ms for r in short_reqs)
    p95 = lat[min(len(lat) - 1, int(len(lat) * 0.95))]
    return engine, hog_reqs, short_reqs, p95


def scenario_hog_shorts(rng, smoke):
    cfg, params = _setup()
    seed = int(rng.integers(0, 2 ** 31))
    hogs, shorts = _hog_shorts_trace(seed, smoke)
    # warmup run compiles every jit entry both runs need: the comparison
    # below then measures scheduling, not compilation order
    _run_hog_shorts(params, cfg, hogs, shorts, preemptive=True)
    eng_on, hogs_on, shorts_on, p95_on = _run_hog_shorts(
        params, cfg, hogs, shorts, preemptive=True)
    eng_off, hogs_off, shorts_off, p95_off = _run_hog_shorts(
        params, cfg, hogs, shorts, preemptive=False)
    assert eng_on.preemption_count >= 1, "hog trace triggered no preemption"
    assert eng_off.preemption_count == 0
    _check_parity(params, cfg, hogs_on + shorts_on)
    _metrics_match_ledgers(eng_on)
    _metrics_match_ledgers(eng_off)
    # fairness SLO: preemption must not make the shorts *worse* (generous
    # 1.5x guard band: interpret-mode timings jitter, the structural gap
    # in this trace is ~2-3x the other way)
    assert p95_on <= p95_off * 1.5, \
        f"shorts p95 with preemption {p95_on:.1f}ms worse than " \
        f"without {p95_off:.1f}ms"
    return (f"shorts p95: {p95_on:.1f}ms preemptive vs {p95_off:.1f}ms "
            f"FIFO ({eng_on.preemption_count} preemptions)")


def scenario_deadline_storm(rng, smoke):
    from repro.serve import lifecycle
    from repro.serve.engine import EventRequest, SNNEventEngine
    cfg, params = _setup()
    n = 8 if smoke else 24
    engine = SNNEventEngine(cfg, params, batch_slots=2, round_steps=4,
                            seed=11)
    reqs = []
    for i in range(n):
        impossible = i % 3 == 0
        reqs.append(EventRequest(
            uid=i, deadline_ms=0.0 if impossible else 60_000.0,
            events=_events(rng, int(rng.integers(4, 12)))))
    for r in reqs:
        engine.submit(r)
    engine.run()
    _terminal_ledger(engine, reqs)
    want_expired = [r for r in reqs if r.deadline_ms == 0.0]
    for r in want_expired:
        assert r.state == lifecycle.EXPIRED, \
            f"uid {r.uid}: impossible deadline not expired ({r.state})"
    served = [r for r in reqs if r.deadline_ms > 0.0]
    _check_parity(params, cfg, served)
    assert all(r.deadline_missed is False for r in served)
    return f"{len(want_expired)} expired (typed), {len(served)} on time"


SCENARIOS = {
    "burst_shed": scenario_burst_shed,
    "malformed": scenario_malformed,
    "random_preempt": scenario_random_preempt,
    "hog_shorts": scenario_hog_shorts,
    "deadline_storm": scenario_deadline_storm,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--seed", type=int, default=0,
                    help="trace RNG seed (traces are seeded + replayable)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced trace sizes for CI (~1 min)")
    ap.add_argument("--scenario", choices=sorted(SCENARIOS), default=None,
                    help="run one scenario instead of all")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export a Perfetto trace of the whole chaos run")
    args = ap.parse_args(argv)

    if args.trace_out:
        from repro.obs import trace as obs_trace
        obs_trace.set_tracer(obs_trace.Tracer(enabled=True))

    import numpy as np
    names = [args.scenario] if args.scenario else list(SCENARIOS)
    failures = 0
    for name in names:
        # zlib.crc32, not hash(): str hashing is salted per process and
        # would break trace replayability across runs
        import zlib
        rng = np.random.default_rng(
            args.seed * 1000 + zlib.crc32(name.encode()) % 997)
        t0 = time.perf_counter()
        try:
            detail = SCENARIOS[name](rng, args.smoke)
            status = "ok"
        except AssertionError as e:
            detail, status, failures = str(e), "FAIL", failures + 1
        dt = time.perf_counter() - t0
        print(f"[chaos] {name:16s} {status:4s} ({dt:5.1f}s)  {detail}")
    if args.trace_out:
        from repro.obs import trace as obs_trace
        n = obs_trace.get_tracer().export(args.trace_out)
        print(f"[chaos] wrote {n} spans to {args.trace_out}")
    if failures:
        print(f"[chaos] {failures} scenario(s) violated serving invariants")
        return 1
    print(f"[chaos] all {len(names)} scenarios hold (seed {args.seed})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
