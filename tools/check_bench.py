"""Validate BENCH_fused_macro.json: schema + clean-path perf regression gate.

Two checks, stdlib only (runs in the minimal container and in CI):

1. **Schema**: the file is ``{"bench": "fused_macro", "records": [...]}``
   and every record carries exactly the fixed keys
   ``op / shape / mode / median_ms / speedup / density`` with the right
   types (plus an *optional* ``obs`` block — round-time quantiles and
   the measured skip rate — validated when present, never gated) — so
   the perf-trajectory artifact stays diffable and downstream tooling
   never meets a silently renamed field.  The canonical op set
   (``REQUIRED_OPS`` — the clean-path serving ops plus the ``train_step``
   rows the silicon-training subsystem added) must each appear at least
   once, so a refactor cannot silently drop a tracked hot path from the
   artifact.  Ops in ``MIN_SPEEDUP_OPS`` additionally carry a speedup
   floor — ``tuned_vs_heuristic`` must report >= 1.0 (the autotuner's
   structural invariant) and ``serve_preempt_on`` must report >= 1.0
   (the scheduler fairness floor: shorts' p95 latency with preemption
   must not be worse than FIFO on the hog trace, same-run ratio so
   machine speed cancels).

2. **Regression gate** (``--baseline PATH``): every *tracked clean-path*
   record (``mode == "kwn"`` with a baseline median of at least
   ``MIN_TRACKED_MS``) present in both files is compared by
   ``(op, shape, mode, density)`` key plus occurrence index (some ops
   appear twice under one key — see ``_indexed``); the run fails if any
   record regresses more
   than ``--tolerance`` (default 20 %) in median wall time.  Medians are
   first normalized by each file's own ``composed_step`` @ 128x256x128
   record — the canonical baseline op — so the gate tracks *relative*
   hot-path regressions rather than raw machine speed (CI runners and dev
   boxes differ by more than any real regression we want to catch; an
   unnormalized gate would flap on every hardware change).  A machine-wide
   slowdown therefore passes; a fused-path-specific one fails.  Records
   under the ``MIN_TRACKED_MS`` floor (the fastest gated single-step
   points) are schema-checked but not perf-gated: interpret-mode medians
   that small are dominated by dispatch jitter, not kernel work.

Usage:
  python tools/check_bench.py BENCH_fused_macro.json                 # schema
  python tools/check_bench.py NEW.json --baseline COMMITTED.json     # + gate
"""

from __future__ import annotations

import argparse
import json
import sys

RECORD_KEYS = {"op", "shape", "mode", "median_ms", "speedup", "density"}
RECORD_TYPES = {"op": str, "shape": str, "mode": str,
                "median_ms": (int, float), "speedup": (int, float),
                "density": (int, float)}
# Optional per-record observability block (PR 10): informative round-time
# quantiles + measured skip rate.  Schema-validated when present, never
# perf-gated — interpret-mode round quantiles are too jittery to gate on.
OBS_KEYS = {"round_ms_p50", "round_ms_p95", "skipped_block_ratio"}
MODES = {"kwn", "kwn+noise"}
# Every tracked hot path must appear in the artifact at least once:
# the serving-side fused ops, the training-side step rows (software
# BPTT baseline + the fused-VJP silicon step, clean and noisy QAT), the
# end-to-end serving rows (continuous-batching engine vs the
# drain-the-queue baseline over the mixed-length request trace), and the
# autotuner rows (cache-tuned tile plan vs the heuristic plan, per cell).
REQUIRED_OPS = {"composed_step", "fused_step", "fused_seq_time_major",
                "fused_seq_noisy", "fused_seq_gated", "fused_seq_dense",
                "fused_seq_2layer", "fused_seq_2layer_roundtrip",
                "train_step_bptt", "train_step_silicon_vjp",
                "serve_stream_drain", "serve_stream_continuous",
                "serve_stream_noisy",
                "serve_preempt_off", "serve_preempt_on",
                "fused_seq_heuristic_plan", "tuned_vs_heuristic"}
# Structural invariants, not perf taste:
# - tuned_vs_heuristic: the heuristic is always in the autotuner's
#   candidate set and the bench re-measures both plans in the same run,
#   reporting the better one as tuned — a row below 1.0 means the
#   plan-resolution path regressed, not that a machine got noisy.
# - serve_preempt_on: the fairness floor.  median_ms on the serve_preempt
#   rows is the shorts' p95 latency on the hog+shorts trace, and speedup
#   is p95_fifo / p95_preemptive measured in the *same* bench run — so
#   machine speed cancels out, and a value below 1.0 means enabling
#   preemption made the latency-sensitive traffic *worse*: the scheduler
#   itself regressed (the trace's structural gap is ~2x in its favor).
MIN_SPEEDUP_OPS = {"tuned_vs_heuristic": 1.0, "serve_preempt_on": 1.0}
NORMALIZER = ("composed_step", "128x256x128", "kwn")
TRACKED_MODE = "kwn"   # clean path only: noise overhead is measured, not gated
MIN_TRACKED_MS = 5.0   # below this, interpret-mode medians are pure jitter
# Per-op tolerance overrides (else --tolerance applies).  The continuous
# serving row carries the tight observability-overhead gate: the
# instrumented engine runs with tracing *disabled* in the bench, and the
# disabled fast path must cost < 2% of round throughput.
TOLERANCE_OVERRIDES = {"serve_stream_continuous": 0.02}


def _check_obs(obs) -> list[str]:
    """Schema errors in one record's optional ``obs`` block."""
    if not isinstance(obs, dict):
        return [f"obs: want an object, got {type(obs).__name__}"]
    errs = []
    if set(obs) != OBS_KEYS:
        errs.append(f"obs keys {sorted(obs)} != {sorted(OBS_KEYS)}")
        return errs
    for key in OBS_KEYS:
        if not isinstance(obs[key], (int, float)) \
                or isinstance(obs[key], bool) or obs[key] < 0:
            errs.append(f"obs.{key}: bad value {obs[key]!r}")
    if not errs:
        if obs["round_ms_p50"] > obs["round_ms_p95"]:
            errs.append(f"obs: round_ms_p50 {obs['round_ms_p50']} > "
                        f"p95 {obs['round_ms_p95']}")
        if obs["skipped_block_ratio"] > 1.0:
            errs.append(f"obs.skipped_block_ratio: "
                        f"{obs['skipped_block_ratio']} > 1")
    return errs


def check_schema(doc: dict) -> list[str]:
    errs = []
    if doc.get("bench") != "fused_macro":
        errs.append(f"bench field: want 'fused_macro', got {doc.get('bench')!r}")
    records = doc.get("records")
    if not isinstance(records, list) or not records:
        return errs + ["records: want a non-empty list"]
    for i, rec in enumerate(records):
        if not isinstance(rec, dict):
            errs.append(f"records[{i}]: not an object")
            continue
        keys = set(rec)
        if keys - {"obs"} != RECORD_KEYS:
            errs.append(f"records[{i}] ({rec.get('op')}): keys {sorted(keys)}"
                        f" != {sorted(RECORD_KEYS)} (+ optional 'obs')")
            continue
        if "obs" in rec:
            errs.extend(f"records[{i}] ({rec.get('op')}): {e}"
                        for e in _check_obs(rec["obs"]))
        for key, typ in RECORD_TYPES.items():
            if not isinstance(rec[key], typ) or isinstance(rec[key], bool):
                errs.append(f"records[{i}].{key}: bad type {type(rec[key])}")
        if rec["mode"] not in MODES:
            errs.append(f"records[{i}].mode: {rec['mode']!r} not in {MODES}")
        if isinstance(rec["median_ms"], (int, float)) and rec["median_ms"] <= 0:
            errs.append(f"records[{i}].median_ms: {rec['median_ms']} <= 0")
        if isinstance(rec["density"], (int, float)) \
                and not 0.0 <= rec["density"] <= 1.0:
            errs.append(f"records[{i}].density: {rec['density']} not in [0,1]")
        floor = MIN_SPEEDUP_OPS.get(rec["op"])
        if floor is not None and isinstance(rec["speedup"], (int, float)) \
                and rec["speedup"] < floor:
            errs.append(f"records[{i}] ({rec['op']} @ {rec['shape']}): "
                        f"speedup {rec['speedup']} < required {floor}")
    seen_ops = {rec.get("op") for rec in records if isinstance(rec, dict)}
    missing = REQUIRED_OPS - seen_ops
    if missing:
        errs.append(f"missing required ops: {sorted(missing)}")
    return errs


def _key(rec: dict):
    return (rec["op"], rec["shape"], rec["mode"], rec["density"])


def _indexed(records: list[dict]) -> dict:
    """Tracked records keyed by (op, shape, mode, density, occurrence).

    Some ops legitimately appear twice with an identical key — e.g.
    ``fused_seq_time_major`` is both the sequence-cadence row and the
    noisy section's clean baseline, measured minutes apart.  A plain
    dict would pair every new duplicate against the *last* baseline
    duplicate (first-vs-last aliasing), so two same-run medians that
    differ by normal jitter read as a regression.  The occurrence index
    pairs each duplicate with its positional twin instead.
    """
    seen: dict = {}
    out: dict = {}
    for rec in records:
        if rec["mode"] != TRACKED_MODE:
            continue
        k = _key(rec)
        n = seen.get(k, 0)
        seen[k] = n + 1
        out[k + (n,)] = rec
    return out


def _normalizer(records: list[dict]) -> float:
    for rec in records:
        if (rec["op"], rec["shape"], rec["mode"]) == NORMALIZER:
            return float(rec["median_ms"])
    raise SystemExit(f"no normalizer record {NORMALIZER} in file")


def check_regressions(new: dict, base: dict, tolerance: float) -> list[str]:
    n_new = _normalizer(new["records"])
    n_base = _normalizer(base["records"])
    base_by_key = {k: r for k, r in _indexed(base["records"]).items()
                   if r["median_ms"] >= MIN_TRACKED_MS}
    errs = []
    compared = 0
    for key, rec in _indexed(new["records"]).items():
        if key not in base_by_key:
            continue
        compared += 1
        rel_new = rec["median_ms"] / n_new
        rel_base = base_by_key[key]["median_ms"] / n_base
        tol = min(tolerance, TOLERANCE_OVERRIDES.get(rec["op"], tolerance))
        if rel_new > rel_base * (1.0 + tol):
            errs.append(
                f"{rec['op']} @ {rec['shape']} d={rec['density']}"
                f"{f' #{key[-1]}' if key[-1] else ''}: "
                f"normalized median {rel_new:.3f} vs baseline "
                f"{rel_base:.3f} (+{100 * (rel_new / rel_base - 1):.0f}%, "
                f"tolerance {100 * tol:.0f}%)")
    if compared == 0:
        errs.append("no tracked records in common with the baseline")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench_json", help="freshly measured records to validate")
    ap.add_argument("--baseline", default=None,
                    help="committed records to gate regressions against")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed relative median regression (default 0.20)")
    args = ap.parse_args(argv)

    with open(args.bench_json) as f:
        new = json.load(f)
    errs = check_schema(new)
    if errs:
        print(f"{args.bench_json}: SCHEMA FAIL")
        for e in errs:
            print(f"  {e}")
        return 1
    print(f"{args.bench_json}: schema OK "
          f"({len(new['records'])} records)")

    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        base_errs = check_schema(base)
        if base_errs:
            print(f"{args.baseline}: baseline schema invalid; "
                  f"skipping regression gate")
            for e in base_errs:
                print(f"  {e}")
            return 1
        regs = check_regressions(new, base, args.tolerance)
        if regs:
            print("REGRESSION FAIL")
            for r in regs:
                print(f"  {r}")
            return 1
        print(f"regression gate OK (tolerance "
              f"{100 * args.tolerance:.0f}%, normalized by "
              f"{NORMALIZER[0]} @ {NORMALIZER[1]})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
