#!/usr/bin/env python
"""Stdlib fallback linter: the floor `make lint` enforces everywhere.

The CI image installs ruff (see ruff.toml for the real rule set); this
container does not, and the no-new-deps rule forbids installing it.  This
script keeps the lint gate meaningful in both worlds with zero
dependencies: it parses every file with ``ast`` and reports

  * syntax errors (anything that does not parse),
  * unused imports (the F401 class — by far the most common rot in a
    fast-growing repo), honouring ``# noqa`` on the import line,
  * tabs in indentation and trailing whitespace (formatting drift that
    ruff's E/W rules would flag).

Exit status is non-zero on any finding, so `make lint` fails the same way
locally and in CI.
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path


def _iter_py_files(paths: list[str]):
    for p in paths:
        path = Path(p)
        if path.is_file() and path.suffix == ".py":
            yield path
        elif path.is_dir():
            yield from sorted(path.rglob("*.py"))


def _used_names(tree: ast.AST) -> set[str]:
    used: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            # the root of a dotted use: pkg.mod.attr -> pkg
            inner = node.value
            while isinstance(inner, ast.Attribute):
                inner = inner.value
            if isinstance(inner, ast.Name):
                used.add(inner.id)
    return used


def _import_findings(tree: ast.AST, lines: list[str],
                     is_init: bool) -> list[tuple[int, str]]:
    if is_init:
        return []       # __init__ re-exports are intentional
    used = _used_names(tree)
    # names exported via __all__ count as used (and nothing else: a
    # docstring mentioning a module's name must not launder its import)
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "__all__"
                for t in node.targets):
            for el in ast.walk(node.value):
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    used.add(el.value)
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.Import, ast.ImportFrom)):
            continue
        line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
        if "noqa" in line:
            continue
        if isinstance(node, ast.ImportFrom) and node.module == "__future__":
            continue
        for alias in node.names:
            if alias.name == "*":
                continue
            bound = alias.asname or alias.name.split(".")[0]
            if bound not in used:
                out.append((node.lineno, f"unused import '{bound}' (F401)"))
    return out


def _whitespace_findings(lines: list[str]) -> list[tuple[int, str]]:
    out = []
    for i, line in enumerate(lines, 1):
        body = line.rstrip("\n")
        if body != body.rstrip():
            out.append((i, "trailing whitespace (W291)"))
        stripped = body.lstrip(" ")
        if stripped.startswith("\t"):
            out.append((i, "tab in indentation (W191)"))
    return out


def lint_file(path: Path) -> list[str]:
    src = path.read_text()
    lines = src.splitlines()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [f"{path}:{e.lineno}: syntax error: {e.msg} (E999)"]
    findings = _import_findings(tree, lines, path.name == "__init__.py")
    findings += _whitespace_findings(lines)
    return [f"{path}:{ln}: {msg}" for ln, msg in sorted(findings)]


def main(argv: list[str]) -> int:
    paths = argv or ["src", "tests", "benchmarks", "examples", "tools"]
    problems: list[str] = []
    n_files = 0
    for f in _iter_py_files(paths):
        n_files += 1
        problems += lint_file(f)
    for p in problems:
        print(p)
    print(f"fallback lint: {n_files} files, {len(problems)} finding(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
